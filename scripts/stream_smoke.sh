#!/usr/bin/env bash
# End-to-end smoke test for `fastcv stream` (docs/STREAM.md).
#
# 1. Generates a deterministic synthetic NDJSON sample stream.
# 2. Runs it through `--exact-refresh-every 1` and `--rebuild`: K=1
#    degenerates to the rebuild reference, so the two outputs must be
#    **byte-identical** (the bitwise exact-refresh contract).
# 3. Runs the pure-incremental mode and asserts per-step agreement with
#    the rebuild reference within tolerance (accuracy ≤ one 1/n quantum,
#    p-value within the n_perm resolution).
# 4. Re-runs the incremental mode and asserts byte-identical output
#    (same-sequence determinism).
#
#   scripts/stream_smoke.sh                # builds target/release/fastcv if absent
#   FASTCV_BIN=path/to/fastcv scripts/stream_smoke.sh
set -euo pipefail
cd "$(dirname "$0")/.."

BIN="${FASTCV_BIN:-target/release/fastcv}"
if [ ! -x "$BIN" ]; then
  echo "== stream_smoke: building release binary =="
  cargo build --release
fi
if ! command -v python3 >/dev/null 2>&1; then
  echo "stream_smoke: python3 is required to generate/compare NDJSON" >&2
  exit 1
fi

TMP="$(mktemp -d "${TMPDIR:-/tmp}/fastcv-stream-smoke.XXXXXX")"
trap 'rm -rf "$TMP"' EXIT

STREAM_FLAGS=(--window 24 --lambda 2.0 --folds 4 --n-perm 8 --seed 7)

echo "== stream_smoke: generating synthetic sample stream =="
python3 - > "$TMP/samples.ndjson" <<'PY'
import json, random
rng = random.Random(2018)
for _ in range(80):
    label = rng.randrange(2)
    shift = 0.8 if label == 0 else -0.8
    x = [rng.gauss(shift, 1.0) for _ in range(6)]
    print(json.dumps({"x": [round(v, 6) for v in x], "label": label}))
PY

echo "== stream_smoke: K=1 exact refresh vs rebuild reference (byte-identical) =="
"$BIN" stream "${STREAM_FLAGS[@]}" --exact-refresh-every 1 \
  < "$TMP/samples.ndjson" > "$TMP/refresh1.ndjson" 2> "$TMP/refresh1.log"
"$BIN" stream "${STREAM_FLAGS[@]}" --rebuild \
  < "$TMP/samples.ndjson" > "$TMP/rebuild.ndjson" 2> "$TMP/rebuild.log"
diff -u "$TMP/rebuild.ndjson" "$TMP/refresh1.ndjson"

echo "== stream_smoke: incremental vs rebuild (per-step tolerance) =="
"$BIN" stream "${STREAM_FLAGS[@]}" \
  < "$TMP/samples.ndjson" > "$TMP/incremental.ndjson" 2> "$TMP/incremental.log"
python3 - "$TMP" <<'PY'
import json, pathlib, sys

tmp = pathlib.Path(sys.argv[1])
inc = [json.loads(l) for l in (tmp / "incremental.ndjson").read_text().splitlines() if l.strip()]
reb = [json.loads(l) for l in (tmp / "rebuild.ndjson").read_text().splitlines() if l.strip()]
assert inc and len(inc) == len(reb), f"step counts differ: {len(inc)} vs {len(reb)}"
n_perm = 8
for a, b in zip(inc, reb):
    assert (a["step"], a["n"], a["evicted"]) == (b["step"], b["n"], b["evicted"]), (a, b)
    # Accuracy is 1/n-quantised; the tiny factor drift may move at most
    # one sample across the decision threshold per step.
    assert abs(a["acc"] - b["acc"]) <= 1.0 / a["n"] + 1e-12, (a, b)
    assert abs(a["p"] - b["p"]) <= 2.0 / (1.0 + n_perm) + 1e-12, (a, b)
maintained = sum(1 for a in inc if not a["refreshed"])
assert maintained > len(inc) // 2, f"incremental mode barely maintained: {maintained}/{len(inc)}"
print(f"stream_smoke: {len(inc)} steps agree ({maintained} maintained incrementally)")
PY
grep -q "downdate rescue" "$TMP/incremental.log"

echo "== stream_smoke: same-sequence determinism (byte-identical rerun) =="
"$BIN" stream "${STREAM_FLAGS[@]}" \
  < "$TMP/samples.ndjson" > "$TMP/incremental2.ndjson" 2>/dev/null
diff -u "$TMP/incremental.ndjson" "$TMP/incremental2.ndjson"

echo "stream_smoke: OK"
