#!/usr/bin/env bash
# Perf-trajectory recorder: run the ablation benches and write their
# BENCH_*.json artifacts to the repo root (or $FASTCV_BENCH_OUT), so the
# performance trajectory of the Gram backends, the tiled engine, and the
# out-of-core spill layer is actually recorded per machine.
#
#   scripts/bench.sh                         # full-scale ablations
#   FASTCV_BENCH_SCALE=tiny scripts/bench.sh # CI-sized smoke run
#   FASTCV_BENCH_OUT=results scripts/bench.sh
#
# Wired into scripts/verify.sh behind BENCH=1 (the default verify run keeps
# only the quick permutation-engine trajectory).
set -euo pipefail
cd "$(dirname "$0")/.."

OUT="${FASTCV_BENCH_OUT:-.}"
for b in ablation_backend ablation_tiling ablation_spill ablation_serve ablation_stream linalg_kernels; do
  echo "== bench: $b =="
  FASTCV_BENCH_OUT="$OUT" cargo bench --bench "$b"
done
echo "bench: wrote $OUT/BENCH_backend.json $OUT/BENCH_tiling.json $OUT/BENCH_spill.json $OUT/BENCH_serve.json $OUT/BENCH_stream.json $OUT/BENCH_gemm.json"
