#!/usr/bin/env bash
# End-to-end smoke test for the `fastcv serve` daemon (docs/SERVE.md).
#
# 1. Runs the Fig. 3a sweep twice: once through the CLI with a shared
#    FactorStore (`fastcv sweep --cache`), once as a `sweep` request to a
#    `fastcv serve` daemon over stdin/stdout NDJSON.
# 2. Diffs the deterministic TSV columns (everything except the wall-clock
#    fields t_std / t_ana / t_point / rel_eff and the run-local cache
#    counters) — the daemon must answer bit-identically to the CLI.
# 3. Sends two identical permutation requests and asserts they answer the
#    same observed accuracy / p-value (the coalescing determinism contract).
# 4. Asserts the daemon's store reported at least one cache hit.
#
#   scripts/serve_smoke.sh                 # builds target/release/fastcv if absent
#   FASTCV_BIN=path/to/fastcv scripts/serve_smoke.sh
set -euo pipefail
cd "$(dirname "$0")/.."

BIN="${FASTCV_BIN:-target/release/fastcv}"
if [ ! -x "$BIN" ]; then
  echo "== serve_smoke: building release binary =="
  cargo build --release
fi
if ! command -v python3 >/dev/null 2>&1; then
  echo "serve_smoke: python3 is required to parse NDJSON responses" >&2
  exit 1
fi

TMP="$(mktemp -d "${TMPDIR:-/tmp}/fastcv-serve-smoke.XXXXXX")"
trap 'rm -rf "$TMP"' EXIT
SEED=2018

echo "== serve_smoke: CLI reference sweep (f3a tiny, --cache) =="
"$BIN" sweep --exp f3a --scale tiny --seed "$SEED" --workers 1 --cache \
  --out "$TMP/cli" >/dev/null

echo "== serve_smoke: daemon sweep + coalesced perms over stdin =="
cat > "$TMP/requests.ndjson" <<EOF
{"id":1,"op":"sweep","exp":"f3a","scale":"tiny","seed":$SEED,"workers":1}
{"id":2,"op":"perm","data":{"synthetic":{"n":24,"p":12,"seed":5}},"folds":{"k":4},"lambda":1.0,"n_perm":8,"seed":100}
{"id":3,"op":"perm","data":{"synthetic":{"n":24,"p":12,"seed":5}},"folds":{"k":4},"lambda":1.0,"n_perm":8,"seed":100}
{"id":4,"op":"stats"}
{"id":5,"op":"shutdown"}
EOF
"$BIN" serve --workers 1 < "$TMP/requests.ndjson" > "$TMP/responses.ndjson"

python3 - "$TMP" <<'PY'
import json, pathlib, sys

tmp = pathlib.Path(sys.argv[1])
resp = {}
for raw in (tmp / "responses.ndjson").read_text().splitlines():
    if raw.strip():
        r = json.loads(raw)
        resp[int(r["id"])] = r
for i in (1, 2, 3, 4, 5):
    assert i in resp, f"missing response id {i}: got {sorted(resp)}"
    assert resp[i].get("ok") is True, f"response {i} not ok: {resp[i]}"

(tmp / "serve.tsv").write_text(resp[1]["tsv"])

for field in ("observed", "p_value", "n_perm", "backend"):
    a, b = resp[2][field], resp[3][field]
    assert a == b, f"identical perm requests disagree on {field}: {a} != {b}"

stats = resp[4]
assert stats["hits"] >= 1, f"expected >= 1 factor-store hit, got {stats}"
print(
    f"serve_smoke: {len(resp)} responses; store hits={stats['hits']:.0f} "
    f"misses={stats['misses']:.0f}; perm observed={resp[2]['observed']:.4f} "
    f"p={resp[2]['p_value']:.4f}"
)
PY

# Deterministic TSV columns: 1-11 = exp..rep, 16-17 = acc_std/acc_ana.
# Excluded: 12-15 are wall-clock (t_std, t_ana, t_point, rel_eff) and 18 is
# the run-local cache counter column.
echo "== serve_smoke: diff CLI sweep vs daemon sweep (non-timing columns) =="
cut -f1-11,16,17 "$TMP/cli/sweep_f3a.tsv" > "$TMP/cli.cut"
cut -f1-11,16,17 "$TMP/serve.tsv" > "$TMP/serve.cut"
diff -u "$TMP/cli.cut" "$TMP/serve.cut"

# Kill-and-restart: SIGTERM a socket-mode daemon mid-serve — the handler
# must unlink the socket file (docs/ROBUSTNESS.md) — then restart on the
# same spill directory, which must come up clean (sweeping any leftovers)
# and answer requests again.
echo "== serve_smoke: SIGTERM cleanup + restart on the same spill dir =="
SOCK="$TMP/serve.sock"
SPILL="$TMP/spill"
"$BIN" serve --workers 1 --socket "$SOCK" --spill-dir "$SPILL" \
  2> "$TMP/daemon1.log" &
DAEMON=$!
for _ in $(seq 1 100); do
  [ -S "$SOCK" ] && break
  sleep 0.1
done
[ -S "$SOCK" ] || { echo "serve_smoke: socket never appeared" >&2; exit 1; }
# Plant a store directory "abandoned by a crashed process" (a PID the
# restart cannot own) so the startup sweep has something to quarantine.
mkdir -p "$SPILL/store-1-0"
echo "half a write" > "$SPILL/store-1-0/panel_0.tmp"
kill -TERM "$DAEMON"
wait "$DAEMON" 2>/dev/null || true
if [ -e "$SOCK" ]; then
  echo "serve_smoke: SIGTERM left a stale socket file behind" >&2
  exit 1
fi
printf '%s\n%s\n' \
  '{"id":1,"op":"stats"}' \
  '{"id":2,"op":"shutdown"}' \
  | "$BIN" serve --workers 1 --spill-dir "$SPILL" \
  > "$TMP/restart.ndjson" 2> "$TMP/daemon2.log"
grep -q 'quarantined 1 orphaned' "$TMP/daemon2.log" \
  || { echo "serve_smoke: restart did not quarantine the orphan" >&2; \
       cat "$TMP/daemon2.log" >&2; exit 1; }
python3 - "$TMP/restart.ndjson" <<'PY'
import json, pathlib, sys
lines = [json.loads(l) for l in pathlib.Path(sys.argv[1]).read_text().splitlines() if l.strip()]
assert len(lines) == 2, f"restarted daemon answered {len(lines)} lines"
assert all(r.get("ok") is True for r in lines), f"restart responses not ok: {lines}"
print("serve_smoke: restart after SIGTERM served cleanly")
PY

echo "serve_smoke: OK"
