#!/usr/bin/env bash
# Tier-1 verification + perf-trajectory artifact.
#
#   scripts/verify.sh          # build + test (hard gates), style (advisory),
#                              # then emit BENCH_perm.json via the
#                              # permutation-engine ablation bench
#   FASTCV_SKIP_BENCH=1 scripts/verify.sh   # skip the bench step
#
# fastcv-lint and clippy are hard gates (clippy only when the component is
# installed); rustfmt stays advisory. CI runs them the same way.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1: cargo build --release =="
cargo build --release

# Static analysis runs before any test: a determinism/safety violation
# (docs/LINTS.md) fails fast, with file:line diagnostics on stdout.
echo "== lint: fastcv-lint (docs/LINTS.md) =="
cargo run --release --bin lint

echo "== tier-1: cargo test -q =="
cargo test -q

# The backend-equivalence property tests are the contract that makes the
# Gram-backend knob a pure wall-clock choice; run them by name so a filtered
# or flaky-skipped suite can never silently drop them.
echo "== backend equivalence: cargo test -q backend_ =="
cargo test -q backend_

# The tiled-engine property tests are the contract that makes TilePolicy a
# pure memory/wall-clock knob (tiled K_c + blocked Cholesky bitwise equal
# to the one-shot kernels); run them by name so they can never be dropped.
echo "== tiled-engine equivalence: cargo test -q tiled_ =="
cargo test -q tiled_

# The spill-layer property tests are the contract that makes the
# out-of-core mode (PanelStore + left-looking chol_spill + streamed
# solves) bitwise equal to the in-RAM kernels; run them by name too.
echo "== spill-layer equivalence: cargo test -q spill_ =="
cargo test -q spill_

# The kernel-conformance suite is the contract that makes the SIMD ISA a
# pure wall-clock knob (every (kernel, ISA) pair bitwise equal to the
# scalar reference under forced dispatch); run it by name too.
echo "== kernel conformance: cargo test -q kernel_conformance_ =="
cargo test -q kernel_conformance_

# The streaming property suite is the contract behind the incremental
# engine: up/downdate algebra, incremental-vs-rebuild agreement (bitwise
# on exact-refresh steps), determinism, and ISA invariance of the rolling
# factor (docs/STREAM.md); run it by name too.
echo "== streaming engine: cargo test -q stream_ =="
cargo test -q stream_

echo "== benches compile: cargo bench --no-run =="
cargo bench --no-run

# Docs are a hard gate: broken intra-doc links (or any rustdoc warning)
# fail the build, keeping README/BACKENDS.md's module map trustworthy.
echo "== docs: cargo doc --no-deps (RUSTDOCFLAGS=-D warnings) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

if cargo fmt --version >/dev/null 2>&1; then
  echo "== style (advisory): cargo fmt --check =="
  cargo fmt --all --check || echo "WARN: rustfmt check failed (advisory)"
else
  echo "rustfmt not installed; skipping fmt check"
fi

# Clippy is a hard gate when the component exists: the noisy style lints
# are allowed once, in rust/Cargo.toml's [lints.clippy] table (with
# thresholds in clippy.toml), so -D warnings only surfaces real findings.
if cargo clippy --version >/dev/null 2>&1; then
  echo "== style: cargo clippy -D warnings (hard gate) =="
  cargo clippy --workspace --all-targets -- -D warnings
else
  echo "clippy not installed; skipping clippy"
fi

if [ "${FASTCV_SKIP_BENCH:-0}" != "1" ]; then
  echo "== perf trajectory: permutation-engine ablation (BENCH_perm.json) =="
  # tiny scale keeps this step quick; unset FASTCV_BENCH_SCALE for the
  # paper-scale numbers (N=256, P=2048, 1000 perms, 8 threads).
  FASTCV_BENCH_OUT="${FASTCV_BENCH_OUT:-.}" \
    cargo bench --bench ablation_updates
fi

# The full ablation set (backend / tiling / spill → BENCH_backend.json,
# BENCH_tiling.json, BENCH_spill.json at the repo root) lives in
# scripts/bench.sh; opt in with BENCH=1 so the default verify stays quick.
if [ "${BENCH:-0}" = "1" ]; then
  echo "== perf trajectory: full ablation set (scripts/bench.sh) =="
  scripts/bench.sh
fi

echo "verify: OK"
