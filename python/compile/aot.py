"""AOT lowering: JAX/Pallas graphs -> HLO *text* artifacts + manifest.

HLO text (NOT ``lowered.compile()`` / serialized protos) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids that the
xla crate's xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Run once via ``make artifacts``; Rust never imports Python. Each artifact is
a fixed-shape lowering of one L2 graph; ``manifest.json`` records op, shapes,
dtype, argument order and fold count so the Rust artifact registry can match
(op, N, P, K, B) requests to files.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402

DTYPE = jnp.float64
DTYPE_NAME = "f64"


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, DTYPE)


# Artifact shape menu. Chosen to cover the repo's examples/tests and an
# EEG-scale configuration; the Rust side falls back to its native engine for
# shapes not listed here (see rust/src/runtime/).
CONFIGS = [
    # (n, p, k_folds, perm_batch)
    (40, 8, 5, 8),      # test-size
    (60, 12, 5, 16),    # quickstart
    (100, 380, 10, 20), # EEG per-timepoint scale (Fig. 4 small-feature case)
]

MULTICLASS_CONFIGS = [
    # (n, p, c, k_folds)
    (60, 12, 3, 5),
    (90, 380, 3, 10),
]


def build_artifacts(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    entries = []

    def emit(name: str, lowered, op: str, meta: dict):
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entries.append({"op": op, "file": fname, "dtype": DTYPE_NAME, **meta})
        print(f"  wrote {fname} ({len(text)} chars)")

    for n, p, k, b in CONFIGS:
        f = lambda x, y, lam: model.analytic_cv(x, y, lam, k_folds=k)
        lowered = jax.jit(f).lower(spec(n, p), spec(n), spec())
        emit(
            f"analytic_cv_n{n}_p{p}_k{k}",
            lowered,
            "analytic_cv",
            {"n": n, "p": p, "k_folds": k, "args": ["x[n,p]", "y[n]", "lambda[]"]},
        )

        fb = lambda x, yb, lam: model.analytic_cv_batch(x, yb, lam, k_folds=k)
        lowered = jax.jit(fb).lower(spec(n, p), spec(b, n), spec())
        emit(
            f"analytic_cv_batch_n{n}_p{p}_k{k}_b{b}",
            lowered,
            "analytic_cv_batch",
            {
                "n": n,
                "p": p,
                "k_folds": k,
                "batch": b,
                "args": ["x[n,p]", "y_batch[b,n]", "lambda[]"],
            },
        )

        lowered = jax.jit(model.hat_matrix).lower(spec(n, p), spec())
        emit(
            f"hat_n{n}_p{p}",
            lowered,
            "hat_matrix",
            {"n": n, "p": p, "args": ["x[n,p]", "lambda[]"]},
        )

    for n, p, c, k in MULTICLASS_CONFIGS:
        fm = lambda x, yi, lam: model.analytic_cv_multiclass_step1(x, yi, lam, k_folds=k)
        lowered = jax.jit(fm).lower(spec(n, p), spec(n, c), spec())
        emit(
            f"analytic_mc_step1_n{n}_p{p}_c{c}_k{k}",
            lowered,
            "analytic_mc_step1",
            {
                "n": n,
                "p": p,
                "c": c,
                "k_folds": k,
                "args": ["x[n,p]", "y_ind[n,c]", "lambda[]"],
            },
        )

    manifest = {"version": 1, "dtype": DTYPE_NAME, "artifacts": entries}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  wrote manifest.json ({len(entries)} artifacts)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    args = ap.parse_args()
    print(f"AOT-lowering artifacts to {args.out} (dtype={DTYPE_NAME})")
    build_artifacts(args.out)


if __name__ == "__main__":
    sys.exit(main())
