"""Pure-HLO dense linear algebra for the L2 graphs.

``jnp.linalg.{inv,solve,cholesky}`` lower to LAPACK custom-calls with the
typed-FFI API (API_VERSION_TYPED_FFI) that the deployment XLA
(xla_extension 0.5.1, the version the published ``xla`` crate binds) cannot
execute. The artifacts therefore ship their own factorisations built from
plain HLO ops (dot, dynamic-slice, while-loop): a loop-based Cholesky plus
triangular solves. All matrices on this path are SPD — the ridged gram
``X~^T X~ + lam I0`` and the per-fold ``I − H_Te`` blocks — so unpivoted
Cholesky is numerically sound.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def chol_factor(a: jax.Array) -> jax.Array:
    """Lower-triangular L with ``a = L @ L.T`` (Cholesky–Banachiewicz,
    column-by-column fori_loop; lowers to a while-loop of vector ops)."""
    n = a.shape[0]
    idx = jnp.arange(n)

    def col_step(j, l):
        # l[j, :j] — row j of the factor so far (columns ≥ j are still 0).
        lj = jnp.where(idx < j, l[j, :], 0.0)
        s = a[:, j] - l @ lj  # s[i] = a[i,j] − Σ_{k<j} L[i,k] L[j,k]
        d = jnp.sqrt(s[j])
        col = jnp.where(idx > j, s / d, 0.0)
        col = col.at[j].set(d)
        return l.at[:, j].set(col)

    return lax.fori_loop(0, n, col_step, jnp.zeros_like(a))


def solve_lower(l: jax.Array, b: jax.Array) -> jax.Array:
    """Forward substitution: solve ``L y = b`` (b may be (n,) or (n, m))."""
    n = l.shape[0]

    def step(i, y):
        yi = (b[i] - l[i, :] @ y) / l[i, i]
        return y.at[i].set(yi)

    return lax.fori_loop(0, n, step, jnp.zeros_like(b))


def solve_upper_t(l: jax.Array, b: jax.Array) -> jax.Array:
    """Backward substitution with the *transpose*: solve ``L.T x = b``."""
    n = l.shape[0]

    def step(t, x):
        i = n - 1 - t
        xi = (b[i] - l[:, i] @ x) / l[i, i]
        return x.at[i].set(xi)

    return lax.fori_loop(0, n, step, jnp.zeros_like(b))


def chol_solve(l: jax.Array, b: jax.Array) -> jax.Array:
    """Solve ``A x = b`` given ``A = L L^T``."""
    return solve_upper_t(l, solve_lower(l, b))


def spd_solve(a: jax.Array, b: jax.Array) -> jax.Array:
    """Solve ``A x = b`` for SPD ``A`` without LAPACK custom-calls."""
    return chol_solve(chol_factor(a), b)


def spd_inverse(a: jax.Array) -> jax.Array:
    """``A^{-1}`` for SPD ``A`` (identity RHS through the Cholesky solves)."""
    n = a.shape[0]
    return chol_solve(chol_factor(a), jnp.eye(n, dtype=a.dtype))
