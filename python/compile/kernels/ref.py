"""Pure-jnp oracles for the Pallas kernels and the analytic-CV model.

Everything here is the slow-but-obviously-correct reference the pytest suite
checks the L1 kernels and the L2 graph against (and, transitively, what the
Rust runtime's artifact execution is validated against).
"""

from __future__ import annotations

import jax.numpy as jnp


def matmul_ref(a, b):
    """Plain ``a @ b``."""
    return a @ b


def gram_ref(x):
    """Plain ``x.T @ x``."""
    return x.T @ x


def augment(x):
    """The paper's augmented design: X~ = [X, 1]."""
    n = x.shape[0]
    return jnp.concatenate([x, jnp.ones((n, 1), dtype=x.dtype)], axis=1)


def gram_ridged_ref(xa, lam):
    """``X~^T X~ + lam * I0`` — I0 leaves the bias cell unpenalised."""
    p1 = xa.shape[1]
    i0 = jnp.eye(p1, dtype=xa.dtype).at[p1 - 1, p1 - 1].set(0.0)
    return xa.T @ xa + lam * i0


def hat_ref(x, lam):
    """H = X~ (X~^T X~ + lam I0)^-1 X~^T (Eq. 8 with §2.6.1 ridge)."""
    xa = augment(x)
    s = jnp.linalg.inv(gram_ridged_ref(xa, lam))
    return xa @ s @ xa.T


def analytic_cv_ref(x, y, k_folds, lam):
    """Eq. 14 with contiguous equal-sized folds, python-loop reference.

    Samples must be arranged so fold ``k`` is rows ``k*nte..(k+1)*nte``
    (the Rust coordinator pre-permutes rows into this layout).
    """
    n = x.shape[0]
    assert n % k_folds == 0, "reference assumes equal fold sizes"
    nte = n // k_folds
    h = hat_ref(x, lam)
    y_hat = h @ y
    e_hat = y - y_hat
    dvals = []
    for k in range(k_folds):
        sl = slice(k * nte, (k + 1) * nte)
        h_te = h[sl, sl]
        e_dot = jnp.linalg.solve(jnp.eye(nte, dtype=x.dtype) - h_te, e_hat[sl])
        dvals.append(y[sl] - e_dot)
    return jnp.concatenate(dvals)


def standard_cv_ref(x, y, k_folds, lam):
    """Retrain-per-fold reference (the 'standard approach'), contiguous folds."""
    n, p = x.shape
    assert n % k_folds == 0
    nte = n // k_folds
    xa = augment(x)
    out = []
    for k in range(k_folds):
        te = jnp.arange(k * nte, (k + 1) * nte)
        tr = jnp.concatenate([jnp.arange(0, k * nte), jnp.arange((k + 1) * nte, n)])
        g = gram_ridged_ref(xa[tr], lam)
        beta = jnp.linalg.solve(g, xa[tr].T @ y[tr])
        out.append(xa[te] @ beta)
    return jnp.concatenate(out)
