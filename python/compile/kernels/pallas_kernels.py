"""Layer-1 Pallas kernels: the compute hot-spots of the analytic CV.

Two kernels cover the hat-matrix build (the only O(N P^2 + P^3 + N^2 P)
work in the whole pipeline):

* :func:`gram` — the symmetric rank-k update ``G = X~^T X~`` (the scatter
  matrix, Eq. 10's "full scatter").
* :func:`matmul` — a tiled general matmul used for ``T = X~ S`` and
  ``H = T X~^T`` (Eq. 8).

TPU-idiomatic structure (see DESIGN.md "Hardware adaptation"): the grid
iterates output tiles with a k-innermost reduction axis; each step streams
one (bm x bk) A-tile and (bk x bn) B-tile HBM->VMEM via BlockSpec and feeds
the MXU-shaped ``jnp.dot`` with f32/f64 accumulation in the output tile.
``interpret=True`` is mandatory on this CPU-only image — real-TPU lowering
emits Mosaic custom-calls the CPU PJRT client cannot execute.

Inputs are zero-padded up to tile multiples in the host wrappers; zero rows/
columns leave the gram matrix and matmul results unchanged, and the wrappers
slice the padding back off.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. On a real TPU these would be multiples of the MXU
# (128x128); for interpret-mode correctness any value works and smaller
# tiles exercise the padding paths better. VMEM footprint per grid step for
# matmul = (BM*BK + BK*BN + BM*BN) * 8 bytes  (f64) — see EXPERIMENTS.md
# "L1 kernel" for the footprint table.
BM = 64
BK = 64
BN = 64


def _pad_to(x: jax.Array, rows: int, cols: int) -> jax.Array:
    """Zero-pad a 2-D array up to (rows, cols)."""
    r, c = x.shape
    if r == rows and c == cols:
        return x
    return jnp.pad(x, ((0, rows - r), (0, cols - c)))


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One (i, j, k) grid step: o[i,j] += a[i,k] @ b[k,j]."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def matmul(a: jax.Array, b: jax.Array, *, bm: int = BM, bk: int = BK, bn: int = BN) -> jax.Array:
    """Tiled Pallas matmul ``a @ b`` (interpret mode)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims differ: {k} vs {k2}"
    mp, kp, np_ = pl.cdiv(m, bm) * bm, pl.cdiv(k, bk) * bk, pl.cdiv(n, bn) * bn
    a_p = _pad_to(a, mp, kp)
    b_p = _pad_to(b, kp, np_)
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        interpret=True,
    )(a_p, b_p)
    return out[:m, :n]


def _gram_kernel(xi_ref, xj_ref, o_ref):
    """One (i, j, k) grid step of G = X^T X: o[i,j] += x[k,i]^T @ x[k,j]."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        xi_ref[...].T, xj_ref[...], preferred_element_type=o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("bn", "bp"))
def gram(x: jax.Array, *, bn: int = BK, bp: int = BM) -> jax.Array:
    """Tiled Pallas gram matrix ``x.T @ x`` (interpret mode).

    The reduction runs over the sample axis (k-innermost); each output tile
    (i, j) accumulates ``x[k-block, i-block].T @ x[k-block, j-block]``.
    """
    n, p = x.shape
    np_, pp = pl.cdiv(n, bn) * bn, pl.cdiv(p, bp) * bp
    x_p = _pad_to(x, np_, pp)
    grid = (pp // bp, pp // bp, np_ // bn)
    out = pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bp), lambda i, j, kk: (kk, i)),
            pl.BlockSpec((bn, bp), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bp, bp), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pp, pp), x.dtype),
        interpret=True,
    )(x_p, x_p)
    return out[:p, :p]


def vmem_footprint_bytes(bm: int = BM, bk: int = BK, bn: int = BN, itemsize: int = 8) -> int:
    """Estimated VMEM bytes held per matmul grid step (A, B, O tiles)."""
    return (bm * bk + bk * bn + bm * bn) * itemsize
