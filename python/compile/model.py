"""Layer-2 JAX model: the analytic-CV compute graphs, built on the Layer-1
Pallas kernels, lowered AOT by :mod:`compile.aot` and executed from Rust.

Fold convention: the graphs assume **contiguous equal-sized folds** — fold
``k`` owns rows ``k*nte..(k+1)*nte``. Fold membership is thereby static in
the HLO (no gather/scatter on the hot path); the Rust coordinator permutes
the rows of X (and y) into this layout before the call, which is free on its
side (a single `take_rows`).

Graphs:

* :func:`hat_matrix`   — H = X~ (X~^T X~ + lam I0)^-1 X~^T
* :func:`analytic_cv`  — Eq. 14 decision values for one response
* :func:`analytic_cv_batch` — Alg. 1: one H, a batch of (permuted) responses
* :func:`analytic_cv_multiclass_step1` — Alg. 2 step 1: Y~ fits for an
  indicator matrix (step 2's C x C eig stays in Rust where fold-wise
  dynamic class counts live)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import linalg_jax as lj
from .kernels import pallas_kernels as pk


def _augment(x):
    n = x.shape[0]
    return jnp.concatenate([x, jnp.ones((n, 1), dtype=x.dtype)], axis=1)


def _inv_gram(xa, lam):
    """S = (X~^T X~ + lam I0)^-1, gram via the Pallas L1 kernel."""
    p1 = xa.shape[1]
    g = pk.gram(xa)
    i0 = jnp.eye(p1, dtype=xa.dtype).at[p1 - 1, p1 - 1].set(0.0)
    # LAPACK-free inverse: jnp.linalg.inv emits a typed-FFI custom-call
    # the deployment XLA cannot run (see linalg_jax.py).
    return lj.spd_inverse(g + lam * i0)


def hat_matrix(x, lam):
    """H = X~ S X~^T with both products on the Pallas matmul kernel."""
    xa = _augment(x)
    s = _inv_gram(xa, lam)
    t = pk.matmul(xa, s)
    return pk.matmul(t, xa.T)


def _fold_blocks(h, k_folds):
    """(K, nte, nte) tensor of diagonal fold blocks H_Te (static slicing)."""
    n = h.shape[0]
    nte = n // k_folds
    return jnp.stack([h[k * nte:(k + 1) * nte, k * nte:(k + 1) * nte] for k in range(k_folds)])


def _cv_from_hat(h, y, k_folds):
    """Eq. 14 given H: batched per-fold solves, returns dvals (N,)."""
    n = h.shape[0]
    nte = n // k_folds
    y_hat = h @ y
    e_hat = (y - y_hat).reshape(k_folds, nte)
    h_blocks = _fold_blocks(h, k_folds)
    eye = jnp.eye(nte, dtype=h.dtype)
    e_dot = jax.vmap(lambda hb, eb: lj.spd_solve(eye - hb, eb))(h_blocks, e_hat)
    return y - e_dot.reshape(n)


@functools.partial(jax.jit, static_argnames=("k_folds",))
def analytic_cv(x, y, lam, *, k_folds):
    """Cross-validated decision values (Eq. 14), one response vector."""
    h = hat_matrix(x, lam)
    return _cv_from_hat(h, y, k_folds)


@functools.partial(jax.jit, static_argnames=("k_folds",))
def analytic_cv_batch(x, y_batch, lam, *, k_folds):
    """Algorithm 1's core: H built once, CV for a (B, N) batch of permuted
    responses. Returns (B, N) decision values."""
    h = hat_matrix(x, lam)
    return jax.vmap(lambda y: _cv_from_hat(h, y, k_folds))(y_batch)


@functools.partial(jax.jit, static_argnames=("k_folds",))
def analytic_cv_multiclass_step1(x, y_ind, lam, *, k_folds):
    """Alg. 2 step 1: cross-validated regression fits for an (N, C) class
    indicator matrix. Returns (Ydot, Ydot_tr_corr) where

    * ``Ydot``  (N, C): cross-validated fits on each sample's own test fold,
    * ``Ydot_tr_corr`` (K, N, C): for every fold k, the cross-validated fits
      of the *training* samples (Eq. 15) with that fold held out; the test
      rows of slice k are zero-filled (Rust reads only training rows).
    """
    n = x.shape[0]
    c = y_ind.shape[1]
    nte = n // k_folds
    h = hat_matrix(x, lam)
    y_hat = h @ y_ind
    e_hat = y_ind - y_hat
    eye = jnp.eye(nte, dtype=x.dtype)

    def fold(k):
        sl_lo = k * nte
        e_hat_te = jax.lax.dynamic_slice(e_hat, (sl_lo, 0), (nte, c))
        h_te = jax.lax.dynamic_slice(h, (sl_lo, sl_lo), (nte, nte))
        e_dot_te = lj.spd_solve(eye - h_te, e_hat_te)
        y_te = jax.lax.dynamic_slice(y_ind, (sl_lo, 0), (nte, c))
        y_dot_te = y_te - e_dot_te
        # Eq. 15 for all rows: E_dot_all = E_hat + H[:, te] @ e_dot_te,
        # then zero the test rows (their training-side value is meaningless).
        h_cols = jax.lax.dynamic_slice(h, (0, sl_lo), (n, nte))
        e_dot_all = e_hat + h_cols @ e_dot_te
        y_dot_all = y_ind - e_dot_all
        mask = (jnp.arange(n) // nte != k)[:, None].astype(x.dtype)
        return y_dot_te, y_dot_all * mask

    y_dot_te_folds, y_dot_tr = jax.vmap(fold)(jnp.arange(k_folds))
    y_dot = y_dot_te_folds.reshape(n, c)
    return y_dot, y_dot_tr


def quickstart_fn(x, y, lam):
    """Tiny end-to-end graph for the smoke artifact: 5-fold analytic CV."""
    return analytic_cv(x, y, lam, k_folds=5)
