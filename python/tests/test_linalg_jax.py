"""Tests for the LAPACK-free Cholesky / triangular solves (linalg_jax)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile import linalg_jax as lj


def spd(rng, n):
    a = rng.standard_normal((n + 3, n))
    return jnp.asarray(a.T @ a + 0.3 * np.eye(n))


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 40), seed=st.integers(0, 2**31 - 1))
def test_chol_factor_reconstructs(n, seed):
    rng = np.random.default_rng(seed)
    a = spd(rng, n)
    l = lj.chol_factor(a)
    np.testing.assert_allclose(l @ l.T, a, rtol=1e-9, atol=1e-9)
    # strictly lower-triangular above diagonal
    assert np.allclose(np.triu(np.asarray(l), 1), 0.0)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 30), m=st.integers(1, 5), seed=st.integers(0, 2**31 - 1))
def test_spd_solve_matches_numpy(n, m, seed):
    rng = np.random.default_rng(seed)
    a = spd(rng, n)
    b = jnp.asarray(rng.standard_normal((n, m)))
    x = lj.spd_solve(a, b)
    np.testing.assert_allclose(a @ x, b, rtol=1e-8, atol=1e-8)
    x_ref = np.linalg.solve(np.asarray(a), np.asarray(b))
    np.testing.assert_allclose(x, x_ref, rtol=1e-7, atol=1e-8)


def test_vector_rhs():
    rng = np.random.default_rng(0)
    a = spd(rng, 12)
    b = jnp.asarray(rng.standard_normal(12))
    x = lj.spd_solve(a, b)
    np.testing.assert_allclose(a @ x, b, rtol=1e-9, atol=1e-9)


def test_spd_inverse():
    rng = np.random.default_rng(1)
    a = spd(rng, 15)
    inv = lj.spd_inverse(a)
    np.testing.assert_allclose(a @ inv, np.eye(15), atol=1e-8)


def test_no_custom_calls_in_lowering():
    """The deployment constraint itself: the lowered HLO of an analytic-CV
    graph must contain no custom-call instructions (xla_extension 0.5.1
    rejects typed-FFI LAPACK calls)."""
    from compile import model

    f = lambda x, y, lam: model.analytic_cv(x, y, lam, k_folds=4)
    spec = jax.ShapeDtypeStruct((16, 5), jnp.float64)
    yspec = jax.ShapeDtypeStruct((16,), jnp.float64)
    lspec = jax.ShapeDtypeStruct((), jnp.float64)
    hlo = jax.jit(f).lower(spec, yspec, lspec).compiler_ir("hlo").as_hlo_text()
    assert "custom-call" not in hlo, "graph must stay custom-call-free"
