"""L2 correctness: the analytic-CV graphs vs references.

The decisive test is `analytic == standard`: the paper's Eq. 14 must
reproduce retrain-per-fold exactly, inside JAX just as in Rust.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile import model
from compile.kernels import ref


def problem(seed, n, p):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n, p)))
    y = jnp.asarray(np.sign(rng.standard_normal(n)) + 0.0)
    return x, y


def test_hat_matches_ref():
    x, _ = problem(0, 30, 7)
    h = model.hat_matrix(x, jnp.asarray(0.3))
    np.testing.assert_allclose(h, ref.hat_ref(x, 0.3), rtol=1e-10, atol=1e-10)


def test_hat_properties():
    x, _ = problem(1, 25, 6)
    h = np.asarray(model.hat_matrix(x, jnp.asarray(0.0)))
    np.testing.assert_allclose(h, h.T, atol=1e-10)           # symmetric
    np.testing.assert_allclose(h @ h, h, atol=1e-8)          # idempotent (λ=0)
    assert abs(np.trace(h) - 7) < 1e-8                       # trace = P+1


@settings(max_examples=20, deadline=None)
@given(
    nte=st.integers(2, 8),
    k=st.integers(2, 6),
    p=st.integers(1, 12),
    lam_pow=st.floats(-3.0, 2.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_analytic_equals_standard(nte, k, p, lam_pow, seed):
    n = nte * k
    lam = 10.0 ** lam_pow
    x, y = problem(seed, n, p)
    ana = model.analytic_cv(x, y, jnp.asarray(lam), k_folds=k)
    std = ref.standard_cv_ref(x, y, k, lam)
    np.testing.assert_allclose(ana, std, rtol=1e-8, atol=1e-8)


def test_analytic_matches_python_loop_ref():
    x, y = problem(5, 40, 9)
    ana = model.analytic_cv(x, y, jnp.asarray(0.5), k_folds=5)
    loop = ref.analytic_cv_ref(x, y, 5, 0.5)
    np.testing.assert_allclose(ana, loop, rtol=1e-11, atol=1e-11)


def test_batch_matches_single():
    x, y = problem(6, 30, 5)
    rng = np.random.default_rng(6)
    perms = jnp.asarray(np.stack([np.asarray(y)[rng.permutation(30)] for _ in range(7)]))
    batch = model.analytic_cv_batch(x, perms, jnp.asarray(0.2), k_folds=5)
    assert batch.shape == (7, 30)
    for b in range(7):
        single = model.analytic_cv(x, perms[b], jnp.asarray(0.2), k_folds=5)
        np.testing.assert_allclose(batch[b], single, rtol=1e-11, atol=1e-11)


def test_multiclass_step1_matches_columnwise_binary():
    """Step 1 of Alg. 2 is Eq. 14/15 applied per indicator column."""
    n, p, c, k = 30, 6, 3, 5
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.standard_normal((n, p)))
    labels = rng.integers(0, c, n)
    y_ind = jnp.asarray(np.eye(c)[labels])
    lam = 0.7
    y_dot, y_dot_tr = model.analytic_cv_multiclass_step1(x, y_ind, jnp.asarray(lam), k_folds=k)
    assert y_dot.shape == (n, c)
    assert y_dot_tr.shape == (k, n, c)
    # Ẏ test fits: column l == analytic_cv on indicator column l.
    for l in range(c):
        col = model.analytic_cv(x, y_ind[:, l], jnp.asarray(lam), k_folds=k)
        np.testing.assert_allclose(y_dot[:, l], col, rtol=1e-9, atol=1e-9)
    # Ẏ_Tr (Eq. 15): training-row fits equal a model trained on the fold's
    # training rows and evaluated there.
    nte = n // k
    xa = ref.augment(x)
    for kk in range(k):
        tr = np.concatenate([np.arange(0, kk * nte), np.arange((kk + 1) * nte, n)])
        g = ref.gram_ridged_ref(xa[tr], lam)
        beta = jnp.linalg.solve(g, xa[tr].T @ y_ind[tr])
        fit_tr = xa[tr] @ beta
        np.testing.assert_allclose(
            np.asarray(y_dot_tr)[kk][tr], fit_tr, rtol=1e-8, atol=1e-8
        )
        # test rows zeroed
        te = np.arange(kk * nte, (kk + 1) * nte)
        assert np.all(np.asarray(y_dot_tr)[kk][te] == 0.0)


def test_permutation_invariance_of_hat():
    """§2.7: H depends only on X — identical for any label permutation."""
    x, y = problem(11, 20, 4)
    h1 = model.hat_matrix(x, jnp.asarray(0.1))
    h2 = model.hat_matrix(x, jnp.asarray(0.1))
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
