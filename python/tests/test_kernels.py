"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes and dtypes; every case asserts allclose against the
reference — the CORE correctness signal for the kernel layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile.kernels import pallas_kernels as pk
from compile.kernels import ref


def rand(rng, *shape, dtype=np.float64):
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 90),
    k=st.integers(1, 90),
    n=st.integers(1, 90),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = rand(rng, m, k)
    b = rand(rng, k, n)
    got = pk.matmul(a, b)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 120),
    p=st.integers(1, 100),
    seed=st.integers(0, 2**31 - 1),
)
def test_gram_matches_ref(n, p, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, n, p)
    got = pk.gram(x)
    want = ref.gram_ref(x)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_dtypes_supported(dtype):
    rng = np.random.default_rng(0)
    a = rand(rng, 33, 17, dtype=dtype)
    b = rand(rng, 17, 21, dtype=dtype)
    got = pk.matmul(a, b)
    assert got.dtype == a.dtype
    tol = 1e-5 if dtype == np.float32 else 1e-12
    np.testing.assert_allclose(got, ref.matmul_ref(a, b), rtol=tol, atol=tol)
    g = pk.gram(a)
    np.testing.assert_allclose(g, ref.gram_ref(a), rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("bm,bk,bn", [(8, 8, 8), (16, 32, 8), (64, 64, 64), (128, 16, 32)])
def test_block_shape_invariance(bm, bk, bn):
    """Result must not depend on the tile decomposition."""
    rng = np.random.default_rng(42)
    a = rand(rng, 70, 45)
    b = rand(rng, 45, 31)
    got = pk.matmul(a, b, bm=bm, bk=bk, bn=bn)
    np.testing.assert_allclose(got, ref.matmul_ref(a, b), rtol=1e-12, atol=1e-12)


def test_gram_is_symmetric_psd():
    rng = np.random.default_rng(7)
    x = rand(rng, 50, 20)
    g = np.asarray(pk.gram(x))
    np.testing.assert_allclose(g, g.T, rtol=0, atol=1e-12)
    evals = np.linalg.eigvalsh(g)
    assert evals.min() > -1e-10


def test_exact_tile_multiples_no_padding_path():
    rng = np.random.default_rng(3)
    a = rand(rng, 128, 64)
    b = rand(rng, 64, 128)
    np.testing.assert_allclose(pk.matmul(a, b), ref.matmul_ref(a, b), rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(pk.gram(a), ref.gram_ref(a), rtol=1e-12, atol=1e-12)


def test_vmem_footprint_estimate():
    # 64^3 default tiles, f64: 3 * 64*64 * 8 = 96 KiB << 16 MiB VMEM.
    assert pk.vmem_footprint_bytes() == 3 * 64 * 64 * 8
    assert pk.vmem_footprint_bytes(128, 128, 128) == 3 * 128 * 128 * 8
