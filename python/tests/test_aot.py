"""AOT pipeline tests: HLO-text emission, manifest integrity, and the
deployment constraints (no custom-calls, f64, return_tuple)."""

import json
import os

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from compile import aot, model


def test_to_hlo_text_emits_parseable_module():
    f = lambda x, y, lam: model.analytic_cv(x, y, lam, k_folds=4)
    lowered = jax.jit(f).lower(
        aot.spec(16, 5), aot.spec(16), aot.spec()
    )
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule"), text[:60]
    assert "custom-call" not in text
    # return_tuple=True: root computation returns a tuple
    assert "tuple(" in text or "(f64[" in text


def test_build_artifacts_manifest(tmp_path):
    # Use one tiny config to keep the test fast.
    old_configs = aot.CONFIGS, aot.MULTICLASS_CONFIGS
    aot.CONFIGS = [(20, 4, 4, 3)]
    aot.MULTICLASS_CONFIGS = [(20, 4, 3, 4)]
    try:
        manifest = aot.build_artifacts(str(tmp_path))
    finally:
        aot.CONFIGS, aot.MULTICLASS_CONFIGS = old_configs

    files = os.listdir(tmp_path)
    assert "manifest.json" in files
    with open(tmp_path / "manifest.json") as f:
        loaded = json.load(f)
    assert loaded == manifest
    assert loaded["dtype"] == "f64"
    ops = {e["op"] for e in loaded["artifacts"]}
    assert ops == {"analytic_cv", "analytic_cv_batch", "hat_matrix", "analytic_mc_step1"}
    for e in loaded["artifacts"]:
        path = tmp_path / e["file"]
        assert path.exists(), e
        text = path.read_text()
        assert text.startswith("HloModule")
        assert "custom-call" not in text, f"{e['file']} has a custom-call"


def test_configs_are_fold_divisible():
    """The contiguous-fold contract requires n % k == 0 for every artifact."""
    for n, p, k, b in aot.CONFIGS:
        assert n % k == 0, f"config ({n},{p},{k},{b}) violates n % k == 0"
    for n, p, c, k in aot.MULTICLASS_CONFIGS:
        assert n % k == 0, f"mc config ({n},{p},{c},{k}) violates n % k == 0"
