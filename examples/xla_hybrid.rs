//! Three-layer composition check: run the analytic CV through the
//! AOT-compiled JAX/Pallas artifact on the PJRT CPU client and through the
//! native Rust engine, verifying bit-level-ish agreement and printing
//! timings for both.
//!
//! Requires `make artifacts` (build-time Python) to have produced
//! `artifacts/manifest.json`; without it the example explains and exits 0.
//!
//! Run: `cargo run --release --example xla_hybrid`

use fastcv::cv::folds::kfold;
use fastcv::data::synthetic::{generate, SyntheticSpec};
use fastcv::runtime::hybrid::{analytic_cv, analytic_cv_batch, Engine};
use fastcv::runtime::XlaRuntime;
use fastcv::util::rng::Rng;
use fastcv::util::timed;

fn main() -> anyhow::Result<()> {
    let rt = XlaRuntime::load_default()?;
    println!("PJRT platform: {}", rt.platform());
    if rt.registry().is_empty() {
        println!("no artifacts found — run `make artifacts` first; nothing to do.");
        return Ok(());
    }
    println!("{} artifacts registered", rt.registry().len());

    // The EEG-scale artifact: N=100, P=380, K=10 (see python/compile/aot.py).
    let (n, p, k) = (100, 380, 10);
    let mut rng = Rng::new(11);
    let mut spec = SyntheticSpec::binary(n, p);
    spec.separation = 1.5;
    let ds = generate(&spec, &mut rng);
    let y = ds.y_signed();
    let folds = kfold(n, k, &mut rng);
    let lambda = 1.0;

    // Single-response CV through both engines.
    let ((dv_xla, e_xla), t_xla) =
        timed(|| analytic_cv(Some(&rt), &ds.x, &y, &folds, lambda).unwrap());
    let ((dv_nat, e_nat), t_nat) = timed(|| analytic_cv(None, &ds.x, &y, &folds, lambda).unwrap());
    assert_eq!(e_xla, Engine::Xla, "artifact should have been used");
    assert_eq!(e_nat, Engine::Native);
    let max_diff = dv_xla
        .iter()
        .zip(&dv_nat)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("single CV  | XLA {t_xla:.3}s vs native {t_nat:.3}s | max |Δ| = {max_diff:.2e}");
    assert!(max_diff < 1e-8, "engines disagree");

    // Batched permutations (Alg. 1) through the batch artifact.
    let b = 20;
    let mut perms = Vec::with_capacity(b);
    for _ in 0..b {
        let p = rng.permutation(n);
        perms.push(p.iter().map(|&i| y[i]).collect::<Vec<f64>>());
    }
    let ((batch_xla, e1), t_bx) =
        timed(|| analytic_cv_batch(Some(&rt), &ds.x, &perms, &folds, lambda).unwrap());
    let ((batch_nat, _), t_bn) =
        timed(|| analytic_cv_batch(None, &ds.x, &perms, &folds, lambda).unwrap());
    assert_eq!(e1, Engine::Xla);
    let mut worst = 0.0f64;
    for (rx, rn) in batch_xla.iter().zip(&batch_nat) {
        for (a, bb) in rx.iter().zip(rn) {
            worst = worst.max((a - bb).abs());
        }
    }
    println!("perm batch | XLA {t_bx:.3}s vs native {t_bn:.3}s | max |Δ| = {worst:.2e} ({b} perms)");
    assert!(worst < 1e-8);

    println!("hybrid OK: L1 (Pallas) → L2 (JAX) → HLO → PJRT execution matches native Rust.");
    Ok(())
}
