//! §4.5 "What about big data?" — the paper's three scaling strategies on a
//! gene-expression-style P ≫ N problem (5,000 genes, 120 patients):
//!
//! 1. streaming hat blocks (no N×N materialisation),
//! 2. sparse random projection to Q ≪ P then analytic CV,
//! 3. an LDA ensemble over feature/sample subsets (parallel training).
//!
//! Run: `cargo run --release --example bigdata_strategies`

use fastcv::cv::folds::stratified_kfold;
use fastcv::cv::metrics::{accuracy_labels, accuracy_signed};
use fastcv::data::genes::{generate, GeneSpec};
use fastcv::fastcv::bigdata::{projected_analytic_cv, LdaEnsemble, SparseProjection, StreamingHat};
use fastcv::fastcv::binary::AnalyticBinaryCv;
use fastcv::model::Reg;
use fastcv::util::rng::Rng;
use fastcv::util::threadpool::ThreadPool;
use fastcv::util::timed;

fn main() -> anyhow::Result<()> {
    let args = fastcv::util::cli::Args::from_env(&[]);
    let p: usize = args.get_parse_or("genes", 5000);
    let n: usize = args.get_parse_or("patients", 120);
    let lambda = 5.0;

    let mut rng = Rng::new(1);
    let spec = GeneSpec { n, p, effect: 1.5, de_fraction: 0.02, ..Default::default() };
    let ds = generate(&spec, &mut rng);
    let y = ds.y_signed();
    let folds = stratified_kfold(&ds.labels, 5, &mut rng);
    println!("gene-expression problem: {n} patients × {p} genes, 5-fold CV\n");

    // Reference: dense-H analytic CV.
    let (dv_dense, t_dense) = timed(|| -> anyhow::Result<Vec<f64>> {
        let cv = AnalyticBinaryCv::fit(&ds.x, &y, lambda)?;
        cv.decision_values(&folds)
    });
    let dv_dense = dv_dense?;
    println!(
        "dense hat matrix     : acc {:.3}  {:.2}s  (memory: N² = {} f64)",
        accuracy_signed(&dv_dense, &y),
        t_dense,
        n * n
    );

    // 1. streaming hat: same numbers, O(NP) memory.
    let (dv_stream, t_stream) = timed(|| -> anyhow::Result<Vec<f64>> {
        let sh = StreamingHat::build(&ds.x, lambda)?;
        sh.decision_values(&y, &folds)
    });
    let dv_stream = dv_stream?;
    let max_diff = dv_dense
        .iter()
        .zip(&dv_stream)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!(
        "streaming hat blocks : acc {:.3}  {:.2}s  (memory: 2·N·(P+1); max|Δ| vs dense {max_diff:.1e})",
        accuracy_signed(&dv_stream, &y),
        t_stream
    );
    assert!(max_diff < 1e-8);

    // 2. sparse random projection to Q = 400.
    let q = 400;
    let proj = SparseProjection::sample(p, q, &mut rng);
    println!(
        "random projection    : P {p} → Q {q} (density {:.2})",
        proj.density()
    );
    let (dv_proj, t_proj) = timed(|| projected_analytic_cv(&ds.x, &y, &folds, q, lambda, &mut rng));
    let dv_proj = dv_proj?;
    println!(
        "  projected CV       : acc {:.3}  {:.2}s",
        accuracy_signed(&dv_proj, &y),
        t_proj
    );

    // 3. ensemble of weak learners, parallel.
    let pool = ThreadPool::with_default_size(8);
    let (ens, t_ens) = timed(|| {
        LdaEnsemble::train(&ds.x, &ds.labels, 25, 0.05, 0.7, Reg::Ridge(1.0), Some(&pool), &mut rng)
    });
    let ens = ens?;
    let pred = ens.predict(&ds.x);
    println!(
        "ensemble ({} members): acc {:.3}  {:.2}s  ({} workers)",
        ens.len(),
        accuracy_labels(&pred, &ds.labels),
        t_ens,
        pool.size()
    );

    println!("\nall three strategies stay well above chance while bounding memory/compute.");
    Ok(())
}
