//! Condition-rich RSA (§4.2): build a Representational Dissimilarity Matrix
//! from pairwise cross-validated LDA — `C(C−1)/2` cross-validations — using
//! the analytic approach, with the Linear Discriminant Contrast (LDC) as the
//! dissimilarity measure.
//!
//! With C conditions the standard approach retrains `K·C(C−1)/2` models;
//! the analytic approach builds one hat matrix **per condition pair** and
//! reads the cross-validated contrasts off it. This example measures both
//! and prints the RDM.
//!
//! Run: `cargo run --release --example rsa_condition_rich`

use fastcv::cv::folds::stratified_kfold;
use fastcv::cv::metrics::ldc_from_dvals;
use fastcv::data::synthetic::{generate, SyntheticSpec};
use fastcv::fastcv::binary::AnalyticBinaryCv;
use fastcv::linalg::Mat;
use fastcv::model::lda_binary::signed_codes;
use fastcv::util::rng::Rng;
use fastcv::util::table::fnum;
use fastcv::util::timed;

fn main() -> anyhow::Result<()> {
    let args = fastcv::util::cli::Args::from_env(&["full"]);
    let conditions: usize = args.get_parse_or("conditions", 8);
    let per_cond: usize = args.get_parse_or("per", 24);
    let p: usize = args.get_parse_or("p", 160);
    let lambda = 1.0;
    let k_folds = 4;

    // One dataset with `conditions` classes; conditions 0..c/2 share a
    // "category" direction so the RDM should show block structure.
    let mut rng = Rng::new(42);
    let mut spec = SyntheticSpec::multiclass(conditions * per_cond, p, conditions);
    spec.separation = 2.0;
    let ds = generate(&spec, &mut rng);

    println!(
        "RSA: {conditions} conditions × {per_cond} trials, P={p} features, \
         {} pairwise CVs × {k_folds} folds",
        conditions * (conditions - 1) / 2
    );

    let pair_data = |a: usize, b: usize| -> (Mat, Vec<usize>) {
        let idx: Vec<usize> = (0..ds.n())
            .filter(|&i| ds.labels[i] == a || ds.labels[i] == b)
            .collect();
        let x = ds.x.take_rows(&idx);
        let labels: Vec<usize> = idx.iter().map(|&i| usize::from(ds.labels[i] == b)).collect();
        (x, labels)
    };

    // ---- analytic RDM ----
    let (rdm_ana, t_ana) = timed(|| -> anyhow::Result<Mat> {
        let mut rdm = Mat::zeros(conditions, conditions);
        let mut rng = Rng::new(777);
        for a in 0..conditions {
            for b in (a + 1)..conditions {
                let (x, labels) = pair_data(a, b);
                let folds = stratified_kfold(&labels, k_folds, &mut rng);
                let y = signed_codes(&labels);
                let cv = AnalyticBinaryCv::fit(&x, &y, lambda)?;
                let dv = cv.decision_values(&folds)?;
                let ldc = ldc_from_dvals(&dv, &labels);
                rdm[(a, b)] = ldc;
                rdm[(b, a)] = ldc;
            }
        }
        Ok(rdm)
    });
    let rdm_ana = rdm_ana?;

    // ---- standard RDM: retrain the same least-squares model per fold ----
    // (Same regression route as the analytic path reproduces, so the RDMs
    // must agree to numerical precision — scaling conventions and all. A
    // classic-LDA baseline would differ only by per-fold w-scaling, which
    // LDC inherits; see `model::regression_lda` for the Appendix-A algebra.)
    let (rdm_std, t_std) = timed(|| -> anyhow::Result<Mat> {
        let mut rdm = Mat::zeros(conditions, conditions);
        let mut rng = Rng::new(777); // same fold stream as above
        for a in 0..conditions {
            for b in (a + 1)..conditions {
                let (x, labels) = pair_data(a, b);
                let folds = stratified_kfold(&labels, k_folds, &mut rng);
                let y = signed_codes(&labels);
                let dv =
                    fastcv::fastcv::binary::standard_cv_decision_values(&x, &y, &folds, lambda)?;
                let ldc = ldc_from_dvals(&dv, &labels);
                rdm[(a, b)] = ldc;
                rdm[(b, a)] = ldc;
            }
        }
        Ok(rdm)
    });
    let rdm_std = rdm_std?;

    let upper = |m: &Mat| -> Vec<f64> {
        let mut v = Vec::new();
        for a in 0..conditions {
            for b in (a + 1)..conditions {
                v.push(m[(a, b)]);
            }
        }
        v
    };
    let ua = upper(&rdm_ana);
    let us = upper(&rdm_std);
    let max_diff = ua
        .iter()
        .zip(&us)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    let rho = spearman(&ua, &us);
    println!("RDM agreement: max |Δ LDC| = {max_diff:.2e}, Spearman ρ = {rho:.4}");
    assert!(max_diff < 1e-6, "RDMs must be identical, max diff {max_diff}");

    println!("\nRDM (LDC, analytic):");
    for a in 0..conditions {
        let row: Vec<String> = (0..conditions).map(|b| fnum(rdm_ana[(a, b)], 2)).collect();
        println!("  [{}]", row.join(", "));
    }
    println!("\nstandard: {t_std:.2} s | analytic: {t_ana:.3} s | speedup {:.1}x", t_std / t_ana);
    Ok(())
}

/// Spearman rank correlation.
fn spearman(a: &[f64], b: &[f64]) -> f64 {
    let rank = |v: &[f64]| -> Vec<f64> {
        let mut idx: Vec<usize> = (0..v.len()).collect();
        idx.sort_by(|&i, &j| v[i].partial_cmp(&v[j]).unwrap());
        let mut r = vec![0.0; v.len()];
        for (pos, &i) in idx.iter().enumerate() {
            r[i] = pos as f64;
        }
        r
    };
    let (ra, rb) = (rank(a), rank(b));
    let ma = fastcv::util::mean(&ra);
    let mb = fastcv::util::mean(&rb);
    let num: f64 = ra.iter().zip(&rb).map(|(x, y)| (x - ma) * (y - mb)).sum();
    let da: f64 = ra.iter().map(|x| (x - ma) * (x - ma)).sum::<f64>().sqrt();
    let db: f64 = rb.iter().map(|y| (y - mb) * (y - mb)).sum::<f64>().sqrt();
    num / (da * db)
}
