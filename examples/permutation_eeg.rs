//! End-to-end driver (Fig. 4): permutation testing of an EEG/MEG-style
//! multi-subject dataset with binary and multi-class LDA, standard vs
//! analytic, reporting per-subject relative efficiency — the paper's
//! headline experiment, run on the simulated Wakeman–Henson substitute.
//!
//! The whole stack composes here: the ERP simulator (substrate), fold
//! stratification (cv), classic LDA baselines (model), hat-matrix analytic
//! engines (fastcv), permutation orchestration (fastcv::perm), and the
//! coordinator's reporting.
//!
//! Run (quick, 2 subjects):  cargo run --release --example permutation_eeg
//! Run (paper-scale):        cargo run --release --example permutation_eeg -- --full
//!
//! Paper expectation: analytic wins everywhere; the margin grows with the
//! number of features and is largest for multi-class LDA (Fig. 4 shows
//! 1000–10,000× at 1900 features). Absolute values differ on this substrate
//! but the ordering and growth must hold.

use fastcv::bench::RelEffReport;
use fastcv::cv::folds::stratified_kfold;
use fastcv::data::eeg::{simulate_subject, EegSpec};
use fastcv::fastcv::perm::{
    analytic_binary_permutation, analytic_multiclass_permutation, standard_binary_permutation,
    standard_multiclass_permutation,
};
use fastcv::fastcv::perm_batch::{
    analytic_binary_permutation_batched, analytic_multiclass_permutation_batched, BatchStrategy,
};
use fastcv::model::Reg;
use fastcv::util::rng::Rng;
use fastcv::util::timed;

fn main() -> anyhow::Result<()> {
    let args = fastcv::util::cli::Args::from_env(&["full"]);
    let full = args.flag("full");
    let n_subjects: usize = args.get_parse_or("subjects", if full { 16 } else { 2 });
    let n_perm: usize = args.get_parse_or("perms", if full { 100 } else { 10 });
    let spec = if full { EegSpec::default() } else { EegSpec::small() };
    let lambda = 1.0;

    println!(
        "Fig. 4 reproduction: {n_subjects} simulated subjects, {} channels, \
         {n_perm} permutations × 10-fold CV",
        spec.n_channels
    );

    let mut root = Rng::new(2018);
    let mut report = RelEffReport::new("per-subject relative efficiency");
    let mut rel_eff_small = Vec::new();
    let mut rel_eff_large = Vec::new();

    for subj in 0..n_subjects {
        let mut rng = root.fork(subj as u64 + 1);
        let subject = simulate_subject(&spec, &mut rng);
        let peak = ((0.17f64 - (-0.5)) * 200.0) as usize; // N170 sample index

        // ---- binary LDA, small feature set (one timepoint, P = channels) ----
        let ds = subject.features_at_timepoint(peak, true);
        let folds = stratified_kfold(&ds.labels, 10, &mut rng);
        let mut rng_std = rng.fork(11);
        let mut rng_ana = rng.fork(11);
        let (std_res, t_std) = timed(|| {
            standard_binary_permutation(&ds.x, &ds.labels, &folds, Reg::Ridge(lambda), n_perm, &mut rng_std)
        });
        let (ana_res, t_ana) = timed(|| {
            analytic_binary_permutation(&ds.x, &ds.labels, &folds, lambda, n_perm, false, &mut rng_ana)
        });
        let (std_res, ana_res) = (std_res?, ana_res?);
        report.push(&format!("subj{subj:02} binary P={}", ds.p()), t_std, t_ana);
        rel_eff_small.push((t_std / t_ana).log10());
        println!(
            "  subj{subj:02} binary  P={:<5} observed acc={:.3} p={:.3} | std {:.2}s ana {:.3}s",
            ds.p(),
            ana_res.observed,
            ana_res.p_value,
            t_std,
            t_ana
        );
        debug_assert!((std_res.observed - ana_res.observed).abs() < 0.2);

        // ---- binary LDA, large feature set (100 ms windows concatenated) ----
        let ds = subject.features_windowed(100, true);
        let folds = stratified_kfold(&ds.labels, 10, &mut rng);
        let mut rng_std = rng.fork(13);
        let mut rng_ana = rng.fork(13);
        // Clone so the batched engine sees the identical anchor — its null
        // distribution is then bit-identical to the serial analytic one.
        let mut rng_bat = rng_ana.clone();
        let (std_res, t_std) = timed(|| {
            standard_binary_permutation(&ds.x, &ds.labels, &folds, Reg::Ridge(lambda), n_perm, &mut rng_std)
        });
        let (ana_res, t_ana) = timed(|| {
            analytic_binary_permutation(&ds.x, &ds.labels, &folds, lambda, n_perm, false, &mut rng_ana)
        });
        let (bat_res, t_bat) = timed(|| {
            analytic_binary_permutation_batched(
                &ds.x, &ds.labels, &folds, lambda, n_perm, false, &mut rng_bat,
                BatchStrategy::auto(),
            )
        });
        std_res?;
        let ana = ana_res?;
        let bat = bat_res?;
        assert!(
            ana.null.iter().zip(&bat.null).all(|(a, b)| (a - b).abs() <= 1e-12),
            "batched engine must reproduce the serial null distribution"
        );
        report.push(&format!("subj{subj:02} binary P={}", ds.p()), t_std, t_ana);
        report.push(&format!("subj{subj:02} binary-batched P={}", ds.p()), t_std, t_bat);
        rel_eff_large.push((t_std / t_ana).log10());
        println!(
            "  subj{subj:02} binary  P={:<5} observed acc={:.3} p={:.3} | std {:.2}s ana {:.3}s \
             batched {:.3}s ({:.1}x vs serial analytic)",
            ds.p(),
            ana.observed,
            ana.p_value,
            t_std,
            t_ana,
            t_bat,
            t_ana / t_bat
        );

        // ---- multi-class LDA, small + large (200 ms windows) ----
        for (tag, ds) in [
            ("multi ", subject.features_at_timepoint(peak, false)),
            ("multi ", subject.features_windowed(200, false)),
        ] {
            let folds = stratified_kfold(&ds.labels, 10, &mut rng);
            let mut rng_std = rng.fork(17);
            let mut rng_ana = rng.fork(17);
            let mut rng_bat = rng_ana.clone();
            let (std_res, t_std) = timed(|| {
                standard_multiclass_permutation(
                    &ds.x, &ds.labels, 3, &folds, Reg::Ridge(lambda), n_perm, &mut rng_std,
                )
            });
            let (ana_res, t_ana) = timed(|| {
                analytic_multiclass_permutation(&ds.x, &ds.labels, 3, &folds, lambda, n_perm, &mut rng_ana)
            });
            let (bat_res, t_bat) = timed(|| {
                analytic_multiclass_permutation_batched(
                    &ds.x, &ds.labels, 3, &folds, lambda, n_perm, &mut rng_bat,
                    BatchStrategy::auto(),
                )
            });
            let (std_res, ana_res, bat_res) = (std_res?, ana_res?, bat_res?);
            assert!(
                (std_res.observed - ana_res.observed).abs() < 1e-9,
                "multi-class engines must agree exactly"
            );
            assert_eq!(
                ana_res.null, bat_res.null,
                "batched multi-class engine must reproduce the serial null"
            );
            report.push(&format!("subj{subj:02} {tag}P={}", ds.p()), t_std, t_ana);
            report.push(&format!("subj{subj:02} {tag}batched P={}", ds.p()), t_std, t_bat);
            println!(
                "  subj{subj:02} multi   P={:<5} observed acc={:.3} p={:.3} | std {:.2}s ana {:.3}s \
                 batched {:.3}s ({:.1}x vs serial analytic)",
                ds.p(),
                ana_res.observed,
                ana_res.p_value,
                t_std,
                t_ana,
                t_bat,
                t_ana / t_bat
            );
        }
    }

    println!("\n{}", report.render());
    let mean_small = fastcv::util::mean(&rel_eff_small);
    let mean_large = fastcv::util::mean(&rel_eff_large);
    println!(
        "binary rel.eff: small-P mean {mean_small:.2}, large-P mean {mean_large:.2} \
         (paper: larger feature set ⇒ larger gain)"
    );
    assert!(
        mean_large > mean_small,
        "feature-count effect must reproduce: {mean_large:.2} vs {mean_small:.2}"
    );
    Ok(())
}
