//! Searchlight analysis (§4.2, Kriegeskorte et al. 2006): validate a
//! classifier on a local neighbourhood centred on every channel, repeating
//! the CV once per "searchlight" — hundreds of cross-validations per
//! dataset, exactly the repeated-validation regime where the analytic
//! approach shines.
//!
//! Channels are laid out on a ring; each searchlight is a channel plus its
//! `radius` neighbours on either side. Prints the per-channel decoding map
//! and the timing of analytic vs standard across all searchlights.
//!
//! Run: `cargo run --release --example searchlight`

use fastcv::cv::folds::stratified_kfold;
use fastcv::cv::metrics::accuracy_signed;
use fastcv::data::eeg::{simulate_subject, EegSpec};
use fastcv::fastcv::binary::AnalyticBinaryCv;
use fastcv::util::rng::Rng;
use fastcv::util::timed;

fn main() -> anyhow::Result<()> {
    let args = fastcv::util::cli::Args::from_env(&["full"]);
    let spec = if args.flag("full") { EegSpec::default() } else { EegSpec::small() };
    let radius: usize = args.get_parse_or("radius", 3);
    let lambda = 1.0;

    let mut rng = Rng::new(12);
    let subject = simulate_subject(&spec, &mut rng);
    let peak = ((0.17f64 + 0.5) * 200.0) as usize;
    let ds = subject.features_at_timepoint(peak, true);
    let nc = ds.p();
    let folds = stratified_kfold(&ds.labels, 5, &mut rng);
    let y = ds.y_signed();

    println!(
        "searchlight: {} channels × radius {radius} → {} local CVs ({} trials)",
        nc,
        nc,
        ds.n()
    );

    // neighbourhood indices on a ring
    let hood = |c: usize| -> Vec<usize> {
        (0..=2 * radius).map(|o| (c + nc + o - radius) % nc).collect()
    };

    // ---- analytic searchlight ----
    let (acc_map, t_ana) = timed(|| -> anyhow::Result<Vec<f64>> {
        let mut map = Vec::with_capacity(nc);
        for c in 0..nc {
            let x_loc = ds.x.take_cols(&hood(c));
            let cv = AnalyticBinaryCv::fit(&x_loc, &y, lambda)?;
            let dv = cv.decision_values(&folds)?;
            map.push(accuracy_signed(&dv, &y));
        }
        Ok(map)
    });
    let acc_map = acc_map?;

    // ---- standard searchlight (sampled: every 8th channel, extrapolated).
    // Retrains the same least-squares model per fold, so decision values —
    // and hence AUCs — must match the analytic path exactly.
    let sample: Vec<usize> = (0..nc).step_by(8).collect();
    let (std_aucs, t_std_sample) = timed(|| -> anyhow::Result<Vec<f64>> {
        let mut out = Vec::new();
        for &c in &sample {
            let x_loc = ds.x.take_cols(&hood(c));
            let dv = fastcv::fastcv::binary::standard_cv_decision_values(
                &x_loc, &y, &folds, lambda,
            )?;
            out.push(fastcv::cv::metrics::auc(&dv, &ds.labels));
        }
        Ok(out)
    });
    let std_aucs = std_aucs?;
    let t_std_est = t_std_sample / sample.len() as f64 * nc as f64;

    // decoding map
    println!("\n  ch   acc");
    for (c, acc) in acc_map.iter().enumerate().step_by((nc / 24).max(1)) {
        let bar = "#".repeat(((acc - 0.4).max(0.0) * 60.0) as usize);
        println!("  {c:>3}  {acc:.3} {bar}");
    }

    // agreement on the sampled channels — same fold partition, so the
    // decision values (and hence accuracies) differ only by bias convention.
    for (i, &c) in sample.iter().enumerate() {
        let x_loc = ds.x.take_cols(&hood(c));
        let cv = AnalyticBinaryCv::fit(&x_loc, &y, lambda)?;
        let dv = cv.decision_values(&folds)?;
        let ana_auc = fastcv::cv::metrics::auc(&dv, &ds.labels);
        assert!(
            (ana_auc - std_aucs[i]).abs() < 1e-9,
            "channel {c}: analytic AUC {ana_auc:.6} vs standard AUC {:.6}",
            std_aucs[i]
        );
    }
    let best = acc_map.iter().cloned().fold(0.0f64, f64::max);
    println!("\nbest searchlight accuracy: {best:.3}");
    println!(
        "analytic: {t_ana:.2}s for {nc} searchlights | standard (extrapolated): ~{t_std_est:.1}s \
         | speedup ~{:.1}x",
        t_std_est / t_ana
    );
    let p_local = 2 * radius + 1;
    println!(
        "note: §4.1's rule of thumb — analytic wins when P > N/K; here P={p_local} vs \
         N/K={:.0}, so grow the radius (--radius) or trial count to see the gap widen.",
        ds.n() as f64 / folds.len() as f64
    );
    Ok(())
}
