//! Quickstart: exact analytic cross-validation in a dozen lines.
//!
//! Generates a P ≫ N dataset (the paper's home turf), runs 10-fold CV with
//! the standard retrain-per-fold approach and with the analytic approach,
//! verifies the decision values match to numerical precision, and prints
//! the speedup.
//!
//! Run: `cargo run --release --example quickstart`

use fastcv::cv::folds::kfold;
use fastcv::cv::metrics::accuracy_signed;
use fastcv::data::synthetic::{generate, SyntheticSpec};
use fastcv::fastcv::binary::{standard_cv_decision_values, AnalyticBinaryCv};
use fastcv::util::rng::Rng;
use fastcv::util::timed;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(7);
    let mut spec = SyntheticSpec::binary(120, 800); // N=120 samples, P=800 features
    spec.separation = 2.0;
    let ds = generate(&spec, &mut rng);
    let y = ds.y_signed();
    let folds = kfold(ds.n(), 10, &mut rng);
    let lambda = 1.0; // ridge keeps the wide design well-posed

    // Standard approach: refit the least-squares model on all 10 folds.
    let (std_dv, t_std) = timed(|| standard_cv_decision_values(&ds.x, &y, &folds, lambda));
    let std_dv = std_dv?;

    // Analytic approach: one full-data fit + Eq. 14 per fold.
    let (ana_dv, t_ana) = timed(|| -> anyhow::Result<Vec<f64>> {
        let cv = AnalyticBinaryCv::fit(&ds.x, &y, lambda)?;
        cv.decision_values(&folds)
    });
    let ana_dv = ana_dv?;

    // Exactness: the two decision-value vectors are the same numbers.
    let max_diff = std_dv
        .iter()
        .zip(&ana_dv)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("max |standard − analytic| decision value: {max_diff:.2e}");
    assert!(max_diff < 1e-6, "analytic CV must be exact");

    println!("accuracy: {:.3}", accuracy_signed(&ana_dv, &y));
    println!("standard: {:.3} s", t_std);
    println!("analytic: {:.4} s", t_ana);
    println!(
        "speedup: {:.0}x (relative efficiency {:.2})",
        t_std / t_ana,
        (t_std / t_ana).log10()
    );
    Ok(())
}
