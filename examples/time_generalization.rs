//! Multi-dimensional data (§4.2, "time generalisation"): validate a
//! classifier at **every time point** of an ERP epoch — 301 independent
//! cross-validations per subject — and show where the analytic approach's
//! one-hat-matrix-per-timepoint pays off.
//!
//! Prints a decoding time-course (accuracy vs time) computed with the
//! analytic engine and cross-checks a sample of time points against the
//! standard approach.
//!
//! Run: `cargo run --release --example time_generalization`

use fastcv::cv::folds::stratified_kfold;
use fastcv::cv::metrics::accuracy_signed;
use fastcv::data::eeg::{simulate_subject, EegSpec, FS, N_T, T0};
use fastcv::fastcv::binary::AnalyticBinaryCv;
use fastcv::fastcv::FoldCache;
use fastcv::model::Reg;
use fastcv::util::rng::Rng;
use fastcv::util::timed;

fn main() -> anyhow::Result<()> {
    let args = fastcv::util::cli::Args::from_env(&["full"]);
    let full = args.flag("full");
    let spec = if full { EegSpec::default() } else { EegSpec::small() };
    let stride: usize = args.get_parse_or("stride", if full { 1 } else { 4 });
    let lambda = 1.0;

    let mut rng = Rng::new(3);
    let subject = simulate_subject(&spec, &mut rng);
    println!(
        "time-resolved decoding: {} trials × {} channels × {} time points (stride {stride})",
        subject.n_trials(),
        subject.n_channels,
        N_T
    );

    let ds0 = subject.features_at_timepoint(0, true);
    let folds = stratified_kfold(&ds0.labels, 10, &mut rng);
    let y = ds0.y_signed();

    // ---- analytic: one hat matrix + cached fold solves per time point ----
    let timepoints: Vec<usize> = (0..N_T).step_by(stride).collect();
    let (curve, t_ana) = timed(|| -> anyhow::Result<Vec<(usize, f64)>> {
        let mut out = Vec::with_capacity(timepoints.len());
        for &it in &timepoints {
            let ds = subject.features_at_timepoint(it, true);
            let cv = AnalyticBinaryCv::fit(&ds.x, &y, lambda)?;
            let cache = FoldCache::prepare(&cv.hat, &folds, false)?;
            let acc = accuracy_signed(&cv.decision_values_cached(&cache), &y);
            out.push((it, acc));
        }
        Ok(out)
    });
    let curve = curve?;

    // ---- standard cross-check on a few time points ----
    let check: Vec<usize> = vec![timepoints[0], timepoints[timepoints.len() / 2], *timepoints.last().unwrap()];
    let (std_accs, t_std_sample) = timed(|| -> anyhow::Result<Vec<f64>> {
        let mut out = Vec::new();
        for &it in &check {
            let ds = subject.features_at_timepoint(it, true);
            let acc = fastcv::cv::runner::standard_binary_cv_accuracy(
                &ds.x,
                &ds.labels,
                &folds,
                Reg::Ridge(lambda),
            )?;
            out.push(acc);
        }
        Ok(out)
    });
    let std_accs = std_accs?;
    let t_std_est = t_std_sample / check.len() as f64 * timepoints.len() as f64;

    // ASCII time-course.
    println!("\n  time(ms)  accuracy");
    for &(it, acc) in curve.iter() {
        let t_ms = (T0 + it as f64 / FS as f64) * 1000.0;
        let bar = "#".repeat(((acc - 0.3).max(0.0) * 50.0) as usize);
        println!("  {t_ms:>7.0}   {acc:.3} {bar}");
    }

    // The N170 window should beat the pre-stimulus baseline.
    let acc_at = |ms: f64| -> f64 {
        let target = ((ms / 1000.0 - T0) * FS as f64) as usize;
        curve
            .iter()
            .min_by_key(|(it, _)| it.abs_diff(target))
            .map(|&(_, a)| a)
            .unwrap()
    };
    let base = acc_at(-300.0);
    let peak = acc_at(170.0);
    println!("\nbaseline acc {base:.3} | N170 acc {peak:.3}");
    assert!(peak > base, "evoked decoding must beat baseline");

    for (i, &it) in check.iter().enumerate() {
        let ana = curve.iter().find(|(t, _)| *t == it).unwrap().1;
        // b_LR vs b_LDA can flip a few boundary samples; accuracies stay close.
        assert!(
            (ana - std_accs[i]).abs() < 0.1,
            "t={it}: analytic {ana:.3} vs standard {:.3}",
            std_accs[i]
        );
    }
    println!(
        "analytic sweep: {t_ana:.2} s for {} time points | standard (extrapolated): ~{t_std_est:.1} s",
        timepoints.len()
    );
    Ok(())
}
