//! Fixture-driven tests for the `fastcv-lint` engine (rules L1–L5 plus the
//! suppression machinery), and the self-check that the shipped tree is
//! lint-clean. Fixtures live in `tests/lint_fixtures/` — a directory the
//! workspace walk deliberately skips — and are linted under *virtual*
//! repo-relative paths so one snippet can be checked against several file
//! classes (numeric module, kernel allowlist, exempt bench, ...).

use fastcv::lint::{lint_source, lint_workspace, Rule};
use std::path::Path;

fn fixture(name: &str) -> String {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/lint_fixtures").join(name);
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
}

/// Lines carrying a diagnostic of `rule`, in file order.
fn lines_of(src: &str, rel: &str, rule: Rule) -> Vec<u32> {
    lint_source(rel, src)
        .diagnostics
        .iter()
        .filter(|d| d.rule == rule)
        .map(|d| d.line)
        .collect()
}

// ---------------------------------------------------------------- L1

#[test]
fn l1_flags_float_accumulation_in_numeric_modules() {
    let src = fixture("bad_l1.rs");
    // `acc += x * 2.0` in a loop, and an untyped `.sum()` reduction.
    assert_eq!(lines_of(&src, "rust/src/fastcv/bad_l1.rs", Rule::FloatAccum), vec![4, 6]);
}

#[test]
fn l1_accepts_literal_steps_and_integer_turbofish() {
    let src = fixture("good_l1.rs");
    let lint = lint_source("rust/src/fastcv/good_l1.rs", &src);
    assert!(lint.diagnostics.is_empty(), "{:?}", lint.diagnostics);
}

#[test]
fn l1_is_silent_inside_the_kernel_allowlist() {
    let src = fixture("bad_l1.rs");
    assert!(lines_of(&src, "rust/src/linalg/gemm.rs", Rule::FloatAccum).is_empty());
}

#[test]
fn l1_is_silent_in_exempt_files() {
    let src = fixture("bad_l1.rs");
    assert!(lines_of(&src, "rust/benches/bad_l1.rs", Rule::FloatAccum).is_empty());
}

#[test]
fn l1_is_silent_in_the_chol_update_kernel_file() {
    // chol_update.rs joined the kernel allowlist with the incremental
    // engine: its rotation recurrences are the accumulation order the
    // stream_* suite pins across ISAs.
    let src = fixture("bad_l1.rs");
    assert!(lines_of(&src, "rust/src/linalg/chol_update.rs", Rule::FloatAccum).is_empty());
}

// ---------------------------------------------------------------- L2

#[test]
fn l2_flags_hash_iteration_wall_clock_and_entropy_rngs() {
    let src = fixture("bad_l2.rs");
    // HashMap, SystemTime, thread_rng — one per line.
    assert_eq!(lines_of(&src, "rust/src/fastcv/bad_l2.rs", Rule::Nondet), vec![1, 2, 3]);
}

#[test]
fn l2_restricts_perm_engine_rng_construction() {
    let src = fixture("bad_l2_perm.rs");
    // `Rng::new` and `.fork()` under a permutation-engine path.
    assert_eq!(lines_of(&src, "rust/src/fastcv/perm_batch.rs", Rule::Nondet), vec![2, 3]);
}

#[test]
fn l2_accepts_counter_seeded_streams_in_perm_engines() {
    let src = fixture("good_l2_perm.rs");
    let lint = lint_source("rust/src/fastcv/perm_batch.rs", &src);
    assert!(lint.diagnostics.is_empty(), "{:?}", lint.diagnostics);
}

// ---------------------------------------------------------------- L3

#[test]
fn l3_flags_unsafe_without_safety_comment_or_audit() {
    let src = fixture("bad_l3.rs");
    // Two findings at the same line: missing SAFETY + unaudited file.
    assert_eq!(lines_of(&src, "rust/src/util/helpers.rs", Rule::Unsafe), vec![2, 2]);
}

#[test]
fn l3_applies_even_in_exempt_test_files() {
    let src = fixture("bad_l3.rs");
    assert_eq!(lines_of(&src, "rust/tests/some_test.rs", Rule::Unsafe), vec![2, 2]);
}

#[test]
fn l3_accepts_safety_comment_in_audited_file() {
    let src = fixture("good_l3.rs");
    let lint = lint_source("rust/src/util/threadpool.rs", &src);
    assert!(lint.diagnostics.is_empty(), "{:?}", lint.diagnostics);
}

#[test]
fn l3_flags_intrinsics_unsafe_outside_the_audited_simd_files() {
    let src = fixture("bad_l3_intrinsics.rs");
    // SAFETY notes are present and adjacent, so only the audited-file leg
    // fires — once per `unsafe` token (the wrapper call at line 8 and the
    // `#[target_feature]` fn declaration at line 14). A new SIMD module
    // cannot ship without being added to UNSAFE_AUDITED_FILES.
    assert_eq!(lines_of(&src, "rust/src/linalg/simd_sse2.rs", Rule::Unsafe), vec![8, 14]);
}

#[test]
fn l3_accepts_the_audited_simd_kernel_files() {
    let src = fixture("bad_l3_intrinsics.rs");
    // The same source is fully clean under both audited SIMD kernel paths:
    // L3 passes (SAFETY + allowlist) and L1 is silent because the SIMD
    // modules sit in the kernel allowlist alongside gemm.rs.
    for rel in ["rust/src/linalg/simd_avx2.rs", "rust/src/linalg/simd_neon.rs"] {
        let lint = lint_source(rel, &src);
        assert!(lint.diagnostics.is_empty(), "{rel}: {:?}", lint.diagnostics);
    }
}

#[test]
fn l1_is_silent_in_the_dispatch_and_simd_kernel_files() {
    let src = fixture("bad_l1.rs");
    for rel in [
        "rust/src/linalg/dispatch.rs",
        "rust/src/linalg/simd_avx2.rs",
        "rust/src/linalg/simd_neon.rs",
    ] {
        assert!(lines_of(&src, rel, Rule::FloatAccum).is_empty(), "{rel}");
    }
}

// ---------------------------------------------------------------- L4

#[test]
fn l4_flags_unwrap_and_panic_on_library_paths() {
    let src = fixture("bad_l4.rs");
    assert_eq!(lines_of(&src, "rust/src/cv/bad_l4.rs", Rule::Panic), vec![2, 4]);
}

#[test]
fn l4_exempts_the_test_region() {
    let src = fixture("good_l4.rs");
    let lint = lint_source("rust/src/cv/good_l4.rs", &src);
    assert!(lint.diagnostics.is_empty(), "{:?}", lint.diagnostics);
}

#[test]
fn l4_is_silent_in_panic_allowed_files() {
    let src = fixture("bad_l4.rs");
    assert!(lines_of(&src, "rust/src/util/prop.rs", Rule::Panic).is_empty());
    // chol_update.rs: dimension-contract asserts are the documented policy
    // (SPD-boundary downdate failures still return Result).
    assert!(lines_of(&src, "rust/src/linalg/chol_update.rs", Rule::Panic).is_empty());
    // The incremental *driver* is not exempt — only the kernel file is.
    assert_eq!(lines_of(&src, "rust/src/fastcv/incremental.rs", Rule::Panic), vec![2, 4]);
}

#[test]
fn l4_exempts_the_serve_catch_unwind_boundary_only() {
    let src = fixture("bad_l4.rs");
    // recover.rs hosts the deliberate fault-injection panic contained by
    // run_caught; the rest of the serve daemon stays under the no-panic
    // policy (filter to Rule::Panic — L5 also fires on these paths).
    assert!(lines_of(&src, "rust/src/serve/recover.rs", Rule::Panic).is_empty());
    assert_eq!(lines_of(&src, "rust/src/serve/handlers.rs", Rule::Panic), vec![2, 4]);
}

#[test]
fn l3_accepts_the_audited_sigterm_cleanup_file() {
    let src = fixture("good_l3.rs");
    // signal.rs joined UNSAFE_AUDITED_FILES with the SIGTERM socket
    // cleanup (hand-declared POSIX externs, SAFETY notes in situ). Filter
    // to Rule::Unsafe: the fixture's undocumented pub fn would trip L5's
    // widened serve/ surface, which is not under test here.
    assert!(lines_of(&src, "rust/src/serve/signal.rs", Rule::Unsafe).is_empty());
    // An unaudited serve file with the same source still fails the
    // audited-file leg.
    assert!(!lines_of(&src, "rust/src/serve/other.rs", Rule::Unsafe).is_empty());
}

// ---------------------------------------------------------------- L5

#[test]
fn l5_flags_undocumented_public_ctx_entry_points() {
    let src = fixture("bad_l5.rs");
    assert_eq!(lines_of(&src, "rust/src/fastcv/bad_l5.rs", Rule::Doc), vec![1]);
}

#[test]
fn l5_accepts_rustdoc_directly_above() {
    let src = fixture("good_l5.rs");
    let lint = lint_source("rust/src/fastcv/good_l5.rs", &src);
    assert!(lint.diagnostics.is_empty(), "{:?}", lint.diagnostics);
}

#[test]
fn l5_widens_to_all_public_items_in_store_and_serve() {
    let src = "pub struct Store { pub n: usize }\n\
               pub fn lookup(s: &Store) -> usize { s.n }\n\
               pub(crate) fn internal() {}\n\
               /// Documented enum.\n\
               pub enum Kind { A }\n";
    // Outside the doc-all dirs only `_ctx` functions are checked.
    assert!(lines_of(src, "rust/src/fastcv/api.rs", Rule::Doc).is_empty());
    // Under store/ and serve/ the undocumented struct and fn are flagged;
    // pub(crate) and the documented enum are not.
    assert_eq!(lines_of(src, "rust/src/store/api.rs", Rule::Doc), vec![1, 2]);
    assert_eq!(lines_of(src, "rust/src/serve/api.rs", Rule::Doc), vec![1, 2]);
}

// ---------------------------------------------------------------- suppressions

#[test]
fn suppressions_are_themselves_linted() {
    let src = fixture("bad_suppression.rs");
    // Unknown rule, missing reason, and an unused (stale) allow.
    assert_eq!(
        lines_of(&src, "rust/src/fastcv/bad_sup.rs", Rule::Suppression),
        vec![1, 4, 7]
    );
}

#[test]
fn a_matching_suppression_silences_and_is_counted() {
    let src = fixture("good_suppression.rs");
    let lint = lint_source("rust/src/model/good_sup.rs", &src);
    assert!(lint.diagnostics.is_empty(), "{:?}", lint.diagnostics);
    assert_eq!(lint.suppressions_used, 1);
}

// ---------------------------------------------------------------- self-check

/// The shipped tree must be lint-clean: this is the same walk `verify.sh`
/// and CI run via the `lint` binary, executed in-process.
#[test]
fn shipped_tree_is_lint_clean() {
    // CARGO_MANIFEST_DIR is rust/; the workspace root is its parent.
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().to_path_buf();
    let report = lint_workspace(&root).expect("walking the workspace");
    assert_eq!(report.violations(), 0, "lint violations:\n{}", report.render());
    assert!(
        report.suppressions_used > 0,
        "the tree carries lint:allow annotations; none matching means the rules drifted"
    );
    assert!(
        report.files_scanned >= 40,
        "only {} files scanned — walk roots look wrong",
        report.files_scanned
    );
}
