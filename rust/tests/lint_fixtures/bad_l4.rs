pub fn pick(v: &[f64]) -> f64 {
    let first = v.first().unwrap();
    if *first < 0.0 {
        panic!("negative");
    }
    *first
}
