pub fn shuffle(seed: u64, idx: u64) -> u64 {
    let mut rng = Rng::stream(seed, idx);
    rng.next_u64()
}
