pub fn mix(xs: &[f64]) -> f64 {
    let mut acc = 0.0;
    for &x in xs {
        // lint:allow(float_accum, reason = "fixture: serial accumulation in one canonical order")
        acc += x;
    }
    acc
}
