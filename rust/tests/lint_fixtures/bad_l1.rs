pub fn accumulate(xs: &[f64]) -> f64 {
    let mut acc = 0.0;
    for &x in xs {
        acc += x * 2.0;
    }
    let extra: f64 = xs.iter().sum();
    acc + extra
}
