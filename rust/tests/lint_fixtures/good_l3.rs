pub fn peek(v: &[u8]) -> u8 {
    // SAFETY: caller guarantees v is non-empty.
    unsafe { *v.get_unchecked(0) }
}
