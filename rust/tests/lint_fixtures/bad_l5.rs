pub fn solve_ctx(n: usize) -> usize {
    n
}
