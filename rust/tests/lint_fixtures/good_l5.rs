/// Solve with a shared compute context.
pub fn solve_ctx(n: usize) -> usize {
    n
}
