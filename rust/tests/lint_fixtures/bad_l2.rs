pub fn bad(map: std::collections::HashMap<u32, u32>) -> u64 {
    let _now = std::time::SystemTime::now();
    let _r = thread_rng();
    map.len() as u64
}
