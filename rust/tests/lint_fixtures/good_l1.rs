pub fn count_and_step(xs: &[f64]) -> usize {
    let n = xs.iter().map(|_| 1usize).sum::<usize>();
    let mut steps = 0.0;
    for _ in 0..n {
        steps += 1.0;
    }
    let _ = steps;
    n
}
