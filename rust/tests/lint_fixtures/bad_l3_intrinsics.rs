// Mini SIMD microkernel in the house idiom: a safe wrapper whose only
// `unsafe` is the call into a `#[target_feature]` impl, each carrying an
// adjacent SAFETY note. Clean under the audited kernel paths; an
// unaudited path must still fail the allowlist leg of rule L3.
pub fn axpy(y: &mut [f64], a: f64, x: &[f64]) {
    // SAFETY: `axpy_impl` only requires the CPU feature promised by the
    // dispatch table, which runtime detection verified before selection.
    unsafe { axpy_impl(y, a, x) }
}

// SAFETY: `#[target_feature]` fn — the implicit unsafe body only touches
// its argument slices through checked iterators; no raw pointers escape.
#[target_feature(enable = "avx2")]
unsafe fn axpy_impl(y: &mut [f64], a: f64, x: &[f64]) {
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}
