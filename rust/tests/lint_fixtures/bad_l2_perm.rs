pub fn shuffle(seed: u64) {
    let mut rng = Rng::new(seed);
    let _child = rng.fork();
}
