pub fn pick(v: &[f64]) -> Option<f64> {
    v.first().copied()
}

#[cfg(test)]
mod tests {
    #[test]
    fn picks() {
        assert_eq!(super::pick(&[1.0]), Some(1.0));
        super::pick(&[]).map(|_| ()).ok_or("empty").unwrap_err();
    }
}
