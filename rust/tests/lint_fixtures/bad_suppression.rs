// lint:allow(bogus, reason = "no such rule")
pub fn a() {}

// lint:allow(panic)
pub fn b() {}

// lint:allow(panic, reason = "stale: nothing here panics")
pub fn c() {}
