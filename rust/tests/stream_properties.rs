//! Property suite for the incremental streaming engine (`stream_*`,
//! gated by `scripts/verify.sh` and the CI isa-matrix job).
//!
//! Pins the three contracts of `fastcv::incremental` + `linalg::chol_update`:
//!
//! 1. **Update algebra** — a rank-1 update rotates the factor to exactly
//!    the refactored Gram's (to factorisation tolerance); a downdate
//!    reverses an update to *roundoff* (bitwise reversal is impossible in
//!    IEEE arithmetic — `sqrt`/square do not cancel — which is exactly why
//!    the driver has `exact_refresh_every`); block-k forms are **bitwise**
//!    k applications of the rank-1 kernels.
//! 2. **Driver agreement** — the sliding-window engine tracks the
//!    from-scratch rebuild reference within tolerance on every step, is
//!    **bitwise** the rebuild on exact-refresh steps, and is bitwise
//!    deterministic for a fixed input sequence.
//! 3. **ISA invariance** — the whole stream produces identical bits under
//!    forced scalar and every supported SIMD dispatch.

use fastcv::fastcv::incremental::{SlidingWindowCv, StepResult, StreamConfig};
use fastcv::fastcv::ComputeContext;
use fastcv::linalg::dispatch::{force_scope, Isa};
use fastcv::linalg::{
    chol_downdate, chol_downdate_block, chol_update, chol_update_block, syrk_t, Cholesky, Mat,
};
use fastcv::store::{ArtifactKey, FactorStore};
use fastcv::util::rng::Rng;

fn random_mat(rng: &mut Rng, r: usize, c: usize) -> Mat {
    Mat::from_fn(r, c, |_, _| rng.gauss())
}

fn spd(rng: &mut Rng, n: usize) -> Mat {
    let base = random_mat(rng, n + 3, n);
    let mut g = syrk_t(&base);
    for i in 0..n {
        g[(i, i)] += 1.0;
    }
    g
}

fn assert_close(got: &[f64], want: &[f64], tol: f64, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
        let scale = w.abs().max(1.0);
        assert!(
            (g - w).abs() <= tol * scale,
            "{what}: index {i}: got {g}, want {w} (tol {tol})"
        );
    }
}

/// A deterministic synthetic stream: gaussian features, labels from the
/// feature sign plus noise so the window carries real signal.
fn stream_data(seed: u64, steps: usize, p: usize) -> Vec<(Vec<f64>, usize)> {
    let mut rng = Rng::new(seed);
    (0..steps)
        .map(|_| {
            let label = (rng.next_u64() % 2) as usize;
            let shift = if label == 0 { 0.8 } else { -0.8 };
            let x: Vec<f64> = (0..p).map(|_| rng.gauss() + shift).collect();
            (x, label)
        })
        .collect()
}

fn run_stream(cfg: &StreamConfig, data: &[(Vec<f64>, usize)]) -> Vec<StepResult> {
    let mut cv = SlidingWindowCv::new(cfg.clone(), ComputeContext::serial()).unwrap();
    data.iter()
        .filter_map(|(x, l)| cv.push(x.clone(), *l).unwrap())
        .collect()
}

// ---------------------------------------------------------------------------
// 1. Update algebra.
// ---------------------------------------------------------------------------

#[test]
fn stream_update_then_downdate_roundtrips_within_tolerance() {
    let mut rng = Rng::new(31);
    for n in [1usize, 2, 5, 12, 24] {
        let g = spd(&mut rng, n);
        let reference = Cholesky::factor(&g).unwrap();
        let v: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
        let mut ch = reference.clone();
        chol_update(&mut ch, &v);
        chol_downdate(&mut ch, &v).unwrap();
        // Roundoff-level return, NOT bitwise: sqrt(r²) ≠ r in general.
        assert_close(
            ch.l().as_slice(),
            reference.l().as_slice(),
            1e-12,
            &format!("update∘downdate n={n}"),
        );
    }
}

#[test]
fn stream_block_kernels_are_bitwise_k_singles() {
    let mut rng = Rng::new(32);
    for (n, k) in [(4usize, 1usize), (8, 3), (16, 5)] {
        let g = spd(&mut rng, n);
        let vs = random_mat(&mut rng, k, n);
        // Block update == k in-order rank-1 updates, bitwise.
        let mut block = Cholesky::factor(&g).unwrap();
        chol_update_block(&mut block, &vs);
        let mut singles = Cholesky::factor(&g).unwrap();
        for r in 0..k {
            chol_update(&mut singles, vs.row(r));
        }
        assert_eq!(
            block.l().as_slice(),
            singles.l().as_slice(),
            "block update n={n} k={k}"
        );
        // Same for the downdate pair (downdating what we just updated).
        chol_downdate_block(&mut block, &vs).unwrap();
        for r in 0..k {
            chol_downdate(&mut singles, vs.row(r)).unwrap();
        }
        assert_eq!(
            block.l().as_slice(),
            singles.l().as_slice(),
            "block downdate n={n} k={k}"
        );
    }
}

#[test]
fn stream_update_matches_refactorisation() {
    // L after a rank-1 update must equal the factor of G + vvᵀ to
    // factorisation accuracy (the algebra, not just self-consistency).
    let mut rng = Rng::new(33);
    for n in [3usize, 10, 21] {
        let g = spd(&mut rng, n);
        let v: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
        let mut ch = Cholesky::factor(&g).unwrap();
        chol_update(&mut ch, &v);
        let mut gv = g.clone();
        for i in 0..n {
            for j in 0..n {
                gv[(i, j)] += v[i] * v[j];
            }
        }
        let want = Cholesky::factor(&gv).unwrap();
        assert_close(ch.l().as_slice(), want.l().as_slice(), 1e-9, &format!("update n={n}"));
    }
}

// ---------------------------------------------------------------------------
// 2. Driver agreement with the rebuild reference.
// ---------------------------------------------------------------------------

fn base_cfg() -> StreamConfig {
    StreamConfig {
        window: 16,
        lambda: 2.0,
        folds: 4,
        n_perm: 8,
        seed: 7,
        exact_refresh_every: 0,
        rebuild: false,
    }
}

#[test]
fn stream_incremental_tracks_rebuild_within_tolerance_every_step() {
    let data = stream_data(101, 40, 6);
    let incremental = run_stream(&base_cfg(), &data);
    let rebuild = run_stream(&StreamConfig { rebuild: true, ..base_cfg() }, &data);
    assert_eq!(incremental.len(), rebuild.len());
    assert!(incremental.len() > 30, "window=16, folds=4 → evaluation from step 4");
    for (inc, reb) in incremental.iter().zip(&rebuild) {
        assert_eq!(inc.step, reb.step);
        assert_eq!(inc.n, reb.n);
        // Accuracy is 1/n-quantised; the ~1e-13 factor drift only moves it
        // if a decision value sits within drift of the threshold — allow
        // at most one sample's worth of disagreement per step.
        let n = inc.n as f64;
        assert!(
            (inc.accuracy - reb.accuracy).abs() <= 1.0 / n + 1e-12,
            "step {}: incremental acc {} vs rebuild {}",
            inc.step,
            inc.accuracy,
            reb.accuracy
        );
        let (Some(pi), Some(pr)) = (inc.p_value, reb.p_value) else {
            panic!("n_perm > 0 must produce p-values");
        };
        assert!(
            (pi - pr).abs() <= 2.0 / (1.0 + 8.0) + 1e-12,
            "step {}: p {} vs {}",
            inc.step,
            pi,
            pr
        );
    }
    // The maintained factor itself stays within roundoff of a rebuild.
    let mut inc_cv = SlidingWindowCv::new(base_cfg(), ComputeContext::serial()).unwrap();
    let mut reb_cv = SlidingWindowCv::new(
        StreamConfig { rebuild: true, ..base_cfg() },
        ComputeContext::serial(),
    )
    .unwrap();
    for (x, l) in &data {
        inc_cv.push(x.clone(), *l).unwrap();
        reb_cv.push(x.clone(), *l).unwrap();
    }
    let (inc_f, reb_f) = (inc_cv.factor().unwrap(), reb_cv.factor().unwrap());
    assert_close(
        inc_f.chol.l().as_slice(),
        reb_f.chol.l().as_slice(),
        1e-9,
        "final factor drift",
    );
    assert!(inc_cv.incremental_steps > 0, "incremental path must actually run");
    assert_eq!(reb_cv.incremental_steps, 0, "rebuild mode must never maintain");
}

#[test]
fn stream_exact_refresh_steps_are_bitwise_the_rebuild() {
    let data = stream_data(102, 36, 5);
    let k = 3;
    let cfg = StreamConfig { exact_refresh_every: k, ..base_cfg() };
    let refreshed = run_stream(&cfg, &data);
    let rebuild = run_stream(&StreamConfig { rebuild: true, ..base_cfg() }, &data);
    let mut refresh_steps = 0;
    for (inc, reb) in refreshed.iter().zip(&rebuild) {
        if inc.refreshed {
            refresh_steps += 1;
            assert_eq!(
                inc.accuracy.to_bits(),
                reb.accuracy.to_bits(),
                "step {}: refresh step must be bitwise the rebuild",
                inc.step
            );
            assert_eq!(
                inc.p_value.map(f64::to_bits),
                reb.p_value.map(f64::to_bits),
                "step {}: refresh-step p-value",
                inc.step
            );
        }
    }
    assert!(refresh_steps > 5, "K={k} over {} evaluated steps", refreshed.len());
    // K = 1 degenerates to the rebuild reference everywhere, bitwise.
    let every = run_stream(&StreamConfig { exact_refresh_every: 1, ..base_cfg() }, &data);
    for (a, b) in every.iter().zip(&rebuild) {
        assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits(), "K=1 step {}", a.step);
        assert_eq!(a.p_value.map(f64::to_bits), b.p_value.map(f64::to_bits));
    }
}

#[test]
fn stream_same_sequence_is_bitwise_deterministic() {
    let data = stream_data(103, 30, 4);
    let a = run_stream(&base_cfg(), &data);
    let b = run_stream(&base_cfg(), &data);
    assert_eq!(a.len(), b.len());
    for (ra, rb) in a.iter().zip(&b) {
        assert_eq!(ra.accuracy.to_bits(), rb.accuracy.to_bits(), "step {}", ra.step);
        assert_eq!(ra.p_value.map(f64::to_bits), rb.p_value.map(f64::to_bits));
        assert_eq!((ra.refreshed, ra.evicted, ra.n), (rb.refreshed, rb.evicted, rb.n));
    }
}

// ---------------------------------------------------------------------------
// 3. ISA invariance.
// ---------------------------------------------------------------------------

#[test]
fn stream_results_are_isa_invariant() {
    let data = stream_data(104, 28, 5);
    let run_under = |isa: Isa| {
        let _g = force_scope(isa).unwrap();
        let mut cv = SlidingWindowCv::new(base_cfg(), ComputeContext::serial()).unwrap();
        let mut out = Vec::new();
        for (x, l) in &data {
            if let Some(r) = cv.push(x.clone(), *l).unwrap() {
                out.push(r);
            }
        }
        let factor_bits: Vec<u64> =
            cv.factor().unwrap().chol.l().as_slice().iter().map(|v| v.to_bits()).collect();
        (out, factor_bits)
    };
    let (want, want_factor) = run_under(Isa::Scalar);
    for isa in Isa::supported() {
        if isa == Isa::Scalar {
            continue;
        }
        let (got, got_factor) = run_under(isa);
        assert_eq!(got.len(), want.len(), "[{isa}]");
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(
                g.accuracy.to_bits(),
                w.accuracy.to_bits(),
                "[{isa}] step {}: accuracy bits moved",
                g.step
            );
            assert_eq!(g.p_value.map(f64::to_bits), w.p_value.map(f64::to_bits), "[{isa}]");
        }
        assert_eq!(got_factor, want_factor, "[{isa}] rolling factor bits moved");
    }
}

// ---------------------------------------------------------------------------
// Store lineage.
// ---------------------------------------------------------------------------

#[test]
fn stream_exact_refresh_stays_exact_when_window_bytes_repeat() {
    // Regression: on a constant stream every exact-refresh step sees the
    // same window bytes, so the content-addressed exact key repeats; the
    // first refresh's entry is then superseded by the incremental steps'
    // drifted factors. A lineage-following store lookup would serve that
    // drifted descendant as if it were an exact rebuild — breaking the
    // bitwise-rebuild contract exactly where the drift-bounding knob (and
    // the refused-downdate rescue) depends on it.
    let x = vec![0.5, -1.25, 2.0];
    let store = FactorStore::new();
    let ctx = ComputeContext::serial().with_store(&store);
    let cfg = StreamConfig {
        window: 6,
        lambda: 2.0,
        folds: 2,
        n_perm: 0,
        seed: 9,
        exact_refresh_every: 3,
        rebuild: false,
    };
    let mut cv = SlidingWindowCv::new(cfg.clone(), ctx).unwrap();
    let mut reb = SlidingWindowCv::new(
        StreamConfig { rebuild: true, ..cfg.clone() },
        ComputeContext::serial(),
    )
    .unwrap();
    let mut checked_refreshes = 0;
    for i in 0..30u64 {
        let ri = cv.push(x.clone(), (i % 2) as usize).unwrap();
        let rr = reb.push(x.clone(), (i % 2) as usize).unwrap();
        assert_eq!(ri.is_some(), rr.is_some());
        let Some(ri) = ri else { continue };
        if ri.refreshed {
            checked_refreshes += 1;
            let (f, fr) = (cv.factor().unwrap(), reb.factor().unwrap());
            assert_eq!(
                f.lineage, fr.lineage,
                "step {}: refresh served a non-exact (drifted) factor",
                ri.step
            );
            assert_eq!(
                f.chol.l().as_slice(),
                fr.chol.l().as_slice(),
                "step {}: refresh factor must be bitwise the rebuild",
                ri.step
            );
        }
    }
    assert!(checked_refreshes >= 7, "K=3 over 29 evaluated steps: {checked_refreshes}");
}

#[test]
fn stream_store_lineage_supersedes_in_place_and_resolves_stale_keys() {
    let data = stream_data(105, 24, 4);
    let store = FactorStore::new();
    let ctx = ComputeContext::serial().with_store(&store);
    let cfg = base_cfg();
    let mut cv = SlidingWindowCv::new(cfg.clone(), ctx).unwrap();
    let mut mid_key = None;
    for (i, (x, l)) in data.iter().enumerate() {
        cv.push(x.clone(), *l).unwrap();
        if i == 10 {
            mid_key = cv.factor().map(|f| ArtifactKey::window(f.lineage, cfg.lambda));
        }
    }
    let s = store.stats();
    // One rolling artifact, updated in place — never a growing entry list.
    assert_eq!(s.entries, 1, "{s:?}");
    assert!(s.supersessions > 10, "each step supersedes its parent: {s:?}");
    assert_eq!(s.evictions, 0, "supersession is not eviction: {s:?}");
    // A stale mid-stream key still resolves — to the *current* factor.
    let stale = mid_key.expect("step 11 must have produced a factor");
    let resolved = store.resolve_window(&stale).expect("lineage must resolve the stale key");
    let current = cv.factor().unwrap();
    assert_eq!(
        resolved.chol.l().as_slice(),
        current.chol.l().as_slice(),
        "stale key must serve the superseding factor"
    );
    assert_eq!(resolved.lineage, current.lineage);
    // The current key resolves directly too.
    let head = ArtifactKey::window(current.lineage, cfg.lambda);
    assert!(store.resolve_window(&head).is_some());
    // Determinism is unaffected by store routing.
    let with_store: Vec<StepResult> = {
        let store2 = FactorStore::new();
        let ctx2 = ComputeContext::serial().with_store(&store2);
        let mut cv2 = SlidingWindowCv::new(cfg.clone(), ctx2).unwrap();
        data.iter().filter_map(|(x, l)| cv2.push(x.clone(), *l).unwrap()).collect()
    };
    let without: Vec<StepResult> = run_stream(&cfg, &data);
    for (a, b) in with_store.iter().zip(&without) {
        assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits(), "store moved a float");
        assert_eq!(a.p_value.map(f64::to_bits), b.p_value.map(f64::to_bits));
    }
}
