//! Chaos suite: deterministic fault injection across the spill, store,
//! serve, and client layers (docs/ROBUSTNESS.md). Every test installs a
//! counter-seeded [`fastcv::fastcv::fault::FaultPlan`] (the `install`
//! scope also serialises fault-state tests against each other), forces a
//! named failure, and then pins the recovery contract: the daemon stays
//! up, the failure surfaces as a typed error or a rebuilt result, and the
//! post-recovery answer is **bitwise identical** to a fault-free run.
//!
//! CI runs this suite twice — forced-scalar and native ISA — plus once
//! under a `FASTCV_FAULT_PLAN` environment plan (the `chaos` job).

use fastcv::fastcv::fault::{self, install, FaultPlan};
use fastcv::linalg::{Mat, PanelStore, SpillError};
use fastcv::serve::{ServeConfig, Server};
use fastcv::util::json::Json;
use std::path::PathBuf;

fn temp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("fastcv_chaos_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn plan(spec: &str) -> FaultPlan {
    FaultPlan::parse(spec).unwrap()
}

// ---------------------------------------------------------------- spill

#[test]
fn chaos_corrupt_read_is_typed_and_the_reread_is_bitwise() {
    let base = temp_dir("corrupt_read");
    let g = Mat::from_fn(8, 8, |i, j| (i * 8 + j) as f64 * 0.5);
    let mut store = PanelStore::new(8, 4, Some(&base)).unwrap();
    store.write_mat(&g).unwrap();
    {
        let _scope = install(plan("spill.read.corrupt@1"));
        let err = store.read_panel(0).err().expect("injected corruption must be detected");
        assert!(
            matches!(err.downcast_ref::<SpillError>(), Some(SpillError::Corrupt { .. })),
            "{err:#}"
        );
        // The fault corrupted the *read*, not the file: the @1 rule is
        // spent and the next read serves the intact bytes.
        assert_eq!(store.read_panel(0).unwrap().as_slice(), &g.as_slice()[..4 * 8]);
    }
    assert_eq!(store.to_mat().unwrap().as_slice(), g.as_slice(), "bitwise after recovery");
    drop(store);
    let _ = std::fs::remove_dir_all(&base);
}

#[test]
fn chaos_delayed_reads_change_timing_never_bytes() {
    let base = temp_dir("delay");
    let g = Mat::from_fn(6, 6, |i, j| 1.0 / (1.0 + (i + 2 * j) as f64));
    let mut store = PanelStore::new(6, 3, Some(&base)).unwrap();
    store.write_mat(&g).unwrap();
    let _scope = install(plan("spill.read.delay%1=2"));
    // Every read is delayed 2 ms; the bytes are untouched.
    assert_eq!(store.to_mat().unwrap().as_slice(), g.as_slice());
    drop(store);
    let _ = std::fs::remove_dir_all(&base);
}

#[test]
fn chaos_torn_write_is_detected_and_the_rewrite_restores_bitwise() {
    let base = temp_dir("torn_write");
    let g = Mat::from_fn(7, 7, |i, j| (i as f64).mul_add(7.0, j as f64));
    let mut store = PanelStore::new(7, 7, Some(&base)).unwrap();
    {
        let _scope = install(plan("spill.write.torn@1=9"));
        store.write_mat(&g).unwrap(); // the torn write "succeeds" silently
        let err = store.read_panel(0).err().expect("torn panel must be rejected");
        assert!(
            matches!(err.downcast_ref::<SpillError>(), Some(SpillError::Torn { .. })),
            "{err:#}"
        );
        store.write_mat(&g).unwrap(); // recovery: rewrite (arrival 2 is clean)
    }
    store.verify().unwrap();
    assert_eq!(store.to_mat().unwrap().as_slice(), g.as_slice(), "bitwise after rewrite");
    drop(store);
    let _ = std::fs::remove_dir_all(&base);
}

#[test]
fn chaos_write_io_errors_are_typed_and_the_retry_lands_the_panel() {
    let base = temp_dir("write_io");
    let g = Mat::from_fn(5, 5, |i, j| ((i + 1) * (j + 2)) as f64);
    let mut store = PanelStore::new(5, 5, Some(&base)).unwrap();
    {
        let _scope = install(plan("spill.write.io@1"));
        let err = store.write_mat(&g).err().expect("injected IO failure must error");
        assert!(
            matches!(err.downcast_ref::<SpillError>(), Some(SpillError::Io { .. })),
            "{err:#}"
        );
        store.write_mat(&g).unwrap();
    }
    assert_eq!(store.to_mat().unwrap().as_slice(), g.as_slice());
    drop(store);
    let _ = std::fs::remove_dir_all(&base);
}

// ---------------------------------------------------------------- serve

const PERM_REQ: &str = r#"{"id":1,"op":"perm","data":{"synthetic":{"n":24,"p":12,"seed":5}},"folds":{"k":4},"lambda":1.0,"n_perm":8,"seed":100}"#;

fn serve_lines(server: &Server, lines: &[&str]) -> Vec<String> {
    let input = lines.join("\n");
    let mut out: Vec<u8> = Vec::new();
    server
        .serve_stream(std::io::Cursor::new(input.into_bytes()), &mut out)
        .unwrap();
    String::from_utf8(out).unwrap().lines().map(str::to_string).collect()
}

#[test]
fn chaos_worker_panic_recovery_preserves_the_bitwise_response_contract() {
    // The acceptance centerpiece: a fault-free run and a post-panic
    // resend must produce byte-identical result lines — the recovery
    // path may cost a retry, never a different answer.
    let shutdown = r#"{"id":9,"op":"shutdown"}"#;
    let clean = Server::new(ServeConfig::default());
    let baseline = serve_lines(&clean, &[PERM_REQ, shutdown]);
    assert_eq!(baseline.len(), 2);

    let _scope = install(plan("serve.worker.panic@1"));
    let faulty = Server::new(ServeConfig::default());
    // Same request twice: the first dies to the injected panic, the
    // resend (arrival 2) runs clean on a store the panic never touched.
    let lines = serve_lines(&faulty, &[PERM_REQ, PERM_REQ, shutdown]);
    assert_eq!(lines.len(), 3, "{lines:?}");
    let first = Json::parse(&lines[0]).unwrap();
    assert_eq!(first.get("ok"), Some(&Json::Bool(false)), "{}", lines[0]);
    assert_eq!(first.get("kind").and_then(Json::as_str), Some("worker_panic"));
    assert_eq!(lines[1], baseline[0], "post-recovery result must be bitwise identical");
    assert_eq!(faulty.worker_panics(), 1);
}

#[test]
fn chaos_conn_drop_loses_one_response_never_the_daemon() {
    let _scope = install(plan("serve.conn.drop@1"));
    let server = Server::new(ServeConfig::default());
    let lines = serve_lines(
        &server,
        &[
            r#"{"id":1,"op":"stats"}"#,
            r#"{"id":2,"op":"stats"}"#,
            r#"{"id":3,"op":"shutdown"}"#,
        ],
    );
    // The first response line was eaten by the dropped connection; the
    // daemon itself kept serving and still honoured the shutdown.
    assert_eq!(lines.len(), 2, "{lines:?}");
    let ids: Vec<f64> = lines
        .iter()
        .map(|l| Json::parse(l).unwrap().get("id").and_then(Json::as_f64).unwrap())
        .collect();
    assert_eq!(ids, vec![2.0, 3.0], "{lines:?}");
}

#[test]
fn chaos_deadline_overflow_and_panic_counters_surface_in_stats() {
    // End-to-end: force one worker panic, then ask the daemon for its
    // stats — the robustness counters ride the same response as the
    // cache counters that operators already scrape.
    let _scope = install(plan("serve.worker.panic@1"));
    let server = Server::new(ServeConfig::default());
    let lines = serve_lines(
        &server,
        &[
            r#"{"id":1,"op":"stats"}"#,
            r#"{"id":2,"op":"stats"}"#,
            r#"{"id":3,"op":"shutdown"}"#,
        ],
    );
    assert_eq!(lines.len(), 3, "{lines:?}");
    let last_stats = Json::parse(&lines[1]).unwrap();
    assert_eq!(last_stats.get("ok"), Some(&Json::Bool(true)), "{}", lines[1]);
    assert_eq!(last_stats.get("worker_panics").and_then(Json::as_f64), Some(1.0));
    assert_eq!(last_stats.get("deadline_exceeded").and_then(Json::as_f64), Some(0.0));
    assert_eq!(last_stats.get("overloaded").and_then(Json::as_f64), Some(0.0));
}

// ---------------------------------------------------------------- plans

#[test]
fn chaos_env_plan_gates_sites_when_ci_exports_one() {
    // The chaos CI job exports FASTCV_FAULT_PLAN="test.env.site@1". With
    // no scope installed, fault::hit falls back to the environment plan;
    // outside that job this test degrades to checking the no-plan no-op.
    match std::env::var("FASTCV_FAULT_PLAN") {
        Ok(spec) if spec.contains("test.env.site") => {
            assert_eq!(fault::hit("test.env.site"), Some(0), "env plan must fire");
            assert_eq!(fault::hit("test.env.site"), None, "@1 fires exactly once");
        }
        _ => {
            assert_eq!(fault::hit("test.env.site"), None, "no plan → every site is a no-op");
        }
    }
}

#[test]
fn chaos_percent_plans_fire_periodically_and_scopes_restore() {
    // `hit` returns the rule's `=arg` payload (0 when absent) on firing
    // arrivals — here every 2nd arrival, with the count shared across the
    // ComputeContext knob because both point at the same plan.
    let outer = install(plan("chaos.outer%2=7"));
    assert_eq!(fault::hit("chaos.outer"), None, "arrival 1 of %2");
    assert_eq!(fault::hit("chaos.outer"), Some(7), "arrival 2 of %2");
    {
        let _ctx = fastcv::fastcv::ComputeContext::serial().with_faults(outer.plan());
        assert_eq!(fault::hit("chaos.outer"), None, "arrival 3 continues the count");
        assert_eq!(fault::hit("chaos.outer"), Some(7), "arrival 4 of %2");
    }
    assert_eq!(outer.plan().arrivals("chaos.outer"), 4);
    drop(outer);
    assert_eq!(fault::hit("chaos.outer"), None, "dropped scope restores prior state");
}
