//! Cross-module integration tests: whole-pipeline exactness, permutation
//! calibration, hybrid backend parity, and failure injection.

use fastcv::cv::folds::{kfold, leave_one_out, stratified_kfold};
use fastcv::cv::metrics::{accuracy_signed, auc};
use fastcv::data::synthetic::{generate, SyntheticSpec};
use fastcv::fastcv::binary::{standard_cv_decision_values, AnalyticBinaryCv};
use fastcv::fastcv::multiclass::{standard_cv_predict, AnalyticMulticlassCv};
use fastcv::fastcv::FoldCache;
use fastcv::util::prop::assert_all_close;
use fastcv::util::rng::Rng;

/// The headline invariant at a realistic (EEG-like) scale: P ≫ N, ridge,
/// 10-fold — analytic decision values equal retraining exactly.
#[test]
fn exactness_at_eeg_scale() {
    let mut rng = Rng::new(1);
    let mut spec = SyntheticSpec::binary(120, 600);
    spec.separation = 1.5;
    let ds = generate(&spec, &mut rng);
    let y = ds.y_signed();
    let folds = kfold(ds.n(), 10, &mut rng);
    let std_dv = standard_cv_decision_values(&ds.x, &y, &folds, 1.0).unwrap();
    let cv = AnalyticBinaryCv::fit(&ds.x, &y, 1.0).unwrap();
    let ana_dv = cv.decision_values(&folds).unwrap();
    assert_all_close(&ana_dv, &std_dv, 1e-6, "eeg-scale exactness");
    // and the AUCs (bias-independent) coincide to machine precision
    let auc_std = auc(&std_dv, &ds.labels);
    let auc_ana = auc(&ana_dv, &ds.labels);
    assert!((auc_std - auc_ana).abs() < 1e-12);
}

/// LOO at moderate scale — the K = N limit the paper calls the analytic
/// approach's best case.
#[test]
fn loo_exactness_and_every_sample_covered() {
    let mut rng = Rng::new(2);
    let ds = generate(&SyntheticSpec::binary(80, 40), &mut rng);
    let y = ds.y_signed();
    let folds = leave_one_out(80);
    let cv = AnalyticBinaryCv::fit(&ds.x, &y, 0.5).unwrap();
    let ana = cv.decision_values(&folds).unwrap();
    let std = standard_cv_decision_values(&ds.x, &y, &folds, 0.5).unwrap();
    assert_all_close(&ana, &std, 1e-7, "LOO");
}

/// Multi-class Alg. 2 equals retraining at a 3-class EEG-like shape.
#[test]
fn multiclass_exactness_wide() {
    let mut rng = Rng::new(3);
    let mut spec = SyntheticSpec::multiclass(90, 300, 3);
    spec.separation = 1.5;
    let ds = generate(&spec, &mut rng);
    let folds = stratified_kfold(&ds.labels, 6, &mut rng);
    let std = standard_cv_predict(&ds.x, &ds.labels, 3, &folds, 2.0).unwrap();
    let cv = AnalyticMulticlassCv::fit(&ds.x, &ds.labels, 3, 2.0).unwrap();
    let ana = cv.predict(&folds).unwrap();
    assert_eq!(std, ana);
}

/// Permutation p-values are calibrated: under a true null, p ≲ α roughly α
/// of the time (coarse check over 30 datasets).
#[test]
fn permutation_p_values_calibrated_under_null() {
    let mut rng = Rng::new(4);
    let mut small_p = 0usize;
    let runs = 30;
    for _ in 0..runs {
        let mut ds = generate(&SyntheticSpec::binary(40, 10), &mut rng);
        rng.shuffle(&mut ds.labels); // break any signal
        let folds = stratified_kfold(&ds.labels, 4, &mut rng);
        let res = fastcv::fastcv::perm::analytic_binary_permutation(
            &ds.x, &ds.labels, &folds, 0.5, 39, false, &mut rng,
        )
        .unwrap();
        if res.p_value <= 0.1 {
            small_p += 1;
        }
    }
    // E[small_p] = 3; allow generous slack (binomial 30, 0.1).
    assert!(small_p <= 9, "null rejected too often: {small_p}/{runs}");
}

/// Fold cache reuse across permutations gives bit-identical results to
/// fresh factorisation.
#[test]
fn cached_and_uncached_fold_solves_identical() {
    let mut rng = Rng::new(5);
    let ds = generate(&SyntheticSpec::binary(60, 20), &mut rng);
    let y = ds.y_signed();
    let folds = kfold(60, 6, &mut rng);
    let mut cv = AnalyticBinaryCv::fit(&ds.x, &y, 0.3).unwrap();
    let cache = FoldCache::prepare(&cv.hat, &folds, false).unwrap();
    for _ in 0..5 {
        let mut y_perm = y.clone();
        rng.shuffle(&mut y_perm);
        cv.set_response(&y_perm);
        let cached = cv.decision_values_cached(&cache);
        let fresh = cv.decision_values(&folds).unwrap();
        assert_eq!(cached, fresh, "cache must not change results");
    }
}

/// Failure injection: degenerate configurations fail loudly, not wrongly.
#[test]
fn degenerate_configs_error_cleanly() {
    let mut rng = Rng::new(6);
    let ds = generate(&SyntheticSpec::binary(20, 50), &mut rng);
    let y = ds.y_signed();
    // P ≥ N with λ=0: singular gram
    assert!(AnalyticBinaryCv::fit(&ds.x, &y, 0.0).is_err());
    // bad folds
    let cv = AnalyticBinaryCv::fit(&ds.x, &y, 1.0).unwrap();
    assert!(cv.decision_values(&[vec![0, 0, 1]]).is_err(), "duplicate index");
    assert!(cv.decision_values(&[vec![99]]).is_err(), "out of range");
    assert!(cv.decision_values(&[(0..20).collect()]).is_err(), "empty train");
    // multiclass: class missing from a training fold
    let labels: Vec<usize> = (0..20).map(|i| usize::from(i >= 18)).collect();
    let mc = AnalyticMulticlassCv::fit(&ds.x, &labels, 2, 1.0).unwrap();
    let bad_folds = vec![vec![18, 19], vec![0, 1]]; // fold 0 removes all of class 1... from test? no:
    // test fold {18,19} removes class 1 entirely from its training set
    let err = mc.predict(&bad_folds);
    assert!(err.is_err(), "missing class must error");
}

/// Response-type genericity: continuous-response ridge regression runs the
/// same machinery (the "all least-squares models" claim, §4.3).
#[test]
fn ridge_regression_cv_r2() {
    let mut rng = Rng::new(7);
    let n = 100;
    let p = 30;
    let x = fastcv::linalg::Mat::from_fn(n, p, |_, _| rng.gauss());
    let w: Vec<f64> = (0..p).map(|j| if j < 5 { 1.0 } else { 0.0 }).collect();
    let y: Vec<f64> = (0..n)
        .map(|i| fastcv::linalg::dot(x.row(i), &w) + 0.3 * rng.gauss())
        .collect();
    let folds = kfold(n, 5, &mut rng);
    let cv = AnalyticBinaryCv::fit(&x, &y, 1.0).unwrap();
    let pred = cv.decision_values(&folds).unwrap();
    let r2 = fastcv::cv::metrics::r_squared(&pred, &y);
    assert!(r2 > 0.6, "cross-validated R² = {r2}");
    let std = standard_cv_decision_values(&x, &y, &folds, 1.0).unwrap();
    assert_all_close(&pred, &std, 1e-8, "regression CV exactness");
}

/// Coordinator smoke: a tiny sweep end-to-end through the scheduler, with
/// accuracy agreement between arms on every point.
#[test]
fn coordinator_tiny_sweep_end_to_end() {
    use fastcv::coordinator::sweep::{grid, Experiment, SweepScale};
    use fastcv::coordinator::{Scheduler, SweepReport};
    let scale = SweepScale::tiny();
    let mut points = grid(Experiment::MultiCv, &scale);
    points.truncate(4);
    let results = Scheduler::new(2, 42, false).run(&points);
    assert_eq!(results.len(), 4);
    for r in &results {
        assert!((r.acc_std - r.acc_ana).abs() < 1e-12, "{}", r.label);
        assert!(r.t_std > 0.0 && r.t_ana > 0.0);
    }
    let report = SweepReport::new(results);
    assert!(report.render("tiny").contains("rel.eff"));
}

/// Hybrid backend parity at the artifact shape (skips without artifacts).
#[test]
fn xla_backend_parity_when_available() {
    let Ok(rt) = fastcv::runtime::XlaRuntime::load_default() else { return };
    let key = fastcv::runtime::ArtifactKey::analytic_cv(60, 12, 5);
    if !rt.has(&key) {
        eprintln!("skipping: artifact (60,12,5) not present");
        return;
    }
    let mut rng = Rng::new(8);
    let ds = generate(&SyntheticSpec::binary(60, 12), &mut rng);
    let y = ds.y_signed();
    let folds = kfold(60, 5, &mut rng);
    let (dv_xla, engine) =
        fastcv::runtime::hybrid::analytic_cv(Some(&rt), &ds.x, &y, &folds, 0.8).unwrap();
    assert_eq!(engine, fastcv::runtime::hybrid::Engine::Xla);
    let (dv_nat, _) = fastcv::runtime::hybrid::analytic_cv(None, &ds.x, &y, &folds, 0.8).unwrap();
    assert_all_close(&dv_xla, &dv_nat, 1e-9, "xla parity");
    // and against the standard approach — three implementations, one answer
    let std = standard_cv_decision_values(&ds.x, &y, &folds, 0.8).unwrap();
    assert_all_close(&dv_xla, &std, 1e-6, "xla vs retraining");
}

/// Repeated CV (§2.1): averaging across repeats reduces variance of the
/// accuracy estimate.
#[test]
fn repeated_cv_reduces_variance() {
    let mut rng = Rng::new(9);
    let mut spec = SyntheticSpec::binary(60, 15);
    spec.separation = 1.2;
    let ds = generate(&spec, &mut rng);
    let y = ds.y_signed();
    let cv = AnalyticBinaryCv::fit(&ds.x, &y, 1.0).unwrap();
    let mut single = Vec::new();
    let mut averaged = Vec::new();
    for _ in 0..12 {
        let folds = kfold(60, 5, &mut rng);
        single.push(accuracy_signed(&cv.decision_values(&folds).unwrap(), &y));
        let reps: Vec<f64> = (0..5)
            .map(|_| {
                let f = kfold(60, 5, &mut rng);
                accuracy_signed(&cv.decision_values(&f).unwrap(), &y)
            })
            .collect();
        averaged.push(fastcv::util::mean(&reps));
    }
    assert!(
        fastcv::util::stddev(&averaged) <= fastcv::util::stddev(&single) + 1e-9,
        "repeated CV should not increase variance"
    );
}
