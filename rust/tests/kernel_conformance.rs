//! Kernel-conformance suite: every `(kernel, ISA)` pair against the scalar
//! reference, **bitwise**.
//!
//! The dispatch layer's contract (`linalg::dispatch` module docs) is that
//! the ISA knob is a pure wall-clock choice: every SIMD kernel reproduces
//! the scalar canonical accumulation order bit-for-bit. These tests pin
//! that contract at three levels —
//!
//! 1. the primitive table entries (`micro`/`axpy`/`axpy_sub`/`dot`) called
//!    directly, across full tiles, `MR`/`NR` remainder lanes, and
//!    `k = 0/1` edges;
//! 2. the blocked entry points (`gemm_acc_isa`, `matmul_isa`,
//!    `syrk_t_isa`) across awkward shapes, `KC` boundaries, and the
//!    `aij == 0` skip path;
//! 3. the dispatched consumers (`Cholesky` solves, `matvec_t`, `ger`)
//!    under [`force_scope`] — the same process-wide override the CLI
//!    `--isa` flag and `FASTCV_FORCE_ISA` install, so each reachable
//!    dispatch path is exercised even on hardware that would auto-select
//!    another. CI drives this binary under `FASTCV_FORCE_ISA=scalar` and
//!    the widest vector ISA (the isa-matrix job) so the env knob itself is
//!    also exercised end to end.
//!
//! On NaN: all *non-NaN* outputs must agree bitwise (that includes every
//! ±∞ and ±0 case — fully determined by IEEE-754). Where an output is NaN,
//! both sides must be NaN at the same position, but the *payload* is not
//! part of the contract (payload propagation is implementation-defined and
//! no consumer inspects it).

use fastcv::linalg::dispatch::{self, force_scope, kernels, Isa};
use fastcv::linalg::{gemm_acc_isa, ger, matmul_isa, matvec_t, syrk_t_isa, Mat};
use fastcv::util::rng::Rng;

fn random_mat(rng: &mut Rng, r: usize, c: usize) -> Mat {
    Mat::from_fn(r, c, |_, _| rng.gauss())
}

/// Bitwise equality, except both-NaN positions (payload not pinned).
fn assert_bits(got: &[f64], want: &[f64], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
        if g.is_nan() && w.is_nan() {
            continue;
        }
        assert!(
            g.to_bits() == w.to_bits(),
            "{what}: index {i} not bitwise equal (got {g:?}, want {w:?})"
        );
    }
}

/// The non-scalar ISAs this host can run (empty on plain x86-64 without
/// AVX2 — then the suite degenerates to scalar-vs-scalar, which is fine:
/// the CI isa-matrix job supplies hardware where it does not).
fn simd_isas() -> Vec<Isa> {
    Isa::supported().into_iter().filter(|&i| i != Isa::Scalar).collect()
}

// ---------------------------------------------------------------------------
// Level 1: primitive table entries, called directly.
// ---------------------------------------------------------------------------

#[test]
fn kernel_conformance_dot_all_isas_bitwise() {
    let scalar = kernels(Isa::Scalar);
    let mut rng = Rng::new(101);
    for isa in Isa::supported() {
        let k = kernels(isa);
        // lengths cover k=0, k=1, sub-stride tails (1..3), exact stride-4
        // multiples, and both sides of the unroll boundary
        for len in [0usize, 1, 2, 3, 4, 5, 7, 8, 11, 12, 64, 101, 256, 257] {
            let a: Vec<f64> = (0..len).map(|_| rng.gauss()).collect();
            let b: Vec<f64> = (0..len).map(|_| rng.gauss()).collect();
            let got = (k.dot)(&a, &b);
            let want = (scalar.dot)(&a, &b);
            assert_bits(&[got], &[want], &format!("dot[{isa}] len={len}"));
        }
        // NaN/∞ propagation
        let a = vec![1.0, f64::NAN, 3.0, f64::INFINITY, 5.0, -6.0, 7.0, 8.0, 9.0];
        let b = vec![1.0; 9];
        assert!((k.dot)(&a, &b).is_nan(), "dot[{isa}] NaN lost");
        let c = vec![1.0, 2.0, 3.0, f64::INFINITY, 5.0, -6.0, 7.0, 8.0, 9.0];
        assert_bits(&[(k.dot)(&c, &b)], &[(scalar.dot)(&c, &b)], &format!("dot[{isa}] inf"));
    }
}

#[test]
fn kernel_conformance_axpy_axpy_sub_all_isas_bitwise() {
    let scalar = kernels(Isa::Scalar);
    let mut rng = Rng::new(102);
    for isa in Isa::supported() {
        let k = kernels(isa);
        for len in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 16, 33, 101] {
            for &a in &[0.7, -1.3, 0.0, f64::INFINITY, f64::NAN] {
                let x: Vec<f64> = (0..len).map(|_| rng.gauss()).collect();
                let acc0: Vec<f64> = (0..len).map(|_| rng.gauss()).collect();
                let mut got = acc0.clone();
                let mut want = acc0.clone();
                (k.axpy)(&mut got, a, &x);
                (scalar.axpy)(&mut want, a, &x);
                assert_bits(&got, &want, &format!("axpy[{isa}] len={len} a={a}"));
                let mut got = acc0.clone();
                let mut want = acc0;
                (k.axpy_sub)(&mut got, a, &x);
                (scalar.axpy_sub)(&mut want, a, &x);
                assert_bits(&got, &want, &format!("axpy_sub[{isa}] len={len} a={a}"));
            }
        }
        // NaN in the vector operand propagates identically
        let x = vec![1.0, f64::NAN, 3.0, 4.0, 5.0];
        let mut got = vec![1.0; 5];
        let mut want = vec![1.0; 5];
        (k.axpy)(&mut got, 2.0, &x);
        (scalar.axpy)(&mut want, 2.0, &x);
        assert_bits(&got, &want, &format!("axpy[{isa}] NaN operand"));
        assert!(got[1].is_nan());
    }
}

/// The per-element reference sequence the micro-kernel contract promises:
/// `acc += a·b` per `k` ascending (two roundings), then `c += alpha·acc`
/// at writeback — computed with plain scalar ops so any kernel that
/// deviates in a single rounding or ordering fails bitwise.
#[allow(clippy::too_many_arguments)]
fn micro_reference(
    c: &mut Mat,
    a_sl: &[f64],
    b_sl: &[f64],
    tile_mr: usize,
    tile_nr: usize,
    ci: usize,
    cj: usize,
    mr: usize,
    nr: usize,
    kc: usize,
    alpha: f64,
) {
    for r in 0..mr {
        for s in 0..nr {
            let mut acc = 0.0f64;
            for k in 0..kc {
                acc += a_sl[k * tile_mr + r] * b_sl[k * tile_nr + s];
            }
            c[(ci + r, cj + s)] += alpha * acc;
        }
    }
}

#[test]
fn kernel_conformance_micro_kernel_all_tiles_edges_and_remainders() {
    let mut rng = Rng::new(103);
    for isa in Isa::supported() {
        let k = kernels(isa);
        let (tile_mr, tile_nr) = (k.gemm_mr, k.gemm_nr);
        // every live sub-tile (remainder lanes) × k edges incl. 0 and 1
        for kc in [0usize, 1, 2, 7, 64] {
            for mr in 1..=tile_mr {
                for nr in 1..=tile_nr {
                    let a_sl: Vec<f64> = (0..kc * tile_mr)
                        .map(|t| if t % tile_mr < mr { rng.gauss() } else { 0.0 })
                        .collect();
                    let b_sl: Vec<f64> = (0..kc * tile_nr)
                        .map(|t| if t % tile_nr < nr { rng.gauss() } else { 0.0 })
                        .collect();
                    let c0 = random_mat(&mut rng, tile_mr + 2, tile_nr + 3);
                    let (ci, cj) = (1, 2);
                    let mut got = c0.clone();
                    (k.micro)(&mut got, &a_sl, &b_sl, ci, cj, mr, nr, kc, 1.5);
                    let mut want = c0;
                    micro_reference(
                        &mut want, &a_sl, &b_sl, tile_mr, tile_nr, ci, cj, mr, nr, kc, 1.5,
                    );
                    assert_bits(
                        got.as_slice(),
                        want.as_slice(),
                        &format!("micro[{isa}] mr={mr} nr={nr} kc={kc}"),
                    );
                }
            }
        }
        // NaN/∞ in the packed operands propagate identically per element
        let kc = 5;
        let mut a_sl: Vec<f64> = (0..kc * tile_mr).map(|_| rng.gauss()).collect();
        let mut b_sl: Vec<f64> = (0..kc * tile_nr).map(|_| rng.gauss()).collect();
        a_sl[tile_mr] = f64::NAN; // row 0, k=1
        b_sl[2 * tile_nr + 1] = f64::INFINITY; // col 1, k=2
        let c0 = random_mat(&mut rng, tile_mr, tile_nr);
        let mut got = c0.clone();
        (k.micro)(&mut got, &a_sl, &b_sl, 0, 0, tile_mr, tile_nr, kc, 1.0);
        let mut want = c0;
        micro_reference(
            &mut want, &a_sl, &b_sl, tile_mr, tile_nr, 0, 0, tile_mr, tile_nr, kc, 1.0,
        );
        assert_bits(got.as_slice(), want.as_slice(), &format!("micro[{isa}] nan/inf"));
        assert!(got[(0, 0)].is_nan(), "micro[{isa}]: NaN row lost");
    }
}

#[test]
fn kernel_conformance_pack_bytes_identical_across_isas() {
    // The packers are pure data movement, so their contract is stronger
    // than the arithmetic kernels': the packed buffer must be *byte*
    // identical across ISAs — including NaN payloads, which moves preserve.
    let strict_bytes = |got: &[f64], want: &[f64], what: &str| {
        assert_eq!(got.len(), want.len(), "{what}: length");
        for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
            assert!(
                g.to_bits() == w.to_bits(),
                "{what}: byte {i} differs (got {g:?}, want {w:?})"
            );
        }
    };
    let scalar = kernels(Isa::Scalar);
    let mut rng = Rng::new(108);
    for isa in Isa::supported() {
        let k = kernels(isa);
        // shapes cover: full slivers only, partial tail slivers, k chunks
        // with and without vector-width tails, kc = 0/1, single row/col
        for &(rows, cols) in &[
            (1usize, 1usize),
            (6, 8),
            (6, 7),
            (5, 8),
            (13, 17),
            (12, 16),
            (24, 9),
            (7, 33),
            (19, 64),
            (31, 31),
        ] {
            let mut a = random_mat(&mut rng, rows, cols);
            if rows > 2 && cols > 3 {
                a[(1, 2)] = f64::NAN;
                a[(2, 3)] = f64::NEG_INFINITY;
                a[(0, 0)] = -0.0;
            }
            // sub-block offsets exercise i0/k0 != 0 paths
            for &(i0, mc, k0, kc) in &[
                (0usize, rows, 0usize, cols),
                (0, rows, cols / 2, cols - cols / 2),
                (rows / 3, rows - rows / 3, 0, cols),
            ] {
                // native geometry plus foreign probes (delegation path)
                for mr in [k.gemm_mr, 4, 5] {
                    let len = mc.next_multiple_of(mr) * kc;
                    let mut got = vec![7.5f64; len];
                    let mut want = vec![7.5f64; len];
                    (k.pack_a)(&a, i0, mc, k0, kc, mr, &mut got);
                    (scalar.pack_a)(&a, i0, mc, k0, kc, mr, &mut want);
                    strict_bytes(
                        &got,
                        &want,
                        &format!("pack_a[{isa}] ({rows},{cols}) i0={i0} mc={mc} k0={k0} kc={kc} mr={mr}"),
                    );
                }
                for nr in [k.gemm_nr, 4, 7] {
                    let len = mc * cols.next_multiple_of(nr);
                    let mut got = vec![7.5f64; len];
                    let mut want = vec![7.5f64; len];
                    (k.pack_b)(&a, i0, mc, nr, &mut got);
                    (scalar.pack_b)(&a, i0, mc, nr, &mut want);
                    strict_bytes(
                        &got,
                        &want,
                        &format!("pack_b[{isa}] ({rows},{cols}) k0={i0} kc={mc} nr={nr}"),
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Level 2: blocked entry points across shapes.
// ---------------------------------------------------------------------------

#[test]
fn kernel_conformance_gemm_bitwise_across_isas() {
    let mut rng = Rng::new(104);
    // full tiles, remainder lanes in both M and N, k = 0/1, and shapes
    // straddling the MC=128 / KC=256 cache-block boundaries
    let shapes: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (4, 7, 8),
        (6, 1, 8),
        (12, 64, 16),
        (3, 0, 5),
        (17, 33, 9),
        (24, 256, 32),
        (65, 129, 31),
        (130, 7, 257),
        (64, 513, 24),
        (131, 300, 41),
    ];
    for &(m, k, n) in shapes {
        let a = random_mat(&mut rng, m, k);
        let b = random_mat(&mut rng, k, n);
        let want = matmul_isa(&a, &b, Isa::Scalar);
        for isa in simd_isas() {
            let got = matmul_isa(&a, &b, isa);
            assert_bits(got.as_slice(), want.as_slice(), &format!("matmul[{isa}] ({m},{k},{n})"));
            // accumulate form with alpha/beta
            let c0 = random_mat(&mut rng, m, n);
            let mut got = c0.clone();
            gemm_acc_isa(&mut got, &a, &b, 2.5, 0.5, isa);
            let mut want_acc = c0;
            gemm_acc_isa(&mut want_acc, &a, &b, 2.5, 0.5, Isa::Scalar);
            assert_bits(
                got.as_slice(),
                want_acc.as_slice(),
                &format!("gemm_acc[{isa}] ({m},{k},{n})"),
            );
        }
    }
    // NaN/∞ inputs: propagation identical across ISAs
    let mut a = random_mat(&mut rng, 19, 70);
    let b = random_mat(&mut rng, 70, 13);
    a[(3, 5)] = f64::NAN;
    a[(7, 69)] = f64::INFINITY;
    a[(12, 0)] = f64::NEG_INFINITY;
    let want = matmul_isa(&a, &b, Isa::Scalar);
    for isa in simd_isas() {
        let got = matmul_isa(&a, &b, isa);
        assert_bits(got.as_slice(), want.as_slice(), &format!("matmul[{isa}] nan/inf"));
        assert!(got[(3, 0)].is_nan(), "matmul[{isa}]: NaN row lost");
    }
}

#[test]
fn kernel_conformance_syrk_bitwise_across_isas() {
    let mut rng = Rng::new(105);
    for &(n, p) in &[(1usize, 1usize), (10, 4), (5, 17), (33, 33), (64, 20), (30, 130), (64, 257)] {
        let mut a = random_mat(&mut rng, n, p);
        // sprinkle exact zeros so the aij == 0 skip path is exercised under
        // every ISA (the skip precedes the axpy, so it cannot change bits —
        // this pins that)
        for i in 0..n {
            for j in 0..p {
                if (i + j) % 5 == 0 {
                    a[(i, j)] = 0.0;
                }
            }
        }
        let want = syrk_t_isa(&a, Isa::Scalar);
        for isa in simd_isas() {
            let got = syrk_t_isa(&a, isa);
            assert_bits(got.as_slice(), want.as_slice(), &format!("syrk_t[{isa}] ({n},{p})"));
        }
    }
}

// ---------------------------------------------------------------------------
// Level 3: dispatched consumers under the process-wide override.
// ---------------------------------------------------------------------------

#[test]
fn kernel_conformance_solves_and_row_kernels_under_forced_dispatch() {
    let mut rng = Rng::new(106);
    let n = 23;
    let base = random_mat(&mut rng, n + 4, n);
    let spd = {
        let mut g = fastcv::linalg::syrk_t(&base);
        for i in 0..n {
            g[(i, i)] += 0.5;
        }
        g
    };
    let b = random_mat(&mut rng, n, 5);
    let u: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
    let v: Vec<f64> = (0..7).map(|_| rng.gauss()).collect();
    let m0 = random_mat(&mut rng, n, 7);
    let x_t: Vec<f64> = (0..n).map(|i| if i % 4 == 0 { 0.0 } else { rng.gauss() }).collect();

    // reference run under forced scalar
    let (l_ref, solve_ref, lmat_ref, ltmat_ref, ger_ref, mvt_ref) = {
        let _g = force_scope(Isa::Scalar).unwrap();
        let ch = fastcv::linalg::Cholesky::factor(&spd).unwrap();
        let mut gm = m0.clone();
        ger(&mut gm, 1.7, &u, &v);
        (
            ch.l().clone(),
            ch.solve_mat(&b),
            ch.solve_l_mat(&b),
            ch.solve_lt_mat(&b),
            gm,
            matvec_t(&base, &x_t),
        )
    };
    for isa in simd_isas() {
        let _g = force_scope(isa).unwrap();
        assert_eq!(dispatch::active(), isa);
        let ch = fastcv::linalg::Cholesky::factor(&spd).unwrap();
        assert_bits(ch.l().as_slice(), l_ref.as_slice(), &format!("chol factor[{isa}]"));
        assert_bits(ch.solve_mat(&b).as_slice(), solve_ref.as_slice(), &format!("solve_mat[{isa}]"));
        assert_bits(ch.solve_l_mat(&b).as_slice(), lmat_ref.as_slice(), &format!("solve_l_mat[{isa}]"));
        assert_bits(
            ch.solve_lt_mat(&b).as_slice(),
            ltmat_ref.as_slice(),
            &format!("solve_lt_mat[{isa}]"),
        );
        let mut gm = m0.clone();
        ger(&mut gm, 1.7, &u, &v);
        assert_bits(gm.as_slice(), ger_ref.as_slice(), &format!("ger[{isa}]"));
        assert_bits(&matvec_t(&base, &x_t), &mvt_ref, &format!("matvec_t[{isa}]"));
    }
}

#[test]
fn kernel_conformance_spilled_solve_under_forced_dispatch() {
    // The spill layer's streamed backward solve shares the axpy_sub table
    // entry — force each ISA and compare the whole out-of-core solve.
    let mut rng = Rng::new(107);
    let n = 20;
    let base = random_mat(&mut rng, n + 4, n);
    let mut g = fastcv::linalg::syrk_t(&base);
    for i in 0..n {
        g[(i, i)] += 0.75;
    }
    let b = random_mat(&mut rng, n, 3);
    let solve_under = |isa: Isa| {
        let _guard = force_scope(isa).unwrap();
        let mut store = fastcv::linalg::PanelStore::new(n, 7, None).unwrap();
        store.write_mat(&g).unwrap();
        let ch = fastcv::linalg::chol_spill(store, None).unwrap();
        ch.solve_mat(&b).unwrap()
    };
    let want = solve_under(Isa::Scalar);
    for isa in simd_isas() {
        let got = solve_under(isa);
        assert_bits(got.as_slice(), want.as_slice(), &format!("spilled solve[{isa}]"));
    }
}

#[test]
fn kernel_conformance_forced_isa_is_what_runs() {
    // force_scope must actually steer dispatch (not just set a flag), and
    // auto-detection must pick the widest supported ISA when cleared.
    for isa in Isa::supported() {
        let _g = force_scope(isa).unwrap();
        assert_eq!(dispatch::active(), isa);
        assert_eq!(dispatch::active_kernels().isa, isa);
    }
}
