//! Offline stub of the `xla` PJRT bindings crate.
//!
//! The real crate links against libxla/PJRT shared objects that are not
//! present in this image, and crates.io is unreachable from the build
//! environment. This stub keeps `fastcv::runtime` compiling with the same
//! API surface; every entry point reports "unavailable", so
//! `PjRtClient::cpu()` fails cleanly and all callers take their native-Rust
//! fallback paths (the runtime tests skip themselves in that case).
//!
//! If a real PJRT toolchain becomes available, delete this directory and
//! point the `xla` dependency in `rust/Cargo.toml` at the real crate — no
//! call-site changes are needed.

#![allow(dead_code)]

use std::fmt;

/// Error type mirroring the real crate's (implements `std::error::Error`,
/// so `?` into `anyhow::Result` works at the call sites).
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// `Result` alias matching the real crate.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: XLA/PJRT runtime not available in this build (vendored stub)"
    )))
}

/// PJRT client handle. `cpu()` always fails in the stub.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Create a CPU PJRT client — always unavailable in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    /// Platform string for diagnostics.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation into an executable.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// A compiled executable. Never constructible through the stub.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with host literals; returns per-device, per-output buffers.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// A device buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Copy back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// A host-side literal value.
pub struct Literal {
    _private: (),
}

impl Literal {
    /// 1-D literal from a slice.
    pub fn vec1(_values: &[f64]) -> Literal {
        Literal { _private: () }
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    /// Array shape of this literal.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        unavailable("Literal::array_shape")
    }

    /// Copy out as a typed host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    /// Unpack a tuple literal into its elements.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

impl From<f64> for Literal {
    fn from(_x: f64) -> Literal {
        Literal { _private: () }
    }
}

/// Shape of an array literal.
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    /// Dimension sizes.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// An HLO module in proto form.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an HLO text file — always unavailable in the stub.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// A computation wrapping an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap a proto.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("not available"));
    }

    #[test]
    fn literal_constructors_exist() {
        let l = Literal::vec1(&[1.0, 2.0]);
        assert!(l.reshape(&[2, 1]).is_err());
        let _scalar: Literal = 3.5.into();
    }
}
