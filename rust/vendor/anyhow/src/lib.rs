//! Vendored offline drop-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this shim provides
//! exactly the surface `fastcv` uses: [`Error`], [`Result`], the [`Context`]
//! extension trait, and the `anyhow!` / `bail!` / `ensure!` macros.
//! Semantics follow the real crate where they matter:
//!
//! - `{e}` displays the outermost message, `{e:#}` the full context chain
//!   joined with `": "`, and `{e:?}` the message plus a `Caused by:` list;
//! - any `E: std::error::Error + Send + Sync + 'static` converts into
//!   [`Error`] via `?` (its `source()` chain is flattened into the context
//!   chain);
//! - `.context(..)` / `.with_context(..)` work on both `Result` and
//!   `Option`.

use std::fmt;

/// A dynamic error: an ordered chain of messages, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Messages from outermost context to root cause (mirrors
    /// `anyhow::Error::chain`).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// `Error` deliberately does not implement `std::error::Error`, exactly like
// the real crate — that is what keeps this blanket `From` coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to `Result`s and `Option`s.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;

    /// Wrap with a lazily evaluated context message.
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (all arguments forward to
/// `format!`, so inline captures like `"{x}"` work).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(format!($($arg)+))
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

/// Early-return with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "ghost file")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = Result::<(), _>::Err(io_err()).context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: ghost file");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.root_cause(), "missing value");
        assert_eq!(Some(5u32).context("unused").unwrap(), 5);
    }

    #[test]
    fn macros_work() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", f(7).unwrap_err()), "unlucky");
        let e = anyhow!("code {}", 42);
        assert_eq!(format!("{e}"), "code 42");
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: Result<u32, std::io::Error> = Ok(1);
        let mut called = false;
        let v = ok
            .with_context(|| {
                called = true;
                "ctx".to_string()
            })
            .unwrap();
        assert_eq!(v, 1);
        assert!(!called, "with_context must not evaluate on Ok");
    }

    #[test]
    fn chain_flattens_sources() {
        let inner = std::io::Error::new(std::io::ErrorKind::Other, "root");
        let e: Error = Error::from(inner).context("mid").context("outer");
        let msgs: Vec<&str> = e.chain().collect();
        assert_eq!(msgs, vec!["outer", "mid", "root"]);
    }
}
