//! Simulated multi-subject EEG/MEG ERP dataset (§2.13 substitution).
//!
//! The paper's Fig. 4 uses the Wakeman & Henson (2015) dataset: 16 subjects,
//! 380 EEG/MEG channels, ~787 trials of faces vs scrambled faces, epochs
//! −0.5..1 s at 200 Hz. That data is not available here, so this module
//! simulates epochs with the same **shapes and statistical structure** —
//! which is all the timing experiment consumes (see DESIGN.md
//! §Substitutions):
//!
//! * per-subject trial counts ~787 ± jitter,
//! * 380 channels with a spatially correlated noise covariance,
//! * 1/f-ish temporal noise + a class-dependent N170-like evoked component
//!   (faces > scrambled, famous/unfamiliar/scrambled for the 3-class split),
//! * epochs −0.5..1 s at 200 Hz (301 samples), baseline-corrected.
//!
//! Feature extraction mirrors §2.13: per-timepoint channel vectors
//! (380 features) or concatenated window-averaged amplitudes
//! (10×380 = 3800 binary / 5×380 = 1900 multi-class features).

use super::Dataset;
use crate::linalg::Mat;
use crate::util::rng::Rng;

/// Sampling rate (Hz) after the paper's downsampling.
pub const FS: usize = 200;
/// Epoch start (s) relative to stimulus onset.
pub const T0: f64 = -0.5;
/// Epoch end (s).
pub const T1: f64 = 1.0;
/// Samples per epoch: 301 (−0.5..1 s at 200 Hz, inclusive).
pub const N_T: usize = 301;

/// One simulated subject: epochs × channels × time.
pub struct SubjectEpochs {
    /// Epoch tensor flattened as `trial → Mat(channels × time)`.
    pub epochs: Vec<Mat>,
    /// Binary labels: 0 = face (paper's class "+1"), 1 = scrambled.
    pub labels2: Vec<usize>,
    /// Three-class labels: 0 = famous face, 1 = unfamiliar face, 2 = scrambled.
    pub labels3: Vec<usize>,
    /// Channel count.
    pub n_channels: usize,
}

/// Simulation parameters.
#[derive(Clone, Debug)]
pub struct EegSpec {
    /// Channels (the real dataset has 380 across EEG+MEG).
    pub n_channels: usize,
    /// Mean trials per subject (real average: 787).
    pub mean_trials: usize,
    /// Trial-count jitter (uniform ±).
    pub trial_jitter: usize,
    /// Evoked-response SNR scale.
    pub snr: f64,
}

impl Default for EegSpec {
    fn default() -> Self {
        EegSpec { n_channels: 380, mean_trials: 787, trial_jitter: 60, snr: 1.0 }
    }
}

/// Smaller spec for tests/quick runs.
impl EegSpec {
    pub fn small() -> EegSpec {
        EegSpec { n_channels: 32, mean_trials: 80, trial_jitter: 10, snr: 1.5 }
    }
}

/// Gaussian bump `exp(−(t−μ)²/2σ²)` evaluated at sample `it`.
fn bump(it: usize, mu_s: f64, sigma_s: f64) -> f64 {
    let t = T0 + it as f64 / FS as f64;
    (-(t - mu_s) * (t - mu_s) / (2.0 * sigma_s * sigma_s)).exp()
}

/// Simulate one subject. Deterministic per (spec, rng state).
pub fn simulate_subject(spec: &EegSpec, rng: &mut Rng) -> SubjectEpochs {
    let nc = spec.n_channels;
    let jit = rng.below(2 * spec.trial_jitter + 1);
    let n_trials = spec.mean_trials - spec.trial_jitter + jit;

    // Spatial mixing for correlated sensor noise: A z, A = random nc×r.
    let r = (nc / 4).max(2);
    let mixing = Mat::from_fn(nc, r, |_, _| rng.gauss() / (r as f64).sqrt());

    // Class topographies: N170-ish component peaking ~170 ms (faces),
    // a weaker response for scrambled, plus a famous/unfamiliar difference
    // around 250 ms (the real dataset's "famous" modulation).
    let topo_face = rng.unit_vector(nc);
    let topo_scram = rng.unit_vector(nc);
    let topo_famous = rng.unit_vector(nc);

    let mut epochs = Vec::with_capacity(n_trials);
    let mut labels2 = Vec::with_capacity(n_trials);
    let mut labels3 = Vec::with_capacity(n_trials);
    let mut noise_col = vec![0.0; r];
    for _ in 0..n_trials {
        // Trial type: 1/3 famous, 1/3 unfamiliar, 1/3 scrambled.
        let l3 = rng.below(3);
        let l2 = usize::from(l3 == 2);
        let mut ep = Mat::zeros(nc, N_T);
        // temporally smoothed noise (AR(1), ~1/f-ish)
        let mut prev = vec![0.0; nc];
        for it in 0..N_T {
            rng.fill_gauss(&mut noise_col);
            let fresh = crate::linalg::matvec(&mixing, &noise_col);
            let n170 = bump(it, 0.17, 0.03);
            let p250 = bump(it, 0.25, 0.05);
            let amp_face = if l2 == 0 { 1.0 } else { 0.35 };
            let amp_fam = if l3 == 0 { 0.6 } else { 0.0 };
            for ch in 0..nc {
                let ar = 0.85 * prev[ch] + fresh[ch];
                prev[ch] = ar;
                let evoked = spec.snr
                    * (amp_face * n170 * topo_face[ch]
                        + 0.4 * p250 * topo_scram[ch]
                        + amp_fam * p250 * topo_famous[ch]);
                ep[(ch, it)] = ar + evoked;
            }
        }
        // Baseline correction: subtract the pre-stimulus channel mean.
        let n_base = (-T0 * FS as f64) as usize; // samples before onset
        for ch in 0..nc {
            let base: f64 =
                // lint:allow(float_accum, reason = "serial per-channel baseline mean in the simulator; single canonical order, never backend-fanned")
                (0..n_base).map(|it| ep[(ch, it)]).sum::<f64>() / n_base as f64;
            for it in 0..N_T {
                // lint:allow(float_accum, reason = "serial baseline subtraction in the simulator; each sample touched once")
                ep[(ch, it)] -= base;
            }
        }
        epochs.push(ep);
        labels2.push(l2);
        labels3.push(l3);
    }
    SubjectEpochs { epochs, labels2, labels3, n_channels: nc }
}

impl SubjectEpochs {
    /// Number of trials.
    pub fn n_trials(&self) -> usize {
        self.epochs.len()
    }

    /// §2.13 analysis 1: features = channel amplitudes at one time point.
    pub fn features_at_timepoint(&self, it: usize, binary: bool) -> Dataset {
        assert!(it < N_T);
        let n = self.n_trials();
        let mut x = Mat::zeros(n, self.n_channels);
        for (tr, ep) in self.epochs.iter().enumerate() {
            for ch in 0..self.n_channels {
                x[(tr, ch)] = ep[(ch, it)];
            }
        }
        self.wrap(x, binary)
    }

    /// §2.13 analysis 2: post-stimulus interval divided into successive
    /// non-overlapping windows of `win_ms` milliseconds; per-window channel
    /// averages concatenated into one feature vector.
    pub fn features_windowed(&self, win_ms: usize, binary: bool) -> Dataset {
        let onset = (-T0 * FS as f64) as usize;
        let win = win_ms * FS / 1000;
        assert!(win > 0);
        let n_win = (N_T - onset) / win;
        let n = self.n_trials();
        let p = n_win * self.n_channels;
        let mut x = Mat::zeros(n, p);
        for (tr, ep) in self.epochs.iter().enumerate() {
            for w in 0..n_win {
                let lo = onset + w * win;
                let hi = lo + win;
                for ch in 0..self.n_channels {
                    let mean: f64 =
                        // lint:allow(float_accum, reason = "serial window mean in the simulator; single canonical order, never backend-fanned")
                        (lo..hi).map(|it| ep[(ch, it)]).sum::<f64>() / win as f64;
                    x[(tr, w * self.n_channels + ch)] = mean;
                }
            }
        }
        self.wrap(x, binary)
    }

    fn wrap(&self, x: Mat, binary: bool) -> Dataset {
        if binary {
            Dataset { x, labels: self.labels2.clone(), n_classes: 2 }
        } else {
            Dataset { x, labels: self.labels3.clone(), n_classes: 3 }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_paper_protocol() {
        let mut rng = Rng::new(1);
        let spec = EegSpec { n_channels: 20, mean_trials: 30, trial_jitter: 5, snr: 1.0 };
        let subj = simulate_subject(&spec, &mut rng);
        assert!((25..=35).contains(&subj.n_trials()));
        let ds_t = subj.features_at_timepoint(150, true);
        assert_eq!(ds_t.p(), 20);
        // 100 ms windows over 1 s post-stimulus → 10 windows
        let ds_w = subj.features_windowed(100, true);
        assert_eq!(ds_w.p(), 10 * 20);
        // 200 ms windows → 5 windows (paper's multi-class variant)
        let ds_w3 = subj.features_windowed(200, false);
        assert_eq!(ds_w3.p(), 5 * 20);
        assert_eq!(ds_w3.n_classes, 3);
    }

    #[test]
    fn labels_consistent_between_binary_and_ternary() {
        let mut rng = Rng::new(2);
        let spec = EegSpec::small();
        let subj = simulate_subject(&spec, &mut rng);
        for (l2, l3) in subj.labels2.iter().zip(&subj.labels3) {
            assert_eq!(*l2, usize::from(*l3 == 2));
        }
        // all three classes present
        for c in 0..3 {
            assert!(subj.labels3.iter().any(|&l| l == c), "class {c} missing");
        }
    }

    #[test]
    fn baseline_corrected() {
        let mut rng = Rng::new(3);
        let spec = EegSpec { n_channels: 8, mean_trials: 10, trial_jitter: 0, snr: 1.0 };
        let subj = simulate_subject(&spec, &mut rng);
        let n_base = 100;
        for ep in &subj.epochs {
            for ch in 0..8 {
                let base: f64 = (0..n_base).map(|it| ep[(ch, it)]).sum::<f64>() / n_base as f64;
                assert!(base.abs() < 1e-10, "baseline not removed: {base}");
            }
        }
    }

    #[test]
    fn evoked_signal_is_decodable_at_peak() {
        let mut rng = Rng::new(4);
        let spec = EegSpec { n_channels: 24, mean_trials: 120, trial_jitter: 0, snr: 2.5 };
        let subj = simulate_subject(&spec, &mut rng);
        // t = 170 ms → sample index (0.17 − (−0.5)) * 200 = 134
        let ds = subj.features_at_timepoint(134, true);
        let folds = crate::cv::folds::stratified_kfold(&ds.labels, 5, &mut rng);
        let acc = crate::cv::runner::standard_binary_cv_accuracy(
            &ds.x,
            &ds.labels,
            &folds,
            crate::model::Reg::Ridge(1.0),
        )
        .unwrap();
        assert!(acc > 0.65, "N170 should be decodable, acc={acc}");
        // pre-stimulus should be ~chance
        let ds0 = subj.features_at_timepoint(20, true);
        let acc0 = crate::cv::runner::standard_binary_cv_accuracy(
            &ds0.x,
            &ds0.labels,
            &folds,
            crate::model::Reg::Ridge(1.0),
        )
        .unwrap();
        assert!(acc0 < 0.65, "pre-stimulus decodable?! acc={acc0}");
    }
}
