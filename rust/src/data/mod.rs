//! Workload generators reproducing the paper's evaluation data.
//!
//! - [`synthetic`] — hypersphere-centroid Gaussian classes with a common
//!   Wishart covariance (§2.12, Fig. 3)
//! - [`eeg`] — simulated multi-subject ERP (EEG/MEG) epochs standing in for
//!   the Wakeman–Henson dataset (§2.13, Fig. 4); see DESIGN.md
//!   §Substitutions
//! - [`genes`] — a gene-expression-like extreme `P ≫ N` generator (§1)

pub mod eeg;
pub mod genes;
pub mod synthetic;

use crate::linalg::Mat;

/// A labelled dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Feature matrix, `N × P`.
    pub x: Mat,
    /// Class labels in `0..n_classes`.
    pub labels: Vec<usize>,
    /// Number of classes.
    pub n_classes: usize,
}

impl Dataset {
    /// Number of samples.
    pub fn n(&self) -> usize {
        self.x.rows()
    }

    /// Number of features.
    pub fn p(&self) -> usize {
        self.x.cols()
    }

    /// Signed ±1 codes (binary datasets only).
    pub fn y_signed(&self) -> Vec<f64> {
        assert_eq!(self.n_classes, 2, "signed codes are for binary problems");
        crate::model::lda_binary::signed_codes(&self.labels)
    }
}
