//! Gene-expression-like extreme `P ≫ N` generator (§1's motivating case:
//! "tens of thousands of genes (features) but not more than a few hundred
//! patients").
//!
//! Expression levels are log-normal-ish with a sparse set of differentially
//! expressed genes between patient groups and block-correlated co-expression
//! modules — the structure that makes regularised LDA the method of choice
//! there.

use super::Dataset;
use crate::linalg::Mat;
use crate::util::rng::Rng;

/// Specification of a simulated expression study.
#[derive(Clone, Debug)]
pub struct GeneSpec {
    /// Patients (samples).
    pub n: usize,
    /// Genes (features), typically ≫ n.
    pub p: usize,
    /// Number of patient groups (classes).
    pub n_classes: usize,
    /// Fraction of genes differentially expressed per class.
    pub de_fraction: f64,
    /// Effect size of differential expression (in SD units).
    pub effect: f64,
    /// Co-expression module size (block-correlation width).
    pub module_size: usize,
}

impl Default for GeneSpec {
    fn default() -> Self {
        GeneSpec { n: 120, p: 5000, n_classes: 2, de_fraction: 0.02, effect: 1.0, module_size: 50 }
    }
}

/// Generate an expression dataset.
pub fn generate(spec: &GeneSpec, rng: &mut Rng) -> Dataset {
    let c = spec.n_classes;
    assert!(c >= 2 && spec.n >= 2 * c);
    let n_de = ((spec.p as f64 * spec.de_fraction) as usize).max(1);
    // Per-class differentially-expressed gene sets and signs.
    let de_sets: Vec<Vec<(usize, f64)>> = (0..c)
        .map(|_| {
            rng.choose(spec.p, n_de)
                .into_iter()
                .map(|g| (g, if rng.below(2) == 0 { spec.effect } else { -spec.effect }))
                .collect()
        })
        .collect();
    let mut x = Mat::zeros(spec.n, spec.p);
    let mut labels = vec![0usize; spec.n];
    let module = spec.module_size.max(1);
    let mut shared = vec![0.0; spec.p / module + 1];
    for i in 0..spec.n {
        let class = i % c;
        labels[i] = class;
        // module-level shared factors (co-expression blocks)
        for s in shared.iter_mut() {
            *s = rng.gauss();
        }
        let row = x.row_mut(i);
        for (g, v) in row.iter_mut().enumerate() {
            *v = 0.6 * shared[g / module] + 0.8 * rng.gauss();
        }
        for &(g, eff) in &de_sets[class] {
            // lint:allow(float_accum, reason = "serial effect injection in the simulator; each gene cell written once per class")
            row[g] += eff;
        }
    }
    // shuffle rows
    let perm = rng.permutation(spec.n);
    let x = x.take_rows(&perm);
    let labels: Vec<usize> = perm.iter().map(|&i| labels[i]).collect();
    Dataset { x, labels, n_classes: c }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wide_shape() {
        let mut rng = Rng::new(1);
        let ds = generate(&GeneSpec { n: 30, p: 400, ..Default::default() }, &mut rng);
        assert_eq!(ds.n(), 30);
        assert_eq!(ds.p(), 400);
        assert!(ds.p() > ds.n());
    }

    #[test]
    fn signal_is_decodable_with_ridge() {
        let mut rng = Rng::new(2);
        let spec = GeneSpec { n: 60, p: 500, effect: 2.0, de_fraction: 0.05, ..Default::default() };
        let ds = generate(&spec, &mut rng);
        let folds = crate::cv::folds::stratified_kfold(&ds.labels, 5, &mut rng);
        // P ≫ N: only the analytic/ridge route is tractable & non-singular.
        let y = ds.y_signed();
        let cv = crate::fastcv::binary::AnalyticBinaryCv::fit(&ds.x, &y, 10.0).unwrap();
        let dv = cv.decision_values(&folds).unwrap();
        let acc = crate::cv::metrics::accuracy_signed(&dv, &y);
        assert!(acc > 0.75, "acc={acc}");
    }

    #[test]
    fn classes_balanced() {
        let mut rng = Rng::new(3);
        let ds = generate(&GeneSpec { n: 40, p: 100, n_classes: 4, ..Default::default() }, &mut rng);
        let counts = crate::stats::class_counts(&ds.labels, 4);
        assert!(counts.iter().all(|&k| k == 10), "{counts:?}");
    }
}
