//! The paper's simulation generator (§2.12).
//!
//! Each class centroid is placed uniformly on the unit hypersphere; a common
//! covariance is drawn from a Wishart distribution; samples are multivariate
//! normal around their class centroid with that covariance.

use super::Dataset;
use crate::linalg::Mat;
use crate::stats::mvn::Mvn;
use crate::stats::wishart::random_covariance;
use crate::util::rng::Rng;

/// Specification of a synthetic classification problem.
#[derive(Clone, Debug)]
pub struct SyntheticSpec {
    /// Total number of samples (split as evenly as possible across classes).
    pub n: usize,
    /// Number of features.
    pub p: usize,
    /// Number of classes.
    pub n_classes: usize,
    /// Scale applied to the hypersphere radius (class separation).
    pub separation: f64,
    /// Extra Wishart degrees of freedom beyond `p` (conditioning).
    pub wishart_dof_extra: usize,
    /// Diagonal jitter added to the sampled covariance.
    pub jitter: f64,
}

impl SyntheticSpec {
    /// Paper-default binary problem.
    pub fn binary(n: usize, p: usize) -> SyntheticSpec {
        SyntheticSpec { n, p, n_classes: 2, separation: 1.0, wishart_dof_extra: 4, jitter: 0.05 }
    }

    /// Paper-default multi-class problem (5 or 10 classes in Fig. 3c/d).
    pub fn multiclass(n: usize, p: usize, c: usize) -> SyntheticSpec {
        SyntheticSpec { n, p, n_classes: c, separation: 1.0, wishart_dof_extra: 4, jitter: 0.05 }
    }
}

/// Generate a dataset per §2.12. Class sizes are `n/c` with the remainder
/// distributed to the first classes; samples are grouped by class then the
/// row order is shuffled (so unstratified folds are still exchangeable).
pub fn generate(spec: &SyntheticSpec, rng: &mut Rng) -> Dataset {
    let c = spec.n_classes;
    assert!(c >= 2 && spec.n >= 2 * c, "need ≥2 samples per class");
    // Common covariance ~ Wishart (normalised trace) + jitter.
    let cov = random_covariance(spec.p, spec.wishart_dof_extra, spec.jitter, rng);
    // Class centroids on the hypersphere.
    let centroids: Vec<Vec<f64>> = (0..c)
        .map(|_| {
            let mut u = rng.unit_vector(spec.p);
            for v in u.iter_mut() {
                *v *= spec.separation;
            }
            u
        })
        .collect();
    let mut x = Mat::zeros(spec.n, spec.p);
    let mut labels = vec![0usize; spec.n];
    let mut row = 0;
    for (class, centroid) in centroids.iter().enumerate() {
        let size = spec.n / c + usize::from(class < spec.n % c);
        // lint:allow(panic, reason = "covariance is Wishart plus diagonal jitter, SPD by construction, so Mvn::new cannot fail")
        let mvn = Mvn::new(centroid.clone(), &cov).expect("jittered Wishart cov is SPD");
        for _ in 0..size {
            mvn.sample_into(rng, x.row_mut(row));
            labels[row] = class;
            row += 1;
        }
    }
    debug_assert_eq!(row, spec.n);
    // Shuffle rows so contiguous folds are valid.
    let perm = rng.permutation(spec.n);
    let x = x.take_rows(&perm);
    let labels: Vec<usize> = perm.iter().map(|&i| labels[i]).collect();
    Dataset { x, labels, n_classes: c }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::class_counts;

    #[test]
    fn shapes_and_balance() {
        let mut rng = Rng::new(1);
        let ds = generate(&SyntheticSpec::multiclass(103, 7, 5), &mut rng);
        assert_eq!(ds.n(), 103);
        assert_eq!(ds.p(), 7);
        let counts = class_counts(&ds.labels, 5);
        assert_eq!(counts.iter().sum::<usize>(), 103);
        assert!(counts.iter().all(|&k| k == 20 || k == 21), "{counts:?}");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(&SyntheticSpec::binary(40, 6), &mut Rng::new(5));
        let b = generate(&SyntheticSpec::binary(40, 6), &mut Rng::new(5));
        assert_eq!(a.x.as_slice(), b.x.as_slice());
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn classes_are_learnable_with_separation() {
        let mut rng = Rng::new(2);
        let mut spec = SyntheticSpec::binary(120, 10);
        spec.separation = 3.0;
        let ds = generate(&spec, &mut rng);
        let folds = crate::cv::folds::stratified_kfold(&ds.labels, 5, &mut rng);
        let acc = crate::cv::runner::standard_binary_cv_accuracy(
            &ds.x,
            &ds.labels,
            &folds,
            crate::model::Reg::Ridge(0.1),
        )
        .unwrap();
        assert!(acc > 0.8, "acc={acc}");
    }

    #[test]
    fn p_greater_than_n_supported() {
        let mut rng = Rng::new(3);
        let ds = generate(&SyntheticSpec::binary(20, 100), &mut rng);
        assert_eq!(ds.p(), 100);
        assert_eq!(ds.n(), 20);
    }
}
