//! Binary LDA as a least-squares problem (§2.3, Appendix A/B).
//!
//! Regressing arbitrary numeric class codes `z₁ ≠ z₂` on the augmented
//! design yields a weight vector **proportional** to the LDA solution
//! `S_w⁻¹(m₁ − m₂)`; the intercept is `b_LR = N₁z₁/N + N₂z₂/N − m̄ᵀw`
//! (which differs from `b_LDA` unless `N₁ = N₂`). This module is both a
//! usable classifier and the executable proof of Appendix A/B used by the
//! test-suite.

use crate::linalg::{dot, Mat};
use crate::model::linreg::LinReg;
use anyhow::Result;

/// Binary LDA fit through the regression route.
#[derive(Clone, Debug)]
pub struct RegressionLda {
    /// Regression weight vector (∝ LDA `w`).
    pub w: Vec<f64>,
    /// Regression intercept `b_LR`.
    pub b_lr: f64,
    /// LDA-style intercept `b_LDA` (centres projected class means).
    pub b_lda: f64,
}

impl RegressionLda {
    /// Train with class codes `z = (z₁, z₂)` for labels (0, 1); ridge λ ≥ 0.
    pub fn train_with_codes(
        x: &Mat,
        labels: &[usize],
        (z1, z2): (f64, f64),
        lambda: f64,
    ) -> Result<RegressionLda> {
        assert!(z1 != z2, "class codes must differ");
        let y: Vec<f64> = labels.iter().map(|&l| if l == 0 { z1 } else { z2 }).collect();
        let reg = LinReg::fit(x, &y, lambda)?;
        // b_LDA: centre the projected class means (needs class means).
        let means = crate::stats::class_means(x, labels, 2);
        let p1 = dot(&reg.w, means.row(0));
        let p2 = dot(&reg.w, means.row(1));
        Ok(RegressionLda { b_lda: -(p1 + p2) / 2.0, w: reg.w, b_lr: reg.b })
    }

    /// Train with the canonical ±1 coding of the paper.
    pub fn train(x: &Mat, labels: &[usize], lambda: f64) -> Result<RegressionLda> {
        Self::train_with_codes(x, labels, (1.0, -1.0), lambda)
    }

    /// Regression decision values `wᵀx + b_LR` (what the analytical CV
    /// reproduces).
    pub fn decision_values_lr(&self, x: &Mat) -> Vec<f64> {
        (0..x.rows()).map(|i| dot(&self.w, x.row(i)) + self.b_lr).collect()
    }

    /// LDA decision values `wᵀx + b_LDA` (bias-adjusted, §2.5).
    pub fn decision_values_lda(&self, x: &Mat) -> Vec<f64> {
        (0..x.rows()).map(|i| dot(&self.w, x.row(i)) + self.b_lda).collect()
    }

    /// Predict labels with the LDA bias.
    pub fn predict(&self, x: &Mat) -> Vec<usize> {
        self.decision_values_lda(x).iter().map(|&d| usize::from(d < 0.0)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::lda_binary::BinaryLda;
    use crate::model::Reg;
    use crate::util::prop::{assert_close, Cases};
    use crate::util::rng::Rng;

    fn random_problem(rng: &mut Rng, n1: usize, n2: usize, p: usize) -> (Mat, Vec<usize>) {
        let n = n1 + n2;
        let mut x = Mat::from_fn(n, p, |_, _| rng.gauss());
        // shift class 0 along a random direction for separation
        let dir = rng.unit_vector(p);
        for i in 0..n1 {
            for j in 0..p {
                x[(i, j)] += 1.5 * dir[j];
            }
        }
        let labels: Vec<usize> = (0..n).map(|i| usize::from(i >= n1)).collect();
        (x, labels)
    }

    fn cosine(a: &[f64], b: &[f64]) -> f64 {
        dot(a, b) / (dot(a, a).sqrt() * dot(b, b).sqrt())
    }

    #[test]
    fn appendix_a_w_parallel_to_lda() {
        // Regression w ∝ classic LDA w, any class codes, balanced or not.
        Cases::new(25).run("appendix-a", |rng| {
            let n1 = 6 + rng.below(20);
            let n2 = 6 + rng.below(20);
            let p = 1 + rng.below(5.min(n1 + n2 - 3));
            let (x, labels) = random_problem(rng, n1, n2, p);
            let z1 = rng.uniform_in(-3.0, 3.0);
            let mut z2 = rng.uniform_in(-3.0, 3.0);
            if (z1 - z2).abs() < 0.3 {
                z2 = z1 + 1.0;
            }
            let reg = RegressionLda::train_with_codes(&x, &labels, (z1, z2), 0.0).unwrap();
            let lda = BinaryLda::train(&x, &labels, Reg::None).unwrap();
            let cos = cosine(&reg.w, &lda.w);
            // sign follows z1 > z2 or z1 < z2
            let expect = if z1 > z2 { 1.0 } else { -1.0 };
            assert_close(cos, expect, 1e-6, "cosine(w_reg, w_lda)");
        });
    }

    #[test]
    fn appendix_a_intercept_formula() {
        // For ±1 codes: b_LR = (N₁−N₂)/N − m̄ᵀw (Eq. 6).
        Cases::new(25).run("appendix-a-bias", |rng| {
            let n1 = 5 + rng.below(15);
            let n2 = 5 + rng.below(15);
            let p = 1 + rng.below(4);
            let (x, labels) = random_problem(rng, n1, n2, p);
            let reg = RegressionLda::train(&x, &labels, 0.0).unwrap();
            let n = (n1 + n2) as f64;
            let grand = x.col_means();
            let expect = (n1 as f64 - n2 as f64) / n - dot(&grand, &reg.w);
            assert_close(reg.b_lr, expect, 1e-8, "b_LR");
        });
    }

    #[test]
    fn appendix_b_ridge_w_parallel_to_ridged_lda() {
        Cases::new(20).run("appendix-b", |rng| {
            let n1 = 5 + rng.below(10);
            let n2 = 5 + rng.below(10);
            let p = 2 + rng.below(8);
            let (x, labels) = random_problem(rng, n1, n2, p);
            let lambda = 10f64.powf(rng.uniform_in(-2.0, 2.0));
            let reg = RegressionLda::train(&x, &labels, lambda).unwrap();
            let lda = BinaryLda::train(&x, &labels, Reg::Ridge(lambda)).unwrap();
            let cos = cosine(&reg.w, &lda.w);
            assert_close(cos, 1.0, 1e-6, "cosine(w_ridge_reg, w_ridge_lda)");
        });
    }

    #[test]
    fn balanced_classes_biases_coincide() {
        let mut rng = Rng::new(1);
        let (x, labels) = random_problem(&mut rng, 20, 20, 3);
        let reg = RegressionLda::train(&x, &labels, 0.0).unwrap();
        // N₁=N₂ ⇒ b_LR == b_LDA (both equal −m̄ᵀw).
        assert!((reg.b_lr - reg.b_lda).abs() < 1e-9, "{} vs {}", reg.b_lr, reg.b_lda);
    }

    #[test]
    fn unbalanced_classes_biases_differ_but_predictions_match_lda() {
        let mut rng = Rng::new(2);
        let (x, labels) = random_problem(&mut rng, 35, 10, 4);
        let reg = RegressionLda::train(&x, &labels, 1e-9).unwrap();
        let lda = BinaryLda::train(&x, &labels, Reg::Ridge(1e-9)).unwrap();
        assert!((reg.b_lr - reg.b_lda).abs() > 1e-3, "biases differ when unbalanced");
        // With the b_LDA adjustment, predicted labels match classic LDA.
        let pr = reg.predict(&x);
        let pl = lda.predict(&x);
        assert_eq!(pr, pl);
    }
}
