//! Binary Linear Discriminant Analysis — the classic formulation (§2.2).
//!
//! Label convention throughout the crate: `labels[i] ∈ {0, 1}` where label
//! **0 is the paper's "class 1"** (numeric code **+1**) and label **1 is
//! "class 2"** (code **−1**). Decision value `ŷ = wᵀx + b`; predict label 0
//! when `ŷ ≥ 0`.

use super::Reg;
use crate::linalg::{dot, Cholesky, Mat};
use crate::stats::{class_counts, class_means, within_scatter};
use anyhow::{Context, Result};

/// Trained binary LDA classifier.
#[derive(Clone, Debug)]
pub struct BinaryLda {
    /// Weight vector `w = S_w⁻¹ (m₁ − m₂)` (Eq. 3, possibly regularised).
    pub w: Vec<f64>,
    /// Bias `b_LDA` centring the projected class means (Eq. 4).
    pub b: f64,
}

impl BinaryLda {
    /// Train on data `x` (N×P) with labels in {0,1} (0 ↔ class "+1").
    pub fn train(x: &Mat, labels: &[usize], reg: Reg) -> Result<BinaryLda> {
        assert_eq!(x.rows(), labels.len());
        let counts = class_counts(labels, 2);
        assert!(counts[0] > 0 && counts[1] > 0, "both classes must be present");
        let means = class_means(x, labels, 2);
        let mut sw = within_scatter(x, labels, 2);
        reg.apply(&mut sw);
        let p = x.cols();
        let diff: Vec<f64> = (0..p).map(|j| means[(0, j)] - means[(1, j)]).collect();
        // Solve S_w w = (m₁ − m₂); Cholesky when SPD, LU fallback.
        let w = match Cholesky::factor(&sw) {
            Ok(ch) => ch.solve_vec(&diff),
            Err(_) => crate::linalg::solve(&sw, &diff)
                .context("within-class scatter singular; add ridge regularisation")?,
        };
        // b_LDA centres the projected class means: b = −wᵀ(m₁+m₂)/2.
        // (The paper's Eq. 4 prints (m₁−m₂) but describes "the center between
        // the projected class means", which is (m₁+m₂)/2 — we implement the
        // described behaviour; the test `bias_centres_projections` pins it.)
        let proj1 = dot(&w, means.row(0));
        let proj2 = dot(&w, means.row(1));
        let b = -(proj1 + proj2) / 2.0;
        Ok(BinaryLda { w, b })
    }

    /// Decision value `wᵀx + b` for one sample.
    pub fn decision_value(&self, x: &[f64]) -> f64 {
        dot(&self.w, x) + self.b
    }

    /// Decision values for all rows of `x`.
    pub fn decision_values(&self, x: &Mat) -> Vec<f64> {
        (0..x.rows()).map(|i| self.decision_value(x.row(i))).collect()
    }

    /// Predicted labels (0 when dval ≥ 0, else 1).
    pub fn predict(&self, x: &Mat) -> Vec<usize> {
        self.decision_values(x).iter().map(|&d| if d >= 0.0 { 0 } else { 1 }).collect()
    }
}

/// Signed class codes for labels: 0 → +1, 1 → −1 (the paper's y vector).
pub fn signed_codes(labels: &[usize]) -> Vec<f64> {
    labels.iter().map(|&l| if l == 0 { 1.0 } else { -1.0 }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::mvn::Mvn;
    use crate::util::rng::Rng;

    fn gaussian_problem(rng: &mut Rng, n_per: usize, p: usize, sep: f64) -> (Mat, Vec<usize>) {
        let cov = Mat::eye(p);
        let mut mean1 = vec![0.0; p];
        mean1[0] = sep / 2.0;
        let mut mean2 = vec![0.0; p];
        mean2[0] = -sep / 2.0;
        let m1 = Mvn::new(mean1, &cov).unwrap().sample_n(rng, n_per);
        let m2 = Mvn::new(mean2, &cov).unwrap().sample_n(rng, n_per);
        let mut x = Mat::zeros(2 * n_per, p);
        let mut labels = vec![0usize; 2 * n_per];
        for i in 0..n_per {
            x.row_mut(i).copy_from_slice(m1.row(i));
            x.row_mut(n_per + i).copy_from_slice(m2.row(i));
            labels[n_per + i] = 1;
        }
        (x, labels)
    }

    #[test]
    fn separable_problem_high_accuracy() {
        let mut rng = Rng::new(1);
        let (x, labels) = gaussian_problem(&mut rng, 100, 5, 6.0);
        let lda = BinaryLda::train(&x, &labels, Reg::Ridge(1e-6)).unwrap();
        let pred = lda.predict(&x);
        let correct = pred.iter().zip(&labels).filter(|(a, b)| a == b).count();
        assert!(correct as f64 / labels.len() as f64 > 0.98);
    }

    #[test]
    fn w_solves_scatter_system() {
        let mut rng = Rng::new(2);
        let (x, labels) = gaussian_problem(&mut rng, 30, 4, 2.0);
        let lda = BinaryLda::train(&x, &labels, Reg::None).unwrap();
        let sw = within_scatter(&x, &labels, 2);
        let means = class_means(&x, &labels, 2);
        let lhs = crate::linalg::matvec(&sw, &lda.w);
        for j in 0..4 {
            let rhs = means[(0, j)] - means[(1, j)];
            assert!((lhs[j] - rhs).abs() < 1e-8, "S_w w = m1-m2 at {j}");
        }
    }

    #[test]
    fn bias_centres_projections() {
        let mut rng = Rng::new(3);
        // Unbalanced classes: bias must still centre the projected means.
        let (x1, _) = gaussian_problem(&mut rng, 40, 3, 3.0);
        let x = x1;
        let labels: Vec<usize> = (0..80).map(|i| usize::from(i >= 40)).collect();
        let lda = BinaryLda::train(&x, &labels, Reg::Ridge(0.1)).unwrap();
        let means = class_means(&x, &labels, 2);
        let d1 = lda.decision_value(means.row(0));
        let d2 = lda.decision_value(means.row(1));
        assert!((d1 + d2).abs() < 1e-9, "projected means centred: {d1} vs {d2}");
        assert!(d1 > 0.0 && d2 < 0.0, "class means on opposite sides");
    }

    #[test]
    fn shrinkage_and_converted_ridge_give_parallel_w() {
        let mut rng = Rng::new(4);
        let (x, labels) = gaussian_problem(&mut rng, 25, 6, 2.0);
        let sw = within_scatter(&x, &labels, 2);
        let nu = sw.trace() / 6.0;
        let ls = 0.3;
        let lr = Reg::shrinkage_to_ridge(ls, nu);
        let a = BinaryLda::train(&x, &labels, Reg::Shrinkage(ls)).unwrap();
        let b = BinaryLda::train(&x, &labels, Reg::Ridge(lr)).unwrap();
        // w_shrink == w_ridge / (1−λs): proportional ⇒ same direction.
        let na = dot(&a.w, &a.w).sqrt();
        let nb = dot(&b.w, &b.w).sqrt();
        let cos = dot(&a.w, &b.w) / (na * nb);
        assert!((cos - 1.0).abs() < 1e-10, "cos={cos}");
    }

    #[test]
    fn wide_data_needs_ridge() {
        let mut rng = Rng::new(5);
        let (x, labels) = gaussian_problem(&mut rng, 5, 30, 4.0); // N=10 < P=30
        assert!(BinaryLda::train(&x, &labels, Reg::None).is_err());
        assert!(BinaryLda::train(&x, &labels, Reg::Ridge(1.0)).is_ok());
    }

    #[test]
    fn signed_codes_convention() {
        assert_eq!(signed_codes(&[0, 1, 0]), vec![1.0, -1.0, 1.0]);
    }
}
