//! Linear SVM via dual coordinate descent (Hsieh et al. 2008) — the
//! kernel-method comparator of §4.4 and the "sometimes similar, slower to
//! train" baseline of §1.
//!
//! L2-regularised L1-loss SVM: `min_w ½‖w‖² + C Σ max(0, 1 − yᵢ wᵀxᵢ)`,
//! solved in the dual `min_α ½αᵀQα − 1ᵀα, 0 ≤ αᵢ ≤ C`, with
//! `Q_ij = yᵢyⱼ xᵢᵀxⱼ`, sweeping coordinates with random permutations and
//! maintaining `w = Σ αᵢyᵢxᵢ` — exactly the cited Algorithm 1.

use crate::linalg::{dot, Mat};
use crate::util::rng::Rng;

/// Trained linear SVM.
#[derive(Clone, Debug)]
pub struct LinearSvm {
    /// Weight vector (includes the bias through feature augmentation).
    pub w: Vec<f64>,
    /// Bias term.
    pub b: f64,
    /// Dual variables at convergence.
    pub alpha: Vec<f64>,
    /// Outer iterations used.
    pub iters: usize,
}

/// Training hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct SvmParams {
    /// Soft-margin cost.
    pub c: f64,
    /// Maximum outer passes over the data.
    pub max_iter: usize,
    /// Stop when the largest projected-gradient violation falls below this.
    pub tol: f64,
}

impl Default for SvmParams {
    fn default() -> Self {
        SvmParams { c: 1.0, max_iter: 200, tol: 1e-4 }
    }
}

impl LinearSvm {
    /// Train on labels in {0,1} (0 ↔ +1, crate convention). The bias is
    /// handled by augmenting each sample with a constant 1 feature (the
    /// standard liblinear trick).
    pub fn train(x: &Mat, labels: &[usize], params: SvmParams, rng: &mut Rng) -> LinearSvm {
        let n = x.rows();
        let p = x.cols();
        assert_eq!(n, labels.len());
        let y: Vec<f64> = labels.iter().map(|&l| if l == 0 { 1.0 } else { -1.0 }).collect();
        // Augmented weight vector: w[p] is the bias.
        let mut w = vec![0.0; p + 1];
        let mut alpha = vec![0.0; n];
        // Qii = ‖x̃ᵢ‖² (augmented).
        let qii: Vec<f64> = (0..n).map(|i| dot(x.row(i), x.row(i)) + 1.0).collect();
        let mut order: Vec<usize> = (0..n).collect();
        let mut iters = 0;
        for it in 0..params.max_iter {
            iters = it + 1;
            rng.shuffle(&mut order);
            let mut max_violation = 0.0f64;
            for &i in &order {
                let xi = x.row(i);
                // G = yᵢ wᵀx̃ᵢ − 1
                let g = y[i] * (dot(&w[..p], xi) + w[p]) - 1.0;
                // projected gradient
                let pg = if alpha[i] <= 0.0 {
                    g.min(0.0)
                } else if alpha[i] >= params.c {
                    g.max(0.0)
                } else {
                    g
                };
                max_violation = max_violation.max(pg.abs());
                if pg.abs() > 1e-14 {
                    let old = alpha[i];
                    alpha[i] = (old - g / qii[i]).clamp(0.0, params.c);
                    let delta = (alpha[i] - old) * y[i];
                    if delta != 0.0 {
                        for (wj, &xj) in w[..p].iter_mut().zip(xi) {
                            // lint:allow(float_accum, reason = "serial SGD weight update; the subgradient loop is inherently sequential")
                            *wj += delta * xj;
                        }
                        // lint:allow(float_accum, reason = "serial SGD bias update; the subgradient loop is inherently sequential")
                        w[p] += delta;
                    }
                }
            }
            if max_violation < params.tol {
                break;
            }
        }
        let b = w[p];
        w.truncate(p);
        LinearSvm { w, b, alpha, iters }
    }

    /// Decision value `wᵀx + b`.
    pub fn decision_value(&self, x: &[f64]) -> f64 {
        dot(&self.w, x) + self.b
    }

    /// Decision values for all rows.
    pub fn decision_values(&self, x: &Mat) -> Vec<f64> {
        (0..x.rows()).map(|i| self.decision_value(x.row(i))).collect()
    }

    /// Predicted labels (0 ↔ +1 convention).
    pub fn predict(&self, x: &Mat) -> Vec<usize> {
        self.decision_values(x).iter().map(|&d| usize::from(d < 0.0)).collect()
    }

    /// Number of support vectors (αᵢ > 0).
    pub fn n_support(&self) -> usize {
        self.alpha.iter().filter(|&&a| a > 1e-12).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::lda_binary::BinaryLda;
    use crate::model::lda_multiclass::tests::blobs;
    use crate::model::Reg;

    #[test]
    fn separable_data_solved() {
        let mut rng = Rng::new(1);
        let (x, labels) = blobs(&mut rng, 40, 2, 5, 4.0);
        let svm = LinearSvm::train(&x, &labels, SvmParams::default(), &mut rng);
        let acc = crate::cv::metrics::accuracy_labels(&svm.predict(&x), &labels);
        assert!(acc > 0.95, "acc={acc}");
        assert!(svm.n_support() < 80, "margin should be sparse in α");
    }

    #[test]
    fn dual_feasible_and_kkt_ish() {
        let mut rng = Rng::new(2);
        let (x, labels) = blobs(&mut rng, 25, 2, 4, 2.0);
        let params = SvmParams { c: 0.7, max_iter: 500, tol: 1e-6 };
        let svm = LinearSvm::train(&x, &labels, params, &mut rng);
        assert!(svm.alpha.iter().all(|&a| (0.0..=0.7 + 1e-12).contains(&a)));
        // w equals Σ αᵢ yᵢ xᵢ
        let mut w_check = vec![0.0; 4];
        for i in 0..x.rows() {
            let yi = if labels[i] == 0 { 1.0 } else { -1.0 };
            for j in 0..4 {
                w_check[j] += svm.alpha[i] * yi * x[(i, j)];
            }
        }
        for j in 0..4 {
            assert!((w_check[j] - svm.w[j]).abs() < 1e-8);
        }
    }

    #[test]
    fn comparable_accuracy_to_lda_on_gaussian_data() {
        // §1's claim: LDA ≈ linear SVM on Gaussian-ish problems.
        let mut rng = Rng::new(3);
        let (x, labels) = blobs(&mut rng, 60, 2, 10, 1.8);
        let (xt, lt) = blobs(&mut rng, 40, 2, 10, 1.8);
        let svm = LinearSvm::train(&x, &labels, SvmParams::default(), &mut rng);
        let lda = BinaryLda::train(&x, &labels, Reg::Ridge(0.5)).unwrap();
        let acc_svm = crate::cv::metrics::accuracy_labels(&svm.predict(&xt), &lt);
        let acc_lda = crate::cv::metrics::accuracy_labels(&lda.predict(&xt), &lt);
        assert!((acc_svm - acc_lda).abs() < 0.15, "svm {acc_svm} vs lda {acc_lda}");
    }

    #[test]
    fn hat_matrix_is_whitened_linear_kernel() {
        // §4.4: H_ij = x̃ᵢᵀ(X̃ᵀX̃+λI₀)⁻¹x̃ⱼ is a valid (whitened) dot product;
        // for whitened spherical data H ≈ K/(N) up to the ridge scaling.
        let mut rng = Rng::new(4);
        let x = Mat::from_fn(30, 6, |_, _| rng.gauss());
        let hat = crate::fastcv::hat::HatMatrix::build(&x, 1.0).unwrap();
        // positive semi-definite: all eigenvalues ≥ −ε
        let eig = crate::linalg::sym_eig(&hat.h);
        assert!(eig.values.iter().all(|&v| v > -1e-10), "H must be PSD");
        // and bounded by 1 (projection shrunk by ridge)
        assert!(eig.values.iter().all(|&v| v <= 1.0 + 1e-10));
    }
}
