//! Classic (retrain-per-fold) least-squares models.
//!
//! These are the *standard approach* the paper benchmarks against, plus the
//! regression reformulations (Appendix A/B) and optimal scoring (Hastie et
//! al. 1995) that the analytical approach builds on:
//!
//! - [`lda_binary`] — Fisher/LDA binary classifier, Eq. (3)/(4)
//! - [`lda_multiclass`] — generalised-eigenvalue multi-class LDA, Eq. (19)
//! - [`linreg`] — linear / ridge regression on the augmented design
//! - [`regression_lda`] — binary LDA cast as least squares (Appendix A)
//! - [`optimal_scoring`] — multi-class LDA as optimal scoring, Eq. (20)

pub mod lda_binary;
pub mod lda_multiclass;
pub mod linreg;
pub mod optimal_scoring;
pub mod regression_lda;
pub mod svm;

/// Regularisation of the within-class scatter (§2.6).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Reg {
    /// No regularisation (requires a well-conditioned scatter).
    None,
    /// Ridge: `S_w + λI`, λ ∈ [0, ∞).
    Ridge(f64),
    /// Shrinkage: `(1−λ)S_w + λνI` with `ν = trace(S_w)/P`, λ ∈ [0, 1].
    Shrinkage(f64),
}

impl Reg {
    /// Apply this regulariser to a scatter matrix in place; returns the
    /// effective scale factor applied to `S_w` (1 for none/ridge, `1−λ` for
    /// shrinkage) so weight-vector scalings can be compared across schemes.
    pub fn apply(self, sw: &mut crate::linalg::Mat) -> f64 {
        let p = sw.rows();
        match self {
            Reg::None => 1.0,
            Reg::Ridge(lambda) => {
                assert!(lambda >= 0.0, "ridge λ must be ≥ 0");
                for i in 0..p {
                    // lint:allow(float_accum, reason = "ridge diagonal add: each entry touched exactly once — order-free")
                    sw[(i, i)] += lambda;
                }
                1.0
            }
            Reg::Shrinkage(lambda) => {
                assert!((0.0..=1.0).contains(&lambda), "shrinkage λ must be in [0,1]");
                let nu = sw.trace() / p as f64;
                sw.scale(1.0 - lambda);
                for i in 0..p {
                    // lint:allow(float_accum, reason = "shrinkage diagonal add: each entry touched exactly once — order-free")
                    sw[(i, i)] += lambda * nu;
                }
                1.0 - lambda
            }
        }
    }

    /// Eq. (18): the ridge parameter equivalent to a shrinkage parameter for
    /// a scatter with scaling `ν = trace(S_w)/P`.
    pub fn shrinkage_to_ridge(lambda_shrink: f64, nu: f64) -> f64 {
        assert!((0.0..1.0).contains(&lambda_shrink), "λ_shrink must be in [0,1)");
        lambda_shrink / (1.0 - lambda_shrink) * nu
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    #[test]
    fn ridge_adds_diagonal() {
        let mut s = Mat::eye(3);
        Reg::Ridge(0.5).apply(&mut s);
        assert_eq!(s[(0, 0)], 1.5);
        assert_eq!(s[(0, 1)], 0.0);
    }

    #[test]
    fn shrinkage_preserves_trace() {
        let mut s = Mat::from_rows(&[&[2.0, 0.3], &[0.3, 4.0]]);
        let tr = s.trace();
        Reg::Shrinkage(0.3).apply(&mut s);
        assert!((s.trace() - tr).abs() < 1e-12, "shrinkage keeps trace");
        assert!((s[(0, 1)] - 0.7 * 0.3).abs() < 1e-12);
    }

    #[test]
    fn eq18_proportionality() {
        // (1−λs) S + λs ν I  ∝  S + λr I with λr from Eq. 18
        let s = Mat::from_rows(&[&[2.0, 0.5], &[0.5, 1.0]]);
        let nu = s.trace() / 2.0;
        let ls = 0.4;
        let lr = Reg::shrinkage_to_ridge(ls, nu);
        let mut a = s.clone();
        Reg::Shrinkage(ls).apply(&mut a);
        let mut b = s.clone();
        Reg::Ridge(lr).apply(&mut b);
        // a == (1−λs) * b
        let mut b_scaled = b.clone();
        b_scaled.scale(1.0 - ls);
        assert!(a.max_abs_diff(&b_scaled) < 1e-12);
    }
}
