//! Multi-class LDA as optimal scoring (§2.9, Hastie et al. 1995).
//!
//! Step 1: multivariate (ridge) regression of the class-indicator matrix
//! `Y ∈ R^{N×C}` on the augmented design, `B̃ = (X̃ᵀX̃ + λI₀)⁻¹ X̃ᵀ Y`,
//! giving fits `Ŷ = X̃ B̃ = H Y`.
//!
//! Step 2: the optimal scores `Θ` solve the `C×C` generalised eigenproblem
//! `(ŶᵀY/N) θ = α² (YᵀY/N) θ` under the constraint `N⁻¹‖Yθ‖² = 1`; the
//! trivial constant score (eigenvalue 1 for an uncentred design) is removed.
//!
//! The discriminant coordinates are then `W = B Θ D` (Eq. 20) with
//! `D = N^{-1/2} diag(α_k²(1−α_k²))^{-1/2}` — including the `√N` correction
//! the paper adds to Hastie's covariance-based formula so that
//! `Wᵀ S_w W = I` (within-*scatter* scaling).

use crate::linalg::{gen_sym_eig, matmul, Cholesky, Mat};
use crate::model::linreg::gram_ridged;
use crate::model::lda_multiclass::nearest_centroid;
use crate::stats::class_means;
use anyhow::{Context, Result};

/// Numerical floor for `α²(1−α²)` below which a discriminant coordinate is
/// considered degenerate (perfectly separated or absent) and dropped.
pub const ALPHA_EPS: f64 = 1e-10;

/// Class-indicator matrix `Y[i, labels[i]] = 1`.
pub fn indicator_matrix(labels: &[usize], c: usize) -> Mat {
    let mut y = Mat::zeros(labels.len(), c);
    for (i, &l) in labels.iter().enumerate() {
        assert!(l < c, "label {l} out of range");
        y[(i, l)] = 1.0;
    }
    y
}

/// Result of optimal-scoring step 2 on a `C×C` cross-product matrix.
#[derive(Clone, Debug)]
pub struct ScoreBasis {
    /// Optimal scores `Θ`, `C × ncomp`, normalised `θᵀ(YᵀY/N)θ = 1`.
    pub theta: Mat,
    /// Eigenvalues `α²_k` (descending), one per retained component.
    pub alpha2: Vec<f64>,
    /// Scaling diag `D` entries, `1/(√N · √(α²(1−α²)))`.
    pub d: Vec<f64>,
}

/// Solve step 2 given `M = ŶᵀY/N` (or its CV analogue `ẎᵀY/N`) and the
/// class-proportion diagonal `Dp = YᵀY/N`, for `n` total samples.
///
/// The trivial score — the eigenvector that is constant across classes,
/// with `α² = 1` for an uncentred design — is identified as the eigenvector
/// maximally aligned (in the `Dp` metric) with the all-ones vector and
/// removed, per §2.9. Degenerate components (`α²(1−α²) ≈ 0`) are dropped.
pub fn score_basis(m: &Mat, dp: &Mat, n: usize) -> Result<ScoreBasis> {
    let c = m.rows();
    let mut msym = m.clone();
    msym.symmetrize(); // exact-arithmetic symmetric; clean up roundoff
    let eig = gen_sym_eig(&msym, dp).context("class-proportion matrix singular")?;
    // Alignment of each eigenvector with 1 (Dp metric): |θᵀ Dp 1|.
    // Vectors are Dp-orthonormal so this is a cosine against the (unit-norm)
    // constant score; the trivial one has |cos| ≈ 1.
    // lint:allow(float_accum, reason = "serial cosine test against the constant score; canonical order, single-threaded")
    let dp1: Vec<f64> = (0..c).map(|i| (0..c).map(|j| dp[(i, j)]).sum()).collect();
    // lint:allow(float_accum, reason = "serial cosine test against the constant score; canonical order, single-threaded")
    let norm1 = (0..c).map(|i| dp1[i]).sum::<f64>().sqrt(); // sqrt(1ᵀDp1)
    let mut trivial = 0usize;
    let mut best = -1.0;
    for k in 0..c {
        let th = eig.vectors.col(k);
        let align = (crate::linalg::dot(&th, &dp1) / norm1).abs();
        if align > best {
            best = align;
            trivial = k;
        }
    }
    let keep: Vec<usize> = (0..c)
        .filter(|&k| k != trivial)
        .filter(|&k| {
            let a2 = eig.values[k].clamp(0.0, 1.0);
            a2 * (1.0 - a2) > ALPHA_EPS
        })
        .collect();
    let theta = eig.vectors.take_cols(&keep);
    let alpha2: Vec<f64> = keep.iter().map(|&k| eig.values[k].clamp(0.0, 1.0)).collect();
    let sqrt_n = (n as f64).sqrt();
    let d: Vec<f64> = alpha2.iter().map(|&a2| 1.0 / (sqrt_n * (a2 * (1.0 - a2)).sqrt())).collect();
    Ok(ScoreBasis { theta, alpha2, d })
}

/// Multi-class LDA trained through optimal scoring.
#[derive(Clone, Debug)]
pub struct OptimalScoringLda {
    /// Full regression weights `B̃`, `(P+1) × C`.
    pub b_tilde: Mat,
    /// Step-2 score basis on the training fits.
    pub basis: ScoreBasis,
    /// Discriminant coordinates `W = B Θ D`, `P × ncomp` (Eq. 20).
    pub w: Mat,
    /// Class centroids in discriminant-score space, `C × ncomp`.
    pub centroids: Mat,
    /// Number of classes.
    pub n_classes: usize,
}

impl OptimalScoringLda {
    /// Train on `x` (N×P), labels in `0..c`, ridge λ ≥ 0.
    pub fn train(x: &Mat, labels: &[usize], c: usize, lambda: f64) -> Result<OptimalScoringLda> {
        let n = x.rows();
        assert_eq!(n, labels.len());
        let y = indicator_matrix(labels, c);
        let xa = x.augment_ones();
        let g = gram_ridged(&xa, lambda);
        let xty = matmul(&xa.t(), &y);
        let b_tilde = match Cholesky::factor(&g) {
            Ok(ch) => ch.solve_mat(&xty),
            Err(_) => crate::linalg::solve_mat(&g, &xty)
                .context("normal equations singular; increase ridge λ")?,
        };
        let y_hat = matmul(&xa, &b_tilde);
        // M = ŶᵀY/N, Dp = YᵀY/N (diagonal of class proportions).
        let mut m = matmul(&y_hat.t(), &y);
        m.scale(1.0 / n as f64);
        let counts = crate::stats::class_counts(labels, c);
        let dp = Mat::diag(&counts.iter().map(|&k| k as f64 / n as f64).collect::<Vec<_>>());
        let basis = score_basis(&m, &dp, n)?;
        // W = B Θ D with B = B̃ without the bias row.
        let b = Mat::from_fn(x.cols(), c, |i, j| b_tilde[(i, j)]);
        let mut w = matmul(&b, &basis.theta);
        for col in 0..w.cols() {
            let dk = basis.d[col];
            for i in 0..w.rows() {
                w[(i, col)] *= dk;
            }
        }
        let means = class_means(x, labels, c);
        let centroids = matmul(&means, &w);
        Ok(OptimalScoringLda { b_tilde, basis, w, centroids, n_classes: c })
    }

    /// Project raw samples onto the discriminant coordinates.
    pub fn project(&self, x: &Mat) -> Mat {
        matmul(x, &self.w)
    }

    /// Predict by nearest centroid in discriminant space.
    pub fn predict(&self, x: &Mat) -> Vec<usize> {
        nearest_centroid(&self.project(x), &self.centroids)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::lda_multiclass::{tests::blobs, MulticlassLda};
    use crate::model::Reg;
    use crate::util::prop::Cases;
    use crate::util::rng::Rng;

    #[test]
    fn indicator_rows_sum_to_one() {
        let y = indicator_matrix(&[0, 2, 1, 2], 3);
        for i in 0..4 {
            assert_eq!(y.row(i).iter().sum::<f64>(), 1.0);
        }
        assert_eq!(y[(1, 2)], 1.0);
    }

    #[test]
    fn eq20_w_matches_generalized_eig_lda() {
        // The central Hastie-et-al. equivalence with the paper's √N fix:
        // W_OS = B Θ D equals the generalised-eig W up to per-column sign.
        Cases::new(15).run("eq20", |rng| {
            let c = 3 + rng.below(3); // 3..5 classes
            let per = 8 + rng.below(10);
            let p = (c - 1) + 1 + rng.below(8);
            let (x, labels) = blobs(rng, per, c, p, 2.5);
            let lambda = if rng.below(2) == 0 { 0.0 } else { 10f64.powf(rng.uniform_in(-2.0, 1.0)) };
            let os = OptimalScoringLda::train(&x, &labels, c, lambda).unwrap();
            let lda = MulticlassLda::train(&x, &labels, c, Reg::Ridge(lambda)).unwrap();
            assert_eq!(os.w.cols(), c - 1, "retained components");
            for col in 0..c - 1 {
                let a = os.w.col(col);
                let b = lda.w.col(col);
                let na = crate::linalg::dot(&a, &a).sqrt();
                let nb = crate::linalg::dot(&b, &b).sqrt();
                let cos = crate::linalg::dot(&a, &b) / (na * nb);
                assert!(
                    (cos.abs() - 1.0).abs() < 1e-5,
                    "col {col}: |cos|={} (λ={lambda})",
                    cos.abs()
                );
                // Scaling match: norms equal (the √N fix).
                assert!(
                    (na / nb - 1.0).abs() < 1e-5,
                    "col {col}: norm ratio {} (λ={lambda})",
                    na / nb
                );
            }
        });
    }

    #[test]
    fn predictions_match_classic_multiclass_lda() {
        Cases::new(15).run("os-predict", |rng| {
            let c = 3 + rng.below(3);
            let per = 10 + rng.below(8);
            let p = c + rng.below(10);
            let (x, labels) = blobs(rng, per, c, p, 2.0);
            let lambda = 10f64.powf(rng.uniform_in(-3.0, 0.5));
            let os = OptimalScoringLda::train(&x, &labels, c, lambda).unwrap();
            let lda = MulticlassLda::train(&x, &labels, c, Reg::Ridge(lambda)).unwrap();
            let (xt, _) = blobs(rng, 5, c, p, 2.0);
            assert_eq!(os.predict(&xt), lda.predict(&xt));
        });
    }

    #[test]
    fn alpha2_within_unit_interval_and_descending() {
        let mut rng = Rng::new(7);
        let (x, labels) = blobs(&mut rng, 20, 4, 6, 2.0);
        let os = OptimalScoringLda::train(&x, &labels, 4, 0.01).unwrap();
        assert!(os.basis.alpha2.iter().all(|&a| (0.0..=1.0).contains(&a)));
        assert!(os.basis.alpha2.windows(2).all(|w| w[0] >= w[1] - 1e-12));
        // Non-trivial scores: not constant across classes.
        for k in 0..os.basis.theta.cols() {
            let th = os.basis.theta.col(k);
            let spread = th.iter().fold(f64::NEG_INFINITY, |m, &v| m.max(v))
                - th.iter().fold(f64::INFINITY, |m, &v| m.min(v));
            assert!(spread > 1e-6, "score {k} is constant");
        }
    }

    #[test]
    fn unbalanced_classes_still_match() {
        let mut rng = Rng::new(8);
        let c = 3;
        let p = 5;
        // build unbalanced blobs: 30/12/6 samples
        let sizes = [30usize, 12, 6];
        let n: usize = sizes.iter().sum();
        let mut x = Mat::zeros(n, p);
        let mut labels = Vec::with_capacity(n);
        let mut r = 0;
        for (cls, &sz) in sizes.iter().enumerate() {
            let dir = rng.unit_vector(p);
            for _ in 0..sz {
                for j in 0..p {
                    x[(r, j)] = rng.gauss() + 2.5 * dir[j];
                }
                labels.push(cls);
                r += 1;
            }
        }
        let os = OptimalScoringLda::train(&x, &labels, c, 0.1).unwrap();
        let lda = MulticlassLda::train(&x, &labels, c, Reg::Ridge(0.1)).unwrap();
        assert_eq!(os.predict(&x), lda.predict(&x));
    }
}
