//! Linear and ridge regression on the augmented design `X̃ = [X, 1]`.
//!
//! `β̂ = (X̃ᵀX̃ + λI₀)⁻¹ X̃ᵀ y` (Eq. 5/17); `I₀` leaves the bias row
//! unpenalised. The analytical CV applies to this model verbatim — `y` is a
//! continuous response instead of class codes.

use crate::linalg::{dot, matvec_t, syrk_t, Cholesky, Mat};
use anyhow::{Context, Result};

/// Trained (ridge) linear regression model.
#[derive(Clone, Debug)]
pub struct LinReg {
    /// Weights on the original features.
    pub w: Vec<f64>,
    /// Intercept (`b_LR`).
    pub b: f64,
}

/// Build the regularised gram matrix `X̃ᵀX̃ + λI₀` for an augmented design.
/// `I₀` is the identity with the last (bias) diagonal entry zeroed (§2.6.1).
pub fn gram_ridged(xa: &Mat, lambda: f64) -> Mat {
    let mut g = syrk_t(xa);
    let p1 = xa.cols();
    for i in 0..p1 - 1 {
        // lint:allow(float_accum, reason = "ridge diagonal add: each entry touched exactly once — order-free")
        g[(i, i)] += lambda;
    }
    g
}

impl LinReg {
    /// Fit by solving the (ridged) normal equations.
    pub fn fit(x: &Mat, y: &[f64], lambda: f64) -> Result<LinReg> {
        assert_eq!(x.rows(), y.len());
        let xa = x.augment_ones();
        let g = gram_ridged(&xa, lambda);
        let xty = matvec_t(&xa, y);
        let beta = match Cholesky::factor(&g) {
            Ok(ch) => ch.solve_vec(&xty),
            Err(_) => crate::linalg::solve(&g, &xty)
                .context("normal equations singular; increase ridge λ")?,
        };
        let (w, b) = beta.split_at(x.cols());
        Ok(LinReg { w: w.to_vec(), b: b[0] })
    }

    /// Predicted response for one sample.
    pub fn predict_one(&self, x: &[f64]) -> f64 {
        dot(&self.w, x) + self.b
    }

    /// Predicted responses for all rows.
    pub fn predict(&self, x: &Mat) -> Vec<f64> {
        (0..x.rows()).map(|i| self.predict_one(x.row(i))).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn recovers_true_coefficients() {
        let mut rng = Rng::new(1);
        let n = 200;
        let p = 4;
        let w_true = [2.0, -1.0, 0.5, 3.0];
        let b_true = -0.7;
        let x = Mat::from_fn(n, p, |_, _| rng.gauss());
        let y: Vec<f64> = (0..n)
            .map(|i| dot(x.row(i), &w_true) + b_true + 0.01 * rng.gauss())
            .collect();
        let m = LinReg::fit(&x, &y, 0.0).unwrap();
        for j in 0..p {
            assert!((m.w[j] - w_true[j]).abs() < 0.01, "w[{j}]={}", m.w[j]);
        }
        assert!((m.b - b_true).abs() < 0.01);
    }

    #[test]
    fn ridge_shrinks_weights_not_bias() {
        let mut rng = Rng::new(2);
        let n = 50;
        let x = Mat::from_fn(n, 3, |_, _| rng.gauss());
        let y: Vec<f64> = (0..n).map(|i| 5.0 + x[(i, 0)] + 0.1 * rng.gauss()).collect();
        let m0 = LinReg::fit(&x, &y, 0.0).unwrap();
        let m1 = LinReg::fit(&x, &y, 1e4).unwrap();
        assert!(m1.w[0].abs() < 0.1 * m0.w[0].abs(), "huge ridge kills w");
        // bias is unpenalised: stays near the response mean.
        let ymean = crate::util::mean(&y);
        assert!((m1.b - ymean).abs() < 0.2, "b={} ymean={ymean}", m1.b);
    }

    #[test]
    fn wide_design_fits_with_ridge() {
        let mut rng = Rng::new(3);
        let x = Mat::from_fn(10, 50, |_, _| rng.gauss());
        let y: Vec<f64> = (0..10).map(|_| rng.gauss()).collect();
        assert!(LinReg::fit(&x, &y, 0.0).is_err(), "N<P unregularised is singular");
        let m = LinReg::fit(&x, &y, 0.5).unwrap();
        assert_eq!(m.w.len(), 50);
    }

    #[test]
    fn gram_ridged_leaves_bias_cell() {
        let x = Mat::from_rows(&[&[1.0], &[2.0]]);
        let xa = x.augment_ones();
        let g = gram_ridged(&xa, 10.0);
        assert_eq!(g[(0, 0)], 5.0 + 10.0); // 1²+2² + λ
        assert_eq!(g[(1, 1)], 2.0); // bias cell unpenalised: N
    }
}
