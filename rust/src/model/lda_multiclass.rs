//! Multi-class LDA via the generalised eigenproblem (§2.8).
//!
//! `S_b W = S_w W Λ` (Eq. 19); the data is projected onto the `C−1` leading
//! discriminant coordinates (scaled `Wᵀ S_w W = I`), and samples are
//! assigned to the class with the nearest projected centroid.

use super::Reg;
use crate::linalg::{gen_sym_eig, Mat};
use crate::stats::{between_scatter, class_counts, class_means, within_scatter};
use anyhow::{Context, Result};

/// Trained multi-class LDA classifier.
#[derive(Clone, Debug)]
pub struct MulticlassLda {
    /// Discriminant coordinates, `P × (C−1)`, columns ordered by descending
    /// generalised eigenvalue, scaled so `Wᵀ S_w_reg W = I`.
    pub w: Mat,
    /// Class centroids in discriminant space, `C × (C−1)`.
    pub centroids: Mat,
    /// Generalised eigenvalues of the retained coordinates.
    pub eigenvalues: Vec<f64>,
    /// Number of classes.
    pub n_classes: usize,
}

impl MulticlassLda {
    /// Train on `x` (N×P) with labels in `0..c`.
    pub fn train(x: &Mat, labels: &[usize], c: usize, reg: Reg) -> Result<MulticlassLda> {
        assert!(c >= 2, "need at least two classes");
        assert_eq!(x.rows(), labels.len());
        let counts = class_counts(labels, c);
        assert!(counts.iter().all(|&n| n > 0), "every class must have samples");
        let sb = between_scatter(x, labels, c);
        let mut sw = within_scatter(x, labels, c);
        reg.apply(&mut sw);
        let eig = gen_sym_eig(&sb, &sw)
            .context("within-class scatter not positive definite; add ridge")?;
        let ncomp = (c - 1).min(x.cols());
        let keep: Vec<usize> = (0..ncomp).collect();
        let w = eig.vectors.take_cols(&keep);
        let eigenvalues = eig.values[..ncomp].to_vec();
        let means = class_means(x, labels, c);
        let centroids = crate::linalg::matmul(&means, &w);
        Ok(MulticlassLda { w, centroids, eigenvalues, n_classes: c })
    }

    /// Project samples onto the discriminant coordinates (`N × (C−1)`).
    pub fn project(&self, x: &Mat) -> Mat {
        crate::linalg::matmul(x, &self.w)
    }

    /// Predict by nearest centroid in discriminant space.
    pub fn predict(&self, x: &Mat) -> Vec<usize> {
        let z = self.project(x);
        nearest_centroid(&z, &self.centroids)
    }
}

/// Assign each row of `z` to the row of `centroids` with minimal squared
/// Euclidean distance.
pub fn nearest_centroid(z: &Mat, centroids: &Mat) -> Vec<usize> {
    assert_eq!(z.cols(), centroids.cols());
    (0..z.rows())
        .map(|i| {
            let row = z.row(i);
            let mut best = 0;
            let mut best_d = f64::INFINITY;
            for cidx in 0..centroids.rows() {
                let c = centroids.row(cidx);
                // lint:allow(float_accum, reason = "serial per-row squared distance in canonical feature order; prediction is single-threaded")
                let d: f64 = row.iter().zip(c).map(|(a, b)| (a - b) * (a - b)).sum();
                if d < best_d {
                    best_d = d;
                    best = cidx;
                }
            }
            best
        })
        .collect()
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::linalg::matmul;
    use crate::util::rng::Rng;

    /// Shared test-data helper: `c` Gaussian blobs with `per` samples each in
    /// `p` dims, centroids `sep` apart along random directions.
    pub(crate) fn blobs(rng: &mut Rng, per: usize, c: usize, p: usize, sep: f64) -> (Mat, Vec<usize>) {
        let n = per * c;
        let mut x = Mat::from_fn(n, p, |_, _| rng.gauss());
        let mut labels = vec![0usize; n];
        for class in 0..c {
            let dir = rng.unit_vector(p);
            for i in 0..per {
                let r = class * per + i;
                labels[r] = class;
                for j in 0..p {
                    x[(r, j)] += sep * dir[j];
                }
            }
        }
        (x, labels)
    }

    #[test]
    fn separable_blobs_high_accuracy() {
        let mut rng = Rng::new(1);
        let (x, labels) = blobs(&mut rng, 40, 4, 8, 5.0);
        let lda = MulticlassLda::train(&x, &labels, 4, Reg::Ridge(1e-6)).unwrap();
        let pred = lda.predict(&x);
        let acc = pred.iter().zip(&labels).filter(|(a, b)| a == b).count() as f64 / labels.len() as f64;
        assert!(acc > 0.95, "acc={acc}");
    }

    #[test]
    fn w_is_sw_orthonormal() {
        let mut rng = Rng::new(2);
        let (x, labels) = blobs(&mut rng, 30, 3, 6, 2.0);
        let lda = MulticlassLda::train(&x, &labels, 3, Reg::Ridge(0.5)).unwrap();
        let mut sw = within_scatter(&x, &labels, 3);
        Reg::Ridge(0.5).apply(&mut sw);
        let wsw = matmul(&lda.w.t(), &matmul(&sw, &lda.w));
        assert!(wsw.max_abs_diff(&Mat::eye(2)) < 1e-7, "WᵀS_wW=I");
    }

    #[test]
    fn c_minus_one_components() {
        let mut rng = Rng::new(3);
        let (x, labels) = blobs(&mut rng, 25, 5, 10, 3.0);
        let lda = MulticlassLda::train(&x, &labels, 5, Reg::Ridge(0.1)).unwrap();
        assert_eq!(lda.w.cols(), 4);
        assert_eq!(lda.centroids.shape(), (5, 4));
        // eigenvalues descending and positive for separable data
        assert!(lda.eigenvalues.windows(2).all(|w| w[0] >= w[1] - 1e-12));
        assert!(lda.eigenvalues[0] > 0.0);
    }

    #[test]
    fn two_class_case_matches_binary_direction() {
        let mut rng = Rng::new(4);
        let (x, labels) = blobs(&mut rng, 30, 2, 5, 3.0);
        let multi = MulticlassLda::train(&x, &labels, 2, Reg::Ridge(0.01)).unwrap();
        let binary =
            crate::model::lda_binary::BinaryLda::train(&x, &labels, crate::model::Reg::Ridge(0.01))
                .unwrap();
        let wm = multi.w.col(0);
        let cos = crate::linalg::dot(&wm, &binary.w)
            / (crate::linalg::dot(&wm, &wm).sqrt() * crate::linalg::dot(&binary.w, &binary.w).sqrt());
        assert!((cos.abs() - 1.0).abs() < 1e-7, "cos={cos}");
    }

    #[test]
    fn rank_deficient_without_ridge_fails_cleanly() {
        let mut rng = Rng::new(5);
        let (x, labels) = blobs(&mut rng, 3, 3, 20, 2.0); // N=9 < P=20
        assert!(MulticlassLda::train(&x, &labels, 3, Reg::None).is_err());
        assert!(MulticlassLda::train(&x, &labels, 3, Reg::Ridge(1.0)).is_ok());
    }
}
