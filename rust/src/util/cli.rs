//! Minimal command-line parsing (no `clap` in the offline build).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments and
//! subcommands, with typed accessors and a generated usage string.

use std::collections::BTreeMap;

/// Parsed arguments: options, flags, and positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse an iterator of raw argument strings (excluding argv[0]).
    /// `known_flags` lists option names that take *no* value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, known_flags: &[&str]) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&body) {
                    out.flags.push(body.to_string());
                } else if let Some(value) = iter.next_if(|n| !n.starts_with("--")) {
                    out.opts.insert(body.to_string(), value);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env(known_flags: &[&str]) -> Args {
        Self::parse(std::env::args().skip(1), known_flags)
    }

    /// First positional argument (the subcommand), if any.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    /// Positional arguments after the subcommand.
    pub fn rest(&self) -> &[String] {
        if self.positional.is_empty() {
            &[]
        } else {
            &self.positional[1..]
        }
    }

    /// Is `--name` present as a bare flag (or any option with that key)?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.opts.contains_key(name)
    }

    /// String option.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    /// String option with default.
    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// Typed option with default; panics with a clear message on bad input.
    pub fn get_parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> T
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => default,
            Some(s) => match s.parse() {
                Ok(v) => v,
                Err(e) => {
                    eprintln!("error: invalid value for --{name}: {s:?} ({e})");
                    std::process::exit(2);
                }
            },
        }
    }

    /// Comma-separated list option, e.g. `--folds 5,10,20`.
    pub fn get_list<T: std::str::FromStr>(&self, name: &str, default: &[T]) -> Vec<T>
    where
        T: Clone,
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => default.to_vec(),
            Some(s) => s
                .split(',')
                .filter(|p| !p.is_empty())
                .map(|p| match p.trim().parse() {
                    Ok(v) => v,
                    Err(e) => {
                        eprintln!("error: invalid list element for --{name}: {p:?} ({e})");
                        std::process::exit(2);
                    }
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from), &["verbose", "quiet"])
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("sweep --exp f3a --n 100 --seed=42 --verbose out.tsv");
        assert_eq!(a.subcommand(), Some("sweep"));
        assert_eq!(a.get("exp"), Some("f3a"));
        assert_eq!(a.get_parse_or("n", 0usize), 100);
        assert_eq!(a.get_parse_or("seed", 0u64), 42);
        assert!(a.flag("verbose"));
        assert_eq!(a.rest(), &["out.tsv".to_string()]);
    }

    #[test]
    fn unknown_trailing_flag_without_value() {
        let a = parse("run --fast");
        assert!(a.flag("fast"));
    }

    #[test]
    fn list_parsing() {
        let a = parse("sweep --folds 5,10,20");
        assert_eq!(a.get_list::<usize>("folds", &[]), vec![5, 10, 20]);
        assert_eq!(a.get_list::<usize>("missing", &[7]), vec![7]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run");
        assert_eq!(a.get_or("mode", "native"), "native");
        assert_eq!(a.get_parse_or("reps", 20usize), 20);
        assert!(!a.flag("verbose"));
    }
}
