//! Deterministic pseudo-random number generation.
//!
//! No external RNG crates are available in the offline build, so this module
//! implements PCG64 (permuted congruential generator, O'Neill 2014) with the
//! DXSM output permutation, plus the distribution samplers the paper's
//! simulations need: uniform, standard normal (Box–Muller with caching),
//! Fisher–Yates permutations, and categorical draws.
//!
//! Everything is seeded explicitly; the paper resets the seed between the
//! analytical and standard timing runs so both see identical data and folds
//! (§2.12) — [`Rng::fork`] supports that pattern cheaply.

/// PCG64-DXSM pseudo-random generator. 128-bit state, 64-bit output.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u128,
    inc: u128,
    /// Cached second output of the last Box–Muller transform.
    gauss_cache: Option<f64>,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Rng {
    /// Create a generator from a 64-bit seed (stream id fixed).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Create a generator with an explicit stream id; different streams are
    /// statistically independent even for equal seeds.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        // SplitMix64 expansion of the seed into 128-bit state.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        };
        let state = ((next() as u128) << 64) | next() as u128;
        let inc = (((stream as u128) << 64) | next() as u128) | 1;
        let mut rng = Rng { state, inc, gauss_cache: None };
        // Burn a few outputs so low-entropy seeds decorrelate.
        for _ in 0..4 {
            rng.next_u64();
        }
        rng
    }

    /// Derive an independent child generator (used to give each simulated
    /// subject / worker its own stream while keeping runs reproducible).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::with_stream(self.next_u64() ^ tag, tag.wrapping_mul(2) | 1)
    }

    /// Counter-seeded stream: generator number `idx` of the family anchored
    /// at `seed`. This is a *pure function* of `(seed, idx)` — unlike
    /// [`Rng::fork`], it does not consume state from any parent generator —
    /// so any two engines that agree on the pair draw bit-identical streams
    /// no matter in what order, on which thread, or in which batch they
    /// evaluate them. The permutation engines
    /// ([`crate::fastcv::perm`] / [`crate::fastcv::perm_batch`]) rely on
    /// this to make serial, batched, and threaded runs produce identical
    /// null distributions.
    pub fn stream(seed: u64, idx: u64) -> Rng {
        // SplitMix64-mix the counter so adjacent indices decorrelate, then
        // give each index its own PCG stream id (forced odd in
        // `with_stream`).
        let mut z = idx.wrapping_add(0x9e37_79b9_7f4a_7c15);
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^= z >> 31;
        Rng::with_stream(seed ^ z, z | 1)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        // PCG64 DXSM output function.
        let state = self.state;
        self.state = state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let mut hi = (state >> 64) as u64;
        let lo = (state as u64) | 1;
        hi ^= hi >> 32;
        hi = hi.wrapping_mul(0xda94_2042_e4dd_58b5);
        hi ^= hi >> 48;
        hi.wrapping_mul(lo)
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Unbiased integer in `[0, n)` (Lemire rejection method).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal via Box–Muller (second deviate cached).
    pub fn gauss(&mut self) -> f64 {
        if let Some(g) = self.gauss_cache.take() {
            return g;
        }
        // Rejection-free polar-less form; u1 strictly positive.
        let mut u1 = self.uniform();
        while u1 <= f64::MIN_POSITIVE {
            u1 = self.uniform();
        }
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.gauss_cache = Some(r * s);
        r * c
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.gauss()
    }

    /// Fill a slice with standard normal deviates.
    pub fn fill_gauss(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.gauss();
        }
    }

    /// A uniformly random permutation of `0..n` (Fisher–Yates).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn choose(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut p: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            p.swap(i, j);
        }
        p.truncate(k);
        p
    }

    /// Random unit vector on the P-dimensional hypersphere (used by the
    /// paper's simulation §2.12 to place class centroids).
    pub fn unit_vector(&mut self, p: usize) -> Vec<f64> {
        loop {
            let mut v = vec![0.0; p];
            self.fill_gauss(&mut v);
            let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
            if norm > 1e-12 {
                for x in v.iter_mut() {
                    *x /= norm;
                }
                return v;
            }
        }
    }

    /// Chi-squared deviate with `k` degrees of freedom (sum of squared
    /// normals for small k, Wilson–Hilferty-corrected gamma for large k).
    pub fn chi2(&mut self, k: usize) -> f64 {
        if k <= 32 {
            let mut s = 0.0;
            for _ in 0..k {
                let g = self.gauss();
                s += g * g;
            }
            s
        } else {
            // Wilson–Hilferty approximation, adequate for Wishart sampling
            // of the *simulated* covariance (only distribution shape needed).
            let kf = k as f64;
            let z = self.gauss();
            let c = 2.0 / (9.0 * kf);
            kf * (1.0 - c + z * c.sqrt()).powi(3).max(0.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let (mut m, mut v) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.gauss();
            m += g;
            v += g * g;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.02, "mean={m}");
        assert!((v - 1.0).abs() < 0.03, "var={v}");
    }

    #[test]
    fn permutation_is_valid() {
        let mut r = Rng::new(5);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
    }

    #[test]
    fn choose_distinct() {
        let mut r = Rng::new(9);
        let k = r.choose(50, 10);
        assert_eq!(k.len(), 10);
        let mut s = k.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 10);
    }

    #[test]
    fn unit_vector_has_unit_norm() {
        let mut r = Rng::new(13);
        for p in [1, 2, 10, 100] {
            let v = r.unit_vector(p);
            let n: f64 = v.iter().map(|x| x * x).sum();
            assert!((n - 1.0).abs() < 1e-10);
        }
    }

    #[test]
    fn chi2_mean_close_to_k() {
        let mut r = Rng::new(17);
        for k in [3usize, 40] {
            let n = 20_000;
            let mean: f64 = (0..n).map(|_| r.chi2(k)).sum::<f64>() / n as f64;
            assert!((mean - k as f64).abs() < 0.15 * k as f64, "k={k} mean={mean}");
        }
    }

    #[test]
    fn stream_is_pure_in_seed_and_index() {
        for idx in [0u64, 1, 2, 1000] {
            let mut a = Rng::stream(42, idx);
            let mut b = Rng::stream(42, idx);
            for _ in 0..32 {
                assert_eq!(a.next_u64(), b.next_u64());
            }
        }
    }

    #[test]
    fn stream_indices_decorrelated() {
        // Adjacent counters (and equal counters under different seeds) must
        // give unrelated streams.
        let mut a = Rng::stream(7, 0);
        let mut b = Rng::stream(7, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2, "adjacent indices correlated");
        let mut c = Rng::stream(8, 0);
        let mut d = Rng::stream(9, 0);
        let same = (0..64).filter(|_| c.next_u64() == d.next_u64()).count();
        assert!(same < 2, "different seeds correlated");
    }

    #[test]
    fn stream_shuffles_are_valid_permutations() {
        for idx in 0..20u64 {
            let p = Rng::stream(5, idx).permutation(50);
            let mut seen = vec![false; 50];
            for &i in &p {
                assert!(!seen[i]);
                seen[i] = true;
            }
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(100);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
