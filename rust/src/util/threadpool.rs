//! A small scoped thread pool.
//!
//! The offline build has no rayon/tokio, so the coordinator's parallelism is
//! built on this pool: a fixed set of workers pulling boxed jobs from a
//! shared injector queue, plus a [`ThreadPool::scope`] API that lets callers
//! borrow stack data safely (all scoped jobs are joined before `scope`
//! returns).
//!
//! Two layers consume it: the coordinator fans *sweep points* out over a
//! pool ([`crate::coordinator::Scheduler`]), and the analytic front-ends
//! fan a *single job's* Gram/GEMM kernels out through a
//! [`crate::fastcv::context::ComputeContext`] (which can own a pool or
//! borrow this one — see its `borrowing` constructor). The pooled kernels
//! ([`crate::linalg::matmul_pool`], [`crate::linalg::syrk_t_pool`]) are
//! bit-identical to their serial forms, so pool size never changes results.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<std::collections::VecDeque<Job>>,
    available: Condvar,
    shutdown: Mutex<bool>,
    panics: AtomicUsize,
}

/// Fixed-size worker pool executing boxed jobs FIFO.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Spawn a pool with `size` workers (min 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(std::collections::VecDeque::new()),
            available: Condvar::new(),
            shutdown: Mutex::new(false),
            panics: AtomicUsize::new(0),
        });
        let workers = (0..size)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("fastcv-worker-{i}"))
                    .spawn(move || worker_loop(shared))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { shared, workers, size }
    }

    /// Pool sized to the machine (logical cores), capped at `cap`.
    pub fn with_default_size(cap: usize) -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n.min(cap.max(1)))
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Number of jobs that panicked since pool creation.
    pub fn panic_count(&self) -> usize {
        self.shared.panics.load(Ordering::Relaxed)
    }

    /// Submit a `'static` job; returns immediately.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        let mut q = self.shared.queue.lock().unwrap();
        q.push_back(Box::new(f));
        drop(q);
        self.shared.available.notify_one();
    }

    /// Run a batch of scoped closures that may borrow from the caller's
    /// stack; blocks until every closure has finished. Panics in jobs are
    /// counted and re-raised here as a single panic.
    ///
    /// Panic accounting is *per scope*: the counter lives in the scope's own
    /// pending state, so a panicking unrelated [`ThreadPool::execute`] job
    /// running concurrently on the same pool never fails an innocent scope
    /// (it still shows up in the pool-wide [`ThreadPool::panic_count`]).
    pub fn scope<'env, F>(&self, jobs: Vec<F>)
    where
        F: FnOnce() + Send + 'env,
    {
        /// Completion state owned by one `scope` call.
        struct ScopeState {
            left: Mutex<usize>,
            done: Condvar,
            panics: AtomicUsize,
        }

        /// Decrements the pending counter on drop so a panicking job still
        /// releases the scope (the panic itself is counted first).
        struct Guard(Arc<ScopeState>);
        impl Drop for Guard {
            fn drop(&mut self) {
                let mut left = self.0.left.lock().unwrap();
                *left -= 1;
                if *left == 0 {
                    self.0.done.notify_all();
                }
            }
        }

        let state = Arc::new(ScopeState {
            left: Mutex::new(jobs.len()),
            done: Condvar::new(),
            panics: AtomicUsize::new(0),
        });

        for job in jobs {
            let state = Arc::clone(&state);
            let shared = Arc::clone(&self.shared);
            // SAFETY: this transmute only erases the `'env` lifetime of the
            // boxed closure (`Box<dyn FnOnce + Send + 'env>` →
            // `Box<dyn FnOnce + Send + 'static>`); layout is identical, so
            // the only obligation is that the closure never runs after
            // `'env` ends. That holds because this function does not return
            // before every job has dropped its `Guard`: the wait loop below
            // blocks on `state.done` until `left == 0`, and `Guard::drop`
            // decrements `left` even when the job panics (the panic is
            // counted first, then caught by `catch_unwind`, so a panicking
            // job still releases the scope rather than poisoning it). A
            // worker can therefore never hold a `'env` borrow once the
            // caller resumes. Audited 2026-08; exercised under
            // ThreadSanitizer by the nightly `tsan` CI job.
            let job: Box<dyn FnOnce() + Send + 'env> = Box::new(job);
            let job: Job = unsafe { std::mem::transmute(job) };
            self.execute(move || {
                // Count the panic *before* the guard releases the scope so
                // the waiter reliably observes it.
                let guard = Guard(Arc::clone(&state));
                if catch_unwind(AssertUnwindSafe(job)).is_err() {
                    shared.panics.fetch_add(1, Ordering::SeqCst);
                    state.panics.fetch_add(1, Ordering::SeqCst);
                }
                drop(guard);
            });
        }
        let mut left = state.left.lock().unwrap();
        while *left > 0 {
            left = state.done.wait(left).unwrap();
        }
        drop(left);
        let scope_panics = state.panics.load(Ordering::SeqCst);
        if scope_panics > 0 {
            panic!("{scope_panics} job(s) panicked inside ThreadPool::scope");
        }
    }

    /// Parallel-for over `0..n`: chunks the index range across the pool and
    /// calls `f(i)` for every index. Blocks until done.
    pub fn for_each<'env, F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Send + Sync + 'env,
    {
        if n == 0 {
            return;
        }
        let chunks = (self.size * 4).min(n);
        let f = &f;
        let jobs: Vec<_> = (0..chunks)
            .map(|c| {
                move || {
                    let lo = c * n / chunks;
                    let hi = (c + 1) * n / chunks;
                    for i in lo..hi {
                        f(i);
                    }
                }
            })
            .collect();
        self.scope(jobs);
    }

    /// Parallel map over `0..n` collecting results in index order.
    ///
    /// Each scoped job owns a disjoint `&mut` chunk of the output, so there
    /// is no per-element locking and `T` needs neither `Default` nor
    /// `Clone` — this is the batched permutation engine's hot path.
    pub fn map<'env, T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send + 'env,
        F: Fn(usize) -> T + Send + Sync + 'env,
    {
        if n == 0 {
            return Vec::new();
        }
        let mut out: Vec<Option<T>> = Vec::with_capacity(n);
        out.resize_with(n, || None);
        let chunks = (self.size * 4).min(n);
        let chunk_len = n.div_ceil(chunks);
        let f = &f;
        let jobs: Vec<_> = out
            .chunks_mut(chunk_len)
            .enumerate()
            .map(|(c, slots)| {
                move || {
                    let base = c * chunk_len;
                    for (off, slot) in slots.iter_mut().enumerate() {
                        *slot = Some(f(base + off));
                    }
                }
            })
            .collect();
        self.scope(jobs);
        out.into_iter().map(|slot| slot.expect("map slot filled")).collect()
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop_front() {
                    break Some(job);
                }
                if *shared.shutdown.lock().unwrap() {
                    break None;
                }
                q = shared.available.wait(q).unwrap();
            }
        };
        match job {
            Some(job) => {
                if catch_unwind(AssertUnwindSafe(job)).is_err() {
                    shared.panics.fetch_add(1, Ordering::Relaxed);
                }
            }
            None => return,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        *self.shared.shutdown.lock().unwrap() = true;
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        // scope with empty vec forces a sync point via drop ordering; easier:
        // poll until done.
        let t0 = std::time::Instant::now();
        while counter.load(Ordering::SeqCst) < 100 {
            assert!(t0.elapsed().as_secs() < 10, "jobs stalled");
            std::thread::yield_now();
        }
    }

    #[test]
    fn scope_borrows_stack_data() {
        let pool = ThreadPool::new(3);
        let data = vec![1u64, 2, 3, 4, 5, 6, 7, 8];
        let sum = AtomicU64::new(0);
        let jobs: Vec<_> = data
            .chunks(2)
            .map(|ch| {
                let sum = &sum;
                move || {
                    sum.fetch_add(ch.iter().sum::<u64>(), Ordering::SeqCst);
                }
            })
            .collect();
        pool.scope(jobs);
        assert_eq!(sum.load(Ordering::SeqCst), 36);
    }

    #[test]
    fn for_each_covers_every_index() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicU64> = (0..257).map(|_| AtomicU64::new(0)).collect();
        pool.for_each(257, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.map(100, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic(expected = "panicked inside")]
    fn scope_propagates_panics() {
        let pool = ThreadPool::new(2);
        pool.scope(vec![|| panic!("boom")]);
    }

    #[test]
    fn scope_ignores_concurrent_unrelated_execute_panic() {
        // Regression: the old implementation diffed the *pool-wide* panic
        // counter around the scope, so a panic from an unrelated `execute`
        // job landing mid-scope failed the innocent scope call.
        let pool = ThreadPool::new(2);
        let before = pool.panic_count();
        let pool_ref = &pool;
        let sum = AtomicU64::new(0);
        let sum_ref = &sum;
        // The single scoped job submits a panicking fire-and-forget job to
        // the second worker, then blocks until that panic has been counted —
        // guaranteeing the unrelated panic lands while the scope is open.
        pool.scope(vec![move || {
            pool_ref.execute(|| panic!("unrelated execute job"));
            let t0 = std::time::Instant::now();
            while pool_ref.panic_count() <= before {
                assert!(t0.elapsed().as_secs() < 10, "unrelated panic never counted");
                std::thread::yield_now();
            }
            sum_ref.fetch_add(1, Ordering::SeqCst);
        }]);
        assert_eq!(sum.load(Ordering::SeqCst), 1, "scope job ran to completion");
        assert_eq!(pool.panic_count(), before + 1, "pool-wide counter still sees it");
    }

    #[test]
    fn map_works_without_default_or_clone() {
        // T intentionally has no Default/Clone impl.
        struct Opaque(usize);
        let pool = ThreadPool::new(4);
        let out = pool.map(103, Opaque);
        assert_eq!(out.len(), 103);
        assert!(out.iter().enumerate().all(|(i, v)| v.0 == i));
        assert!(pool.map(0, Opaque).is_empty());
    }
}
