//! General-purpose substrates built from scratch for the offline
//! environment: RNG, thread pool, CLI parsing, JSON, tables, property tests,
//! and timing helpers.

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod table;
pub mod threadpool;

use std::time::Instant;

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// A monotonic wall-clock: seconds since this call, as a closure.
///
/// Numeric modules are banned from reading `Instant` directly (lint L2 —
/// clocks are a nondeterminism source), so timing-aware entry points like
/// [`crate::coordinator::Scheduler::run_clocked`] take a
/// `&(dyn Fn() -> f64 + Sync)` injected by the caller. The CLI and the
/// serve daemon hand in this clock; tests hand in counters or `|| 0.0`.
pub fn monotonic_clock() -> impl Fn() -> f64 + Send + Sync {
    let t0 = Instant::now();
    move || t0.elapsed().as_secs_f64()
}

/// Geometrically spaced grid from `lo` to `hi` inclusive with `steps`
/// points, deduplicated after rounding to integers — mirrors the paper's
/// "10 to 1000 in 40 logarithmic steps" feature grid.
pub fn log_grid_usize(lo: usize, hi: usize, steps: usize) -> Vec<usize> {
    assert!(lo >= 1 && hi >= lo && steps >= 2);
    let (l0, l1) = ((lo as f64).ln(), (hi as f64).ln());
    let mut out: Vec<usize> = (0..steps)
        .map(|i| (l0 + (l1 - l0) * i as f64 / (steps - 1) as f64).exp().round() as usize)
        .collect();
    out.dedup();
    out
}

/// Median of a slice (copies + sorts; fine for result post-processing).
pub fn median(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Sample mean.
pub fn mean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample standard deviation (0 for n<2).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_grid_endpoints_and_monotone() {
        let g = log_grid_usize(10, 1000, 40);
        assert_eq!(*g.first().unwrap(), 10);
        assert_eq!(*g.last().unwrap(), 1000);
        assert!(g.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn stats_helpers() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((stddev(&[1.0, 2.0, 3.0]) - 1.0).abs() < 1e-12);
        assert_eq!(stddev(&[5.0]), 0.0);
    }

    #[test]
    fn timed_returns_result() {
        let (v, dt) = timed(|| 21 * 2);
        assert_eq!(v, 42);
        assert!(dt >= 0.0);
    }

    #[test]
    fn monotonic_clock_is_nondecreasing() {
        let clock = monotonic_clock();
        let a = clock();
        let b = clock();
        assert!(a >= 0.0 && b >= a);
    }
}
