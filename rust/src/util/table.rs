//! ASCII table rendering for paper-style result reporting.

/// A simple column-aligned table builder.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    /// New table with the given column headers.
    pub fn new<S: Into<String>>(header: Vec<S>) -> Table {
        Table { header: header.into_iter().map(Into::into).collect(), rows: Vec::new(), title: None }
    }

    /// Set a title line printed above the table.
    pub fn with_title<S: Into<String>>(mut self, t: S) -> Table {
        self.title = Some(t.into());
        self
    }

    /// Append a row (stringified cells). Panics if the arity mismatches.
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row arity != header arity");
        self.rows.push(cells);
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when no data rows have been added.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render with box-drawing separators.
    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut width = vec![0usize; cols];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let sep = |l: char, m: char, r: char| {
            let mut s = String::new();
            s.push(l);
            for (i, w) in width.iter().enumerate() {
                s.push_str(&"─".repeat(w + 2));
                s.push(if i + 1 == cols { r } else { m });
            }
            s.push('\n');
            s
        };
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("│");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:>w$} │", c, w = width[i]));
            }
            s.push('\n');
            s
        };
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        out.push_str(&sep('┌', '┬', '┐'));
        out.push_str(&fmt_row(&self.header));
        out.push_str(&sep('├', '┼', '┤'));
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out.push_str(&sep('└', '┴', '┘'));
        out
    }

    /// Render as tab-separated values (header + rows) for file dumps.
    pub fn to_tsv(&self) -> String {
        let mut out = self.header.join("\t");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join("\t"));
            out.push('\n');
        }
        out
    }
}

/// Format a float with `d` significant-looking decimals, trimming noise.
pub fn fnum(x: f64, d: usize) -> String {
    if x.abs() >= 1e5 || (x != 0.0 && x.abs() < 1e-4) {
        format!("{x:.*e}", d)
    } else {
        format!("{x:.*}", d)
    }
}

/// Format a duration in adaptive units.
pub fn fdur(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.1} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{secs:.2} s")
    } else {
        format!("{:.1} min", secs / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["P", "rel.eff"]).with_title("demo");
        t.row(vec!["10", "0.12"]);
        t.row(vec!["1000", "3.01"]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.contains("rel.eff"));
        assert!(s.lines().count() >= 6);
        // all body lines equal width
        let widths: Vec<usize> = s.lines().skip(1).map(|l| l.chars().count()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]), "{s}");
    }

    #[test]
    fn tsv_roundtrip() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["1", "2"]);
        assert_eq!(t.to_tsv(), "a\tb\n1\t2\n");
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["1"]);
    }

    #[test]
    fn num_formatting() {
        assert_eq!(fnum(1.23456, 2), "1.23");
        assert!(fnum(1.0e-7, 2).contains('e'));
        assert!(fdur(0.5e-7).ends_with("ns"));
        assert!(fdur(0.005).ends_with("ms"));
        assert!(fdur(5.0).ends_with('s'));
        assert!(fdur(600.0).ends_with("min"));
    }
}
