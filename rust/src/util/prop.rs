//! A miniature property-based testing harness (no `proptest` offline).
//!
//! A [`Cases`] driver draws seeded random test cases from user generators and
//! runs an assertion closure per case; on failure it reports the seed and
//! case index so the exact case can be replayed. Generators for the shapes
//! the paper's invariants need (dims, folds, ridge values, class balances)
//! live here too.

use crate::util::rng::Rng;

/// Property-test driver: `Cases::new(n).run(name, |rng| { ... })`.
pub struct Cases {
    n: usize,
    base_seed: u64,
}

impl Cases {
    /// `n` random cases; seed can be overridden via `FASTCV_PROP_SEED`.
    pub fn new(n: usize) -> Cases {
        let base_seed = std::env::var("FASTCV_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0x5eed_cafe);
        Cases { n, base_seed }
    }

    /// Run `prop` for each case with a per-case RNG. The closure should
    /// panic (e.g. via assert!) on property violation.
    pub fn run<F: Fn(&mut Rng)>(&self, name: &str, prop: F) {
        for case in 0..self.n {
            let seed = self.base_seed.wrapping_add(case as u64);
            let mut rng = Rng::new(seed);
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
            if let Err(e) = result {
                let msg = e
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<non-string panic>".into());
                panic!(
                    "property {name:?} failed at case {case} (replay with FASTCV_PROP_SEED={seed}): {msg}"
                );
            }
        }
    }
}

/// Draw a problem size (n_samples, n_features) biased toward small-but-
/// interesting shapes, including P > N and P < N regimes.
pub fn dims(rng: &mut Rng) -> (usize, usize) {
    let n = 8 + rng.below(40); // 8..48 samples
    let p = match rng.below(3) {
        0 => 1 + rng.below(n.saturating_sub(2).max(1)), // P < N (classic)
        1 => n + rng.below(30),                         // P >= N (needs ridge)
        _ => 1 + rng.below(6),                          // tiny P
    };
    (n, p)
}

/// Number of folds valid for n samples (2..=min(n,12), occasionally LOO).
pub fn folds(rng: &mut Rng, n: usize) -> usize {
    if rng.below(5) == 0 {
        n // leave-one-out
    } else {
        2 + rng.below(n.min(12).saturating_sub(2).max(1))
    }
}

/// A ridge penalty: 0 sometimes (when allowed), else log-uniform 1e-4..1e3.
pub fn ridge(rng: &mut Rng, allow_zero: bool) -> f64 {
    if allow_zero && rng.below(4) == 0 {
        0.0
    } else {
        10f64.powf(rng.uniform_in(-4.0, 3.0))
    }
}

/// Class sizes for `c` classes totalling at least `min_per` each.
pub fn class_sizes(rng: &mut Rng, c: usize, min_per: usize, extra: usize) -> Vec<usize> {
    let mut sizes = vec![min_per; c];
    for _ in 0..extra {
        let i = rng.below(c);
        sizes[i] += 1;
    }
    sizes
}

/// Assert two floats match to a relative-or-absolute tolerance.
#[track_caller]
pub fn assert_close(a: f64, b: f64, tol: f64, what: &str) {
    let scale = a.abs().max(b.abs()).max(1.0);
    assert!(
        (a - b).abs() <= tol * scale,
        "{what}: {a} vs {b} (|Δ|={}, tol={})",
        (a - b).abs(),
        tol * scale
    );
}

/// Assert two slices match element-wise (relative-or-absolute tolerance).
#[track_caller]
pub fn assert_all_close(a: &[f64], b: &[f64], tol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let scale = x.abs().max(y.abs()).max(1.0);
        assert!(
            (x - y).abs() <= tol * scale,
            "{what}[{i}]: {x} vs {y} (|Δ|={}, tol={})",
            (x - y).abs(),
            tol * scale
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cases_pass_when_property_holds() {
        Cases::new(50).run("tautology", |rng| {
            let x = rng.uniform();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "replay with FASTCV_PROP_SEED=")]
    fn cases_report_seed_on_failure() {
        Cases::new(20).run("always-false", |rng| {
            assert!(rng.uniform() < -1.0);
        });
    }

    #[test]
    fn dims_cover_both_regimes() {
        let mut rng = Rng::new(1);
        let (mut wide, mut tall) = (0, 0);
        for _ in 0..200 {
            let (n, p) = dims(&mut rng);
            assert!(n >= 8 && p >= 1);
            if p >= n {
                wide += 1;
            } else {
                tall += 1;
            }
        }
        assert!(wide > 20 && tall > 20);
    }

    #[test]
    fn folds_valid() {
        let mut rng = Rng::new(2);
        for _ in 0..200 {
            let n = 8 + rng.below(40);
            let k = folds(&mut rng, n);
            assert!((2..=n).contains(&k));
        }
    }

    #[test]
    fn class_sizes_sum() {
        let mut rng = Rng::new(3);
        let s = class_sizes(&mut rng, 4, 3, 10);
        assert_eq!(s.iter().sum::<usize>(), 22);
        assert!(s.iter().all(|&x| x >= 3));
    }

    #[test]
    fn close_helpers() {
        assert_close(1.0, 1.0 + 1e-12, 1e-9, "ok");
        assert_all_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-12], 1e-9, "ok");
    }
}
