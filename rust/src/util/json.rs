//! Tiny JSON parser/serializer (offline build has no serde).
//!
//! Covers the full JSON grammar minus some escape exotica; used to read the
//! AOT `artifacts/manifest.json` written by `python/compile/aot.py` and to
//! dump benchmark results.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(src: &str) -> Result<Json, String> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }

    /// Object field accessor.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// String content if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Number if numeric.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Integer if numeric and integral.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    /// Array items if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.src.len() && matches!(self.src[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.pos))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.src[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.pos)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'u') => {
                            let hex = self
                                .src
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?,
                                16,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let rest = std::str::from_utf8(&self.src[self.pos..]).map_err(|e| e.to_string())?;
                    let Some(c) = rest.chars().next() else {
                        return Err("unterminated string".to_string());
                    };
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.src[start..self.pos])
            .ok()
            .and_then(|s| s.parse().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                other => return Err(format!("expected , or ] got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(format!("expected , or }} got {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let src = r#"{"artifacts":[{"op":"hat_matrix","n":100,"p":380,"file":"hat_100x380.hlo.txt","ridge":0.01}],"version":1}"#;
        let v = Json::parse(src).unwrap();
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 1);
        assert_eq!(arts[0].get("op").unwrap().as_str(), Some("hat_matrix"));
        assert_eq!(arts[0].get("n").unwrap().as_usize(), Some(100));
        assert_eq!(arts[0].get("ridge").unwrap().as_f64(), Some(0.01));
        let dumped = v.dump();
        assert_eq!(Json::parse(&dumped).unwrap(), v);
    }

    #[test]
    fn parses_nested_and_escapes() {
        let v = Json::parse(r#"{"a":[1,2.5,-3e2,true,false,null],"s":"x\n\"y\"A"}"#).unwrap();
        assert_eq!(v.get("s").unwrap().as_str(), Some("x\n\"y\"A"));
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[2].as_f64(), Some(-300.0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" { \"k\" :\n[ 1 , 2 ] } ").unwrap();
        assert_eq!(v.get("k").unwrap().as_arr().unwrap().len(), 2);
    }
}
