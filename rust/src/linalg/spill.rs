//! Out-of-core Gram spill: panel persistence + a left-looking spilled
//! Cholesky whose panels never all coexist in RAM.
//!
//! The tiled engine ([`crate::linalg::tiled`]) bounded every *transient*
//! of the §4.5 big-data builds to `O(tile)` slabs — but the factor itself
//! (the dual `N×N`, or the primal `(P+1)×(P+1)`) still had to live
//! resident for the in-place Cholesky. This module removes that last
//! resident square:
//!
//! * [`PanelStore`] — the Gram (and later its factor) as contiguous
//!   `tile×N` row-slab *panels*, held either in RAM (accounting/tests) or
//!   as files under a spill directory (`--spill-dir` on the CLI).
//! * [`gram_spill`] / [`syrk_spill`] — assemble `V Vᵀ` (dual/nested side)
//!   or `AᵀA` (primal side) straight into a store, panel by panel, with
//!   values **bitwise-identical** to the one-shot kernels
//!   ([`crate::linalg::gram_tiled`] + mirror, [`crate::linalg::syrk_t`]).
//! * [`chol_spill`] — a **left-looking, panel-at-a-time blocked
//!   Cholesky**: each panel is loaded, updated against the previously
//!   factored panels, factored in place, and written back. Every element
//!   keeps [`Cholesky::factor_into`]'s per-element arithmetic (one
//!   full-prefix [`dot`], one subtract, one divide), so the spilled factor
//!   is **bitwise-identical** to the in-RAM one (`spill_*` property
//!   tests).
//! * [`SpilledCholesky::solve_mat_in_place`] — triangular solves that
//!   stream panels the same way, bitwise-identical to
//!   [`Cholesky::solve_mat_in_place`].
//!
//! ## Bitwise determinism
//!
//! Spilling, like tiling, is a pure memory/IO knob. The factor argument:
//! element `L[i,j]` is `(A[i,j] − dot(L[i,..j], L[j,..j])) / L[j,j]`, a
//! function of *final* prefix values only — so the left-looking schedule
//! (all columns `< lo` applied to panel `[lo,hi)` before its diagonal
//! block) performs the identical arithmetic the serial column-major
//! recurrence does, merely in a different global order. The solve
//! argument: forward substitution consumes row prefixes (one streaming
//! pass); backward substitution consumes *column* strips, which are
//! gathered from the row-slab panels per target panel (≈`T/2` re-reads of
//! the factor — the documented IO cost of keeping row-major panels).
//!
//! ## Resident-memory model
//!
//! Beyond the streamed `O(NP)` outputs a caller keeps anyway, every phase
//! holds `O(tile·(N+P))`: assembly has three `tile×P` operand slabs plus a
//! `tile×N` band; the factor holds two `tile×N` panels; the backward solve
//! holds one `tile×N` panel plus one `N×tile` column strip. The `N²` (or
//! `(P+1)²`) square never exists in RAM. `benches/ablation_spill.rs`
//! records the model per row in `BENCH_spill.json`.
//!
//! ## Crash safety
//!
//! Disk panels are crash-safe (`docs/ROBUSTNESS.md`): every panel file
//! carries an 8-byte FNV-1a checksum footer over its exact `f64` bit
//! patterns, writes go through write-temp-then-rename (a reader never
//! observes a half-written `panel_{t}.bin` — at worst a leftover
//! `.tmp`), and reads verify length **and** checksum, surfacing the
//! typed [`SpillError::Torn`] / [`SpillError::Corrupt`] instead of bad
//! floats. [`PanelStore::open`] re-opens a directory a crashed process
//! left behind, quarantining any torn/corrupt/orphaned files, and
//! [`quarantine_orphans`] sweeps whole abandoned store directories out
//! of a spill dir at daemon startup. The named fault sites
//! (`spill.write.io`, `spill.write.torn`, `spill.read.corrupt`,
//! `spill.read.delay` — see [`crate::fastcv::fault`]) let the `chaos_*`
//! suite drive every one of those paths deterministically.

use super::chol::Cholesky;
use super::gemm::{dot, matmul, syrk_t_rows_into};
use super::mat::Mat;
use crate::fastcv::fault;
use crate::store::key::Fnv;
use crate::util::threadpool::ThreadPool;
use anyhow::{bail, ensure, Context, Result};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// A typed spill-layer fault: what a disk panel read/write detected.
/// Travels wrapped in `anyhow::Error` (every existing `Result` chain
/// works unchanged); recovery layers pick it out with
/// `err.downcast_ref::<SpillError>()` — the [`crate::store::FactorStore`]
/// answers `Torn`/`Corrupt` by evicting the artifact and rebuilding.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SpillError {
    /// The panel file's byte length is wrong — a partial write (crash
    /// mid-`write`) or external truncation.
    Torn {
        /// The panel file.
        path: PathBuf,
        /// Bytes found.
        got: usize,
        /// Bytes a complete panel (payload + footer) occupies.
        expected: usize,
    },
    /// The panel file is complete but its payload does not match the
    /// checksum footer — bit rot or an interleaved/overwritten write.
    Corrupt {
        /// The panel file.
        path: PathBuf,
        /// The footer's stored checksum.
        stored: u64,
        /// The checksum the payload actually hashes to.
        computed: u64,
    },
    /// An injected IO fault (the `spill.write.io` site) — stands in for
    /// ENOSPC/EIO in chaos drills.
    Io {
        /// The panel file the operation targeted.
        path: PathBuf,
    },
}

impl std::fmt::Display for SpillError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SpillError::Torn { path, got, expected } => write!(
                f,
                "torn panel file {}: {got} bytes, expected {expected}",
                path.display()
            ),
            SpillError::Corrupt { path, stored, computed } => write!(
                f,
                "corrupt panel file {}: stored checksum {stored:#018x}, payload hashes to {computed:#018x}",
                path.display()
            ),
            SpillError::Io { path } => {
                write!(f, "injected spill IO fault on {}", path.display())
            }
        }
    }
}

impl std::error::Error for SpillError {}

/// FNV-1a over a panel payload's exact bit patterns (length-prefixed) —
/// the footer every disk panel carries.
fn panel_checksum(payload: &[f64]) -> u64 {
    let mut h = Fnv::new().word(payload.len() as u64);
    for v in payload {
        h = h.word(v.to_bits());
    }
    h.finish()
}

/// Extra bytes a disk panel carries beyond its `f64` payload: the 8-byte
/// checksum footer.
const FOOTER_BYTES: usize = 8;

/// Process-wide counter so every disk-backed store gets its own
/// subdirectory under the caller's `--spill-dir` (per-λ factor stores and
/// the λ-free Gram store would otherwise collide on panel file names).
static STORE_COUNTER: AtomicU64 = AtomicU64::new(0);

/// Where a [`PanelStore`]'s panels live.
enum StoreBackend {
    /// Panels resident as plain buffers — the accounting/testing backend
    /// (also the right choice when the point of spilling is the blocked
    /// *schedule*, not disk: peak residency is still `O(tile·N)` per
    /// loaded panel plus the store itself).
    Ram(Vec<Option<Vec<f64>>>),
    /// One file per panel (`panel_{t}.bin`, little-endian `f64`) under a
    /// store-private subdirectory of the spill dir; removed on drop.
    Disk { dir: PathBuf },
}

/// An `N×N` symmetric matrix (a Gram, or its Cholesky factor) persisted as
/// contiguous `tile×N` row-slab panels — the storage layer behind
/// [`chol_spill`] and the `TilePolicy::Spill` builds.
///
/// Panel `t` holds rows `[t·tile, min((t+1)·tile, N))` as one row-major
/// buffer. With `dir = None` panels live in RAM; with `dir = Some(..)`
/// each panel is a file under a store-private subdirectory (created on
/// demand, removed when the store is dropped). Disk panels carry an FNV
/// checksum footer and publish via write-temp-then-rename; reads verify
/// length **and** checksum, so a torn or corrupted panel (partial write,
/// crash, bit rot) surfaces as a typed [`SpillError`] rather than being
/// silently read.
///
/// ```
/// use fastcv::linalg::{Mat, PanelStore};
///
/// let g = Mat::from_fn(6, 6, |i, j| (1 + i * 6 + j) as f64);
/// let mut store = PanelStore::new(6, 4, None).unwrap(); // RAM panels, remainder panel of 2
/// store.write_mat(&g).unwrap();
/// assert_eq!(store.panels(), 2);
/// assert_eq!(store.range(1), (4, 6));
/// assert_eq!(store.to_mat().unwrap().as_slice(), g.as_slice());
/// ```
pub struct PanelStore {
    n: usize,
    tile: usize,
    backend: StoreBackend,
    /// The matrix diagonal, refreshed on every [`PanelStore::write_panel`]
    /// — `O(N)` resident, and what lets the per-λ pivot floor be computed
    /// without an extra full pass over the (possibly on-disk) panels.
    diag: Vec<f64>,
}

impl PanelStore {
    /// A store for an `n×n` matrix in `tile`-row panels. `dir = None` keeps
    /// panels in RAM; `dir = Some(base)` spills each panel to a file under
    /// a fresh subdirectory of `base` (created here).
    pub fn new(n: usize, tile: usize, dir: Option<&Path>) -> Result<PanelStore> {
        let tile = tile.clamp(1, n.max(1));
        let backend = match dir {
            None => StoreBackend::Ram(vec![None; n.div_ceil(tile.max(1))]),
            Some(base) => {
                let sub = base.join(format!(
                    "store-{}-{}",
                    std::process::id(),
                    STORE_COUNTER.fetch_add(1, Ordering::Relaxed)
                ));
                std::fs::create_dir_all(&sub)
                    .with_context(|| format!("creating spill dir {}", sub.display()))?;
                StoreBackend::Disk { dir: sub }
            }
        };
        Ok(PanelStore { n, tile, backend, diag: vec![0.0; n] })
    }

    /// Matrix dimension `N`.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Panel height (the last panel may be shorter).
    pub fn tile(&self) -> usize {
        self.tile
    }

    /// Number of panels.
    pub fn panels(&self) -> usize {
        self.n.div_ceil(self.tile)
    }

    /// Row range `[lo, hi)` of panel `t`.
    pub fn range(&self, t: usize) -> (usize, usize) {
        let lo = t * self.tile;
        (lo, (lo + self.tile).min(self.n))
    }

    /// Is this store disk-backed?
    pub fn is_disk(&self) -> bool {
        matches!(self.backend, StoreBackend::Disk { .. })
    }

    /// The on-disk path of panel `t` (`None` for a RAM store). Exposed for
    /// the crash-safety tests and for operators inspecting a spill dir.
    pub fn panel_path(&self, t: usize) -> Option<PathBuf> {
        match &self.backend {
            StoreBackend::Ram(_) => None,
            StoreBackend::Disk { dir } => Some(dir.join(format!("panel_{t}.bin"))),
        }
    }

    /// Persist panel `t`. `panel` must be the exact `(hi−lo)×N` slab.
    pub fn write_panel(&mut self, t: usize, panel: Mat) -> Result<()> {
        let (lo, hi) = self.range(t);
        ensure!(
            panel.shape() == (hi - lo, self.n),
            "panel {t}: shape {:?} does not match the {}×{} slab",
            panel.shape(),
            hi - lo,
            self.n
        );
        for r in 0..(hi - lo) {
            self.diag[lo + r] = panel[(r, lo + r)];
        }
        match &mut self.backend {
            StoreBackend::Ram(slots) => slots[t] = Some(panel.into_vec()),
            StoreBackend::Disk { dir } => {
                let path = dir.join(format!("panel_{t}.bin"));
                if fault::hit("spill.write.io").is_some() {
                    return Err(SpillError::Io { path }.into());
                }
                let payload = panel.as_slice();
                let sum = panel_checksum(payload);
                let mut bytes = Vec::with_capacity(payload.len() * 8 + FOOTER_BYTES);
                for v in payload {
                    bytes.extend_from_slice(&v.to_le_bytes());
                }
                bytes.extend_from_slice(&sum.to_le_bytes());
                if let Some(drop_bytes) = fault::hit("spill.write.torn") {
                    // Simulated crash mid-write: a short file at the *final*
                    // path (as if the process died before the fsync), no
                    // rename. The next read must detect it, not decode it.
                    let keep = bytes.len().saturating_sub(drop_bytes.max(1) as usize);
                    std::fs::write(&path, &bytes[..keep])
                        .with_context(|| format!("writing spill panel {}", path.display()))?;
                    return Ok(());
                }
                // Write-temp-then-rename: `panel_{t}.bin` either holds the
                // previous complete panel or the new one, never a prefix.
                let tmp = dir.join(format!("panel_{t}.tmp"));
                std::fs::write(&tmp, bytes)
                    .with_context(|| format!("writing spill panel {}", tmp.display()))?;
                std::fs::rename(&tmp, &path)
                    .with_context(|| format!("publishing spill panel {}", path.display()))?;
            }
        }
        Ok(())
    }

    /// Load panel `t` as an owned matrix. Disk reads verify the byte
    /// length first, so a torn panel file errors instead of being silently
    /// misinterpreted. Read-only consumers should prefer
    /// [`PanelStore::panel_cow`], which borrows RAM panels without a copy.
    pub fn read_panel(&self, t: usize) -> Result<Mat> {
        let (lo, hi) = self.range(t);
        Ok(Mat::from_vec(hi - lo, self.n, self.panel_cow(t)?.into_owned()))
    }

    /// Panel `t`'s row-major buffer, borrow-or-read: RAM panels come back
    /// as a **borrow** (no copy — the factor's left-looking updates and the
    /// solves re-read panels `O(T²/2)` times, which must not mean `O(T²/2)`
    /// allocations in the in-RAM mode), disk panels as an owned,
    /// length-checked read.
    pub fn panel_cow(&self, t: usize) -> Result<std::borrow::Cow<'_, [f64]>> {
        let (lo, hi) = self.range(t);
        let rows = hi - lo;
        match &self.backend {
            StoreBackend::Ram(slots) => match &slots[t] {
                Some(data) => Ok(std::borrow::Cow::Borrowed(data.as_slice())),
                None => bail!("panel {t} was never written"),
            },
            StoreBackend::Disk { dir } => {
                let path = dir.join(format!("panel_{t}.bin"));
                if let Some(ms) = fault::hit("spill.read.delay") {
                    std::thread::sleep(std::time::Duration::from_millis(ms));
                }
                let mut bytes = std::fs::read(&path)
                    .with_context(|| format!("reading spill panel {}", path.display()))?;
                if fault::hit("spill.read.corrupt").is_some() && !bytes.is_empty() {
                    bytes[0] ^= 0xff; // bit rot on a payload byte: the footer must catch it
                }
                let expected = rows * self.n * 8 + FOOTER_BYTES;
                if bytes.len() != expected {
                    return Err(
                        SpillError::Torn { path, got: bytes.len(), expected }.into()
                    );
                }
                let (payload, footer) = bytes.split_at(bytes.len() - FOOTER_BYTES);
                let data: Vec<f64> = payload
                    .chunks_exact(8)
                    // lint:allow(panic, reason = "chunks_exact(8) guarantees every chunk converts to [u8; 8]")
                    .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                let mut stored_bytes = [0u8; FOOTER_BYTES];
                stored_bytes.copy_from_slice(footer);
                let stored = u64::from_le_bytes(stored_bytes);
                let computed = panel_checksum(&data);
                if stored != computed {
                    return Err(SpillError::Corrupt { path, stored, computed }.into());
                }
                Ok(std::borrow::Cow::Owned(data))
            }
        }
    }

    /// Split a dense square matrix into this store's panels (tests and the
    /// "spill an existing Gram" path).
    pub fn write_mat(&mut self, m: &Mat) -> Result<()> {
        ensure!(m.shape() == (self.n, self.n), "write_mat: shape mismatch");
        for t in 0..self.panels() {
            let (lo, hi) = self.range(t);
            let panel =
                Mat::from_vec(hi - lo, self.n, m.rows_slice(lo, hi).to_vec());
            self.write_panel(t, panel)?;
        }
        Ok(())
    }

    /// Gather every panel into a dense matrix (tests; the dual hat's RHS).
    pub fn to_mat(&self) -> Result<Mat> {
        let mut out = Mat::zeros(self.n, self.n);
        for t in 0..self.panels() {
            let (lo, hi) = self.range(t);
            out.rows_slice_mut(lo, hi).copy_from_slice(&self.panel_cow(t)?);
        }
        Ok(out)
    }

    /// Gather the `idx × idx` principal submatrix, one panel at a time —
    /// the spilled analogue of [`Mat::take`]`(idx, idx)` (bitwise: a pure
    /// gather). Backs [`crate::fastcv::hat::SharedNestedGram`]'s per-fold
    /// downdates when the shared `XXᵀ` is spilled.
    pub fn take_square(&self, idx: &[usize]) -> Result<Mat> {
        // Mat::take would hit an out-of-bounds panic on a bad index; the
        // panel gather must not silently zero-fill instead.
        ensure!(
            idx.iter().all(|&i| i < self.n),
            "take_square: index out of range (n = {})",
            self.n
        );
        let m = idx.len();
        let mut out = Mat::zeros(m, m);
        for t in 0..self.panels() {
            let (lo, hi) = self.range(t);
            if !idx.iter().any(|&i| lo <= i && i < hi) {
                continue;
            }
            let panel = self.panel_cow(t)?;
            for (pos, &i) in idx.iter().enumerate() {
                if lo <= i && i < hi {
                    let src = &panel[(i - lo) * self.n..(i - lo + 1) * self.n];
                    let dst = out.row_mut(pos);
                    for (l, &j) in idx.iter().enumerate() {
                        dst[l] = src[j];
                    }
                }
            }
        }
        Ok(out)
    }

    /// Re-read and checksum every panel. `Ok` means each `panel_{t}.bin`
    /// decodes to the right length and matches its footer; the error
    /// chain carries the first bad panel's typed [`SpillError`]. RAM
    /// stores verify trivially (their buffers cannot rot). This is the
    /// verify-on-hit sweep [`crate::store::FactorStore`] runs before
    /// serving a spill-backed artifact — degrade (rebuild) rather than
    /// ever serve bad bytes.
    pub fn verify(&self) -> Result<()> {
        if !self.is_disk() {
            return Ok(());
        }
        for t in 0..self.panels() {
            self.panel_cow(t).with_context(|| format!("verifying spill panel {t}"))?;
        }
        Ok(())
    }

    /// Re-open a store directory a previous (possibly crashed) process
    /// left behind, sweeping it first: leftover `.tmp` files (a write
    /// that never renamed), panel files for out-of-range indices, and
    /// panels that fail the length/checksum verify are all **moved into
    /// a `quarantine/` subdirectory** — never deleted, never served.
    /// Surviving panels refresh the cached diagonal. Returns the opened
    /// store plus the number of files quarantined. Like every disk
    /// store, the returned store owns `dir` and removes it on drop.
    pub fn open(n: usize, tile: usize, dir: &Path) -> Result<(PanelStore, usize)> {
        ensure!(dir.is_dir(), "spill store dir {} does not exist", dir.display());
        let tile = tile.clamp(1, n.max(1));
        let mut store = PanelStore {
            n,
            tile,
            backend: StoreBackend::Disk { dir: dir.to_path_buf() },
            diag: vec![0.0; n],
        };
        let mut names: Vec<String> = std::fs::read_dir(dir)
            .with_context(|| format!("opening spill store dir {}", dir.display()))?
            .filter_map(|e| e.ok()?.file_name().into_string().ok())
            .collect();
        names.sort(); // deterministic sweep order regardless of the OS
        let mut quarantined = 0;
        for name in &names {
            let path = dir.join(name);
            if !path.is_file() {
                continue; // e.g. an earlier sweep's quarantine/ subdir
            }
            let panel_index = name
                .strip_prefix("panel_")
                .and_then(|s| s.strip_suffix(".bin"))
                .and_then(|s| s.parse::<usize>().ok());
            let verdict = match panel_index {
                _ if name.ends_with(".tmp") => Err(anyhow::anyhow!("orphaned temp file")),
                None => continue, // not ours — leave unrecognised files alone
                Some(t) if t >= store.panels() => {
                    Err(anyhow::anyhow!("panel index {t} out of range"))
                }
                Some(t) => store.panel_cow(t).map(|data| (t, data.into_owned())),
            };
            match verdict {
                Ok((t, data)) => {
                    let (lo, hi) = store.range(t);
                    for r in 0..(hi - lo) {
                        store.diag[lo + r] = data[r * n + lo + r];
                    }
                }
                Err(_) => {
                    quarantine_file(dir, &path)?;
                    quarantined += 1;
                }
            }
        }
        Ok((store, quarantined))
    }
}

/// Move `path` into `dir/quarantine/` (created on demand), keeping its
/// file name.
fn quarantine_file(dir: &Path, path: &Path) -> Result<()> {
    let qdir = dir.join("quarantine");
    std::fs::create_dir_all(&qdir)
        .with_context(|| format!("creating quarantine dir {}", qdir.display()))?;
    let Some(name) = path.file_name() else {
        bail!("quarantine: {} has no file name", path.display());
    };
    std::fs::rename(path, qdir.join(name))
        .with_context(|| format!("quarantining {}", path.display()))?;
    Ok(())
}

/// Sweep a user-level spill directory at daemon startup: whole `store-*`
/// subdirectories abandoned by *other* (crashed) processes are moved
/// into `base/quarantine/` — inspectable, never deleted, and never in
/// the way of fresh stores. The current process's own live stores
/// (`store-{pid}-*`) are left alone. Returns the number of directories
/// moved; a missing `base` is not an error (nothing to sweep).
pub fn quarantine_orphans(base: &Path) -> Result<usize> {
    if !base.is_dir() {
        return Ok(0);
    }
    let own = format!("store-{}-", std::process::id());
    let mut names: Vec<String> = std::fs::read_dir(base)
        .with_context(|| format!("sweeping spill dir {}", base.display()))?
        .filter_map(|e| e.ok()?.file_name().into_string().ok())
        .collect();
    names.sort();
    let mut moved = 0;
    for name in &names {
        if !name.starts_with("store-") || name.starts_with(&own) {
            continue;
        }
        let path = base.join(name);
        if !path.is_dir() {
            continue;
        }
        quarantine_file(base, &path)?;
        moved += 1;
    }
    Ok(moved)
}

impl Drop for PanelStore {
    fn drop(&mut self) {
        if let StoreBackend::Disk { dir } = &self.backend {
            // Best-effort cleanup of the store-private subdirectory; a
            // crashed process leaves its panels for inspection instead.
            let _ = std::fs::remove_dir_all(dir);
        }
    }
}

impl std::fmt::Debug for PanelStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PanelStore")
            .field("n", &self.n)
            .field("tile", &self.tile)
            .field("disk", &self.is_disk())
            .finish()
    }
}

/// Assemble the symmetric `G = V Vᵀ + ridge·I` straight into `store`,
/// panel by panel, from row slabs of `V` produced on demand by
/// `slab(lo, hi)` — the spilled sibling of [`crate::linalg::gram_tiled`].
///
/// Every element is **bitwise-identical** to the one-shot
/// `matmul(&v, &v.t())` + `symmetrize()` build (and hence to
/// `gram_tiled`): diagonal and upper blocks run the identical blocked
/// GEMM, and a lower block `(t, u<t)` is **transpose-copied from the
/// already-written panel `u`** — the exact mirror `gram_tiled` performs,
/// done panel-at-a-time, so no block's flops are ever paid twice. `ridge`
/// is added to the assembled diagonal (pass `0.0` for a λ-free Gram),
/// exactly as the in-RAM paths ridge after assembly. Per-panel GEMM blocks
/// fan out over `pool`; peak residency is the current `tile×N` band plus
/// per-worker `tile×P` operand slabs (and one earlier panel during the
/// mirror copy).
pub fn gram_spill<F>(
    store: &mut PanelStore,
    ridge: f64,
    slab: F,
    pool: Option<&ThreadPool>,
) -> Result<()>
where
    F: Fn(usize, usize) -> Mat + Sync,
{
    let n = store.n();
    let t_count = store.panels();
    for t in 0..t_count {
        let (lo, hi) = store.range(t);
        let rows = hi - lo;
        let v_t = slab(lo, hi);
        let mut band = Mat::zeros(rows, n);
        // Strictly-lower blocks: mirror from the already-written panels —
        // band[r, j] = G[lo+r, j] := G[j, lo+r], which panel u computed as
        // part of its upper block (u, t). A panel read replaces a GEMM.
        for u in 0..t {
            let (lo_u, hi_u) = store.range(u);
            let pu = store.panel_cow(u)?;
            for r in 0..rows {
                let brow = band.row_mut(r);
                for j in lo_u..hi_u {
                    brow[j] = pu[(j - lo_u) * n + lo + r];
                }
            }
        }
        // Diagonal + upper blocks: the blocked GEMM, fanned over the pool.
        let block_of = |u: usize| -> Mat {
            let (lo_u, hi_u) = store.range(u);
            if u == t {
                matmul(&v_t, &v_t.t())
            } else {
                let v_u = slab(lo_u, hi_u);
                matmul(&v_t, &v_u.t())
            }
        };
        let blocks: Vec<Mat> = match pool {
            Some(pool) if pool.size() > 1 && t_count - t > 1 => {
                pool.map(t_count - t, |k| block_of(t + k))
            }
            _ => (t..t_count).map(block_of).collect(),
        };
        for (k, block) in blocks.iter().enumerate() {
            let (lo_u, hi_u) = store.range(t + k);
            for r in 0..rows {
                band.row_mut(r)[lo_u..hi_u].copy_from_slice(block.row(r));
            }
        }
        if ridge != 0.0 {
            for r in 0..rows {
                band[(r, lo + r)] += ridge;
            }
        }
        store.write_panel(t, band)?;
    }
    Ok(())
}

/// Assemble the primal Gram `G = AᵀA` into `store` (`store.n()` must equal
/// `A.cols()`), panel by panel, with every element **bitwise-identical**
/// to [`crate::linalg::syrk_t`]'s (mirrored) output — the spilled form of
/// the `(P+1)×(P+1)` quadrant. Like [`gram_spill`], no flops are paid
/// twice: each band computes only its upper-triangle part through
/// `syrk_t_rows`'s recurrence (row chunks fanned over `pool`;
/// row-split-invariant accumulation, so pooling moves no bits), mirrors
/// its own diagonal block in place, and mirror-copies the columns left of
/// the band from the already-written panels — a panel read per earlier
/// panel instead of a duplicate accumulation.
pub fn syrk_spill(store: &mut PanelStore, a: &Mat, pool: Option<&ThreadPool>) -> Result<()> {
    let p = store.n();
    ensure!(
        p == a.cols(),
        "syrk_spill: store holds a {}-dim matrix but AᵀA is {}-dim",
        p,
        a.cols()
    );
    for t in 0..store.panels() {
        let (lo, hi) = store.range(t);
        let rows = hi - lo;
        let mut band = Mat::zeros(rows, p);
        // Columns [0, lo): mirror from the already-written panels —
        // band[r, j] = G[lo+r, j] := G[j, lo+r], computed in panel(j)'s
        // upper part (exactly the copy syrk_t's mirror_upper performs).
        for u in 0..t {
            let (lo_u, hi_u) = store.range(u);
            let pu = store.panel_cow(u)?;
            for r in 0..rows {
                let brow = band.row_mut(r);
                for j in lo_u..hi_u {
                    brow[j] = pu[(j - lo_u) * p + lo + r];
                }
            }
        }
        // Columns ≥ row: the upper-triangle recurrence, row chunks over
        // the pool (it never touches columns < its row, so the mirrored
        // prefix above is untouched).
        match pool {
            Some(pool) if pool.size() > 1 && rows >= 2 => {
                let chunk = rows.div_ceil(pool.size() * 2).max(1);
                let jobs: Vec<_> = band
                    .as_mut_slice()
                    .chunks_mut(chunk * p)
                    .enumerate()
                    .map(|(c, slice)| {
                        let clo = lo + c * chunk;
                        let chi = (clo + chunk).min(hi);
                        move || syrk_t_rows_into(a, clo, chi, slice)
                    })
                    .collect();
                pool.scope(jobs);
            }
            _ => syrk_t_rows_into(a, lo, hi, band.as_mut_slice()),
        }
        // Diagonal block's strictly-lower part: mirror within the band
        // (the source rows above are final once the recurrence is done).
        for r in 1..rows {
            for j in lo..(lo + r) {
                band[(r, j)] = band[(j - lo, lo + r)];
            }
        }
        store.write_panel(t, band)?;
    }
    Ok(())
}

/// The spilled lower Cholesky factor: panels of `L` living in the
/// [`PanelStore`] that [`chol_spill`] consumed and factored in place.
#[derive(Debug)]
pub struct SpilledCholesky {
    store: PanelStore,
}

/// Left-looking, panel-at-a-time blocked Cholesky over a [`PanelStore`]
/// holding the SPD matrix `A` (lower triangle + diagonal are read; the
/// upper triangle is ignored and comes back zeroed, exactly like
/// [`Cholesky::factor_into`]). Panels are factored in place and written
/// back — the full `N×N` never exists in RAM.
///
/// **Bitwise-identical** to [`Cholesky::factor`] /
/// [`Cholesky::factor_into`] for any tile height or pool size: each
/// element keeps the serial recurrence's exact arithmetic (one
/// full-prefix [`dot`] against final `L` values, one subtract, one
/// divide), and the relative pivot floor is computed from the same
/// original diagonal (cached `O(N)` by the store at write time — no extra
/// panel pass). The left-looking
/// update of a panel against each previously factored panel fans its rows
/// out over `pool` (rows are independent; per-element arithmetic is
/// untouched).
///
/// ```
/// use fastcv::linalg::{chol_spill, syrk_t, Cholesky, Mat, PanelStore};
/// use fastcv::util::rng::Rng;
///
/// let mut rng = Rng::new(7);
/// let a = Mat::from_fn(12, 9, |_, _| rng.gauss());
/// let mut g = syrk_t(&a);
/// for i in 0..9 {
///     g[(i, i)] += 0.5;
/// }
/// let mut store = PanelStore::new(9, 4, None).unwrap();
/// store.write_mat(&g).unwrap();
/// let spilled = chol_spill(store, None).unwrap();
/// let serial = Cholesky::factor(&g).unwrap();
/// assert_eq!(spilled.store().to_mat().unwrap().as_slice(), serial.l().as_slice());
/// ```
pub fn chol_spill(mut store: PanelStore, pool: Option<&ThreadPool>) -> Result<SpilledCholesky> {
    let floor = pivot_floor(&store, 0.0, false);
    for t in 0..store.panels() {
        let (lo, hi) = store.range(t);
        let mut w = store.read_panel(t)?;
        // Left-looking: apply every previously factored panel (in place,
        // panels < t already hold final L rows), then the diagonal block.
        for u in 0..t {
            let (lo_u, hi_u) = store.range(u);
            let lu = store.panel_cow(u)?;
            left_looking_update(&mut w, &lu, lo_u, hi_u, pool);
        }
        factor_diagonal_block(&mut w, lo, hi, floor)?;
        store.write_panel(t, w)?;
    }
    Ok(SpilledCholesky { store })
}

/// Relative pivot floor `1e-10·max|A_ii + ridge|` over a store's diagonal
/// (the exact floor the in-RAM [`Cholesky::factor`] computes after the
/// caller's `+= λ` loop; `skip_last` mirrors the primal unpenalised
/// intercept). Reads the `O(N)` diagonal the store caches at write time —
/// no panel IO. Shared by both spilled factorisations.
fn pivot_floor(store: &PanelStore, ridge: f64, skip_last: bool) -> f64 {
    let last = store.n().saturating_sub(1);
    let mut max_diag = 0.0f64;
    for (i, &v) in store.diag.iter().enumerate() {
        let mut d = v;
        if ridge != 0.0 && !(skip_last && i == last) {
            d += ridge;
        }
        max_diag = max_diag.max(d.abs());
    }
    1e-10 * max_diag
}

/// One left-looking update of working panel `w` against an already
/// factored panel (`lu` = rows `[lo_u, hi_u)` of `L`, flat row-major):
/// for each of its columns `j`, `w[r, j] = (w[r, j] −
/// dot(w[r, ..j], L[j, ..j])) / L[j, j]` — the serial recurrence's exact
/// per-element arithmetic. Rows of `w` are independent (each consumes
/// only its own prefix plus `lu`'s final rows), so they fan out over
/// `pool` in row chunks. Shared by [`chol_spill`] / [`chol_spill_ridged`].
fn left_looking_update(
    w: &mut Mat,
    lu: &[f64],
    lo_u: usize,
    hi_u: usize,
    pool: Option<&ThreadPool>,
) {
    let n = w.cols();
    let rows = w.rows();
    let update_rows = |w_rows: &mut [f64]| {
        for row_w in w_rows.chunks_mut(n) {
            for j in lo_u..hi_u {
                let lrow = &lu[(j - lo_u) * n..(j - lo_u + 1) * n];
                let s = row_w[j] - dot(&row_w[..j], &lrow[..j]);
                row_w[j] = s / lrow[j];
            }
        }
    };
    match pool {
        Some(pool) if pool.size() > 1 && rows >= 2 => {
            let chunk = rows.div_ceil(pool.size() * 2).max(1);
            let update_rows = &update_rows;
            let jobs: Vec<_> = w
                .as_mut_slice()
                .chunks_mut(chunk * n)
                .map(|w_rows| move || update_rows(w_rows))
                .collect();
            pool.scope(jobs);
        }
        _ => update_rows(w.as_mut_slice()),
    }
}

/// Factor the diagonal block of working panel `w` (global rows `[lo, hi)`)
/// with the serial recurrence — rows and columns both panel-local,
/// prefixes final — then zero the panel's upper triangle so the gathered
/// factor is exactly [`Cholesky::factor`]'s `L`. Shared tail of both
/// spilled factorisations.
fn factor_diagonal_block(w: &mut Mat, lo: usize, hi: usize, floor: f64) -> Result<()> {
    let rows = hi - lo;
    for j in lo..hi {
        let r_j = j - lo;
        let d = w[(r_j, j)] - dot(&w.row(r_j)[..j], &w.row(r_j)[..j]);
        if d <= floor || !d.is_finite() {
            bail!("matrix not positive definite at pivot {j} (d={d})");
        }
        let d = d.sqrt();
        w[(r_j, j)] = d;
        for r in (r_j + 1)..rows {
            let s = w[(r, j)] - dot(&w.row(r)[..j], &w.row(r_j)[..j]);
            w[(r, j)] = s / d;
        }
    }
    for r in 0..rows {
        let i = lo + r;
        w.row_mut(r)[(i + 1)..].fill(0.0);
    }
    Ok(())
}

/// [`chol_spill`] of `src + ridge·diag` **without materialising the ridged
/// copy**: each `A` panel is loaded once from the λ-free `src` store with
/// the ridge folded onto its diagonal at load time — the identical `+= λ`
/// float op the in-RAM paths apply to their dense Gram — and the factored
/// panels stream into a fresh store under `dir`. `skip_last` leaves the
/// final diagonal entry unridged (the primal Gram's unpenalised-intercept
/// convention, `λI₀`). This is the per-λ-candidate factor of the spilled
/// [`crate::fastcv::hat::GramCache`] arms: `src` stays intact for the next
/// candidate (and for the dual RHS), and no intermediate ridged store is
/// ever written and re-read. Bitwise-identical to ridging the dense Gram
/// and calling [`Cholesky::factor`].
pub fn chol_spill_ridged(
    src: &PanelStore,
    ridge: f64,
    skip_last: bool,
    dir: Option<&Path>,
    pool: Option<&ThreadPool>,
) -> Result<SpilledCholesky> {
    let n = src.n();
    let last = n.saturating_sub(1);
    let mut dest =
        PanelStore::new(n, src.tile(), dir).context("creating the spilled-factor store")?;
    let floor = pivot_floor(src, ridge, skip_last);
    for t in 0..src.panels() {
        let (lo, hi) = src.range(t);
        // Load the A panel once, folding the ridge onto its diagonal — the
        // identical `+= λ` float op the in-RAM paths apply to their dense
        // Gram.
        let mut w = src.read_panel(t)?;
        if ridge != 0.0 {
            for r in 0..(hi - lo) {
                let i = lo + r;
                if !(skip_last && i == last) {
                    w[(r, i)] += ridge;
                }
            }
        }
        // Left-looking updates read the factored panels from `dest`; the
        // arithmetic is chol_spill's (and hence Cholesky::factor's) exactly.
        for u in 0..t {
            let (lo_u, hi_u) = dest.range(u);
            let lu = dest.panel_cow(u)?;
            left_looking_update(&mut w, &lu, lo_u, hi_u, pool);
        }
        factor_diagonal_block(&mut w, lo, hi, floor)?;
        dest.write_panel(t, w)?;
    }
    Ok(SpilledCholesky { store: dest })
}

impl SpilledCholesky {
    /// Dimension.
    pub fn n(&self) -> usize {
        self.store.n()
    }

    /// The factor's panel store (panel `t` holds rows `[lo, hi)` of `L`).
    pub fn store(&self) -> &PanelStore {
        &self.store
    }

    /// Consume into the underlying store.
    pub fn into_store(self) -> PanelStore {
        self.store
    }

    /// Solve `A X = B` overwriting `x` in place, streaming factor panels —
    /// **bitwise-identical** to [`Cholesky::solve_mat_in_place`] (same
    /// subtraction sequence per row, same zero-skip, same divides).
    ///
    /// Forward substitution consumes row prefixes: one ascending pass over
    /// the panels. Backward substitution consumes *columns* of `L`, so per
    /// target panel (descending) the needed `(N−lo)×tile` column strip is
    /// gathered from panels `t..T` — ≈`T/2` re-reads of the factor, the IO
    /// price of row-slab panels; residency stays `O(tile·N)`.
    pub fn solve_mat_in_place(&self, x: &mut Mat) -> Result<()> {
        let n = self.n();
        assert_eq!(x.rows(), n, "solve RHS row mismatch");
        let nrhs = x.cols();
        let t_count = self.store.panels();
        let kr = super::dispatch::active_kernels();
        // forward: L Y = B
        for t in 0..t_count {
            let (lo, hi) = self.store.range(t);
            let lp = self.store.panel_cow(t)?;
            for i in lo..hi {
                let lrow = &lp[(i - lo) * n..(i - lo + 1) * n];
                for (k, &lik) in lrow[..i].iter().enumerate() {
                    if lik == 0.0 {
                        continue;
                    }
                    let (head, tail) = x.as_mut_slice().split_at_mut(i * nrhs);
                    let xk = &head[k * nrhs..(k + 1) * nrhs];
                    let xi = &mut tail[..nrhs];
                    (kr.axpy_sub)(xi, lik, xk);
                }
                let d = lrow[i];
                for v in x.row_mut(i) {
                    *v /= d;
                }
            }
        }
        // backward: Lᵀ X = Y — target panels descending; per target panel
        // gather the column strip L[lo.., lo..hi] from panels t..T, then
        // run the serial row loop against the strip.
        for t in (0..t_count).rev() {
            let (lo, hi) = self.store.range(t);
            let width = hi - lo;
            let mut strip = Mat::zeros(n - lo, width);
            for u in t..t_count {
                let (lo_u, hi_u) = self.store.range(u);
                let lp = self.store.panel_cow(u)?;
                for k in lo_u..hi_u {
                    strip
                        .row_mut(k - lo)
                        .copy_from_slice(&lp[(k - lo_u) * n + lo..(k - lo_u) * n + hi]);
                }
            }
            for i in (lo..hi).rev() {
                let ci = i - lo;
                for k in (i + 1)..n {
                    let lki = strip[(k - lo, ci)];
                    if lki == 0.0 {
                        continue;
                    }
                    let (head, tail) = x.as_mut_slice().split_at_mut(k * nrhs);
                    let xi = &mut head[i * nrhs..(i + 1) * nrhs];
                    let xk = &tail[..nrhs];
                    (kr.axpy_sub)(xi, lki, xk);
                }
                let d = strip[(ci, ci)];
                for v in x.row_mut(i) {
                    *v /= d;
                }
            }
        }
        Ok(())
    }

    /// [`SpilledCholesky::solve_mat_in_place`] on a copy of the RHS.
    pub fn solve_mat(&self, b: &Mat) -> Result<Mat> {
        let mut x = b.clone();
        self.solve_mat_in_place(&mut x)?;
        Ok(x)
    }

    /// Gather the factor into an in-RAM [`Cholesky`] (tests / callers that
    /// decide the factor fits after all).
    pub fn to_cholesky(&self) -> Result<Cholesky> {
        Ok(Cholesky::from_lower(self.store.to_mat()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::syrk_t;
    use crate::linalg::tiled::gram_tiled;
    use crate::util::rng::Rng;

    fn spd(rng: &mut Rng, n: usize) -> Mat {
        let a = Mat::from_fn(n + 3, n, |_, _| rng.gauss());
        let mut g = syrk_t(&a);
        for i in 0..n {
            g[(i, i)] += 0.5;
        }
        g
    }

    fn temp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("fastcv-spill-test-{tag}-{}", std::process::id()))
    }

    #[test]
    fn spill_panel_store_ram_roundtrip() {
        let g = Mat::from_fn(7, 7, |i, j| (i * 7 + j) as f64);
        let mut store = PanelStore::new(7, 3, None).unwrap();
        assert_eq!(store.panels(), 3);
        assert_eq!(store.range(2), (6, 7));
        assert!(!store.is_disk());
        assert!(store.panel_path(0).is_none());
        // reading before writing is an error, not garbage
        assert!(store.read_panel(1).is_err());
        store.write_mat(&g).unwrap();
        assert_eq!(store.to_mat().unwrap().as_slice(), g.as_slice());
        // RAM panels are borrowed, not copied, on read-only access
        assert!(matches!(store.panel_cow(0).unwrap(), std::borrow::Cow::Borrowed(_)));
        assert_eq!(&*store.panel_cow(1).unwrap(), store.read_panel(1).unwrap().as_slice());
        // take_square is a pure gather
        let idx = [0usize, 2, 5, 6];
        assert_eq!(
            store.take_square(&idx).unwrap().as_slice(),
            g.take(&idx, &idx).as_slice()
        );
    }

    #[test]
    fn spill_panel_store_disk_roundtrip_and_cleanup() {
        let base = temp_dir("roundtrip");
        let g = Mat::from_fn(9, 9, |i, j| (i as f64) - 0.5 * j as f64);
        let panel0;
        {
            let mut store = PanelStore::new(9, 4, Some(&base)).unwrap();
            assert!(store.is_disk());
            store.write_mat(&g).unwrap();
            panel0 = store.panel_path(0).unwrap();
            assert!(panel0.exists(), "panel file must exist after write");
            assert_eq!(store.to_mat().unwrap().as_slice(), g.as_slice());
            // disk panels come back owned (read from the file)
            assert!(matches!(store.panel_cow(0).unwrap(), std::borrow::Cow::Owned(_)));
            let idx = [1usize, 4, 8];
            assert_eq!(
                store.take_square(&idx).unwrap().as_slice(),
                g.take(&idx, &idx).as_slice()
            );
        }
        // drop removed the store-private subdirectory
        assert!(!panel0.exists(), "dropped store must clean its panels up");
        let _ = std::fs::remove_dir_all(&base);
    }

    #[test]
    fn spill_torn_panel_file_is_detected() {
        // Crash safety: a partially written panel must be *detected* by the
        // length check, not silently read as a shorter matrix.
        let base = temp_dir("torn");
        let g = Mat::from_fn(6, 6, |i, j| (i + j) as f64);
        let mut store = PanelStore::new(6, 4, Some(&base)).unwrap();
        store.write_mat(&g).unwrap();
        let path = store.panel_path(1).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 5]).unwrap(); // tear it
        let err = store.read_panel(1).err().expect("torn panel must error");
        assert!(format!("{err:#}").contains("torn panel file"), "{err:#}");
        // the intact panel still reads fine
        assert!(store.read_panel(0).is_ok());
        drop(store);
        let _ = std::fs::remove_dir_all(&base);
    }

    #[test]
    fn spill_corrupt_panel_is_detected_by_the_checksum_footer() {
        // Bit rot keeps the file length intact, so only the FNV footer can
        // catch it — and it must surface as the typed SpillError::Corrupt.
        let base = temp_dir("corrupt");
        let g = Mat::from_fn(6, 6, |i, j| (i + 2 * j) as f64);
        let mut store = PanelStore::new(6, 4, Some(&base)).unwrap();
        store.write_mat(&g).unwrap();
        let path = store.panel_path(0).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[3] ^= 0x40; // flip one payload bit
        std::fs::write(&path, &bytes).unwrap();
        let err = store.read_panel(0).err().expect("corrupt panel must error");
        assert!(format!("{err:#}").contains("corrupt panel file"), "{err:#}");
        assert!(
            matches!(err.downcast_ref::<SpillError>(), Some(SpillError::Corrupt { .. })),
            "recovery layers need the typed variant: {err:#}"
        );
        assert!(store.verify().is_err(), "verify must sweep up the corruption");
        assert!(store.read_panel(1).is_ok(), "the intact panel still reads");
        drop(store);
        let _ = std::fs::remove_dir_all(&base);
    }

    #[test]
    fn spill_writes_publish_atomically_and_leave_no_temp_files() {
        let base = temp_dir("atomic");
        let g = Mat::from_fn(9, 9, |i, j| (i * 9 + j) as f64 * 0.25);
        let mut store = PanelStore::new(9, 4, Some(&base)).unwrap();
        store.write_mat(&g).unwrap();
        let dir = store.panel_path(0).unwrap().parent().unwrap().to_path_buf();
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok()?.file_name().into_string().ok())
            .filter(|n| n.ends_with(".tmp"))
            .collect();
        assert!(leftovers.is_empty(), "temp files must be renamed away: {leftovers:?}");
        // overwriting a panel goes through the same temp-then-rename and
        // the store stays fully verifiable
        store.write_mat(&g).unwrap();
        store.verify().unwrap();
        assert_eq!(store.to_mat().unwrap().as_slice(), g.as_slice());
        drop(store);
        let _ = std::fs::remove_dir_all(&base);
    }

    #[test]
    fn chaos_spill_fault_sites_fire_torn_and_io() {
        // The injected faults must produce exactly the failures the
        // detection layer is built for: write.io → typed Io error,
        // write.torn → a short final file the next read rejects as Torn.
        let base = temp_dir("faults");
        let g = Mat::from_fn(6, 6, |i, j| (i * 6 + j) as f64);
        let mut store = PanelStore::new(6, 6, Some(&base)).unwrap();
        {
            let _scope = crate::fastcv::fault::install(
                crate::fastcv::fault::FaultPlan::parse("spill.write.io@1").unwrap(),
            );
            let err = store.write_mat(&g).err().expect("injected IO fault must error");
            assert!(
                matches!(err.downcast_ref::<SpillError>(), Some(SpillError::Io { .. })),
                "{err:#}"
            );
            // second write: the @1 rule is spent, the write succeeds
            store.write_mat(&g).unwrap();
        }
        {
            let _scope = crate::fastcv::fault::install(
                crate::fastcv::fault::FaultPlan::parse("spill.write.torn@1=13").unwrap(),
            );
            store.write_mat(&g).unwrap(); // "succeeds" — the crash is silent
            let err = store.read_panel(0).err().expect("torn write must be detected");
            assert!(
                matches!(err.downcast_ref::<SpillError>(), Some(SpillError::Torn { .. })),
                "{err:#}"
            );
            // recovery: rewrite the panel, read back bitwise intact
            store.write_mat(&g).unwrap();
            assert_eq!(store.to_mat().unwrap().as_slice(), g.as_slice());
        }
        drop(store);
        let _ = std::fs::remove_dir_all(&base);
    }

    #[test]
    fn spill_open_quarantines_bad_panels_and_serves_good_ones() {
        let base = temp_dir("open");
        let g = Mat::from_fn(7, 7, |i, j| (i * 7 + j) as f64);
        let mut store = PanelStore::new(7, 3, Some(&base)).unwrap();
        store.write_mat(&g).unwrap();
        let dir = store.panel_path(0).unwrap().parent().unwrap().to_path_buf();
        // sabotage: tear panel 1, plant an orphaned temp file and an
        // out-of-range panel
        let p1 = store.panel_path(1).unwrap();
        let bytes = std::fs::read(&p1).unwrap();
        std::fs::write(&p1, &bytes[..bytes.len() - 3]).unwrap();
        std::fs::write(dir.join("panel_0.tmp"), b"half a write").unwrap();
        std::fs::write(dir.join("panel_9.bin"), b"orphan").unwrap();
        std::mem::forget(store); // the "crashed process" never ran Drop
        let (reopened, quarantined) = PanelStore::open(7, 3, &dir).unwrap();
        assert_eq!(quarantined, 3, "torn panel + temp + orphan");
        for name in ["panel_1.bin", "panel_0.tmp", "panel_9.bin"] {
            assert!(dir.join("quarantine").join(name).exists(), "{name} must be preserved");
        }
        // surviving panels serve bitwise, and their diagonal was rebuilt
        let p0 = reopened.read_panel(0).unwrap();
        assert_eq!(p0.as_slice(), g.rows_slice(0, 3));
        assert_eq!(reopened.read_panel(2).unwrap().as_slice(), g.rows_slice(6, 7));
        assert!(reopened.read_panel(1).is_err(), "the torn panel is gone, not served");
        assert_eq!(reopened.diag[0], g[(0, 0)]);
        assert_eq!(reopened.diag[6], g[(6, 6)]);
        drop(reopened);
        let _ = std::fs::remove_dir_all(&base);
    }

    #[test]
    fn spill_quarantine_orphans_sweeps_foreign_stores_only() {
        let base = temp_dir("orphans");
        // fake pids far above any real one, so they can never collide with
        // this process's own `store-{pid}-` prefix
        std::fs::create_dir_all(base.join("store-909090901-0")).unwrap();
        std::fs::write(base.join("store-909090901-0").join("panel_0.bin"), b"junk").unwrap();
        std::fs::create_dir_all(base.join("store-909090902-5")).unwrap();
        // a live store of *this* process must not be touched
        let mut live = PanelStore::new(4, 2, Some(&base)).unwrap();
        live.write_mat(&Mat::from_fn(4, 4, |i, j| (i + j) as f64)).unwrap();
        let moved = quarantine_orphans(&base).unwrap();
        assert_eq!(moved, 2, "both foreign stores swept");
        assert!(base
            .join("quarantine")
            .join("store-909090901-0")
            .join("panel_0.bin")
            .exists());
        assert!(!base.join("store-909090902-5").exists());
        live.verify().unwrap();
        assert_eq!(quarantine_orphans(&base).unwrap(), 0, "second sweep finds nothing");
        // a missing dir is a no-op, not an error
        assert_eq!(quarantine_orphans(&base.join("nope")).unwrap(), 0);
        drop(live);
        let _ = std::fs::remove_dir_all(&base);
    }

    #[test]
    fn spill_chol_bitwise_matches_factor_across_tiles() {
        // Acceptance: the spilled factor equals Cholesky::factor to the
        // last bit across tile heights {1, 7, N, N+3} (remainder panels
        // included), serial and pooled.
        let mut rng = Rng::new(31);
        let pool = ThreadPool::new(4);
        for n in [5usize, 23, 40] {
            let g = spd(&mut rng, n);
            let serial = Cholesky::factor(&g).unwrap();
            for tile in [1usize, 7, n, n + 3] {
                for pool_opt in [None, Some(&pool)] {
                    let mut store = PanelStore::new(n, tile, None).unwrap();
                    store.write_mat(&g).unwrap();
                    let spilled = chol_spill(store, pool_opt).unwrap();
                    assert_eq!(
                        spilled.store().to_mat().unwrap().as_slice(),
                        serial.l().as_slice(),
                        "n={n} tile={tile} pooled={}",
                        pool_opt.is_some()
                    );
                }
            }
        }
    }

    #[test]
    fn spill_solves_bitwise_match_in_ram() {
        let mut rng = Rng::new(32);
        let pool = ThreadPool::new(3);
        for n in [6usize, 19, 30] {
            let g = spd(&mut rng, n);
            let serial = Cholesky::factor(&g).unwrap();
            let b = Mat::from_fn(n, 5, |_, _| rng.gauss());
            let mut expect = b.clone();
            serial.solve_mat_in_place(&mut expect);
            for tile in [1usize, 7, n, n + 3] {
                let mut store = PanelStore::new(n, tile, None).unwrap();
                store.write_mat(&g).unwrap();
                let spilled = chol_spill(store, Some(&pool)).unwrap();
                let mut x = b.clone();
                spilled.solve_mat_in_place(&mut x).unwrap();
                assert_eq!(x.as_slice(), expect.as_slice(), "n={n} tile={tile}");
                // solve_mat and to_cholesky agree too
                assert_eq!(
                    spilled.solve_mat(&b).unwrap().as_slice(),
                    expect.as_slice()
                );
                assert_eq!(
                    spilled.to_cholesky().unwrap().l().as_slice(),
                    serial.l().as_slice()
                );
            }
        }
    }

    #[test]
    fn spill_chol_ridged_bitwise_matches_factor_of_ridged_gram() {
        // The per-λ-candidate factor: ridge folded onto the diagonal at
        // panel load (λ-free source store untouched) must equal ridging
        // the dense Gram then Cholesky::factor, bitwise — both diagonal
        // conventions, RAM and disk destinations, serial and pooled.
        let mut rng = Rng::new(36);
        let pool = ThreadPool::new(3);
        let base = temp_dir("ridged");
        for n in [6usize, 19, 31] {
            // A PSD-but-unridged Gram of a wide matrix: singular without λ,
            // SPD once ridged — exactly the per-candidate situation.
            let a = Mat::from_fn(n.div_ceil(2), n, |_, _| rng.gauss());
            let g0 = crate::linalg::gemm::syrk_t(&a);
            for tile in [1usize, 7, n, n + 3] {
                let mut src = PanelStore::new(n, tile, None).unwrap();
                src.write_mat(&g0).unwrap();
                for &(lambda, skip_last) in &[(0.7, false), (2.5, true)] {
                    let mut ridged = g0.clone();
                    let cut = if skip_last { n - 1 } else { n };
                    for i in 0..cut {
                        ridged[(i, i)] += lambda;
                    }
                    let serial = match Cholesky::factor(&ridged) {
                        Ok(ch) => ch,
                        // skip_last leaves the unridged corner: the dense
                        // factor can legitimately reject; so must we.
                        Err(_) => {
                            assert!(
                                chol_spill_ridged(&src, lambda, skip_last, None, None).is_err(),
                                "dense factor rejected but spilled accepted (n={n})"
                            );
                            continue;
                        }
                    };
                    for dir in [None, Some(base.as_path())] {
                        let spilled =
                            chol_spill_ridged(&src, lambda, skip_last, dir, Some(&pool)).unwrap();
                        assert_eq!(
                            spilled.store().to_mat().unwrap().as_slice(),
                            serial.l().as_slice(),
                            "n={n} tile={tile} λ={lambda} skip_last={skip_last}"
                        );
                    }
                    // the λ-free source store is untouched
                    assert_eq!(src.to_mat().unwrap().as_slice(), g0.as_slice());
                }
                // unridged + singular must fail cleanly
                assert!(chol_spill_ridged(&src, 0.0, false, None, None).is_err());
            }
        }
        let _ = std::fs::remove_dir_all(&base);
    }

    #[test]
    fn spill_chol_rejects_indefinite() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eigenvalues 3, -1
        let mut store = PanelStore::new(2, 1, None).unwrap();
        store.write_mat(&a).unwrap();
        let err = chol_spill(store, None).err().expect("indefinite must fail");
        assert!(format!("{err:#}").contains("not positive definite"), "{err:#}");
    }

    #[test]
    fn spill_gram_spill_bitwise_matches_gram_tiled() {
        // gram_spill's panels (upper blocks by GEMM + lower blocks
        // mirror-copied from the already-written panels) must equal
        // gram_tiled's (upper blocks + in-RAM mirror) to the last bit,
        // with and without ridge, serial and pooled.
        let mut rng = Rng::new(33);
        let pool = ThreadPool::new(4);
        for &(n, p) in &[(13usize, 40usize), (24, 7)] {
            let v = Mat::from_fn(n, p, |_, _| rng.gauss());
            for tile in [1usize, 7, n, n + 3] {
                let slab = |lo: usize, hi: usize| {
                    Mat::from_fn(hi - lo, p, |r, j| v[(lo + r, j)])
                };
                let mut reference = gram_tiled(n, tile, slab, None);
                for ridge in [0.0, 0.8] {
                    if ridge != 0.0 {
                        for i in 0..n {
                            reference[(i, i)] += ridge;
                        }
                    }
                    for pool_opt in [None, Some(&pool)] {
                        let mut store = PanelStore::new(n, tile, None).unwrap();
                        gram_spill(&mut store, ridge, slab, pool_opt).unwrap();
                        assert_eq!(
                            store.to_mat().unwrap().as_slice(),
                            reference.as_slice(),
                            "n={n} p={p} tile={tile} ridge={ridge}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn spill_disk_chol_end_to_end() {
        // Disk-backed store through assembly, factorisation, and solve.
        let base = temp_dir("chol");
        let mut rng = Rng::new(34);
        let n = 17;
        let g = spd(&mut rng, n);
        let serial = Cholesky::factor(&g).unwrap();
        let mut store = PanelStore::new(n, 5, Some(&base)).unwrap();
        store.write_mat(&g).unwrap();
        let spilled = chol_spill(store, None).unwrap();
        assert_eq!(spilled.store().to_mat().unwrap().as_slice(), serial.l().as_slice());
        let b = Mat::from_fn(n, 3, |_, _| rng.gauss());
        let mut expect = b.clone();
        serial.solve_mat_in_place(&mut expect);
        let mut x = b.clone();
        spilled.solve_mat_in_place(&mut x).unwrap();
        assert_eq!(x.as_slice(), expect.as_slice());
        drop(spilled);
        let _ = std::fs::remove_dir_all(&base);
    }

    #[test]
    fn spill_syrk_spill_bitwise_matches_syrk_t() {
        let mut rng = Rng::new(35);
        let pool = ThreadPool::new(4);
        for &(n, p) in &[(20usize, 9usize), (8, 26)] {
            let mut a = Mat::from_fn(n, p, |_, _| rng.gauss());
            // sprinkle exact zeros so the skip branches are exercised
            for i in 0..n {
                for j in 0..p {
                    if (i + j) % 5 == 0 {
                        a[(i, j)] = 0.0;
                    }
                }
            }
            let reference = syrk_t(&a);
            for tile in [1usize, 7, p, p + 3] {
                for pool_opt in [None, Some(&pool)] {
                    let mut store = PanelStore::new(p, tile, None).unwrap();
                    syrk_spill(&mut store, &a, pool_opt).unwrap();
                    assert_eq!(
                        store.to_mat().unwrap().as_slice(),
                        reference.as_slice(),
                        "n={n} p={p} tile={tile} pooled={}",
                        pool_opt.is_some()
                    );
                }
            }
        }
    }
}
