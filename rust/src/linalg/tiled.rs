//! Panel-tiled Gram construction and the blocked Cholesky — the §4.5
//! "big data" memory-bounded build layer.
//!
//! The dual/spectral Gram backends ([`crate::fastcv::hat`]) and the dual
//! [`crate::fastcv::bigdata::StreamingHat`] all need the centered `N×N`
//! Gram `K_c = X_c X_cᵀ`. The one-shot build materialises the full
//! centered copy `X_c` (`O(NP)`) *and* transposes it (`O(NP)` again) before
//! a single `N×N×P` GEMM; in the P ≫ N **and** N-huge quadrant that is
//! exactly where memory runs out first. This module provides the blockwise
//! alternative (in the spirit of Engstrøm & Jensen 2024's partition-based
//! `XᵀX`/`XᵀY` formulation — blockwise with centering folded in is *exact*,
//! not approximate):
//!
//! * [`gram_tiled`] — `G = V Vᵀ` from row *slabs* of `V` produced on
//!   demand by a closure, so no more than three `tile×P` slabs (per
//!   worker: own band, partner band, partner's transposed copy) exist at
//!   once. Tile pairs of the upper triangle fan out over a
//!   [`ThreadPool`]; the lower triangle is mirrored.
//! * [`syrk_tiled`] — the primal sibling: `AᵀA` in `tile`-row output
//!   bands (bit-identical to [`crate::linalg::syrk_t`]), so the
//!   `(P+1)×(P+1)` primal quadrant gets the same slab treatment the dual
//!   side got.
//! * [`chol_blocked`] — panel-blocked Cholesky whose per-column
//!   subdiagonal updates fan out over the pool in `tile`-row chunks (see
//!   [`Cholesky::factor_blocked`]; an in-place variant,
//!   [`Cholesky::factor_into`], factors a Gram buffer without allocating a
//!   second `N×N`).
//! * [`TilePolicy`] — the knob the [`crate::fastcv::context::ComputeContext`]
//!   carries: `Off` reproduces the historical one-shot kernels bitwise,
//!   `Rows`/`Budget` pick a tile height (the latter from a transient-memory
//!   budget in bytes), and `Spill` routes the Gram *and its factor*
//!   through the out-of-core [`crate::linalg::spill`] layer (panels on
//!   disk or in RAM; nothing `N×N` ever resident).
//!
//! ## Bitwise determinism
//!
//! Tiling is a **pure memory/wall-clock knob**: every tiled kernel is
//! bit-identical to its one-shot counterpart (property-tested as the
//! `tiled_*` suite).
//!
//! * For [`gram_tiled`]: an output element `G[i,j] = Σ_k v_ik·v_jk`
//!   accumulates over the inner dimension in [`matmul`]'s fixed KC-block
//!   order, which is independent of how the *output* rows/columns are
//!   split into tiles (the same argument that makes
//!   [`crate::linalg::matmul_pool`] bit-identical to [`matmul`]). The
//!   mirrored lower triangle is exact because IEEE multiplication
//!   commutes: `G[j,i]` accumulates the identical products in the
//!   identical order, so `G[i,j] == G[j,i]` to the last bit — which also
//!   makes the one-shot path's trailing `symmetrize()` (`0.5·(a+a) = a`)
//!   a no-op on these values.
//! * For the blocked Cholesky: each element keeps the serial recurrence's
//!   exact arithmetic — a full-prefix [`crate::linalg::dot`] — so blocking
//!   governs *which thread* computes an element, never *how*. (A classical
//!   right-looking trailing-GEMM update would re-associate the sums and
//!   break bit-identity; the panel fan-out here parallelises the same
//!   recurrence instead.)

use super::chol::Cholesky;
use super::gemm::{matmul, mirror_upper, syrk_t_rows_into};
use super::mat::Mat;
use crate::util::threadpool::ThreadPool;
use anyhow::Result;
use std::path::{Path, PathBuf};

/// Default spill panel height when `--spill-dir` is given without an
/// explicit `--tile-rows`.
const DEFAULT_SPILL_TILE: usize = 256;

/// How (whether) to tile the `N×N` Gram builds and their Cholesky.
///
/// Carried by [`crate::fastcv::context::ComputeContext`] and surfaced on
/// the CLI as `--tile-rows R` / `--mem-budget MB` / `--spill-dir PATH`.
/// `Off` (the default) reproduces the historical one-shot kernels bitwise;
/// the tiled modes are bit-identical to them (see the module docs) but
/// bound every transient slab to `O(tile)` rows, and `Spill` goes further:
/// the Gram and its Cholesky factor live as
/// [`PanelStore`](crate::linalg::spill::PanelStore) panels (RAM or disk)
/// and never coexist in RAM (the [`crate::linalg::spill`] layer — still
/// bitwise, property-tested as the `spill_*` suite).
///
/// ```
/// use fastcv::fastcv::bigdata::StreamingHat;
/// use fastcv::fastcv::{ComputeContext, GramBackend};
/// use fastcv::linalg::{Mat, TilePolicy};
/// use fastcv::util::rng::Rng;
///
/// let mut rng = Rng::new(3);
/// let x = Mat::from_fn(20, 60, |_, _| rng.gauss());   // P ≫ N
/// let ctx = ComputeContext::serial()
///     .with_backend(GramBackend::Dual)
///     // RAM panels; pass `dir: Some(path)` to spill them to disk
///     .with_tile_policy(TilePolicy::Spill { dir: None, tile: 8 });
/// let hat = StreamingHat::build_ctx(&x, 0.5, &ctx).unwrap();
/// assert_eq!(hat.t.shape(), (20, 60));                // K_c never lived in RAM
/// ```
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub enum TilePolicy {
    /// No tiling: the historical one-shot kernels, bitwise-unchanged.
    #[default]
    Off,
    /// Fixed tile height in rows (clamped to `[1, N]` per build).
    Rows(usize),
    /// Pick the tile height from a transient-memory budget in bytes: the
    /// largest `tile` such that one worker's slabs — the `tile×P` row slab
    /// for its own band, the partner band's slab **plus its `P×tile`
    /// transposed copy** (the GEMM's B operand), and a `tile×N` output
    /// strip — fit the budget.
    Budget {
        /// Transient budget in bytes (per concurrent worker).
        bytes: usize,
    },
    /// Out-of-core: Gram panels (and the Cholesky factor's) are persisted
    /// in a [`PanelStore`](crate::linalg::spill::PanelStore) and streamed
    /// through the left-looking [`crate::linalg::spill::chol_spill`], so
    /// the `N×N` (or primal `(P+1)×(P+1)`) square never coexists in RAM.
    /// Bitwise-identical to the one-shot builds, like every other mode.
    Spill {
        /// `Some(dir)` writes panels as files under `dir` (the CLI's
        /// `--spill-dir`); `None` keeps panels as RAM buffers — the
        /// blocked out-of-core *schedule* without the disk IO.
        dir: Option<PathBuf>,
        /// Panel height in rows (clamped to `[1, N]` per build).
        tile: usize,
    },
}

impl TilePolicy {
    /// Build from the CLI knobs: `--spill-dir` selects the out-of-core
    /// mode (panel height from `--tile-rows`, else a 256-row default);
    /// otherwise `--tile-rows R` wins when both remaining knobs are given,
    /// `--mem-budget MB` (mebibytes) next, `Off` when none.
    pub fn from_cli(tile_rows: usize, mem_budget_mb: usize, spill_dir: Option<&str>) -> TilePolicy {
        if let Some(dir) = spill_dir {
            let tile = if tile_rows > 0 { tile_rows } else { DEFAULT_SPILL_TILE };
            TilePolicy::Spill { dir: Some(PathBuf::from(dir)), tile }
        } else if tile_rows > 0 {
            TilePolicy::Rows(tile_rows)
        } else if mem_budget_mb > 0 {
            TilePolicy::Budget { bytes: mem_budget_mb << 20 }
        } else {
            TilePolicy::Off
        }
    }

    /// Is this the bitwise-historical no-tiling mode?
    pub fn is_off(&self) -> bool {
        matches!(self, TilePolicy::Off)
    }

    /// The spill parameters `(dir, tile)` when this is the out-of-core
    /// mode — builders check this *before* [`TilePolicy::tile_rows`], which
    /// treats `Spill` as a plain in-RAM tiling for consumers that have no
    /// spilled form (the spectral eigendecomposition, say).
    pub fn spill(&self) -> Option<(Option<&Path>, usize)> {
        match self {
            TilePolicy::Spill { dir, tile } => Some((dir.as_deref(), *tile)),
            _ => None,
        }
    }

    /// Resolve the tile height for an `N×P` build: `None` when off,
    /// otherwise a height in `[1, N]`.
    pub fn tile_rows(&self, n: usize, p: usize) -> Option<usize> {
        match self {
            TilePolicy::Off => None,
            TilePolicy::Rows(t) => Some((*t).clamp(1, n.max(1))),
            TilePolicy::Budget { bytes } => {
                // Three tile×P slabs live at once inside a worker (own band,
                // partner band, partner's transposed copy) plus the tile×N
                // output strip — see `fill_upper_band`.
                let per_row = 8 * (3 * p + n).max(1);
                Some((bytes / per_row).clamp(1, n.max(1)))
            }
            TilePolicy::Spill { tile, .. } => Some((*tile).clamp(1, n.max(1))),
        }
    }

    /// Short tag for labels / TSV columns (`off`, `tile-r64`, `tile-b256m`,
    /// `spill-r256[-disk]`; sub-MiB budgets print in KiB so distinct
    /// budgets never collide on a `b0m` label).
    pub fn tag(&self) -> String {
        match self {
            TilePolicy::Off => "off".to_string(),
            TilePolicy::Rows(t) => format!("tile-r{t}"),
            TilePolicy::Budget { bytes } if *bytes >= (1 << 20) => {
                format!("tile-b{}m", bytes >> 20)
            }
            TilePolicy::Budget { bytes } => format!("tile-b{}k", bytes >> 10),
            TilePolicy::Spill { dir: None, tile } => format!("spill-r{tile}"),
            TilePolicy::Spill { dir: Some(_), tile } => format!("spill-r{tile}-disk"),
        }
    }
}

/// `G = V Vᵀ` (`N×N`, symmetric) where rows `lo..hi` of `V` are produced on
/// demand by `slab(lo, hi)` — never materialising more than three
/// tile-high slabs (per worker) at once. `tile` is the slab height; tile
/// pairs of the upper triangle fan out over `pool` when given (each worker
/// owns disjoint row bands of the output), and the strictly-lower triangle
/// is mirrored.
///
/// Bit-identical to `matmul(&v, &v.t())` followed by `symmetrize()` for
/// any tile height, pool size, or slab split — see the module docs. The
/// centered Gram `K_c` (slab = centered rows of `X`) and the uncentered
/// nested-CV Gram `K = XXᵀ` (slab = raw rows) are the intended callers.
pub fn gram_tiled<F>(n: usize, tile: usize, slab: F, pool: Option<&ThreadPool>) -> Mat
where
    F: Fn(usize, usize) -> Mat + Sync,
{
    let tile = tile.clamp(1, n.max(1));
    let tiles: Vec<(usize, usize)> =
        (0..n).step_by(tile).map(|lo| (lo, (lo + tile).min(n))).collect();
    let mut out = Mat::zeros(n, n);
    match pool {
        Some(pool) if pool.size() > 1 && tiles.len() > 1 => {
            // Chunk the output into per-tile row bands (row-major ⇒ each
            // band is one contiguous slice) so jobs write without locks;
            // every band is tile·n elements except the remainder, which is
            // exactly how `tiles` was built. Upper-triangle bands have
            // skewed work (band t computes T−t blocks): when there are
            // enough bands to keep the pool busy, each job pairs band `t`
            // with band `T−1−t` so every pair owns T+1 blocks (balanced
            // instead of a 1..T staircase); with few bands, one job per
            // band maximises overlap (pairing T=2 bands into one job would
            // serialise the whole build on a single worker).
            let tiles_ref = &tiles;
            let slab_ref = &slab;
            let t_count = tiles.len();
            let pair = t_count.div_ceil(2) >= pool.size();
            let mut bands: Vec<Option<(usize, &mut [f64])>> =
                out.as_mut_slice().chunks_mut(tile * n).enumerate().map(Some).collect();
            let job_count = if pair { t_count.div_ceil(2) } else { t_count };
            let jobs: Vec<_> = (0..job_count)
                .map(|lo| {
                    // lint:allow(panic, reason = "each band index is taken exactly once per job build; a None here is a scheduler bug")
                    let (t_first, first) = bands[lo].take().expect("band consumed once");
                    let hi = t_count - 1 - lo;
                    let second = if pair && hi > lo { bands[hi].take() } else { None };
                    move || {
                        fill_upper_band(t_first, first, n, tiles_ref, slab_ref);
                        if let Some((t_second, band)) = second {
                            fill_upper_band(t_second, band, n, tiles_ref, slab_ref);
                        }
                    }
                })
                .collect();
            pool.scope(jobs);
        }
        _ => {
            for (t, &(lo, hi)) in tiles.iter().enumerate() {
                let band = &mut out.as_mut_slice()[lo * n..hi * n];
                fill_upper_band(t, band, n, &tiles, &slab);
            }
        }
    }
    // Mirror the strictly-lower blocks from the computed upper triangle
    // (exact: IEEE multiplication commutes, so G[j,i] == G[i,j] bitwise).
    for i in 0..n {
        for j in 0..i {
            out[(i, j)] = out[(j, i)];
        }
    }
    out
}

/// Fill row band `t` of the upper block triangle: blocks `(t, u)` for
/// `u ≥ t`. `band` is rows `tiles[t]` of the output as one flat slice.
fn fill_upper_band<F>(t: usize, band: &mut [f64], n: usize, tiles: &[(usize, usize)], slab: &F)
where
    F: Fn(usize, usize) -> Mat,
{
    let (lo_i, hi_i) = tiles[t];
    let v_i = slab(lo_i, hi_i);
    for (u, &(lo_j, hi_j)) in tiles.iter().enumerate().skip(t) {
        let block = if u == t {
            matmul(&v_i, &v_i.t())
        } else {
            let v_j = slab(lo_j, hi_j);
            matmul(&v_i, &v_j.t())
        };
        for r in 0..(hi_i - lo_i) {
            band[r * n + lo_j..r * n + hi_j].copy_from_slice(block.row(r));
        }
    }
}

/// `G = AᵀA` in `tile`-row output bands — the **tiled primal syrk**
/// (ROADMAP's `(P+1)`-huge-quadrant sibling of [`gram_tiled`]). Bands of
/// the upper block triangle are computed straight into disjoint row slabs
/// of the output (no per-band copies beyond the accumulator itself) and
/// fan out over `pool` with the same balanced head/tail pairing as
/// [`gram_tiled`] (leading bands own the long upper-triangle rows); the
/// strictly-lower triangle is mirrored.
///
/// Bit-identical to [`crate::linalg::syrk_t`] / `syrk_t_pool` for any tile
/// height, pool size, or remainder panel: every upper-triangle element
/// accumulates over the sample index in ascending order whichever band its
/// row lands in (the `syrk_t_rows` split-invariance), and the mirror is an
/// exact copy. The primal `G₀ = X̃ᵀX̃` build of
/// [`crate::fastcv::hat::GramCache`] routes here under a tiled
/// [`TilePolicy`]; the spilled form is
/// [`crate::linalg::spill::syrk_spill`].
pub fn syrk_tiled(a: &Mat, tile: usize, pool: Option<&ThreadPool>) -> Mat {
    let p = a.cols();
    let tile = tile.clamp(1, p.max(1));
    let tiles: Vec<(usize, usize)> =
        (0..p).step_by(tile).map(|lo| (lo, (lo + tile).min(p))).collect();
    let mut g = Mat::zeros(p, p);
    match pool {
        Some(pool) if pool.size() > 1 && tiles.len() > 1 => {
            let tiles_ref = &tiles;
            let t_count = tiles.len();
            let pair = t_count.div_ceil(2) >= pool.size();
            let mut bands: Vec<Option<(usize, &mut [f64])>> =
                g.as_mut_slice().chunks_mut(tile * p).enumerate().map(Some).collect();
            let job_count = if pair { t_count.div_ceil(2) } else { t_count };
            let jobs: Vec<_> = (0..job_count)
                .map(|lo_idx| {
                    // lint:allow(panic, reason = "each band index is taken exactly once per job build; a None here is a scheduler bug")
                    let (t_first, first) = bands[lo_idx].take().expect("band consumed once");
                    let hi_idx = t_count - 1 - lo_idx;
                    let second = if pair && hi_idx > lo_idx { bands[hi_idx].take() } else { None };
                    move || {
                        let (lo, hi) = tiles_ref[t_first];
                        syrk_t_rows_into(a, lo, hi, first);
                        if let Some((t_second, band)) = second {
                            let (lo, hi) = tiles_ref[t_second];
                            syrk_t_rows_into(a, lo, hi, band);
                        }
                    }
                })
                .collect();
            pool.scope(jobs);
        }
        _ => {
            for &(lo, hi) in &tiles {
                let band = &mut g.as_mut_slice()[lo * p..hi * p];
                syrk_t_rows_into(a, lo, hi, band);
            }
        }
    }
    mirror_upper(&mut g);
    g
}

/// Panel-blocked, pool-parallel Cholesky — a free-function alias for
/// [`Cholesky::factor_blocked`] (bit-identical to [`Cholesky::factor`]
/// for any tile height or pool size). The per-λ `K_c + λI` factor of the
/// dual Gram backend and the dual streaming-hat build are the intended
/// callers.
pub fn chol_blocked(a: &Mat, tile: usize, pool: Option<&ThreadPool>) -> Result<Cholesky> {
    Cholesky::factor_blocked(a, tile, pool)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::matmul;
    use crate::util::rng::Rng;

    fn random_mat(rng: &mut Rng, r: usize, c: usize) -> Mat {
        Mat::from_fn(r, c, |_, _| rng.gauss())
    }

    /// Reference: the one-shot path the dual/spectral backends use today.
    fn gram_one_shot(v: &Mat) -> Mat {
        let mut g = matmul(v, &v.t());
        g.symmetrize();
        g
    }

    #[test]
    fn tiled_gram_bitwise_matches_one_shot_across_tile_sizes() {
        // Acceptance: tile heights {1, 7, N, N+3} — including the
        // non-divisible remainder panel — reproduce the one-shot Gram to
        // the last bit, serial and pooled.
        let mut rng = Rng::new(31);
        let pool = ThreadPool::new(4);
        for &(n, p) in &[(13usize, 40usize), (24, 7), (40, 150)] {
            let v = random_mat(&mut rng, n, p);
            let reference = gram_one_shot(&v);
            for tile in [1usize, 7, n, n + 3] {
                let slab = |lo: usize, hi: usize| {
                    Mat::from_fn(hi - lo, p, |r, j| v[(lo + r, j)])
                };
                let serial = gram_tiled(n, tile, slab, None);
                assert_eq!(
                    serial.as_slice(),
                    reference.as_slice(),
                    "serial n={n} p={p} tile={tile}"
                );
                let pooled = gram_tiled(n, tile, slab, Some(&pool));
                assert_eq!(
                    pooled.as_slice(),
                    reference.as_slice(),
                    "pooled n={n} p={p} tile={tile}"
                );
            }
        }
    }

    #[test]
    fn tiled_gram_slabs_are_requested_in_bounds() {
        // The slab closure must only ever be asked for in-range, tile-high
        // row windows (this is what bounds the transient memory).
        let n = 29;
        let tile = 8;
        let max_seen = std::sync::atomic::AtomicUsize::new(0);
        let g = gram_tiled(
            n,
            tile,
            |lo, hi| {
                assert!(lo < hi && hi <= n, "slab [{lo},{hi}) out of range");
                assert!(hi - lo <= tile, "slab higher than the tile");
                max_seen.fetch_max(hi - lo, std::sync::atomic::Ordering::Relaxed);
                Mat::from_fn(hi - lo, 3, |r, j| (lo + r) as f64 + j as f64)
            },
            None,
        );
        assert_eq!(g.shape(), (n, n));
        assert_eq!(max_seen.load(std::sync::atomic::Ordering::Relaxed), tile);
    }

    #[test]
    fn tiled_policy_resolves_rows_and_budget() {
        assert_eq!(TilePolicy::Off.tile_rows(100, 50), None);
        assert!(TilePolicy::Off.is_off());
        assert_eq!(TilePolicy::Rows(16).tile_rows(100, 50), Some(16));
        // clamped to [1, N]
        assert_eq!(TilePolicy::Rows(0).tile_rows(100, 50), Some(1));
        assert_eq!(TilePolicy::Rows(500).tile_rows(100, 50), Some(100));
        // budget: 8·(3P + N) bytes per tile row (three slabs + output strip)
        let per_row = 8 * (3 * 50 + 100);
        let policy = TilePolicy::Budget { bytes: 10 * per_row };
        assert_eq!(policy.tile_rows(100, 50), Some(10));
        // a tiny budget still yields a usable tile of 1
        assert_eq!(TilePolicy::Budget { bytes: 1 }.tile_rows(100, 50), Some(1));
        // CLI mapping: spill-dir wins, then rows, then budget, else off
        assert_eq!(TilePolicy::from_cli(32, 0, None), TilePolicy::Rows(32));
        assert_eq!(TilePolicy::from_cli(32, 7, None), TilePolicy::Rows(32));
        assert_eq!(TilePolicy::from_cli(0, 2, None), TilePolicy::Budget { bytes: 2 << 20 });
        assert_eq!(TilePolicy::from_cli(0, 0, None), TilePolicy::Off);
        assert_eq!(
            TilePolicy::from_cli(32, 0, Some("/tmp/s")),
            TilePolicy::Spill { dir: Some("/tmp/s".into()), tile: 32 }
        );
        assert_eq!(
            TilePolicy::from_cli(0, 0, Some("/tmp/s")),
            TilePolicy::Spill { dir: Some("/tmp/s".into()), tile: 256 },
            "--spill-dir without --tile-rows uses the default panel height"
        );
        // tags
        assert_eq!(TilePolicy::Off.tag(), "off");
        assert_eq!(TilePolicy::Rows(64).tag(), "tile-r64");
        assert_eq!(TilePolicy::Budget { bytes: 256 << 20 }.tag(), "tile-b256m");
        // sub-MiB budgets stay distinguishable (KiB units, never "b0m")
        assert_eq!(TilePolicy::Budget { bytes: 32 << 10 }.tag(), "tile-b32k");
        assert_eq!(TilePolicy::Budget { bytes: 512 << 10 }.tag(), "tile-b512k");
        assert_eq!(TilePolicy::Spill { dir: None, tile: 64 }.tag(), "spill-r64");
        assert_eq!(
            TilePolicy::Spill { dir: Some("/tmp/s".into()), tile: 64 }.tag(),
            "spill-r64-disk"
        );
        // spill() exposes the parameters, tile_rows() the assembly height
        let spill = TilePolicy::Spill { dir: None, tile: 8 };
        assert_eq!(spill.spill(), Some((None, 8)));
        assert_eq!(spill.tile_rows(100, 50), Some(8));
        assert!(!spill.is_off());
        assert_eq!(TilePolicy::Rows(8).spill(), None);
    }

    #[test]
    fn spill_syrk_tiled_bitwise_matches_syrk_t_pool() {
        // Acceptance: the tiled primal syrk reproduces syrk_t (and the
        // pooled syrk_t_pool, which equals it) to the last bit across tile
        // heights {1, 7, P, P+3} — remainder bands included — serial and
        // pooled, including through the == 0.0 skip path.
        use crate::linalg::gemm::{syrk_t, syrk_t_pool};
        let mut rng = Rng::new(41);
        let pool = ThreadPool::new(4);
        for &(n, p) in &[(20usize, 9usize), (8, 26), (30, 64)] {
            let mut a = random_mat(&mut rng, n, p);
            for i in 0..n {
                for j in 0..p {
                    if (i + j) % 6 == 0 {
                        a[(i, j)] = 0.0;
                    }
                }
            }
            let reference = syrk_t(&a);
            assert_eq!(
                reference.as_slice(),
                syrk_t_pool(&a, Some(&pool)).as_slice(),
                "precondition: pooled syrk equals serial"
            );
            for tile in [1usize, 7, p, p + 3] {
                let serial = syrk_tiled(&a, tile, None);
                assert_eq!(serial.as_slice(), reference.as_slice(), "serial ({n},{p}) tile={tile}");
                let pooled = syrk_tiled(&a, tile, Some(&pool));
                assert_eq!(pooled.as_slice(), reference.as_slice(), "pooled ({n},{p}) tile={tile}");
            }
        }
    }
}
