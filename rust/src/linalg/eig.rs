//! Symmetric eigendecomposition (cyclic Jacobi) and the generalised
//! symmetric-definite problem `A w = λ B w`.
//!
//! Multi-class LDA needs `S_b W = S_w W Λ` (Eq. 19) and optimal scoring's
//! step 2 needs the `C×C` eigenproblem (Alg. 2). Jacobi is exact enough
//! (machine-precision orthogonality) and trivially robust for the sizes we
//! hit (`C ≤ 10` per fold on the hot path, `P ≤ 1000` for the classic
//! baseline model).

use super::chol::Cholesky;
use super::gemm::matmul;
use super::mat::Mat;
use anyhow::Result;

/// Eigendecomposition of a symmetric matrix: `A = V diag(λ) Vᵀ`.
#[derive(Clone, Debug)]
pub struct SymEig {
    /// Eigenvalues, descending.
    pub values: Vec<f64>,
    /// Eigenvectors as columns, matching `values` order.
    pub vectors: Mat,
}

/// Symmetric eigendecomposition. Dispatches to Householder tridiagonal +
/// implicit-QL (`O(4/3·n³)`, the LAPACK-style algorithm) above a small-size
/// threshold, and to cyclic Jacobi below it (simpler, and the reference the
/// QL path is property-tested against).
pub fn sym_eig(a: &Mat) -> SymEig {
    if a.rows() > 24 {
        sym_eig_ql(a)
    } else {
        sym_eig_jacobi(a)
    }
}

/// Householder tridiagonalisation + implicit-shift QL with eigenvector
/// accumulation (Numerical Recipes `tred2`/`tqli`).
pub fn sym_eig_ql(a: &Mat) -> SymEig {
    let n = a.rows();
    assert_eq!(a.rows(), a.cols(), "sym_eig of non-square");
    let mut z = a.clone();
    z.symmetrize();
    let mut d = vec![0.0f64; n]; // diagonal
    let mut e = vec![0.0f64; n]; // off-diagonal

    // --- tred2: reduce to tridiagonal, accumulating transforms in z ---
    for i in (1..n).rev() {
        let l = i - 1;
        let mut h = 0.0;
        if l > 0 {
            let scale: f64 = (0..=l).map(|k| z[(i, k)].abs()).sum();
            if scale == 0.0 {
                e[i] = z[(i, l)];
            } else {
                for k in 0..=l {
                    z[(i, k)] /= scale;
                    h += z[(i, k)] * z[(i, k)];
                }
                let mut f = z[(i, l)];
                let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                z[(i, l)] = f - g;
                f = 0.0;
                for j in 0..=l {
                    z[(j, i)] = z[(i, j)] / h;
                    let mut g = 0.0;
                    for k in 0..=j {
                        g += z[(j, k)] * z[(i, k)];
                    }
                    for k in (j + 1)..=l {
                        g += z[(k, j)] * z[(i, k)];
                    }
                    e[j] = g / h;
                    f += e[j] * z[(i, j)];
                }
                let hh = f / (h + h);
                for j in 0..=l {
                    let f = z[(i, j)];
                    let g = e[j] - hh * f;
                    e[j] = g;
                    for k in 0..=j {
                        let upd = f * e[k] + g * z[(i, k)];
                        z[(j, k)] -= upd;
                    }
                }
            }
        } else {
            e[i] = z[(i, l)];
        }
        d[i] = h;
    }
    d[0] = 0.0;
    e[0] = 0.0;
    for i in 0..n {
        if d[i] != 0.0 {
            // accumulate transform
            for j in 0..i {
                let mut g = 0.0;
                for k in 0..i {
                    g += z[(i, k)] * z[(k, j)];
                }
                for k in 0..i {
                    let upd = g * z[(k, i)];
                    z[(k, j)] -= upd;
                }
            }
        }
        d[i] = z[(i, i)];
        z[(i, i)] = 1.0;
        for j in 0..i {
            z[(j, i)] = 0.0;
            z[(i, j)] = 0.0;
        }
    }

    // --- tqli: implicit-shift QL on (d, e) with vector accumulation in z ---
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;
    for l in 0..n {
        let mut iter = 0;
        loop {
            // find a small off-diagonal to split
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            assert!(iter <= 50, "tqli failed to converge at index {l}");
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = g.hypot(1.0);
            let sign_r = if g >= 0.0 { r.abs() } else { -r.abs() };
            g = d[m] - d[l] + e[l] / (g + sign_r);
            let (mut s, mut c) = (1.0f64, 1.0f64);
            let mut p = 0.0f64;
            for i in (l..m).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                r = f.hypot(g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                // accumulate eigenvectors
                for k in 0..n {
                    f = z[(k, i + 1)];
                    z[(k, i + 1)] = s * z[(k, i)] + c * f;
                    z[(k, i)] = c * z[(k, i)] - s * f;
                }
            }
            if r == 0.0 && m > l + 1 {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }

    // Sort descending (columns of z follow d).
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| d[b].total_cmp(&d[a]));
    let values: Vec<f64> = idx.iter().map(|&i| d[i]).collect();
    let vectors = z.take_cols(&idx);
    SymEig { values, vectors }
}

/// Cyclic Jacobi eigendecomposition of a symmetric matrix.
pub fn sym_eig_jacobi(a: &Mat) -> SymEig {
    let n = a.rows();
    assert_eq!(a.rows(), a.cols(), "sym_eig of non-square");
    let mut m = a.clone();
    m.symmetrize();
    let mut v = Mat::eye(n);
    let max_sweeps = 64;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        let scale = m.max_abs().max(1e-300);
        if off.sqrt() <= 1e-15 * scale * n as f64 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() <= 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                // Rotation angle (Golub & Van Loan 8.4).
                let tau = (aqq - app) / (2.0 * apq);
                let t = if tau >= 0.0 {
                    1.0 / (tau + (1.0 + tau * tau).sqrt())
                } else {
                    -1.0 / (-tau + (1.0 + tau * tau).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // Apply rotation J(p,q,θ): rows/cols p,q of m.
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }
    // Extract, sort descending.
    let mut idx: Vec<usize> = (0..n).collect();
    let vals: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    idx.sort_by(|&a, &b| vals[b].total_cmp(&vals[a]));
    let values: Vec<f64> = idx.iter().map(|&i| vals[i]).collect();
    let vectors = v.take_cols(&idx);
    SymEig { values, vectors }
}

/// Generalised symmetric-definite eigenproblem `A w = λ B w` with `B` SPD.
///
/// Reduced via `B = L Lᵀ` to the ordinary symmetric problem
/// `(L⁻¹ A L⁻ᵀ) y = λ y`, then back-transformed `w = L⁻ᵀ y`. The returned
/// vectors satisfy `wᵀ B w = 1` (the paper's `Wᵀ S_w W = I` scaling).
pub fn gen_sym_eig(a: &Mat, b: &Mat) -> Result<SymEig> {
    let ch = Cholesky::factor(b)?;
    // C = L⁻¹ A L⁻ᵀ  computed as  L⁻¹ (L⁻¹ Aᵀ)ᵀ  (A symmetric).
    let la = ch.solve_l_mat(a); // L⁻¹ A
    let c = ch.solve_l_mat(&la.t()); // L⁻¹ Aᵀ L⁻ᵀ... careful: (L⁻¹A)ᵀ = AᵀL⁻ᵀ = A L⁻ᵀ; L⁻¹(A L⁻ᵀ) ✓
    let mut c = c;
    c.symmetrize();
    let eig = sym_eig(&c);
    let vectors = ch.solve_lt_mat(&eig.vectors); // w = L⁻ᵀ y
    Ok(SymEig { values: eig.values, vectors })
}

/// Check `V` columns are B-orthonormal: `VᵀBV = I` (test helper).
pub fn b_orthonormality_error(v: &Mat, b: &Mat) -> f64 {
    let vt_b_v = matmul(&v.t(), &matmul(b, v));
    vt_b_v.max_abs_diff(&Mat::eye(v.cols()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::syrk_t;
    use crate::util::rng::Rng;

    fn random_sym(rng: &mut Rng, n: usize) -> Mat {
        let mut a = Mat::from_fn(n, n, |_, _| rng.gauss());
        a.symmetrize();
        a
    }

    fn random_spd(rng: &mut Rng, n: usize) -> Mat {
        let a = Mat::from_fn(n + 2, n, |_, _| rng.gauss());
        let mut g = syrk_t(&a);
        for i in 0..n {
            g[(i, i)] += 0.3;
        }
        g
    }

    #[test]
    fn diagonal_matrix_eigs() {
        let e = sym_eig(&Mat::diag(&[3.0, -1.0, 2.0]));
        assert!((e.values[0] - 3.0).abs() < 1e-12);
        assert!((e.values[1] - 2.0).abs() < 1e-12);
        assert!((e.values[2] + 1.0).abs() < 1e-12);
    }

    #[test]
    fn reconstruction_and_orthogonality() {
        let mut rng = Rng::new(1);
        for n in [1, 2, 3, 10, 40] {
            let a = random_sym(&mut rng, n);
            let e = sym_eig(&a);
            // V diag(λ) Vᵀ == A
            let vl = Mat::from_fn(n, n, |i, j| e.vectors[(i, j)] * e.values[j]);
            let rec = matmul(&vl, &e.vectors.t());
            assert!(rec.max_abs_diff(&a) < 1e-9 * a.max_abs().max(1.0), "n={n}");
            // VᵀV == I
            let vtv = matmul(&e.vectors.t(), &e.vectors);
            assert!(vtv.max_abs_diff(&Mat::eye(n)) < 1e-10, "n={n}");
            // sorted descending
            assert!(e.values.windows(2).all(|w| w[0] >= w[1] - 1e-12));
        }
    }

    #[test]
    fn trace_and_det_invariants() {
        let mut rng = Rng::new(2);
        let a = random_sym(&mut rng, 12);
        let e = sym_eig(&a);
        let tr: f64 = e.values.iter().sum();
        assert!((tr - a.trace()).abs() < 1e-9);
    }

    #[test]
    fn generalized_eig_satisfies_pencil() {
        let mut rng = Rng::new(3);
        for n in [2, 5, 12] {
            let a = random_sym(&mut rng, n);
            let b = random_spd(&mut rng, n);
            let e = gen_sym_eig(&a, &b).unwrap();
            // A w = λ B w columnwise
            let aw = matmul(&a, &e.vectors);
            let bw = matmul(&b, &e.vectors);
            for j in 0..n {
                for i in 0..n {
                    assert!(
                        (aw[(i, j)] - e.values[j] * bw[(i, j)]).abs() < 1e-8 * (1.0 + a.max_abs()),
                        "n={n} ({i},{j})"
                    );
                }
            }
            // B-orthonormal
            assert!(b_orthonormality_error(&e.vectors, &b) < 1e-8, "n={n}");
        }
    }

    #[test]
    fn ql_matches_jacobi_and_reconstructs() {
        let mut rng = Rng::new(7);
        for n in [2, 5, 25, 60, 130] {
            let a = random_sym(&mut rng, n);
            let ql = sym_eig_ql(&a);
            let jac = sym_eig_jacobi(&a);
            // same spectrum
            for (x, y) in ql.values.iter().zip(&jac.values) {
                assert!((x - y).abs() < 1e-8 * a.max_abs().max(1.0), "n={n}: {x} vs {y}");
            }
            // reconstruction + orthogonality of the QL vectors
            let vl = Mat::from_fn(n, n, |i, j| ql.vectors[(i, j)] * ql.values[j]);
            let rec = matmul(&vl, &ql.vectors.t());
            assert!(rec.max_abs_diff(&a) < 1e-8 * a.max_abs().max(1.0), "n={n}");
            let vtv = matmul(&ql.vectors.t(), &ql.vectors);
            assert!(vtv.max_abs_diff(&Mat::eye(n)) < 1e-9, "n={n}");
        }
    }

    #[test]
    fn ql_handles_degenerate_spectra() {
        // repeated eigenvalues and zero matrix
        let e = sym_eig_ql(&Mat::zeros(30, 30));
        assert!(e.values.iter().all(|&v| v.abs() < 1e-12));
        let e = sym_eig_ql(&Mat::eye(40));
        assert!(e.values.iter().all(|&v| (v - 1.0).abs() < 1e-12));
        let vtv = matmul(&e.vectors.t(), &e.vectors);
        assert!(vtv.max_abs_diff(&Mat::eye(40)) < 1e-10);
    }

    #[test]
    fn rank_one_pencil_matches_lemma1() {
        // Lemma 1: S_b = c Δ Δᵀ gives a single non-zero eigenvalue
        // c ΔᵀS_w⁻¹Δ with eigenvector ∝ S_w⁻¹Δ.
        let mut rng = Rng::new(4);
        let p = 8;
        let sw = random_spd(&mut rng, p);
        let delta: Vec<f64> = (0..p).map(|_| rng.gauss()).collect();
        let c = 1.7;
        let mut sb = Mat::zeros(p, p);
        crate::linalg::gemm::ger(&mut sb, c, &delta, &delta);
        let e = gen_sym_eig(&sb, &sw).unwrap();
        let w_expect = Cholesky::factor(&sw).unwrap().solve_vec(&delta);
        let lam_expect = c * crate::linalg::gemm::dot(&delta, &w_expect);
        assert!((e.values[0] - lam_expect).abs() < 1e-8 * lam_expect.abs());
        for &v in &e.values[1..] {
            assert!(v.abs() < 1e-8, "other eigenvalues ~0, got {v}");
        }
        // leading eigenvector parallel to S_w⁻¹Δ
        let lead = e.vectors.col(0);
        let cos = crate::linalg::gemm::dot(&lead, &w_expect)
            / (crate::linalg::gemm::dot(&lead, &lead).sqrt()
                * crate::linalg::gemm::dot(&w_expect, &w_expect).sqrt());
        assert!((cos.abs() - 1.0).abs() < 1e-8, "cos={cos}");
    }
}
