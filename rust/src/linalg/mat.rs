//! Dense row-major matrix type.
//!
//! The workhorse container for the whole library. Storage is a flat
//! `Vec<f64>` in row-major order; views are expressed through explicit
//! index-set gathers (the CV code slices train/test rows constantly, and the
//! gather form keeps those copies contiguous for the blocked kernels).

use std::fmt;
use std::ops::{Index, IndexMut};

/// Dense row-major `rows × cols` matrix of `f64`.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    /// Matrix wrapping an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Mat {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        Mat { rows, cols, data }
    }

    /// Matrix from nested row slices (tests/readability).
    pub fn from_rows(rows: &[&[f64]]) -> Mat {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Diagonal matrix from a slice.
    pub fn diag(d: &[f64]) -> Mat {
        let mut m = Mat::zeros(d.len(), d.len());
        for (i, &x) in d.iter().enumerate() {
            m[(i, i)] = x;
        }
        m
    }

    /// Column vector (n×1) from a slice.
    pub fn col_vec(v: &[f64]) -> Mat {
        Mat { rows: v.len(), cols: 1, data: v.to_vec() }
    }

    /// Number of rows.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// (rows, cols).
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Flat row-major data.
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Mutable flat row-major data.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Consume into the flat buffer.
    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row slice.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Borrow rows `lo..hi` as one contiguous row-major slice (row-major
    /// storage makes any row band a single slab). The GEMM packers and the
    /// spill layer's panel copies read/write through this instead of
    /// element-by-element `(r, c)` indexing.
    #[inline]
    pub fn rows_slice(&self, lo: usize, hi: usize) -> &[f64] {
        debug_assert!(lo <= hi && hi <= self.rows);
        &self.data[lo * self.cols..hi * self.cols]
    }

    /// Mutable variant of [`Mat::rows_slice`].
    #[inline]
    pub fn rows_slice_mut(&mut self, lo: usize, hi: usize) -> &mut [f64] {
        debug_assert!(lo <= hi && hi <= self.rows);
        &mut self.data[lo * self.cols..hi * self.cols]
    }

    /// Copy column `j` out.
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Set column `j` from a slice.
    pub fn set_col(&mut self, j: usize, v: &[f64]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            self[(i, j)] = v[i];
        }
    }

    /// Transposed copy.
    pub fn t(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on large matrices.
        const B: usize = 32;
        for i0 in (0..self.rows).step_by(B) {
            for j0 in (0..self.cols).step_by(B) {
                for i in i0..(i0 + B).min(self.rows) {
                    for j in j0..(j0 + B).min(self.cols) {
                        out[(j, i)] = self[(i, j)];
                    }
                }
            }
        }
        out
    }

    /// Gather rows by index set into a new contiguous matrix.
    pub fn take_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (k, &i) in idx.iter().enumerate() {
            out.row_mut(k).copy_from_slice(self.row(i));
        }
        out
    }

    /// Gather columns by index set into a new contiguous matrix.
    pub fn take_cols(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(self.rows, idx.len());
        for i in 0..self.rows {
            let src = self.row(i);
            let dst = out.row_mut(i);
            for (k, &j) in idx.iter().enumerate() {
                dst[k] = src[j];
            }
        }
        out
    }

    /// Submatrix `self[ridx, cidx]` (gather on both axes).
    pub fn take(&self, ridx: &[usize], cidx: &[usize]) -> Mat {
        let mut out = Mat::zeros(ridx.len(), cidx.len());
        for (k, &i) in ridx.iter().enumerate() {
            let src = self.row(i);
            let dst = out.row_mut(k);
            for (l, &j) in cidx.iter().enumerate() {
                dst[l] = src[j];
            }
        }
        out
    }

    /// Horizontal concatenation `[self, other]`.
    pub fn hcat(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows, "hcat row mismatch");
        let mut out = Mat::zeros(self.rows, self.cols + other.cols);
        for i in 0..self.rows {
            out.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
            out.row_mut(i)[self.cols..].copy_from_slice(other.row(i));
        }
        out
    }

    /// Augment with a column of ones (the paper's `X̃ = [X, 1]`).
    pub fn augment_ones(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols + 1);
        for i in 0..self.rows {
            out.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
            out[(i, self.cols)] = 1.0;
        }
        out
    }

    /// Element-wise map into a new matrix.
    pub fn map(&self, f: impl Fn(f64) -> f64) -> Mat {
        Mat { rows: self.rows, cols: self.cols, data: self.data.iter().map(|&x| f(x)).collect() }
    }

    /// In-place scaled add: `self += alpha * other`.
    pub fn axpy(&mut self, alpha: f64, other: &Mat) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// In-place scalar multiply.
    pub fn scale(&mut self, alpha: f64) {
        for a in self.data.iter_mut() {
            *a *= alpha;
        }
    }

    /// `self - other` as a new matrix.
    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!(self.shape(), other.shape());
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        }
    }

    /// `self + other` as a new matrix.
    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!(self.shape(), other.shape());
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect(),
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Max absolute element.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |m, x| m.max(x.abs()))
    }

    /// Trace (square only).
    pub fn trace(&self) -> f64 {
        assert_eq!(self.rows, self.cols, "trace of non-square");
        (0..self.rows).map(|i| self[(i, i)]).sum()
    }

    /// Mean of each column.
    pub fn col_means(&self) -> Vec<f64> {
        let mut m = vec![0.0; self.cols];
        for i in 0..self.rows {
            for (j, x) in self.row(i).iter().enumerate() {
                m[j] += x;
            }
        }
        let n = self.rows.max(1) as f64;
        for x in m.iter_mut() {
            *x /= n;
        }
        m
    }

    /// Largest |a-b| between two matrices.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f64, |m, (a, b)| m.max((a - b).abs()))
    }

    /// Symmetrize in place: `self = (self + selfᵀ)/2` (square only).
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let v = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = v;
                self[(j, i)] = v;
            }
        }
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols, "index ({i},{j}) out of {}x{}", self.rows, self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        let show_r = self.rows.min(8);
        for i in 0..show_r {
            write!(f, "  [")?;
            let show_c = self.cols.min(8);
            for j in 0..show_c {
                write!(f, "{:>10.4}", self[(i, j)])?;
                if j + 1 < show_c {
                    write!(f, ", ")?;
                }
            }
            if self.cols > 8 {
                write!(f, ", …")?;
            }
            writeln!(f, "]")?;
        }
        if self.rows > 8 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let m = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        assert_eq!(m.shape(), (2, 2));
        assert_eq!(m[(0, 1)], 2.0);
        assert_eq!(m.row(1), &[3.0, 4.0]);
        assert_eq!(m.col(0), vec![1.0, 3.0]);
    }

    #[test]
    fn transpose_blocked_matches_naive() {
        let m = Mat::from_fn(67, 41, |i, j| (i * 41 + j) as f64);
        let t = m.t();
        for i in 0..67 {
            for j in 0..41 {
                assert_eq!(t[(j, i)], m[(i, j)]);
            }
        }
    }

    #[test]
    fn rows_slice_is_the_contiguous_band() {
        let m = Mat::from_fn(5, 3, |i, j| (10 * i + j) as f64);
        assert_eq!(m.rows_slice(1, 3), &m.as_slice()[3..9]);
        assert_eq!(m.rows_slice(0, 0), &[] as &[f64]);
        let mut w = m.clone();
        w.rows_slice_mut(2, 3).copy_from_slice(&[7.0, 8.0, 9.0]);
        assert_eq!(w.row(2), &[7.0, 8.0, 9.0]);
        assert_eq!(w.row(1), m.row(1));
    }

    #[test]
    fn rows_slice_last_partial_band_stops_at_the_matrix_edge() {
        // The GEMM packers take MR-row bands with `lo + mr.min(rows - lo)`;
        // with the widened per-ISA MR=6 the last band of e.g. a 5×p or 13×p
        // operand is partial. The band slab must cover exactly the live
        // rows — through hi == rows — and never read past the allocation.
        for (rows, mr) in [(5usize, 4usize), (5, 6), (13, 6), (7, 8)] {
            let m = Mat::from_fn(rows, 3, |i, j| (10 * i + j) as f64);
            let lo = (rows / mr) * mr;
            let live = mr.min(rows - lo);
            let band = m.rows_slice(lo, lo + live);
            assert_eq!(band.len(), live * 3, "rows={rows} mr={mr}");
            assert_eq!(band[0], (10 * lo) as f64);
            assert_eq!(*band.last().unwrap(), (10 * (rows - 1) + 2) as f64);
            // The full-height band is the whole backing slab.
            assert_eq!(m.rows_slice(0, rows), m.as_slice());
        }
        // Mutable variant at the same boundary: the write lands on the last
        // row and leaves every earlier row untouched.
        let mut w = Mat::from_fn(5, 3, |i, j| (10 * i + j) as f64);
        let band = w.rows_slice_mut(4, 5);
        band.copy_from_slice(&[-1.0, -2.0, -3.0]);
        assert_eq!(w.row(4), &[-1.0, -2.0, -3.0]);
        assert_eq!(w.row(3), &[30.0, 31.0, 32.0]);
    }

    #[test]
    fn gathers() {
        let m = Mat::from_fn(5, 4, |i, j| (10 * i + j) as f64);
        let r = m.take_rows(&[4, 0]);
        assert_eq!(r.row(0), &[40.0, 41.0, 42.0, 43.0]);
        assert_eq!(r.row(1), &[0.0, 1.0, 2.0, 3.0]);
        let c = m.take_cols(&[3, 1]);
        assert_eq!(c.row(2), &[23.0, 21.0]);
        let s = m.take(&[1, 2], &[0, 3]);
        assert_eq!(s.row(0), &[10.0, 13.0]);
        assert_eq!(s.row(1), &[20.0, 23.0]);
    }

    #[test]
    fn augment_and_hcat() {
        let m = Mat::from_rows(&[&[1.0], &[2.0]]);
        let a = m.augment_ones();
        assert_eq!(a.row(0), &[1.0, 1.0]);
        assert_eq!(a.row(1), &[2.0, 1.0]);
        let h = m.hcat(&a);
        assert_eq!(h.shape(), (2, 3));
        assert_eq!(h.row(1), &[2.0, 2.0, 1.0]);
    }

    #[test]
    fn arithmetic_helpers() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Mat::eye(2);
        let mut c = a.clone();
        c.axpy(2.0, &b);
        assert_eq!(c[(0, 0)], 3.0);
        assert_eq!(c[(0, 1)], 2.0);
        assert_eq!(a.sub(&a).fro_norm(), 0.0);
        assert_eq!(a.add(&a)[(1, 1)], 8.0);
        assert_eq!(a.trace(), 5.0);
        assert_eq!(a.max_abs(), 4.0);
        assert_eq!(Mat::diag(&[1.0, 2.0])[(1, 1)], 2.0);
    }

    #[test]
    fn col_means_and_symmetrize() {
        let m = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 6.0]]);
        assert_eq!(m.col_means(), vec![2.0, 4.0]);
        let mut s = Mat::from_rows(&[&[1.0, 4.0], &[0.0, 1.0]]);
        s.symmetrize();
        assert_eq!(s[(0, 1)], 2.0);
        assert_eq!(s[(1, 0)], 2.0);
    }

    #[test]
    #[should_panic]
    fn from_vec_size_checked() {
        Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0]);
    }
}
