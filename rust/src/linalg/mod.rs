//! Dense linear algebra substrate (built from scratch; no BLAS/LAPACK in the
//! offline environment).
//!
//! - [`mat::Mat`] — row-major dense matrix with gather-based slicing
//! - [`gemm`] — blocked matmul / syrk / matvec kernels
//! - [`chol`] — Cholesky factor/solve for SPD scatter matrices
//! - [`lu`] — partially pivoted LU for general systems
//! - [`eig`] — Jacobi symmetric + generalised symmetric-definite eig
//! - [`tiled`] — panel-tiled Gram builds + blocked Cholesky for the §4.5
//!   memory-bounded regime ([`TilePolicy`], [`gram_tiled`], [`chol_blocked`])

pub mod chol;
pub mod eig;
pub mod gemm;
pub mod lu;
pub mod mat;
pub mod tiled;

pub use chol::Cholesky;
pub use eig::{gen_sym_eig, sym_eig, SymEig};
pub use gemm::{
    dot, gemm_acc, ger, matmul, matmul_pool, matvec, matvec_gemm_order, matvec_t, syrk_t,
    syrk_t_pool,
};
pub use lu::{solve, solve_mat, Lu};
pub use mat::Mat;
pub use tiled::{chol_blocked, gram_tiled, TilePolicy};
