//! Dense linear algebra substrate (built from scratch; no BLAS/LAPACK in the
//! offline environment).
//!
//! - [`mat::Mat`] — row-major dense matrix with gather-based slicing
//! - [`gemm`] — blocked matmul / syrk / matvec kernels
//! - [`chol`] — Cholesky factor/solve for SPD scatter matrices
//! - [`chol_update`] — rank-1/block up/downdates rotating an existing
//!   factor in `O(n²)` (the streaming engine's maintenance kernels)
//! - [`lu`] — partially pivoted LU for general systems
//! - [`eig`] — Jacobi symmetric + generalised symmetric-definite eig
//! - [`tiled`] — panel-tiled Gram builds + blocked Cholesky for the §4.5
//!   memory-bounded regime ([`TilePolicy`], [`gram_tiled`], [`syrk_tiled`],
//!   [`chol_blocked`])
//! - [`spill`] — out-of-core panel persistence ([`PanelStore`], RAM or
//!   disk) + the left-looking spilled Cholesky ([`chol_spill`]) and
//!   streaming solves, all bitwise-identical to the in-RAM kernels
//! - [`dispatch`] — runtime ISA selection for the microkernels
//!   ([`Isa`], [`Kernels`]; scalar reference + AVX2/NEON SIMD, all
//!   bitwise-identical by the canonical-accumulation-order contract)

pub mod chol;
pub mod chol_update;
pub mod dispatch;
pub mod eig;
pub mod gemm;
pub mod lu;
pub mod mat;
#[cfg(target_arch = "x86_64")]
pub(crate) mod simd_avx2;
#[cfg(target_arch = "aarch64")]
pub(crate) mod simd_neon;
pub mod spill;
pub mod tiled;

pub use chol::Cholesky;
pub use chol_update::{chol_downdate, chol_downdate_block, chol_update, chol_update_block};
pub use dispatch::{Isa, Kernels};
pub use eig::{gen_sym_eig, sym_eig, SymEig};
pub use gemm::{
    dot, gemm_acc, gemm_acc_isa, ger, matmul, matmul_isa, matmul_pool, matvec, matvec_gemm_order,
    matvec_t, syrk_t, syrk_t_isa, syrk_t_pool,
};
pub use lu::{solve, solve_mat, Lu};
pub use mat::Mat;
pub use spill::{
    chol_spill, chol_spill_ridged, gram_spill, quarantine_orphans, syrk_spill, PanelStore,
    SpillError, SpilledCholesky,
};
pub use tiled::{chol_blocked, gram_tiled, syrk_tiled, TilePolicy};
