//! Rank-1 and block-k Cholesky up/downdates — the streaming engine's
//! factor-maintenance kernels.
//!
//! Given `A = L Lᵀ`, [`chol_update`] rotates `L` into the factor of
//! `A + v vᵀ` and [`chol_downdate`] into the factor of `A − v vᵀ`, in
//! `O(n²)` instead of the `O(n³)` a refactorisation costs. Appending a
//! sample to a Gram matrix is exactly `G += x̃ x̃ᵀ`, evicting one is
//! `G −= x̃ x̃ᵀ`, so a sliding window over N samples pays `O(P²)` per step
//! where a rebuild pays `O(NP² + P³)` — the asymmetry
//! `benches/ablation_stream.rs` measures and docs/STREAM.md derives.
//!
//! ## The rotations
//!
//! Column `k` zeroes `v[k]` against the pivot `l_kk`:
//!
//! * **update** (Givens): `r = √(l_kk² + v_k²)`, `c = r/l_kk`,
//!   `s = v_k/l_kk`; then for rows `i > k`:
//!   `l_i ← (l_i + s·v_i)/c`, `v_i ← (v_i − s·l_i^old)/c`.
//! * **downdate** (hyperbolic, metric `diag(I, −1)`):
//!   `r = √(l_kk² − v_k²)`, same `c`/`s`; `l_i ← (l_i − s·v_i)/c`,
//!   `v_i ← (v_i − s·l_i^old)/c`. When `l_kk² − v_k²` is not safely
//!   positive the downdated matrix is no longer positive definite and the
//!   kernel fails **cleanly, leaving the factor unchanged** (callers
//!   refresh from scratch; the sliding-window driver never hits this while
//!   its ridge is active).
//!
//! ## Determinism contract (docs/LINTS.md)
//!
//! Same rules as every other `linalg` kernel:
//!
//! * one accumulation order per output element — each column applies one
//!   mul-then-add (or mul-then-sub) per element via the dispatched
//!   [`Kernels`](crate::linalg::Kernels) `axpy`/`axpy_sub` inner
//!   loops, then one scalar division; SIMD lanes are distinct elements, so
//!   every ISA is bitwise-identical (pinned by `stream_*` under forced
//!   dispatch);
//! * the blocked forms are **defined** as the in-order composition of
//!   rank-1 rotations, so `k` single updates and one block-`k` update are
//!   bitwise-equal by construction (pinned by
//!   `stream_block_kernels_are_bitwise_k_singles`);
//! * a `v_k == 0.0` column is skipped outright — the rotation is the
//!   identity, and skipping (rather than multiplying through `c ≈ 1`)
//!   keeps a no-op update from perturbing low bits.
//!
//! Exact floating-point inverses do **not** exist here: updating then
//! downdating the same `v` returns the original factor only to roundoff
//! (`√`/square do not cancel bitwise), which is why the sliding-window
//! driver offers `--exact-refresh-every` and the round-trip property test
//! is tolerance-based. See docs/STREAM.md for the drift policy.

use super::chol::Cholesky;
use super::dispatch;
use super::mat::Mat;
use anyhow::{bail, Result};

/// Relative floor under which a downdated pivot square counts as
/// non-positive: `l_kk² − v_k² ≤ REL_FLOOR · l_kk²` fails cleanly rather
/// than produce a factor dominated by cancellation noise. Mirrors the
/// relative pivot floor of [`Cholesky::factor`].
const REL_FLOOR: f64 = 1e-12;

/// Rotate `ch` (factor of `A`) into the factor of `A + v vᵀ` in place.
/// `O(n²)`; cannot fail (an update keeps every pivot positive).
pub fn chol_update(ch: &mut Cholesky, v: &[f64]) {
    let n = ch.n();
    if v.len() != n {
        // Dimension-contract assert: a caller bug, the same policy as Mat
        // indexing (file-level L4 allowlist entry, docs/LINTS.md).
        panic!("chol_update: vector length {} vs factor dimension {n}", v.len());
    }
    let mut w = v.to_vec();
    let mut scratch = Scratch::new(n);
    update_in_place(ch.l_mut(), &mut w, &mut scratch);
}

/// Rotate `ch` (factor of `A`) into the factor of `A − v vᵀ` in place.
/// `O(n²)`. Fails cleanly — **the factor is left unchanged** — when the
/// downdated matrix is no longer safely positive definite.
pub fn chol_downdate(ch: &mut Cholesky, v: &[f64]) -> Result<()> {
    let n = ch.n();
    if v.len() != n {
        // Dimension-contract assert: a caller bug, the same policy as Mat
        // indexing (file-level L4 allowlist entry, docs/LINTS.md).
        panic!("chol_downdate: vector length {} vs factor dimension {n}", v.len());
    }
    // Error safety by copy-and-swap: the rotations are applied to a working
    // copy, so a failed pivot at column k cannot leave a half-rotated
    // factor behind. One n×n memcpy against 4n² flops of rotation work.
    let mut l = ch.l().clone();
    let mut w = v.to_vec();
    let mut scratch = Scratch::new(n);
    downdate_in_place(&mut l, &mut w, &mut scratch)?;
    *ch = Cholesky::from_lower(l);
    Ok(())
}

/// Block-`k` update: rotate in each **row** of `vs` (`k × n`) in order.
/// Bitwise-equal to `k` successive [`chol_update`] calls by construction —
/// the blocked form exists so whole epochs append with one call (and one
/// scratch allocation), not so the arithmetic can differ.
pub fn chol_update_block(ch: &mut Cholesky, vs: &Mat) {
    let n = ch.n();
    if vs.cols() != n {
        // Dimension-contract assert: a caller bug, the same policy as Mat
        // indexing (file-level L4 allowlist entry, docs/LINTS.md).
        panic!("chol_update_block: vector length {} vs factor dimension {n}", vs.cols());
    }
    let mut scratch = Scratch::new(n);
    let mut w = vec![0.0; n];
    for r in 0..vs.rows() {
        w.copy_from_slice(vs.row(r));
        update_in_place(ch.l_mut(), &mut w, &mut scratch);
    }
}

/// Block-`k` downdate: rotate out each row of `vs` in order. Bitwise-equal
/// to `k` successive [`chol_downdate`] calls; on failure at any row the
/// factor is left **fully unchanged** (one copy guards the whole block,
/// amortising the rank-1 kernel's per-call copy `k`-fold).
pub fn chol_downdate_block(ch: &mut Cholesky, vs: &Mat) -> Result<()> {
    let n = ch.n();
    if vs.cols() != n {
        // Dimension-contract assert: a caller bug, the same policy as Mat
        // indexing (file-level L4 allowlist entry, docs/LINTS.md).
        panic!("chol_downdate_block: vector length {} vs factor dimension {n}", vs.cols());
    }
    let mut l = ch.l().clone();
    let mut scratch = Scratch::new(n);
    let mut w = vec![0.0; n];
    for r in 0..vs.rows() {
        w.copy_from_slice(vs.row(r));
        downdate_in_place(&mut l, &mut w, &mut scratch)
            .map_err(|e| e.context(format!("block downdate failed at row {r}")))?;
    }
    *ch = Cholesky::from_lower(l);
    Ok(())
}

/// Per-call gather buffers: the factor is row-major, so a column tail is
/// strided — each rotation gathers it once, runs the contiguous dispatched
/// inner loops, and scatters it back. Pure data movement on both sides, so
/// the gather does not touch the bitwise contract.
struct Scratch {
    /// The column tail being rotated (becomes the new `l` column).
    col: Vec<f64>,
    /// The pre-rotation column tail (the `l^old` operand of the `v` step).
    old: Vec<f64>,
}

impl Scratch {
    fn new(n: usize) -> Scratch {
        Scratch { col: vec![0.0; n], old: vec![0.0; n] }
    }
}

/// One Givens-style column sweep of the update rotation. `l` must be a
/// lower-triangular factor with positive diagonal; `w` is consumed.
fn update_in_place(l: &mut Mat, w: &mut [f64], scratch: &mut Scratch) {
    let n = l.rows();
    let kr = dispatch::active_kernels();
    for k in 0..n {
        let wk = w[k];
        if wk == 0.0 {
            continue; // identity rotation — see the module docs
        }
        let lkk = l[(k, k)];
        let r = (lkk * lkk + wk * wk).sqrt();
        let c = r / lkk;
        let s = wk / lkk;
        l[(k, k)] = r;
        let m = n - k - 1;
        if m == 0 {
            continue;
        }
        let col = &mut scratch.col[..m];
        let old = &mut scratch.old[..m];
        for (i, slot) in old.iter_mut().enumerate() {
            *slot = l[(k + 1 + i, k)];
        }
        col.copy_from_slice(old);
        let w_tail = &mut w[k + 1..];
        // l ← (l + s·v)/c, v ← (v − s·l_old)/c — dispatched mul-then-add
        // inner loops (lanes = distinct elements), then a scalar division
        // per element. Identical sequence under every ISA.
        (kr.axpy)(col, s, w_tail);
        for x in col.iter_mut() {
            *x /= c;
        }
        (kr.axpy_sub)(w_tail, s, old);
        for x in w_tail.iter_mut() {
            *x /= c;
        }
        for (i, &x) in col.iter().enumerate() {
            l[(k + 1 + i, k)] = x;
        }
    }
}

/// One hyperbolic column sweep of the downdate rotation. On `Err` the
/// factor `l` may be partially rotated — the public wrappers guard with a
/// copy, so callers never observe that state.
fn downdate_in_place(l: &mut Mat, w: &mut [f64], scratch: &mut Scratch) -> Result<()> {
    let n = l.rows();
    let kr = dispatch::active_kernels();
    for k in 0..n {
        let wk = w[k];
        if wk == 0.0 {
            continue; // identity rotation — see the module docs
        }
        let lkk = l[(k, k)];
        let d = lkk * lkk - wk * wk;
        if d <= REL_FLOOR * lkk * lkk || !d.is_finite() {
            bail!(
                "downdate leaves the matrix non-positive-definite at pivot {k} \
                 (l_kk²−v_k² = {d:e}) — refresh the factor from scratch"
            );
        }
        let r = d.sqrt();
        let c = r / lkk;
        let s = wk / lkk;
        l[(k, k)] = r;
        let m = n - k - 1;
        if m == 0 {
            continue;
        }
        let col = &mut scratch.col[..m];
        let old = &mut scratch.old[..m];
        for (i, slot) in old.iter_mut().enumerate() {
            *slot = l[(k + 1 + i, k)];
        }
        col.copy_from_slice(old);
        let w_tail = &mut w[k + 1..];
        // l ← (l − s·v)/c, v ← (v − s·l_old)/c — the hyperbolic twin of the
        // update sweep, same dispatched inner loops.
        (kr.axpy_sub)(col, s, w_tail);
        for x in col.iter_mut() {
            *x /= c;
        }
        (kr.axpy_sub)(w_tail, s, old);
        for x in w_tail.iter_mut() {
            *x /= c;
        }
        for (i, &x) in col.iter().enumerate() {
            l[(k + 1 + i, k)] = x;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{matmul, syrk_t};
    use crate::util::rng::Rng;

    fn spd(rng: &mut Rng, n: usize) -> Mat {
        let a = Mat::from_fn(n + 4, n, |_, _| rng.gauss());
        let mut g = syrk_t(&a);
        for i in 0..n {
            g[(i, i)] += 1.0;
        }
        g
    }

    fn reconstruct(ch: &Cholesky) -> Mat {
        matmul(ch.l(), &ch.l().t())
    }

    #[test]
    fn update_matches_refactor() {
        let mut rng = Rng::new(41);
        for n in [1usize, 2, 5, 17, 40] {
            let a = spd(&mut rng, n);
            let v: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
            let mut ch = Cholesky::factor(&a).unwrap();
            chol_update(&mut ch, &v);
            let mut want = a.clone();
            ger(&mut want, 1.0, &v);
            assert!(
                reconstruct(&ch).max_abs_diff(&want) < 1e-8 * want.max_abs().max(1.0),
                "n={n}"
            );
            // lower-triangular with positive diagonal
            for i in 0..n {
                assert!(ch.l()[(i, i)] > 0.0);
                for j in (i + 1)..n {
                    assert_eq!(ch.l()[(i, j)], 0.0);
                }
            }
        }
    }

    #[test]
    fn downdate_matches_refactor() {
        let mut rng = Rng::new(42);
        for n in [1usize, 2, 5, 17, 40] {
            let a = spd(&mut rng, n);
            let v: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
            // A + vvᵀ is SPD and downdating v from it is safely PD again.
            let mut up = a.clone();
            ger(&mut up, 1.0, &v);
            let mut ch = Cholesky::factor(&up).unwrap();
            chol_downdate(&mut ch, &v).unwrap();
            assert!(
                reconstruct(&ch).max_abs_diff(&a) < 1e-7 * a.max_abs().max(1.0),
                "n={n}"
            );
        }
    }

    #[test]
    fn failed_downdate_leaves_factor_unchanged() {
        let mut rng = Rng::new(43);
        let n = 9;
        let a = spd(&mut rng, n);
        let ch0 = Cholesky::factor(&a).unwrap();
        let mut ch = ch0.clone();
        // Removing 10·a_00 from the (0,0) entry makes A − vvᵀ indefinite.
        let mut v = vec![0.0; n];
        v[0] = (10.0 * a[(0, 0)]).sqrt();
        assert!(chol_downdate(&mut ch, &v).is_err());
        assert_eq!(ch.l().as_slice(), ch0.l().as_slice(), "factor must be untouched on Err");
        // Block form: a good row followed by a bad one must also roll back.
        let good: Vec<f64> = (0..n).map(|_| 0.1 * rng.gauss()).collect();
        let vs = Mat::from_rows(&[&good[..], &v[..]]);
        assert!(chol_downdate_block(&mut ch, &vs).is_err());
        assert_eq!(ch.l().as_slice(), ch0.l().as_slice(), "block must roll back fully");
    }

    #[test]
    fn zero_vector_is_bitwise_noop() {
        let mut rng = Rng::new(44);
        let n = 12;
        let a = spd(&mut rng, n);
        let ch0 = Cholesky::factor(&a).unwrap();
        let mut ch = ch0.clone();
        chol_update(&mut ch, &vec![0.0; n]);
        assert_eq!(ch.l().as_slice(), ch0.l().as_slice());
        chol_downdate(&mut ch, &vec![0.0; n]).unwrap();
        assert_eq!(ch.l().as_slice(), ch0.l().as_slice());
    }

    #[test]
    fn sparse_vector_skips_identity_columns_correctly() {
        // v with interior zeros exercises the wk == 0 skip in mid-sweep.
        let mut rng = Rng::new(45);
        let n = 14;
        let a = spd(&mut rng, n);
        let mut v = vec![0.0; n];
        for i in (0..n).step_by(3) {
            v[i] = rng.gauss();
        }
        let mut ch = Cholesky::factor(&a).unwrap();
        chol_update(&mut ch, &v);
        let mut want = a.clone();
        ger(&mut want, 1.0, &v);
        assert!(reconstruct(&ch).max_abs_diff(&want) < 1e-8 * want.max_abs().max(1.0));
    }

    /// `M += alpha · u uᵀ` test helper (symmetric ger).
    fn ger(m: &mut Mat, alpha: f64, u: &[f64]) {
        crate::linalg::gemm::ger(m, alpha, u, u);
    }
}
