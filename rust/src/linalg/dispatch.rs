//! Runtime ISA dispatch for the `linalg` hot core.
//!
//! Every backend shipped so far — primal/dual/spectral hats, the tiled and
//! spilled Gram engines, the permutation batchers — ultimately bottoms out
//! in the packed GEMM/syrk microkernels of [`crate::linalg::gemm`]. This
//! module selects, **once per process and overridably**, which concrete
//! microkernel implementations those entry points run:
//!
//! * [`Isa::Scalar`] — the portable reference kernels (`MR=4 × NR=8`
//!   register tile, 4-partial [`dot`](crate::linalg::dot)). These define
//!   the *canonical accumulation order*; everything else must reproduce
//!   them bit-for-bit.
//! * [`Isa::Avx2`] — x86-64 AVX2 kernels (`MR=6 × NR=8`, 4-lane `f64`
//!   vectors; `linalg::simd_avx2`), selected when the CPU reports AVX2 at
//!   startup.
//! * [`Isa::Neon`] — aarch64 NEON kernels (`MR=6 × NR=8`, 2-lane `f64`
//!   vectors; `linalg::simd_neon`); NEON is baseline on aarch64.
//!
//! ## The cross-ISA bitwise contract
//!
//! The repo's determinism story (docs/LINTS.md, the `backend_*`/`tiled_*`/
//! `spill_*` suites) pins *one* accumulation order per output element. The
//! SIMD kernels keep that order by construction:
//!
//! * vector lanes are always **distinct output elements** (GEMM columns,
//!   syrk band entries, solve RHS columns, `dot`'s four stride partials) —
//!   never splits of one element's sum;
//! * every lane performs the scalar sequence `acc = acc + a·b` with a
//!   rounded multiply **then** a rounded add — fused multiply-add is
//!   deliberately not used anywhere (FMA's single rounding would change
//!   results);
//! * remainder lanes come from the zero-padded pack buffers and are never
//!   written back.
//!
//! Hence every `(kernel, ISA)` pair is bitwise-identical to the scalar
//! reference — enforced by the `kernel_conformance_*` differential suite
//! (`rust/tests/kernel_conformance.rs`) and end-to-end by the golden
//! perm-engine null distributions under forced dispatch. The ISA knob is a
//! pure wall-clock choice, exactly like the pool/tile/spill knobs.
//!
//! ## Selection and overrides
//!
//! Priority, highest first:
//!
//! 1. [`force_isa`] / [`force_scope`] — programmatic override (the CLI
//!    `--isa` flag, [`ComputeContext::with_isa`](crate::fastcv::context::ComputeContext::with_isa),
//!    and the conformance/golden tests);
//! 2. the `FASTCV_FORCE_ISA` environment variable (`scalar` | `avx2` |
//!    `neon`), read once — how CI's ISA matrix drives each dispatch path;
//! 3. runtime CPU-feature detection, widest supported ISA wins.
//!
//! A forced ISA the CPU cannot run is a loud error ([`force_isa`] returns
//! `Err`; a bad `FASTCV_FORCE_ISA` value panics at first kernel use) — a
//! test or bench leg that silently fell back to scalar would claim coverage
//! it does not have.

use crate::linalg::mat::Mat;
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock, PoisonError};

/// An instruction-set choice for the `linalg` microkernels. All variants
/// exist on every architecture (so tags always parse); [`Isa::supported`]
/// says which ones this CPU can actually run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Isa {
    /// Portable scalar reference kernels — the canonical accumulation order.
    Scalar,
    /// x86-64 AVX2 (4×f64 lanes), runtime-detected.
    Avx2,
    /// aarch64 NEON (2×f64 lanes), baseline on aarch64.
    Neon,
}

impl Isa {
    /// Stable lowercase tag (`scalar` | `avx2` | `neon`) — the CLI `--isa`
    /// and `FASTCV_FORCE_ISA` vocabulary, also used in bench labels.
    pub fn tag(&self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }

    /// Parse a [`Isa::tag`] string.
    pub fn from_tag(tag: &str) -> Option<Isa> {
        match tag {
            "scalar" => Some(Isa::Scalar),
            "avx2" => Some(Isa::Avx2),
            "neon" => Some(Isa::Neon),
            _ => None,
        }
    }

    /// The ISAs this CPU can run, narrowest first (`Scalar` is always
    /// first; the widest entry is what auto-detection picks). Conformance
    /// tests iterate this to exercise every dispatch path reachable on the
    /// host.
    pub fn supported() -> Vec<Isa> {
        let mut v = vec![Isa::Scalar];
        #[cfg(target_arch = "x86_64")]
        if std::arch::is_x86_feature_detected!("avx2") {
            v.push(Isa::Avx2);
        }
        #[cfg(target_arch = "aarch64")]
        v.push(Isa::Neon);
        v
    }

    /// Is this ISA runnable on the current CPU?
    pub fn is_supported(&self) -> bool {
        Self::supported().contains(self)
    }
}

impl std::fmt::Display for Isa {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.tag())
    }
}

/// The per-ISA kernel table: register-tile geometry plus the primitive
/// inner loops every `linalg` entry point routes through. All function
/// pointers in one table produce bitwise-identical results to the
/// [`SCALAR`] table's — the table only chooses *how fast* the canonical
/// order runs.
pub struct Kernels {
    /// Which ISA this table implements.
    pub isa: Isa,
    /// GEMM register-tile rows (`MR`): height of the packed A slivers.
    pub gemm_mr: usize,
    /// GEMM register-tile columns (`NR`): width of the packed B slivers.
    pub gemm_nr: usize,
    /// The `MR×NR` GEMM micro-kernel over packed slivers
    /// (`C[ci.., cj..] += alpha·A·B`, masked to `mr×nr` live outputs).
    pub micro: fn(&mut Mat, &[f64], &[f64], usize, usize, usize, usize, usize, f64),
    /// `acc[t] += a · x[t]` in ascending `t` (mul-then-add per element) —
    /// the syrk band update, `ger`, and `matvec_t` inner loop.
    pub axpy: fn(&mut [f64], f64, &[f64]),
    /// `acc[t] -= a · x[t]` in ascending `t` (mul-then-sub per element) —
    /// the triangular-solve RHS update loops.
    pub axpy_sub: fn(&mut [f64], f64, &[f64]),
    /// Dot product in the canonical 4-partial order
    /// (`((s0+s1)+s2)+s3` over stride-4 partials, sequential tail) — the
    /// Cholesky/LU recurrence inner product.
    pub dot: fn(&[f64], &[f64]) -> f64,
    /// Pack an `mc×kc` A block into `mr`-tall row slivers
    /// (`(a, i0, mc, k0, kc, mr, pack)`). Pure data movement: every table's
    /// packer emits **byte-identical** buffers (the packed-bytes contract;
    /// `kernel_conformance_pack_bytes_identical_across_isas`) — the SIMD
    /// entries only move the same bytes with wider loads/stores.
    pub pack_a: fn(&Mat, usize, usize, usize, usize, usize, &mut [f64]),
    /// Pack a `kc`-row B panel into `nr`-wide column slivers
    /// (`(b, k0, kc, nr, pack)`). Same byte-identity contract as
    /// [`Kernels::pack_a`].
    pub pack_b: fn(&Mat, usize, usize, usize, &mut [f64]),
}

/// The scalar reference table — the canonical accumulation order itself.
static SCALAR: Kernels = Kernels {
    isa: Isa::Scalar,
    gemm_mr: crate::linalg::gemm::SCALAR_MR,
    gemm_nr: crate::linalg::gemm::SCALAR_NR,
    micro: crate::linalg::gemm::micro_kernel_scalar,
    axpy: crate::linalg::gemm::axpy_scalar,
    axpy_sub: crate::linalg::gemm::axpy_sub_scalar,
    dot: crate::linalg::gemm::dot_scalar,
    pack_a: crate::linalg::gemm::pack_a_scalar,
    pack_b: crate::linalg::gemm::pack_b_scalar,
};

#[cfg(target_arch = "x86_64")]
static AVX2: Kernels = Kernels {
    isa: Isa::Avx2,
    gemm_mr: crate::linalg::simd_avx2::MR,
    gemm_nr: crate::linalg::simd_avx2::NR,
    micro: crate::linalg::simd_avx2::micro_kernel,
    axpy: crate::linalg::simd_avx2::axpy,
    axpy_sub: crate::linalg::simd_avx2::axpy_sub,
    dot: crate::linalg::simd_avx2::dot,
    pack_a: crate::linalg::simd_avx2::pack_a,
    pack_b: crate::linalg::simd_avx2::pack_b,
};

#[cfg(target_arch = "aarch64")]
static NEON: Kernels = Kernels {
    isa: Isa::Neon,
    gemm_mr: crate::linalg::simd_neon::MR,
    gemm_nr: crate::linalg::simd_neon::NR,
    micro: crate::linalg::simd_neon::micro_kernel,
    axpy: crate::linalg::simd_neon::axpy,
    axpy_sub: crate::linalg::simd_neon::axpy_sub,
    dot: crate::linalg::simd_neon::dot,
    pack_a: crate::linalg::simd_neon::pack_a,
    pack_b: crate::linalg::simd_neon::pack_b,
};

/// The kernel table for an ISA. The caller must hold a supported `isa`
/// (see [`Isa::supported`]); an unsupported one falls back to the scalar
/// table on a foreign architecture build, which keeps this total without
/// `unsafe` feature assumptions — [`force_isa`] is the validating gate.
pub fn kernels(isa: Isa) -> &'static Kernels {
    match isa {
        Isa::Scalar => &SCALAR,
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => &AVX2,
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => &NEON,
        #[allow(unreachable_patterns)] // arms above are cfg-gated
        _ => &SCALAR,
    }
}

/// `0` = no override; otherwise `Isa as u8 + 1`.
static FORCED: AtomicU8 = AtomicU8::new(0);
/// Serialises [`force_scope`] users (tests) so nested scopes can't
/// interleave their restore writes.
static FORCE_LOCK: Mutex<()> = Mutex::new(());

fn isa_to_u8(isa: Isa) -> u8 {
    match isa {
        Isa::Scalar => 1,
        Isa::Avx2 => 2,
        Isa::Neon => 3,
    }
}

fn isa_from_u8(v: u8) -> Option<Isa> {
    match v {
        1 => Some(Isa::Scalar),
        2 => Some(Isa::Avx2),
        3 => Some(Isa::Neon),
        _ => None,
    }
}

/// `FASTCV_FORCE_ISA`, parsed once. An unknown tag or an ISA the CPU
/// cannot run is a configuration error and must fail loudly — a CI matrix
/// leg that silently re-anchored to scalar would claim coverage it does
/// not have.
fn env_force() -> Option<Isa> {
    static ENV: OnceLock<Option<Isa>> = OnceLock::new();
    *ENV.get_or_init(|| {
        let tag = std::env::var("FASTCV_FORCE_ISA").ok()?;
        let isa = Isa::from_tag(&tag).unwrap_or_else(|| {
            // lint:allow(panic, reason = "FASTCV_FORCE_ISA misconfiguration must fail loudly, not silently fall back and fake ISA coverage")
            panic!("FASTCV_FORCE_ISA={tag:?} is not a known ISA (scalar|avx2|neon)")
        });
        if !isa.is_supported() {
            // lint:allow(panic, reason = "forcing an ISA this CPU cannot run must fail loudly, not silently fall back and fake ISA coverage")
            panic!("FASTCV_FORCE_ISA={tag} is not supported on this CPU (supported: {:?})", Isa::supported());
        }
        Some(isa)
    })
}

/// The ISA the next kernel call will run: programmatic override, else
/// `FASTCV_FORCE_ISA`, else the widest CPU-supported ISA. Cheap (one
/// relaxed atomic load after first use).
pub fn active() -> Isa {
    if let Some(f) = isa_from_u8(FORCED.load(Ordering::Relaxed)) {
        return f;
    }
    if let Some(e) = env_force() {
        return e;
    }
    static DETECTED: OnceLock<Isa> = OnceLock::new();
    *DETECTED.get_or_init(|| *Isa::supported().last().unwrap_or(&Isa::Scalar))
}

/// The kernel table for [`active`].
pub fn active_kernels() -> &'static Kernels {
    kernels(active())
}

/// Install (or with `None`, clear) a process-wide ISA override — the CLI
/// `--isa` flag and `ComputeContext::with_isa` land here. Errors on an ISA
/// this CPU cannot run. Takes effect for every subsequent kernel call in
/// the process; results are bitwise-unchanged by construction (the
/// conformance contract), so this is a wall-clock/testing knob only.
pub fn force_isa(isa: Option<Isa>) -> Result<()> {
    if let Some(isa) = isa {
        if !isa.is_supported() {
            bail!(
                "ISA {} is not supported on this CPU (supported: {})",
                isa.tag(),
                Isa::supported().iter().map(Isa::tag).collect::<Vec<_>>().join(", ")
            );
        }
    }
    FORCED.store(isa.map_or(0, isa_to_u8), Ordering::Relaxed);
    Ok(())
}

/// A scoped ISA override for tests: forces `isa` until the guard drops,
/// then restores the previous override. Holds a global lock so concurrent
/// `force_scope` users serialise (results could never differ — the bitwise
/// contract — but an interleaved restore could leave the wrong override
/// installed).
pub fn force_scope(isa: Isa) -> Result<ForcedIsa> {
    let lock = FORCE_LOCK.lock().unwrap_or_else(PoisonError::into_inner);
    let prev = FORCED.load(Ordering::Relaxed);
    force_isa(Some(isa))?;
    Ok(ForcedIsa { prev, _lock: lock })
}

/// Guard returned by [`force_scope`]; restores the previous override on
/// drop.
pub struct ForcedIsa {
    prev: u8,
    _lock: MutexGuard<'static, ()>,
}

impl Drop for ForcedIsa {
    fn drop(&mut self) {
        FORCED.store(self.prev, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tags_round_trip() {
        for isa in [Isa::Scalar, Isa::Avx2, Isa::Neon] {
            assert_eq!(Isa::from_tag(isa.tag()), Some(isa));
        }
        assert_eq!(Isa::from_tag("sse9"), None);
    }

    #[test]
    fn scalar_is_always_supported_and_first() {
        let sup = Isa::supported();
        assert_eq!(sup.first(), Some(&Isa::Scalar));
        assert!(Isa::Scalar.is_supported());
    }

    #[test]
    fn kernel_tables_carry_their_isa_and_sane_tiles() {
        for isa in Isa::supported() {
            let k = kernels(isa);
            assert_eq!(k.isa, isa);
            assert!(k.gemm_mr >= 1 && k.gemm_mr <= crate::linalg::gemm::MR_MAX);
            assert!(k.gemm_nr >= 1 && k.gemm_nr <= crate::linalg::gemm::NR_MAX);
        }
    }

    #[test]
    fn force_scope_installs_and_restores() {
        let before = active();
        {
            let _g = force_scope(Isa::Scalar).unwrap();
            assert_eq!(active(), Isa::Scalar);
        }
        assert_eq!(active(), before);
    }

    #[test]
    fn forcing_an_unsupported_isa_errors() {
        // At most one of Avx2/Neon is supported on any real target, so the
        // other must be rejected; on plain x86-64-without-AVX2 both are.
        let unsupported: Vec<Isa> =
            [Isa::Avx2, Isa::Neon].into_iter().filter(|i| !i.is_supported()).collect();
        for isa in unsupported {
            assert!(force_isa(Some(isa)).is_err(), "{isa} should be rejected");
        }
        // clearing is always fine
        force_isa(None).unwrap();
    }
}
