//! BLAS-3-style kernels: blocked matrix multiply, symmetric rank-k update,
//! matrix–vector products.
//!
//! The standard-approach baseline spends its time in `S_w` formation (syrk)
//! and solves, and the analytical approach in the one-off hat-matrix build;
//! both paths run through these kernels, so they are written with cache
//! blocking + a small register-tiled micro-kernel rather than naive triple
//! loops. See EXPERIMENTS.md §Perf for measured GFLOP/s.
//!
//! The inner loops — the `MR×NR` GEMM micro-kernel, the syrk band update,
//! the triangular-solve RHS update, and [`dot`] — are routed through
//! [`crate::linalg::dispatch`]: the scalar reference implementations in
//! this file define the *canonical accumulation order*, and the per-ISA
//! SIMD kernels (`linalg::simd_avx2`, `linalg::simd_neon`) reproduce it
//! bit-for-bit (see the dispatch module docs for the contract and the
//! `kernel_conformance_*` suite for its enforcement). Register-tile
//! geometry (`MR×NR`) comes from the selected kernel table; the cache
//! blocking (`MC`, `KC`) is ISA-independent, and `KC` is what pins the
//! per-element partial-sum split, so changing `MR×NR` never changes bits.

use super::dispatch::{self, Isa, Kernels};
use super::mat::Mat;

/// Cache-block sizes (f64): MC×KC panel of A (~256 KB, L2-resident),
/// KC×NR slivers of B streamed from L1. `KC` is part of the bitwise
/// contract (it fixes where per-element partial sums split); `MC` is not.
const MC: usize = 128;
const KC: usize = 256;
/// Scalar reference register tile: 4 packed-A rows × 8 packed-B columns.
pub(crate) const SCALAR_MR: usize = 4;
/// See [`SCALAR_MR`].
pub(crate) const SCALAR_NR: usize = 8;
/// Upper bounds on any kernel table's `MR`/`NR` — sizes the stack-allocated
/// sliver scratch in the packers (dispatch's table test pins tables to it).
pub(crate) const MR_MAX: usize = 8;
/// See [`MR_MAX`].
pub(crate) const NR_MAX: usize = 8;

/// `C = A · B` under the active ISA (see [`crate::linalg::dispatch`]).
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    let mut c = Mat::zeros(a.rows(), b.cols());
    gemm_acc(&mut c, a, b, 1.0, 0.0);
    c
}

/// [`matmul`] under an explicit ISA — the conformance suite's entry point.
pub fn matmul_isa(a: &Mat, b: &Mat, isa: Isa) -> Mat {
    let mut c = Mat::zeros(a.rows(), b.cols());
    gemm_acc_isa(&mut c, a, b, 1.0, 0.0, isa);
    c
}

/// `C = alpha · A·B + beta · C` (general update; C must be preallocated)
/// under the active ISA.
pub fn gemm_acc(c: &mut Mat, a: &Mat, b: &Mat, alpha: f64, beta: f64) {
    gemm_acc_k(c, a, b, alpha, beta, dispatch::active_kernels());
}

/// [`gemm_acc`] under an explicit ISA — the conformance suite's entry
/// point. Bitwise-identical to every other ISA by the dispatch contract.
pub fn gemm_acc_isa(c: &mut Mat, a: &Mat, b: &Mat, alpha: f64, beta: f64, isa: Isa) {
    gemm_acc_k(c, a, b, alpha, beta, dispatch::kernels(isa));
}

fn gemm_acc_k(c: &mut Mat, a: &Mat, b: &Mat, alpha: f64, beta: f64, kr: &Kernels) {
    let (m, ka) = a.shape();
    let (kb, n) = b.shape();
    assert_eq!(ka, kb, "gemm inner-dim mismatch: {ka} vs {kb}");
    assert_eq!(c.shape(), (m, n), "gemm output shape mismatch");
    if beta != 1.0 {
        if beta == 0.0 {
            c.as_mut_slice().fill(0.0);
        } else {
            c.scale(beta);
        }
    }
    if m == 0 || n == 0 || ka == 0 || alpha == 0.0 {
        return;
    }

    let (mr, nr) = (kr.gemm_mr, kr.gemm_nr);
    // Packed panels reused across the j-loop. The A pack rounds MC up to a
    // whole number of MR-tall slivers (MR need not divide MC — AVX2/NEON
    // use MR=6 against MC=128).
    let mut a_pack = vec![0.0f64; MC.next_multiple_of(mr) * KC];
    let mut b_pack = vec![0.0f64; KC * n.next_multiple_of(nr)];

    for k0 in (0..ka).step_by(KC) {
        let kc = KC.min(ka - k0);
        // Pack B panel: KC×n, laid out as NR-wide column slivers.
        (kr.pack_b)(b, k0, kc, nr, &mut b_pack);
        for i0 in (0..m).step_by(MC) {
            let mc = MC.min(m - i0);
            // Pack A block: mc×kc as MR-tall row slivers.
            (kr.pack_a)(a, i0, mc, k0, kc, mr, &mut a_pack);
            macro_kernel(c, &a_pack, &b_pack, i0, mc, kc, n, alpha, kr);
        }
    }
}

/// Pack `a[i0.., k0..]` (`mc×kc`) as `mr`-tall row slivers: for each
/// sliver, `kc` columns of `mr` values, dead tail rows zero-filled. Packed
/// bytes depend only on `(a, i0, mc, k0, kc, mr)` — **never on the ISA
/// that will consume them, nor on the ISA that packed them**: the SIMD
/// packers (`simd_avx2::pack_a`, `simd_neon::pack_a`) are pure data
/// movement and must emit byte-identical buffers
/// (`kernel_conformance_pack_bytes_identical_across_isas`).
pub(crate) fn pack_a_scalar(a: &Mat, i0: usize, mc: usize, k0: usize, kc: usize, mr: usize, pack: &mut [f64]) {
    // Row slices are resolved once per sliver so the hot loop reads
    // contiguous slices instead of going through the (r, c) indexing
    // operator per element — identical packed bytes, fewer index
    // computations and bounds checks.
    debug_assert!(mr >= 1 && mr <= MR_MAX);
    const EMPTY: &[f64] = &[];
    let mut idx = 0;
    let mut i = 0;
    while i < mc {
        let live = mr.min(mc - i);
        let mut rows: [&[f64]; MR_MAX] = [EMPTY; MR_MAX];
        for (r, slot) in rows.iter_mut().enumerate().take(live) {
            *slot = &a.row(i0 + i + r)[k0..k0 + kc];
        }
        for k in 0..kc {
            for (r, row) in rows.iter().enumerate().take(mr) {
                pack[idx] = if r < live { row[k] } else { 0.0 };
                idx += 1;
            }
        }
        i += mr;
    }
}

/// Pack rows `k0..k0+kc` of `b` as `nr`-wide column slivers (tail lanes
/// zero-filled). Packed bytes depend only on `(b, k0, kc, nr)` — byte
/// contract as [`pack_a_scalar`].
pub(crate) fn pack_b_scalar(b: &Mat, k0: usize, kc: usize, nr: usize, pack: &mut [f64]) {
    debug_assert!(nr >= 1 && nr <= NR_MAX);
    let n = b.cols();
    let mut idx = 0;
    let mut j = 0;
    while j < n {
        let live = nr.min(n - j);
        for k in 0..kc {
            let row = &b.row(k0 + k)[j..j + live];
            pack[idx..idx + live].copy_from_slice(row);
            pack[idx + live..idx + nr].fill(0.0);
            idx += nr;
        }
        j += nr;
    }
}

#[allow(clippy::too_many_arguments)]
fn macro_kernel(c: &mut Mat, a_pack: &[f64], b_pack: &[f64], i0: usize, mc: usize, kc: usize, n: usize, alpha: f64, kr: &Kernels) {
    let (mr, nr) = (kr.gemm_mr, kr.gemm_nr);
    let mut j = 0;
    let mut jb = 0; // sliver index into b_pack
    while j < n {
        let nrl = nr.min(n - j);
        let b_sl = &b_pack[jb * kc * nr..(jb + 1) * kc * nr];
        let mut i = 0;
        let mut ib = 0;
        while i < mc {
            let mrl = mr.min(mc - i);
            let a_sl = &a_pack[ib * kc * mr..(ib + 1) * kc * mr];
            (kr.micro)(c, a_sl, b_sl, i0 + i, j, mrl, nrl, kc, alpha);
            i += mr;
            ib += 1;
        }
        j += nr;
        jb += 1;
    }
}

/// Scalar `MR×NR` register-tiled micro-kernel:
/// `C[ci..ci+mr, cj..cj+nr] += alpha·A·B` over packed slivers. This is the
/// canonical accumulation order every SIMD kernel must reproduce bitwise:
/// per output element, one `acc += a·b` (two roundings) per `k` in
/// ascending order, then one `c += alpha·acc` at writeback.
#[allow(clippy::too_many_arguments)]
#[inline]
pub(crate) fn micro_kernel_scalar(c: &mut Mat, a_sl: &[f64], b_sl: &[f64], ci: usize, cj: usize, mr: usize, nr: usize, kc: usize, alpha: f64) {
    let mut acc = [[0.0f64; SCALAR_NR]; SCALAR_MR];
    let mut ap = 0;
    let mut bp = 0;
    for _ in 0..kc {
        let a0 = a_sl[ap];
        let a1 = a_sl[ap + 1];
        let a2 = a_sl[ap + 2];
        let a3 = a_sl[ap + 3];
        let bv: &[f64] = &b_sl[bp..bp + SCALAR_NR];
        for r in 0..SCALAR_NR {
            let b = bv[r];
            acc[0][r] += a0 * b;
            acc[1][r] += a1 * b;
            acc[2][r] += a2 * b;
            acc[3][r] += a3 * b;
        }
        ap += SCALAR_MR;
        bp += SCALAR_NR;
    }
    for r in 0..mr {
        let crow = c.row_mut(ci + r);
        for s in 0..nr {
            crow[cj + s] += alpha * acc[r][s];
        }
    }
}

/// `C = A·B` with row panels of `A` fanned out over a
/// [`ThreadPool`](crate::util::threadpool::ThreadPool).
///
/// Bit-identical to [`matmul`] for any pool size or panel split: every
/// output element is produced by the same blocked kernel, and its
/// accumulation order (sequential within each KC block, blocks added in
/// ascending `k0`) does not depend on which row panel the element's row
/// lands in. The dual Gram build (`K_c = X_c X_cᵀ`, `N×N×P` flops) is the
/// intended caller. Falls back to the serial kernel when no pool is given,
/// the pool has a single worker, or `A` is too short to split.
///
/// The same split-invariance of the per-element accumulation order is what
/// makes [`crate::linalg::tiled::gram_tiled`]'s two-sided tiling — row
/// *and* column panels, with operand slabs materialised on demand — bit-
/// identical to this kernel; see that module for the memory-bounded form.
pub fn matmul_pool(a: &Mat, b: &Mat, pool: Option<&crate::util::threadpool::ThreadPool>) -> Mat {
    let pool = match pool {
        Some(p) if p.size() > 1 && a.rows() >= 2 * SCALAR_MR => p,
        _ => return matmul(a, b),
    };
    let panels = (pool.size() * 2).min(a.rows());
    let panel_rows = a.rows().div_ceil(panels);
    let ranges: Vec<(usize, usize)> = (0..a.rows())
        .step_by(panel_rows)
        .map(|lo| (lo, (lo + panel_rows).min(a.rows())))
        .collect();
    let blocks = pool.map(ranges.len(), |c| {
        let (lo, hi) = ranges[c];
        let idx: Vec<usize> = (lo..hi).collect();
        matmul(&a.take_rows(&idx), b)
    });
    let mut data = Vec::with_capacity(a.rows() * b.cols());
    for blk in blocks {
        data.extend_from_slice(blk.as_slice());
    }
    Mat::from_vec(a.rows(), b.cols(), data)
}

/// `AᵀA` symmetric rank-k update (forms the scatter/gram matrix). Only the
/// upper triangle is computed then mirrored. See [`syrk_t_pool`] for the
/// pool-parallel panel fan-out (bit-identical output).
pub fn syrk_t(a: &Mat) -> Mat {
    syrk_t_isa(a, dispatch::active())
}

/// [`syrk_t`] under an explicit ISA — the conformance suite's entry point.
pub fn syrk_t_isa(a: &Mat, isa: Isa) -> Mat {
    let p = a.cols();
    let mut g = Mat::zeros(p, p);
    syrk_t_rows_into_k(a, 0, p, g.as_mut_slice(), dispatch::kernels(isa));
    mirror_upper(&mut g);
    g
}

/// Rows `lo..hi` of the upper triangle of `AᵀA`, as an `(hi-lo)×p` block
/// (entries left of the diagonal stay zero). The accumulation into every
/// element `g[(j,k)]` runs over the sample index `i` in ascending order, so
/// the per-element float sequence — and hence the result — is independent
/// of how `0..p` is split into `[lo, hi)` panels. That independence is what
/// makes [`syrk_t_pool`] bit-identical to [`syrk_t`].
fn syrk_t_rows(a: &Mat, lo: usize, hi: usize) -> Mat {
    let mut g = Mat::zeros(hi - lo, a.cols());
    syrk_t_rows_into(a, lo, hi, g.as_mut_slice());
    g
}

/// The accumulation loop of `syrk_t_rows` into a caller-owned zeroed band
/// (`(hi-lo)×p`, row-major) — what lets [`crate::linalg::syrk_tiled`]
/// write its output bands straight into disjoint slices of the final `p×p`
/// Gram without holding per-band copies. Identical arithmetic.
pub(crate) fn syrk_t_rows_into(a: &Mat, lo: usize, hi: usize, band: &mut [f64]) {
    syrk_t_rows_into_k(a, lo, hi, band, dispatch::active_kernels());
}

/// The band kernel under an explicit kernel table. The inner loop is an
/// `axpy` over the upper-triangle row tail (`grow[j..] += aij · row[j..]`,
/// ascending `k`, one mul-then-add per element) — exactly the scalar
/// sequence, whichever table runs it.
fn syrk_t_rows_into_k(a: &Mat, lo: usize, hi: usize, band: &mut [f64], kr: &Kernels) {
    let (n, p) = a.shape();
    debug_assert_eq!(band.len(), (hi - lo) * p);
    // Process in row panels of A to keep accumulation cache-friendly.
    const PANEL: usize = 64;
    for i0 in (0..n).step_by(PANEL) {
        let i1 = (i0 + PANEL).min(n);
        for i in i0..i1 {
            let row = a.row(i);
            for j in lo..hi {
                let aij = row[j];
                if aij == 0.0 {
                    continue;
                }
                let grow = &mut band[(j - lo) * p..(j - lo + 1) * p];
                // upper triangle only
                (kr.axpy)(&mut grow[j..], aij, &row[j..]);
            }
        }
    }
}

/// Copy the upper triangle of `g` onto the lower.
pub(crate) fn mirror_upper(g: &mut Mat) {
    let p = g.rows();
    for j in 0..p {
        for k in (j + 1)..p {
            g[(k, j)] = g[(j, k)];
        }
    }
}

/// [`syrk_t`] with panels of output columns fanned out over a
/// [`ThreadPool`](crate::util::threadpool::ThreadPool).
///
/// Bit-identical to the serial kernel for any pool size or panel split:
/// every upper-triangle element accumulates over the sample index in the
/// same (ascending) order whichever panel its row lands in — see
/// `syrk_t_rows`. The primal gram build `G₀ = X̃ᵀX̃`
/// ([`crate::fastcv::hat::GramCache`]'s `Primal` arm) is the intended
/// caller; it is `O(NP²)`, dominated by `P` on wide shapes, which is
/// exactly where the panels are plentiful. Falls back to the serial kernel
/// when no pool is given, the pool has one worker, or `A` is too narrow to
/// split.
pub fn syrk_t_pool(a: &Mat, pool: Option<&crate::util::threadpool::ThreadPool>) -> Mat {
    let p = a.cols();
    let pool = match pool {
        Some(pl) if pl.size() > 1 && p >= 16 => pl,
        _ => return syrk_t(a),
    };
    // 4× oversubscription: the leading panels own longer upper-triangle
    // rows, so extra chunks let idle workers steal the short tail.
    let chunks = (pool.size() * 4).min(p);
    let chunk_len = p.div_ceil(chunks);
    let ranges: Vec<(usize, usize)> = (0..p)
        .step_by(chunk_len)
        .map(|lo| (lo, (lo + chunk_len).min(p)))
        .collect();
    let blocks = pool.map(ranges.len(), |c| {
        let (lo, hi) = ranges[c];
        syrk_t_rows(a, lo, hi)
    });
    let mut g = Mat::zeros(p, p);
    for (&(lo, hi), blk) in ranges.iter().zip(&blocks) {
        for j in lo..hi {
            g.row_mut(j).copy_from_slice(blk.row(j - lo));
        }
    }
    mirror_upper(&mut g);
    g
}

/// `y = A·x`.
pub fn matvec(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols(), x.len());
    (0..a.rows()).map(|i| dot(a.row(i), x)).collect()
}

/// `y = A·x` with the *exact accumulation order* of [`gemm_acc`]'s blocked
/// kernel: a sequential partial sum per KC-block of the inner dimension,
/// block partials added in ascending order. The result is therefore
/// bit-identical to one column of a `matmul` of any width — which is what
/// lets the serial permutation engine (single response) and the batched
/// engine (`N×B` responses) produce byte-equal decision values. Same flop
/// count as [`matvec`]; only the summation association differs.
///
/// Deliberately scalar under every ISA: a single column cannot be
/// lane-split without changing lanes from *elements* to *partials*, and
/// the per-element order here (sequential within each KC block) is what
/// every ISA's `matmul` column reproduces — so this stays the serial ↔
/// batched bridge regardless of dispatch.
pub fn matvec_gemm_order(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols(), x.len());
    let mut y = vec![0.0; a.rows()];
    for k0 in (0..a.cols()).step_by(KC) {
        let kc = KC.min(a.cols() - k0);
        for (i, yi) in y.iter_mut().enumerate() {
            let row = &a.row(i)[k0..k0 + kc];
            let xs = &x[k0..k0 + kc];
            let mut acc = 0.0;
            for (av, xv) in row.iter().zip(xs) {
                acc += av * xv;
            }
            *yi += acc;
        }
    }
    y
}

/// `y = Aᵀ·x`. Row-axpy form, dispatched.
pub fn matvec_t(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows(), x.len());
    let kr = dispatch::active_kernels();
    let mut y = vec![0.0; a.cols()];
    for i in 0..a.rows() {
        let xi = x[i];
        if xi == 0.0 {
            continue;
        }
        (kr.axpy)(&mut y, xi, a.row(i));
    }
    y
}

/// Dot product under the active ISA, in the canonical 4-partial order of
/// [`dot_scalar`] (bitwise-identical whichever table runs it).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    (dispatch::active_kernels().dot)(a, b)
}

/// Scalar reference dot product with 4-way unrolling: stride-4 partials
/// `s0..s3`, reduced `((s0+s1)+s2)+s3`, then a sequential tail. This *is*
/// the canonical order; SIMD `dot` kernels map lane `r` to partial `s_r`.
#[inline]
pub(crate) fn dot_scalar(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = 4 * c;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in 4 * chunks..n {
        s += a[i] * b[i];
    }
    s
}

/// Scalar reference `acc[t] += a · x[t]` (ascending `t`, one rounded
/// multiply then one rounded add per element) — the canonical order for
/// the syrk band update, [`ger`], and [`matvec_t`] inner loops.
#[inline]
pub(crate) fn axpy_scalar(acc: &mut [f64], a: f64, x: &[f64]) {
    debug_assert_eq!(acc.len(), x.len());
    for (ai, &xi) in acc.iter_mut().zip(x) {
        *ai += a * xi;
    }
}

/// Scalar reference `acc[t] -= a · x[t]` (ascending `t`) — the canonical
/// order for the triangular-solve RHS update loops in `chol`/`spill`.
#[inline]
pub(crate) fn axpy_sub_scalar(acc: &mut [f64], a: f64, x: &[f64]) {
    debug_assert_eq!(acc.len(), x.len());
    for (ai, &xi) in acc.iter_mut().zip(x) {
        *ai -= a * xi;
    }
}

/// Outer-product accumulate: `M += alpha · u vᵀ`. Row-axpy form,
/// dispatched.
pub fn ger(m: &mut Mat, alpha: f64, u: &[f64], v: &[f64]) {
    assert_eq!(m.rows(), u.len());
    assert_eq!(m.cols(), v.len());
    let kr = dispatch::active_kernels();
    for i in 0..u.len() {
        let au = alpha * u[i];
        if au == 0.0 {
            continue;
        }
        (kr.axpy)(m.row_mut(i), au, v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for k in 0..a.cols() {
                let aik = a[(i, k)];
                for j in 0..b.cols() {
                    c[(i, j)] += aik * b[(k, j)];
                }
            }
        }
        c
    }

    fn random_mat(rng: &mut Rng, r: usize, c: usize) -> Mat {
        Mat::from_fn(r, c, |_, _| rng.gauss())
    }

    #[test]
    fn matmul_matches_naive_awkward_shapes() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (4, 8, 8), (17, 33, 9), (65, 129, 31), (130, 7, 257)] {
            let a = random_mat(&mut rng, m, k);
            let b = random_mat(&mut rng, k, n);
            let c = matmul(&a, &b);
            let r = naive_matmul(&a, &b);
            assert!(c.max_abs_diff(&r) < 1e-10, "shape ({m},{k},{n})");
        }
    }

    #[test]
    fn gemm_acc_alpha_beta() {
        let mut rng = Rng::new(2);
        let a = random_mat(&mut rng, 13, 7);
        let b = random_mat(&mut rng, 7, 11);
        let c0 = random_mat(&mut rng, 13, 11);
        let mut c = c0.clone();
        gemm_acc(&mut c, &a, &b, 2.0, 0.5);
        let mut expect = naive_matmul(&a, &b);
        expect.scale(2.0);
        let mut half = c0.clone();
        half.scale(0.5);
        expect.axpy(1.0, &half);
        assert!(c.max_abs_diff(&expect) < 1e-10);
    }

    #[test]
    fn empty_dims_ok() {
        let a = Mat::zeros(0, 3);
        let b = Mat::zeros(3, 2);
        assert_eq!(matmul(&a, &b).shape(), (0, 2));
        let a = Mat::zeros(2, 0);
        let b = Mat::zeros(0, 2);
        assert_eq!(matmul(&a, &b).as_slice(), &[0.0; 4]);
    }

    #[test]
    fn syrk_matches_matmul() {
        let mut rng = Rng::new(3);
        for &(n, p) in &[(10, 4), (5, 17), (33, 33), (64, 20)] {
            let a = random_mat(&mut rng, n, p);
            let g = syrk_t(&a);
            let r = matmul(&a.t(), &a);
            assert!(g.max_abs_diff(&r) < 1e-10, "({n},{p})");
            // symmetry exact
            for i in 0..p {
                for j in 0..p {
                    assert_eq!(g[(i, j)], g[(j, i)]);
                }
            }
        }
    }

    #[test]
    fn matvec_gemm_order_bitwise_matches_matmul_column() {
        // The determinism contract of the permutation engines rests on this:
        // a single-column product in GEMM order equals the corresponding
        // column of a wide GEMM *exactly* (==, not approximately), for inner
        // dimensions below and above the KC blocking threshold — and it must
        // hold under every ISA the host supports, since the serial engine is
        // always scalar while the batched engine dispatches.
        let mut rng = Rng::new(9);
        for &(m, k, extra_cols) in &[(5, 7, 3), (33, 64, 5), (17, 300, 2), (64, 513, 4)] {
            let a = random_mat(&mut rng, m, k);
            let b = random_mat(&mut rng, k, extra_cols + 1);
            let x = b.col(0);
            let y = matvec_gemm_order(&a, &x);
            for isa in Isa::supported() {
                let c = matmul_isa(&a, &b, isa);
                for i in 0..m {
                    assert_eq!(y[i], c[(i, 0)], "({m},{k}) row {i} [{isa}]: not bitwise equal");
                }
            }
            // and it is the same mathematical product as plain matvec
            let y_ref = matvec(&a, &x);
            for i in 0..m {
                assert!((y[i] - y_ref[i]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn backend_pool_syrk_t_bitwise_matches_serial() {
        // The pooled primal gram build relies on this: fanning upper-triangle
        // column panels over the pool must not change a single bit, including
        // through the aij == 0 skip path.
        let mut rng = Rng::new(12);
        let pool = crate::util::threadpool::ThreadPool::new(4);
        for &(n, p) in &[(10usize, 4usize), (5, 17), (40, 33), (30, 130), (64, 257)] {
            let mut a = random_mat(&mut rng, n, p);
            // sprinkle exact zeros so the skip branch is exercised
            for i in 0..n {
                for j in 0..p {
                    if (i + j) % 7 == 0 {
                        a[(i, j)] = 0.0;
                    }
                }
            }
            let serial = syrk_t(&a);
            let pooled = syrk_t_pool(&a, Some(&pool));
            assert_eq!(serial.as_slice(), pooled.as_slice(), "({n},{p})");
            // no-pool fallback is the serial kernel itself
            let none = syrk_t_pool(&a, None);
            assert_eq!(serial.as_slice(), none.as_slice(), "({n},{p}) fallback");
        }
    }

    #[test]
    fn matmul_pool_bitwise_matches_serial() {
        // The dual Gram build relies on this: fanning row panels over the
        // pool must not change a single bit of the product.
        let mut rng = Rng::new(11);
        let pool = crate::util::threadpool::ThreadPool::new(4);
        for &(m, k, n) in &[(3, 5, 4), (65, 40, 65), (130, 17, 130), (257, 64, 31)] {
            let a = random_mat(&mut rng, m, k);
            let b = random_mat(&mut rng, k, n);
            let serial = matmul(&a, &b);
            let pooled = matmul_pool(&a, &b, Some(&pool));
            assert_eq!(serial.as_slice(), pooled.as_slice(), "({m},{k},{n})");
            // no-pool fallback is the serial kernel itself
            let none = matmul_pool(&a, &b, None);
            assert_eq!(serial.as_slice(), none.as_slice(), "({m},{k},{n}) fallback");
        }
    }

    #[test]
    fn pack_a_b_match_elementwise_reference() {
        // The slice-based packers must produce the identical buffers a
        // per-element (r, c)-indexed loop would — including the zero-padded
        // tail lanes of awkward shapes — for every register-tile geometry a
        // kernel table can request (scalar 4×8, SIMD 6×8, and the MR_MAX
        // bound), not just the scalar one.
        let mut rng = Rng::new(21);
        for &(m, k) in &[(3usize, 5usize), (9, 17), (130, 300)] {
            for &(mr, nr) in &[(SCALAR_MR, SCALAR_NR), (6, 8), (MR_MAX, NR_MAX), (5, 3)] {
                let a = random_mat(&mut rng, m, k);
                let (i0, mc) = (0, m.min(MC));
                let (k0, kc) = (0, k.min(KC));
                let mut pack = vec![f64::NAN; mc.next_multiple_of(mr) * kc];
                pack_a_scalar(&a, i0, mc, k0, kc, mr, &mut pack);
                let mut idx = 0;
                let mut i = 0;
                while i < mc {
                    let live = mr.min(mc - i);
                    for kk in 0..kc {
                        for r in 0..mr {
                            let want = if r < live { a[(i0 + i + r, k0 + kk)] } else { 0.0 };
                            assert_eq!(pack[idx], want, "pack_a ({m},{k}) mr {mr} idx {idx}");
                            idx += 1;
                        }
                    }
                    i += mr;
                }
                let b = random_mat(&mut rng, k, m);
                let n = b.cols();
                let mut packb = vec![f64::NAN; kc * n.next_multiple_of(nr)];
                pack_b_scalar(&b, k0, kc, nr, &mut packb);
                let mut idx = 0;
                let mut j = 0;
                while j < n {
                    let live = nr.min(n - j);
                    for kk in 0..kc {
                        for r in 0..nr {
                            let want = if r < live { b[(k0 + kk, j + r)] } else { 0.0 };
                            assert_eq!(packb[idx], want, "pack_b ({m},{k}) nr {nr} idx {idx}");
                            idx += 1;
                        }
                    }
                    j += nr;
                }
            }
        }
    }

    #[test]
    fn matvec_both_ways() {
        let mut rng = Rng::new(4);
        let a = random_mat(&mut rng, 9, 6);
        let x: Vec<f64> = (0..6).map(|_| rng.gauss()).collect();
        let y = matvec(&a, &x);
        let yy = matmul(&a, &Mat::col_vec(&x));
        for i in 0..9 {
            assert!((y[i] - yy[(i, 0)]).abs() < 1e-12);
        }
        let z: Vec<f64> = (0..9).map(|_| rng.gauss()).collect();
        let w = matvec_t(&a, &z);
        let ww = matmul(&a.t(), &Mat::col_vec(&z));
        for j in 0..6 {
            assert!((w[j] - ww[(j, 0)]).abs() < 1e-12);
        }
    }

    #[test]
    fn ger_accumulates() {
        let mut m = Mat::zeros(3, 2);
        ger(&mut m, 2.0, &[1.0, 0.0, -1.0], &[3.0, 4.0]);
        assert_eq!(m.row(0), &[6.0, 8.0]);
        assert_eq!(m.row(1), &[0.0, 0.0]);
        assert_eq!(m.row(2), &[-6.0, -8.0]);
    }

    #[test]
    fn dot_unrolled_matches() {
        let mut rng = Rng::new(5);
        for len in [0, 1, 3, 4, 7, 64, 101] {
            let a: Vec<f64> = (0..len).map(|_| rng.gauss()).collect();
            let b: Vec<f64> = (0..len).map(|_| rng.gauss()).collect();
            let s: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - s).abs() < 1e-10);
            // the dispatched dot is bitwise the scalar reference
            assert_eq!(dot(&a, &b), dot_scalar(&a, &b));
        }
    }

    #[test]
    fn axpy_scalar_matches_plain_loop() {
        let mut rng = Rng::new(31);
        for len in [0usize, 1, 2, 5, 64, 101] {
            let x: Vec<f64> = (0..len).map(|_| rng.gauss()).collect();
            let mut acc: Vec<f64> = (0..len).map(|_| rng.gauss()).collect();
            let a = rng.gauss();
            let mut want = acc.clone();
            for (w, &xi) in want.iter_mut().zip(&x) {
                *w += a * xi;
            }
            axpy_scalar(&mut acc, a, &x);
            assert_eq!(acc, want, "axpy len {len}");
            let mut want_sub = acc.clone();
            for (w, &xi) in want_sub.iter_mut().zip(&x) {
                *w -= a * xi;
            }
            axpy_sub_scalar(&mut acc, a, &x);
            assert_eq!(acc, want_sub, "axpy_sub len {len}");
        }
    }
}
