//! AVX2 microkernels for the `linalg` hot core (x86-64 only).
//!
//! Every function here is the vector twin of a scalar reference in
//! [`crate::linalg::gemm`], selected at runtime through
//! [`crate::linalg::dispatch`]. The bitwise contract (see the dispatch
//! module docs) is upheld by three rules, visible in every loop below:
//!
//! 1. **lanes are distinct output elements** — a 4-lane `f64` vector holds
//!    four GEMM columns / band entries / stride partials, never four
//!    pieces of one element's sum;
//! 2. **multiply then add, never FMA** — `_mm256_add_pd(acc,
//!    _mm256_mul_pd(a, b))` performs the exact two IEEE roundings the
//!    scalar `acc += a * b` performs (`_mm256_fmadd_pd` would fuse them
//!    into one and change results);
//! 3. **ascending index order** — vector chunks and scalar tails walk the
//!    same ascending element order as the scalar loops.
//!
//! The `kernel_conformance_*` suite pins each function against its scalar
//! reference across shapes, remainder lanes, and NaN/∞ inputs.
//!
//! ## Unsafe audit (rule L3, docs/LINTS.md)
//!
//! `unsafe` appears in exactly two forms: the `#[target_feature(enable =
//! "avx2")] unsafe fn` implementations (whose bodies may use raw-pointer
//! loads/stores; every offset is justified in a comment at the use site
//! against the length `debug_assert!`s at the top), and the one
//! `unsafe { ..._impl(...) }` call inside each safe wrapper, sound because
//! the wrappers are only reachable through `dispatch::kernels(Isa::Avx2)`,
//! which is handed out strictly after `is_x86_feature_detected!("avx2")`
//! (`force_isa` validates explicit requests; auto-detection probes) — and
//! each wrapper re-checks with a `debug_assert!`. No aliasing is possible:
//! sources are `&[f64]`, destinations `&mut [f64]`, and the borrow checker
//! separates them before any pointer is formed.

#![allow(clippy::too_many_arguments)] // microkernel signatures mirror the scalar reference

use crate::linalg::mat::Mat;
use core::arch::x86_64::{
    __m256d, _mm256_add_pd, _mm256_castpd256_pd128, _mm256_extractf128_pd, _mm256_loadu_pd,
    _mm256_mul_pd, _mm256_permute2f128_pd, _mm256_set1_pd, _mm256_setzero_pd, _mm256_storeu_pd,
    _mm256_sub_pd, _mm256_unpackhi_pd, _mm256_unpacklo_pd, _mm_storeu_pd,
};

/// AVX2 GEMM register tile: 6 packed-A rows × 8 packed-B columns (two
/// 4-lane vectors), 12 accumulator registers + 2 B loads + 1 broadcast —
/// comfortably inside the 16 architectural `ymm` registers.
pub(crate) const MR: usize = 6;
/// See [`MR`].
pub(crate) const NR: usize = 8;

/// Does this CPU run these kernels? (Cached by std; cheap.)
#[inline]
fn have_avx2() -> bool {
    std::arch::is_x86_feature_detected!("avx2")
}

/// `MR×NR` GEMM micro-kernel over packed slivers:
/// `C[ci..ci+mr, cj..cj+nr] += alpha · A_sliver · B_sliver`.
///
/// Same contract as `gemm::micro_kernel_scalar`: `a_sl` is `kc` columns of
/// `MR` packed (zero-padded) rows, `b_sl` is `kc` rows of `NR` packed
/// columns, and only the `mr×nr` live outputs are written back.
pub(crate) fn micro_kernel(
    c: &mut Mat,
    a_sl: &[f64],
    b_sl: &[f64],
    ci: usize,
    cj: usize,
    mr: usize,
    nr: usize,
    kc: usize,
    alpha: f64,
) {
    debug_assert!(have_avx2(), "AVX2 kernel dispatched on a CPU without AVX2");
    // SAFETY: AVX2 is present — this wrapper is only installed in the
    // dispatch table after a runtime `is_x86_feature_detected!("avx2")`
    // probe (see the module-level audit note).
    unsafe { micro_kernel_impl(c, a_sl, b_sl, ci, cj, mr, nr, kc, alpha) }
}

// SAFETY: callers must have verified AVX2 support (the safe wrapper above
// is the only caller); the body's raw-pointer accesses are bounded by the
// `debug_assert!`ed packed-sliver lengths, justified per use below.
#[target_feature(enable = "avx2")]
unsafe fn micro_kernel_impl(
    c: &mut Mat,
    a_sl: &[f64],
    b_sl: &[f64],
    ci: usize,
    cj: usize,
    mr: usize,
    nr: usize,
    kc: usize,
    alpha: f64,
) {
    debug_assert!(a_sl.len() >= kc * MR && b_sl.len() >= kc * NR);
    debug_assert!(mr <= MR && nr <= NR && nr <= c.cols());
    let ap = a_sl.as_ptr();
    let bp = b_sl.as_ptr();
    // acc[r][h]: row r of the tile, columns 4h..4h+4. Lanes are distinct
    // output columns; each accumulates its own `+= a·b` sequence over k in
    // ascending order — the canonical order, two roundings per step.
    let mut acc = [[_mm256_setzero_pd(); 2]; MR];
    for k in 0..kc {
        // In bounds: k < kc and b_sl.len() >= kc*NR, so offsets k*8 and
        // k*8+4 each leave 4 readable lanes.
        let b0 = _mm256_loadu_pd(bp.add(k * NR));
        let b1 = _mm256_loadu_pd(bp.add(k * NR + 4));
        for (r, accr) in acc.iter_mut().enumerate() {
            // In bounds: k < kc, r < MR, a_sl.len() >= kc*MR.
            let ar = _mm256_set1_pd(*ap.add(k * MR + r));
            accr[0] = _mm256_add_pd(accr[0], _mm256_mul_pd(ar, b0));
            accr[1] = _mm256_add_pd(accr[1], _mm256_mul_pd(ar, b1));
        }
    }
    // Write back through a lane spill + the scalar update, so the final
    // `c += alpha * acc` op is literally the scalar reference's.
    let mut lanes = [0.0f64; NR];
    for r in 0..mr {
        // In bounds: lanes is NR = 8 long; the two stores cover 0..4, 4..8.
        _mm256_storeu_pd(lanes.as_mut_ptr(), acc[r][0]);
        _mm256_storeu_pd(lanes.as_mut_ptr().add(4), acc[r][1]);
        let crow = c.row_mut(ci + r);
        for s in 0..nr {
            crow[cj + s] += alpha * lanes[s];
        }
    }
}

/// `acc[t] += a · x[t]`, ascending `t`, mul-then-add per element — the
/// vector twin of `gemm::axpy_scalar`.
pub(crate) fn axpy(acc: &mut [f64], a: f64, x: &[f64]) {
    debug_assert!(have_avx2(), "AVX2 kernel dispatched on a CPU without AVX2");
    // SAFETY: AVX2 is present — dispatch-table invariant (module audit
    // note) plus the debug probe above.
    unsafe { axpy_impl(acc, a, x) }
}

// SAFETY: caller must have verified AVX2 (safe wrapper above is the only
// caller); pointer offsets are bounded by the equal slice lengths.
#[target_feature(enable = "avx2")]
unsafe fn axpy_impl(acc: &mut [f64], a: f64, x: &[f64]) {
    let n = acc.len();
    debug_assert_eq!(n, x.len());
    let av = _mm256_set1_pd(a);
    let xp = x.as_ptr();
    let cp = acc.as_mut_ptr();
    let chunks = n / 4;
    for cix in 0..chunks {
        // In bounds: i + 4 <= n for every chunk, on both same-length slices.
        let i = 4 * cix;
        let xv = _mm256_loadu_pd(xp.add(i));
        let cv = _mm256_loadu_pd(cp.add(i));
        _mm256_storeu_pd(cp.add(i), _mm256_add_pd(cv, _mm256_mul_pd(av, xv)));
    }
    for i in 4 * chunks..n {
        acc[i] += a * x[i];
    }
}

/// `acc[t] -= a · x[t]`, ascending `t`, mul-then-sub per element — the
/// vector twin of `gemm::axpy_sub_scalar` (the triangular-solve update).
pub(crate) fn axpy_sub(acc: &mut [f64], a: f64, x: &[f64]) {
    debug_assert!(have_avx2(), "AVX2 kernel dispatched on a CPU without AVX2");
    // SAFETY: AVX2 is present — dispatch-table invariant (module audit
    // note) plus the debug probe above.
    unsafe { axpy_sub_impl(acc, a, x) }
}

// SAFETY: caller must have verified AVX2 (safe wrapper above is the only
// caller); pointer offsets are bounded by the equal slice lengths.
#[target_feature(enable = "avx2")]
unsafe fn axpy_sub_impl(acc: &mut [f64], a: f64, x: &[f64]) {
    let n = acc.len();
    debug_assert_eq!(n, x.len());
    let av = _mm256_set1_pd(a);
    let xp = x.as_ptr();
    let cp = acc.as_mut_ptr();
    let chunks = n / 4;
    for cix in 0..chunks {
        // In bounds: i + 4 <= n for every chunk, on both same-length slices.
        let i = 4 * cix;
        let xv = _mm256_loadu_pd(xp.add(i));
        let cv = _mm256_loadu_pd(cp.add(i));
        _mm256_storeu_pd(cp.add(i), _mm256_sub_pd(cv, _mm256_mul_pd(av, xv)));
    }
    for i in 4 * chunks..n {
        acc[i] -= a * x[i];
    }
}

/// Dot product in the canonical 4-partial order: lane `r` of the vector
/// accumulator is exactly the scalar reference's stride-4 partial `s_r`,
/// and the horizontal reduction spells out `((s0+s1)+s2)+s3` before the
/// sequential tail — bitwise `gemm::dot_scalar`.
pub(crate) fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert!(have_avx2(), "AVX2 kernel dispatched on a CPU without AVX2");
    // SAFETY: AVX2 is present — dispatch-table invariant (module audit
    // note) plus the debug probe above.
    unsafe { dot_impl(a, b) }
}

/// A-block packer: same byte layout as `gemm::pack_a_scalar` (the packed
/// bytes depend only on the inputs — the packed-bytes contract), produced
/// with 4×4 vector transposes for the full `MR = 6` slivers. Geometries
/// other than `MR` and partial/tail slivers delegate to the scalar packer,
/// which writes the identical bytes.
pub(crate) fn pack_a(a: &Mat, i0: usize, mc: usize, k0: usize, kc: usize, mr: usize, pack: &mut [f64]) {
    debug_assert!(have_avx2(), "AVX2 kernel dispatched on a CPU without AVX2");
    if mr != MR {
        // Foreign geometry (conformance probes) — bytes are defined by the
        // scalar packer anyway.
        return crate::linalg::gemm::pack_a_scalar(a, i0, mc, k0, kc, mr, pack);
    }
    // SAFETY: AVX2 is present — dispatch-table invariant (module audit
    // note) plus the debug probe above.
    unsafe { pack_a_impl(a, i0, mc, k0, kc, pack) }
}

// SAFETY: caller must have verified AVX2 (safe wrapper above is the only
// caller); every pointer offset is bounded by the sliver extents asserted
// below and justified per use.
#[target_feature(enable = "avx2")]
unsafe fn pack_a_impl(a: &Mat, i0: usize, mc: usize, k0: usize, kc: usize, pack: &mut [f64]) {
    // Release-mode assert: the raw-pointer stores below are bounded by this
    // check alone — a short pack buffer must panic like the scalar packer
    // does, never write out of bounds (audited-unsafe policy).
    assert!(pack.len() >= mc.next_multiple_of(MR) * kc);
    let mut idx = 0;
    let mut i = 0;
    while i < mc {
        let live = MR.min(mc - i);
        if live < MR {
            // Partial tail sliver: scalar copy + zero pad — exactly the
            // scalar packer's bytes.
            for k in 0..kc {
                for r in 0..MR {
                    pack[idx] = if r < live { a.row(i0 + i + r)[k0 + k] } else { 0.0 };
                    idx += 1;
                }
            }
            i += MR;
            continue;
        }
        let rows: [&[f64]; MR] = [
            &a.row(i0 + i)[k0..k0 + kc],
            &a.row(i0 + i + 1)[k0..k0 + kc],
            &a.row(i0 + i + 2)[k0..k0 + kc],
            &a.row(i0 + i + 3)[k0..k0 + kc],
            &a.row(i0 + i + 4)[k0..k0 + kc],
            &a.row(i0 + i + 5)[k0..k0 + kc],
        ];
        let chunks = kc / 4;
        for ck in 0..chunks {
            let k = 4 * ck;
            // In bounds: k + 4 <= kc on every row slice (len kc each).
            let r0 = _mm256_loadu_pd(rows[0].as_ptr().add(k));
            let r1 = _mm256_loadu_pd(rows[1].as_ptr().add(k));
            let r2 = _mm256_loadu_pd(rows[2].as_ptr().add(k));
            let r3 = _mm256_loadu_pd(rows[3].as_ptr().add(k));
            // 4×4 transpose: lanes stay distinct elements; pure movement.
            let t0 = _mm256_unpacklo_pd(r0, r1); // [a_k   b_k   a_k+2 b_k+2]
            let t1 = _mm256_unpackhi_pd(r0, r1); // [a_k+1 b_k+1 a_k+3 b_k+3]
            let t2 = _mm256_unpacklo_pd(r2, r3);
            let t3 = _mm256_unpackhi_pd(r2, r3);
            let c0 = _mm256_permute2f128_pd::<0x20>(t0, t2); // rows 0..4 at col k
            let c1 = _mm256_permute2f128_pd::<0x20>(t1, t3); // ... at col k+1
            let c2 = _mm256_permute2f128_pd::<0x31>(t0, t2); // ... at col k+2
            let c3 = _mm256_permute2f128_pd::<0x31>(t1, t3); // ... at col k+3
            let pp = pack.as_mut_ptr().add(idx + k * MR);
            // In bounds: the furthest write below is idx + (k+3)·MR + 6
            //         <= idx + kc·MR, the end of this sliver's region
            // (k + 3 <= kc - 1), which the length assert covers.
            _mm256_storeu_pd(pp, c0);
            _mm256_storeu_pd(pp.add(MR), c1);
            _mm256_storeu_pd(pp.add(2 * MR), c2);
            _mm256_storeu_pd(pp.add(3 * MR), c3);
            // Rows 4..6: interleave the two remaining rows and store the
            // 2-wide column pairs straight into the stride-MR slots.
            let r4 = _mm256_loadu_pd(rows[4].as_ptr().add(k));
            let r5 = _mm256_loadu_pd(rows[5].as_ptr().add(k));
            let lo = _mm256_unpacklo_pd(r4, r5); // [e_k   f_k   e_k+2 f_k+2]
            let hi = _mm256_unpackhi_pd(r4, r5); // [e_k+1 f_k+1 e_k+3 f_k+3]
            _mm_storeu_pd(pp.add(4), _mm256_castpd256_pd128(lo));
            _mm_storeu_pd(pp.add(MR + 4), _mm256_castpd256_pd128(hi));
            _mm_storeu_pd(pp.add(2 * MR + 4), _mm256_extractf128_pd::<1>(lo));
            _mm_storeu_pd(pp.add(3 * MR + 4), _mm256_extractf128_pd::<1>(hi));
        }
        // Scalar k tail: same bytes as the scalar packer.
        for k in 4 * chunks..kc {
            for (r, row) in rows.iter().enumerate() {
                pack[idx + k * MR + r] = row[k];
            }
        }
        idx += kc * MR;
        i += MR;
    }
}

/// B-panel packer: same byte layout as `gemm::pack_b_scalar`, with the
/// full `NR = 8` slivers copied through two 4-lane vector moves per row.
/// Foreign `nr` geometries and partial slivers delegate to the scalar
/// packer (identical bytes).
pub(crate) fn pack_b(b: &Mat, k0: usize, kc: usize, nr: usize, pack: &mut [f64]) {
    debug_assert!(have_avx2(), "AVX2 kernel dispatched on a CPU without AVX2");
    if nr != NR {
        return crate::linalg::gemm::pack_b_scalar(b, k0, kc, nr, pack);
    }
    // SAFETY: AVX2 is present — dispatch-table invariant (module audit
    // note) plus the debug probe above.
    unsafe { pack_b_impl(b, k0, kc, pack) }
}

// SAFETY: caller must have verified AVX2 (safe wrapper above is the only
// caller); pointer offsets are bounded by the row-slice lengths and the
// pack-length assert, justified per use.
#[target_feature(enable = "avx2")]
unsafe fn pack_b_impl(b: &Mat, k0: usize, kc: usize, pack: &mut [f64]) {
    let n = b.cols();
    // Release-mode assert: the raw-pointer stores below are bounded by this
    // check alone — a short pack buffer must panic like the scalar packer
    // does, never write out of bounds (audited-unsafe policy).
    assert!(pack.len() >= kc * n.next_multiple_of(NR));
    let mut idx = 0;
    let mut j = 0;
    while j < n {
        let live = NR.min(n - j);
        if live == NR {
            for k in 0..kc {
                let row = &b.row(k0 + k)[j..j + NR];
                let rp = row.as_ptr();
                let pp = pack.as_mut_ptr().add(idx);
                // In bounds: row is exactly NR = 8 long, and idx + 8 <=
                // pack.len() by the length assert (idx advances NR per k).
                _mm256_storeu_pd(pp, _mm256_loadu_pd(rp));
                _mm256_storeu_pd(pp.add(4), _mm256_loadu_pd(rp.add(4)));
                idx += NR;
            }
        } else {
            // Partial trailing sliver: scalar copy + zero pad — exactly
            // the scalar packer's bytes.
            for k in 0..kc {
                let row = &b.row(k0 + k)[j..j + live];
                pack[idx..idx + live].copy_from_slice(row);
                pack[idx + live..idx + NR].fill(0.0);
                idx += NR;
            }
        }
        j += NR;
    }
}

// SAFETY: caller must have verified AVX2 (safe wrapper above is the only
// caller); pointer offsets are bounded by the equal slice lengths.
#[target_feature(enable = "avx2")]
unsafe fn dot_impl(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len();
    debug_assert_eq!(n, b.len());
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let chunks = n / 4;
    let mut accv: __m256d = _mm256_setzero_pd();
    for c in 0..chunks {
        // In bounds: i + 4 <= n for every chunk, on both same-length slices.
        let i = 4 * c;
        let av = _mm256_loadu_pd(ap.add(i));
        let bv = _mm256_loadu_pd(bp.add(i));
        accv = _mm256_add_pd(accv, _mm256_mul_pd(av, bv));
    }
    let mut lanes = [0.0f64; 4];
    // In bounds: lanes is exactly 4 elements — one full vector store.
    _mm256_storeu_pd(lanes.as_mut_ptr(), accv);
    let mut s = lanes[0] + lanes[1] + lanes[2] + lanes[3];
    for i in 4 * chunks..n {
        s += a[i] * b[i];
    }
    s
}
