//! Cholesky factorisation and SPD solves.
//!
//! The scatter matrices `X̃ᵀX̃ + λI₀` and `S_w + λI` are symmetric positive
//! definite whenever the ridge is active (and usually also without it for
//! N > P), so Cholesky is the preferred factorisation on both the standard
//! and the analytical path.

use super::dispatch;
use super::gemm::dot;
use super::mat::Mat;
use crate::util::threadpool::ThreadPool;
use anyhow::{bail, Result};

/// Lower-triangular Cholesky factor `L` with `A = L Lᵀ`.
#[derive(Clone, Debug)]
pub struct Cholesky {
    l: Mat,
}

impl Cholesky {
    /// Factor a symmetric positive definite matrix. Fails (cleanly) on
    /// non-SPD input — callers fall back to LU or add ridge.
    pub fn factor(a: &Mat) -> Result<Cholesky> {
        let n = a.rows();
        assert_eq!(a.rows(), a.cols(), "cholesky of non-square");
        // Relative pivot floor: a rank-deficient gram matrix yields pivots
        // at roundoff level (~1e-16·‖A‖) rather than exact zeros; treating
        // those as "positive definite" would silently produce garbage.
        let floor = 1e-10 * (0..n).map(|i| a[(i, i)].abs()).fold(0.0f64, f64::max);
        let mut l = Mat::zeros(n, n);
        for j in 0..n {
            // diagonal
            let mut d = a[(j, j)] - dot(&l.row(j)[..j], &l.row(j)[..j]);
            if d <= floor || !d.is_finite() {
                bail!("matrix not positive definite at pivot {j} (d={d})");
            }
            d = d.sqrt();
            l[(j, j)] = d;
            // column below the diagonal: L[i,j] = (A[i,j] - L[i,:j]·L[j,:j]) / d
            for i in (j + 1)..n {
                let s = a[(i, j)] - dot_rows(&l, i, j, j);
                l[(i, j)] = s / d;
            }
        }
        Ok(Cholesky { l })
    }

    /// Panel-blocked, pool-parallel factorisation — **bit-identical** to
    /// [`Cholesky::factor`] for any `tile` or pool size. Each column's
    /// subdiagonal entries are independent once the pivot is known, so
    /// they fan out over `pool` in `tile`-row chunks; every element keeps
    /// the serial recurrence's exact arithmetic (full-prefix [`dot`]), so
    /// blocking moves work between threads without re-associating a single
    /// sum. See [`crate::linalg::tiled`] for the design notes (and why a
    /// right-looking trailing-GEMM update was rejected: it would break
    /// bit-identity).
    pub fn factor_blocked(a: &Mat, tile: usize, pool: Option<&ThreadPool>) -> Result<Cholesky> {
        Self::factor_into(a.clone(), tile, pool)
    }

    /// [`Cholesky::factor_blocked`] that factors **in place**, consuming
    /// the input buffer instead of allocating a second `N×N` — the memory
    /// half of the §4.5 tiled story: a Gram built tile-by-tile can be
    /// factored without ever holding two `N×N` matrices. The upper
    /// triangle is zeroed afterwards so [`Cholesky::l`] is a proper lower
    /// factor. Values are bit-identical to [`Cholesky::factor`].
    pub fn factor_into(mut a: Mat, tile: usize, pool: Option<&ThreadPool>) -> Result<Cholesky> {
        let n = a.rows();
        assert_eq!(a.rows(), a.cols(), "cholesky of non-square");
        let tile = tile.clamp(1, n.max(1));
        // Same relative pivot floor as `factor` — computed up front, before
        // the diagonal is overwritten by factor values.
        let floor = 1e-10 * (0..n).map(|i| a[(i, i)].abs()).fold(0.0f64, f64::max);
        for j in 0..n {
            // Column j: rows < j hold final L values, rows ≥ j still hold A.
            let mut d = a[(j, j)] - dot(&a.row(j)[..j], &a.row(j)[..j]);
            if d <= floor || !d.is_finite() {
                bail!("matrix not positive definite at pivot {j} (d={d})");
            }
            d = d.sqrt();
            a[(j, j)] = d;
            let below = n - j - 1;
            match pool {
                // Fan the subdiagonal column out in tile-row chunks; each
                // element reads only finalised data (columns < j plus the
                // pivot row prefix), so values are computed against the
                // immutable borrow and written back afterwards.
                Some(pool) if pool.size() > 1 && below >= 2 * tile => {
                    let ranges: Vec<(usize, usize)> = (j + 1..n)
                        .step_by(tile)
                        .map(|lo| (lo, (lo + tile).min(n)))
                        .collect();
                    let a_ref = &a;
                    let cols: Vec<Vec<f64>> = pool.map(ranges.len(), |c| {
                        let (lo, hi) = ranges[c];
                        (lo..hi)
                            .map(|i| {
                                (a_ref[(i, j)] - dot(&a_ref.row(i)[..j], &a_ref.row(j)[..j])) / d
                            })
                            .collect()
                    });
                    for (&(lo, _), vals) in ranges.iter().zip(&cols) {
                        for (off, &v) in vals.iter().enumerate() {
                            a[(lo + off, j)] = v;
                        }
                    }
                }
                _ => {
                    for i in (j + 1)..n {
                        let s = a[(i, j)] - dot_rows(&a, i, j, j);
                        a[(i, j)] = s / d;
                    }
                }
            }
        }
        // The upper triangle still holds A's entries; zero it so the
        // factor is exactly what `factor` would have produced.
        for i in 0..n {
            for k in (i + 1)..n {
                a[(i, k)] = 0.0;
            }
        }
        Ok(Cholesky { l: a })
    }

    /// Wrap an already-computed lower factor (no validation) — how the
    /// spilled factor ([`crate::linalg::spill::SpilledCholesky`]) gathers
    /// back into an in-RAM `Cholesky` when a caller decides it fits.
    pub(crate) fn from_lower(l: Mat) -> Cholesky {
        assert_eq!(l.rows(), l.cols(), "cholesky factor must be square");
        Cholesky { l }
    }

    /// The lower factor.
    pub fn l(&self) -> &Mat {
        &self.l
    }

    /// Mutable access to the lower factor — the in-place seam for the
    /// rank-1/block up/downdate kernels ([`crate::linalg::chol_update`]),
    /// which rotate the factor column by column without reallocating.
    pub(crate) fn l_mut(&mut self) -> &mut Mat {
        &mut self.l
    }

    /// Dimension.
    pub fn n(&self) -> usize {
        self.l.rows()
    }

    /// Solve `A x = b` for a single right-hand side.
    pub fn solve_vec(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n();
        assert_eq!(b.len(), n);
        let mut y = b.to_vec();
        // forward: L y = b
        for i in 0..n {
            let s = dot(&self.l.row(i)[..i], &y[..i]);
            y[i] = (y[i] - s) / self.l[(i, i)];
        }
        // backward: Lᵀ x = y
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in (i + 1)..n {
                s -= self.l[(k, i)] * y[k];
            }
            y[i] = s / self.l[(i, i)];
        }
        y
    }

    /// Solve `A X = B` for a matrix right-hand side.
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        let mut x = b.clone();
        self.solve_mat_in_place(&mut x);
        x
    }

    /// [`Cholesky::solve_mat`] overwriting the right-hand side in place —
    /// no extra `N×nrhs` clone. The dual streaming-hat build uses this to
    /// turn its centered-data buffer directly into `T_c = (K_c+λI)⁻¹X_c`.
    pub fn solve_mat_in_place(&self, x: &mut Mat) {
        let n = self.n();
        assert_eq!(x.rows(), n);
        let nrhs = x.cols();
        let kr = dispatch::active_kernels();
        // forward substitution across all RHS columns (row-major friendly).
        for i in 0..n {
            // x.row(i) -= sum_k<i L[i,k] * x.row(k); then /= L[i,i]
            for k in 0..i {
                let lik = self.l[(i, k)];
                if lik == 0.0 {
                    continue;
                }
                let (head, tail) = x.as_mut_slice().split_at_mut(i * nrhs);
                let xk = &head[k * nrhs..(k + 1) * nrhs];
                let xi = &mut tail[..nrhs];
                (kr.axpy_sub)(xi, lik, xk);
            }
            let d = self.l[(i, i)];
            for v in x.row_mut(i) {
                *v /= d;
            }
        }
        // backward
        for i in (0..n).rev() {
            for k in (i + 1)..n {
                let lki = self.l[(k, i)];
                if lki == 0.0 {
                    continue;
                }
                let (head, tail) = x.as_mut_slice().split_at_mut(k * nrhs);
                let xi = &mut head[i * nrhs..(i + 1) * nrhs];
                let xk = &tail[..nrhs];
                (kr.axpy_sub)(xi, lki, xk);
            }
            let d = self.l[(i, i)];
            for v in x.row_mut(i) {
                *v /= d;
            }
        }
    }

    /// Explicit inverse `A⁻¹` (used for the hat matrix where the full
    /// inverse genuinely is needed: `H = X̃ S X̃ᵀ`).
    pub fn inverse(&self) -> Mat {
        let n = self.n();
        self.solve_mat(&Mat::eye(n))
    }

    /// log(det A) = 2 Σ log L[i,i].
    pub fn log_det(&self) -> f64 {
        (0..self.n()).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }

    /// Solve `Lᵀ x = b` only (half-solve; used for whitening transforms).
    pub fn solve_lt_vec(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n();
        let mut y = b.to_vec();
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in (i + 1)..n {
                s -= self.l[(k, i)] * y[k];
            }
            y[i] = s / self.l[(i, i)];
        }
        y
    }

    /// Solve `L Y = B` (forward only, matrix RHS) — for the two-sided
    /// reduction `L⁻¹ A L⁻ᵀ` in the generalised eigenproblem.
    pub fn solve_l_mat(&self, b: &Mat) -> Mat {
        let n = self.n();
        assert_eq!(b.rows(), n);
        let nrhs = b.cols();
        let mut x = b.clone();
        let kr = dispatch::active_kernels();
        for i in 0..n {
            for k in 0..i {
                let lik = self.l[(i, k)];
                if lik == 0.0 {
                    continue;
                }
                let (head, tail) = x.as_mut_slice().split_at_mut(i * nrhs);
                let xk = &head[k * nrhs..(k + 1) * nrhs];
                let xi = &mut tail[..nrhs];
                (kr.axpy_sub)(xi, lik, xk);
            }
            let d = self.l[(i, i)];
            for v in x.row_mut(i) {
                *v /= d;
            }
        }
        x
    }

    /// Solve `Lᵀ X = B` (backward only, matrix RHS).
    pub fn solve_lt_mat(&self, b: &Mat) -> Mat {
        let n = self.n();
        assert_eq!(b.rows(), n);
        let nrhs = b.cols();
        let mut x = b.clone();
        let kr = dispatch::active_kernels();
        for i in (0..n).rev() {
            for k in (i + 1)..n {
                let lki = self.l[(k, i)];
                if lki == 0.0 {
                    continue;
                }
                let (head, tail) = x.as_mut_slice().split_at_mut(k * nrhs);
                let xi = &mut head[i * nrhs..(i + 1) * nrhs];
                let xk = &tail[..nrhs];
                (kr.axpy_sub)(xi, lki, xk);
            }
            let d = self.l[(i, i)];
            for v in x.row_mut(i) {
                *v /= d;
            }
        }
        x
    }
}

#[inline]
fn dot_rows(l: &Mat, i: usize, j: usize, len: usize) -> f64 {
    dot(&l.row(i)[..len], &l.row(j)[..len])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{matmul, syrk_t};
    use crate::util::rng::Rng;

    fn spd(rng: &mut Rng, n: usize) -> Mat {
        let a = Mat::from_fn(n + 3, n, |_, _| rng.gauss());
        let mut g = syrk_t(&a);
        for i in 0..n {
            g[(i, i)] += 0.5;
        }
        g
    }

    #[test]
    fn factor_reconstructs() {
        let mut rng = Rng::new(1);
        for n in [1, 2, 5, 20, 60] {
            let a = spd(&mut rng, n);
            let ch = Cholesky::factor(&a).unwrap();
            let rec = matmul(ch.l(), &ch.l().t());
            assert!(rec.max_abs_diff(&a) < 1e-8 * a.max_abs().max(1.0), "n={n}");
        }
    }

    #[test]
    fn solve_vec_and_mat_agree() {
        let mut rng = Rng::new(2);
        let n = 24;
        let a = spd(&mut rng, n);
        let ch = Cholesky::factor(&a).unwrap();
        let b = Mat::from_fn(n, 3, |_, _| rng.gauss());
        let xm = ch.solve_mat(&b);
        for c in 0..3 {
            let xv = ch.solve_vec(&b.col(c));
            for i in 0..n {
                assert!((xv[i] - xm[(i, c)]).abs() < 1e-9);
            }
        }
        // residual check
        let res = matmul(&a, &xm).sub(&b);
        assert!(res.max_abs() < 1e-8);
    }

    #[test]
    fn inverse_is_inverse() {
        let mut rng = Rng::new(3);
        let n = 15;
        let a = spd(&mut rng, n);
        let inv = Cholesky::factor(&a).unwrap().inverse();
        let eye = matmul(&a, &inv);
        assert!(eye.max_abs_diff(&Mat::eye(n)) < 1e-8);
    }

    #[test]
    fn rejects_indefinite() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eigenvalues 3, -1
        assert!(Cholesky::factor(&a).is_err());
    }

    #[test]
    fn log_det_matches_2x2() {
        let a = Mat::from_rows(&[&[4.0, 1.0], &[1.0, 3.0]]);
        let ch = Cholesky::factor(&a).unwrap();
        assert!((ch.log_det() - (11.0f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn tiled_factor_blocked_bitwise_matches_serial() {
        // Acceptance: the blocked/pooled Cholesky reproduces the serial
        // factor to the last bit across tile sizes {1, 7, N, N+3} —
        // including the non-divisible remainder panel — with and without a
        // pool, and through the in-place variant.
        let mut rng = Rng::new(7);
        let pool = crate::util::threadpool::ThreadPool::new(4);
        for n in [5usize, 23, 40] {
            let a = spd(&mut rng, n);
            let serial = Cholesky::factor(&a).unwrap();
            for tile in [1usize, 7, n, n + 3] {
                // through the free-function alias the tiled layer exports
                let blocked = crate::linalg::chol_blocked(&a, tile, None).unwrap();
                assert_eq!(
                    serial.l().as_slice(),
                    blocked.l().as_slice(),
                    "serial blocked n={n} tile={tile}"
                );
                let pooled = Cholesky::factor_blocked(&a, tile, Some(&pool)).unwrap();
                assert_eq!(
                    serial.l().as_slice(),
                    pooled.l().as_slice(),
                    "pooled blocked n={n} tile={tile}"
                );
                let in_place = Cholesky::factor_into(a.clone(), tile, Some(&pool)).unwrap();
                assert_eq!(
                    serial.l().as_slice(),
                    in_place.l().as_slice(),
                    "in-place n={n} tile={tile}"
                );
            }
            // identical factors ⇒ identical solves
            let b = Mat::from_fn(n, 3, |_, _| rng.gauss());
            let blocked = Cholesky::factor_blocked(&a, 7, Some(&pool)).unwrap();
            assert_eq!(serial.solve_mat(&b).as_slice(), blocked.solve_mat(&b).as_slice());
        }
    }

    #[test]
    fn tiled_factor_into_rejects_indefinite_and_zeroes_upper() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eigenvalues 3, -1
        assert!(Cholesky::factor_into(a, 4, None).is_err());
        let mut rng = Rng::new(8);
        let g = spd(&mut rng, 9);
        let ch = Cholesky::factor_into(g, 4, None).unwrap();
        for i in 0..9 {
            for k in (i + 1)..9 {
                assert_eq!(ch.l()[(i, k)], 0.0, "upper triangle not zeroed at ({i},{k})");
            }
        }
    }

    #[test]
    fn tiled_solve_mat_in_place_matches_solve_mat() {
        let mut rng = Rng::new(9);
        let n = 17;
        let a = spd(&mut rng, n);
        let ch = Cholesky::factor(&a).unwrap();
        let b = Mat::from_fn(n, 5, |_, _| rng.gauss());
        let out = ch.solve_mat(&b);
        let mut in_place = b.clone();
        ch.solve_mat_in_place(&mut in_place);
        assert_eq!(out.as_slice(), in_place.as_slice());
    }

    #[test]
    fn half_solves_compose_to_full() {
        let mut rng = Rng::new(4);
        let n = 12;
        let a = spd(&mut rng, n);
        let ch = Cholesky::factor(&a).unwrap();
        let b = Mat::from_fn(n, 2, |_, _| rng.gauss());
        let full = ch.solve_mat(&b);
        let half = ch.solve_lt_mat(&ch.solve_l_mat(&b));
        assert!(full.max_abs_diff(&half) < 1e-9);
        let bv = b.col(0);
        let hv = ch.solve_lt_vec(&ch.solve_l_mat(&Mat::col_vec(&bv)).col(0));
        for i in 0..n {
            assert!((hv[i] - full[(i, 0)]).abs() < 1e-9);
        }
    }
}
