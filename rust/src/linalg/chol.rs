//! Cholesky factorisation and SPD solves.
//!
//! The scatter matrices `X̃ᵀX̃ + λI₀` and `S_w + λI` are symmetric positive
//! definite whenever the ridge is active (and usually also without it for
//! N > P), so Cholesky is the preferred factorisation on both the standard
//! and the analytical path.

use super::gemm::dot;
use super::mat::Mat;
use anyhow::{bail, Result};

/// Lower-triangular Cholesky factor `L` with `A = L Lᵀ`.
#[derive(Clone, Debug)]
pub struct Cholesky {
    l: Mat,
}

impl Cholesky {
    /// Factor a symmetric positive definite matrix. Fails (cleanly) on
    /// non-SPD input — callers fall back to LU or add ridge.
    pub fn factor(a: &Mat) -> Result<Cholesky> {
        let n = a.rows();
        assert_eq!(a.rows(), a.cols(), "cholesky of non-square");
        // Relative pivot floor: a rank-deficient gram matrix yields pivots
        // at roundoff level (~1e-16·‖A‖) rather than exact zeros; treating
        // those as "positive definite" would silently produce garbage.
        let floor = 1e-10 * (0..n).map(|i| a[(i, i)].abs()).fold(0.0f64, f64::max);
        let mut l = Mat::zeros(n, n);
        for j in 0..n {
            // diagonal
            let mut d = a[(j, j)] - dot(&l.row(j)[..j], &l.row(j)[..j]);
            if d <= floor || !d.is_finite() {
                bail!("matrix not positive definite at pivot {j} (d={d})");
            }
            d = d.sqrt();
            l[(j, j)] = d;
            // column below the diagonal: L[i,j] = (A[i,j] - L[i,:j]·L[j,:j]) / d
            for i in (j + 1)..n {
                let s = a[(i, j)] - dot_rows(&l, i, j, j);
                l[(i, j)] = s / d;
            }
        }
        Ok(Cholesky { l })
    }

    /// The lower factor.
    pub fn l(&self) -> &Mat {
        &self.l
    }

    /// Dimension.
    pub fn n(&self) -> usize {
        self.l.rows()
    }

    /// Solve `A x = b` for a single right-hand side.
    pub fn solve_vec(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n();
        assert_eq!(b.len(), n);
        let mut y = b.to_vec();
        // forward: L y = b
        for i in 0..n {
            let s = dot(&self.l.row(i)[..i], &y[..i]);
            y[i] = (y[i] - s) / self.l[(i, i)];
        }
        // backward: Lᵀ x = y
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in (i + 1)..n {
                s -= self.l[(k, i)] * y[k];
            }
            y[i] = s / self.l[(i, i)];
        }
        y
    }

    /// Solve `A X = B` for a matrix right-hand side.
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        let n = self.n();
        assert_eq!(b.rows(), n);
        let nrhs = b.cols();
        let mut x = b.clone();
        // forward substitution across all RHS columns (row-major friendly).
        for i in 0..n {
            // x.row(i) -= sum_k<i L[i,k] * x.row(k); then /= L[i,i]
            for k in 0..i {
                let lik = self.l[(i, k)];
                if lik == 0.0 {
                    continue;
                }
                let (head, tail) = x.as_mut_slice().split_at_mut(i * nrhs);
                let xk = &head[k * nrhs..(k + 1) * nrhs];
                let xi = &mut tail[..nrhs];
                for c in 0..nrhs {
                    xi[c] -= lik * xk[c];
                }
            }
            let d = self.l[(i, i)];
            for v in x.row_mut(i) {
                *v /= d;
            }
        }
        // backward
        for i in (0..n).rev() {
            for k in (i + 1)..n {
                let lki = self.l[(k, i)];
                if lki == 0.0 {
                    continue;
                }
                let (head, tail) = x.as_mut_slice().split_at_mut(k * nrhs);
                let xi = &mut head[i * nrhs..(i + 1) * nrhs];
                let xk = &tail[..nrhs];
                for c in 0..nrhs {
                    xi[c] -= lki * xk[c];
                }
            }
            let d = self.l[(i, i)];
            for v in x.row_mut(i) {
                *v /= d;
            }
        }
        x
    }

    /// Explicit inverse `A⁻¹` (used for the hat matrix where the full
    /// inverse genuinely is needed: `H = X̃ S X̃ᵀ`).
    pub fn inverse(&self) -> Mat {
        let n = self.n();
        self.solve_mat(&Mat::eye(n))
    }

    /// log(det A) = 2 Σ log L[i,i].
    pub fn log_det(&self) -> f64 {
        (0..self.n()).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }

    /// Solve `Lᵀ x = b` only (half-solve; used for whitening transforms).
    pub fn solve_lt_vec(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n();
        let mut y = b.to_vec();
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in (i + 1)..n {
                s -= self.l[(k, i)] * y[k];
            }
            y[i] = s / self.l[(i, i)];
        }
        y
    }

    /// Solve `L Y = B` (forward only, matrix RHS) — for the two-sided
    /// reduction `L⁻¹ A L⁻ᵀ` in the generalised eigenproblem.
    pub fn solve_l_mat(&self, b: &Mat) -> Mat {
        let n = self.n();
        assert_eq!(b.rows(), n);
        let nrhs = b.cols();
        let mut x = b.clone();
        for i in 0..n {
            for k in 0..i {
                let lik = self.l[(i, k)];
                if lik == 0.0 {
                    continue;
                }
                let (head, tail) = x.as_mut_slice().split_at_mut(i * nrhs);
                let xk = &head[k * nrhs..(k + 1) * nrhs];
                let xi = &mut tail[..nrhs];
                for c in 0..nrhs {
                    xi[c] -= lik * xk[c];
                }
            }
            let d = self.l[(i, i)];
            for v in x.row_mut(i) {
                *v /= d;
            }
        }
        x
    }

    /// Solve `Lᵀ X = B` (backward only, matrix RHS).
    pub fn solve_lt_mat(&self, b: &Mat) -> Mat {
        let n = self.n();
        assert_eq!(b.rows(), n);
        let nrhs = b.cols();
        let mut x = b.clone();
        for i in (0..n).rev() {
            for k in (i + 1)..n {
                let lki = self.l[(k, i)];
                if lki == 0.0 {
                    continue;
                }
                let (head, tail) = x.as_mut_slice().split_at_mut(k * nrhs);
                let xi = &mut head[i * nrhs..(i + 1) * nrhs];
                let xk = &tail[..nrhs];
                for c in 0..nrhs {
                    xi[c] -= lki * xk[c];
                }
            }
            let d = self.l[(i, i)];
            for v in x.row_mut(i) {
                *v /= d;
            }
        }
        x
    }
}

#[inline]
fn dot_rows(l: &Mat, i: usize, j: usize, len: usize) -> f64 {
    dot(&l.row(i)[..len], &l.row(j)[..len])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{matmul, syrk_t};
    use crate::util::rng::Rng;

    fn spd(rng: &mut Rng, n: usize) -> Mat {
        let a = Mat::from_fn(n + 3, n, |_, _| rng.gauss());
        let mut g = syrk_t(&a);
        for i in 0..n {
            g[(i, i)] += 0.5;
        }
        g
    }

    #[test]
    fn factor_reconstructs() {
        let mut rng = Rng::new(1);
        for n in [1, 2, 5, 20, 60] {
            let a = spd(&mut rng, n);
            let ch = Cholesky::factor(&a).unwrap();
            let rec = matmul(ch.l(), &ch.l().t());
            assert!(rec.max_abs_diff(&a) < 1e-8 * a.max_abs().max(1.0), "n={n}");
        }
    }

    #[test]
    fn solve_vec_and_mat_agree() {
        let mut rng = Rng::new(2);
        let n = 24;
        let a = spd(&mut rng, n);
        let ch = Cholesky::factor(&a).unwrap();
        let b = Mat::from_fn(n, 3, |_, _| rng.gauss());
        let xm = ch.solve_mat(&b);
        for c in 0..3 {
            let xv = ch.solve_vec(&b.col(c));
            for i in 0..n {
                assert!((xv[i] - xm[(i, c)]).abs() < 1e-9);
            }
        }
        // residual check
        let res = matmul(&a, &xm).sub(&b);
        assert!(res.max_abs() < 1e-8);
    }

    #[test]
    fn inverse_is_inverse() {
        let mut rng = Rng::new(3);
        let n = 15;
        let a = spd(&mut rng, n);
        let inv = Cholesky::factor(&a).unwrap().inverse();
        let eye = matmul(&a, &inv);
        assert!(eye.max_abs_diff(&Mat::eye(n)) < 1e-8);
    }

    #[test]
    fn rejects_indefinite() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eigenvalues 3, -1
        assert!(Cholesky::factor(&a).is_err());
    }

    #[test]
    fn log_det_matches_2x2() {
        let a = Mat::from_rows(&[&[4.0, 1.0], &[1.0, 3.0]]);
        let ch = Cholesky::factor(&a).unwrap();
        assert!((ch.log_det() - (11.0f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn half_solves_compose_to_full() {
        let mut rng = Rng::new(4);
        let n = 12;
        let a = spd(&mut rng, n);
        let ch = Cholesky::factor(&a).unwrap();
        let b = Mat::from_fn(n, 2, |_, _| rng.gauss());
        let full = ch.solve_mat(&b);
        let half = ch.solve_lt_mat(&ch.solve_l_mat(&b));
        assert!(full.max_abs_diff(&half) < 1e-9);
        let bv = b.col(0);
        let hv = ch.solve_lt_vec(&ch.solve_l_mat(&Mat::col_vec(&bv)).col(0));
        for i in 0..n {
            assert!((hv[i] - full[(i, 0)]).abs() < 1e-9);
        }
    }
}
