//! NEON microkernels for the `linalg` hot core (aarch64 only).
//!
//! The 128-bit twin of `linalg::simd_avx2`: every function is the vector
//! counterpart of a scalar reference in [`crate::linalg::gemm`], selected
//! at runtime through [`crate::linalg::dispatch`], and bound by the same
//! bitwise contract — lanes are **distinct output elements**, every step
//! is **multiply then add** (`vaddq_f64(acc, vmulq_f64(a, b))`, never
//! `vfmaq_f64`, whose fused single rounding would diverge from the scalar
//! `acc += a * b`), and chunks plus tails walk **ascending index order**.
//!
//! NEON vectors carry two `f64` lanes, so the canonical stride-4 partials
//! of `gemm::dot_scalar` need *two* accumulators: `acc0` holds partials
//! `(s0, s1)` (loads from `a[4c..]`), `acc1` holds `(s2, s3)` (loads from
//! `a[4c + 2..]`), and the horizontal reduction spells out
//! `((s0 + s1) + s2) + s3` — a naïve stride-2 dot would compute different
//! partial sums and break bitwise equality.
//!
//! The `kernel_conformance_*` suite pins each function against its scalar
//! reference across shapes, remainder lanes, and NaN/∞ inputs.
//!
//! ## Unsafe audit (rule L3, docs/LINTS.md)
//!
//! Same shape as the AVX2 module: `unsafe` is confined to the
//! `#[target_feature(enable = "neon")] unsafe fn` implementations (raw
//! pointer loads/stores, each offset justified at the use site against the
//! `debug_assert!`ed slice lengths) and the single `unsafe { ..._impl }`
//! call in each safe wrapper — sound because the wrappers are only
//! installed in the dispatch table after a runtime
//! `is_aarch64_feature_detected!("neon")` probe (NEON is mandatory on
//! aarch64, but we keep the probe for symmetry) and each wrapper re-checks
//! with a `debug_assert!`. Sources are `&[f64]`, destinations are
//! `&mut [f64]`; the borrow checker rules out aliasing before any pointer
//! is formed.

#![allow(clippy::too_many_arguments)] // microkernel signatures mirror the scalar reference

use crate::linalg::mat::Mat;
use core::arch::aarch64::{
    float64x2_t, vaddq_f64, vdupq_n_f64, vgetq_lane_f64, vld1q_f64, vmulq_f64, vst1q_f64,
    vsubq_f64, vzip1q_f64, vzip2q_f64,
};

/// NEON GEMM register tile: 6 packed-A rows × 8 packed-B columns (four
/// 2-lane vectors), 24 accumulator registers + 4 B loads + 1 broadcast —
/// inside the 32 architectural `v` registers.
pub(crate) const MR: usize = 6;
/// See [`MR`].
pub(crate) const NR: usize = 8;

/// Does this CPU run these kernels? (NEON is baseline on aarch64.)
#[inline]
fn have_neon() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}

/// `MR×NR` GEMM micro-kernel over packed slivers:
/// `C[ci..ci+mr, cj..cj+nr] += alpha · A_sliver · B_sliver`.
///
/// Same contract as `gemm::micro_kernel_scalar`: `a_sl` is `kc` columns of
/// `MR` packed (zero-padded) rows, `b_sl` is `kc` rows of `NR` packed
/// columns, and only the `mr×nr` live outputs are written back.
pub(crate) fn micro_kernel(
    c: &mut Mat,
    a_sl: &[f64],
    b_sl: &[f64],
    ci: usize,
    cj: usize,
    mr: usize,
    nr: usize,
    kc: usize,
    alpha: f64,
) {
    debug_assert!(have_neon(), "NEON kernel dispatched on a CPU without NEON");
    // SAFETY: NEON is present — this wrapper is only installed in the
    // dispatch table after a runtime feature probe (module audit note).
    unsafe { micro_kernel_impl(c, a_sl, b_sl, ci, cj, mr, nr, kc, alpha) }
}

// SAFETY: callers must have verified NEON support (the safe wrapper above
// is the only caller); the body's raw-pointer accesses are bounded by the
// `debug_assert!`ed packed-sliver lengths, justified per use below.
#[target_feature(enable = "neon")]
unsafe fn micro_kernel_impl(
    c: &mut Mat,
    a_sl: &[f64],
    b_sl: &[f64],
    ci: usize,
    cj: usize,
    mr: usize,
    nr: usize,
    kc: usize,
    alpha: f64,
) {
    debug_assert!(a_sl.len() >= kc * MR && b_sl.len() >= kc * NR);
    debug_assert!(mr <= MR && nr <= NR && nr <= c.cols());
    let ap = a_sl.as_ptr();
    let bp = b_sl.as_ptr();
    // acc[r][h]: row r of the tile, columns 2h..2h+2. Lanes are distinct
    // output columns; each accumulates its own `+= a·b` sequence over k in
    // ascending order — the canonical order, two roundings per step.
    let mut acc = [[vdupq_n_f64(0.0); 4]; MR];
    for k in 0..kc {
        // In bounds: k < kc and b_sl.len() >= kc*NR, so offsets k*8 + {0,2,4,6}
        // each leave 2 readable lanes.
        let b0 = vld1q_f64(bp.add(k * NR));
        let b1 = vld1q_f64(bp.add(k * NR + 2));
        let b2 = vld1q_f64(bp.add(k * NR + 4));
        let b3 = vld1q_f64(bp.add(k * NR + 6));
        for (r, accr) in acc.iter_mut().enumerate() {
            // In bounds: k < kc, r < MR, a_sl.len() >= kc*MR.
            let ar = vdupq_n_f64(*ap.add(k * MR + r));
            accr[0] = vaddq_f64(accr[0], vmulq_f64(ar, b0));
            accr[1] = vaddq_f64(accr[1], vmulq_f64(ar, b1));
            accr[2] = vaddq_f64(accr[2], vmulq_f64(ar, b2));
            accr[3] = vaddq_f64(accr[3], vmulq_f64(ar, b3));
        }
    }
    // Write back through a lane spill + the scalar update, so the final
    // `c += alpha * acc` op is literally the scalar reference's.
    let mut lanes = [0.0f64; NR];
    for r in 0..mr {
        for (h, &accv) in acc[r].iter().enumerate() {
            // In bounds: lanes is NR = 8 long; stores cover 2h..2h+2, h < 4.
            vst1q_f64(lanes.as_mut_ptr().add(2 * h), accv);
        }
        let crow = c.row_mut(ci + r);
        for s in 0..nr {
            crow[cj + s] += alpha * lanes[s];
        }
    }
}

/// `acc[t] += a · x[t]`, ascending `t`, mul-then-add per element — the
/// vector twin of `gemm::axpy_scalar`.
pub(crate) fn axpy(acc: &mut [f64], a: f64, x: &[f64]) {
    debug_assert!(have_neon(), "NEON kernel dispatched on a CPU without NEON");
    // SAFETY: NEON is present — dispatch-table invariant (module audit
    // note) plus the debug probe above.
    unsafe { axpy_impl(acc, a, x) }
}

// SAFETY: caller must have verified NEON (safe wrapper above is the only
// caller); pointer offsets are bounded by the equal slice lengths.
#[target_feature(enable = "neon")]
unsafe fn axpy_impl(acc: &mut [f64], a: f64, x: &[f64]) {
    let n = acc.len();
    debug_assert_eq!(n, x.len());
    let av = vdupq_n_f64(a);
    let xp = x.as_ptr();
    let cp = acc.as_mut_ptr();
    let chunks = n / 2;
    for cix in 0..chunks {
        // In bounds: i + 2 <= n for every chunk, on both same-length slices.
        let i = 2 * cix;
        let xv = vld1q_f64(xp.add(i));
        let cv = vld1q_f64(cp.add(i));
        vst1q_f64(cp.add(i), vaddq_f64(cv, vmulq_f64(av, xv)));
    }
    for i in 2 * chunks..n {
        acc[i] += a * x[i];
    }
}

/// `acc[t] -= a · x[t]`, ascending `t`, mul-then-sub per element — the
/// vector twin of `gemm::axpy_sub_scalar` (the triangular-solve update).
pub(crate) fn axpy_sub(acc: &mut [f64], a: f64, x: &[f64]) {
    debug_assert!(have_neon(), "NEON kernel dispatched on a CPU without NEON");
    // SAFETY: NEON is present — dispatch-table invariant (module audit
    // note) plus the debug probe above.
    unsafe { axpy_sub_impl(acc, a, x) }
}

// SAFETY: caller must have verified NEON (safe wrapper above is the only
// caller); pointer offsets are bounded by the equal slice lengths.
#[target_feature(enable = "neon")]
unsafe fn axpy_sub_impl(acc: &mut [f64], a: f64, x: &[f64]) {
    let n = acc.len();
    debug_assert_eq!(n, x.len());
    let av = vdupq_n_f64(a);
    let xp = x.as_ptr();
    let cp = acc.as_mut_ptr();
    let chunks = n / 2;
    for cix in 0..chunks {
        // In bounds: i + 2 <= n for every chunk, on both same-length slices.
        let i = 2 * cix;
        let xv = vld1q_f64(xp.add(i));
        let cv = vld1q_f64(cp.add(i));
        vst1q_f64(cp.add(i), vsubq_f64(cv, vmulq_f64(av, xv)));
    }
    for i in 2 * chunks..n {
        acc[i] -= a * x[i];
    }
}

/// Dot product in the canonical 4-partial order. Two 2-lane accumulators
/// reproduce the scalar reference's stride-4 partials exactly: `acc0`
/// lanes are `(s0, s1)` (loads at `4c`), `acc1` lanes are `(s2, s3)`
/// (loads at `4c + 2`), reduced as `((s0 + s1) + s2) + s3` before the
/// sequential tail — bitwise `gemm::dot_scalar`.
pub(crate) fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert!(have_neon(), "NEON kernel dispatched on a CPU without NEON");
    // SAFETY: NEON is present — dispatch-table invariant (module audit
    // note) plus the debug probe above.
    unsafe { dot_impl(a, b) }
}

/// A-block packer: same byte layout as `gemm::pack_a_scalar` (the packed
/// bytes depend only on the inputs — the packed-bytes contract), produced
/// with 2-lane zip transposes for the full `MR = 6` slivers. Geometries
/// other than `MR` and partial/tail slivers delegate to the scalar packer,
/// which writes the identical bytes.
pub(crate) fn pack_a(a: &Mat, i0: usize, mc: usize, k0: usize, kc: usize, mr: usize, pack: &mut [f64]) {
    debug_assert!(have_neon(), "NEON kernel dispatched on a CPU without NEON");
    if mr != MR {
        // Foreign geometry (conformance probes) — bytes are defined by the
        // scalar packer anyway.
        return crate::linalg::gemm::pack_a_scalar(a, i0, mc, k0, kc, mr, pack);
    }
    // SAFETY: NEON is present — dispatch-table invariant (module audit
    // note) plus the debug probe above.
    unsafe { pack_a_impl(a, i0, mc, k0, kc, pack) }
}

// SAFETY: caller must have verified NEON (safe wrapper above is the only
// caller); every pointer offset is bounded by the sliver extents asserted
// below and justified per use.
#[target_feature(enable = "neon")]
unsafe fn pack_a_impl(a: &Mat, i0: usize, mc: usize, k0: usize, kc: usize, pack: &mut [f64]) {
    // Release-mode assert: the raw-pointer stores below are bounded by this
    // check alone — a short pack buffer must panic like the scalar packer
    // does, never write out of bounds (audited-unsafe policy).
    assert!(pack.len() >= mc.next_multiple_of(MR) * kc);
    let mut idx = 0;
    let mut i = 0;
    while i < mc {
        let live = MR.min(mc - i);
        if live < MR {
            // Partial tail sliver: scalar copy + zero pad — exactly the
            // scalar packer's bytes.
            for k in 0..kc {
                for r in 0..MR {
                    pack[idx] = if r < live { a.row(i0 + i + r)[k0 + k] } else { 0.0 };
                    idx += 1;
                }
            }
            i += MR;
            continue;
        }
        let rows: [&[f64]; MR] = [
            &a.row(i0 + i)[k0..k0 + kc],
            &a.row(i0 + i + 1)[k0..k0 + kc],
            &a.row(i0 + i + 2)[k0..k0 + kc],
            &a.row(i0 + i + 3)[k0..k0 + kc],
            &a.row(i0 + i + 4)[k0..k0 + kc],
            &a.row(i0 + i + 5)[k0..k0 + kc],
        ];
        let chunks = kc / 2;
        for ck in 0..chunks {
            let k = 2 * ck;
            // In bounds: k + 2 <= kc on every row slice (len kc each).
            let r01a = vld1q_f64(rows[0].as_ptr().add(k));
            let r01b = vld1q_f64(rows[1].as_ptr().add(k));
            let r23a = vld1q_f64(rows[2].as_ptr().add(k));
            let r23b = vld1q_f64(rows[3].as_ptr().add(k));
            let r45a = vld1q_f64(rows[4].as_ptr().add(k));
            let r45b = vld1q_f64(rows[5].as_ptr().add(k));
            // zip1 = column k of each row pair, zip2 = column k+1 —
            // pure data movement, no arithmetic.
            let pp = pack.as_mut_ptr().add(idx + k * MR);
            // In bounds: the furthest write below is idx + (k+1)·MR + 6
            //         <= idx + kc·MR, the end of this sliver's region
            // (k + 1 <= kc - 1), which the length assert covers.
            vst1q_f64(pp, vzip1q_f64(r01a, r01b));
            vst1q_f64(pp.add(2), vzip1q_f64(r23a, r23b));
            vst1q_f64(pp.add(4), vzip1q_f64(r45a, r45b));
            vst1q_f64(pp.add(MR), vzip2q_f64(r01a, r01b));
            vst1q_f64(pp.add(MR + 2), vzip2q_f64(r23a, r23b));
            vst1q_f64(pp.add(MR + 4), vzip2q_f64(r45a, r45b));
        }
        // Scalar k tail: same bytes as the scalar packer.
        for k in 2 * chunks..kc {
            for (r, row) in rows.iter().enumerate() {
                pack[idx + k * MR + r] = row[k];
            }
        }
        idx += kc * MR;
        i += MR;
    }
}

/// B-panel packer: same byte layout as `gemm::pack_b_scalar`, with the
/// full `NR = 8` slivers copied through four 2-lane vector moves per row.
/// Foreign `nr` geometries and partial slivers delegate to the scalar
/// packer (identical bytes).
pub(crate) fn pack_b(b: &Mat, k0: usize, kc: usize, nr: usize, pack: &mut [f64]) {
    debug_assert!(have_neon(), "NEON kernel dispatched on a CPU without NEON");
    if nr != NR {
        return crate::linalg::gemm::pack_b_scalar(b, k0, kc, nr, pack);
    }
    // SAFETY: NEON is present — dispatch-table invariant (module audit
    // note) plus the debug probe above.
    unsafe { pack_b_impl(b, k0, kc, pack) }
}

// SAFETY: caller must have verified NEON (safe wrapper above is the only
// caller); pointer offsets are bounded by the row-slice lengths and the
// pack-length assert, justified per use.
#[target_feature(enable = "neon")]
unsafe fn pack_b_impl(b: &Mat, k0: usize, kc: usize, pack: &mut [f64]) {
    let n = b.cols();
    // Release-mode assert: the raw-pointer stores below are bounded by this
    // check alone — a short pack buffer must panic like the scalar packer
    // does, never write out of bounds (audited-unsafe policy).
    assert!(pack.len() >= kc * n.next_multiple_of(NR));
    let mut idx = 0;
    let mut j = 0;
    while j < n {
        let live = NR.min(n - j);
        if live == NR {
            for k in 0..kc {
                let row = &b.row(k0 + k)[j..j + NR];
                let rp = row.as_ptr();
                let pp = pack.as_mut_ptr().add(idx);
                // In bounds: row is exactly NR = 8 long, and idx + 8 <=
                // pack.len() by the length assert (idx advances NR per k).
                vst1q_f64(pp, vld1q_f64(rp));
                vst1q_f64(pp.add(2), vld1q_f64(rp.add(2)));
                vst1q_f64(pp.add(4), vld1q_f64(rp.add(4)));
                vst1q_f64(pp.add(6), vld1q_f64(rp.add(6)));
                idx += NR;
            }
        } else {
            // Partial trailing sliver: scalar copy + zero pad — exactly
            // the scalar packer's bytes.
            for k in 0..kc {
                let row = &b.row(k0 + k)[j..j + live];
                pack[idx..idx + live].copy_from_slice(row);
                pack[idx + live..idx + NR].fill(0.0);
                idx += NR;
            }
        }
        j += NR;
    }
}

// SAFETY: caller must have verified NEON (safe wrapper above is the only
// caller); pointer offsets are bounded by the equal slice lengths.
#[target_feature(enable = "neon")]
unsafe fn dot_impl(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len();
    debug_assert_eq!(n, b.len());
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let chunks = n / 4;
    let mut acc0: float64x2_t = vdupq_n_f64(0.0);
    let mut acc1: float64x2_t = vdupq_n_f64(0.0);
    for c in 0..chunks {
        // In bounds: i + 4 <= n for every chunk, on both same-length slices,
        // so the two 2-lane loads at i and i + 2 are both covered.
        let i = 4 * c;
        let av0 = vld1q_f64(ap.add(i));
        let bv0 = vld1q_f64(bp.add(i));
        let av1 = vld1q_f64(ap.add(i + 2));
        let bv1 = vld1q_f64(bp.add(i + 2));
        acc0 = vaddq_f64(acc0, vmulq_f64(av0, bv0));
        acc1 = vaddq_f64(acc1, vmulq_f64(av1, bv1));
    }
    let s0 = vgetq_lane_f64::<0>(acc0);
    let s1 = vgetq_lane_f64::<1>(acc0);
    let s2 = vgetq_lane_f64::<0>(acc1);
    let s3 = vgetq_lane_f64::<1>(acc1);
    let mut s = ((s0 + s1) + s2) + s3;
    for i in 4 * chunks..n {
        s += a[i] * b[i];
    }
    s
}
