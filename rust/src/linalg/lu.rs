//! LU factorisation with partial pivoting.
//!
//! General-purpose solver used where symmetry/definiteness is not
//! guaranteed: the per-fold `(I − H_Te)` systems of Eq. 14 are symmetric but
//! can be indefinite-looking numerically when λ=0 and folds are large, so the
//! analytic path solves them with LU.

use super::mat::Mat;
use anyhow::{bail, Result};

/// Packed LU decomposition `P·A = L·U` with partial pivoting.
#[derive(Clone, Debug)]
pub struct Lu {
    lu: Mat,
    piv: Vec<usize>,
    sign: f64,
}

impl Lu {
    /// Factor a square matrix; fails on exact singularity.
    pub fn factor(a: &Mat) -> Result<Lu> {
        let n = a.rows();
        assert_eq!(a.rows(), a.cols(), "LU of non-square");
        let mut lu = a.clone();
        let mut piv: Vec<usize> = (0..n).collect();
        let mut sign = 1.0;
        // Relative singularity floor (see Cholesky::factor): numerically
        // rank-deficient systems must fail loudly, not produce garbage.
        let floor = 1e-13 * a.max_abs();
        for k in 0..n {
            // pivot search
            let mut pmax = lu[(k, k)].abs();
            let mut prow = k;
            for i in (k + 1)..n {
                let v = lu[(i, k)].abs();
                if v > pmax {
                    pmax = v;
                    prow = i;
                }
            }
            if pmax <= floor || !pmax.is_finite() {
                bail!("singular matrix at pivot {k} (|pivot|={pmax})");
            }
            if prow != k {
                piv.swap(k, prow);
                sign = -sign;
                // swap rows in-place
                for j in 0..n {
                    let t = lu[(k, j)];
                    lu[(k, j)] = lu[(prow, j)];
                    lu[(prow, j)] = t;
                }
            }
            let pivot = lu[(k, k)];
            for i in (k + 1)..n {
                let m = lu[(i, k)] / pivot;
                lu[(i, k)] = m;
                if m == 0.0 {
                    continue;
                }
                // row update: lu[i, k+1..] -= m * lu[k, k+1..]
                let (top, bottom) = lu.as_mut_slice().split_at_mut(i * n);
                let krow = &top[k * n..(k + 1) * n];
                let irow = &mut bottom[..n];
                for j in (k + 1)..n {
                    irow[j] -= m * krow[j];
                }
            }
        }
        Ok(Lu { lu, piv, sign })
    }

    /// Dimension.
    pub fn n(&self) -> usize {
        self.lu.rows()
    }

    /// Solve `A x = b`.
    pub fn solve_vec(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n();
        assert_eq!(b.len(), n);
        // apply permutation
        let mut y: Vec<f64> = self.piv.iter().map(|&i| b[i]).collect();
        // forward L (unit diagonal)
        for i in 1..n {
            let mut s = y[i];
            let row = self.lu.row(i);
            for k in 0..i {
                s -= row[k] * y[k];
            }
            y[i] = s;
        }
        // backward U
        for i in (0..n).rev() {
            let mut s = y[i];
            let row = self.lu.row(i);
            for k in (i + 1)..n {
                s -= row[k] * y[k];
            }
            y[i] = s / row[i];
        }
        y
    }

    /// Solve `A X = B` (matrix RHS).
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        let n = self.n();
        assert_eq!(b.rows(), n);
        let nrhs = b.cols();
        let mut x = Mat::zeros(n, nrhs);
        for (i, &pi) in self.piv.iter().enumerate() {
            x.row_mut(i).copy_from_slice(b.row(pi));
        }
        // forward
        for i in 1..n {
            for k in 0..i {
                let lik = self.lu[(i, k)];
                if lik == 0.0 {
                    continue;
                }
                let (head, tail) = x.as_mut_slice().split_at_mut(i * nrhs);
                let xk = &head[k * nrhs..(k + 1) * nrhs];
                let xi = &mut tail[..nrhs];
                for c in 0..nrhs {
                    xi[c] -= lik * xk[c];
                }
            }
        }
        // backward
        for i in (0..n).rev() {
            for k in (i + 1)..n {
                let uik = self.lu[(i, k)];
                if uik == 0.0 {
                    continue;
                }
                let (head, tail) = x.as_mut_slice().split_at_mut(k * nrhs);
                let xi = &mut head[i * nrhs..(i + 1) * nrhs];
                let xk = &tail[..nrhs];
                for c in 0..nrhs {
                    xi[c] -= uik * xk[c];
                }
            }
            let d = self.lu[(i, i)];
            for v in x.row_mut(i) {
                *v /= d;
            }
        }
        x
    }

    /// Explicit inverse.
    pub fn inverse(&self) -> Mat {
        self.solve_mat(&Mat::eye(self.n()))
    }

    /// Determinant.
    pub fn det(&self) -> f64 {
        self.sign * (0..self.n()).map(|i| self.lu[(i, i)]).product::<f64>()
    }
}

/// Convenience: solve `A x = b` in one call.
pub fn solve(a: &Mat, b: &[f64]) -> Result<Vec<f64>> {
    Ok(Lu::factor(a)?.solve_vec(b))
}

/// Convenience: solve `A X = B` in one call.
pub fn solve_mat(a: &Mat, b: &Mat) -> Result<Mat> {
    Ok(Lu::factor(a)?.solve_mat(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::matmul;
    use crate::util::rng::Rng;

    #[test]
    fn solves_random_systems() {
        let mut rng = Rng::new(1);
        for n in [1, 2, 3, 8, 25, 64] {
            let a = Mat::from_fn(n, n, |_, _| rng.gauss());
            let xtrue: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
            let b = crate::linalg::gemm::matvec(&a, &xtrue);
            let x = solve(&a, &b).unwrap();
            for i in 0..n {
                assert!((x[i] - xtrue[i]).abs() < 1e-7, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn matrix_rhs_and_inverse() {
        let mut rng = Rng::new(2);
        let n = 20;
        let a = Mat::from_fn(n, n, |_, _| rng.gauss());
        let lu = Lu::factor(&a).unwrap();
        let b = Mat::from_fn(n, 4, |_, _| rng.gauss());
        let x = lu.solve_mat(&b);
        assert!(matmul(&a, &x).max_abs_diff(&b) < 1e-8);
        let inv = lu.inverse();
        assert!(matmul(&a, &inv).max_abs_diff(&Mat::eye(n)) < 1e-8);
    }

    #[test]
    fn det_known_values() {
        let a = Mat::from_rows(&[&[2.0, 0.0], &[0.0, 3.0]]);
        assert!((Lu::factor(&a).unwrap().det() - 6.0).abs() < 1e-12);
        let b = Mat::from_rows(&[&[0.0, 1.0], &[1.0, 0.0]]); // det -1, needs pivot
        assert!((Lu::factor(&b).unwrap().det() + 1.0).abs() < 1e-12);
    }

    #[test]
    fn singular_detected() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 4.0]]);
        assert!(Lu::factor(&a).is_err());
    }

    #[test]
    fn pivoting_handles_zero_diagonal() {
        let a = Mat::from_rows(&[&[0.0, 2.0], &[3.0, 1.0]]);
        let x = solve(&a, &[4.0, 5.0]).unwrap();
        // 2y=4 => y=2 ; 3x+y=5 => x=1
        assert!((x[0] - 1.0).abs() < 1e-12 && (x[1] - 2.0).abs() < 1e-12);
    }
}
