//! Micro-benchmark framework (no `criterion` in the offline build).
//!
//! Measures wall-clock with warmup + adaptive iteration counts, reports
//! median / MAD / min, and renders paper-style tables. Used by all the
//! `benches/*.rs` targets (each declared `harness = false`).

use crate::util::table::{fdur, Table};
use std::time::Instant;

/// One measured statistic set (seconds).
#[derive(Clone, Debug)]
pub struct Sample {
    pub median: f64,
    pub mad: f64,
    pub min: f64,
    pub iters: usize,
}

/// Benchmark configuration.
#[derive(Clone, Debug)]
pub struct Bench {
    /// Minimum number of timed iterations.
    pub min_iters: usize,
    /// Maximum number of timed iterations.
    pub max_iters: usize,
    /// Target total measurement time (seconds).
    pub target_time: f64,
    /// Warmup iterations.
    pub warmup: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { min_iters: 3, max_iters: 50, target_time: 1.0, warmup: 1 }
    }
}

impl Bench {
    /// Quick profile for expensive end-to-end workloads.
    pub fn quick() -> Bench {
        Bench { min_iters: 2, max_iters: 10, target_time: 0.5, warmup: 1 }
    }

    /// Time a closure. The closure should return something observable to
    /// prevent dead-code elimination; its result is black-boxed here.
    pub fn run<T, F: FnMut() -> T>(&self, mut f: F) -> Sample {
        for _ in 0..self.warmup {
            black_box(f());
        }
        // Estimate single-shot cost to pick iteration count.
        let t0 = Instant::now();
        black_box(f());
        let once = t0.elapsed().as_secs_f64().max(1e-9);
        let iters = ((self.target_time / once) as usize)
            .clamp(self.min_iters, self.max_iters);
        let mut times = Vec::with_capacity(iters + 1);
        times.push(once);
        for _ in 0..iters {
            let t = Instant::now();
            black_box(f());
            times.push(t.elapsed().as_secs_f64());
        }
        times.sort_by(|a, b| a.total_cmp(b));
        let median = times[times.len() / 2];
        let mut dev: Vec<f64> = times.iter().map(|t| (t - median).abs()).collect();
        dev.sort_by(|a, b| a.total_cmp(b));
        Sample { median, mad: dev[dev.len() / 2], min: times[0], iters: times.len() }
    }
}

/// Prevent the optimiser from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Collects rows of (label, standard, analytic) and renders the paper's
/// relative-efficiency table: `log10(t_std / t_analytic)`.
pub struct RelEffReport {
    table: Table,
    rows: Vec<(String, f64, f64)>,
}

impl RelEffReport {
    /// New report with a title.
    pub fn new(title: &str) -> RelEffReport {
        RelEffReport {
            table: Table::new(vec!["config", "t_standard", "t_analytic", "speedup", "rel.eff (log10)"])
                .with_title(title.to_string()),
            rows: Vec::new(),
        }
    }

    /// Record one configuration.
    pub fn push(&mut self, label: &str, t_std: f64, t_ana: f64) {
        let speedup = t_std / t_ana;
        self.table.row(vec![
            label.to_string(),
            fdur(t_std),
            fdur(t_ana),
            format!("{speedup:.1}x"),
            format!("{:.2}", speedup.log10()),
        ]);
        self.rows.push((label.to_string(), t_std, t_ana));
    }

    /// Relative efficiency (log10 speedup) per recorded row.
    pub fn rel_eff(&self) -> Vec<(String, f64)> {
        self.rows.iter().map(|(l, s, a)| (l.clone(), (s / a).log10())).collect()
    }

    /// Render the table.
    pub fn render(&self) -> String {
        self.table.render()
    }

    /// Raw TSV of the timing rows (label, t_std, t_analytic, rel_eff).
    pub fn to_tsv(&self) -> String {
        let mut out = String::from("config\tt_standard\tt_analytic\trel_eff\n");
        for (l, s, a) in &self.rows {
            out.push_str(&format!("{l}\t{s:.6e}\t{a:.6e}\t{:.4}\n", (s / a).log10()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_reports_sane_stats() {
        let b = Bench { min_iters: 3, max_iters: 5, target_time: 0.01, warmup: 1 };
        let s = b.run(|| {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(s.median > 0.0);
        assert!(s.min <= s.median);
        assert!(s.iters >= 3);
    }

    #[test]
    fn rel_eff_report_math() {
        let mut r = RelEffReport::new("demo");
        r.push("cfg", 1.0, 0.001);
        let eff = r.rel_eff();
        assert!((eff[0].1 - 3.0).abs() < 1e-12);
        assert!(r.render().contains("1000.0x"));
        assert!(r.to_tsv().lines().count() == 2);
    }
}
