//! Standard-approach CV runners — the baseline the paper times against.
//!
//! These retrain the *classic* formulations (scatter matrices + solve /
//! generalised eig; §2.11's complexity model) on every training fold, rather
//! than the regression forms, so the measured baseline matches what an
//! MVPA-Light-style toolbox actually executes.

use crate::fastcv::{complement, validate_folds};
use crate::linalg::Mat;
use crate::model::lda_binary::BinaryLda;
use crate::model::lda_multiclass::MulticlassLda;
use crate::model::Reg;
use anyhow::Result;

/// Decision values from retraining binary LDA on every fold.
pub fn standard_binary_cv_dvals(
    x: &Mat,
    labels: &[usize],
    folds: &[Vec<usize>],
    reg: Reg,
) -> Result<Vec<f64>> {
    validate_folds(folds, x.rows())?;
    let mut dvals = vec![f64::NAN; x.rows()];
    for te in folds {
        let tr = complement(te, x.rows());
        let x_tr = x.take_rows(&tr);
        let l_tr: Vec<usize> = tr.iter().map(|&i| labels[i]).collect();
        let model = BinaryLda::train(&x_tr, &l_tr, reg)?;
        let d = model.decision_values(&x.take_rows(te));
        for (j, &i) in te.iter().enumerate() {
            dvals[i] = d[j];
        }
    }
    Ok(dvals)
}

/// Cross-validated accuracy from retraining binary LDA on every fold.
pub fn standard_binary_cv_accuracy(
    x: &Mat,
    labels: &[usize],
    folds: &[Vec<usize>],
    reg: Reg,
) -> Result<f64> {
    let dvals = standard_binary_cv_dvals(x, labels, folds, reg)?;
    let y = crate::model::lda_binary::signed_codes(labels);
    Ok(crate::cv::metrics::accuracy_signed(&dvals, &y))
}

/// Predicted labels from retraining multi-class LDA on every fold.
pub fn standard_multiclass_cv_predict(
    x: &Mat,
    labels: &[usize],
    c: usize,
    folds: &[Vec<usize>],
    reg: Reg,
) -> Result<Vec<usize>> {
    validate_folds(folds, x.rows())?;
    let mut pred = vec![usize::MAX; x.rows()];
    for te in folds {
        let tr = complement(te, x.rows());
        let x_tr = x.take_rows(&tr);
        let l_tr: Vec<usize> = tr.iter().map(|&i| labels[i]).collect();
        let model = MulticlassLda::train(&x_tr, &l_tr, c, reg)?;
        let p = model.predict(&x.take_rows(te));
        for (j, &i) in te.iter().enumerate() {
            pred[i] = p[j];
        }
    }
    Ok(pred)
}

/// Cross-validated accuracy of the standard multi-class pipeline.
pub fn standard_multiclass_cv_accuracy(
    x: &Mat,
    labels: &[usize],
    c: usize,
    folds: &[Vec<usize>],
    reg: Reg,
) -> Result<f64> {
    let pred = standard_multiclass_cv_predict(x, labels, c, folds, reg)?;
    Ok(crate::cv::metrics::accuracy_labels(&pred, labels))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cv::folds::{kfold, stratified_kfold};
    use crate::model::lda_multiclass::tests::blobs;
    use crate::util::rng::Rng;

    #[test]
    fn binary_cv_beats_chance_on_separable_data() {
        let mut rng = Rng::new(1);
        let (x, labels) = blobs(&mut rng, 40, 2, 6, 3.0);
        let folds = kfold(80, 5, &mut rng);
        let acc = standard_binary_cv_accuracy(&x, &labels, &folds, Reg::Ridge(0.1)).unwrap();
        assert!(acc > 0.85, "acc={acc}");
    }

    #[test]
    fn binary_cv_is_chance_on_shuffled_labels() {
        let mut rng = Rng::new(2);
        let (x, mut labels) = blobs(&mut rng, 40, 2, 6, 3.0);
        rng.shuffle(&mut labels);
        let folds = kfold(80, 5, &mut rng);
        let acc = standard_binary_cv_accuracy(&x, &labels, &folds, Reg::Ridge(0.1)).unwrap();
        assert!((0.25..=0.75).contains(&acc), "acc={acc}");
    }

    #[test]
    fn multiclass_cv_accuracy_reasonable() {
        let mut rng = Rng::new(3);
        let (x, labels) = blobs(&mut rng, 25, 4, 8, 4.0);
        let folds = stratified_kfold(&labels, 5, &mut rng);
        let acc = standard_multiclass_cv_accuracy(&x, &labels, 4, &folds, Reg::Ridge(0.1)).unwrap();
        assert!(acc > 0.8, "acc={acc}");
    }

    #[test]
    fn dvals_assigned_for_every_sample() {
        let mut rng = Rng::new(4);
        let (x, labels) = blobs(&mut rng, 12, 2, 4, 2.0);
        let folds = kfold(24, 6, &mut rng);
        let d = standard_binary_cv_dvals(&x, &labels, &folds, Reg::Ridge(0.01)).unwrap();
        assert!(d.iter().all(|v| v.is_finite()));
    }
}
