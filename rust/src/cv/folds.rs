//! Fold partitioners for k-fold cross-validation (§2.1).

use crate::util::rng::Rng;

/// Random k-fold partition of `0..n`: shuffles indices and deals them into
/// `k` nearly equal test sets. Returns the test-index set per fold.
pub fn kfold(n: usize, k: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    assert!(k >= 2, "need at least 2 folds");
    assert!(k <= n, "more folds than samples");
    let perm = rng.permutation(n);
    let mut folds = vec![Vec::with_capacity(n / k + 1); k];
    for (pos, &i) in perm.iter().enumerate() {
        folds[pos % k].push(i);
    }
    for f in folds.iter_mut() {
        f.sort_unstable();
    }
    folds
}

/// Leave-one-out partition.
pub fn leave_one_out(n: usize) -> Vec<Vec<usize>> {
    (0..n).map(|i| vec![i]).collect()
}

/// Stratified k-fold: class proportions are (approximately) preserved in
/// every fold.
///
/// Contract (the caller always gets what it asked for, or a loud failure):
///
/// * returns **exactly `k`** folds — the round-robin deal assigns sample
///   `r` to fold `r mod k`, so with `k ≤ N` every fold is non-empty;
/// * when `k ≤ min_j N_j`, every fold additionally contains at least one
///   sample of **every** class (each class's run of ≥ k consecutive
///   round-robin slots covers all k residues);
/// * when `min_j N_j < k ≤ N`, the partition is still exactly `k` folds
///   but scarce classes necessarily miss some folds — callers that need
///   per-fold class coverage must bound `k` by the smallest class size;
/// * panics when `k > N`: a k-fold partition of fewer samples does not
///   exist. (The old behaviour silently returned fewer than `k` folds —
///   a caller requesting 5 folds could get 3 with no signal.)
pub fn stratified_kfold(labels: &[usize], k: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    assert!(k >= 2, "need at least 2 folds");
    assert!(
        k <= labels.len(),
        "more folds than samples ({k} > {}) — cannot stratify",
        labels.len()
    );
    let c = labels.iter().copied().max().map(|m| m + 1).unwrap_or(0);
    let mut folds = vec![Vec::new(); k];
    let mut fold_rr = 0usize; // round-robin across classes so fold sizes balance
    for class in 0..c {
        let mut idx: Vec<usize> = (0..labels.len()).filter(|&i| labels[i] == class).collect();
        rng.shuffle(&mut idx);
        for i in idx {
            folds[fold_rr % k].push(i);
            fold_rr += 1;
        }
    }
    for f in folds.iter_mut() {
        f.sort_unstable();
    }
    assert!(
        folds.iter().all(|f| !f.is_empty()),
        "stratified_kfold invariant violated: empty fold with k = {k} ≤ N = {}",
        labels.len()
    );
    folds
}

/// `reps` independent k-fold partitions (repeated CV, §2.1).
pub fn repeated_kfold(n: usize, k: usize, reps: usize, rng: &mut Rng) -> Vec<Vec<Vec<usize>>> {
    (0..reps).map(|_| kfold(n, k, rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_partition(folds: &[Vec<usize>], n: usize) {
        let mut seen = vec![false; n];
        for f in folds {
            for &i in f {
                assert!(!seen[i], "duplicate {i}");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "not all samples covered");
    }

    #[test]
    fn kfold_partitions_everything() {
        let mut rng = Rng::new(1);
        for (n, k) in [(10, 2), (11, 3), (100, 7), (5, 5)] {
            let folds = kfold(n, k, &mut rng);
            assert_eq!(folds.len(), k);
            assert_partition(&folds, n);
            // sizes within 1 of each other
            let sizes: Vec<usize> = folds.iter().map(|f| f.len()).collect();
            let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(mx - mn <= 1, "{sizes:?}");
        }
    }

    #[test]
    fn loo_is_n_singletons() {
        let folds = leave_one_out(7);
        assert_eq!(folds.len(), 7);
        assert_partition(&folds, 7);
        assert!(folds.iter().all(|f| f.len() == 1));
    }

    #[test]
    fn stratified_preserves_proportions() {
        let mut rng = Rng::new(2);
        // 40 of class 0, 20 of class 1, 10 of class 2
        let labels: Vec<usize> =
            std::iter::repeat_n(0, 40).chain(std::iter::repeat_n(1, 20)).chain(std::iter::repeat_n(2, 10)).collect();
        let folds = stratified_kfold(&labels, 5, &mut rng);
        assert_eq!(folds.len(), 5);
        assert_partition(&folds, 70);
        for f in &folds {
            let c0 = f.iter().filter(|&&i| labels[i] == 0).count();
            let c1 = f.iter().filter(|&&i| labels[i] == 1).count();
            let c2 = f.iter().filter(|&&i| labels[i] == 2).count();
            assert!((7..=9).contains(&c0), "c0={c0}");
            assert!((3..=5).contains(&c1), "c1={c1}");
            assert!((1..=3).contains(&c2), "c2={c2}");
        }
    }

    #[test]
    fn stratified_boundary_k_equals_smallest_class() {
        // k = min_j N_j: exactly k folds, every fold sees every class.
        let mut rng = Rng::new(4);
        let labels: Vec<usize> =
            std::iter::repeat_n(0, 12).chain(std::iter::repeat_n(1, 4)).collect();
        let folds = stratified_kfold(&labels, 4, &mut rng);
        assert_eq!(folds.len(), 4, "caller asked for 4 folds, must get 4");
        assert_partition(&folds, 16);
        for (j, f) in folds.iter().enumerate() {
            assert!(f.iter().any(|&i| labels[i] == 0), "fold {j} lost class 0");
            assert!(f.iter().any(|&i| labels[i] == 1), "fold {j} lost class 1");
        }
    }

    #[test]
    fn stratified_k_beyond_smallest_class_still_exactly_k_folds() {
        // min_j N_j < k ≤ N: the partition must still have exactly k
        // non-empty folds (scarce classes miss some folds, documented).
        // Regression guard on the old `retain`, which could silently
        // shrink the partition.
        let mut rng = Rng::new(5);
        let labels: Vec<usize> =
            std::iter::repeat_n(0, 10).chain(std::iter::repeat_n(1, 2)).collect();
        let folds = stratified_kfold(&labels, 6, &mut rng);
        assert_eq!(folds.len(), 6, "caller asked for 6 folds, must get 6");
        assert_partition(&folds, 12);
        assert!(folds.iter().all(|f| !f.is_empty()));
    }

    #[test]
    #[should_panic(expected = "more folds than samples")]
    fn stratified_rejects_more_folds_than_samples() {
        // Regression: this configuration used to silently return fewer
        // than k folds instead of signalling the impossible request.
        let mut rng = Rng::new(6);
        stratified_kfold(&[0usize, 1, 0], 5, &mut rng);
    }

    #[test]
    fn repeated_kfold_gives_distinct_partitions() {
        let mut rng = Rng::new(3);
        let reps = repeated_kfold(30, 5, 3, &mut rng);
        assert_eq!(reps.len(), 3);
        assert!(reps[0] != reps[1] || reps[1] != reps[2], "should differ");
        for r in &reps {
            assert_partition(r, 30);
        }
    }

    #[test]
    fn kfold_deterministic_under_seed() {
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        assert_eq!(kfold(20, 4, &mut a), kfold(20, 4, &mut b));
    }
}
