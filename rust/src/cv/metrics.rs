//! Classification / regression performance metrics.

/// Accuracy of signed decision values against ±1 codes (the paper's
/// "class +1 for ŷ ≥ 0, class −1 for ŷ < 0").
///
/// Samples with a `NaN` decision value are *skipped* — both numerator and
/// denominator. `validate_folds` deliberately accepts partitions whose test
/// sets do not cover every sample (subsampled CV), and the engines mark the
/// uncovered samples `NaN`; counting those as errors would silently deflate
/// the accuracy. Panics if no sample has a finite decision value.
pub fn accuracy_signed(dvals: &[f64], y_signed: &[f64]) -> f64 {
    assert_eq!(dvals.len(), y_signed.len());
    assert!(!dvals.is_empty());
    let mut correct = 0usize;
    let mut covered = 0usize;
    for (&d, &y) in dvals.iter().zip(y_signed) {
        if d.is_nan() {
            continue;
        }
        covered += 1;
        if (d >= 0.0 && y > 0.0) || (d < 0.0 && y < 0.0) {
            correct += 1;
        }
    }
    assert!(covered > 0, "accuracy_signed: every decision value is NaN (no fold covered any sample)");
    correct as f64 / covered as f64
}

/// Accuracy of predicted labels.
///
/// Predictions equal to `usize::MAX` — the engines' "not covered by any
/// test fold" sentinel — are skipped from both numerator and denominator,
/// mirroring [`accuracy_signed`]'s treatment of `NaN`. Panics if every
/// prediction is the sentinel.
pub fn accuracy_labels(pred: &[usize], truth: &[usize]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    assert!(!pred.is_empty());
    let mut correct = 0usize;
    let mut covered = 0usize;
    for (&p, &t) in pred.iter().zip(truth) {
        if p == usize::MAX {
            continue;
        }
        covered += 1;
        if p == t {
            correct += 1;
        }
    }
    assert!(covered > 0, "accuracy_labels: every prediction is the uncovered sentinel");
    correct as f64 / covered as f64
}

/// Area under the ROC curve via the rank statistic (ties get 0.5 credit).
/// Positive class = label 0 (+1 code) with *larger* decision values.
/// Returns `NaN` when a class is absent from `labels`.
pub fn auc(dvals: &[f64], labels: &[usize]) -> f64 {
    assert_eq!(dvals.len(), labels.len());
    let pos: Vec<f64> = dvals.iter().zip(labels).filter(|(_, &l)| l == 0).map(|(&d, _)| d).collect();
    let neg: Vec<f64> = dvals.iter().zip(labels).filter(|(_, &l)| l == 1).map(|(&d, _)| d).collect();
    if pos.is_empty() || neg.is_empty() {
        // The ranking is undefined with a single class; NaN (not a panic)
        // so model selection can order it as worst — see
        // `fastcv::lambda_search::select_best`.
        return f64::NAN;
    }
    let mut wins = 0.0;
    for &p in &pos {
        for &n in &neg {
            if p > n {
                wins += 1.0;
            } else if p == n {
                wins += 0.5;
            }
        }
    }
    wins / (pos.len() * neg.len()) as f64
}

/// Confusion matrix `counts[truth][pred]` for `c` classes.
pub fn confusion(pred: &[usize], truth: &[usize], c: usize) -> Vec<Vec<usize>> {
    assert_eq!(pred.len(), truth.len());
    let mut m = vec![vec![0usize; c]; c];
    for (&p, &t) in pred.iter().zip(truth) {
        m[t][p] += 1;
    }
    m
}

/// Mean squared error (regression CV).
pub fn mse(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    assert!(!pred.is_empty());
    // lint:allow(float_accum, reason = "serial scalar metric in one canonical order; metrics never run under a parallel backend")
    pred.iter().zip(truth).map(|(a, b)| (a - b) * (a - b)).sum::<f64>() / pred.len() as f64
}

/// Coefficient of determination R².
pub fn r_squared(pred: &[f64], truth: &[f64]) -> f64 {
    let m = crate::util::mean(truth);
    // lint:allow(float_accum, reason = "serial scalar metric in one canonical order; metrics never run under a parallel backend")
    let ss_res: f64 = pred.iter().zip(truth).map(|(a, b)| (b - a) * (b - a)).sum();
    // lint:allow(float_accum, reason = "serial scalar metric in one canonical order; metrics never run under a parallel backend")
    let ss_tot: f64 = truth.iter().map(|b| (b - m) * (b - m)).sum();
    1.0 - ss_res / ss_tot
}

/// The Linear Discriminant Contrast (LDC, §4.2): cross-validated projected
/// mean difference — the RSA dissimilarity `(m₁−m₂)_trainᵀ w` evaluated on
/// held-out data. Here computed from cross-validated decision values as the
/// difference of class-conditional means of `ẏ`.
pub fn ldc_from_dvals(dvals: &[f64], labels: &[usize]) -> f64 {
    let (mut s0, mut n0, mut s1, mut n1) = (0.0, 0usize, 0.0, 0usize);
    for (&d, &l) in dvals.iter().zip(labels) {
        if l == 0 {
            // lint:allow(float_accum, reason = "serial class-sum in one canonical order; metrics never run under a parallel backend")
            s0 += d;
            n0 += 1;
        } else {
            // lint:allow(float_accum, reason = "serial class-sum in one canonical order; metrics never run under a parallel backend")
            s1 += d;
            n1 += 1;
        }
    }
    assert!(n0 > 0 && n1 > 0);
    s0 / n0 as f64 - s1 / n1 as f64
}

/// Balanced accuracy: mean of per-class recalls (robust to class imbalance,
/// the metric of choice when the §2.5 bias issue matters).
pub fn balanced_accuracy(pred: &[usize], truth: &[usize], c: usize) -> f64 {
    let m = confusion(pred, truth, c);
    let mut acc = 0.0;
    let mut classes = 0;
    for t in 0..c {
        // lint:allow(float_accum, reason = "integer confusion-matrix count — exact arithmetic")
        let total: usize = m[t].iter().sum();
        if total > 0 {
            // lint:allow(float_accum, reason = "serial balanced-accuracy sum in one canonical order; metrics never run under a parallel backend")
            acc += m[t][t] as f64 / total as f64;
            classes += 1;
        }
    }
    acc / classes.max(1) as f64
}

/// F1 score for the positive class (label 0, the "+1" class).
pub fn f1_binary(pred: &[usize], truth: &[usize]) -> f64 {
    let m = confusion(pred, truth, 2);
    let tp = m[0][0] as f64;
    let fp = m[1][0] as f64;
    let fn_ = m[0][1] as f64;
    if tp == 0.0 {
        return 0.0;
    }
    let precision = tp / (tp + fp);
    let recall = tp / (tp + fn_);
    2.0 * precision * recall / (precision + recall)
}

/// Signal-detection d′ from decision values: separation of the two
/// class-conditional dval distributions in pooled-SD units.
pub fn d_prime(dvals: &[f64], labels: &[usize]) -> f64 {
    let pos: Vec<f64> = dvals.iter().zip(labels).filter(|(_, &l)| l == 0).map(|(&d, _)| d).collect();
    let neg: Vec<f64> = dvals.iter().zip(labels).filter(|(_, &l)| l == 1).map(|(&d, _)| d).collect();
    assert!(pos.len() >= 2 && neg.len() >= 2, "d' needs ≥2 samples per class");
    let (mp, mn) = (crate::util::mean(&pos), crate::util::mean(&neg));
    let (sp, sn) = (crate::util::stddev(&pos), crate::util::stddev(&neg));
    let pooled = (0.5 * (sp * sp + sn * sn)).sqrt();
    (mp - mn) / pooled.max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_signed_basic() {
        let dv = [1.0, -2.0, 0.0, -0.1];
        let y = [1.0, -1.0, -1.0, 1.0];
        // correct: 0 (1≥0,+), 1 (−2<0,−); wrong: 2 (0≥0 vs −), 3 (−0.1<0 vs +)
        assert_eq!(accuracy_signed(&dv, &y), 0.5);
    }

    #[test]
    fn accuracy_skips_uncovered_samples() {
        // Partial fold coverage: NaN decision values / usize::MAX labels
        // are excluded from both numerator and denominator.
        let dv = [1.0, f64::NAN, -2.0, f64::NAN];
        let y = [1.0, -1.0, -1.0, 1.0];
        assert_eq!(accuracy_signed(&dv, &y), 1.0);
        let dv = [1.0, f64::NAN, 2.0, f64::NAN];
        let y = [1.0, -1.0, -1.0, 1.0];
        assert_eq!(accuracy_signed(&dv, &y), 0.5);
        let pred = [0usize, usize::MAX, 1, usize::MAX];
        let truth = [0usize, 1, 0, 1];
        assert_eq!(accuracy_labels(&pred, &truth), 0.5);
    }

    #[test]
    #[should_panic(expected = "NaN")]
    fn accuracy_signed_rejects_all_nan() {
        accuracy_signed(&[f64::NAN, f64::NAN], &[1.0, -1.0]);
    }

    #[test]
    #[should_panic(expected = "sentinel")]
    fn accuracy_labels_rejects_all_sentinel() {
        accuracy_labels(&[usize::MAX, usize::MAX], &[0, 1]);
    }

    #[test]
    fn auc_perfect_and_chance() {
        let labels = [0, 0, 1, 1];
        assert_eq!(auc(&[2.0, 1.5, 0.2, -1.0], &labels), 1.0);
        assert_eq!(auc(&[-1.0, 0.2, 1.5, 2.0], &labels), 0.0);
        assert_eq!(auc(&[1.0, 1.0, 1.0, 1.0], &labels), 0.5);
    }

    #[test]
    fn auc_single_class_is_nan_not_panic() {
        // Regression: this used to assert. Single-class labellings occur
        // under label permutation / degenerate folds; λ selection must be
        // able to observe the undefined metric and rank it worst.
        assert!(auc(&[0.5, -0.5], &[0, 0]).is_nan());
        assert!(auc(&[0.5, -0.5], &[1, 1]).is_nan());
    }

    #[test]
    fn auc_invariant_to_bias_shift() {
        // §2.5: "if AUC is used, the bias term is irrelevant".
        let labels = [0, 1, 0, 1, 0];
        let dv = [0.3, -0.2, 1.1, 0.0, 0.6];
        let shifted: Vec<f64> = dv.iter().map(|d| d + 57.3).collect();
        assert_eq!(auc(&dv, &labels), auc(&shifted, &labels));
    }

    #[test]
    fn confusion_counts() {
        let m = confusion(&[0, 1, 1, 2, 0], &[0, 1, 2, 2, 1], 3);
        assert_eq!(m[0][0], 1);
        assert_eq!(m[1][1], 1);
        assert_eq!(m[1][0], 1);
        assert_eq!(m[2][1], 1);
        assert_eq!(m[2][2], 1);
    }

    #[test]
    fn regression_metrics() {
        let truth = [1.0, 2.0, 3.0];
        assert_eq!(mse(&truth, &truth), 0.0);
        assert!((r_squared(&truth, &truth) - 1.0).abs() < 1e-12);
        assert!(mse(&[0.0, 0.0, 0.0], &truth) > 0.0);
    }

    #[test]
    fn ldc_sign_and_magnitude() {
        let dv = [2.0, 2.0, -1.0, -1.0];
        let labels = [0, 0, 1, 1];
        assert_eq!(ldc_from_dvals(&dv, &labels), 3.0);
    }

    #[test]
    fn balanced_accuracy_vs_plain() {
        // 9 of class 0 (all right), 1 of class 1 (wrong): plain 0.9, balanced 0.5.
        let truth = [0, 0, 0, 0, 0, 0, 0, 0, 0, 1];
        let pred = [0usize; 10];
        assert!((accuracy_labels(&pred, &truth) - 0.9).abs() < 1e-12);
        assert!((balanced_accuracy(&pred, &truth, 2) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn f1_known_case() {
        // tp=1, fp=1, fn=1 → precision=recall=0.5 → F1=0.5
        let truth = [0, 0, 1, 1];
        let pred = [0, 1, 0, 1];
        assert!((f1_binary(&pred, &truth) - 0.5).abs() < 1e-12);
        // degenerate: no positives predicted right
        assert_eq!(f1_binary(&[1, 1], &[0, 0]), 0.0);
    }

    #[test]
    fn d_prime_separation() {
        let dv = [3.0, 2.5, 3.5, -3.0, -2.5, -3.5];
        let labels = [0, 0, 0, 1, 1, 1];
        assert!(d_prime(&dv, &labels) > 5.0);
        let dv_null = [0.1, -0.1, 0.2, 0.1, -0.1, 0.2];
        assert!(d_prime(&dv_null, &labels).abs() < 1.0);
    }
}
