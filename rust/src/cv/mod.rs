//! Cross-validation framework: fold partitioners, performance metrics, and
//! the standard (retrain-per-fold) CV runners used as the paper's baseline.

pub mod folds;
pub mod metrics;
pub mod runner;
