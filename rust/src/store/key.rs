//! Deterministic fingerprints for [`super::ArtifactKey`].
//!
//! A factor artifact is reusable exactly when every input that determines
//! its floats matches: the data bytes, the fold partition (for
//! fold-dependent artifacts), the *resolved* backend, the tile policy, the
//! preprocessing stage, and — for λ-specific artifacts — the ridge value.
//! The fingerprints here hash those inputs with FNV-1a over the exact bit
//! patterns (`f64::to_bits`), so two datasets collide only if they are
//! byte-identical in the same shape — which is precisely when sharing the
//! factor is bitwise-safe. No wall clock, no pointer identity, no entropy:
//! the same inputs fingerprint identically across runs and machines.
//!
//! Cost: one `O(NP)` pass per lookup — noise next to the `O(N²P)`/`O(NP²)`
//! Gram build a hit avoids.

use crate::linalg::Mat;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a over a stream of `u64` words (each mixed byte-by-byte).
#[derive(Clone, Copy, Debug)]
pub struct Fnv(u64);

impl Default for Fnv {
    fn default() -> Self {
        Fnv(FNV_OFFSET)
    }
}

impl Fnv {
    /// Fresh hasher at the FNV-1a offset basis.
    pub fn new() -> Fnv {
        Fnv::default()
    }

    /// Mix one 64-bit word (little-endian byte order).
    pub fn word(mut self, w: u64) -> Fnv {
        let mut h = self.0;
        for b in w.to_le_bytes() {
            h = (h ^ u64::from(b)).wrapping_mul(FNV_PRIME);
        }
        self.0 = h;
        self
    }

    /// Mix a string (length-prefixed so `"ab","c"` ≠ `"a","bc"`).
    pub fn str(mut self, s: &str) -> Fnv {
        self = self.word(s.len() as u64);
        let mut h = self.0;
        for b in s.bytes() {
            h = (h ^ u64::from(b)).wrapping_mul(FNV_PRIME);
        }
        self.0 = h;
        self
    }

    /// The digest.
    pub fn finish(self) -> u64 {
        self.0
    }
}

/// Fingerprint a matrix: shape plus every entry's exact bit pattern, in
/// row-major order. Bitwise-equal matrices of equal shape — and only those
/// — fingerprint equal (up to the 64-bit collision bound).
pub fn fingerprint_mat(m: &Mat) -> u64 {
    let mut h = Fnv::new().word(m.rows() as u64).word(m.cols() as u64);
    for v in m.as_slice() {
        h = h.word(v.to_bits());
    }
    h.finish()
}

/// Fingerprint a label vector (`f64` labels, exact bit patterns).
pub fn fingerprint_labels(labels: &[f64]) -> u64 {
    let mut h = Fnv::new().word(labels.len() as u64);
    for v in labels {
        h = h.word(v.to_bits());
    }
    h.finish()
}

/// Fingerprint a fold partition: fold count, then each fold's length and
/// test indices in order. Permuting folds or indices changes the digest —
/// fold-dependent artifacts are only safe to share for the identical
/// partition.
pub fn fingerprint_folds(folds: &[Vec<usize>]) -> u64 {
    let mut h = Fnv::new().word(folds.len() as u64);
    for fold in folds {
        h = h.word(fold.len() as u64);
        for &i in fold {
            h = h.word(i as u64);
        }
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mat_fingerprint_is_deterministic_and_shape_sensitive() {
        let a = Mat::from_fn(3, 4, |i, j| (i * 4 + j) as f64);
        let b = Mat::from_fn(3, 4, |i, j| (i * 4 + j) as f64);
        let c = Mat::from_fn(4, 3, |i, j| (i * 3 + j) as f64);
        assert_eq!(fingerprint_mat(&a), fingerprint_mat(&b));
        assert_ne!(fingerprint_mat(&a), fingerprint_mat(&c));
        let mut d = a.clone();
        d[(2, 1)] += 1e-9;
        assert_ne!(fingerprint_mat(&a), fingerprint_mat(&d));
    }

    #[test]
    fn negative_zero_is_distinct_from_positive_zero() {
        // The cache key must match *bitwise* reuse semantics: -0.0 and 0.0
        // are == but have different bit patterns, and a backend could in
        // principle produce different signs downstream.
        let a = Mat::from_fn(1, 1, |_, _| 0.0);
        let b = Mat::from_fn(1, 1, |_, _| -0.0);
        assert_ne!(fingerprint_mat(&a), fingerprint_mat(&b));
    }

    #[test]
    fn fold_fingerprint_is_order_sensitive() {
        let f1 = vec![vec![0usize, 1], vec![2, 3]];
        let f2 = vec![vec![2usize, 3], vec![0, 1]];
        let f3 = vec![vec![0usize, 1], vec![2, 3]];
        assert_eq!(fingerprint_folds(&f1), fingerprint_folds(&f3));
        assert_ne!(fingerprint_folds(&f1), fingerprint_folds(&f2));
    }

    #[test]
    fn label_fingerprint_separates_length_prefixes() {
        assert_ne!(fingerprint_labels(&[1.0, 2.0]), fingerprint_labels(&[1.0, 2.0, 0.0]));
    }

    #[test]
    fn str_mixing_is_length_prefixed() {
        let a = Fnv::new().str("ab").str("c").finish();
        let b = Fnv::new().str("a").str("bc").finish();
        assert_ne!(a, b);
    }
}
