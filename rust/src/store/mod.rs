//! # `FactorStore` — a keyed, budgeted cache of factor artifacts
//!
//! The paper's speed-up comes from building one Gram/factor structure and
//! amortising it across folds, λ grids, and permutations. Before this
//! module, that reuse logic was re-implemented ad hoc at every site:
//! `search_lambda_ctx` threaded a [`GramCache`] by hand,
//! `nested_cv_ctx` built its own [`SharedNestedGram`], each perm engine
//! rebuilt the hat from scratch, and the sweep coordinator rebuilt
//! everything per grid point. [`FactorStore`] centralises it: a keyed map
//!
//! ```text
//! ArtifactKey (data fp × fold fp × backend × tile × prep × λ) → Artifact
//! ```
//!
//! with an explicit memory budget, LRU eviction that *demotes* dense Gram
//! artifacts into the existing [`PanelStore`] spill layer before dropping
//! them, and hit/miss/evict/demote counters surfaced in `fastcv sweep`'s
//! TSV and `fastcv serve` responses.
//!
//! ## Bitwise contract
//!
//! A store hit returns the **same floats** a fresh build would produce:
//! the key covers every input that determines the artifact's bytes — the
//! exact data bit patterns ([`key::fingerprint_mat`]), the *resolved*
//! backend, the tile policy, the preprocessing stage, and (for λ-specific
//! artifacts) the ridge bits. Demotion to the spill layer preserves this:
//! [`PanelStore::write_mat`] is a pure byte round-trip and the spilled hat
//! paths are bitwise-identical to the dense Cholesky paths (the `spill_*`
//! property suites) — so evict-to-spill + readmit round-trips bitwise.
//! The one corner: a demoted `Primal` cache has no LU fallback, so a
//! λ = 0 fit on a *singular* Gram errors out of core instead of falling
//! back (same rule as [`TilePolicy::Spill`] itself).
//!
//! The store is strictly **opt-in**: a
//! [`ComputeContext`](crate::fastcv::context::ComputeContext) without one
//! (the default) takes the historical build paths untouched, so every
//! pre-existing entry point stays bitwise-unchanged.
//!
//! ## Corruption recovery
//!
//! Disk-spill-backed entries are **verified on every hit**: the panel
//! checksum sweep ([`GramCache::verify_spill`]) runs before the artifact
//! is served, and a torn or bit-rotted panel file (the typed
//! [`crate::linalg::SpillError`]) turns the hit into an eviction plus a
//! transparent rebuild — degrade, never serve bad bytes. The rebuilt
//! factor is bitwise the never-corrupted one (pinned by the `chaos_*`
//! suite); [`StoreStats::corruptions`] counts the events. See
//! `docs/ROBUSTNESS.md`.
//!
//! ## Concurrency
//!
//! All state sits behind one poison-tolerant [`Mutex`]; builds run
//! *outside* the lock (two threads may race to build the same key — the
//! first insert wins, the loser's work is dropped, both get the same
//! `Arc`). Recency is a logical clock, not wall time, so eviction order
//! is deterministic for a deterministic access sequence.

pub mod key;

use crate::fastcv::bigdata::StreamingHat;
use crate::fastcv::incremental::WindowFactor;
use crate::fastcv::context::ComputeContext;
use crate::fastcv::hat::{GramBackend, GramCache, SharedNestedGram};
use crate::linalg::{Mat, PanelStore, TilePolicy};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

/// What kind of factor an [`ArtifactKey`] names. Part of the key so the
/// same dataset can carry e.g. a λ-grid [`GramCache`] *and* a nested-CV
/// [`SharedNestedGram`] side by side.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum ArtifactKind {
    /// A λ-free [`GramCache`] (primal/dual/spectral, dense or spilled).
    Gram,
    /// A [`SharedNestedGram`] (full-data uncentered `XXᵀ`).
    Nested,
    /// A λ-specific [`StreamingHat`] (§4.5 big-data hat state).
    Streaming,
    /// A λ-specific sliding-window Cholesky factor maintained by rank-1
    /// up/downdates ([`WindowFactor`], the incremental engine's rolling
    /// state). Unlike the other kinds, window entries evolve: each stream
    /// step **supersedes** the previous key via [`FactorStore::supersede`]
    /// rather than invalidating it.
    Window,
}

/// Preprocessing stage baked into the cached factor. Currently only `Raw`
/// exists; the ROADMAP's fold-safe z-score/min-max stage will extend this
/// enum, and keying on it now means those artifacts can never collide with
/// raw ones.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Prep {
    /// No preprocessing — the factor is built from the data as given.
    Raw,
}

/// The full reuse key: two requests may share a cached factor **iff** their
/// keys are equal. Every field is an input that determines the factor's
/// float bytes — see the module docs for the bitwise contract.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct ArtifactKey {
    /// Which artifact family (Gram / nested / streaming).
    pub kind: ArtifactKind,
    /// FNV-1a fingerprint of the data matrix ([`key::fingerprint_mat`]).
    pub data: u64,
    /// Fold-partition fingerprint ([`key::fingerprint_folds`]); `0` for
    /// fold-free artifacts (all three current kinds — the hat machinery is
    /// fold-free by construction, that is the paper's point).
    pub folds: u64,
    /// The **resolved** backend tag ([`GramBackend::tag`]) — never `auto`,
    /// callers resolve first so a cache built as `dual` is never served to
    /// a `spectral` request.
    pub backend: &'static str,
    /// Tile-policy tag ([`TilePolicy::tag`]). Policies differing only in
    /// spill *directory* share a tag (the directory moves bytes, never
    /// floats).
    pub tile: String,
    /// Preprocessing stage.
    pub prep: Prep,
    /// `λ.to_bits()` for λ-specific artifacts ([`StreamingHat`]); `0` for
    /// λ-free caches, which exist precisely to serve every λ.
    pub lambda_bits: u64,
}

impl ArtifactKey {
    /// Key for a λ-free [`GramCache`] of `x` under a resolved `backend`
    /// and tile policy.
    pub fn gram(x: &Mat, backend: GramBackend, tile: &TilePolicy) -> ArtifactKey {
        ArtifactKey {
            kind: ArtifactKind::Gram,
            data: key::fingerprint_mat(x),
            folds: 0,
            backend: backend.tag(),
            tile: tile.tag(),
            prep: Prep::Raw,
            lambda_bits: 0,
        }
    }

    /// Key for the backend-free [`SharedNestedGram`] of `x` (the raw
    /// uncentered `XXᵀ` every outer fold downdates from).
    pub fn nested(x: &Mat, tile: &TilePolicy) -> ArtifactKey {
        ArtifactKey {
            kind: ArtifactKind::Nested,
            data: key::fingerprint_mat(x),
            folds: 0,
            backend: "nested",
            tile: tile.tag(),
            prep: Prep::Raw,
            lambda_bits: 0,
        }
    }

    /// Key for a λ-specific [`StreamingHat`] of `x` under a resolved
    /// `backend` and tile policy.
    pub fn streaming(
        x: &Mat,
        lambda: f64,
        backend: GramBackend,
        tile: &TilePolicy,
    ) -> ArtifactKey {
        ArtifactKey {
            kind: ArtifactKind::Streaming,
            data: key::fingerprint_mat(x),
            folds: 0,
            backend: backend.tag(),
            tile: tile.tag(),
            prep: Prep::Raw,
            lambda_bits: lambda.to_bits(),
        }
    }

    /// Key for a sliding-window factor ([`WindowFactor`]) identified by a
    /// *lineage fingerprint* — a running FNV digest over the exact
    /// append/evict/refresh operation sequence that produced the factor
    /// (see [`crate::fastcv::incremental`]), not a data-matrix pass. Two
    /// streams reach the same key exactly when they applied bitwise the
    /// same operations in the same order, which is when the factors are
    /// bitwise shareable.
    pub fn window(lineage: u64, lambda: f64) -> ArtifactKey {
        ArtifactKey {
            kind: ArtifactKind::Window,
            data: lineage,
            folds: 0,
            backend: "window",
            tile: TilePolicy::Off.tag(),
            prep: Prep::Raw,
            lambda_bits: lambda.to_bits(),
        }
    }
}

/// A cached factor, shared by `Arc` — a hit and the build that produced it
/// alias the same allocation.
#[derive(Clone)]
pub enum Artifact {
    /// λ-free Gram cache (primal/dual/spectral, dense or spilled).
    Gram(Arc<GramCache>),
    /// Shared nested-CV Gram.
    Nested(Arc<SharedNestedGram>),
    /// λ-specific streaming hat state.
    Streaming(Arc<StreamingHat>),
    /// Sliding-window rolling factor (the incremental engine's state).
    Window(Arc<WindowFactor>),
}

impl Artifact {
    /// Approximate resident RAM of the artifact in bytes (disk-backed
    /// panels count ~0 — that is what demotion buys).
    pub fn resident_bytes(&self) -> usize {
        match self {
            Artifact::Gram(g) => g.resident_bytes(),
            Artifact::Nested(g) => g.resident_bytes(),
            Artifact::Streaming(s) => s.resident_bytes(),
            Artifact::Window(w) => w.resident_bytes(),
        }
    }
}

/// One cache slot: the artifact, its byte cost, and a logical-clock stamp
/// for LRU ordering.
struct Entry {
    artifact: Artifact,
    bytes: usize,
    last_used: u64,
}

/// How many superseded (ancestor) keys stay resolvable through the
/// lineage map. A bounded trail keeps a long-running stream's memory flat:
/// every step adds one link, and handles more than `LINEAGE_CAP`
/// supersessions stale resolve as ordinary misses.
const LINEAGE_CAP: usize = 64;

struct Inner {
    entries: BTreeMap<ArtifactKey, Entry>,
    /// Lineage trail: superseded key → the key of the artifact that
    /// replaced it. Path-compressed on every supersession (all links point
    /// at the *live* descendant, never an intermediate), bounded by
    /// [`LINEAGE_CAP`] with `lineage_order` as the FIFO eviction queue.
    lineage: BTreeMap<ArtifactKey, ArtifactKey>,
    lineage_order: std::collections::VecDeque<ArtifactKey>,
    /// Logical access clock — monotone per store operation, no wall time,
    /// so eviction order is a pure function of the access sequence.
    clock: u64,
    budget: Option<usize>,
    /// Demotion target: spill directory + panel tile height. Without one,
    /// over-budget entries are dropped instead of demoted.
    spill: Option<(PathBuf, usize)>,
    hits: u64,
    misses: u64,
    evictions: u64,
    demotions: u64,
    supersessions: u64,
    corruptions: u64,
}

/// Counter snapshot returned by [`FactorStore::stats`]; the sweep TSV's
/// `cache` column and `fastcv serve`'s `stats` op render
/// [`StoreStats::tag`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Lookups served from the cache.
    pub hits: u64,
    /// Lookups that had to build.
    pub misses: u64,
    /// Entries dropped outright under budget pressure.
    pub evictions: u64,
    /// Dense Gram entries demoted into the spill layer under budget
    /// pressure (kept servable, resident cost ≈ the `X̃` working set).
    pub demotions: u64,
    /// In-place lineage replacements ([`FactorStore::supersede`]): a child
    /// artifact took over its parent's slot — not an eviction, the state
    /// advanced.
    pub supersessions: u64,
    /// Spill-backed entries whose verify-on-hit checksum sweep failed:
    /// each was evicted and transparently rebuilt (degrade, never serve
    /// bad bytes — see the module docs on corruption recovery).
    pub corruptions: u64,
    /// Live entries.
    pub entries: usize,
    /// Total resident bytes across live entries.
    pub resident_bytes: usize,
    /// The configured budget (`None` = unbounded).
    pub budget_bytes: Option<usize>,
}

impl StoreStats {
    /// Compact `h<hits>/m<misses>/e<evictions>/d<demotions>` tag for TSV
    /// columns and serve responses.
    pub fn tag(&self) -> String {
        format!("h{}/m{}/e{}/d{}", self.hits, self.misses, self.evictions, self.demotions)
    }

    /// Counter-wise difference against an earlier snapshot (for per-point
    /// deltas in the sweep TSV). Entry/byte gauges are taken from `self`.
    pub fn since(&self, earlier: &StoreStats) -> StoreStats {
        StoreStats {
            hits: self.hits - earlier.hits,
            misses: self.misses - earlier.misses,
            evictions: self.evictions - earlier.evictions,
            demotions: self.demotions - earlier.demotions,
            supersessions: self.supersessions - earlier.supersessions,
            corruptions: self.corruptions - earlier.corruptions,
            entries: self.entries,
            resident_bytes: self.resident_bytes,
            budget_bytes: self.budget_bytes,
        }
    }
}

/// The keyed factor cache. See the module docs for semantics; see
/// [`gram_for_ctx`] / [`nested_for_ctx`] for how the `_ctx` entry points
/// route through it.
pub struct FactorStore {
    inner: Mutex<Inner>,
}

impl std::fmt::Debug for FactorStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        f.debug_struct("FactorStore")
            .field("entries", &s.entries)
            .field("resident_bytes", &s.resident_bytes)
            .field("budget_bytes", &s.budget_bytes)
            .field("counters", &s.tag())
            .finish()
    }
}

impl FactorStore {
    /// An unbounded store (no budget, no spill demotion).
    pub fn new() -> FactorStore {
        FactorStore {
            inner: Mutex::new(Inner {
                entries: BTreeMap::new(),
                lineage: BTreeMap::new(),
                lineage_order: std::collections::VecDeque::new(),
                clock: 0,
                budget: None,
                spill: None,
                hits: 0,
                misses: 0,
                evictions: 0,
                demotions: 0,
                supersessions: 0,
                corruptions: 0,
            }),
        }
    }

    /// A store that keeps at most `budget_bytes` of resident factor state;
    /// beyond it, LRU entries are demoted (see [`FactorStore::with_spill`])
    /// or evicted.
    pub fn with_budget(budget_bytes: usize) -> FactorStore {
        let store = FactorStore::new();
        store.lock().budget = Some(budget_bytes);
        store
    }

    /// Configure a spill directory + panel tile height as the demotion
    /// target (builder style): under budget pressure, dense primal/dual
    /// Gram caches are rewritten as disk-backed [`PanelStore`] panels —
    /// still servable, bitwise-identical hats — before anything is dropped.
    pub fn with_spill(self, dir: PathBuf, tile: usize) -> FactorStore {
        self.lock().spill = Some((dir, tile.max(1)));
        self
    }

    /// Counter snapshot.
    pub fn stats(&self) -> StoreStats {
        let g = self.lock();
        StoreStats {
            hits: g.hits,
            misses: g.misses,
            evictions: g.evictions,
            demotions: g.demotions,
            supersessions: g.supersessions,
            corruptions: g.corruptions,
            entries: g.entries.len(),
            resident_bytes: resident_total(&g),
            budget_bytes: g.budget,
        }
    }

    /// Fetch the [`GramCache`] under `key`, building it with `build` on a
    /// miss. The returned `Arc` is shared with the cache slot.
    pub fn get_or_build_gram(
        &self,
        key: &ArtifactKey,
        build: impl FnOnce() -> Result<GramCache>,
    ) -> Result<Arc<GramCache>> {
        match self.fetch(key, || Ok(Artifact::Gram(Arc::new(build()?))))? {
            Artifact::Gram(g) => Ok(g),
            _ => bail!("factor store: key {key:?} holds a non-Gram artifact"),
        }
    }

    /// Fetch the [`SharedNestedGram`] under `key`, building on a miss.
    pub fn get_or_build_nested(
        &self,
        key: &ArtifactKey,
        build: impl FnOnce() -> Result<SharedNestedGram>,
    ) -> Result<Arc<SharedNestedGram>> {
        match self.fetch(key, || Ok(Artifact::Nested(Arc::new(build()?))))? {
            Artifact::Nested(g) => Ok(g),
            _ => bail!("factor store: key {key:?} holds a non-Nested artifact"),
        }
    }

    /// Fetch the [`StreamingHat`] under `key`, building on a miss.
    pub fn get_or_build_streaming(
        &self,
        key: &ArtifactKey,
        build: impl FnOnce() -> Result<StreamingHat>,
    ) -> Result<Arc<StreamingHat>> {
        match self.fetch(key, || Ok(Artifact::Streaming(Arc::new(build()?))))? {
            Artifact::Streaming(s) => Ok(s),
            _ => bail!("factor store: key {key:?} holds a non-Streaming artifact"),
        }
    }

    /// Insert `artifact` under `key` as a fresh lineage root (no parent).
    /// The incremental engine calls this once per stream when the first
    /// exact factor is built; each subsequent step goes through
    /// [`FactorStore::supersede`].
    pub fn put(&self, key: ArtifactKey, artifact: Artifact) {
        let bytes = artifact.resident_bytes();
        let mut g = self.lock();
        g.clock += 1;
        let now = g.clock;
        g.entries.insert(key.clone(), Entry { artifact, bytes, last_used: now });
        enforce_budget(&mut g, &key);
    }

    /// The key-lineage update: install `artifact` under `child`, retiring
    /// `parent` **in place** — the parent's slot is replaced, not
    /// invalidated, and a lineage link `parent → child` is recorded so a
    /// caller still holding the parent key resolves to the updated
    /// artifact through [`FactorStore::resolve`]. Existing links pointing
    /// at `parent` are rewritten to `child` (path compression), so every
    /// surviving ancestor resolves in one hop; the trail is bounded by
    /// [`LINEAGE_CAP`] (oldest links expire first, becoming plain misses).
    pub fn supersede(&self, parent: &ArtifactKey, child: ArtifactKey, artifact: Artifact) {
        let bytes = artifact.resident_bytes();
        let mut g = self.lock();
        g.clock += 1;
        let now = g.clock;
        g.entries.remove(parent);
        g.entries.insert(child.clone(), Entry { artifact, bytes, last_used: now });
        g.supersessions += 1;
        if *parent != child {
            // Path compression: every ancestor that resolved to `parent`
            // now resolves straight to `child`.
            for v in g.lineage.values_mut() {
                if *v == *parent {
                    *v = child.clone();
                }
            }
            if g.lineage.insert(parent.clone(), child.clone()).is_none() {
                g.lineage_order.push_back(parent.clone());
            }
            while g.lineage_order.len() > LINEAGE_CAP {
                if let Some(old) = g.lineage_order.pop_front() {
                    g.lineage.remove(&old);
                }
            }
        }
        enforce_budget(&mut g, &child);
    }

    /// Lineage-following lookup: the artifact live under `key`, or — when
    /// `key` has been superseded — under its latest recorded descendant.
    /// Counts as a hit either way (the state the caller asked about is
    /// still being served); `None` is a miss.
    pub fn resolve(&self, key: &ArtifactKey) -> Option<Artifact> {
        let mut g = self.lock();
        g.clock += 1;
        let now = g.clock;
        let live = if g.entries.contains_key(key) {
            key.clone()
        } else {
            match g.lineage.get(key) {
                Some(child) => child.clone(),
                None => {
                    g.misses += 1;
                    return None;
                }
            }
        };
        match g.entries.get_mut(&live) {
            Some(e) => {
                e.last_used = now;
                g.hits += 1;
                Some(e.artifact.clone())
            }
            None => {
                // The descendant itself fell to budget pressure.
                g.misses += 1;
                None
            }
        }
    }

    /// [`FactorStore::resolve`] narrowed to the sliding-window factor the
    /// incremental engine stores ([`Artifact::Window`]); `None` on a miss
    /// or a kind clash.
    pub fn resolve_window(&self, key: &ArtifactKey) -> Option<Arc<WindowFactor>> {
        match self.resolve(key) {
            Some(Artifact::Window(w)) => Some(w),
            _ => None,
        }
    }

    /// Exact-key lookup that does **not** follow supersession links: the
    /// artifact live under `key` itself, or `None`. Content-addressed
    /// callers — the incremental engine's exact-refresh keys, where the
    /// key names specific window bytes — must use this instead of
    /// [`FactorStore::resolve`]: a superseded content key means "the
    /// factor for those bytes was replaced by a *drifted* descendant",
    /// which must read as a miss, never be served as an exact hit.
    pub fn get(&self, key: &ArtifactKey) -> Option<Artifact> {
        let mut g = self.lock();
        g.clock += 1;
        let now = g.clock;
        match g.entries.get_mut(key) {
            Some(e) => {
                e.last_used = now;
                g.hits += 1;
                Some(e.artifact.clone())
            }
            None => {
                g.misses += 1;
                None
            }
        }
    }

    /// [`FactorStore::get`] narrowed to [`Artifact::Window`]; `None` on a
    /// miss, a superseded key, or a kind clash.
    pub fn get_window(&self, key: &ArtifactKey) -> Option<Arc<WindowFactor>> {
        match self.get(key) {
            Some(Artifact::Window(w)) => Some(w),
            _ => None,
        }
    }

    fn lock(&self) -> MutexGuard<'_, Inner> {
        // A poisoned store only means another thread panicked mid-insert;
        // the map itself is always structurally valid, so recover.
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// The single lookup-or-build path. The build runs **outside** the
    /// lock; on a racing double-build the first insert wins and both
    /// callers receive the winner's `Arc`.
    ///
    /// Disk-spill-backed hits are **verified before being served**: the
    /// panel checksum sweep ([`GramCache::verify_spill`]) runs outside
    /// the lock, and a failure — a torn or bit-rotted panel file — turns
    /// the hit into an eviction plus a transparent rebuild. The caller
    /// gets the rebuilt artifact (bitwise what the never-corrupted one
    /// served — the store's contract), never the bad bytes; the
    /// [`StoreStats::corruptions`] counter records the event.
    fn fetch(
        &self,
        key: &ArtifactKey,
        build: impl FnOnce() -> Result<Artifact>,
    ) -> Result<Artifact> {
        let candidate = {
            let mut g = self.lock();
            g.clock += 1;
            let now = g.clock;
            g.entries.get_mut(key).map(|e| {
                e.last_used = now;
                e.artifact.clone()
            })
        };
        match candidate {
            Some(a) => match verify_artifact(&a) {
                Ok(()) => {
                    self.lock().hits += 1;
                    return Ok(a);
                }
                Err(_) => {
                    // Degrade, never serve bad bytes: drop the corrupt
                    // entry (only if the slot still holds it — a racing
                    // writer may have replaced it already) and rebuild.
                    let mut g = self.lock();
                    g.corruptions += 1;
                    g.misses += 1;
                    let stale = g
                        .entries
                        .get(key)
                        .is_some_and(|e| artifact_ptr_eq(&e.artifact, &a));
                    if stale {
                        g.entries.remove(key);
                    }
                }
            },
            None => self.lock().misses += 1,
        }
        let built = build()?;
        let bytes = built.resident_bytes();
        let mut g = self.lock();
        g.clock += 1;
        let now = g.clock;
        let raced = g.entries.get_mut(key).map(|e| {
            e.last_used = now;
            e.artifact.clone()
        });
        if let Some(a) = raced {
            return Ok(a);
        }
        g.entries
            .insert(key.clone(), Entry { artifact: built.clone(), bytes, last_used: now });
        enforce_budget(&mut g, key);
        Ok(built)
    }
}

fn resident_total(g: &Inner) -> usize {
    g.entries.values().map(|e| e.bytes).sum::<usize>()
}

/// The verify-on-hit check: disk-spill-backed Gram caches re-read and
/// checksum their panels ([`GramCache::verify_spill`]); every resident
/// artifact verifies trivially (RAM cannot rot).
fn verify_artifact(a: &Artifact) -> Result<()> {
    match a {
        Artifact::Gram(g) if g.is_disk_spill() => g.verify_spill(),
        _ => Ok(()),
    }
}

/// Do two artifact handles alias the same allocation? Used to evict a
/// corrupt entry only when its slot still holds the artifact that failed
/// verification.
fn artifact_ptr_eq(a: &Artifact, b: &Artifact) -> bool {
    match (a, b) {
        (Artifact::Gram(x), Artifact::Gram(y)) => Arc::ptr_eq(x, y),
        (Artifact::Nested(x), Artifact::Nested(y)) => Arc::ptr_eq(x, y),
        (Artifact::Streaming(x), Artifact::Streaming(y)) => Arc::ptr_eq(x, y),
        (Artifact::Window(x), Artifact::Window(y)) => Arc::ptr_eq(x, y),
        _ => false,
    }
}

/// Demote or evict LRU entries until the store fits its budget. The entry
/// under `protect` (the one being returned right now) is never touched, so
/// a single over-budget artifact still gets served.
fn enforce_budget(g: &mut Inner, protect: &ArtifactKey) {
    let Some(budget) = g.budget else { return };
    while resident_total(g) > budget {
        let victim = g
            .entries
            .iter()
            .filter(|(k, _)| *k != protect)
            .min_by_key(|(_, e)| e.last_used)
            .map(|(k, _)| k.clone());
        let Some(k) = victim else { return };
        let demoted = match (&g.spill, g.entries.get(&k).map(|e| &e.artifact)) {
            (Some((dir, tile)), Some(Artifact::Gram(gc))) => demote_gram(gc, dir, *tile),
            _ => None,
        };
        match demoted {
            Some(spilled) => {
                let bytes = spilled.resident_bytes();
                if let Some(e) = g.entries.get_mut(&k) {
                    e.artifact = Artifact::Gram(Arc::new(spilled));
                    e.bytes = bytes;
                }
                g.demotions += 1;
            }
            None => {
                g.entries.remove(&k);
                g.evictions += 1;
            }
        }
    }
}

/// Rewrite a dense primal/dual [`GramCache`] as disk-backed [`PanelStore`]
/// panels. `None` when the variant has nothing dense to demote (spectral —
/// its eigenvector matrix cannot spill — or already-spilled arms) or on
/// spill-store IO failure (the caller then evicts instead). The panel
/// bytes equal the dense bytes ([`PanelStore::write_mat`] is a pure
/// round-trip), so readmitted hats are bitwise the dense Cholesky path's.
fn demote_gram(gc: &GramCache, dir: &Path, tile: usize) -> Option<GramCache> {
    match gc {
        GramCache::Primal { xa, g0 } => {
            let mut store = PanelStore::new(g0.rows(), tile, Some(dir)).ok()?;
            store.write_mat(g0).ok()?;
            Some(GramCache::PrimalSpill {
                xa: xa.clone(),
                g0: store,
                spill_dir: Some(dir.to_path_buf()),
            })
        }
        GramCache::Dual { xa, kc } => {
            let mut store = PanelStore::new(kc.rows(), tile, Some(dir)).ok()?;
            store.write_mat(kc).ok()?;
            Some(GramCache::DualSpill {
                xa: xa.clone(),
                kc: store,
                spill_dir: Some(dir.to_path_buf()),
            })
        }
        _ => None,
    }
}

/// The store-aware [`GramCache`] fetch every `_ctx` reuse site routes
/// through: without a store on the context this is exactly the historical
/// [`GramCache::build_tiled`] call (bitwise-unchanged paths); with one, the
/// build is keyed on (data fp × resolved backend × tile × prep) and shared
/// across requests. `backend` must be pre-resolved (never `Auto`) — the
/// callers resolve via [`ComputeContext::resolve_for_grid`] /
/// [`GramBackend::resolve`] exactly as before.
pub fn gram_for_ctx(
    x: &Mat,
    backend: GramBackend,
    ctx: &ComputeContext<'_>,
) -> Result<Arc<GramCache>> {
    match ctx.store() {
        None => Ok(Arc::new(GramCache::build_tiled(x, backend, ctx.pool(), ctx.tile_policy())?)),
        Some(store) => {
            let key = ArtifactKey::gram(x, backend, &ctx.tile_policy());
            store
                .get_or_build_gram(&key, || {
                    GramCache::build_tiled(x, backend, ctx.pool(), ctx.tile_policy())
                })
                .context("factor store gram fetch")
        }
    }
}

/// Store-aware [`SharedNestedGram`] fetch — the nested-CV sibling of
/// [`gram_for_ctx`], used by
/// [`crate::fastcv::lambda_search::nested_cv_ctx`] when the context both
/// shares nested Grams and carries a store.
pub fn nested_for_ctx(x: &Mat, ctx: &ComputeContext<'_>) -> Result<Arc<SharedNestedGram>> {
    match ctx.store() {
        None => Ok(Arc::new(SharedNestedGram::build_tiled(x, ctx.pool(), ctx.tile_policy())?)),
        Some(store) => {
            let key = ArtifactKey::nested(x, &ctx.tile_policy());
            store
                .get_or_build_nested(&key, || {
                    SharedNestedGram::build_tiled(x, ctx.pool(), ctx.tile_policy())
                })
                .context("factor store nested-gram fetch")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fastcv::hat::HatMatrix;
    use crate::util::rng::Rng;

    fn random_x(rng: &mut Rng, n: usize, p: usize) -> Mat {
        Mat::from_fn(n, p, |_, _| rng.gauss())
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("fastcv_store_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn keys_discriminate_every_field() {
        let mut rng = Rng::new(11);
        let x = random_x(&mut rng, 8, 20);
        let y = random_x(&mut rng, 8, 20);
        let k1 = ArtifactKey::gram(&x, GramBackend::Dual, &TilePolicy::Off);
        assert_eq!(k1, ArtifactKey::gram(&x, GramBackend::Dual, &TilePolicy::Off));
        assert_ne!(k1, ArtifactKey::gram(&y, GramBackend::Dual, &TilePolicy::Off));
        assert_ne!(k1, ArtifactKey::gram(&x, GramBackend::Spectral, &TilePolicy::Off));
        assert_ne!(k1, ArtifactKey::gram(&x, GramBackend::Dual, &TilePolicy::Rows(4)));
        assert_ne!(k1, ArtifactKey::nested(&x, &TilePolicy::Off));
        let s1 = ArtifactKey::streaming(&x, 0.5, GramBackend::Dual, &TilePolicy::Off);
        let s2 = ArtifactKey::streaming(&x, 1.5, GramBackend::Dual, &TilePolicy::Off);
        assert_ne!(s1, s2);
    }

    #[test]
    fn store_served_factor_bitwise_matches_fresh() {
        // Satellite property (a): a factor served from the store is
        // bitwise-identical to one built fresh — for every backend family.
        let mut rng = Rng::new(21);
        for backend in [GramBackend::Primal, GramBackend::Dual, GramBackend::Spectral] {
            let x = random_x(&mut rng, 12, 30);
            let fresh = GramCache::build(&x, backend, None).hat(0.7).unwrap();
            let store = FactorStore::new();
            let ctx = ComputeContext::serial().with_backend(backend).with_store(&store);
            let first = gram_for_ctx(&x, backend, &ctx).unwrap().hat(0.7).unwrap();
            let served = gram_for_ctx(&x, backend, &ctx).unwrap().hat(0.7).unwrap();
            assert_eq!(first.h.as_slice(), fresh.h.as_slice(), "{backend:?} miss-built");
            assert_eq!(served.h.as_slice(), fresh.h.as_slice(), "{backend:?} cache-served");
            let s = store.stats();
            assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1), "{backend:?}: {s:?}");
        }
    }

    #[test]
    fn store_served_hat_ctx_bitwise_matches_storeless() {
        // The HatMatrix::build_ctx seam (all four perm engines sit on it):
        // storeless vs store-carrying contexts produce byte-equal hats.
        let mut rng = Rng::new(22);
        let x = random_x(&mut rng, 10, 25);
        let plain = ComputeContext::serial();
        let store = FactorStore::new();
        let cached = ComputeContext::serial().with_store(&store);
        for lambda in [0.3, 2.0] {
            let a = HatMatrix::build_ctx(&x, lambda, &plain).unwrap();
            let b = HatMatrix::build_ctx(&x, lambda, &cached).unwrap();
            let c = HatMatrix::build_ctx(&x, lambda, &cached).unwrap();
            assert_eq!(a.h.as_slice(), b.h.as_slice(), "λ={lambda} miss");
            assert_eq!(a.h.as_slice(), c.h.as_slice(), "λ={lambda} hit");
        }
        // Both λ share one resolved backend on this shape → one entry.
        let s = store.stats();
        assert_eq!(s.entries, 1, "{s:?}");
        assert!(s.hits >= 1, "{s:?}");
    }

    #[test]
    fn store_evict_to_spill_readmit_roundtrips_bitwise() {
        // Satellite property (b): budget pressure demotes the LRU dense
        // Gram into disk panels; the readmitted artifact serves hats
        // byte-equal to the dense build, and nothing was dropped.
        let dir = tmp_dir("demote");
        let mut rng = Rng::new(23);
        let xa_mat = random_x(&mut rng, 10, 30); // dual: xa 10×31 + kc 10×10
        let xb_mat = random_x(&mut rng, 10, 30);
        let fresh = GramCache::build(&xa_mat, GramBackend::Dual, None).hat(0.9).unwrap();
        let bytes_dense = (10 * 31 + 10 * 10) * 8; // per dense dual entry
        let bytes_spilled = 10 * 31 * 8; // xa only once panels hit disk
        let store = FactorStore::with_budget(bytes_dense + bytes_spilled + 64)
            .with_spill(dir.clone(), 4);
        let ctx = ComputeContext::serial()
            .with_backend(GramBackend::Dual)
            .with_store(&store);
        gram_for_ctx(&xa_mat, GramBackend::Dual, &ctx).unwrap();
        gram_for_ctx(&xb_mat, GramBackend::Dual, &ctx).unwrap(); // over budget → demote A
        let s = store.stats();
        assert_eq!((s.demotions, s.evictions, s.entries), (1, 0, 2), "{s:?}");
        assert!(s.resident_bytes <= bytes_dense + bytes_spilled, "{s:?}");
        // Readmit A: a *hit* on the demoted entry, bitwise the dense hat.
        let readmitted = gram_for_ctx(&xa_mat, GramBackend::Dual, &ctx).unwrap();
        assert!(
            matches!(&*readmitted, GramCache::DualSpill { .. }),
            "entry should be serving from the spill layer"
        );
        let hat = readmitted.hat(0.9).unwrap();
        assert_eq!(hat.h.as_slice(), fresh.h.as_slice(), "evict-to-spill + readmit");
        let s = store.stats();
        assert_eq!((s.hits, s.misses), (1, 2), "{s:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn store_without_spill_evicts_outright_and_rebuilds() {
        let mut rng = Rng::new(24);
        let xa_mat = random_x(&mut rng, 10, 30);
        let xb_mat = random_x(&mut rng, 10, 30);
        let bytes_dense = (10 * 31 + 10 * 10) * 8;
        let store = FactorStore::with_budget(bytes_dense + 64); // fits exactly one
        let ctx = ComputeContext::serial()
            .with_backend(GramBackend::Dual)
            .with_store(&store);
        gram_for_ctx(&xa_mat, GramBackend::Dual, &ctx).unwrap();
        gram_for_ctx(&xb_mat, GramBackend::Dual, &ctx).unwrap(); // evicts A
        let s = store.stats();
        assert_eq!((s.evictions, s.demotions, s.entries), (1, 0, 1), "{s:?}");
        // A comes back as a fresh build (miss), still bitwise right.
        let rebuilt = gram_for_ctx(&xa_mat, GramBackend::Dual, &ctx).unwrap();
        let fresh = GramCache::build(&xa_mat, GramBackend::Dual, None).hat(0.4).unwrap();
        assert_eq!(rebuilt.hat(0.4).unwrap().h.as_slice(), fresh.h.as_slice());
        assert_eq!(store.stats().misses, 3);
    }

    #[test]
    fn protected_entry_survives_even_over_budget() {
        let mut rng = Rng::new(25);
        let x = random_x(&mut rng, 10, 30);
        let store = FactorStore::with_budget(8); // smaller than any artifact
        let ctx = ComputeContext::serial()
            .with_backend(GramBackend::Dual)
            .with_store(&store);
        let got = gram_for_ctx(&x, GramBackend::Dual, &ctx).unwrap();
        assert_eq!(got.n(), 10);
        // The just-inserted entry is protected; nothing to evict.
        let s = store.stats();
        assert_eq!((s.entries, s.evictions), (1, 0), "{s:?}");
    }

    #[test]
    fn chaos_corrupt_spill_artifact_is_evicted_and_rebuilt_bitwise() {
        // The corruption-recovery contract: a spill-backed entry whose
        // panel checksum fails on a hit is never served — the store
        // evicts it, rebuilds, and the rebuilt factor is bitwise the
        // never-corrupted one.
        let dir = tmp_dir("corrupt");
        let mut rng = Rng::new(26);
        let x = random_x(&mut rng, 12, 30);
        let spill = TilePolicy::Spill { dir: Some(dir.clone()), tile: 4 };
        let fresh = GramCache::build_tiled(&x, GramBackend::Dual, None, spill.clone())
            .unwrap()
            .hat(0.9)
            .unwrap();
        let store = FactorStore::new();
        let ctx = ComputeContext::serial()
            .with_backend(GramBackend::Dual)
            .with_store(&store)
            .with_tile_policy(spill);
        let first = gram_for_ctx(&x, GramBackend::Dual, &ctx).unwrap();
        let panel = match &*first {
            GramCache::DualSpill { kc, .. } => kc.panel_path(0).unwrap(),
            _ => panic!("spill policy must build a spilled dual cache"),
        };
        // bit rot on disk, behind the store's back
        let mut bytes = std::fs::read(&panel).unwrap();
        bytes[5] ^= 0x10;
        std::fs::write(&panel, &bytes).unwrap();
        // the next fetch detects it: eviction + transparent rebuild
        let recovered = gram_for_ctx(&x, GramBackend::Dual, &ctx).unwrap();
        assert!(!Arc::ptr_eq(&first, &recovered), "the corrupt artifact must not be served");
        assert_eq!(
            recovered.hat(0.9).unwrap().h.as_slice(),
            fresh.h.as_slice(),
            "rebuilt-after-corruption factor must equal the never-corrupted one"
        );
        let s = store.stats();
        assert_eq!((s.corruptions, s.misses), (1, 2), "{s:?}");
        // the recovered entry serves clean verified hits from here on
        let again = gram_for_ctx(&x, GramBackend::Dual, &ctx).unwrap();
        assert!(Arc::ptr_eq(&recovered, &again));
        assert_eq!(store.stats().hits, 1);
        drop((first, recovered, again));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn get_is_exact_while_resolve_follows_supersession() {
        use crate::fastcv::incremental::WindowFactor;
        use crate::linalg::Cholesky;
        let store = FactorStore::new();
        let wf = |lineage: u64| {
            let g = Mat::from_fn(2, 2, |i, j| if i == j { 2.0 + lineage as f64 } else { 0.5 });
            Arc::new(WindowFactor { chol: Cholesky::factor(&g).unwrap(), lineage })
        };
        let parent = ArtifactKey::window(1, 1.0);
        let child = ArtifactKey::window(2, 1.0);
        store.put(parent.clone(), Artifact::Window(wf(1)));
        store.supersede(&parent, child.clone(), Artifact::Window(wf(2)));
        // resolve serves the superseding artifact through the stale key…
        assert_eq!(store.resolve_window(&parent).unwrap().lineage, 2);
        // …get treats the superseded key as the miss it is.
        assert!(store.get_window(&parent).is_none());
        assert_eq!(store.get_window(&child).unwrap().lineage, 2);
    }

    #[test]
    fn stats_since_subtracts_counters() {
        let a = StoreStats { hits: 5, misses: 3, evictions: 1, demotions: 1, ..Default::default() };
        let b = StoreStats { hits: 2, misses: 3, evictions: 0, demotions: 1, ..Default::default() };
        let d = a.since(&b);
        assert_eq!((d.hits, d.misses, d.evictions, d.demotions), (3, 0, 1, 0));
        assert_eq!(d.tag(), "h3/m0/e1/d0");
    }
}
