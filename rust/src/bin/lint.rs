//! `fastcv-lint` standalone binary.
//!
//! Walks every `.rs` file in the workspace and enforces the determinism &
//! safety rule set (L1–L5; see `docs/LINTS.md`). Exits non-zero when any
//! violation is found, printing `file:line: [rule] message` diagnostics.
//!
//! ```text
//! cargo run --release --bin lint            # lint the workspace
//! cargo run --release --bin lint -- --root /path/to/repo
//! ```
//!
//! The same engine backs the `fastcv lint` subcommand and the
//! `lint_self_check_*` test; this binary is what `scripts/verify.sh` and CI
//! run *before* the test suite (fail fast).

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--root" => root = args.next().map(PathBuf::from),
            "--help" | "-h" => {
                println!("fastcv-lint: determinism & safety static analysis (docs/LINTS.md)");
                println!("usage: lint [--root REPO_ROOT]");
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("lint: unknown argument {other:?} (try --help)");
                return ExitCode::from(2);
            }
        }
    }
    // Default to the repo root this binary was compiled in: the parent of
    // the rust/ package directory. `--root` overrides for out-of-tree use.
    let root = root.unwrap_or_else(|| {
        let manifest: PathBuf = env!("CARGO_MANIFEST_DIR").into();
        manifest.parent().map(PathBuf::from).unwrap_or(manifest)
    });
    match fastcv::lint::lint_workspace(&root) {
        Ok(report) => {
            print!("{}", report.render());
            if report.violations() == 0 {
                ExitCode::SUCCESS
            } else {
                ExitCode::FAILURE
            }
        }
        Err(e) => {
            eprintln!("lint: failed to walk workspace at {}: {e}", root.display());
            ExitCode::from(2)
        }
    }
}
