//! # fastcv
//!
//! A production-grade reproduction of *"Cross-validation in high-dimensional
//! spaces: a lifeline for least-squares models and multi-class LDA"*
//! (Treder, 2018) as a three-layer Rust + JAX + Pallas system.
//!
//! The paper's contribution — obtaining **exact** k-fold cross-validated
//! predictions for least-squares models from a *single* full-data fit via the
//! hat matrix, and its non-trivial extension to multi-class LDA through
//! optimal scoring — lives in [`fastcv`]. Everything it rests on is
//! implemented here as well: dense linear algebra ([`linalg`]), statistical
//! sampling ([`stats`]), the classic retrain-per-fold baselines ([`model`],
//! [`cv`]), simulated workloads matching the paper's evaluation ([`data`]),
//! a sweep/permutation coordinator ([`coordinator`]), and a PJRT runtime
//! that executes the JAX/Pallas-compiled HLO artifacts ([`runtime`]).
//!
//! Performance is governed by three orthogonal, correctness-preserving
//! levers: the Gram backend ([`fastcv::hat::GramBackend`]; decision guide
//! in `docs/BACKENDS.md`), the permutation engine
//! ([`fastcv::perm_batch`]), and the thread pool a
//! [`fastcv::context::ComputeContext`] hands to the analytic front-ends.
//! The repository-root `README.md` maps the paper's equations to modules.
//!
//! ## Quick start
//!
//! ```no_run
//! use fastcv::data::synthetic::{SyntheticSpec, generate};
//! use fastcv::cv::folds::kfold;
//! use fastcv::fastcv::binary::AnalyticBinaryCv;
//! use fastcv::util::rng::Rng;
//!
//! let mut rng = Rng::new(7);
//! let ds = generate(&SyntheticSpec::binary(60, 12), &mut rng);
//! let folds = kfold(ds.n(), 5, &mut rng);
//! let cv = AnalyticBinaryCv::fit(&ds.x, &ds.y_signed(), 0.1).unwrap();
//! let dvals = cv.decision_values(&folds).unwrap();
//! let acc = fastcv::cv::metrics::accuracy_signed(&dvals, &ds.y_signed());
//! assert!(acc > 0.5);
//! ```

pub mod bench;
pub mod coordinator;
pub mod cv;
pub mod data;
pub mod error;
pub mod fastcv;
pub mod linalg;
pub mod lint;
pub mod model;
pub mod runtime;
pub mod serve;
pub mod stats;
pub mod store;
pub mod util;
