//! The five `fastcv-lint` rules (L1–L5) plus the suppression machinery,
//! evaluated over one file's token stream. See `docs/LINTS.md` for the
//! written rule set and the rationale mapping each rule to the repo's
//! bitwise-determinism contract.

use super::lexer::{Comment, TokKind, Token};
use super::{Diagnostic, Rule};

/// Per-file facts the rules condition on, derived from the relative path by
/// [`super::file_info`] (class, numeric-module membership, allowlists).
#[derive(Debug, Clone, Copy)]
pub struct FileInfo<'a> {
    pub rel: &'a str,
    /// `rust/src/**` — full rule set applies.
    pub library: bool,
    /// Numeric module (fastcv/linalg/stats/model/cv/data): L1 + `Instant`.
    pub numeric: bool,
    /// L1 kernel allowlist: float accumulation is this file's contract.
    pub kernel: bool,
    /// L3 audited-unsafe allowlist.
    pub unsafe_audited: bool,
    /// L4 file allowlist (documented panic policy, e.g. the thread pool).
    pub panic_allowed: bool,
    /// Permutation engine: only `Rng::stream(seed, idx)` construction.
    pub perm_engine: bool,
    /// Doc-everything surface (the store/serve daemon API): L5 extends
    /// beyond `_ctx` functions to every `pub fn`/`pub struct`/`pub enum`.
    pub doc_all_public: bool,
}

struct Suppression {
    line: u32,
    rule: Rule,
    used: bool,
}

/// Result of linting one file.
#[derive(Debug, Default)]
pub struct FileLint {
    pub diagnostics: Vec<Diagnostic>,
    /// Number of `lint:allow` suppressions that matched a violation.
    pub suppressions_used: usize,
}

const INT_TYPES: [&str; 12] = [
    "usize", "u8", "u16", "u32", "u64", "u128", "isize", "i8", "i16", "i32", "i64", "i128",
];

/// Run all rules over one file's lexed form.
pub fn lint_tokens(info: &FileInfo<'_>, toks: &[Token], comments: &[Comment]) -> FileLint {
    let mut out = FileLint::default();

    // ---- test region: from the first `#[cfg(test)]` or `#[test]` to EOF.
    // The repo convention keeps test modules at the bottom of each file;
    // the linter leans on that (documented in docs/LINTS.md).
    let mut test_from: Option<u32> = None;
    for k in 0..toks.len() {
        if tok_is(toks, k, TokKind::Punct, "#") && tok_is(toks, k + 1, TokKind::Punct, "[") {
            if tok_is(toks, k + 2, TokKind::Ident, "cfg")
                && tok_is(toks, k + 3, TokKind::Punct, "(")
                && tok_is(toks, k + 4, TokKind::Ident, "test")
            {
                test_from = Some(toks[k].line);
                break;
            }
            if tok_is(toks, k + 2, TokKind::Ident, "test") && tok_is(toks, k + 3, TokKind::Punct, "]")
            {
                test_from = Some(toks[k].line);
                break;
            }
        }
    }
    let in_test = |line: u32| test_from.is_some_and(|t| line >= t);

    // ---- parse `lint:allow(rule, reason = "...")` suppressions.
    let mut sups: Vec<Suppression> = Vec::new();
    for c in comments {
        // A directive is a plain `//` line comment starting with lint:allow(;
        // doc comments and prose mentions are not directives.
        if c.doc || !c.text.trim_start_matches('/').trim_start().starts_with("lint:allow(") {
            continue;
        }
        let Some(idx) = c.text.find("lint:allow(") else { continue };
        let inner = &c.text[idx + "lint:allow(".len()..];
        let body = match inner.find(')') {
            Some(close) => &inner[..close],
            None => inner,
        };
        let rule_name = body.split(',').next().unwrap_or("").trim();
        let rest = &c.text[idx..];
        let reason = rest.find("reason").and_then(|ridx| {
            let q1 = rest[ridx..].find('"').map(|q| ridx + q)?;
            let q2 = rest[q1 + 1..].find('"').map(|q| q1 + 1 + q)?;
            Some(&rest[q1 + 1..q2])
        });
        let Some(rule) = Rule::parse(rule_name) else {
            out.diagnostics.push(Diagnostic {
                line: c.line,
                rule: Rule::Suppression,
                msg: format!("unknown rule `{rule_name}` in lint:allow"),
            });
            continue;
        };
        if !matches!(reason, Some(r) if !r.is_empty()) {
            out.diagnostics.push(Diagnostic {
                line: c.line,
                rule: Rule::Suppression,
                msg: format!("lint:allow({rule_name}) missing reason = \"...\""),
            });
            continue;
        }
        sups.push(Suppression { line: c.line, rule, used: false });
    }

    // A suppression covers its own line and the first token-bearing line
    // after it (the annotate-above idiom).
    let mut tok_lines: Vec<u32> = toks.iter().map(|t| t.line).collect();
    tok_lines.dedup();
    let next_tok_line = |after: u32| -> Option<u32> {
        tok_lines.iter().copied().find(|&l| l > after)
    };
    let covered = |line: u32, rule: Rule, sups: &mut Vec<Suppression>| -> bool {
        for s in sups.iter_mut() {
            if s.rule != rule {
                continue;
            }
            if s.line == line || next_tok_line(s.line) == Some(line) {
                s.used = true;
                return true;
            }
        }
        false
    };

    // ---- token walk with just enough structure for the rules.
    let mut brace_is_loop: Vec<bool> = Vec::new();
    let mut loop_depth = 0usize;
    let mut pending_loop = false;
    let mut paren = 0usize;
    let mut bracket = 0usize;
    let m = toks.len();

    for k in 0..m {
        let t = &toks[k];
        let line = t.line;
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "(" => paren += 1,
                ")" => paren = paren.saturating_sub(1),
                "[" => bracket += 1,
                "]" => bracket = bracket.saturating_sub(1),
                "{" => {
                    let is_loop = pending_loop && paren == 0 && bracket == 0;
                    if is_loop {
                        pending_loop = false;
                        loop_depth += 1;
                    }
                    brace_is_loop.push(is_loop);
                }
                "}" => {
                    if brace_is_loop.pop() == Some(true) {
                        loop_depth = loop_depth.saturating_sub(1);
                    }
                }
                _ => {}
            }
        }
        if t.kind == TokKind::Ident && matches!(t.text.as_str(), "for" | "while" | "loop") {
            // `for<'a>` higher-ranked bounds are not loops.
            if !(t.text == "for" && tok_is(toks, k + 1, TokKind::Punct, "<")) {
                pending_loop = true;
            }
        }

        // ---- L1: float accumulation outside the kernel allowlist.
        if info.library && info.numeric && !info.kernel {
            if t.kind == TokKind::Punct && (t.text == "+=" || t.text == "-=") && loop_depth > 0 {
                let literal_rhs = toks
                    .get(k + 1)
                    .is_some_and(|n| n.kind == TokKind::Int || n.kind == TokKind::Float)
                    && tok_is(toks, k + 2, TokKind::Punct, ";");
                if !literal_rhs && !in_test(line) && !covered(line, Rule::FloatAccum, &mut sups) {
                    out.diagnostics.push(Diagnostic {
                        line,
                        rule: Rule::FloatAccum,
                        msg: format!(
                            "compound accumulation `{}` in a loop outside the kernel allowlist \
                             — route through linalg kernels or lint:allow with a reason",
                            t.text
                        ),
                    });
                }
            }
            if t.kind == TokKind::Ident
                && matches!(t.text.as_str(), "sum" | "product" | "fold")
                && prev_is(toks, k, TokKind::Punct, ".")
                && (tok_is(toks, k + 1, TokKind::Punct, "(") || tok_is(toks, k + 1, TokKind::Punct, "::"))
            {
                // `.sum::<usize>()` and friends are integer-exact: exempt.
                let int_turbofish = tok_is(toks, k + 1, TokKind::Punct, "::")
                    && tok_is(toks, k + 2, TokKind::Punct, "<")
                    && toks
                        .get(k + 3)
                        .is_some_and(|n| n.kind == TokKind::Ident && INT_TYPES.contains(&n.text.as_str()));
                if !int_turbofish && !in_test(line) && !covered(line, Rule::FloatAccum, &mut sups) {
                    out.diagnostics.push(Diagnostic {
                        line,
                        rule: Rule::FloatAccum,
                        msg: format!(
                            "iterator reduction `.{}` outside the kernel allowlist \
                             — route through linalg kernels or lint:allow with a reason",
                            t.text
                        ),
                    });
                }
            }
        }

        // ---- L2: nondeterminism sources.
        if info.library && t.kind == TokKind::Ident {
            let nondet_msg: Option<String> = match t.text.as_str() {
                "HashMap" | "HashSet" => Some(format!(
                    "`{}` iteration order is nondeterministic; use BTreeMap/BTreeSet/Vec",
                    t.text
                )),
                "SystemTime" | "UNIX_EPOCH" => {
                    Some(format!("wall-clock `{}` in library code", t.text))
                }
                "thread_rng" | "from_entropy" | "OsRng" | "getrandom" => Some(format!(
                    "entropy-seeded RNG `{}` — all randomness must be explicitly seeded",
                    t.text
                )),
                "Instant" if info.numeric => {
                    Some("`Instant` in a numeric module — wall-clock must never feed results".into())
                }
                _ => None,
            };
            if let Some(msg) = nondet_msg {
                if !in_test(line) && !covered(line, Rule::Nondet, &mut sups) {
                    out.diagnostics.push(Diagnostic { line, rule: Rule::Nondet, msg });
                }
            }
            if info.perm_engine {
                if t.text == "Rng"
                    && tok_is(toks, k + 1, TokKind::Punct, "::")
                    && toks.get(k + 2).is_some_and(|n| {
                        n.kind == TokKind::Ident && (n.text == "new" || n.text == "with_stream")
                    })
                {
                    if !in_test(line) && !covered(line, Rule::Nondet, &mut sups) {
                        out.diagnostics.push(Diagnostic {
                            line,
                            rule: Rule::Nondet,
                            msg: format!(
                                "`Rng::{}` in a permutation engine — only counter-seeded \
                                 `Rng::stream(seed, idx)` keeps engines order-independent",
                                toks[k + 2].text
                            ),
                        });
                    }
                }
                if t.text == "fork" && prev_is(toks, k, TokKind::Punct, ".") {
                    if !in_test(line) && !covered(line, Rule::Nondet, &mut sups) {
                        out.diagnostics.push(Diagnostic {
                            line,
                            rule: Rule::Nondet,
                            msg: "stateful `.fork()` in a permutation engine — use \
                                  `Rng::stream(seed, idx)`"
                                .into(),
                        });
                    }
                }
            }
        }

        // ---- L3: unsafe hygiene (applies in tests too).
        if t.kind == TokKind::Ident && t.text == "unsafe" {
            // A SAFETY argument may be long: locate the comment block that
            // ends within 5 lines above the `unsafe`, then search the whole
            // contiguous block for the marker.
            let comment_lines: std::collections::BTreeSet<u32> =
                comments.iter().map(|c| c.line).collect();
            let nearest = comment_lines
                .iter()
                .copied()
                .filter(|&cl| cl <= line && cl + 5 >= line)
                .max();
            let has_safety = nearest.is_some_and(|nearest| {
                let mut start = nearest;
                while start > 0 && comment_lines.contains(&(start - 1)) {
                    start -= 1;
                }
                comments
                    .iter()
                    .any(|c| c.line >= start && c.line <= nearest && c.text.contains("SAFETY:"))
            });
            if !has_safety && !covered(line, Rule::Unsafe, &mut sups) {
                out.diagnostics.push(Diagnostic {
                    line,
                    rule: Rule::Unsafe,
                    msg: "unsafe block without an adjacent `// SAFETY:` comment".into(),
                });
            }
            if !info.unsafe_audited && !covered(line, Rule::Unsafe, &mut sups) {
                out.diagnostics.push(Diagnostic {
                    line,
                    rule: Rule::Unsafe,
                    msg: format!("`unsafe` outside the audited-file allowlist ({})", info.rel),
                });
            }
        }

        // ---- L4: panic hygiene on library paths.
        if info.library && !info.panic_allowed {
            let panicky = if t.kind == TokKind::Ident
                && (t.text == "unwrap" || t.text == "expect")
                && prev_is(toks, k, TokKind::Punct, ".")
                && tok_is(toks, k + 1, TokKind::Punct, "(")
            {
                Some(format!(
                    "`.{}()` on a library path — propagate the error or lint:allow with a reason",
                    t.text
                ))
            } else if t.kind == TokKind::Ident
                && matches!(t.text.as_str(), "panic" | "unreachable" | "todo" | "unimplemented")
                && tok_is(toks, k + 1, TokKind::Punct, "!")
            {
                Some(format!(
                    "`{}!` on a library path — return Err or lint:allow with a reason",
                    t.text
                ))
            } else {
                None
            };
            if let Some(msg) = panicky {
                if !in_test(line) && !covered(line, Rule::Panic, &mut sups) {
                    out.diagnostics.push(Diagnostic { line, rule: Rule::Panic, msg });
                }
            }
        }

        // ---- L5: rustdoc on the public contract surface. Everywhere:
        // public `_ctx` entry points. In doc-all files (the store/serve
        // daemon API): every `pub fn`/`pub struct`/`pub enum`
        // (`pub(crate)` is internal and stays exempt).
        if info.library
            && t.kind == TokKind::Ident
            && t.text == "pub"
            && !tok_is(toks, k + 1, TokKind::Punct, "(")
        {
            let kw = toks.get(k + 1).filter(|n| n.kind == TokKind::Ident);
            let name = toks.get(k + 2).filter(|n| n.kind == TokKind::Ident);
            let needs_doc = match (kw, name) {
                (Some(kw), Some(nm)) if kw.text == "fn" => {
                    nm.text.ends_with("_ctx") || info.doc_all_public
                }
                (Some(kw), Some(_)) if kw.text == "struct" || kw.text == "enum" => {
                    info.doc_all_public
                }
                _ => false,
            };
            if needs_doc {
                let has_doc = comments
                    .iter()
                    .any(|c| c.doc && c.line + 3 >= line && c.line < line);
                if !has_doc && !in_test(line) && !covered(line, Rule::Doc, &mut sups) {
                    let surface = if info.doc_all_public {
                        "the store/serve API documents every public item"
                    } else {
                        "the ComputeContext surface is the documented API"
                    };
                    out.diagnostics.push(Diagnostic {
                        line,
                        rule: Rule::Doc,
                        msg: format!(
                            "public `{}` without rustdoc — {surface}",
                            toks[k + 2].text
                        ),
                    });
                }
            }
        }
    }

    // Unused suppressions are violations: an allow that no longer matches
    // anything is stale documentation.
    for s in &sups {
        if !s.used && !in_test(s.line) {
            out.diagnostics.push(Diagnostic {
                line: s.line,
                rule: Rule::Suppression,
                msg: format!("unused lint:allow({})", s.rule.name()),
            });
        }
    }
    out.suppressions_used = sups.iter().filter(|s| s.used).count();
    out.diagnostics.sort_by_key(|d| d.line);
    out
}

fn tok_is(toks: &[Token], k: usize, kind: TokKind, text: &str) -> bool {
    toks.get(k).is_some_and(|t| t.kind == kind && t.text == text)
}

fn prev_is(toks: &[Token], k: usize, kind: TokKind, text: &str) -> bool {
    k > 0 && toks[k - 1].kind == kind && toks[k - 1].text == text
}
