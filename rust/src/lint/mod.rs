//! # `fastcv-lint` — repo-local determinism & safety static analysis
//!
//! Every speedup this repo ships (pooled GEMM, tiled Cholesky, out-of-core
//! spill, the `Auto` backend flip) rests on one invariant: all backends
//! reproduce the serial accumulation order **bitwise**, so the paper's
//! analytic CV and its permutation nulls stay exact rather than
//! approximately equal. The dynamic property suites (`backend_*`, `tiled_*`,
//! `spill_*`) enforce that contract at run time; this module enforces its
//! *preconditions* at the source level, before any test runs:
//!
//! - **L1 `float_accum`** — float accumulation (`+=`/`-=` in loops, iterator
//!   `.sum`/`.fold`/`.product`) only inside the canonical-kernel allowlist.
//! - **L2 `nondet`** — no `HashMap`/`HashSet`, wall-clock types, or
//!   entropy-seeded RNGs on library paths; permutation engines construct
//!   RNGs only via counter-seeded `Rng::stream(seed, idx)`.
//! - **L3 `unsafe`** — every `unsafe` needs an adjacent `// SAFETY:` comment
//!   and must live in an audited file.
//! - **L4 `panic`** — no `unwrap`/`expect`/`panic!` on library paths outside
//!   the documented allowlist (groundwork for a `fastcv serve` daemon).
//! - **L5 `doc`** — every public `_ctx` entry point carries rustdoc; under
//!   `rust/src/store/` and `rust/src/serve/` (the daemon's public API) the
//!   requirement widens to every `pub fn`/`pub struct`/`pub enum`.
//!
//! Violations are suppressed site-by-site with
//! `// lint:allow(<rule>, reason = "...")`; suppressions are counted,
//! reported, and themselves linted (unknown rule, missing reason, unused).
//! The full rule set, allowlist policy, and known blind spots are written up
//! in `docs/LINTS.md`.
//!
//! Entry points: the `lint` binary (`cargo run --release --bin lint`), the
//! `fastcv lint` subcommand, and [`lint_workspace`] for the self-check test.

pub mod lexer;
pub mod rules;

use rules::{FileInfo, FileLint};
use std::fmt;
use std::path::{Path, PathBuf};

/// Rule identifiers, named as they appear in `lint:allow(...)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    /// L1: float accumulation outside the kernel allowlist.
    FloatAccum,
    /// L2: nondeterminism sources (hash iteration, wall clock, entropy RNG).
    Nondet,
    /// L3: unsafe hygiene.
    Unsafe,
    /// L4: panic hygiene on library paths.
    Panic,
    /// L5: doc/contract drift on public `_ctx` entry points.
    Doc,
    /// Meta: malformed or unused `lint:allow` markers.
    Suppression,
}

impl Rule {
    /// The name used in `lint:allow(<name>, ...)` and diagnostics.
    pub fn name(&self) -> &'static str {
        match self {
            Rule::FloatAccum => "float_accum",
            Rule::Nondet => "nondet",
            Rule::Unsafe => "unsafe",
            Rule::Panic => "panic",
            Rule::Doc => "doc",
            Rule::Suppression => "suppression",
        }
    }

    /// Parse a `lint:allow` rule name (the meta `suppression` rule cannot
    /// itself be suppressed).
    pub fn parse(name: &str) -> Option<Rule> {
        match name {
            "float_accum" => Some(Rule::FloatAccum),
            "nondet" => Some(Rule::Nondet),
            "unsafe" => Some(Rule::Unsafe),
            "panic" => Some(Rule::Panic),
            "doc" => Some(Rule::Doc),
            _ => None,
        }
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One finding at a file line.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub line: u32,
    pub rule: Rule,
    pub msg: String,
}

// ---------------------------------------------------------------------------
// Allowlists. Every entry carries its reason here, in one audited place;
// docs/LINTS.md explains the policy for growing or shrinking these.
// ---------------------------------------------------------------------------

/// Numeric modules: where L1 (float accumulation) and the `Instant` ban
/// apply. The coordinator/runtime/util layers orchestrate and report — they
/// never produce numbers that feed results.
const NUMERIC_DIRS: [&str; 6] = [
    "rust/src/fastcv/",
    "rust/src/linalg/",
    "rust/src/stats/",
    "rust/src/model/",
    "rust/src/cv/",
    "rust/src/data/",
];

/// L1 kernel allowlist: files whose float accumulation order *is* the
/// repo-wide contract. Everything else routes through these.
const KERNEL_FILES: [&str; 12] = [
    "rust/src/linalg/gemm.rs",      // blocked GEMM microkernel: the canonical order
    "rust/src/linalg/tiled.rs",     // tiled Gram/syrk — bitwise = gemm order (tiled_* suite)
    "rust/src/linalg/spill.rs",     // out-of-core panels — bitwise = in-RAM (spill_* suite)
    "rust/src/linalg/chol.rs",      // Cholesky recurrence: serial order pinned by factor_into
    "rust/src/linalg/chol_update.rs", // rank-1 up/downdate rotations — ISA-invariant (stream_* suite)
    "rust/src/linalg/lu.rs",        // LU recurrence, same contract
    "rust/src/linalg/eig.rs",       // symmetric eig sweeps (spectral backend contract)
    "rust/src/linalg/mat.rs",       // Mat primitives (matvec_gemm_order et al.)
    "rust/src/linalg/mod.rs",       // pooled wrappers (matmul_pool/syrk_t_pool)
    "rust/src/linalg/dispatch.rs",  // ISA kernel tables (routes to the files below)
    "rust/src/linalg/simd_avx2.rs", // AVX2 kernels — bitwise = scalar (kernel_conformance_*)
    "rust/src/linalg/simd_neon.rs", // NEON kernels — bitwise = scalar (kernel_conformance_*)
];

/// L3: files whose `unsafe` blocks have been audited (see the SAFETY
/// comments in situ and the ThreadSanitizer CI job).
const UNSAFE_AUDITED_FILES: [&str; 4] = [
    "rust/src/util/threadpool.rs",
    // SIMD intrinsics: every `unsafe` carries an adjacent SAFETY note and
    // the wrappers re-check the CPU feature the dispatch table promised —
    // see the "Unsafe audit" section in each module's docs.
    "rust/src/linalg/simd_avx2.rs",
    "rust/src/linalg/simd_neon.rs",
    // Hand-declared POSIX externs (no libc crate) for the SIGTERM socket
    // cleanup; the handler body is restricted to async-signal-safe calls
    // and every unsafe block carries its SAFETY note in situ.
    "rust/src/serve/signal.rs",
];

/// L4 file allowlist: panicking is these files' documented policy.
const PANIC_ALLOWED_FILES: [&str; 4] = [
    // Lock-poisoning propagation and scope panic re-raise are the pool's
    // contract (audited with L3; jobs are individually catch_unwind-ed).
    "rust/src/util/threadpool.rs",
    // The property-test harness reports failures by panicking.
    "rust/src/util/prop.rs",
    // Dimension-contract asserts on the update kernels (caller bug, the
    // same policy as Mat indexing); SPD-boundary failures return Result.
    "rust/src/linalg/chol_update.rs",
    // The serve daemon's catch_unwind boundary: `maybe_panic` is the
    // deliberate fault-injection path for the serve.*.panic chaos sites,
    // contained by run_caught into typed worker_panic responses.
    "rust/src/serve/recover.rs",
];

/// L2: permutation engines — RNG construction restricted to `Rng::stream`.
const PERM_ENGINE_FILES: [&str; 2] =
    ["rust/src/fastcv/perm.rs", "rust/src/fastcv/perm_batch.rs"];

/// L5 doc-everything surface: the factor store and the serve daemon are
/// public API whose whole item set (not just `_ctx` functions) must carry
/// rustdoc — their keying/eviction/coalescing semantics live in the docs.
const DOC_ALL_PUBLIC_DIRS: [&str; 2] = ["rust/src/store/", "rust/src/serve/"];

/// Directory names never descended into when walking the workspace.
const SKIP_DIRS: [&str; 3] = [
    "vendor",        // offline API stubs: external code, not ours to lint
    "target",
    "lint_fixtures", // deliberately-violating corpus for the lint tests
];

/// How a file participates in linting, derived from its repo-relative path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FileClass {
    /// `rust/vendor/**` — skipped entirely.
    Vendor,
    /// Tests, benches, examples: only L3 (unsafe hygiene) applies.
    Exempt,
    /// `rust/src/**`: the full rule set.
    Library,
}

/// Classify a repo-relative path (forward slashes).
pub fn classify(rel: &str) -> FileClass {
    if rel.starts_with("rust/vendor/") {
        FileClass::Vendor
    } else if rel.starts_with("rust/src/") {
        FileClass::Library
    } else {
        FileClass::Exempt
    }
}

/// Build the per-file rule facts for a repo-relative path.
pub fn file_info(rel: &str) -> FileInfo<'_> {
    let class = classify(rel);
    FileInfo {
        rel,
        library: class == FileClass::Library,
        numeric: NUMERIC_DIRS.iter().any(|d| rel.starts_with(d)),
        kernel: KERNEL_FILES.contains(&rel),
        unsafe_audited: UNSAFE_AUDITED_FILES.contains(&rel),
        panic_allowed: PANIC_ALLOWED_FILES.contains(&rel),
        perm_engine: PERM_ENGINE_FILES.contains(&rel),
        doc_all_public: DOC_ALL_PUBLIC_DIRS.iter().any(|d| rel.starts_with(d)),
    }
}

/// Lint one file's source under its repo-relative path. Vendor paths return
/// an empty report.
pub fn lint_source(rel: &str, src: &str) -> FileLint {
    if classify(rel) == FileClass::Vendor {
        return FileLint::default();
    }
    let (toks, comments) = lexer::lex(src);
    rules::lint_tokens(&file_info(rel), &toks, &comments)
}

/// One file's findings inside a workspace report.
#[derive(Debug)]
pub struct FileReport {
    pub rel: String,
    pub diagnostics: Vec<Diagnostic>,
}

/// Workspace-wide lint result.
#[derive(Debug, Default)]
pub struct Report {
    pub files: Vec<FileReport>,
    pub files_scanned: usize,
    pub suppressions_used: usize,
}

impl Report {
    /// Total violation count.
    pub fn violations(&self) -> usize {
        self.files.iter().map(|f| f.diagnostics.len()).sum()
    }

    /// Render `file:line: [rule] message` diagnostics plus a summary line.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for f in &self.files {
            for d in &f.diagnostics {
                out.push_str(&format!("{}:{}: [{}] {}\n", f.rel, d.line, d.rule, d.msg));
            }
        }
        out.push_str(&format!(
            "fastcv-lint: {} violation(s), {} suppression(s) in use, {} file(s) scanned\n",
            self.violations(),
            self.suppressions_used,
            self.files_scanned
        ));
        out
    }
}

/// The workspace sub-trees the linter walks (relative to the repo root).
const WALK_ROOTS: [&str; 4] = ["rust/src", "rust/benches", "rust/tests", "examples"];

/// Collect every lintable `.rs` file under `root` in a deterministic
/// (sorted) order.
pub fn workspace_files(root: &Path) -> std::io::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    for top in WALK_ROOTS {
        let dir = root.join(top);
        if dir.is_dir() {
            collect_rs(&dir, &mut out)?;
        }
    }
    Ok(out)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<PathBuf> =
        std::fs::read_dir(dir)?.map(|e| e.map(|e| e.path())).collect::<Result<_, _>>()?;
    entries.sort();
    for path in entries {
        if path.is_dir() {
            let skip = path
                .file_name()
                .map(|n| SKIP_DIRS.iter().any(|s| n == std::ffi::OsStr::new(s)))
                .unwrap_or(true);
            if !skip {
                collect_rs(&path, out)?;
            }
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Lint every workspace file under `root` (the repo root — the directory
/// holding `rust/` and `examples/`).
pub fn lint_workspace(root: &Path) -> std::io::Result<Report> {
    let mut report = Report::default();
    for path in workspace_files(root)? {
        let rel_path = path.strip_prefix(root).unwrap_or(&path);
        let rel = rel_path.to_string_lossy().replace('\\', "/");
        let src = std::fs::read_to_string(&path)?;
        let lint = lint_source(&rel, &src);
        report.files_scanned += 1;
        report.suppressions_used += lint.suppressions_used;
        if !lint.diagnostics.is_empty() {
            report.files.push(FileReport { rel, diagnostics: lint.diagnostics });
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classify_partitions_the_tree() {
        assert_eq!(classify("rust/vendor/anyhow/src/lib.rs"), FileClass::Vendor);
        assert_eq!(classify("rust/tests/integration.rs"), FileClass::Exempt);
        assert_eq!(classify("rust/benches/fig4_eeg.rs"), FileClass::Exempt);
        assert_eq!(classify("examples/quickstart.rs"), FileClass::Exempt);
        assert_eq!(classify("rust/src/fastcv/hat.rs"), FileClass::Library);
    }

    #[test]
    fn file_info_flags() {
        let fi = file_info("rust/src/linalg/gemm.rs");
        assert!(fi.kernel && fi.numeric && fi.library);
        let fi = file_info("rust/src/fastcv/perm.rs");
        assert!(fi.perm_engine && !fi.kernel);
        let fi = file_info("rust/src/util/threadpool.rs");
        assert!(fi.unsafe_audited && fi.panic_allowed && !fi.numeric);
        let fi = file_info("rust/src/store/mod.rs");
        assert!(fi.doc_all_public && fi.library && !fi.numeric);
        let fi = file_info("rust/src/serve/mod.rs");
        assert!(fi.doc_all_public && !fi.perm_engine);
        let fi = file_info("rust/src/fastcv/hat.rs");
        assert!(!fi.doc_all_public);
        let fi = file_info("rust/src/linalg/chol_update.rs");
        assert!(fi.kernel && fi.panic_allowed && fi.numeric && !fi.unsafe_audited);
        let fi = file_info("rust/src/fastcv/incremental.rs");
        assert!(!fi.kernel && !fi.panic_allowed && fi.numeric && fi.library);
        // The serve robustness layer: recover.rs may panic (it is the
        // injection path the catch_unwind boundary contains), signal.rs
        // carries audited unsafe; neither is a numeric file.
        let fi = file_info("rust/src/serve/recover.rs");
        assert!(fi.panic_allowed && !fi.unsafe_audited && !fi.numeric);
        let fi = file_info("rust/src/serve/signal.rs");
        assert!(fi.unsafe_audited && !fi.panic_allowed && !fi.numeric);
        assert!(fi.doc_all_public, "serve/ requires rustdoc on pub items");
    }

    #[test]
    fn rule_names_round_trip() {
        for r in [Rule::FloatAccum, Rule::Nondet, Rule::Unsafe, Rule::Panic, Rule::Doc] {
            assert_eq!(Rule::parse(r.name()), Some(r));
        }
        assert_eq!(Rule::parse("suppression"), None);
        assert_eq!(Rule::parse("bogus"), None);
    }

    #[test]
    fn vendor_paths_lint_empty() {
        let lint = lint_source("rust/vendor/anyhow/src/lib.rs", "fn f() { x.unwrap(); }");
        assert!(lint.diagnostics.is_empty());
    }
}
