//! A minimal hand-rolled Rust lexer for `fastcv-lint`.
//!
//! No external parser crates exist in the offline build, so the lint rules
//! run over a flat token stream produced here. The lexer understands exactly
//! as much Rust as the rules need: identifiers (including raw `r#ident`
//! forms), integer vs float literals, all four string-literal families
//! (cooked, raw, byte, raw-byte) plus char literals, lifetimes vs chars
//! after a `'`, nested block comments, and multi-character operators. Every
//! token carries its 1-based source line so diagnostics are clickable.
//!
//! Comments are *retained* as trivia (they never enter the token stream):
//! rule L3 looks for adjacent `// SAFETY:` text, rule L5 for rustdoc, and
//! the suppression machinery for `// lint:allow(...)` markers.

/// Token classification — just enough structure for the rules.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`for`, `unsafe`, `HashMap`, ...).
    Ident,
    /// Lifetime or loop label (`'a`, `'outer`).
    Lifetime,
    /// Integer literal (`42`, `0xff_u8`).
    Int,
    /// Float literal (`1.0`, `1e-3`, `2f64`).
    Float,
    /// Any string/char/byte literal — contents are never inspected.
    Str,
    /// Operator or delimiter, possibly multi-character (`+=`, `::`).
    Punct,
}

/// One lexed token with its 1-based source line.
#[derive(Debug, Clone)]
pub struct Token {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

/// One comment (line or block) with its starting line; `doc` marks rustdoc
/// forms (`///`, `//!`, `/**`, `/*!`).
#[derive(Debug, Clone)]
pub struct Comment {
    pub line: u32,
    pub text: String,
    pub doc: bool,
}

fn ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn ident_cont(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

const PUNCT2: [&str; 14] = [
    "+=", "-=", "*=", "/=", "::", "->", "=>", "==", "!=", "<=", ">=", "&&", "||", "..",
];

/// Lex `src` into (tokens, comments). Never fails: unterminated constructs
/// are consumed to end-of-file, which is the right behaviour for a linter
/// that must keep scanning after malformed input.
pub fn lex(src: &str) -> (Vec<Token>, Vec<Comment>) {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut toks = Vec::new();
    let mut comments = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;

    let at = |j: usize| -> char {
        if j < n {
            chars[j]
        } else {
            '\0'
        }
    };

    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment (incl. /// //! doc forms).
        if c == '/' && at(i + 1) == '/' {
            let mut j = i;
            while j < n && chars[j] != '\n' {
                j += 1;
            }
            let text: String = chars[i..j].iter().collect();
            let doc = text.starts_with("///") || text.starts_with("//!");
            comments.push(Comment { line, text, doc });
            i = j;
            continue;
        }
        // Block comment, nesting allowed.
        if c == '/' && at(i + 1) == '*' {
            let start_line = line;
            let doc = at(i + 2) == '*' || at(i + 2) == '!';
            let mut depth = 1usize;
            let mut j = i + 2;
            let mut buf = String::from("/*");
            while j < n && depth > 0 {
                if chars[j] == '\n' {
                    line += 1;
                }
                if chars[j] == '/' && at(j + 1) == '*' {
                    depth += 1;
                    buf.push_str("/*");
                    j += 2;
                    continue;
                }
                if chars[j] == '*' && at(j + 1) == '/' {
                    depth -= 1;
                    buf.push_str("*/");
                    j += 2;
                    continue;
                }
                buf.push(chars[j]);
                j += 1;
            }
            comments.push(Comment { line: start_line, text: buf, doc });
            i = j;
            continue;
        }
        // Raw strings r"..." / r#"..."#, or raw idents r#ident.
        if c == 'r' && (at(i + 1) == '"' || at(i + 1) == '#') {
            let mut j = i + 1;
            let mut hashes = 0usize;
            while j < n && chars[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if at(j) == '"' {
                j += 1;
                while j < n {
                    if chars[j] == '\n' {
                        line += 1;
                    }
                    if chars[j] == '"' {
                        let mut k = j + 1;
                        let mut h = 0usize;
                        while k < n && h < hashes && chars[k] == '#' {
                            h += 1;
                            k += 1;
                        }
                        if h == hashes {
                            j = k;
                            break;
                        }
                    }
                    j += 1;
                }
                toks.push(Token { kind: TokKind::Str, text: String::new(), line });
                i = j.max(i + 1);
                continue;
            } else if hashes == 1 && ident_start(at(j)) {
                // raw identifier r#type
                let start = j;
                while j < n && ident_cont(chars[j]) {
                    j += 1;
                }
                toks.push(Token {
                    kind: TokKind::Ident,
                    text: chars[start..j].iter().collect(),
                    line,
                });
                i = j;
                continue;
            }
            // else: plain identifier starting with 'r', handled below.
        }
        // Byte strings / byte chars: b"..." b'x' br"..." br#"..."#.
        if c == 'b' && (at(i + 1) == '"' || at(i + 1) == '\'') {
            if at(i + 1) == '"' {
                let mut j = i + 2;
                while j < n {
                    if chars[j] == '\n' {
                        line += 1;
                    }
                    if chars[j] == '\\' {
                        if at(j + 1) == '\n' {
                            line += 1;
                        }
                        j += 2;
                        continue;
                    }
                    if chars[j] == '"' {
                        j += 1;
                        break;
                    }
                    j += 1;
                }
                toks.push(Token { kind: TokKind::Str, text: String::new(), line });
                i = j;
                continue;
            }
            let mut j = i + 2;
            if at(j) == '\\' {
                j += 1;
            }
            j += 1;
            while j < n && chars[j] != '\'' {
                j += 1;
            }
            toks.push(Token { kind: TokKind::Str, text: String::new(), line });
            i = j + 1;
            continue;
        }
        if c == 'b' && at(i + 1) == 'r' && (at(i + 2) == '"' || at(i + 2) == '#') {
            let mut j = i + 2;
            let mut hashes = 0usize;
            while j < n && chars[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if at(j) == '"' {
                j += 1;
                while j < n {
                    if chars[j] == '\n' {
                        line += 1;
                    }
                    if chars[j] == '"' {
                        let mut k = j + 1;
                        let mut h = 0usize;
                        while k < n && h < hashes && chars[k] == '#' {
                            h += 1;
                            k += 1;
                        }
                        if h == hashes {
                            j = k;
                            break;
                        }
                    }
                    j += 1;
                }
                toks.push(Token { kind: TokKind::Str, text: String::new(), line });
                i = j.max(i + 1);
                continue;
            }
        }
        // Identifier / keyword.
        if ident_start(c) {
            let start = i;
            let mut j = i;
            while j < n && ident_cont(chars[j]) {
                j += 1;
            }
            toks.push(Token {
                kind: TokKind::Ident,
                text: chars[start..j].iter().collect(),
                line,
            });
            i = j;
            continue;
        }
        // Numeric literal.
        if c.is_ascii_digit() {
            let start = i;
            let mut j = i;
            let mut kind = TokKind::Int;
            if c == '0' && matches!(at(j + 1), 'x' | 'b' | 'o') {
                j += 2;
                while j < n && (chars[j].is_alphanumeric() || chars[j] == '_') {
                    j += 1;
                }
            } else {
                while j < n && (chars[j].is_ascii_digit() || chars[j] == '_') {
                    j += 1;
                }
                if at(j) == '.' {
                    let nxt = at(j + 1);
                    if nxt.is_ascii_digit() {
                        kind = TokKind::Float;
                        j += 1;
                        while j < n && (chars[j].is_ascii_digit() || chars[j] == '_') {
                            j += 1;
                        }
                    } else if nxt != '.' && !ident_start(nxt) {
                        // `1.` — a float; `1..n` is a range, `1.max()` a call.
                        kind = TokKind::Float;
                        j += 1;
                    }
                }
                if matches!(at(j), 'e' | 'E') {
                    let mut k = j + 1;
                    if matches!(at(k), '+' | '-') {
                        k += 1;
                    }
                    if at(k).is_ascii_digit() {
                        kind = TokKind::Float;
                        j = k;
                        while j < n && (chars[j].is_ascii_digit() || chars[j] == '_') {
                            j += 1;
                        }
                    }
                }
                if ident_start(at(j)) {
                    let sfx = j;
                    while j < n && ident_cont(chars[j]) {
                        j += 1;
                    }
                    let suffix: String = chars[sfx..j].iter().collect();
                    if suffix == "f32" || suffix == "f64" {
                        kind = TokKind::Float;
                    }
                }
            }
            toks.push(Token { kind, text: chars[start..j].iter().collect(), line });
            i = j;
            continue;
        }
        // Cooked string.
        if c == '"' {
            let mut j = i + 1;
            while j < n {
                if chars[j] == '\n' {
                    line += 1;
                }
                if chars[j] == '\\' {
                    // An escaped newline (line continuation) must still
                    // advance the line counter or every diagnostic after a
                    // multi-line string would drift.
                    if at(j + 1) == '\n' {
                        line += 1;
                    }
                    j += 2;
                    continue;
                }
                if chars[j] == '"' {
                    j += 1;
                    break;
                }
                j += 1;
            }
            toks.push(Token { kind: TokKind::Str, text: String::new(), line });
            i = j;
            continue;
        }
        // Char literal vs lifetime.
        if c == '\'' {
            if at(i + 1) == '\\' {
                // Escaped char literal: skip the escape head, scan to the
                // closing quote (covers \n, \\, \', \u{...}).
                let mut j = i + 3;
                while j < n && chars[j] != '\'' {
                    j += 1;
                }
                toks.push(Token { kind: TokKind::Str, text: String::new(), line });
                i = j + 1;
                continue;
            }
            if ident_start(at(i + 1)) || at(i + 1).is_ascii_digit() {
                if at(i + 2) == '\'' {
                    toks.push(Token { kind: TokKind::Str, text: String::new(), line });
                    i += 3;
                    continue;
                }
                let start = i;
                let mut j = i + 1;
                while j < n && ident_cont(chars[j]) {
                    j += 1;
                }
                toks.push(Token {
                    kind: TokKind::Lifetime,
                    text: chars[start..j].iter().collect(),
                    line,
                });
                i = j;
                continue;
            }
            // Punctuation char literal like '(' or ' '.
            let mut j = i + 1;
            while j < n && chars[j] != '\'' {
                j += 1;
            }
            toks.push(Token { kind: TokKind::Str, text: String::new(), line });
            i = j + 1;
            continue;
        }
        // Operators: greedy two-char match, then single char.
        if i + 1 < n {
            let two: String = chars[i..i + 2].iter().collect();
            if PUNCT2.contains(&two.as_str()) {
                toks.push(Token { kind: TokKind::Punct, text: two, line });
                i += 2;
                continue;
            }
        }
        toks.push(Token { kind: TokKind::Punct, text: c.to_string(), line });
        i += 1;
    }
    (toks, comments)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).0.into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn idents_and_puncts() {
        let ks = kinds("a += b.c::<f64>();");
        assert_eq!(ks[0], (TokKind::Ident, "a".into()));
        assert_eq!(ks[1], (TokKind::Punct, "+=".into()));
        assert!(ks.iter().any(|k| k == &(TokKind::Punct, "::".into())));
    }

    #[test]
    fn strings_hide_their_contents() {
        // `.unwrap()` inside a string must not produce ident tokens.
        let (toks, _) = lex(r#"let s = "x.unwrap() += HashMap";"#);
        assert!(!toks.iter().any(|t| t.text == "unwrap" || t.text == "HashMap"));
        assert!(toks.iter().any(|t| t.kind == TokKind::Str));
    }

    #[test]
    fn comments_are_trivia_with_doc_flag() {
        let (toks, comments) = lex("/// doc\n// SAFETY: fine\nfn f() {}\n/* block */");
        assert_eq!(comments.len(), 3);
        assert!(comments[0].doc);
        assert!(!comments[1].doc);
        assert_eq!(comments[1].line, 2);
        assert!(comments[1].text.contains("SAFETY:"));
        assert!(toks.iter().any(|t| t.text == "fn"));
    }

    #[test]
    fn nested_block_comments() {
        let (toks, comments) = lex("/* a /* b */ c */ fn");
        assert_eq!(comments.len(), 1);
        assert_eq!(toks.len(), 1);
        assert_eq!(toks[0].text, "fn");
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let ks = kinds("fn f<'a>(x: &'a str) { let c = 'x'; let d = '\\n'; }");
        assert_eq!(ks.iter().filter(|k| k.0 == TokKind::Lifetime).count(), 2);
        // 'x' and '\n' are char literals; `str` stays an ident.
        assert_eq!(ks.iter().filter(|k| k.0 == TokKind::Str).count(), 2);
    }

    #[test]
    fn int_vs_float_literals() {
        let ks = kinds("1 1.0 1e-3 2f64 0xff 1..4 3.max(4)");
        let floats: Vec<_> = ks.iter().filter(|k| k.0 == TokKind::Float).collect();
        let ints: Vec<_> = ks.iter().filter(|k| k.0 == TokKind::Int).collect();
        assert_eq!(floats.len(), 3, "{floats:?}");
        // 1, 0xff, 1, 4 (range ends), 3 (method receiver), 4 (argument).
        assert_eq!(ints.len(), 6, "{ints:?}");
    }

    #[test]
    fn escaped_newline_in_string_keeps_line_count() {
        let (toks, _) = lex("let s = \"a\\\n   b\";\nlet t = 1;");
        let t_tok = toks.iter().find(|t| t.text == "t");
        assert_eq!(t_tok.map(|t| t.line), Some(3));
    }

    #[test]
    fn raw_strings_and_raw_idents() {
        let ks = kinds(r##"let s = r#"raw "quoted" body"#; let r#type = 1;"##);
        assert!(ks.iter().any(|k| k == &(TokKind::Ident, "type".into())));
        assert!(!ks.iter().any(|k| k.1 == "quoted"));
    }
}
