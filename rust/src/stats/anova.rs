//! Factorial fixed-effects ANOVA.
//!
//! The paper's Results section reports three-way ANOVAs (features × N ×
//! folds, etc.) on relative efficiency. This module reproduces those
//! statistics: a full-factorial ANOVA with all interaction terms, computed
//! via effect-coded least squares with sequential (type-I) sums of squares,
//! plus the F-distribution tail probability through the regularised
//! incomplete beta function.

use crate::linalg::{matvec, matvec_t, syrk_t, Lu, Mat};

/// One factor: a name and a per-observation level index.
#[derive(Clone, Debug)]
pub struct Factor {
    pub name: String,
    /// level of each observation, 0-based
    pub levels: Vec<usize>,
    /// number of distinct levels
    pub n_levels: usize,
}

impl Factor {
    /// Build a factor from raw level codes (auto-compacted).
    pub fn new<S: Into<String>>(name: S, raw: &[usize]) -> Factor {
        let mut uniq: Vec<usize> = raw.to_vec();
        uniq.sort_unstable();
        uniq.dedup();
        // lint:allow(panic, reason = "every level value was collected into uniq above, so binary_search always finds it")
        let levels = raw.iter().map(|r| uniq.binary_search(r).unwrap()).collect();
        Factor { name: name.into(), levels, n_levels: uniq.len() }
    }

    /// Build by binning a continuous covariate into quantile groups — the
    /// paper treats `features` as continuous; binning gives a close factorial
    /// analogue for the F-statistics.
    pub fn from_continuous<S: Into<String>>(name: S, values: &[f64], bins: usize) -> Factor {
        let mut sorted = values.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let edges: Vec<f64> = (1..bins)
            .map(|b| sorted[(b * values.len() / bins).min(values.len() - 1)])
            .collect();
        let raw: Vec<usize> = values
            .iter()
            .map(|v| edges.iter().take_while(|e| v > e).count())
            .collect();
        Factor::new(name, &raw)
    }
}

/// One row of the ANOVA table.
#[derive(Clone, Debug)]
pub struct AnovaRow {
    pub term: String,
    pub df: usize,
    pub sum_sq: f64,
    pub f: f64,
    pub p: f64,
}

/// Full-factorial ANOVA result.
#[derive(Clone, Debug)]
pub struct AnovaTable {
    pub rows: Vec<AnovaRow>,
    pub residual_df: usize,
    pub residual_ss: f64,
}

/// Effect-coded columns for one factor (n_levels − 1 columns).
fn effect_columns(f: &Factor, n: usize) -> Vec<Vec<f64>> {
    let mut cols = Vec::new();
    for l in 0..f.n_levels.saturating_sub(1) {
        let mut c = vec![0.0; n];
        for (i, &li) in f.levels.iter().enumerate() {
            c[i] = if li == l {
                1.0
            } else if li == f.n_levels - 1 {
                -1.0
            } else {
                0.0
            };
        }
        cols.push(c);
    }
    cols
}

/// Element-wise products of column sets (interaction design columns).
fn interact(a: &[Vec<f64>], b: &[Vec<f64>]) -> Vec<Vec<f64>> {
    let mut out = Vec::new();
    for ca in a {
        for cb in b {
            out.push(ca.iter().zip(cb).map(|(x, y)| x * y).collect());
        }
    }
    out
}

/// Residual sum of squares of regressing `y` on `[1, cols]`.
fn rss(cols: &[Vec<f64>], y: &[f64]) -> f64 {
    let n = y.len();
    let k = cols.len() + 1;
    let mut x = Mat::zeros(n, k);
    for i in 0..n {
        x[(i, 0)] = 1.0;
    }
    for (j, c) in cols.iter().enumerate() {
        x.set_col(j + 1, c);
    }
    let xtx = syrk_t(&x);
    let xty = matvec_t(&x, y);
    // Ridge-free normal equations; tiny jitter for numerical rank safety.
    let mut a = xtx;
    for i in 0..k {
        a[(i, i)] += 1e-10;
    }
    // lint:allow(panic, reason = "design gram carries a 1e-10 diagonal jitter, so the LU factor cannot be singular")
    let beta = Lu::factor(&a).expect("design matrix").solve_vec(&xty);
    let fitted = matvec(&x, &beta);
    // lint:allow(float_accum, reason = "serial residual sum of squares in canonical order; single-threaded")
    y.iter().zip(&fitted).map(|(yi, fi)| (yi - fi) * (yi - fi)).sum()
}

/// Run a full-factorial ANOVA of `y` on the given factors (all main effects
/// and all interactions up to the full order), sequential sums of squares.
pub fn anova(y: &[f64], factors: &[Factor]) -> AnovaTable {
    let n = y.len();
    assert!(factors.iter().all(|f| f.levels.len() == n), "factor length mismatch");
    assert!(!factors.is_empty() && factors.len() <= 3, "1..=3 factors supported");

    // Enumerate terms: all non-empty subsets of factors, ordered by size.
    let nf = factors.len();
    let mut subsets: Vec<Vec<usize>> = (1..(1usize << nf))
        .map(|mask| (0..nf).filter(|i| mask & (1 << i) != 0).collect())
        .collect();
    subsets.sort_by_key(|s| s.len());

    let fac_cols: Vec<Vec<Vec<f64>>> = factors.iter().map(|f| effect_columns(f, n)).collect();

    // Sequentially grow the design and record SS decrease per term.
    let mut cols: Vec<Vec<f64>> = Vec::new();
    let mut prev_rss = rss(&cols, y); // total SS around the mean
    let mut rows = Vec::new();
    for s in &subsets {
        let mut term_cols = fac_cols[s[0]].clone();
        for &fi in &s[1..] {
            term_cols = interact(&term_cols, &fac_cols[fi]);
        }
        let df = term_cols.len();
        cols.extend(term_cols);
        let new_rss = rss(&cols, y);
        let name = s.iter().map(|&i| factors[i].name.clone()).collect::<Vec<_>>().join(" × ");
        rows.push((name, df, (prev_rss - new_rss).max(0.0)));
        prev_rss = new_rss;
    }

    // lint:allow(float_accum, reason = "integer degrees-of-freedom sum — exact arithmetic")
    let model_df: usize = rows.iter().map(|r| r.1).sum();
    let residual_df = n.saturating_sub(model_df + 1);
    let residual_ss = prev_rss;
    let ms_res = residual_ss / residual_df.max(1) as f64;

    let rows = rows
        .into_iter()
        .map(|(term, df, ss)| {
            let f = if ms_res > 0.0 && df > 0 { (ss / df as f64) / ms_res } else { f64::INFINITY };
            let p = f_tail(f, df as f64, residual_df as f64);
            AnovaRow { term, df, sum_sq: ss, f, p }
        })
        .collect();

    AnovaTable { rows, residual_df, residual_ss }
}

/// Upper tail of the F(d1, d2) distribution: `P[F > f]`.
pub fn f_tail(f: f64, d1: f64, d2: f64) -> f64 {
    if !f.is_finite() {
        return 0.0;
    }
    if f <= 0.0 {
        return 1.0;
    }
    // P[F > f] = I_{d2/(d2 + d1 f)}(d2/2, d1/2)
    reg_inc_beta(d2 / (d2 + d1 * f), d2 / 2.0, d1 / 2.0)
}

/// Regularised incomplete beta `I_x(a, b)` (Lentz continued fraction,
/// Numerical Recipes §6.4).
pub fn reg_inc_beta(x: f64, a: f64, b: f64) -> f64 {
    if x <= 0.0 {
        return 0.0;
    }
    if x >= 1.0 {
        return 1.0;
    }
    let ln_front = ln_gamma(a + b) - ln_gamma(a) - ln_gamma(b) + a * x.ln() + b * (1.0 - x).ln();
    let front = ln_front.exp();
    if x < (a + 1.0) / (a + b + 2.0) {
        front * beta_cf(x, a, b) / a
    } else {
        1.0 - front * beta_cf(1.0 - x, b, a) / b
    }
}

fn beta_cf(x: f64, a: f64, b: f64) -> f64 {
    const MAX_IT: usize = 300;
    const EPS: f64 = 1e-14;
    const TINY: f64 = 1e-300;
    let qab = a + b;
    let qap = a + 1.0;
    let qam = a - 1.0;
    let mut c = 1.0;
    let mut d = 1.0 - qab * x / qap;
    if d.abs() < TINY {
        d = TINY;
    }
    d = 1.0 / d;
    let mut h = d;
    for m in 1..=MAX_IT {
        let m = m as f64;
        let m2 = 2.0 * m;
        let aa = m * (b - m) * x / ((qam + m2) * (a + m2));
        d = 1.0 + aa * d;
        if d.abs() < TINY {
            d = TINY;
        }
        c = 1.0 + aa / c;
        if c.abs() < TINY {
            c = TINY;
        }
        d = 1.0 / d;
        h *= d * c;
        let aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2));
        d = 1.0 + aa * d;
        if d.abs() < TINY {
            d = TINY;
        }
        c = 1.0 + aa / c;
        if c.abs() < TINY {
            c = TINY;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < EPS {
            break;
        }
    }
    h
}

/// Lanczos log-gamma.
pub fn ln_gamma(x: f64) -> f64 {
    const G: [f64; 7] = [
        76.18009172947146,
        -86.50532032941677,
        24.01409824083091,
        -1.231739572450155,
        0.1208650973866179e-2,
        -0.5395239384953e-5,
        2.5066282746310005,
    ];
    let mut y = x;
    let tmp = x + 5.5;
    let tmp = tmp - (x + 0.5) * tmp.ln();
    let mut ser = 1.000000000190015;
    for g in &G[..6] {
        y += 1.0;
        // lint:allow(float_accum, reason = "Lanczos series for ln Γ: fixed six-term serial sum in canonical order")
        ser += g / y;
    }
    -tmp + (G[6] * ser / x).ln()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn ln_gamma_known() {
        assert!((ln_gamma(1.0)).abs() < 1e-10);
        assert!((ln_gamma(5.0) - (24.0f64).ln()).abs() < 1e-9);
        assert!((ln_gamma(0.5) - (std::f64::consts::PI.sqrt()).ln()).abs() < 1e-9);
    }

    #[test]
    fn f_tail_sanity() {
        // F(1, inf-ish) tail at f=3.84 ~ chi2(1) tail ~ 0.05
        let p = f_tail(3.84, 1.0, 100_000.0);
        assert!((p - 0.05).abs() < 0.002, "p={p}");
        assert!(f_tail(0.0, 3.0, 10.0) == 1.0);
        assert!(f_tail(1e9, 3.0, 10.0) < 1e-6);
    }

    #[test]
    fn detects_real_main_effect() {
        let mut rng = Rng::new(1);
        let n = 120;
        let a_levels: Vec<usize> = (0..n).map(|i| i % 2).collect();
        let b_levels: Vec<usize> = (0..n).map(|i| (i / 2) % 3).collect();
        let y: Vec<f64> = (0..n)
            .map(|i| 2.0 * a_levels[i] as f64 + 0.3 * rng.gauss())
            .collect();
        let tab = anova(
            &y,
            &[Factor::new("A", &a_levels), Factor::new("B", &b_levels)],
        );
        let a_row = tab.rows.iter().find(|r| r.term == "A").unwrap();
        let b_row = tab.rows.iter().find(|r| r.term == "B").unwrap();
        let ab_row = tab.rows.iter().find(|r| r.term == "A × B").unwrap();
        assert!(a_row.p < 1e-6, "A should be significant, p={}", a_row.p);
        assert!(b_row.p > 0.01, "B should be null, p={}", b_row.p);
        assert!(ab_row.p > 0.01, "A×B should be null, p={}", ab_row.p);
    }

    #[test]
    fn detects_pure_interaction() {
        let mut rng = Rng::new(2);
        let n = 160;
        let a: Vec<usize> = (0..n).map(|i| i % 2).collect();
        let b: Vec<usize> = (0..n).map(|i| (i / 2) % 2).collect();
        // XOR pattern: no main effects, strong interaction.
        let y: Vec<f64> = (0..n)
            .map(|i| if a[i] ^ b[i] == 1 { 1.0 } else { -1.0 } + 0.3 * rng.gauss())
            .collect();
        let tab = anova(&y, &[Factor::new("A", &a), Factor::new("B", &b)]);
        let ab = tab.rows.iter().find(|r| r.term == "A × B").unwrap();
        assert!(ab.p < 1e-6, "interaction p={}", ab.p);
    }

    #[test]
    fn three_way_layout_has_seven_terms() {
        let n = 80;
        let a: Vec<usize> = (0..n).map(|i| i % 2).collect();
        let b: Vec<usize> = (0..n).map(|i| (i / 2) % 2).collect();
        let c: Vec<usize> = (0..n).map(|i| (i / 4) % 2).collect();
        let y: Vec<f64> = (0..n).map(|i| i as f64 * 0.01).collect();
        let tab = anova(
            &y,
            &[Factor::new("A", &a), Factor::new("B", &b), Factor::new("C", &c)],
        );
        assert_eq!(tab.rows.len(), 7); // 3 mains + 3 two-way + 1 three-way
    }

    #[test]
    fn continuous_binning() {
        let vals: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let f = Factor::from_continuous("x", &vals, 4);
        assert_eq!(f.n_levels, 4);
        assert_eq!(f.levels[0], 0);
        assert_eq!(f.levels[99], 3);
    }
}
