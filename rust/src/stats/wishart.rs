//! Wishart-distributed random covariance matrices.
//!
//! The paper's simulation (§2.12) samples the common within-class covariance
//! from a Wishart distribution; we use the Bartlett decomposition, which
//! needs only chi-squared and normal deviates and one triangular product.

use crate::linalg::{matmul, Cholesky, Mat};
use crate::util::rng::Rng;
use anyhow::Result;

/// Sample `W ~ Wishart(scale, dof)` via the Bartlett decomposition:
/// with `scale = L Lᵀ`, `W = L A Aᵀ Lᵀ` where `A` is lower triangular with
/// `A[i,i] = sqrt(chi2(dof - i))` and `A[i,j] ~ N(0,1)` for `i > j`.
pub fn sample_wishart(scale: &Mat, dof: usize, rng: &mut Rng) -> Result<Mat> {
    let p = scale.rows();
    assert!(dof >= p, "Wishart dof ({dof}) must be >= dimension ({p})");
    let l = Cholesky::factor(scale)?.l().clone();
    let mut a = Mat::zeros(p, p);
    for i in 0..p {
        a[(i, i)] = rng.chi2(dof - i).sqrt();
        for j in 0..i {
            a[(i, j)] = rng.gauss();
        }
    }
    let la = matmul(&l, &a);
    Ok(matmul(&la, &la.t()))
}

/// A well-conditioned random covariance for the simulations: Wishart draw
/// with `dof = p + dof_extra`, rescaled to unit average variance, plus a
/// small diagonal `jitter` to bound the condition number so both the
/// standard and analytic paths stay numerically comparable.
pub fn random_covariance(p: usize, dof_extra: usize, jitter: f64, rng: &mut Rng) -> Mat {
    // lint:allow(panic, reason = "the identity scale matrix is SPD, so the Wishart sampler cannot fail")
    let mut w = sample_wishart(&Mat::eye(p), p + dof_extra, rng).expect("identity scale is SPD");
    let scale = p as f64 / w.trace();
    w.scale(scale);
    for i in 0..p {
        // lint:allow(float_accum, reason = "diagonal jitter add: each entry touched exactly once — order-free")
        w[(i, i)] += jitter;
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wishart_mean_is_dof_times_scale() {
        let mut rng = Rng::new(1);
        let p = 4;
        let dof = 12;
        let scale = Mat::from_fn(p, p, |i, j| if i == j { 1.0 } else { 0.2 });
        let reps = 400;
        let mut acc = Mat::zeros(p, p);
        for _ in 0..reps {
            acc.axpy(1.0 / reps as f64, &sample_wishart(&scale, dof, &mut rng).unwrap());
        }
        // E[W] = dof * scale
        let mut expect = scale.clone();
        expect.scale(dof as f64);
        assert!(acc.max_abs_diff(&expect) < 0.9, "mean deviates: {:?}", acc);
    }

    #[test]
    fn draws_are_spd() {
        let mut rng = Rng::new(2);
        for p in [1, 3, 8] {
            let w = sample_wishart(&Mat::eye(p), p + 2, &mut rng).unwrap();
            assert!(Cholesky::factor(&w).is_ok(), "p={p}");
        }
    }

    #[test]
    fn random_covariance_normalised() {
        let mut rng = Rng::new(3);
        let p = 10;
        let c = random_covariance(p, 5, 0.05, &mut rng);
        assert!((c.trace() / p as f64 - 1.05).abs() < 1e-9);
        assert!(Cholesky::factor(&c).is_ok());
    }

    #[test]
    #[should_panic(expected = "dof")]
    fn dof_below_dim_rejected() {
        let mut rng = Rng::new(4);
        let _ = sample_wishart(&Mat::eye(5), 3, &mut rng);
    }
}
