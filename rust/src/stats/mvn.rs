//! Multivariate normal sampling.

use crate::linalg::{Cholesky, Mat};
use crate::util::rng::Rng;
use anyhow::Result;

/// Multivariate normal distribution `N(mean, Σ)` prepared for repeated
/// sampling (Σ factored once).
pub struct Mvn {
    mean: Vec<f64>,
    chol_l: Mat,
}

impl Mvn {
    /// Build from mean and covariance (must be SPD).
    pub fn new(mean: Vec<f64>, cov: &Mat) -> Result<Mvn> {
        assert_eq!(mean.len(), cov.rows());
        let ch = Cholesky::factor(cov)?;
        Ok(Mvn { mean, chol_l: ch.l().clone() })
    }

    /// Dimensionality.
    pub fn dim(&self) -> usize {
        self.mean.len()
    }

    /// Draw one sample into `out`.
    pub fn sample_into(&self, rng: &mut Rng, out: &mut [f64]) {
        let p = self.dim();
        assert_eq!(out.len(), p);
        // z ~ N(0, I); x = mean + L z
        let mut z = vec![0.0; p];
        rng.fill_gauss(&mut z);
        for i in 0..p {
            let mut s = self.mean[i];
            let row = self.chol_l.row(i);
            for k in 0..=i {
                // lint:allow(float_accum, reason = "serial lower-triangular matvec inside the sampler; canonical order, single-threaded")
                s += row[k] * z[k];
            }
            out[i] = s;
        }
    }

    /// Draw `n` samples as rows of a matrix.
    pub fn sample_n(&self, rng: &mut Rng, n: usize) -> Mat {
        let mut out = Mat::zeros(n, self.dim());
        for i in 0..n {
            self.sample_into(rng, out.row_mut(i));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_moments_match() {
        let mut rng = Rng::new(1);
        let cov = Mat::from_rows(&[&[2.0, 0.6], &[0.6, 1.0]]);
        let mvn = Mvn::new(vec![1.0, -2.0], &cov).unwrap();
        let n = 40_000;
        let xs = mvn.sample_n(&mut rng, n);
        let means = xs.col_means();
        assert!((means[0] - 1.0).abs() < 0.05, "mean0={}", means[0]);
        assert!((means[1] + 2.0).abs() < 0.05, "mean1={}", means[1]);
        // empirical covariance
        let mut c = [[0.0f64; 2]; 2];
        for i in 0..n {
            let r = xs.row(i);
            let d = [r[0] - means[0], r[1] - means[1]];
            for a in 0..2 {
                for b in 0..2 {
                    c[a][b] += d[a] * d[b];
                }
            }
        }
        for a in 0..2 {
            for b in 0..2 {
                c[a][b] /= (n - 1) as f64;
                assert!((c[a][b] - cov[(a, b)]).abs() < 0.07, "cov[{a}][{b}]={}", c[a][b]);
            }
        }
    }

    #[test]
    fn rejects_indefinite_cov() {
        let cov = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]);
        assert!(Mvn::new(vec![0.0, 0.0], &cov).is_err());
    }
}
