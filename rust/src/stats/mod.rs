//! Statistical sampling and descriptive statistics used by the simulated
//! workloads (§2.12 of the paper) and by result reporting.

pub mod anova;
pub mod mvn;
pub mod wishart;

use crate::linalg::Mat;

/// Per-class sample mean vectors for labelled data.
/// `labels[i] ∈ 0..c`; returns a `c × p` matrix of class means.
pub fn class_means(x: &Mat, labels: &[usize], c: usize) -> Mat {
    assert_eq!(x.rows(), labels.len());
    let p = x.cols();
    let mut means = Mat::zeros(c, p);
    let mut counts = vec![0usize; c];
    for (i, &l) in labels.iter().enumerate() {
        assert!(l < c, "label {l} out of range (c={c})");
        counts[l] += 1;
        let row = x.row(i);
        let m = means.row_mut(l);
        for j in 0..p {
            // lint:allow(float_accum, reason = "serial class-mean accumulation in canonical sample order; single-threaded")
            m[j] += row[j];
        }
    }
    for l in 0..c {
        assert!(counts[l] > 0, "class {l} is empty");
        let inv = 1.0 / counts[l] as f64;
        for v in means.row_mut(l) {
            *v *= inv;
        }
    }
    means
}

/// Counts per class.
pub fn class_counts(labels: &[usize], c: usize) -> Vec<usize> {
    let mut counts = vec![0usize; c];
    for &l in labels {
        counts[l] += 1;
    }
    counts
}

/// Within-class scatter matrix `S_w = Σ_j Σ_{i∈C_j} (x_i−m_j)(x_i−m_j)ᵀ`.
pub fn within_scatter(x: &Mat, labels: &[usize], c: usize) -> Mat {
    let means = class_means(x, labels, c);
    let p = x.cols();
    let mut sw = Mat::zeros(p, p);
    let mut centered = vec![0.0; p];
    for (i, &l) in labels.iter().enumerate() {
        let row = x.row(i);
        let m = means.row(l);
        for j in 0..p {
            centered[j] = row[j] - m[j];
        }
        crate::linalg::ger(&mut sw, 1.0, &centered, &centered);
    }
    sw
}

/// Between-classes scatter `S_b = Σ_j N_j (m_j−m̄)(m_j−m̄)ᵀ`.
pub fn between_scatter(x: &Mat, labels: &[usize], c: usize) -> Mat {
    let means = class_means(x, labels, c);
    let counts = class_counts(labels, c);
    let grand = x.col_means();
    let p = x.cols();
    let mut sb = Mat::zeros(p, p);
    let mut d = vec![0.0; p];
    for l in 0..c {
        let m = means.row(l);
        for j in 0..p {
            d[j] = m[j] - grand[j];
        }
        crate::linalg::ger(&mut sb, counts[l] as f64, &d, &d);
    }
    sb
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_means_and_counts() {
        let x = Mat::from_rows(&[&[1.0, 0.0], &[3.0, 0.0], &[0.0, 2.0]]);
        let labels = [0, 0, 1];
        let m = class_means(&x, &labels, 2);
        assert_eq!(m.row(0), &[2.0, 0.0]);
        assert_eq!(m.row(1), &[0.0, 2.0]);
        assert_eq!(class_counts(&labels, 2), vec![2, 1]);
    }

    #[test]
    fn scatter_decomposition() {
        // Total scatter about the grand mean = S_w + S_b (standard identity).
        let x = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 1.0], &[5.0, 4.0], &[6.0, 7.0], &[4.0, 4.0]]);
        let labels = [0, 0, 1, 1, 1];
        let sw = within_scatter(&x, &labels, 2);
        let sb = between_scatter(&x, &labels, 2);
        let grand = x.col_means();
        let mut st = Mat::zeros(2, 2);
        for i in 0..x.rows() {
            let d: Vec<f64> = x.row(i).iter().zip(&grand).map(|(a, b)| a - b).collect();
            crate::linalg::ger(&mut st, 1.0, &d, &d);
        }
        let total = sw.add(&sb);
        assert!(total.max_abs_diff(&st) < 1e-10);
    }

    #[test]
    #[should_panic(expected = "empty")]
    fn empty_class_rejected() {
        let x = Mat::from_rows(&[&[1.0], &[2.0]]);
        class_means(&x, &[0, 0], 2);
    }
}
