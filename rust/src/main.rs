//! `fastcv` — leader binary / CLI for the analytic-CV reproduction.
//!
//! Subcommands map one-to-one onto the paper's evaluation artefacts:
//!
//! ```text
//! fastcv sweep --exp f3a|f3b|f3c|f3d [--scale tiny|medium|paper] [--out results/]
//! fastcv parity                      # §4.1 N=P crossover check
//! fastcv complexity                  # Table 1 empirical scaling fits
//! fastcv eeg [--subjects 16] [--perms 100] [--full]   # Fig. 4
//! fastcv quickstart                  # end-to-end smoke run
//! fastcv artifacts                   # list AOT artifacts + PJRT platform
//! fastcv lint                        # determinism & safety static analysis
//! ```
//!
//! Every command prints paper-style tables and (with `--out DIR`) writes
//! raw TSVs for EXPERIMENTS.md.

use anyhow::Result;
use fastcv::coordinator::report::AnovaFactor;
use fastcv::coordinator::sweep::{grid, Experiment, PermEngine, SweepScale};
use fastcv::coordinator::{Scheduler, SweepReport};
use fastcv::fastcv::hat::GramBackend;
use fastcv::util::cli::Args;

fn main() {
    let args = Args::from_env(&["verbose", "full", "help", "cache", "rebuild"]);
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn dispatch(args: &Args) -> Result<()> {
    apply_isa(args)?;
    match args.subcommand() {
        Some("sweep") => cmd_sweep(args),
        Some("parity") => cmd_parity(args),
        Some("complexity") => cmd_complexity(args),
        Some("eeg") => cmd_eeg(args),
        Some("bigdata") => cmd_bigdata(args),
        Some("quickstart") => cmd_quickstart(args),
        Some("stream") => cmd_stream(args),
        Some("serve") => cmd_serve(args),
        Some("artifacts") => cmd_artifacts(args),
        Some("lint") => cmd_lint(args),
        _ => {
            print_usage();
            Ok(())
        }
    }
}

/// Global `--isa scalar|avx2|neon` flag: pin the `linalg` microkernel ISA
/// for the whole run (`linalg::dispatch`). Results are bitwise-unchanged by
/// the kernel-conformance contract — this is a wall-clock/testing lever,
/// like `FASTCV_FORCE_ISA` (which an explicit flag overrides). Rejects an
/// ISA the CPU cannot run.
fn apply_isa(args: &Args) -> Result<()> {
    if let Some(tag) = args.get("isa") {
        let isa = fastcv::linalg::Isa::from_tag(tag)
            .ok_or_else(|| anyhow::anyhow!("unknown ISA {tag:?} (scalar|avx2|neon)"))?;
        fastcv::linalg::dispatch::force_isa(Some(isa))?;
    }
    Ok(())
}

fn print_usage() {
    println!(
        "fastcv — analytical cross-validation for least-squares models & multi-class LDA\n\
         (reproduction of Treder 2018)\n\n\
         USAGE: fastcv <command> [options]\n\n\
         COMMANDS\n\
           sweep --exp f3a|f3b|f3c|f3d   Fig. 3 relative-efficiency sweeps\n\
                 [--scale tiny|medium|paper] [--seed N] [--workers N] [--out DIR]\n\
                 [--engine serial|batched] [--batch B]  (perm sweeps)\n\
                 [--backend primal|dual|spectral|auto]  (analytic-arm Gram backend)\n\
                 [--threads T]  (analytic-arm pool: hat builds + perm batches)\n\
                 [--tile-rows R | --mem-budget MB]  (tile the N×N Gram builds:\n\
                 fixed rows, or auto-sized from a transient-memory budget;\n\
                 bit-identical to untiled — memory/wall-clock only)\n\
                 [--spill-dir PATH]  (out-of-core: Gram + Cholesky factor live\n\
                 as tile×N panel files under PATH, never resident at once;\n\
                 panel height from --tile-rows, default 256; still bit-identical)\n\
                 [--cache [--budget-mb MB]]  (share factor builds across sweep\n\
                 points through a FactorStore: equal-spec points reuse Grams;\n\
                 adds hit/miss counters to the TSV cache column; note this\n\
                 remaps per-point seeds so equal-spec points share datasets)\n\
           parity                        §4.1 N≈P crossover table\n\
           complexity                    Table 1 empirical scaling exponents\n\
           eeg [--subjects N] [--perms N] [--full]   Fig. 4 EEG/MEG permutation study\n\
           bigdata [--n N] [--p P] [--q Q] [--lambda L]   §4.5 strategies demo:\n\
                 streaming hat + sparse projection + LDA ensemble, all through\n\
                 one ComputeContext ([--threads T] [--backend ...]\n\
                 [--tile-rows R | --mem-budget MB | --spill-dir PATH])\n\
           quickstart                    30-second end-to-end demo\n\
           stream [--window N] [--lambda L] [--folds K] [--n-perm B] [--seed S]\n\
                 [--exact-refresh-every K] [--rebuild] [--threads T]\n\
                 sliding-window CV over NDJSON samples on stdin (one\n\
                 {{\"x\":[...],\"label\":0|1}} per line); the window's Cholesky\n\
                 factor is maintained by O(P²) rank-1 up/downdates instead of\n\
                 per-step rebuilds, emitting rolling accuracy (+ permutation\n\
                 p-value with --n-perm) as NDJSON — see docs/STREAM.md;\n\
                 a malformed line yields an error line, not an abort\n\
           serve [--workers N] [--threads T] [--budget-mb MB]\n\
                 [--tile-rows R | --mem-budget MB | --spill-dir PATH]\n\
                 [--deadline-ms MS]  (answer deadline_exceeded instead of\n\
                 running requests that waited longer than MS; 0 = off)\n\
                 [--queue-cap N]  (reject with typed overloaded beyond N\n\
                 queued requests; 0 = unbounded; shutdown always admitted)\n\
                 [--socket PATH]         long-lived NDJSON job daemon over a\n\
                 shared FactorStore (stdin/stdout, or a Unix socket); queued\n\
                 permutation requests on one dataset key coalesce into a\n\
                 single batched GEMM pass — see docs/SERVE.md and\n\
                 docs/ROBUSTNESS.md (fault injection, typed errors, retry)\n\
           artifacts                     list AOT artifacts and PJRT platform\n\
           lint [--root DIR]             determinism & safety static analysis\n\
                 (docs/LINTS.md; non-zero exit on any violation)\n\n\
         GLOBAL OPTIONS\n\
           --isa scalar|avx2|neon        pin the linalg microkernel ISA\n\
                 (default: widest the CPU supports; results are bitwise-\n\
                 identical across ISAs — wall-clock only; also settable\n\
                 via FASTCV_FORCE_ISA, see docs/BACKENDS.md)"
    );
}

fn cmd_lint(args: &Args) -> Result<()> {
    // Same engine and default root as the standalone `lint` binary: the
    // repo this binary was compiled in, unless --root points elsewhere.
    let root = match args.get("root") {
        Some(dir) => std::path::PathBuf::from(dir),
        None => {
            let manifest = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
            manifest.parent().map(std::path::PathBuf::from).unwrap_or(manifest)
        }
    };
    let report = fastcv::lint::lint_workspace(&root)?;
    print!("{}", report.render());
    anyhow::ensure!(report.violations() == 0, "{} lint violation(s)", report.violations());
    Ok(())
}

fn scale_from(args: &Args) -> SweepScale {
    match args.get_or("scale", "medium").as_str() {
        "tiny" => SweepScale::tiny(),
        "paper" => SweepScale::paper(),
        _ => SweepScale::medium(),
    }
}

fn maybe_write(args: &Args, name: &str, content: &str) -> Result<()> {
    if let Some(dir) = args.get("out") {
        std::fs::create_dir_all(dir)?;
        let path = std::path::Path::new(dir).join(name);
        std::fs::write(&path, content)?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let tag = args.get_or("exp", "f3a");
    let exp = Experiment::from_tag(&tag)
        .ok_or_else(|| anyhow::anyhow!("unknown experiment {tag:?} (f3a..f3d)"))?;
    let scale = scale_from(args);
    let seed: u64 = args.get_parse_or("seed", 2018);
    let workers: usize = args.get_parse_or("workers", 0);
    let threads: usize = args.get_parse_or("threads", 1);
    let engine = match args.get_or("engine", "serial").as_str() {
        "serial" => PermEngine::Serial,
        "batched" => PermEngine::Batched { batch: args.get_parse_or("batch", 64), threads },
        other => anyhow::bail!("unknown engine {other:?} (serial|batched)"),
    };
    let backend_tag = args.get_or("backend", "primal");
    let backend = GramBackend::from_tag(&backend_tag)
        .ok_or_else(|| anyhow::anyhow!("unknown backend {backend_tag:?} (primal|dual|spectral|auto)"))?;
    let tile = fastcv::linalg::TilePolicy::from_cli(
        args.get_parse_or("tile-rows", 0usize),
        args.get_parse_or("mem-budget", 0usize),
        args.get("spill-dir"),
    );
    let mut points = grid(exp, &scale);
    if engine != PermEngine::Serial {
        // The engine only governs the analytic arm of permutation points;
        // stamping it on pure-CV points would record an engine that never ran.
        if matches!(exp, Experiment::BinaryPerm | Experiment::MultiPerm) {
            for p in points.iter_mut() {
                p.engine = engine;
            }
        } else {
            eprintln!("--engine is ignored for {} (no permutation arm)", exp.name());
        }
    }
    // The Gram backend governs the analytic arm's hat build on every
    // experiment (all grid points carry λ > 0, so dual/spectral are always
    // well-defined; `auto` re-resolves per point's P/N ratio). `--threads`
    // likewise reaches every analytic arm: each point's hat build fans its
    // Gram/GEMM work over a ComputeContext pool of that width (bit-identical
    // to serial — wall-clock only), not just the perm batcher. `--tile-rows`
    // / `--mem-budget` tile the N×N Gram builds + Cholesky the same way
    // (bit-identical; bounds transient memory instead of wall-clock).
    for p in points.iter_mut() {
        p.backend = backend;
        p.threads = threads;
        p.tile = tile.clone();
    }
    eprintln!("{}: {} points", exp.name(), points.len());
    let sched = Scheduler::new(workers, seed, args.flag("verbose"));
    // Clock injection (not read inside the scheduler) keeps lint L2's
    // Instant ban on numeric modules intact; --cache opts into a shared
    // FactorStore, which also remaps seeds so equal-spec points share
    // datasets (documented on Scheduler::run_clocked).
    let clock = fastcv::util::monotonic_clock();
    let store = if args.flag("cache") {
        let store = match args.get_parse_or("budget-mb", 0usize) {
            0 => fastcv::store::FactorStore::new(),
            mb => fastcv::store::FactorStore::with_budget(mb * 1024 * 1024),
        };
        Some(match args.get("spill-dir") {
            Some(dir) => store.with_spill(
                std::path::PathBuf::from(dir),
                args.get_parse_or("tile-rows", 256usize),
            ),
            None => store,
        })
    } else {
        None
    };
    let results = sched.run_clocked(&points, &clock, store.as_ref());
    if let Some(s) = &store {
        let stats = s.stats();
        eprintln!(
            "factor store: {} — {} entries, {} resident bytes",
            stats.tag(),
            stats.entries,
            stats.resident_bytes
        );
    }
    let report = SweepReport::new(results);
    println!("{}", report.render(exp.name()));
    let factor = match exp {
        Experiment::BinaryCv => AnovaFactor::Folds,
        Experiment::BinaryPerm | Experiment::MultiPerm => AnovaFactor::Permutations,
        Experiment::MultiCv => AnovaFactor::Classes,
    };
    if let Some(tab) = report.anova_rel_eff(factor) {
        println!("{}", SweepReport::render_anova(&tab, &format!("{} — ANOVA on rel.eff", exp.name())));
    }
    maybe_write(args, &format!("sweep_{tag}.tsv"), &report.to_tsv())?;
    Ok(())
}

/// §4.1: "is it just a trade-off?" — N = P configurations.
fn cmd_parity(args: &Args) -> Result<()> {
    use fastcv::coordinator::sweep::{run_point, SweepPoint};
    let n: usize = args.get_parse_or("n", 300);
    let seed: u64 = args.get_parse_or("seed", 2018);
    let mut results = Vec::new();
    for (exp, k, c) in [
        (Experiment::BinaryCv, 10usize, 2usize),
        (Experiment::BinaryCv, usize::MAX, 2),
        (Experiment::MultiCv, 10, 5),
    ] {
        let point = SweepPoint {
            exp,
            n,
            p: n,
            k,
            c,
            n_perm: 0,
            rep: 0,
            lambda: 1.0,
            engine: PermEngine::Serial,
            backend: GramBackend::Primal,
            threads: 1,
            tile: fastcv::linalg::TilePolicy::Off,
        };
        results.push(run_point(&point, seed)?);
    }
    let report = SweepReport::new(results);
    println!("{}", report.render(&format!("§4.1 parity check at N = P = {n}")));
    println!(
        "paper's claim: 10-fold ≈ 1 order of magnitude, LOO ≈ 2, multi-class ≈ 3 \
         (crossover when N/K ≈ P)."
    );
    maybe_write(args, "parity.tsv", &report.to_tsv())?;
    Ok(())
}

/// Table 1: fit empirical scaling exponents of the two approaches.
fn cmd_complexity(args: &Args) -> Result<()> {
    use fastcv::util::table::{fnum, Table};
    let seed: u64 = args.get_parse_or("seed", 2018);
    let quick = !args.flag("full");

    // time vs P at fixed N (standard should go ~P^3, analytic ~flat-ish)
    let ps: Vec<usize> = if quick { vec![40, 80, 160, 320] } else { vec![50, 100, 200, 400, 800] };
    let n = if quick { 60 } else { 100 };
    let mut rows_p = Vec::new();
    for &p in &ps {
        let point = fastcv::coordinator::sweep::SweepPoint {
            exp: Experiment::BinaryCv,
            n,
            p,
            k: 10.min(n),
            c: 2,
            n_perm: 0,
            rep: 0,
            lambda: 1.0,
            engine: PermEngine::Serial,
            backend: GramBackend::Primal,
            threads: 1,
            tile: fastcv::linalg::TilePolicy::Off,
        };
        let r = fastcv::coordinator::sweep::run_point(&point, seed)?;
        rows_p.push((p as f64, r.t_std, r.t_ana));
    }
    // time vs N at fixed P (analytic should go ~N^3 across folds ≈ N^3/K²·K)
    let ns: Vec<usize> = if quick { vec![40, 80, 160, 320] } else { vec![100, 200, 400, 800] };
    let p_fix = if quick { 40 } else { 100 };
    let mut rows_n = Vec::new();
    for &n in &ns {
        let point = fastcv::coordinator::sweep::SweepPoint {
            exp: Experiment::BinaryCv,
            n,
            p: p_fix,
            k: 10,
            c: 2,
            n_perm: 0,
            rep: 0,
            lambda: 1.0,
            engine: PermEngine::Serial,
            backend: GramBackend::Primal,
            threads: 1,
            tile: fastcv::linalg::TilePolicy::Off,
        };
        let r = fastcv::coordinator::sweep::run_point(&point, seed)?;
        rows_n.push((n as f64, r.t_std, r.t_ana));
    }

    let slope = |rows: &[(f64, f64, f64)], idx: usize| -> f64 {
        // least-squares slope of log t vs log x
        let pts: Vec<(f64, f64)> = rows
            .iter()
            .map(|r| (r.0.ln(), if idx == 1 { r.1.ln() } else { r.2.ln() }))
            .collect();
        let mx = pts.iter().map(|p| p.0).sum::<f64>() / pts.len() as f64;
        let my = pts.iter().map(|p| p.1).sum::<f64>() / pts.len() as f64;
        let num: f64 = pts.iter().map(|p| (p.0 - mx) * (p.1 - my)).sum();
        let den: f64 = pts.iter().map(|p| (p.0 - mx) * (p.0 - mx)).sum();
        num / den
    };

    let mut t = Table::new(vec!["scaling", "standard (measured)", "analytic (measured)", "paper (Table 1)"])
        .with_title("Table 1 — empirical complexity exponents".to_string());
    t.row(vec![
        format!("time vs P (N={n})"),
        format!("P^{}", fnum(slope(&rows_p, 1), 2)),
        format!("P^{}", fnum(slope(&rows_p, 2), 2)),
        "std: KNP²+KP³ → ~P²··³ | ana: P enters only via H build".into(),
    ]);
    t.row(vec![
        format!("time vs N (P={p_fix})"),
        format!("N^{}", fnum(slope(&rows_n, 1), 2)),
        format!("N^{}", fnum(slope(&rows_n, 2), 2)),
        "std: ~N | ana: KN³ with N_te=N/K → ~N²··³".into(),
    ]);
    println!("{}", t.render());
    let mut tsv = String::from("axis\tx\tt_std\tt_ana\n");
    for r in &rows_p {
        tsv.push_str(&format!("P\t{}\t{:.6e}\t{:.6e}\n", r.0, r.1, r.2));
    }
    for r in &rows_n {
        tsv.push_str(&format!("N\t{}\t{:.6e}\t{:.6e}\n", r.0, r.1, r.2));
    }
    maybe_write(args, "complexity.tsv", &tsv)?;
    Ok(())
}

/// Fig. 4: per-subject EEG/MEG permutation study on simulated subjects.
fn cmd_eeg(args: &Args) -> Result<()> {
    use fastcv::data::eeg::{simulate_subject, EegSpec};
    use fastcv::util::rng::Rng;
    let full = args.flag("full");
    let n_subjects: usize = args.get_parse_or("subjects", if full { 16 } else { 4 });
    let n_perm: usize = args.get_parse_or("perms", if full { 100 } else { 20 });
    let seed: u64 = args.get_parse_or("seed", 2018);
    let spec = if full { EegSpec::default() } else { EegSpec::small() };
    let lambda: f64 = args.get_parse_or("lambda", 1.0);

    let mut root = Rng::new(seed);
    let mut report =
        fastcv::bench::RelEffReport::new(&format!(
            "Fig. 4 — EEG/MEG permutation study ({n_subjects} simulated subjects, {n_perm} perms, 10-fold)"
        ));
    let mut tsv = String::from("subject\tanalysis\tfeatures\tt_std\tt_ana\trel_eff\n");
    for subj in 0..n_subjects {
        let mut rng = root.fork(subj as u64 + 1);
        let subject = simulate_subject(&spec, &mut rng);
        // Binary, small features: one representative timepoint (N170 peak).
        let peak = ((0.17 - (-0.5)) * 200.0) as usize;
        let cases: Vec<(&str, fastcv::data::Dataset)> = vec![
            ("binary small", subject.features_at_timepoint(peak, true)),
            ("binary large", subject.features_windowed(100, true)),
            ("multi small", subject.features_at_timepoint(peak, false)),
            ("multi large", subject.features_windowed(200, false)),
        ];
        for (name, ds) in cases {
            let folds = fastcv::cv::folds::stratified_kfold(&ds.labels, 10, &mut rng);
            let mut rng_std = rng.fork(7);
            let mut rng_ana = rng.fork(7);
            let (t_std, t_ana) = if ds.n_classes == 2 {
                let (r1, t1) = fastcv::util::timed(|| {
                    fastcv::fastcv::perm::standard_binary_permutation(
                        &ds.x, &ds.labels, &folds,
                        fastcv::model::Reg::Ridge(lambda), n_perm, &mut rng_std,
                    )
                });
                let (r2, t2) = fastcv::util::timed(|| {
                    fastcv::fastcv::perm::analytic_binary_permutation(
                        &ds.x, &ds.labels, &folds, lambda, n_perm, false, &mut rng_ana,
                    )
                });
                r1?;
                r2?;
                (t1, t2)
            } else {
                let (r1, t1) = fastcv::util::timed(|| {
                    fastcv::fastcv::perm::standard_multiclass_permutation(
                        &ds.x, &ds.labels, 3, &folds,
                        fastcv::model::Reg::Ridge(lambda), n_perm, &mut rng_std,
                    )
                });
                let (r2, t2) = fastcv::util::timed(|| {
                    fastcv::fastcv::perm::analytic_multiclass_permutation(
                        &ds.x, &ds.labels, 3, &folds, lambda, n_perm, &mut rng_ana,
                    )
                });
                r1?;
                r2?;
                (t1, t2)
            };
            report.push(&format!("subj{subj:02} {name} P={}", ds.p()), t_std, t_ana);
            tsv.push_str(&format!(
                "{subj}\t{name}\t{}\t{t_std:.6e}\t{t_ana:.6e}\t{:.4}\n",
                ds.p(),
                (t_std / t_ana).log10()
            ));
            eprintln!("  subj{subj:02} {name}: done");
        }
    }
    println!("{}", report.render());
    maybe_write(args, "fig4_eeg.tsv", &tsv)?;
    Ok(())
}

/// §4.5 "what about big data?" — run all three coping strategies through
/// one `ComputeContext`, so `--threads`, `--backend`, and
/// `--tile-rows`/`--mem-budget` reach every big-data mode from the CLI.
fn cmd_bigdata(args: &Args) -> Result<()> {
    use fastcv::data::synthetic::{generate, SyntheticSpec};
    use fastcv::fastcv::bigdata::{projected_analytic_cv_ctx, LdaEnsemble, StreamingHat};
    use fastcv::fastcv::ComputeContext;
    use fastcv::util::rng::Rng;

    let n: usize = args.get_parse_or("n", 200);
    let p: usize = args.get_parse_or("p", 1000);
    let q: usize = args.get_parse_or("q", 200);
    let lambda: f64 = args.get_parse_or("lambda", 1.0);
    let seed: u64 = args.get_parse_or("seed", 2018);
    let threads: usize = args.get_parse_or("threads", 1);
    let backend_tag = args.get_or("backend", "auto");
    let backend = GramBackend::from_tag(&backend_tag)
        .ok_or_else(|| anyhow::anyhow!("unknown backend {backend_tag:?} (primal|dual|spectral|auto)"))?;
    let tile = fastcv::linalg::TilePolicy::from_cli(
        args.get_parse_or("tile-rows", 0usize),
        args.get_parse_or("mem-budget", 0usize),
        args.get("spill-dir"),
    );
    let ctx = ComputeContext::with_threads(threads).with_backend(backend).with_tile_policy(tile);

    let mut rng = Rng::new(seed);
    let mut spec = SyntheticSpec::binary(n, p);
    spec.separation = 2.0;
    let ds = generate(&spec, &mut rng);
    let y = ds.y_signed();
    let folds = fastcv::cv::folds::kfold(n, 10.min(n / 3).max(2), &mut rng);
    println!("bigdata demo: N={n} P={p} λ={lambda} ({ctx:?})");

    // 1. Too many samples: streaming hat (no N×N H; tiled K_c when asked).
    let (hat, t_stream) =
        fastcv::util::timed(|| StreamingHat::build_ctx(&ds.x, lambda, &ctx));
    let hat = hat?;
    let dv = hat.decision_values(&y, &folds)?;
    let acc = fastcv::cv::metrics::accuracy_signed(&dv, &y);
    println!(
        "  streaming hat  [{:>7}]: {:.3}s  acc={acc:.3}  (T is {}×{})",
        hat.backend_label(),
        t_stream,
        hat.t.shape().0,
        hat.t.shape().1
    );

    // 2. Too many features: sparse random projection → analytic CV.
    let (dv_proj, t_proj) =
        fastcv::util::timed(|| projected_analytic_cv_ctx(&ds.x, &y, &folds, q, lambda, &mut rng, &ctx));
    let acc_proj = fastcv::cv::metrics::accuracy_signed(&dv_proj?, &y);
    println!("  projection → Q={q:<5}: {t_proj:.3}s  acc={acc_proj:.3}");

    // 3. Both: ensemble of weak LDA learners on random subsets.
    let (ens, t_ens) = fastcv::util::timed(|| {
        LdaEnsemble::train_ctx(
            &ds.x,
            &ds.labels,
            15,
            0.2,
            0.6,
            fastcv::model::Reg::Ridge(lambda),
            &ctx,
            &mut rng,
        )
    });
    let ens = ens?;
    let acc_ens =
        fastcv::cv::metrics::accuracy_labels(&ens.predict(&ds.x), &ds.labels);
    println!("  LDA ensemble ({} members): {:.3}s  train-acc={acc_ens:.3}", ens.len(), t_ens);
    Ok(())
}

fn cmd_quickstart(args: &Args) -> Result<()> {
    use fastcv::data::synthetic::{generate, SyntheticSpec};
    use fastcv::util::rng::Rng;
    let seed: u64 = args.get_parse_or("seed", 7);
    let mut rng = Rng::new(seed);
    let mut spec = SyntheticSpec::binary(100, 500);
    spec.separation = 2.0;
    let ds = generate(&spec, &mut rng);
    let folds = fastcv::cv::folds::kfold(ds.n(), 10, &mut rng);
    let y = ds.y_signed();

    let (std_dv, t_std) = fastcv::util::timed(|| {
        fastcv::cv::runner::standard_binary_cv_dvals(
            &ds.x,
            &ds.labels,
            &folds,
            fastcv::model::Reg::Ridge(1.0),
        )
    });
    let (ana_dv, t_ana) = fastcv::util::timed(|| -> Result<Vec<f64>> {
        let cv = fastcv::fastcv::binary::AnalyticBinaryCv::fit(&ds.x, &y, 1.0)?;
        cv.decision_values(&folds)
    });
    let acc_std = fastcv::cv::metrics::accuracy_signed(&std_dv?, &y);
    let acc_ana = fastcv::cv::metrics::accuracy_signed(&ana_dv?, &y);
    println!("quickstart: N=100 P=500 K=10 ridge=1.0");
    println!("  standard approach: {:.3}s  acc={acc_std:.3}", t_std);
    println!("  analytic approach: {:.3}s  acc={acc_ana:.3}", t_ana);
    println!("  speedup: {:.1}x (rel.eff {:.2})", t_std / t_ana, (t_std / t_ana).log10());
    Ok(())
}

/// Sliding-window streaming CV: NDJSON samples on stdin, one rolling
/// `StepResult` per line on stdout. The window's Cholesky factor is
/// maintained by `O(P²)` rank-1 up/downdates (`--rebuild` switches to the
/// per-step from-scratch reference; `--exact-refresh-every K` bounds
/// drift) — see docs/STREAM.md.
fn cmd_stream(args: &Args) -> Result<()> {
    use fastcv::fastcv::incremental::{SlidingWindowCv, StreamConfig};
    use fastcv::fastcv::ComputeContext;
    use std::io::{BufRead, Write};

    let cfg = StreamConfig {
        window: args.get_parse_or("window", 64usize),
        lambda: args.get_parse_or("lambda", 1.0f64),
        folds: args.get_parse_or("folds", 5usize),
        n_perm: args.get_parse_or("n-perm", 0usize),
        seed: args.get_parse_or("seed", 42u64),
        exact_refresh_every: args.get_parse_or("exact-refresh-every", 0usize),
        rebuild: args.flag("rebuild"),
    };
    let threads: usize = args.get_parse_or("threads", 1);
    // The rolling factor lives in a FactorStore: each step supersedes the
    // previous window artifact in place (lineage API) rather than piling
    // up per-step entries.
    let store = fastcv::store::FactorStore::new();
    let ctx = ComputeContext::with_threads(threads).with_store(&store);
    let mut cv = SlidingWindowCv::new(cfg, ctx)?;
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let mut out = std::io::BufWriter::new(stdout.lock());
    let mut samples = 0u64;
    let mut malformed = 0u64;
    for (lineno, line) in stdin.lock().lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        // A malformed mid-stream line must not abort a long-running
        // stream: emit a typed error step and keep the window rolling.
        let (x, label) = match parse_stream_sample(&line) {
            Ok(sample) => sample,
            Err(e) => {
                let msg = fastcv::util::json::Json::Str(format!("{e:#}")).dump();
                writeln!(out, "{{\"line\":{},\"ok\":false,\"error\":{msg}}}", lineno + 1)?;
                out.flush()?;
                malformed += 1;
                continue;
            }
        };
        samples += 1;
        if let Some(r) = cv.push(x, label)? {
            let p = r.p_value.map_or_else(|| "null".to_string(), |p| format!("{p}"));
            writeln!(
                out,
                "{{\"step\":{},\"n\":{},\"acc\":{},\"p\":{},\"refreshed\":{},\"evicted\":{}}}",
                r.step, r.n, r.accuracy, p, r.refreshed, r.evicted
            )?;
        }
    }
    out.flush()?;
    let stats = store.stats();
    eprintln!(
        "fastcv stream: {samples} sample(s), {malformed} malformed line(s) skipped — \
         {} incremental step(s), {} downdate rescue(s), \
         store {} ({} supersession(s), {} entry(ies))",
        cv.incremental_steps,
        cv.downdate_rescues,
        stats.tag(),
        stats.supersessions,
        stats.entries
    );
    Ok(())
}

/// One NDJSON stream sample: `{"x":[...], "label":0|1}` (or `"y":±1`).
fn parse_stream_sample(line: &str) -> Result<(Vec<f64>, usize)> {
    use fastcv::util::json::Json;
    let v = Json::parse(line).map_err(|e| anyhow::anyhow!("bad JSON: {e}"))?;
    let xs = v
        .get("x")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("missing \"x\" feature array"))?;
    let x = xs
        .iter()
        .map(|j| j.as_f64().ok_or_else(|| anyhow::anyhow!("non-numeric \"x\" entry")))
        .collect::<Result<Vec<f64>>>()?;
    let label = if let Some(l) = v.get("label").and_then(Json::as_usize) {
        l
    } else if let Some(y) = v.get("y").and_then(Json::as_f64) {
        usize::from(y <= 0.0) // +1 → class 0, −1 → class 1 (signed_codes order)
    } else {
        anyhow::bail!("missing \"label\" (0|1) or \"y\" (±1)");
    };
    anyhow::ensure!(label < 2, "streaming CV is binary — label must be 0|1 (got {label})");
    Ok((x, label))
}

/// Long-lived job daemon: NDJSON requests over stdin/stdout (or a Unix
/// socket), answered through one shared `FactorStore` with permutation
/// request coalescing — see docs/SERVE.md for the protocol.
fn cmd_serve(args: &Args) -> Result<()> {
    use fastcv::serve::{ServeConfig, Server};
    let workers: usize = args.get_parse_or("workers", 1);
    let threads: usize = args.get_parse_or("threads", 1);
    let budget_mb: usize = args.get_parse_or("budget-mb", 0);
    let tile = fastcv::linalg::TilePolicy::from_cli(
        args.get_parse_or("tile-rows", 0usize),
        args.get_parse_or("mem-budget", 0usize),
        args.get("spill-dir"),
    );
    let config = ServeConfig {
        workers: workers.max(1),
        threads: threads.max(1),
        budget_bytes: (budget_mb > 0).then(|| budget_mb * 1024 * 1024),
        spill_dir: args.get("spill-dir").map(std::path::PathBuf::from),
        tile,
        deadline_ms: args.get_parse_or("deadline-ms", 0u64),
        queue_cap: args.get_parse_or("queue-cap", 0usize),
    };
    // A previous run may have died mid-spill: sweep store directories
    // abandoned by crashed processes into base/quarantine/ before any
    // fresh panel lands next to them.
    if let Some(dir) = config.spill_dir.as_deref() {
        std::fs::create_dir_all(dir)?;
        let swept = fastcv::linalg::quarantine_orphans(dir)?;
        if swept > 0 {
            eprintln!("fastcv serve: quarantined {swept} orphaned spill store(s) in {dir:?}");
        }
    }
    let server = Server::new(config);
    match args.get("socket") {
        Some(path) => {
            // A supervisor's SIGTERM must not strand the socket file.
            fastcv::serve::signal::install_sigterm_cleanup(std::path::Path::new(path))?;
            eprintln!("fastcv serve: listening on {path} ({workers} worker(s))");
            server.serve_unix(std::path::Path::new(path))?;
        }
        None => {
            eprintln!("fastcv serve: NDJSON requests on stdin ({workers} worker(s))");
            let stdin = std::io::stdin();
            server.serve_stream(stdin.lock(), std::io::stdout())?;
        }
    }
    let stats = server.store().stats();
    eprintln!(
        "fastcv serve: done — cache {} ({} entries), {} request(s) coalesced",
        stats.tag(),
        stats.entries,
        server.coalesced()
    );
    Ok(())
}

fn cmd_artifacts(_args: &Args) -> Result<()> {
    let rt = fastcv::runtime::XlaRuntime::load_default()?;
    println!("PJRT platform: {}", rt.platform());
    println!("artifact dir:  {}", rt.registry().dir().display());
    if rt.registry().is_empty() {
        println!("no artifacts found — run `make artifacts`");
        return Ok(());
    }
    for e in rt.registry().entries() {
        println!(
            "  {:22} n={:<5} p={:<5} k={:<3} b={:<3} c={:<2} {}",
            e.key.op,
            e.key.n,
            e.key.p,
            e.key.k_folds,
            e.key.batch,
            e.key.c,
            e.file.file_name().unwrap_or(e.file.as_os_str()).to_string_lossy()
        );
    }
    Ok(())
}
