//! Result aggregation and paper-style reporting.

use super::sweep::SweepResult;
use crate::stats::anova::{anova, AnovaTable, Factor};
use crate::util::table::{fdur, fnum, Table};

/// Aggregated view over a sweep's results.
pub struct SweepReport {
    pub results: Vec<SweepResult>,
}

impl SweepReport {
    /// Wrap raw results.
    pub fn new(results: Vec<SweepResult>) -> SweepReport {
        SweepReport { results }
    }

    /// Mean relative efficiency per (N, P, K|T|C) configuration, averaged
    /// over reps — the quantity plotted in Fig. 3.
    pub fn aggregate(&self) -> Vec<(String, f64, f64, f64, usize)> {
        // label (without rep) → (rel_effs, t_std, t_ana)
        let mut map: std::collections::BTreeMap<String, (Vec<f64>, Vec<f64>, Vec<f64>)> =
            Default::default();
        for r in &self.results {
            let e = map.entry(r.label.clone()).or_default();
            e.0.push(r.rel_eff());
            e.1.push(r.t_std);
            e.2.push(r.t_ana);
        }
        map.into_iter()
            .map(|(label, (effs, ts, ta))| {
                (
                    label,
                    crate::util::mean(&effs),
                    crate::util::mean(&ts),
                    crate::util::mean(&ta),
                    effs.len(),
                )
            })
            .collect()
    }

    /// Render the Fig. 3-style table.
    pub fn render(&self, title: &str) -> String {
        let mut t = Table::new(vec!["config", "t_std", "t_analytic", "rel.eff", "reps"])
            .with_title(title.to_string());
        for (label, eff, ts, ta, reps) in self.aggregate() {
            t.row(vec![label, fdur(ts), fdur(ta), fnum(eff, 2), reps.to_string()]);
        }
        t.render()
    }

    /// TSV dump of raw per-rep rows. `t_point` is the whole-point wall
    /// clock when the sweep ran through [`super::Scheduler::run_clocked`]
    /// (0 on the historical path); `cache` is the point's
    /// [`crate::store::FactorStore`] counter delta (`-` without a store).
    pub fn to_tsv(&self) -> String {
        let mut out = String::from(
            "exp\tengine\tbackend\tthreads\ttile\tn\tp\tk\tc\tn_perm\trep\tt_std\tt_ana\tt_point\trel_eff\tacc_std\tacc_ana\tcache\n",
        );
        for r in &self.results {
            let tile = if r.tile.is_empty() { "off" } else { r.tile.as_str() };
            let cache = if r.cache.is_empty() { "-" } else { r.cache.as_str() };
            out.push_str(&format!(
                "{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{:.6e}\t{:.6e}\t{:.6e}\t{:.4}\t{:.4}\t{:.4}\t{}\n",
                r.exp_tag, r.engine, r.backend, r.threads.max(1), tile, r.n, r.p, r.k, r.c,
                r.n_perm, r.rep, r.t_std, r.t_ana, r.t_point, r.rel_eff(), r.acc_std,
                r.acc_ana, cache
            ));
        }
        out
    }

    /// The paper's three-way ANOVA on relative efficiency (Results §3.1).
    /// Factors are chosen per experiment: features is binned into quartile
    /// groups (it is continuous in the paper's model).
    pub fn anova_rel_eff(&self, second_factor: AnovaFactor) -> Option<AnovaTable> {
        if self.results.len() < 16 {
            return None;
        }
        let y: Vec<f64> = self.results.iter().map(|r| r.rel_eff()).collect();
        let features: Vec<f64> = self.results.iter().map(|r| r.p as f64).collect();
        let n_levels: Vec<usize> = self.results.iter().map(|r| r.n).collect();
        let second: Vec<usize> = self
            .results
            .iter()
            .map(|r| match second_factor {
                AnovaFactor::Folds => r.k,
                AnovaFactor::Permutations => r.n_perm,
                AnovaFactor::Classes => r.c,
            })
            .collect();
        let p_bins = 4.min(
            features.iter().map(|&f| f as usize).collect::<std::collections::BTreeSet<_>>().len(),
        );
        Some(anova(
            &y,
            &[
                Factor::from_continuous("features", &features, p_bins.max(2)),
                Factor::new("N", &n_levels),
                Factor::new(second_factor.name(), &second),
            ],
        ))
    }

    /// Render an ANOVA table the way the paper reports it.
    pub fn render_anova(tab: &AnovaTable, title: &str) -> String {
        let mut t =
            Table::new(vec!["term", "df", "SS", "F", "p"]).with_title(title.to_string());
        for row in &tab.rows {
            t.row(vec![
                row.term.clone(),
                row.df.to_string(),
                fnum(row.sum_sq, 3),
                fnum(row.f, 2),
                if row.p < 0.001 { "<.001".into() } else { format!("{:.3}", row.p) },
            ]);
        }
        t.row(vec![
            "residual".into(),
            tab.residual_df.to_string(),
            fnum(tab.residual_ss, 3),
            "".into(),
            "".into(),
        ]);
        t.render()
    }
}

/// The experiment-specific third factor of the paper's ANOVAs.
#[derive(Clone, Copy, Debug)]
pub enum AnovaFactor {
    Folds,
    Permutations,
    Classes,
}

impl AnovaFactor {
    fn name(&self) -> &'static str {
        match self {
            AnovaFactor::Folds => "folds",
            AnovaFactor::Permutations => "permutations",
            AnovaFactor::Classes => "classes",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_result(n: usize, p: usize, k: usize, rep: usize, eff: f64) -> SweepResult {
        SweepResult {
            label: format!("N={n} P={p} K={k}"),
            exp_tag: "BinaryCv".into(),
            engine: "serial".into(),
            backend: "primal".into(),
            threads: 1,
            tile: "off".into(),
            n,
            p,
            k,
            c: 2,
            n_perm: 0,
            rep,
            t_std: 10f64.powf(eff),
            t_ana: 1.0,
            acc_std: 0.9,
            acc_ana: 0.9,
            t_point: 0.0,
            cache: String::new(),
        }
    }

    #[test]
    fn aggregate_averages_reps() {
        let rs = vec![
            fake_result(100, 50, 5, 0, 1.0),
            fake_result(100, 50, 5, 1, 2.0),
            fake_result(100, 99, 5, 0, 3.0),
        ];
        let rep = SweepReport::new(rs);
        let agg = rep.aggregate();
        assert_eq!(agg.len(), 2);
        let first = agg.iter().find(|(l, ..)| l.contains("P=50")).unwrap();
        assert!((first.1 - 1.5).abs() < 1e-12);
        assert_eq!(first.4, 2);
        assert!(rep.render("t").contains("rel.eff"));
        let tsv = rep.to_tsv();
        assert_eq!(tsv.lines().count(), 4);
        let header = tsv.lines().next().unwrap();
        assert!(header.contains("\tt_point\t") && header.ends_with("\tcache"));
        assert!(tsv.lines().nth(1).unwrap().ends_with("\t-"), "empty cache renders as -");
    }

    #[test]
    fn anova_detects_feature_effect() {
        // rel_eff grows with P → features factor significant.
        let mut rs = Vec::new();
        for (pi, p) in [10usize, 50, 200, 800].iter().enumerate() {
            for n in [100usize, 1000] {
                for k in [5usize, 10] {
                    for rep in 0..3 {
                        let eff = pi as f64 + 0.01 * rep as f64;
                        rs.push(fake_result(n, *p, k, rep, eff));
                    }
                }
            }
        }
        let rep = SweepReport::new(rs);
        let tab = rep.anova_rel_eff(AnovaFactor::Folds).unwrap();
        let feat = tab.rows.iter().find(|r| r.term == "features").unwrap();
        assert!(feat.p < 1e-6, "features p={}", feat.p);
        let rendered = SweepReport::render_anova(&tab, "ANOVA");
        assert!(rendered.contains("features"));
    }

    #[test]
    fn anova_none_for_tiny_result_sets() {
        let rep = SweepReport::new(vec![fake_result(10, 5, 2, 0, 0.5)]);
        assert!(rep.anova_rel_eff(AnovaFactor::Folds).is_none());
    }
}
