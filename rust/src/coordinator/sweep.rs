//! Experiment definitions and the per-point timing protocol.
//!
//! Every sweep point generates a dataset (§2.12), builds folds, then times
//! both arms on *identical* data and folds — the RNG is forked per point so
//! arms and points are reproducible regardless of scheduling order.

use crate::cv::folds::{kfold, leave_one_out, stratified_kfold};
use crate::data::synthetic::{generate, SyntheticSpec};
use crate::fastcv::binary::AnalyticBinaryCv;
use crate::fastcv::multiclass::AnalyticMulticlassCv;
use crate::fastcv::hat::GramBackend;
use crate::fastcv::perm::{
    analytic_binary_permutation_ctx, analytic_multiclass_permutation_ctx,
    standard_binary_permutation, standard_multiclass_permutation,
};
use crate::fastcv::perm_batch::{
    analytic_binary_permutation_batched_ctx, analytic_multiclass_permutation_batched_ctx,
    BatchStrategy,
};
use crate::fastcv::{ComputeContext, FoldCache};
use crate::linalg::TilePolicy;
use crate::model::lda_binary::signed_codes;
use crate::model::Reg;
use crate::util::rng::Rng;
use crate::util::{log_grid_usize, timed};
use anyhow::Result;

/// Which paper experiment a point belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Experiment {
    /// Fig. 3a: binary cross-validation sweep.
    BinaryCv,
    /// Fig. 3b: binary permutation sweep.
    BinaryPerm,
    /// Fig. 3c: multi-class cross-validation sweep.
    MultiCv,
    /// Fig. 3d: multi-class permutation sweep.
    MultiPerm,
}

impl Experiment {
    /// Parse a CLI tag (`f3a`..`f3d`).
    pub fn from_tag(tag: &str) -> Option<Experiment> {
        match tag {
            "f3a" => Some(Experiment::BinaryCv),
            "f3b" => Some(Experiment::BinaryPerm),
            "f3c" => Some(Experiment::MultiCv),
            "f3d" => Some(Experiment::MultiPerm),
            _ => None,
        }
    }

    /// Human-readable name.
    pub fn name(&self) -> &'static str {
        match self {
            Experiment::BinaryCv => "Fig3a binary CV",
            Experiment::BinaryPerm => "Fig3b binary permutations",
            Experiment::MultiCv => "Fig3c multi-class CV",
            Experiment::MultiPerm => "Fig3d multi-class permutations",
        }
    }
}

/// Which analytic engine runs the analytic arm of a permutation point.
/// Ignored for the pure-CV experiments. Either choice yields bit-identical
/// accuracies (the `perm_batch` determinism contract) — only timing moves.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PermEngine {
    /// One permutation at a time (Alg. 1/2 with cached fold LUs).
    Serial,
    /// Batched GEMM/multi-RHS engine, optionally thread-parallel.
    Batched {
        /// Permutations per response matrix.
        batch: usize,
        /// Worker threads (1 = caller thread only).
        threads: usize,
    },
}

impl PermEngine {
    /// Short tag for labels / TSV columns.
    pub fn tag(&self) -> String {
        match self {
            PermEngine::Serial => "serial".to_string(),
            PermEngine::Batched { batch, threads } => format!("batched-b{batch}-t{threads}"),
        }
    }

    /// The batching strategy, when batched.
    pub fn strategy(&self) -> Option<BatchStrategy> {
        match *self {
            PermEngine::Serial => None,
            PermEngine::Batched { batch, threads } => Some(BatchStrategy::new(batch, threads)),
        }
    }
}

/// One configuration to measure.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub exp: Experiment,
    /// Samples.
    pub n: usize,
    /// Features.
    pub p: usize,
    /// Folds (`usize::MAX` encodes leave-one-out).
    pub k: usize,
    /// Classes (2 for binary).
    pub c: usize,
    /// Permutations (0 for pure-CV experiments).
    pub n_perm: usize,
    /// Repetition index (fresh data per rep, §2.12: 20 reps).
    pub rep: usize,
    /// Ridge penalty (regularisation keeps wide configs well-posed).
    pub lambda: f64,
    /// Analytic-arm engine for permutation experiments.
    pub engine: PermEngine,
    /// Gram backend for the analytic arm's hat build (`Auto` resolves by
    /// the point's P/N ratio; `Primal` reproduces the historical arm).
    pub backend: GramBackend,
    /// Worker threads for the analytic arm's *hat build* (the
    /// [`ComputeContext`] pool; 1 = serial). Pooled builds are bit-identical
    /// to serial ones, so this is a pure wall-clock knob — unlike
    /// [`SweepPoint::engine`]'s `threads`, which parallelises permutation
    /// batches instead. The CLI's `--threads` sets both.
    ///
    /// Pool lifetime mirrors [`BatchStrategy`]'s note: each `run_point`
    /// call owns a short-lived pool (spawn cost is a few hundred
    /// microseconds against a point that times two full CV arms). Combining
    /// a large `--workers` with a large `--threads` multiplies OS threads —
    /// size their product to the machine, or hoist a shared pool via
    /// [`ComputeContext::borrowing`] if a future caller drives many tiny
    /// points in a tight loop.
    pub threads: usize,
    /// [`TilePolicy`] for the analytic arm's `N×N` Gram builds/Cholesky
    /// (`Off` = the historical one-shot kernels; tiled modes are
    /// bit-identical, memory-bounded — the CLI's `--tile-rows` /
    /// `--mem-budget`). Pure wall-clock/memory knob: accuracies never move.
    pub tile: TilePolicy,
}

impl SweepPoint {
    /// Short config label for tables. Non-serial engines are tagged so the
    /// report aggregates them as distinct configurations.
    pub fn label(&self) -> String {
        let k = if self.k == usize::MAX { "LOO".into() } else { self.k.to_string() };
        let base = match self.exp {
            Experiment::BinaryCv => format!("N={} P={} K={k}", self.n, self.p),
            Experiment::BinaryPerm => {
                format!("N={} P={} K={k} T={}", self.n, self.p, self.n_perm)
            }
            Experiment::MultiCv => format!("N={} P={} K={k} C={}", self.n, self.p, self.c),
            Experiment::MultiPerm => {
                format!("N={} P={} K={k} C={} T={}", self.n, self.p, self.c, self.n_perm)
            }
        };
        let base = match (self.exp, self.engine) {
            (Experiment::BinaryPerm | Experiment::MultiPerm, PermEngine::Batched { .. }) => {
                format!("{base} [{}]", self.engine.tag())
            }
            _ => base,
        };
        // Non-primal backends are tagged so the report aggregates them as
        // distinct configurations (accuracies are invariant, timings not).
        let base = if self.backend == GramBackend::Primal {
            base
        } else {
            format!("{base} [{}]", self.backend.tag())
        };
        // Pooled hat builds likewise change timing only.
        let base = if self.threads > 1 {
            format!("{base} [pool-t{}]", self.threads)
        } else {
            base
        };
        // Tiled builds change memory/timing only.
        if self.tile.is_off() {
            base
        } else {
            format!("{base} [{}]", self.tile.tag())
        }
    }

    /// The same point with a different analytic permutation engine.
    pub fn with_engine(&self, engine: PermEngine) -> SweepPoint {
        SweepPoint { engine, ..self.clone() }
    }
}

/// Timed outcome of one point.
#[derive(Clone, Debug, Default)]
pub struct SweepResult {
    pub label: String,
    pub exp_tag: String,
    /// Analytic-arm engine tag (`serial` / `batched-b…-t…`).
    pub engine: String,
    /// Analytic-arm Gram backend tag (`primal`/`dual`/`spectral`/`auto`).
    pub backend: String,
    /// Analytic-arm hat-build pool width (1 = serial; `Default` yields 0,
    /// normalised to 1 by [`run_point`]).
    pub threads: usize,
    /// Analytic-arm tile-policy tag (`off`, `tile-r64`, `tile-b256m`;
    /// `Default` yields the empty string, normalised to `off` in the TSV).
    pub tile: String,
    pub n: usize,
    pub p: usize,
    pub k: usize,
    pub c: usize,
    pub n_perm: usize,
    pub rep: usize,
    /// Standard-approach wall-clock (s).
    pub t_std: f64,
    /// Analytic-approach wall-clock (s).
    pub t_ana: f64,
    /// Accuracy from the standard arm.
    pub acc_std: f64,
    /// Accuracy from the analytic arm.
    pub acc_ana: f64,
    /// End-to-end point wall-clock (s) from the caller-injected monotonic
    /// clock (see [`crate::coordinator::Scheduler::run_clocked`]); 0.0
    /// when no clock was injected (the historical [`run_point`] path).
    pub t_point: f64,
    /// [`crate::store::FactorStore`] counter delta for this point
    /// (`h…/m…/e…/d…`), filled by the scheduler in store mode; empty
    /// (rendered `-` in the TSV) otherwise.
    pub cache: String,
}

impl SweepResult {
    /// `log10(t_std / t_ana)` — the paper's relative efficiency.
    pub fn rel_eff(&self) -> f64 {
        (self.t_std / self.t_ana).log10()
    }
}

/// Scale factor for sweep grids: 1.0 reproduces the paper's ranges; smaller
/// values shrink N/P/perms for quick runs (used by tests and CI).
#[derive(Clone, Copy, Debug)]
pub struct SweepScale {
    /// Max features in the log grid (paper: 1000).
    pub p_max: usize,
    /// Feature-grid resolution (paper: 40 log steps).
    pub p_steps: usize,
    /// Sample sizes (paper: 100 and 1000).
    pub ns: &'static [usize],
    /// Repetitions per configuration (paper: 20).
    pub reps: usize,
    /// Permutation counts, binary (paper: 100/1000/10000).
    pub perms_binary: &'static [usize],
    /// Permutation counts, multi-class (paper: 10/100).
    pub perms_multi: &'static [usize],
    /// Feature cap for the multi-class experiments (the standard arm pays a
    /// full generalised eig per fold, so the paper too limited multi-class
    /// permutation counts "to keep overall computation time tractable").
    pub p_max_multi: usize,
}

impl SweepScale {
    /// The paper's full grids (hours of compute).
    pub fn paper() -> SweepScale {
        SweepScale {
            p_max: 1000,
            p_steps: 40,
            ns: &[100, 1000],
            reps: 20,
            perms_binary: &[100, 1000, 10000],
            perms_multi: &[10, 100],
            p_max_multi: 1000,
        }
    }

    /// A laptop-scale grid preserving the qualitative shape (default CLI):
    /// same N-small/N-large, folds, and permutation contrasts as the paper,
    /// with P capped at 500 and 2 reps so the full Fig. 3 suite finishes in
    /// minutes rather than the paper's cluster-hours.
    pub fn medium() -> SweepScale {
        SweepScale {
            p_max: 500,
            p_steps: 8,
            ns: &[100, 300],
            reps: 2,
            perms_binary: &[10, 50],
            perms_multi: &[5, 20],
            p_max_multi: 250,
        }
    }

    /// Tiny grid for tests.
    pub fn tiny() -> SweepScale {
        SweepScale {
            p_max: 60,
            p_steps: 4,
            ns: &[40],
            reps: 1,
            perms_binary: &[5],
            perms_multi: &[3],
            p_max_multi: 60,
        }
    }
}

/// Build the grid of points for one experiment.
pub fn grid(exp: Experiment, scale: &SweepScale) -> Vec<SweepPoint> {
    let ps = log_grid_usize(10, scale.p_max, scale.p_steps);
    let lambda = 1.0; // fixed moderate ridge; identical in both arms
    let mut out = Vec::new();
    match exp {
        Experiment::BinaryCv => {
            // folds ∈ {5, 10, 20, LOO}
            for &n in scale.ns {
                for &p in &ps {
                    for k in [5usize, 10, 20, usize::MAX] {
                        for rep in 0..scale.reps {
                            out.push(SweepPoint {
                                exp,
                                n,
                                p,
                                k,
                                c: 2,
                                n_perm: 0,
                                rep,
                                lambda,
                                engine: PermEngine::Serial,
                                backend: GramBackend::Primal,
                                threads: 1,
                                tile: TilePolicy::Off,
                            });
                        }
                    }
                }
            }
        }
        Experiment::BinaryPerm => {
            for &n in scale.ns {
                for &p in &ps {
                    for &t in scale.perms_binary {
                        for rep in 0..scale.reps {
                            out.push(SweepPoint {
                                exp,
                                n,
                                p,
                                k: 10,
                                c: 2,
                                n_perm: t,
                                rep,
                                lambda,
                                engine: PermEngine::Serial,
                                backend: GramBackend::Primal,
                                threads: 1,
                                tile: TilePolicy::Off,
                            });
                        }
                    }
                }
            }
        }
        Experiment::MultiCv => {
            for &n in scale.ns {
                for &p in ps.iter().filter(|&&p| p <= scale.p_max_multi) {
                    for c in [5usize, 10] {
                        if n / c < 4 {
                            continue;
                        }
                        for rep in 0..scale.reps {
                            out.push(SweepPoint {
                                exp,
                                n,
                                p,
                                k: 10,
                                c,
                                n_perm: 0,
                                rep,
                                lambda,
                                engine: PermEngine::Serial,
                                backend: GramBackend::Primal,
                                threads: 1,
                                tile: TilePolicy::Off,
                            });
                        }
                    }
                }
            }
        }
        Experiment::MultiPerm => {
            for &n in scale.ns {
                for &p in ps.iter().filter(|&&p| p <= scale.p_max_multi) {
                    for &t in scale.perms_multi {
                        for rep in 0..scale.reps {
                            out.push(SweepPoint {
                                exp,
                                n,
                                p,
                                k: 10,
                                c: 5,
                                n_perm: t,
                                rep,
                                lambda,
                                engine: PermEngine::Serial,
                                backend: GramBackend::Primal,
                                threads: 1,
                                tile: TilePolicy::Off,
                            });
                        }
                    }
                }
            }
        }
    }
    out
}

/// Run one sweep point: generate data, build folds, time both arms on the
/// identical data/folds (fresh RNG forks per arm mimic the paper's seed
/// reset), and sanity-check that the two arms agree on accuracy.
pub fn run_point(point: &SweepPoint, seed: u64) -> Result<SweepResult> {
    run_point_store(point, seed, None)
}

/// [`run_point`] with an optional shared [`FactorStore`]: the analytic
/// arm's [`ComputeContext`] borrows the store, so its factor builds land
/// in (and are served from) the cross-point cache. The store is a pure
/// wall-clock/memory knob — `run_point_store(p, s, Some(store))` returns
/// bitwise the same result as `run_point(p, s)`; only `t_*` timings move.
pub fn run_point_store(
    point: &SweepPoint,
    seed: u64,
    store: Option<&crate::store::FactorStore>,
) -> Result<SweepResult> {
    let mut rng = Rng::with_stream(seed, (point.rep as u64) << 8);
    let spec = if point.c == 2 {
        SyntheticSpec::binary(point.n, point.p)
    } else {
        SyntheticSpec::multiclass(point.n, point.p, point.c)
    };
    let ds = generate(&spec, &mut rng);
    let k_actual = if point.k == usize::MAX { point.n } else { point.k };
    let folds = if point.k == usize::MAX {
        leave_one_out(point.n)
    } else if point.c == 2 {
        kfold(point.n, k_actual, &mut rng)
    } else {
        stratified_kfold(&ds.labels, k_actual, &mut rng)
    };

    let mut result = SweepResult {
        label: point.label(),
        exp_tag: format!("{:?}", point.exp),
        engine: point.engine.tag(),
        backend: point.backend.tag().to_string(),
        n: point.n,
        p: point.p,
        k: k_actual,
        c: point.c,
        n_perm: point.n_perm,
        rep: point.rep,
        threads: point.threads.max(1),
        tile: point.tile.tag(),
        ..Default::default()
    };
    // Pool spawn happens outside the timed closures; with threads ≤ 1 no
    // pool exists and the context is free.
    let mut ctx = ComputeContext::with_threads(point.threads)
        .with_backend(point.backend)
        .with_tile_policy(point.tile.clone());
    if let Some(s) = store {
        ctx = ctx.with_store(s);
    }

    match point.exp {
        Experiment::BinaryCv => {
            let y = signed_codes(&ds.labels);
            let (std_dv, t_std) = timed(|| {
                crate::cv::runner::standard_binary_cv_dvals(
                    &ds.x,
                    &ds.labels,
                    &folds,
                    Reg::Ridge(point.lambda),
                )
            });
            let (ana_dv, t_ana) = timed(|| -> Result<Vec<f64>> {
                let cv = AnalyticBinaryCv::fit_ctx(&ds.x, &y, point.lambda, &ctx)?;
                let cache = FoldCache::prepare_pool(&cv.hat, &folds, false, ctx.pool())?;
                Ok(cv.decision_values_cached(&cache))
            });
            result.t_std = t_std;
            result.t_ana = t_ana;
            result.acc_std = crate::cv::metrics::accuracy_signed(&std_dv?, &y);
            result.acc_ana = crate::cv::metrics::accuracy_signed(&ana_dv?, &y);
        }
        Experiment::BinaryPerm => {
            let mut rng_std = rng.fork(1);
            let mut rng_ana = rng_std.clone(); // same state: identical permutation anchors
            let (std_res, t_std) = timed(|| {
                standard_binary_permutation(
                    &ds.x,
                    &ds.labels,
                    &folds,
                    Reg::Ridge(point.lambda),
                    point.n_perm,
                    &mut rng_std,
                )
            });
            let (ana_res, t_ana) = timed(|| match point.engine.strategy() {
                None => analytic_binary_permutation_ctx(
                    &ds.x,
                    &ds.labels,
                    &folds,
                    point.lambda,
                    point.n_perm,
                    false,
                    &mut rng_ana,
                    &ctx,
                ),
                Some(strategy) => analytic_binary_permutation_batched_ctx(
                    &ds.x,
                    &ds.labels,
                    &folds,
                    point.lambda,
                    point.n_perm,
                    false,
                    &mut rng_ana,
                    strategy,
                    &ctx,
                ),
            });
            result.t_std = t_std;
            result.t_ana = t_ana;
            result.acc_std = std_res?.observed;
            result.acc_ana = ana_res?.observed;
        }
        Experiment::MultiCv => {
            let (std_pred, t_std) = timed(|| {
                crate::cv::runner::standard_multiclass_cv_predict(
                    &ds.x,
                    &ds.labels,
                    point.c,
                    &folds,
                    Reg::Ridge(point.lambda),
                )
            });
            let (ana_pred, t_ana) = timed(|| -> Result<Vec<usize>> {
                let cv = AnalyticMulticlassCv::fit_ctx(
                    &ds.x,
                    &ds.labels,
                    point.c,
                    point.lambda,
                    &ctx,
                )?;
                let cache = FoldCache::prepare_pool(&cv.hat, &folds, true, ctx.pool())?;
                cv.predict_cached(&cache)
            });
            result.t_std = t_std;
            result.t_ana = t_ana;
            result.acc_std = crate::cv::metrics::accuracy_labels(&std_pred?, &ds.labels);
            result.acc_ana = crate::cv::metrics::accuracy_labels(&ana_pred?, &ds.labels);
        }
        Experiment::MultiPerm => {
            let mut rng_std = rng.fork(1);
            let mut rng_ana = rng_std.clone(); // same state: identical permutation anchors
            let (std_res, t_std) = timed(|| {
                standard_multiclass_permutation(
                    &ds.x,
                    &ds.labels,
                    point.c,
                    &folds,
                    Reg::Ridge(point.lambda),
                    point.n_perm,
                    &mut rng_std,
                )
            });
            let (ana_res, t_ana) = timed(|| match point.engine.strategy() {
                None => analytic_multiclass_permutation_ctx(
                    &ds.x,
                    &ds.labels,
                    point.c,
                    &folds,
                    point.lambda,
                    point.n_perm,
                    &mut rng_ana,
                    &ctx,
                ),
                Some(strategy) => analytic_multiclass_permutation_batched_ctx(
                    &ds.x,
                    &ds.labels,
                    point.c,
                    &folds,
                    point.lambda,
                    point.n_perm,
                    &mut rng_ana,
                    strategy,
                    &ctx,
                ),
            });
            result.t_std = t_std;
            result.t_ana = t_ana;
            result.acc_std = std_res?.observed;
            result.acc_ana = ana_res?.observed;
        }
    }
    Ok(result)
}

/// Time only the *analytic* arm of a permutation point (the standard arm
/// is skipped; `t_std`/`acc_std` are left at their defaults for the caller
/// to fill from a previous measurement). Data, folds, and the permutation
/// anchor are derived exactly as in [`run_point`], so for equal `(point,
/// seed)` the analytic arm sees identical inputs. Errors on pure-CV
/// experiments, which have no permutation arm to isolate.
pub fn run_point_analytic_perm(point: &SweepPoint, seed: u64) -> Result<SweepResult> {
    anyhow::ensure!(
        matches!(point.exp, Experiment::BinaryPerm | Experiment::MultiPerm),
        "run_point_analytic_perm: {:?} is not a permutation experiment",
        point.exp
    );
    let mut rng = Rng::with_stream(seed, (point.rep as u64) << 8);
    let spec = if point.c == 2 {
        SyntheticSpec::binary(point.n, point.p)
    } else {
        SyntheticSpec::multiclass(point.n, point.p, point.c)
    };
    let ds = generate(&spec, &mut rng);
    let k_actual = if point.k == usize::MAX { point.n } else { point.k };
    let folds = if point.k == usize::MAX {
        leave_one_out(point.n)
    } else if point.c == 2 {
        kfold(point.n, k_actual, &mut rng)
    } else {
        stratified_kfold(&ds.labels, k_actual, &mut rng)
    };
    // Mirror run_point's RNG discipline: the analytic arm gets a clone of
    // the fork the standard arm would have consumed.
    let rng_std = rng.fork(1);
    let mut rng_ana = rng_std.clone();

    let mut result = SweepResult {
        label: point.label(),
        exp_tag: format!("{:?}", point.exp),
        engine: point.engine.tag(),
        backend: point.backend.tag().to_string(),
        n: point.n,
        p: point.p,
        k: k_actual,
        c: point.c,
        n_perm: point.n_perm,
        rep: point.rep,
        threads: point.threads.max(1),
        tile: point.tile.tag(),
        ..Default::default()
    };
    let ctx = ComputeContext::with_threads(point.threads)
        .with_backend(point.backend)
        .with_tile_policy(point.tile.clone());
    let (ana_res, t_ana) = if point.exp == Experiment::BinaryPerm {
        timed(|| match point.engine.strategy() {
            None => analytic_binary_permutation_ctx(
                &ds.x,
                &ds.labels,
                &folds,
                point.lambda,
                point.n_perm,
                false,
                &mut rng_ana,
                &ctx,
            ),
            Some(strategy) => analytic_binary_permutation_batched_ctx(
                &ds.x,
                &ds.labels,
                &folds,
                point.lambda,
                point.n_perm,
                false,
                &mut rng_ana,
                strategy,
                &ctx,
            ),
        })
    } else {
        timed(|| match point.engine.strategy() {
            None => analytic_multiclass_permutation_ctx(
                &ds.x,
                &ds.labels,
                point.c,
                &folds,
                point.lambda,
                point.n_perm,
                &mut rng_ana,
                &ctx,
            ),
            Some(strategy) => analytic_multiclass_permutation_batched_ctx(
                &ds.x,
                &ds.labels,
                point.c,
                &folds,
                point.lambda,
                point.n_perm,
                &mut rng_ana,
                strategy,
                &ctx,
            ),
        })
    };
    result.t_ana = t_ana;
    result.acc_ana = ana_res?.observed;
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grids_have_expected_structure() {
        let scale = SweepScale::tiny();
        let g = grid(Experiment::BinaryCv, &scale);
        // 1 N × 4 P × 4 folds × 1 rep
        assert_eq!(g.len(), scale.ns.len() * 4 * 4 * scale.reps);
        assert!(g.iter().any(|p| p.k == usize::MAX), "LOO present");
        let gp = grid(Experiment::BinaryPerm, &scale);
        assert!(gp.iter().all(|p| p.n_perm > 0 && p.k == 10));
        let gm = grid(Experiment::MultiCv, &scale);
        assert!(gm.iter().all(|p| p.c == 5 || p.c == 10));
    }

    #[test]
    fn binary_cv_point_runs_and_arms_agree() {
        let point = SweepPoint {
            exp: Experiment::BinaryCv,
            n: 40,
            p: 12,
            k: 5,
            c: 2,
            n_perm: 0,
            rep: 0,
            lambda: 1.0,
            engine: PermEngine::Serial,
            backend: GramBackend::Primal,
            threads: 1,
            tile: TilePolicy::Off,
        };
        let r = run_point(&point, 1234).unwrap();
        assert!(r.t_std > 0.0 && r.t_ana > 0.0);
        // Analytic arm uses b_LR, standard uses b_LDA: accuracies are close
        // but not forced equal; the exactness tests cover value equality.
        assert!((r.acc_std - r.acc_ana).abs() < 0.15, "{} vs {}", r.acc_std, r.acc_ana);
    }

    #[test]
    fn multiclass_point_exact_agreement() {
        let point = SweepPoint {
            exp: Experiment::MultiCv,
            n: 50,
            p: 10,
            k: 5,
            c: 5,
            n_perm: 0,
            rep: 0,
            lambda: 1.0,
            engine: PermEngine::Serial,
            backend: GramBackend::Primal,
            threads: 1,
            tile: TilePolicy::Off,
        };
        let r = run_point(&point, 99).unwrap();
        assert!(
            (r.acc_std - r.acc_ana).abs() < 1e-12,
            "multiclass arms must agree exactly: {} vs {}",
            r.acc_std,
            r.acc_ana
        );
    }

    #[test]
    fn perm_points_run() {
        for exp in [Experiment::BinaryPerm, Experiment::MultiPerm] {
            let point = SweepPoint {
                exp,
                n: 30,
                p: 8,
                k: 3,
                c: if exp == Experiment::MultiPerm { 3 } else { 2 },
                n_perm: 3,
                rep: 0,
                lambda: 1.0,
                engine: PermEngine::Serial,
                backend: GramBackend::Primal,
                threads: 1,
                tile: TilePolicy::Off,
            };
            let r = run_point(&point, 7).unwrap();
            assert!(r.t_std > 0.0 && r.t_ana > 0.0);
            assert!((r.acc_std - r.acc_ana).abs() < 1e-9, "{exp:?}");
        }
    }

    #[test]
    fn batched_engine_point_matches_serial() {
        let serial = SweepPoint {
            exp: Experiment::BinaryPerm,
            n: 30,
            p: 8,
            k: 3,
            c: 2,
            n_perm: 6,
            rep: 0,
            lambda: 1.0,
            engine: PermEngine::Serial,
            backend: GramBackend::Primal,
            threads: 1,
            tile: TilePolicy::Off,
        };
        let batched = serial.with_engine(PermEngine::Batched { batch: 4, threads: 2 });
        let a = run_point(&serial, 7).unwrap();
        let b = run_point(&batched, 7).unwrap();
        assert_eq!(a.acc_ana, b.acc_ana, "engines must agree on accuracy");
        assert_eq!(a.acc_std, b.acc_std);
        assert_eq!(b.engine, "batched-b4-t2");
        assert!(b.label.contains("batched"), "batched label tagged: {}", b.label);
        // analytic-only rerun regenerates identical inputs → same accuracy
        let only = run_point_analytic_perm(&batched, 7).unwrap();
        assert_eq!(only.acc_ana, a.acc_ana);
        assert!(run_point_analytic_perm(&serial.with_engine(PermEngine::Serial), 7)
            .unwrap()
            .acc_ana
            .eq(&a.acc_ana));
        assert!(
            run_point_analytic_perm(
                &SweepPoint { exp: Experiment::BinaryCv, ..serial.clone() },
                7
            )
            .is_err(),
            "pure-CV points must be rejected"
        );
    }

    #[test]
    fn backend_equivalence_sweep_point_accuracies_invariant() {
        // A wide point run through each backend must report the same
        // analytic accuracy; only timing may move. Labels/TSV tag the
        // non-primal backends.
        let base = SweepPoint {
            exp: Experiment::BinaryCv,
            n: 24,
            p: 60,
            k: 4,
            c: 2,
            n_perm: 0,
            rep: 0,
            lambda: 1.0,
            engine: PermEngine::Serial,
            backend: GramBackend::Primal,
            threads: 1,
            tile: TilePolicy::Off,
        };
        let r_primal = run_point(&base, 11).unwrap();
        for backend in [GramBackend::Dual, GramBackend::Spectral, GramBackend::Auto] {
            let point = SweepPoint { backend, ..base.clone() };
            let r = run_point(&point, 11).unwrap();
            assert_eq!(r.acc_ana, r_primal.acc_ana, "{backend:?} accuracy moved");
            assert_eq!(r.acc_std, r_primal.acc_std);
            assert_eq!(r.backend, backend.tag());
            assert!(r.label.contains(backend.tag()), "label untagged: {}", r.label);
        }
        assert!(!r_primal.label.contains("primal"), "primal label stays bare");
        // perm experiment: the analytic arm's observed accuracy is
        // backend-invariant too (b_LR vs b_LDA keeps the std arm apart, so
        // compare analytic-vs-analytic).
        let perm_primal =
            SweepPoint { exp: Experiment::BinaryPerm, n_perm: 4, ..base.clone() };
        let perm_auto = SweepPoint { backend: GramBackend::Auto, ..perm_primal.clone() };
        let r_p = run_point(&perm_primal, 11).unwrap();
        let r_a = run_point(&perm_auto, 11).unwrap();
        assert_eq!(r_p.acc_ana, r_a.acc_ana, "perm analytic arm backend-invariant");
    }

    #[test]
    fn backend_pool_threads_do_not_change_point_accuracies() {
        // `--threads` on the analytic path is wall-clock only: a pooled
        // point must report the identical accuracies, and its label must be
        // tagged so the report aggregates it separately.
        let base = SweepPoint {
            exp: Experiment::BinaryCv,
            n: 24,
            p: 70,
            k: 4,
            c: 2,
            n_perm: 0,
            rep: 0,
            lambda: 1.0,
            engine: PermEngine::Serial,
            backend: GramBackend::Auto,
            threads: 1,
            tile: TilePolicy::Off,
        };
        let serial = run_point(&base, 13).unwrap();
        let pooled_point = SweepPoint { threads: 4, ..base.clone() };
        let pooled = run_point(&pooled_point, 13).unwrap();
        assert_eq!(pooled.acc_ana, serial.acc_ana, "pooled hat build moved the accuracy");
        assert_eq!(pooled.acc_std, serial.acc_std);
        assert_eq!(pooled.threads, 4);
        assert!(pooled.label.contains("pool-t4"), "label untagged: {}", pooled.label);
        assert!(!serial.label.contains("pool"), "serial label stays bare: {}", serial.label);
        // perm experiment through the ctx engines too
        let perm = SweepPoint { exp: Experiment::BinaryPerm, n_perm: 5, ..base.clone() };
        let perm_pooled = SweepPoint { threads: 3, ..perm.clone() };
        let a = run_point(&perm, 13).unwrap();
        let b = run_point(&perm_pooled, 13).unwrap();
        assert_eq!(a.acc_ana, b.acc_ana);
        let only = run_point_analytic_perm(&perm_pooled, 13).unwrap();
        assert_eq!(only.acc_ana, a.acc_ana);
    }

    #[test]
    fn tiled_sweep_point_accuracies_invariant_and_labelled() {
        // `--tile-rows`/`--mem-budget` are memory/wall-clock knobs: a tiled
        // point must report identical accuracies, and its label/TSV row
        // must be tagged so the report aggregates it separately.
        let base = SweepPoint {
            exp: Experiment::BinaryCv,
            n: 24,
            p: 70,
            k: 4,
            c: 2,
            n_perm: 0,
            rep: 0,
            lambda: 1.0,
            engine: PermEngine::Serial,
            backend: GramBackend::Auto,
            threads: 1,
            tile: TilePolicy::Off,
        };
        let off = run_point(&base, 17).unwrap();
        assert_eq!(off.tile, "off");
        assert!(!off.label.contains("tile"), "Off label stays bare: {}", off.label);
        for tile in [
            TilePolicy::Rows(8),
            TilePolicy::Budget { bytes: 1 << 20 },
            TilePolicy::Spill { dir: None, tile: 8 },
        ] {
            let point = SweepPoint { tile: tile.clone(), ..base.clone() };
            let r = run_point(&point, 17).unwrap();
            assert_eq!(r.acc_ana, off.acc_ana, "{tile:?} accuracy moved");
            assert_eq!(r.acc_std, off.acc_std);
            assert_eq!(r.tile, tile.tag());
            assert!(r.label.contains(&tile.tag()), "label untagged: {}", r.label);
        }
        // perm experiment reaches the tiled build through the ctx engines
        let perm = SweepPoint {
            exp: Experiment::BinaryPerm,
            n_perm: 4,
            backend: GramBackend::Dual,
            ..base.clone()
        };
        let perm_tiled = SweepPoint { tile: TilePolicy::Rows(5), ..perm.clone() };
        let a = run_point(&perm, 17).unwrap();
        let b = run_point(&perm_tiled, 17).unwrap();
        assert_eq!(a.acc_ana, b.acc_ana, "tiled perm arm accuracy moved");
        let only = run_point_analytic_perm(&perm_tiled, 17).unwrap();
        assert_eq!(only.acc_ana, a.acc_ana);
        assert_eq!(only.tile, "tile-r5");
    }

    #[test]
    fn deterministic_given_seed() {
        let point = SweepPoint {
            exp: Experiment::BinaryCv,
            n: 30,
            p: 6,
            k: 3,
            c: 2,
            n_perm: 0,
            rep: 2,
            lambda: 0.5,
            engine: PermEngine::Serial,
            backend: GramBackend::Primal,
            threads: 1,
            tile: TilePolicy::Off,
        };
        let a = run_point(&point, 42).unwrap();
        let b = run_point(&point, 42).unwrap();
        assert_eq!(a.acc_std, b.acc_std);
        assert_eq!(a.acc_ana, b.acc_ana);
    }
}
