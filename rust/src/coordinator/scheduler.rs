//! Job scheduler: fan sweep points out over the worker pool.
//!
//! Each job gets a deterministic RNG stream derived from (base seed, job
//! index), so results are identical regardless of worker count or
//! completion order. Progress is reported through a shared atomic counter.

use super::sweep::{run_point, SweepPoint, SweepResult};
use crate::util::threadpool::ThreadPool;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Sweep scheduler over a thread pool.
pub struct Scheduler {
    pool: ThreadPool,
    base_seed: u64,
    verbose: bool,
}

/// Deterministic per-job RNG seed for job `index` of a sweep anchored at
/// `base_seed` — the same derivation [`Scheduler::run`] uses, exposed so
/// out-of-scheduler reruns (e.g. the engine-comparison benches) can
/// regenerate the identical data and folds for a given point index.
pub fn job_seed(base_seed: u64, index: usize) -> u64 {
    base_seed ^ (index as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15)
}

impl Scheduler {
    /// `workers = 0` → one per logical core (capped at 16).
    pub fn new(workers: usize, base_seed: u64, verbose: bool) -> Scheduler {
        let pool = if workers == 0 {
            ThreadPool::with_default_size(16)
        } else {
            ThreadPool::new(workers)
        };
        Scheduler { pool, base_seed, verbose }
    }

    /// Worker count.
    pub fn workers(&self) -> usize {
        self.pool.size()
    }

    /// Run all points; results come back in input order. Failed points are
    /// reported and skipped (they do not abort the sweep).
    pub fn run(&self, points: &[SweepPoint]) -> Vec<SweepResult> {
        let total = points.len();
        let done = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<SweepResult>>> =
            (0..total).map(|_| Mutex::new(None)).collect();
        let slots_ref = &slots;
        let done_ref = &done;
        let base_seed = self.base_seed;
        let verbose = self.verbose;
        self.pool.for_each(total, move |i| {
            let point = &points[i];
            let seed = job_seed(base_seed, i);
            match run_point(point, seed) {
                Ok(res) => {
                    // lint:allow(panic, reason = "mutex poisoning is unreachable: the closure stores a value and cannot panic while holding the lock")
                    *slots_ref[i].lock().unwrap() = Some(res);
                }
                Err(e) => {
                    eprintln!("sweep point {} failed: {e:#}", point.label());
                }
            }
            let d = done_ref.fetch_add(1, Ordering::Relaxed) + 1;
            if verbose && (d % 10 == 0 || d == total) {
                eprintln!("  [{d}/{total}] sweep points done");
            }
        });
        // lint:allow(panic, reason = "into_inner poisoning would mean a worker panicked mid-store, which the closure cannot do")
        slots.into_iter().filter_map(|s| s.into_inner().unwrap()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sweep::{grid, Experiment, SweepScale};

    #[test]
    fn scheduler_runs_tiny_grid_in_order() {
        let scale = SweepScale::tiny();
        let mut points = grid(Experiment::BinaryCv, &scale);
        points.truncate(6);
        let sched = Scheduler::new(3, 99, false);
        let results = sched.run(&points);
        assert_eq!(results.len(), 6);
        for (p, r) in points.iter().zip(&results) {
            assert_eq!(p.label(), r.label, "order preserved");
            assert!(r.t_std > 0.0 && r.t_ana > 0.0);
        }
    }

    #[test]
    fn results_independent_of_worker_count() {
        let scale = SweepScale::tiny();
        let mut points = grid(Experiment::BinaryCv, &scale);
        points.truncate(4);
        let r1 = Scheduler::new(1, 7, false).run(&points);
        let r4 = Scheduler::new(4, 7, false).run(&points);
        assert_eq!(r1.len(), r4.len());
        for (a, b) in r1.iter().zip(&r4) {
            assert_eq!(a.acc_std, b.acc_std, "{}", a.label);
            assert_eq!(a.acc_ana, b.acc_ana, "{}", a.label);
        }
    }
}
