//! Job scheduler: fan sweep points out over the worker pool.
//!
//! Each job gets a deterministic RNG stream derived from (base seed, job
//! index), so results are identical regardless of worker count or
//! completion order. Progress is reported through a shared atomic counter.

use super::sweep::{run_point_store, SweepPoint, SweepResult};
use crate::store::FactorStore;
use crate::util::threadpool::ThreadPool;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Sweep scheduler over a thread pool.
pub struct Scheduler {
    pool: ThreadPool,
    base_seed: u64,
    verbose: bool,
}

/// Deterministic per-job RNG seed for job `index` of a sweep anchored at
/// `base_seed` — the same derivation [`Scheduler::run`] uses, exposed so
/// out-of-scheduler reruns (e.g. the engine-comparison benches) can
/// regenerate the identical data and folds for a given point index.
pub fn job_seed(base_seed: u64, index: usize) -> u64 {
    base_seed ^ (index as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15)
}

impl Scheduler {
    /// `workers = 0` → one per logical core (capped at 16).
    pub fn new(workers: usize, base_seed: u64, verbose: bool) -> Scheduler {
        let pool = if workers == 0 {
            ThreadPool::with_default_size(16)
        } else {
            ThreadPool::new(workers)
        };
        Scheduler { pool, base_seed, verbose }
    }

    /// Worker count.
    pub fn workers(&self) -> usize {
        self.pool.size()
    }

    /// Run all points; results come back in input order. Failed points are
    /// reported and skipped (they do not abort the sweep).
    ///
    /// Historical entry point: per-index job seeds, no clock, no store —
    /// results are bitwise-identical to every prior release (`t_point`
    /// stays 0.0 and `cache` empty). Callers that want real per-point wall
    /// time or cross-point factor sharing use [`Scheduler::run_clocked`].
    pub fn run(&self, points: &[SweepPoint]) -> Vec<SweepResult> {
        self.run_clocked(points, &|| 0.0, None)
    }

    /// [`Scheduler::run`] with a caller-injected monotonic clock and an
    /// optional shared [`FactorStore`].
    ///
    /// The clock is *passed in* rather than read here so numeric modules
    /// keep their `Instant` ban (lint L2): the CLI hands in
    /// [`crate::util::monotonic_clock`], tests can hand in `|| 0.0` or a
    /// counter. Each point's `t_point` is the clock delta around its whole
    /// run; with a store, `cache` records the point's counter delta
    /// ([`crate::store::StoreStats::since`]) — exact at one worker,
    /// approximate when concurrent workers interleave on the store.
    ///
    /// **Store mode changes seeding.** Without a store every point gets
    /// `job_seed(base, index)` (the historical contract). With one, a
    /// point instead gets the seed of the *first* point in `points` with
    /// the same `(n, p, c, rep)` — equal-spec points (e.g. the same
    /// dataset swept over fold counts) then generate identical data, so
    /// their Gram fingerprints collide and the store actually shares
    /// factors across points. That remap moves accuracies relative to
    /// `run`, which is why sharing is opt-in (`fastcv sweep --cache`, the
    /// serve daemon) and never the default path.
    pub fn run_clocked(
        &self,
        points: &[SweepPoint],
        clock: &(dyn Fn() -> f64 + Sync),
        store: Option<&FactorStore>,
    ) -> Vec<SweepResult> {
        let total = points.len();
        let done = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<SweepResult>>> =
            (0..total).map(|_| Mutex::new(None)).collect();
        // Store mode: canonical seed index = first equal-spec point, so
        // shared datasets become shared store keys (see the doc above).
        let canon: Vec<usize> = match store {
            None => (0..total).collect(),
            Some(_) => points
                .iter()
                .enumerate()
                .map(|(i, p)| {
                    points[..i]
                        .iter()
                        .position(|q| (q.n, q.p, q.c, q.rep) == (p.n, p.p, p.c, p.rep))
                        .unwrap_or(i)
                })
                .collect(),
        };
        let slots_ref = &slots;
        let done_ref = &done;
        let canon_ref = &canon;
        let base_seed = self.base_seed;
        let verbose = self.verbose;
        self.pool.for_each(total, move |i| {
            let point = &points[i];
            let seed = job_seed(base_seed, canon_ref[i]);
            let before = store.map(FactorStore::stats);
            let t0 = clock();
            match run_point_store(point, seed, store) {
                Ok(mut res) => {
                    res.t_point = clock() - t0;
                    if let (Some(s), Some(b)) = (store, &before) {
                        res.cache = s.stats().since(b).tag();
                    }
                    // lint:allow(panic, reason = "mutex poisoning is unreachable: the closure stores a value and cannot panic while holding the lock")
                    *slots_ref[i].lock().unwrap() = Some(res);
                }
                Err(e) => {
                    eprintln!("sweep point {} failed: {e:#}", point.label());
                }
            }
            let d = done_ref.fetch_add(1, Ordering::Relaxed) + 1;
            if verbose && (d % 10 == 0 || d == total) {
                eprintln!("  [{d}/{total}] sweep points done");
            }
        });
        // lint:allow(panic, reason = "into_inner poisoning would mean a worker panicked mid-store, which the closure cannot do")
        slots.into_iter().filter_map(|s| s.into_inner().unwrap()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sweep::{grid, Experiment, SweepScale};

    #[test]
    fn scheduler_runs_tiny_grid_in_order() {
        let scale = SweepScale::tiny();
        let mut points = grid(Experiment::BinaryCv, &scale);
        points.truncate(6);
        let sched = Scheduler::new(3, 99, false);
        let results = sched.run(&points);
        assert_eq!(results.len(), 6);
        for (p, r) in points.iter().zip(&results) {
            assert_eq!(p.label(), r.label, "order preserved");
            assert!(r.t_std > 0.0 && r.t_ana > 0.0);
        }
    }

    #[test]
    fn run_clocked_without_store_matches_run_and_times_points() {
        let scale = SweepScale::tiny();
        let mut points = grid(Experiment::BinaryCv, &scale);
        points.truncate(4);
        let sched = Scheduler::new(2, 41, false);
        let plain = sched.run(&points);
        let ticks = std::sync::atomic::AtomicUsize::new(0);
        let clock = || ticks.fetch_add(1, std::sync::atomic::Ordering::SeqCst) as f64;
        let clocked = sched.run_clocked(&points, &clock, None);
        assert_eq!(plain.len(), clocked.len());
        for (a, b) in plain.iter().zip(&clocked) {
            assert_eq!(a.acc_std, b.acc_std, "{}", a.label);
            assert_eq!(a.acc_ana, b.acc_ana, "{}", a.label);
            assert_eq!(a.t_point, 0.0, "run never reads a clock");
            assert_eq!(b.t_point, 1.0, "counter clock ticks once per bracket");
            assert!(a.cache.is_empty() && b.cache.is_empty(), "no store, no tag");
        }
    }

    #[test]
    fn store_mode_shares_factors_across_equal_spec_points() {
        // Tiny BinaryCv points: fold counts vary while (n, p, c, rep)
        // repeats, so canonical seeding must produce real store hits.
        let scale = SweepScale::tiny();
        let mut points = grid(Experiment::BinaryCv, &scale);
        points.truncate(6);
        let store = crate::store::FactorStore::new();
        let sched = Scheduler::new(1, 99, false);
        let results = sched.run_clocked(&points, &|| 0.0, Some(&store));
        assert_eq!(results.len(), 6);
        let stats = store.stats();
        assert!(stats.hits >= 1, "equal-spec points must share factors: {stats:?}");
        assert!(stats.misses >= 1, "first touch of a key still builds: {stats:?}");
        assert!(
            results.iter().all(|r| !r.cache.is_empty()),
            "per-point cache tags must be filled in store mode"
        );
        // At one worker the per-point deltas are exact: they sum to the
        // store totals.
        let parse = |tag: &str, idx: usize| -> u64 {
            tag.split('/').nth(idx).and_then(|s| s[1..].parse().ok()).unwrap()
        };
        let (mut h, mut m) = (0u64, 0u64);
        for r in &results {
            h += parse(&r.cache, 0);
            m += parse(&r.cache, 1);
        }
        assert_eq!(h, stats.hits);
        assert_eq!(m, stats.misses);
    }

    #[test]
    fn results_independent_of_worker_count() {
        let scale = SweepScale::tiny();
        let mut points = grid(Experiment::BinaryCv, &scale);
        points.truncate(4);
        let r1 = Scheduler::new(1, 7, false).run(&points);
        let r4 = Scheduler::new(4, 7, false).run(&points);
        assert_eq!(r1.len(), r4.len());
        for (a, b) in r1.iter().zip(&r4) {
            assert_eq!(a.acc_std, b.acc_std, "{}", a.label);
            assert_eq!(a.acc_ana, b.acc_ana, "{}", a.label);
        }
    }
}
