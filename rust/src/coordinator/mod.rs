//! L3 coordinator: the sweep/permutation orchestration engine.
//!
//! Reproducing Fig. 3 means running hundreds of (N, P, K, C, perms, rep)
//! configurations, each timing the standard approach against the analytic
//! approach on identical data and folds. This module owns that machinery:
//!
//! - [`sweep`] — experiment grids (Fig. 3a–d, Table 1, parity §4.1) and the
//!   per-point timing protocol (seed reset between the two arms, as in
//!   §2.12)
//! - [`scheduler`] — job fan-out over the worker pool with deterministic
//!   per-job RNG streams and progress reporting
//! - [`report`] — result collection, relative-efficiency summaries, ANOVA
//!   tables matching the paper's Results section, TSV dumps

pub mod report;
pub mod scheduler;
pub mod sweep;

pub use report::SweepReport;
pub use scheduler::Scheduler;
pub use sweep::{Experiment, SweepPoint, SweepResult};
