//! The hat matrix `H = X̃ (X̃ᵀX̃ + λI₀)⁻¹ X̃ᵀ` (§2.4.2, §2.6.1) and the
//! Gram backends that build it.
//!
//! Built **once** per dataset; it depends on the features only, so it is
//! reused across every fold *and every label permutation* (§2.7) — that
//! reuse is the entire source of the paper's speed-up.
//!
//! ## Gram backends
//!
//! The same `H` admits three algebraically equivalent constructions with
//! very different costs (see [`GramBackend`]):
//!
//! * **Primal** — factor the `(P+1)×(P+1)` Gram `X̃ᵀX̃ + λI₀` and form
//!   `H = X̃·solve(G, X̃ᵀ)`: `O(NP² + P³)`. Best for N ≫ P; the historical
//!   path, and the only one defined at λ = 0.
//! * **Dual** (§4.4's kernel view) — with the intercept handled by the
//!   centering decomposition, `H = (1/N)𝟙𝟙ᵀ + K_c (K_c + λI)⁻¹` where
//!   `K_c = X_c X_cᵀ` is the centered `N×N` Gram: `O(N²P + N³)`. The
//!   paper's P ≫ N lifeline — the whole point of the 10,000× regime.
//! * **Spectral** — one symmetric eigendecomposition `K_c = U diag(d) Uᵀ`,
//!   after which `H(λ) = (1/N)𝟙𝟙ᵀ + U diag(dᵢ/(dᵢ+λ)) Uᵀ` makes every
//!   additional λ candidate an `O(N³)`-GEMM with no refactorisation — the
//!   λ-grid workhorse behind [`crate::fastcv::lambda_search`].
//!
//! The centering identity: ridge with an unpenalised intercept fits
//! `w = (X_cᵀX_c + λI)⁻¹ X_cᵀ y`, `b = ȳ − x̄ᵀw`, so the fitted values are
//! `ŷ = X_c w + ȳ𝟙 = [(1/N)𝟙𝟙ᵀ + X_c (X_cᵀX_c + λI)⁻¹ X_cᵀ] y`, and the
//! push-through identity turns the inner term into `K_c (K_c + λI)⁻¹`.
//! Since `K_c𝟙 = 0` (columns of `X_c` are centered), `H𝟙 = 𝟙` holds in
//! every backend — the unpenalised-intercept invariant.
//!
//! ## Choosing, and parallelising
//!
//! `Auto` resolves per shape: a single hat picks `Dual` iff `λ > 0 ∧ P > N`
//! ([`GramBackend::resolve`]); a λ-grid upgrades the wide case to
//! `Spectral` once ≥ 2 positive candidates amortise the eigendecomposition
//! ([`GramBackend::resolve_for_grid`]). The full decision guide — memory
//! footprints, the λ = 0 caveat, measured orderings — is
//! `docs/BACKENDS.md` in the repository root.
//!
//! Every λ-free build (the `K_c` GEMM, the primal `G₀` syrk) and every
//! per-candidate GEMM can fan out over a
//! [`ThreadPool`](crate::util::threadpool::ThreadPool) — usually handed
//! down from a [`crate::fastcv::context::ComputeContext`] — through
//! kernels that are bit-identical to their serial forms
//! ([`crate::linalg::matmul_pool`], [`crate::linalg::syrk_t_pool`]), so
//! pooling never changes a result.

use super::context::ComputeContext;
use crate::linalg::{
    chol_spill_ridged, gram_spill, gram_tiled, matmul, matmul_pool, matvec_gemm_order, sym_eig,
    syrk_spill, syrk_t_pool, syrk_tiled, Cholesky, Lu, Mat, PanelStore, SymEig, TilePolicy,
};
use crate::model::linreg::gram_ridged;
use crate::util::threadpool::ThreadPool;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Panel width for the pooled per-λ `K_c + λI` Cholesky when no explicit
/// tile height is in force (the factor is `N×N`, so any fixed panel works;
/// the value only shapes the pool fan-out granularity).
const CHOL_PANEL: usize = 64;

/// Which construction of the hat matrix to use. `Auto` picks by the P/N
/// ratio: `Dual` when `λ > 0` and `P > N`, `Primal` otherwise (λ-grid
/// callers resolve to `Spectral` instead — see
/// [`GramBackend::resolve_for_grid`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum GramBackend {
    /// Pick per shape: `Dual` when `λ > 0 ∧ P > N`, else `Primal`.
    #[default]
    Auto,
    /// Factor the `(P+1)×(P+1)` primal Gram — `O(NP² + P³)`.
    Primal,
    /// Factor the centered `N×N` Gram `K_c + λI` — `O(N²P + N³)`, λ > 0.
    Dual,
    /// Eigendecompose `K_c` once; each λ is then a GEMM — λ > 0.
    Spectral,
}

impl GramBackend {
    /// Parse a CLI tag (`auto|primal|dual|spectral`).
    pub fn from_tag(tag: &str) -> Option<GramBackend> {
        match tag {
            "auto" => Some(GramBackend::Auto),
            "primal" => Some(GramBackend::Primal),
            "dual" => Some(GramBackend::Dual),
            "spectral" => Some(GramBackend::Spectral),
            _ => None,
        }
    }

    /// Short tag for labels / TSV columns.
    pub fn tag(&self) -> &'static str {
        match self {
            GramBackend::Auto => "auto",
            GramBackend::Primal => "primal",
            GramBackend::Dual => "dual",
            GramBackend::Spectral => "spectral",
        }
    }

    /// Resolve `Auto` for a single hat build: `Dual` beats `Primal` exactly
    /// when the `N×N` side is the smaller problem (`P > N`) and the dual
    /// form is defined (`λ > 0`).
    pub fn resolve(self, n: usize, p: usize, lambda: f64) -> GramBackend {
        match self {
            GramBackend::Auto => {
                if lambda > 0.0 && p > n {
                    GramBackend::Dual
                } else {
                    GramBackend::Primal
                }
            }
            other => other,
        }
    }

    /// Resolve `Auto` for a λ-grid cache: with ≥ 2 positive candidates on a
    /// wide shape the one-off eigendecomposition amortises, so `Spectral`;
    /// a single positive candidate still prefers `Dual`; tall shapes keep
    /// the primal factor (its `P³` is the cheap side there).
    pub fn resolve_for_grid(self, n: usize, p: usize, positive_candidates: usize) -> GramBackend {
        match self {
            GramBackend::Auto => {
                if p > n && positive_candidates >= 2 {
                    GramBackend::Spectral
                } else if p > n && positive_candidates == 1 {
                    GramBackend::Dual
                } else {
                    GramBackend::Primal
                }
            }
            other => other,
        }
    }

    /// [`GramBackend::resolve_for_grid`] made **spill-aware** — the single
    /// source of the out-of-core downgrade rule: under a
    /// [`TilePolicy::Spill`] policy, an `Auto` that would pick `Spectral`
    /// picks `Dual` instead (the spectral eigenvector matrix is an
    /// irreducible resident `N×N`, which is exactly what spilling asks to
    /// avoid; the dual per-λ Cholesky streams fully out of core). Explicit
    /// backends — including `Spectral` — pass through untouched. Called by
    /// [`crate::fastcv::context::ComputeContext::resolve_for_grid`] and
    /// [`GramCache::build_tiled`]'s blind-`Auto` fallback.
    pub fn resolve_for_grid_spill_aware(
        self,
        n: usize,
        p: usize,
        positive_candidates: usize,
        tile: &TilePolicy,
    ) -> GramBackend {
        let resolved = self.resolve_for_grid(n, p, positive_candidates);
        if self == GramBackend::Auto
            && resolved == GramBackend::Spectral
            && tile.spill().is_some()
        {
            GramBackend::Dual
        } else {
            resolved
        }
    }
}

/// Which factorisation of the gram matrix backs this hat matrix.
#[derive(Clone, Debug)]
enum GramFactor {
    Chol(Cholesky),
    Lu(Lu),
    /// Dual/spectral builds never factor the primal Gram; the primal-side
    /// utilities ([`HatMatrix::inv_gram`] / [`HatMatrix::solve_gram`], off
    /// the hot path) refactor from `xa` on demand.
    OnDemand,
}

/// Precomputed full-data quantities shared by the analytic CV paths.
#[derive(Clone, Debug)]
pub struct HatMatrix {
    /// `H`, `N × N`.
    pub h: Mat,
    /// Augmented design `X̃ = [X, 1]`, `N × (P+1)`.
    pub xa: Mat,
    /// Factorisation of `G = X̃ᵀX̃ + λI₀` (the explicit inverse `S` is never
    /// needed on the hot path — see [`HatMatrix::inv_gram`]).
    factor: GramFactor,
    /// Ridge parameter used.
    pub lambda: f64,
    /// The (resolved) backend that built `h` — never `Auto`.
    pub backend: GramBackend,
}

/// λ-free precomputation shared across a ridge grid: everything about the
/// chosen Gram backend that does **not** depend on λ. One `GramCache` plus
/// one [`GramCache::hat`] call per candidate replaces a from-scratch
/// [`HatMatrix::build_with`] per candidate:
///
/// * `Primal` — shares the `O(NP²)` Gram `X̃ᵀX̃`; each λ pays the `P³/3`
///   factor and the hat GEMM.
/// * `Dual` — shares the `O(N²P)` centered Gram `K_c`; each λ pays an
///   `N³/3` Cholesky and an `N³` solve.
/// * `Spectral` — shares the eigendecomposition of `K_c`; each λ pays one
///   `N³` GEMM and nothing else. The per-candidate winner for P ≫ N.
pub enum GramCache {
    /// `X̃` and the unridged primal Gram `G₀ = X̃ᵀX̃`.
    Primal { xa: Mat, g0: Mat },
    /// `X̃` and the centered dual Gram `K_c = X_c X_cᵀ`.
    Dual { xa: Mat, kc: Mat },
    /// Eigendecomposition of `K_c`.
    Spectral(SpectralGram),
    /// Out-of-core primal ([`TilePolicy::Spill`]): `G₀` lives as
    /// [`PanelStore`] panels; each λ streams a left-looking factor through
    /// [`crate::linalg::spill::chol_spill_ridged`] (ridge folded onto the
    /// diagonal at panel load — no intermediate ridged store), so the
    /// `(P+1)×(P+1)` quadrant never coexists in RAM. Hats are bitwise what
    /// the in-RAM primal arm produces (on its Cholesky path — out of core
    /// there is no LU fallback for singular unridged grams).
    PrimalSpill {
        /// Augmented design `X̃` (`O(NP)` — the streamed working set).
        xa: Mat,
        /// `G₀ = X̃ᵀX̃` as `tile×(P+1)` panels, values bitwise equal to
        /// [`crate::linalg::syrk_t`]'s.
        g0: PanelStore,
        /// Spill directory for the per-λ factor stores (`None` = RAM
        /// panels).
        spill_dir: Option<PathBuf>,
    },
    /// Out-of-core dual ([`TilePolicy::Spill`]): `K_c` lives as
    /// [`PanelStore`] panels; each λ streams a left-looking factor through
    /// [`crate::linalg::spill::chol_spill_ridged`]. Beyond the `N×N` hat
    /// output itself, nothing square is resident.
    DualSpill {
        /// Augmented design `X̃`.
        xa: Mat,
        /// Centered `K_c = X_c X_cᵀ` as `tile×N` panels, values bitwise
        /// equal to the one-shot centered Gram.
        kc: PanelStore,
        /// Spill directory for the per-λ factor stores.
        spill_dir: Option<PathBuf>,
    },
}

impl GramCache {
    /// Precompute the λ-free state for `backend`. The λ-free GEMMs — the
    /// dual/spectral `K_c` build *and* the primal `G₀ = X̃ᵀX̃` `syrk`
    /// ([`crate::linalg::syrk_t_pool`]) — fan out over `pool` when given;
    /// pooled and serial builds are bit-identical.
    ///
    /// `Auto` here *assumes a multi-candidate grid*: it resolves as
    /// `resolve_for_grid(n, p, 2)` — `Spectral` when `P > N`, else
    /// `Primal` — because a cache exists to serve many λ. A caller that
    /// knows its actual grid (or wants a single hat) should pre-resolve
    /// with [`GramBackend::resolve_for_grid`] / [`GramBackend::resolve`]
    /// and pass the result, as [`crate::fastcv::lambda_search`] and
    /// [`HatMatrix::build_with`] do — on a wide shape with ≤ 1 positive
    /// candidate, a blind `Auto` pays an eigendecomposition that `Dual`
    /// would have skipped.
    ///
    /// ```
    /// use fastcv::fastcv::hat::{GramBackend, GramCache};
    /// use fastcv::linalg::Mat;
    /// use fastcv::util::rng::Rng;
    ///
    /// // Wide data (P ≫ N): one spectral decomposition serves the grid.
    /// let mut rng = Rng::new(7);
    /// let x = Mat::from_fn(12, 40, |_, _| rng.gauss());
    /// let cache = GramCache::build(&x, GramBackend::Spectral, None);
    /// for lambda in [0.1, 1.0, 10.0] {
    ///     let hat = cache.hat(lambda).unwrap();   // O(N³) GEMM, no refactorisation
    ///     assert_eq!(hat.h.rows(), 12);
    /// }
    /// ```
    pub fn build(x: &Mat, backend: GramBackend, pool: Option<&ThreadPool>) -> GramCache {
        Self::build_tiled(x, backend, pool, TilePolicy::Off)
            // lint:allow(panic, reason = "TilePolicy::Off cannot spill, and the non-tiled build has no fallible step")
            .expect("TilePolicy::Off builds cannot fail")
    }

    /// [`GramCache::build`] under a [`TilePolicy`]: with tiling on, the
    /// dual/spectral `K_c` is assembled from `tile×P` centered slabs
    /// ([`crate::linalg::gram_tiled`]) instead of a full `O(NP)` centered
    /// copy plus its transpose, and the primal `G₀ = X̃ᵀX̃` goes through the
    /// banded [`crate::linalg::syrk_tiled`] — bit-identical output,
    /// tile-bounded transients. [`TilePolicy::Spill`] goes out of core:
    /// the primal/dual Gram lives as [`PanelStore`] panels (RAM or disk)
    /// and every per-λ factor streams through
    /// [`crate::linalg::spill::chol_spill_ridged`] — see
    /// [`GramCache::PrimalSpill`] / [`GramCache::DualSpill`]. [`TilePolicy::Off`] reproduces the
    /// one-shot build verbatim. Errors only on spill-store IO.
    pub fn build_tiled(
        x: &Mat,
        backend: GramBackend,
        pool: Option<&ThreadPool>,
        tile: TilePolicy,
    ) -> Result<GramCache> {
        // A blind Auto under an out-of-core policy must not build a
        // resident spectral cache — same rule as the ctx-level resolution.
        let backend = backend.resolve_for_grid_spill_aware(x.rows(), x.cols(), 2, &tile);
        Ok(match backend {
            GramBackend::Primal => {
                let xa = x.augment_ones();
                let p1 = xa.cols();
                if let Some((dir, t)) = tile.spill() {
                    let mut g0 = PanelStore::new(p1, t, dir)
                        .context("creating the primal spill store")?;
                    syrk_spill(&mut g0, &xa, pool)?;
                    GramCache::PrimalSpill { xa, g0, spill_dir: dir.map(Path::to_path_buf) }
                } else {
                    // Band height resolved against the (P+1)-dim output —
                    // the primal Gram has no N×N; its slab is a band row of
                    // width P+1.
                    let g0 = match tile.tile_rows(p1, p1) {
                        None => syrk_t_pool(&xa, pool),
                        Some(t) => syrk_tiled(&xa, t, pool),
                    };
                    GramCache::Primal { xa, g0 }
                }
            }
            GramBackend::Dual => {
                let xa = x.augment_ones();
                if let Some((dir, t)) = tile.spill() {
                    let mut kc = PanelStore::new(x.rows(), t, dir)
                        .context("creating the dual spill store")?;
                    let means = x.col_means();
                    let p = x.cols();
                    gram_spill(
                        &mut kc,
                        0.0,
                        |lo, hi| Mat::from_fn(hi - lo, p, |r, j| x[(lo + r, j)] - means[j]),
                        pool,
                    )?;
                    GramCache::DualSpill { xa, kc, spill_dir: dir.map(Path::to_path_buf) }
                } else {
                    let kc = match tile.tile_rows(x.rows(), x.cols()) {
                        None => centered_gram(x, pool),
                        Some(t) => centered_gram_tiled(x, t, pool),
                    };
                    GramCache::Dual { xa, kc }
                }
            }
            GramBackend::Spectral | GramBackend::Auto => {
                GramCache::Spectral(SpectralGram::build_tiled(x, pool, tile))
            }
        })
    }

    /// Number of samples behind the cached state.
    pub fn n(&self) -> usize {
        match self {
            GramCache::Primal { xa, .. }
            | GramCache::Dual { xa, .. }
            | GramCache::PrimalSpill { xa, .. }
            | GramCache::DualSpill { xa, .. } => xa.rows(),
            GramCache::Spectral(sg) => sg.n(),
        }
    }

    /// Approximate resident RAM in bytes (the
    /// [`crate::store::FactorStore`] budget currency): the `f64` payload of
    /// every dense member. Disk-backed spill panels count ~0 — that is
    /// exactly what demoting a dense cache into the spill layer buys;
    /// RAM-backed panels count their full square.
    pub fn resident_bytes(&self) -> usize {
        let mat_bytes = |m: &Mat| m.rows() * m.cols() * 8;
        let store_bytes =
            |s: &PanelStore| if s.is_disk() { 0 } else { s.n() * s.n() * 8 };
        match self {
            GramCache::Primal { xa, g0 } => mat_bytes(xa) + mat_bytes(g0),
            GramCache::Dual { xa, kc } => mat_bytes(xa) + mat_bytes(kc),
            GramCache::Spectral(sg) => sg.resident_bytes(),
            GramCache::PrimalSpill { xa, g0, .. } => mat_bytes(xa) + store_bytes(g0),
            GramCache::DualSpill { xa, kc, .. } => mat_bytes(xa) + store_bytes(kc),
        }
    }

    /// Checksum-verify the spill-backed panel store behind this cache —
    /// `Ok(())` for the resident variants (RAM cannot rot) and for RAM
    /// panel stores. On a disk store this re-reads every panel and checks
    /// its FNV footer ([`PanelStore::verify`]); the error chain carries
    /// the typed [`crate::linalg::SpillError`], which
    /// [`crate::store::FactorStore`] answers by evicting the artifact and
    /// rebuilding — degrade, never serve bad bytes.
    pub fn verify_spill(&self) -> Result<()> {
        match self {
            GramCache::PrimalSpill { g0, .. } => g0.verify(),
            GramCache::DualSpill { kc, .. } => kc.verify(),
            _ => Ok(()),
        }
    }

    /// Does this cache hold disk-resident panels (i.e. is it a candidate
    /// for the store's verify-on-hit sweep)?
    pub fn is_disk_spill(&self) -> bool {
        match self {
            GramCache::PrimalSpill { g0, .. } => g0.is_disk(),
            GramCache::DualSpill { kc, .. } => kc.is_disk(),
            _ => false,
        }
    }

    /// The hat matrix for one λ candidate against the cached state.
    pub fn hat(&self, lambda: f64) -> Result<HatMatrix> {
        self.hat_pool(lambda, None)
    }

    /// [`GramCache::hat`] with the per-candidate GEMMs (the primal
    /// `H = X̃·W` product, the spectral rescale product) fanned out over
    /// `pool`. Bit-identical to the serial [`GramCache::hat`] for any pool
    /// size ([`crate::linalg::matmul_pool`]'s contract).
    pub fn hat_pool(&self, lambda: f64, pool: Option<&ThreadPool>) -> Result<HatMatrix> {
        self.hat_pool_tiled(lambda, pool, TilePolicy::Off)
    }

    /// [`GramCache::hat_pool`] under a [`TilePolicy`]: the dual arm's per-λ
    /// `K_c + λI` Cholesky goes through the panel-blocked, pool-parallel
    /// [`Cholesky::factor_into`] — in place (no second `N×N`), with the
    /// panel updates fanned out over `pool`. Bit-identical to the serial
    /// factor for any tile/pool combination (the `tiled_*` contract).
    pub fn hat_pool_tiled(
        &self,
        lambda: f64,
        pool: Option<&ThreadPool>,
        tile: TilePolicy,
    ) -> Result<HatMatrix> {
        assert!(lambda >= 0.0, "ridge λ must be ≥ 0");
        match self {
            GramCache::Primal { xa, g0 } => {
                let mut g = g0.clone();
                let p1 = xa.cols();
                for i in 0..p1 - 1 {
                    // lint:allow(float_accum, reason = "ridge diagonal add: each entry touched exactly once — order-free")
                    g[(i, i)] += lambda;
                }
                hat_from_primal_gram(xa, &g, lambda, pool)
            }
            GramCache::Dual { xa, kc } => {
                if lambda <= 0.0 {
                    bail!("dual Gram backend requires ridge λ > 0 (K_c is always singular: K_c𝟙 = 0)");
                }
                let n = kc.rows();
                let mut kl = kc.clone();
                for i in 0..n {
                    // lint:allow(float_accum, reason = "ridge diagonal add: each entry touched exactly once — order-free")
                    kl[(i, i)] += lambda;
                }
                let panel = tile.tile_rows(n, n);
                let ch = if panel.is_none() && pool.is_none() {
                    Cholesky::factor(&kl)
                } else {
                    Cholesky::factor_into(kl, panel.unwrap_or(CHOL_PANEL), pool)
                }
                .context("centered dual Gram K_c + λI not SPD — is λ > 0?")?;
                // H = (1/N)𝟙𝟙ᵀ + (K_c + λI)⁻¹ K_c  (symmetric: both terms
                // are functions of K_c).
                let mut h = ch.solve_mat(kc);
                let inv_n = 1.0 / n as f64;
                for v in h.as_mut_slice() {
                    // lint:allow(float_accum, reason = "uniform centering offset: each entry touched exactly once — order-free")
                    *v += inv_n;
                }
                h.symmetrize();
                Ok(HatMatrix {
                    h,
                    xa: xa.clone(),
                    factor: GramFactor::OnDemand,
                    lambda,
                    backend: GramBackend::Dual,
                })
            }
            GramCache::Spectral(sg) => sg.hat_pool(lambda, pool),
            GramCache::PrimalSpill { xa, g0, spill_dir } => {
                // Left-looking spilled factor with the ridge folded onto
                // each panel's diagonal at load (intercept unpenalised,
                // like the in-RAM `g[(i,i)] += λ` loop — no intermediate
                // ridged store), streamed solve of `W = G⁻¹X̃ᵀ`, then the
                // same hat GEMM — bitwise the in-RAM primal Cholesky path.
                // Neutral context: the cause may be a non-SPD gram *or*
                // spill-store IO — the error chain carries the specifics.
                let ch = chol_spill_ridged(g0, lambda, true, spill_dir.as_deref(), pool)
                    .context(
                        "spilled primal-gram factor failed: gram not SPD (increase ridge λ — \
                         out of core there is no LU fallback) or spill-store IO (see cause)",
                    )?;
                let mut w = xa.t();
                ch.solve_mat_in_place(&mut w)?;
                let mut h = matmul_pool(xa, &w, pool);
                h.symmetrize();
                Ok(HatMatrix {
                    h,
                    xa: xa.clone(),
                    factor: GramFactor::OnDemand,
                    lambda,
                    backend: GramBackend::Primal,
                })
            }
            GramCache::DualSpill { xa, kc, spill_dir } => {
                if lambda <= 0.0 {
                    bail!("dual Gram backend requires ridge λ > 0 (K_c is always singular: K_c𝟙 = 0)");
                }
                let ch = chol_spill_ridged(kc, lambda, false, spill_dir.as_deref(), pool)
                    .context(
                        "spilled dual factor failed: K_c + λI not SPD (is λ > 0?) \
                         or spill-store IO (see cause)",
                    )?;
                // The RHS K_c becomes H in place — the one N×N that must
                // exist (it is the output); the factor streams past it.
                let mut h = kc.to_mat()?;
                ch.solve_mat_in_place(&mut h)?;
                let n = kc.n();
                let inv_n = 1.0 / n as f64;
                for v in h.as_mut_slice() {
                    // lint:allow(float_accum, reason = "uniform centering offset: each entry touched exactly once — order-free")
                    *v += inv_n;
                }
                h.symmetrize();
                Ok(HatMatrix {
                    h,
                    xa: xa.clone(),
                    factor: GramFactor::OnDemand,
                    lambda,
                    backend: GramBackend::Dual,
                })
            }
        }
    }
}

/// Centered data `X_c = (I − (1/N)𝟙𝟙ᵀ) X`.
fn centered(x: &Mat) -> Mat {
    let means = x.col_means();
    Mat::from_fn(x.rows(), x.cols(), |i, j| x[(i, j)] - means[j])
}

/// Centered `N×N` Gram `K_c = X_c X_cᵀ`, optionally pool-parallel.
fn centered_gram(x: &Mat, pool: Option<&ThreadPool>) -> Mat {
    let xc = centered(x);
    let mut kc = matmul_pool(&xc, &xc.t(), pool);
    kc.symmetrize();
    kc
}

/// [`centered_gram`] through the tiled engine: centered `tile×P` row slabs
/// are materialised on demand (never the full `X_c` copy or its `P×N`
/// transpose), the upper block triangle fans out over `pool`, and the
/// result is bit-identical to the one-shot build
/// ([`crate::linalg::gram_tiled`]'s contract — the per-slab centering
/// performs the exact subtraction the full `X_c` copy would).
pub(crate) fn centered_gram_tiled(x: &Mat, tile: usize, pool: Option<&ThreadPool>) -> Mat {
    let means = x.col_means();
    let p = x.cols();
    gram_tiled(
        x.rows(),
        tile,
        |lo, hi| Mat::from_fn(hi - lo, p, |r, j| x[(lo + r, j)] - means[j]),
        pool,
    )
}

/// One symmetric eigendecomposition of the centered Gram `K_c`, from which
/// the hat matrix of **every** ridge value follows by a diagonal rescale:
/// `H(λ) = (1/N)𝟙𝟙ᵀ + U diag(dᵢ/(dᵢ+λ)) Uᵀ`. This is what lets
/// [`crate::fastcv::lambda_search::search_lambda`] sweep a grid without a
/// fresh `O(P³)` factorisation per candidate.
#[derive(Clone, Debug)]
pub struct SpectralGram {
    /// Augmented design (carried into the produced [`HatMatrix`]).
    xa: Mat,
    /// Eigenvalues of `K_c`, descending, clamped at 0 (roundoff guard).
    values: Vec<f64>,
    /// Matching eigenvectors as columns.
    vectors: Mat,
}

impl SpectralGram {
    /// Center `x`, form `K_c` (pool-parallel when given) and
    /// eigendecompose it — the one-off `O(N²P + N³)` cost every λ shares.
    pub fn build(x: &Mat, pool: Option<&ThreadPool>) -> SpectralGram {
        Self::build_tiled(x, pool, TilePolicy::Off)
    }

    /// [`SpectralGram::build`] under a [`TilePolicy`]: the `K_c` assembly
    /// goes through the tile-bounded engine (bit-identical; see
    /// [`GramCache::build_tiled`]). The eigendecomposition itself is dense
    /// `N×N` either way — spectral reuse is for λ *grids*, where that
    /// one-off cost is the point. A [`TilePolicy::Spill`] therefore only
    /// tile-bounds the *assembly* here (the eigenvector matrix is an
    /// irreducible resident `N×N`); single-λ wide callers that must stay
    /// out of core should use the dual backend, whose
    /// [`GramCache::DualSpill`] arm never holds a resident square.
    pub fn build_tiled(x: &Mat, pool: Option<&ThreadPool>, tile: TilePolicy) -> SpectralGram {
        let xa = x.augment_ones();
        let kc = match tile.tile_rows(x.rows(), x.cols()) {
            None => centered_gram(x, pool),
            Some(t) => centered_gram_tiled(x, t, pool),
        };
        let SymEig { values, vectors } = sym_eig(&kc);
        // K_c is PSD; tiny negative eigenvalues are roundoff and would put
        // d/(d+λ) on the wrong side of 0 — clamp.
        let values = values.into_iter().map(|d| d.max(0.0)).collect();
        SpectralGram { xa, values, vectors }
    }

    /// Assemble from an already-computed eigendecomposition of a centered
    /// Gram. `xa` is the augmented design the produced hats will carry,
    /// `values`/`vectors` the eigenpairs of its centered `N×N` Gram
    /// (values are clamped at 0 here, as [`SpectralGram::build`] does).
    /// This is how [`SharedNestedGram`] turns a downdated full-data Gram
    /// into a per-fold spectral cache without touching `X` again.
    pub fn from_parts(xa: Mat, values: Vec<f64>, vectors: Mat) -> SpectralGram {
        assert_eq!(xa.rows(), vectors.rows(), "eigenvector rows must equal N");
        assert_eq!(values.len(), vectors.cols(), "one eigenvalue per eigenvector");
        let values = values.into_iter().map(|d| d.max(0.0)).collect();
        SpectralGram { xa, values, vectors }
    }

    /// Number of samples.
    pub fn n(&self) -> usize {
        self.xa.rows()
    }

    /// Approximate resident RAM in bytes (`X̃` + eigenpairs) — the
    /// [`crate::store::FactorStore`] budget currency. A spectral cache is
    /// always fully resident: its eigenvector matrix cannot spill.
    pub fn resident_bytes(&self) -> usize {
        (self.xa.rows() * self.xa.cols()
            + self.values.len()
            + self.vectors.rows() * self.vectors.cols())
            * 8
    }

    /// The hat matrix for one ridge value: `O(N³)` GEMM, no factorisation.
    pub fn hat(&self, lambda: f64) -> Result<HatMatrix> {
        self.hat_pool(lambda, None)
    }

    /// [`SpectralGram::hat`] with the rescale GEMM fanned out over `pool`
    /// (bit-identical to serial for any pool size).
    pub fn hat_pool(&self, lambda: f64, pool: Option<&ThreadPool>) -> Result<HatMatrix> {
        if lambda <= 0.0 {
            bail!("spectral Gram backend requires ridge λ > 0 (K_c is always singular: K_c𝟙 = 0)");
        }
        let n = self.n();
        let scaled = Mat::from_fn(n, n, |i, j| {
            self.vectors[(i, j)] * (self.values[j] / (self.values[j] + lambda))
        });
        let mut h = matmul_pool(&scaled, &self.vectors.t(), pool);
        let inv_n = 1.0 / n as f64;
        for v in h.as_mut_slice() {
            // lint:allow(float_accum, reason = "uniform centering offset: each entry touched exactly once — order-free")
            *v += inv_n;
        }
        h.symmetrize();
        Ok(HatMatrix {
            h,
            xa: self.xa.clone(),
            factor: GramFactor::OnDemand,
            lambda,
            backend: GramBackend::Spectral,
        })
    }
}

/// One full-data **uncentered** Gram `K = XXᵀ` shared across the outer
/// folds of a nested CV (the Gram-level analogue of the paper's Eq. 9–12
/// downdates: instead of rebuilding each training set's Gram from `X` —
/// `O(N_tr²P)` per fold — the training block is *downdated* out of the one
/// `O(N²P)` full Gram by index selection, then re-centered in `O(N_tr²)`).
///
/// The identity: with `C = I − (1/m)𝟙𝟙ᵀ` the centering projector on the
/// `m = |Tr|` training rows,
///
/// ```text
/// K_c^{Tr} = X_c^{Tr} (X_c^{Tr})ᵀ = C K[Tr,Tr] C
///          = K_ij − rowmean_i − rowmean_j + grandmean   (double-centering)
/// ```
///
/// so each outer fold's centered training Gram — and from it the
/// [`SpectralGram`] that serves the whole inner λ grid — follows from the
/// shared `K` without touching the `P`-dimensional data again. Feature
/// work is paid **once** for the entire nested CV instead of once per
/// outer fold.
///
/// The downdated Gram equals the rebuilt one in exact arithmetic but not
/// bitwise (different accumulation order), so this path is opt-in — see
/// [`crate::fastcv::context::ComputeContext::with_nested_sharing`] and
/// [`crate::fastcv::lambda_search::nested_cv_ctx`]. Agreement is
/// property-tested at tolerance.
pub struct SharedNestedGram {
    /// `K = XXᵀ`, `N×N`, symmetric — dense, or spilled to
    /// [`PanelStore`] panels under a [`TilePolicy::Spill`] (the shared
    /// Gram is long-lived across all outer folds, so spilling it is a real
    /// `8N²`-byte saving; each fold gathers only its `N_tr²` selection).
    k: NestedGramStorage,
}

/// Dense-or-spilled storage for the shared nested-CV Gram.
enum NestedGramStorage {
    Dense(Mat),
    Spilled(PanelStore),
}

impl SharedNestedGram {
    /// One `O(N²P)` Gram build (pool-parallel when given) for the whole
    /// nested CV.
    pub fn build(x: &Mat, pool: Option<&ThreadPool>) -> SharedNestedGram {
        Self::build_tiled(x, pool, TilePolicy::Off)
            // lint:allow(panic, reason = "TilePolicy::Off cannot spill, and the non-tiled build has no fallible step")
            .expect("TilePolicy::Off builds cannot fail")
    }

    /// [`SharedNestedGram::build`] under a [`TilePolicy`]: the full `XXᵀ`
    /// is assembled from raw `tile×P` row slabs — no `P×N` transpose copy —
    /// bit-identical to the one-shot build (the tiled engine's contract).
    /// Under [`TilePolicy::Spill`] the assembled panels stay in the
    /// [`PanelStore`] (disk when a dir is given); per-fold selections
    /// gather from the panels ([`PanelStore::take_square`], a pure
    /// gather, bitwise). Errors only on spill-store IO.
    pub fn build_tiled(
        x: &Mat,
        pool: Option<&ThreadPool>,
        tile: TilePolicy,
    ) -> Result<SharedNestedGram> {
        let p = x.cols();
        let raw_slab = |lo: usize, hi: usize| Mat::from_fn(hi - lo, p, |r, j| x[(lo + r, j)]);
        let k = if let Some((dir, t)) = tile.spill() {
            let mut store = PanelStore::new(x.rows(), t, dir)
                .context("creating the nested-CV spill store")?;
            gram_spill(&mut store, 0.0, raw_slab, pool)?;
            NestedGramStorage::Spilled(store)
        } else {
            NestedGramStorage::Dense(match tile.tile_rows(x.rows(), x.cols()) {
                None => {
                    let mut k = matmul_pool(x, &x.t(), pool);
                    k.symmetrize();
                    k
                }
                Some(t) => gram_tiled(x.rows(), t, raw_slab, pool),
            })
        };
        Ok(SharedNestedGram { k })
    }

    /// Number of samples in the full dataset.
    pub fn n(&self) -> usize {
        match &self.k {
            NestedGramStorage::Dense(k) => k.rows(),
            NestedGramStorage::Spilled(store) => store.n(),
        }
    }

    /// Approximate resident RAM in bytes — the
    /// [`crate::store::FactorStore`] budget currency. Disk-spilled storage
    /// counts ~0, dense storage its full `N×N`.
    pub fn resident_bytes(&self) -> usize {
        match &self.k {
            NestedGramStorage::Dense(k) => k.rows() * k.cols() * 8,
            NestedGramStorage::Spilled(store) => {
                if store.is_disk() {
                    0
                } else {
                    store.n() * store.n() * 8
                }
            }
        }
    }

    /// Gather the shared Gram into a dense matrix (tests / callers that
    /// decide it fits after all). A no-copy borrow is impossible for the
    /// spilled form, so this always allocates.
    pub fn to_dense(&self) -> Result<Mat> {
        match &self.k {
            NestedGramStorage::Dense(k) => Ok(k.clone()),
            NestedGramStorage::Spilled(store) => store.to_mat(),
        }
    }

    /// One outer fold's centered training Gram `K_c^{Tr}` by the Eq. 9–12
    /// style downdate: select `K[Tr,Tr]`, double-center in `O(N_tr²)` — no
    /// `O(N_tr²P)` feature-side rebuild. Errors only on spill-store IO.
    fn fold_gram(&self, tr: &[usize]) -> Result<Mat> {
        let m = tr.len();
        let kt = match &self.k {
            NestedGramStorage::Dense(k) => k.take(tr, tr),
            NestedGramStorage::Spilled(store) => store.take_square(tr)?,
        };
        // lint:allow(float_accum, reason = "serial double-centering row means in canonical order; identical on every backend by construction")
        let row_means: Vec<f64> = (0..m).map(|i| kt.row(i).iter().sum::<f64>() / m as f64).collect();
        // lint:allow(float_accum, reason = "serial double-centering grand mean in canonical order; identical on every backend by construction")
        let grand = row_means.iter().sum::<f64>() / m as f64;
        Ok(Mat::from_fn(m, m, |i, j| kt[(i, j)] - row_means[i] - row_means[j] + grand))
    }

    /// The spectral cache for one outer fold's training set: select
    /// `K[Tr,Tr]`, double-center it, eigendecompose. `x_tr` must be the
    /// matching training rows of the data (only used to carry the augmented
    /// design into the produced hats — no `O(N_tr²P)` Gram rebuild).
    /// Errors only on spill-store IO.
    pub fn fold_spectral(&self, x_tr: &Mat, tr: &[usize]) -> Result<SpectralGram> {
        assert_eq!(x_tr.rows(), tr.len(), "x_tr rows must match the training index set");
        let kc = self.fold_gram(tr)?;
        let SymEig { values, vectors } = sym_eig(&kc);
        Ok(SpectralGram::from_parts(x_tr.augment_ones(), values, vectors))
    }

    /// The **dual** cache for one outer fold's training set — the
    /// single-positive-λ sibling of [`SharedNestedGram::fold_spectral`]:
    /// the same downdated `K_c^{Tr}`, but served as a [`GramCache::Dual`]
    /// so the fold pays one Cholesky instead of an eigendecomposition.
    /// This is what lets [`crate::fastcv::lambda_search::nested_cv_ctx`]
    /// share the full-data Gram on wide shapes whose grid has exactly one
    /// positive candidate (where [`GramBackend::resolve_for_grid`] picks
    /// `Dual`, not `Spectral`). Errors only on spill-store IO.
    pub fn fold_dual(&self, x_tr: &Mat, tr: &[usize]) -> Result<GramCache> {
        assert_eq!(x_tr.rows(), tr.len(), "x_tr rows must match the training index set");
        Ok(GramCache::Dual { xa: x_tr.augment_ones(), kc: self.fold_gram(tr)? })
    }
}

/// Primal construction from an already-ridged Gram `G = X̃ᵀX̃ + λI₀`:
/// factor, multi-RHS solve, hat GEMM (pool-parallel when `pool` is given —
/// bit-identical to serial). Shared by [`HatMatrix::build`] and
/// [`GramCache::hat`] so the two are bit-identical.
fn hat_from_primal_gram(
    xa: &Mat,
    g: &Mat,
    lambda: f64,
    pool: Option<&ThreadPool>,
) -> Result<HatMatrix> {
    // Cholesky (G is SPD whenever invertible here); LU fallback gives a
    // clean error message for singular unridged fits.
    let (factor, w) = match Cholesky::factor(g) {
        Ok(ch) => {
            let w = ch.solve_mat(&xa.t()); // W = G⁻¹X̃ᵀ, (P+1)×N
            (GramFactor::Chol(ch), w)
        }
        Err(_) => {
            let lu = Lu::factor(g)
                .context("gram matrix singular — increase ridge λ (P ≥ N with λ=0?)")?;
            let w = lu.solve_mat(&xa.t());
            (GramFactor::Lu(lu), w)
        }
    };
    // H = X̃ W.
    let mut h = matmul_pool(xa, &w, pool);
    h.symmetrize(); // exact-math symmetric; tidy roundoff
    Ok(HatMatrix { h, xa: xa.clone(), factor, lambda, backend: GramBackend::Primal })
}

impl HatMatrix {
    /// Build from an already-augmented design `xa = [X, 1]` and an
    /// externally maintained Cholesky factor of its ridged Gram
    /// `G̃ = X̃ᵀX̃ + λI₀` — the seam the incremental engine
    /// ([`crate::fastcv::incremental`]) uses: after a rank-1 up/downdate
    /// it already holds the current factor, so rebuilding via
    /// [`HatMatrix::build`] would redo the `O(P³)` factorisation this
    /// constructor skips. The solve + hat GEMM are the exact code path of
    /// the primal builder, so given a bitwise-equal factor the result is
    /// bitwise equal to a from-scratch build.
    pub(crate) fn from_primal_factor(
        xa: &Mat,
        ch: Cholesky,
        lambda: f64,
        pool: Option<&ThreadPool>,
    ) -> HatMatrix {
        assert_eq!(ch.n(), xa.cols(), "factor dimension must match augmented design");
        let w = ch.solve_mat(&xa.t()); // W = G⁻¹X̃ᵀ, (P+1)×N
        let mut h = matmul_pool(xa, &w, pool);
        h.symmetrize();
        HatMatrix {
            h,
            xa: xa.clone(),
            factor: GramFactor::Chol(ch),
            lambda,
            backend: GramBackend::Primal,
        }
    }

    /// Build from raw data `x` (N×P) with ridge λ (λ=0 allowed when the
    /// gram matrix is non-singular, i.e. typically N > P). Always the
    /// primal construction — the historical entry point, kept bit-stable;
    /// use [`HatMatrix::build_with`] to pick a backend.
    ///
    /// Perf note (EXPERIMENTS.md §Perf L3 #4): `H = X̃ G⁻¹ X̃ᵀ` is computed
    /// as `X̃ · solve(G, X̃ᵀ)` — a factorisation (`P³/3`) plus an `O(P²N)`
    /// multi-RHS solve — rather than materialising `G⁻¹` (`≈P³` extra).
    pub fn build(x: &Mat, lambda: f64) -> Result<HatMatrix> {
        Self::build_with(x, lambda, GramBackend::Primal, None)
    }

    /// Build through a chosen [`GramBackend`] (`Auto` resolves by the P/N
    /// ratio). All backends produce the same `H` up to roundoff (≲1e-10 on
    /// well-conditioned problems); the dual/spectral paths additionally fan
    /// the `K_c` GEMM over `pool` when one is given.
    pub fn build_with(
        x: &Mat,
        lambda: f64,
        backend: GramBackend,
        pool: Option<&ThreadPool>,
    ) -> Result<HatMatrix> {
        assert!(lambda >= 0.0, "ridge λ must be ≥ 0");
        let resolved = backend.resolve(x.rows(), x.cols(), lambda);
        GramCache::build(x, resolved, pool).hat_pool(lambda, pool)
    }

    /// Build under a full [`ComputeContext`]: backend policy, pool fan-out,
    /// **and** the context's [`TilePolicy`] — with tiling on, the dual
    /// `K_c` assembly and its Cholesky stay tile-bounded/in-place
    /// ([`GramCache::build_tiled`], [`GramCache::hat_pool_tiled`]).
    /// Bit-identical to [`HatMatrix::build_with`] for any context (the
    /// pool and tile knobs never move a float). When the context lends a
    /// [`crate::store::FactorStore`], the λ-free [`GramCache`] is fetched
    /// through it ([`crate::store::gram_for_ctx`]) — this one seam puts
    /// every `fit_ctx` front-end, and through them all four permutation
    /// engines, on the shared cache; a hit serves the same floats a fresh
    /// build would (the store's bitwise contract).
    pub fn build_ctx(x: &Mat, lambda: f64, ctx: &ComputeContext<'_>) -> Result<HatMatrix> {
        assert!(lambda >= 0.0, "ridge λ must be ≥ 0");
        let resolved = ctx.backend().resolve(x.rows(), x.cols(), lambda);
        crate::store::gram_for_ctx(x, resolved, ctx)?
            .hat_pool_tiled(lambda, ctx.pool(), ctx.tile_policy())
    }

    /// Explicit inverse gram `S = (X̃ᵀX̃ + λI₀)⁻¹` — off the hot path; used
    /// by the Woodbury derivation utilities and tests. Dual/spectral-built
    /// hats factor the primal Gram on demand here (they never needed it).
    pub fn inv_gram(&self) -> Mat {
        match &self.factor {
            GramFactor::Chol(ch) => ch.inverse(),
            GramFactor::Lu(lu) => lu.inverse(),
            GramFactor::OnDemand => match self.primal_factor() {
                GramFactor::Chol(ch) => ch.inverse(),
                GramFactor::Lu(lu) => lu.inverse(),
                // lint:allow(panic, reason = "primal_factor() factors eagerly and never returns OnDemand")
                GramFactor::OnDemand => unreachable!(),
            },
        }
    }

    /// Solve `G z = b` against the stored (or on-demand) factorisation.
    pub fn solve_gram(&self, b: &Mat) -> Mat {
        match &self.factor {
            GramFactor::Chol(ch) => ch.solve_mat(b),
            GramFactor::Lu(lu) => lu.solve_mat(b),
            GramFactor::OnDemand => match self.primal_factor() {
                GramFactor::Chol(ch) => ch.solve_mat(b),
                GramFactor::Lu(lu) => lu.solve_mat(b),
                // lint:allow(panic, reason = "primal_factor() factors eagerly and never returns OnDemand")
                GramFactor::OnDemand => unreachable!(),
            },
        }
    }

    /// Factor the primal Gram from the stored `xa` (hats whose builder
    /// kept no factor: dual/spectral, and the spilled primal/dual arms —
    /// for those this **re-materialises the dense `(P+1)²` Gram** the
    /// spill policy avoided, so keep [`HatMatrix::inv_gram`] /
    /// [`HatMatrix::solve_gram`] off out-of-core hot paths). With λ > 0
    /// the Gram is SPD, so this cannot fail for a well-formed hat.
    fn primal_factor(&self) -> GramFactor {
        let g = gram_ridged(&self.xa, self.lambda);
        match Cholesky::factor(&g) {
            Ok(ch) => GramFactor::Chol(ch),
            Err(_) => GramFactor::Lu(
                // lint:allow(panic, reason = "LU fallback after Cholesky; the gram is nonsingular for λ > 0 and the λ = 0 case is named in the message")
                Lu::factor(&g).expect("primal gram singular — dual/spectral hat with λ = 0?"),
            ),
        }
    }

    /// Number of samples.
    pub fn n(&self) -> usize {
        self.h.rows()
    }

    /// Full-data fitted values `ŷ = H y` for a response/label vector.
    ///
    /// Computed in GEMM accumulation order ([`matvec_gemm_order`]) so the
    /// result is bit-identical to one column of [`Self::fit_response_mat`]
    /// — the serial and batched permutation engines rely on that equality.
    pub fn fit_response(&self, y: &[f64]) -> Vec<f64> {
        matvec_gemm_order(&self.h, y)
    }

    /// Full-data fits for a response *matrix* (multi-class `Ŷ = H Y`).
    pub fn fit_response_mat(&self, y: &Mat) -> Mat {
        matmul(&self.h, y)
    }

    /// The fold-local block `H_Te` (rows & cols at `te`).
    pub fn block(&self, te: &[usize]) -> Mat {
        self.h.take(te, te)
    }

    /// The cross block `H_{Tr,Te}` (rows `tr`, cols `te`) used by the bias
    /// adjustment (Eq. 15).
    pub fn cross_block(&self, tr: &[usize], te: &[usize]) -> Mat {
        self.h.take(tr, te)
    }

    /// `I − H_Te` for a fold.
    pub fn i_minus_block(&self, te: &[usize]) -> Mat {
        let mut m = self.block(te);
        m.scale(-1.0);
        for i in 0..te.len() {
            m[(i, i)] += 1.0;
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_all_close, Cases};
    use crate::util::rng::Rng;

    fn random_x(rng: &mut Rng, n: usize, p: usize) -> Mat {
        Mat::from_fn(n, p, |_, _| rng.gauss())
    }

    #[test]
    fn symmetric_and_idempotent_unridged() {
        let mut rng = Rng::new(1);
        let x = random_x(&mut rng, 20, 6);
        let hat = HatMatrix::build(&x, 0.0).unwrap();
        // symmetry
        assert!(hat.h.max_abs_diff(&hat.h.t()) < 1e-10);
        // idempotent: H² = H (projection) when λ=0
        let hh = matmul(&hat.h, &hat.h);
        assert!(hh.max_abs_diff(&hat.h) < 1e-8);
        // trace H = rank X̃ = P+1
        assert!((hat.h.trace() - 7.0).abs() < 1e-8);
    }

    #[test]
    fn ridge_contracts_hat() {
        let mut rng = Rng::new(2);
        let x = random_x(&mut rng, 15, 5);
        let h0 = HatMatrix::build(&x, 0.0).unwrap();
        let h1 = HatMatrix::build(&x, 10.0).unwrap();
        // Ridge shrinks the projection: trace decreases.
        assert!(h1.h.trace() < h0.h.trace());
        // Ones direction unpenalised (I₀): H·1 = 1 in both.
        let ones = vec![1.0; 15];
        assert_all_close(&h0.fit_response(&ones), &ones, 1e-8, "H·1 λ=0");
        assert_all_close(&h1.fit_response(&ones), &ones, 1e-8, "H·1 λ>0");
    }

    #[test]
    fn hy_matches_regression_fit() {
        // ŷ = Hy equals the prediction of the ridge regression fit.
        Cases::new(20).run("hat-vs-regression", |rng| {
            let n = 10 + rng.below(25);
            let p = 1 + rng.below(8);
            let x = random_x(rng, n, p);
            let y: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
            let lambda = crate::util::prop::ridge(rng, p < n);
            let hat = HatMatrix::build(&x, lambda).unwrap();
            let fit = crate::model::linreg::LinReg::fit(&x, &y, lambda).unwrap();
            assert_all_close(&hat.fit_response(&y), &fit.predict(&x), 1e-6, "Hy vs X̃β̂");
        });
    }

    #[test]
    fn wide_data_requires_ridge() {
        let mut rng = Rng::new(3);
        let x = random_x(&mut rng, 8, 20);
        assert!(HatMatrix::build(&x, 0.0).is_err());
        assert!(HatMatrix::build(&x, 0.5).is_ok());
    }

    #[test]
    fn blocks_agree_with_take() {
        let mut rng = Rng::new(4);
        let x = random_x(&mut rng, 12, 4);
        let hat = HatMatrix::build(&x, 0.1).unwrap();
        let te = [2usize, 5, 9];
        let tr = [0usize, 1, 3, 4, 6, 7, 8, 10, 11];
        let b = hat.block(&te);
        assert_eq!(b.shape(), (3, 3));
        assert_eq!(b[(0, 1)], hat.h[(2, 5)]);
        let cb = hat.cross_block(&tr, &te);
        assert_eq!(cb.shape(), (9, 3));
        assert_eq!(cb[(0, 2)], hat.h[(0, 9)]);
        let imb = hat.i_minus_block(&te);
        assert!((imb[(0, 0)] - (1.0 - hat.h[(2, 2)])).abs() < 1e-15);
        assert!((imb[(0, 1)] + hat.h[(2, 5)]).abs() < 1e-15);
    }

    #[test]
    fn backend_tags_roundtrip_and_auto_resolves_by_shape() {
        for b in [GramBackend::Auto, GramBackend::Primal, GramBackend::Dual, GramBackend::Spectral]
        {
            assert_eq!(GramBackend::from_tag(b.tag()), Some(b));
        }
        assert_eq!(GramBackend::from_tag("nope"), None);
        // single hat: dual only for wide + ridged
        assert_eq!(GramBackend::Auto.resolve(100, 20, 1.0), GramBackend::Primal);
        assert_eq!(GramBackend::Auto.resolve(20, 100, 1.0), GramBackend::Dual);
        assert_eq!(GramBackend::Auto.resolve(20, 100, 0.0), GramBackend::Primal);
        // grid: wide + several positive candidates → spectral
        assert_eq!(GramBackend::Auto.resolve_for_grid(20, 100, 4), GramBackend::Spectral);
        assert_eq!(GramBackend::Auto.resolve_for_grid(20, 100, 1), GramBackend::Dual);
        assert_eq!(GramBackend::Auto.resolve_for_grid(100, 20, 4), GramBackend::Primal);
        // explicit choices pass through untouched
        assert_eq!(GramBackend::Dual.resolve(100, 20, 1.0), GramBackend::Dual);
        assert_eq!(GramBackend::Primal.resolve_for_grid(20, 100, 4), GramBackend::Primal);
    }

    #[test]
    fn backend_equivalence_dual_matches_primal_hat() {
        // Wide (P ≫ N) and tall (N ≫ P) shapes, several ridge values: the
        // dual construction must reproduce the primal H to ~1e-8.
        let mut rng = Rng::new(21);
        for &(n, p) in &[(12usize, 40usize), (40, 8), (25, 25), (20, 120)] {
            let x = random_x(&mut rng, n, p);
            for lambda in [0.05, 1.0, 50.0] {
                let primal =
                    HatMatrix::build_with(&x, lambda, GramBackend::Primal, None).unwrap();
                let dual = HatMatrix::build_with(&x, lambda, GramBackend::Dual, None).unwrap();
                assert_eq!(dual.backend, GramBackend::Dual);
                assert!(
                    primal.h.max_abs_diff(&dual.h) < 1e-8,
                    "n={n} p={p} λ={lambda}: |ΔH| = {}",
                    primal.h.max_abs_diff(&dual.h)
                );
                // unpenalised intercept: H·1 = 1 in the dual too
                let ones = vec![1.0; n];
                assert_all_close(&dual.fit_response(&ones), &ones, 1e-8, "dual H·1");
            }
        }
    }

    #[test]
    fn backend_equivalence_spectral_matches_primal_across_grid() {
        // One SpectralGram serves the whole grid; every λ must agree with a
        // from-scratch primal build.
        let mut rng = Rng::new(22);
        for &(n, p) in &[(15usize, 60usize), (35, 10)] {
            let x = random_x(&mut rng, n, p);
            let sg = SpectralGram::build(&x, None);
            assert_eq!(sg.n(), n);
            for lambda in [0.05, 0.7, 4.0, 200.0] {
                let primal =
                    HatMatrix::build_with(&x, lambda, GramBackend::Primal, None).unwrap();
                let spectral = sg.hat(lambda).unwrap();
                assert_eq!(spectral.backend, GramBackend::Spectral);
                assert!(
                    primal.h.max_abs_diff(&spectral.h) < 1e-8,
                    "n={n} p={p} λ={lambda}: |ΔH| = {}",
                    primal.h.max_abs_diff(&spectral.h)
                );
            }
        }
    }

    #[test]
    fn backend_equivalence_gram_cache_primal_bitwise_matches_build() {
        // The λ-grid cache's primal arm shares G₀ but must reproduce
        // HatMatrix::build exactly (same floats, same factor path).
        let mut rng = Rng::new(23);
        let x = random_x(&mut rng, 30, 12);
        let cache = GramCache::build(&x, GramBackend::Primal, None);
        for lambda in [0.0, 0.3, 10.0] {
            let from_cache = cache.hat(lambda).unwrap();
            let direct = HatMatrix::build(&x, lambda).unwrap();
            assert_eq!(from_cache.h.as_slice(), direct.h.as_slice(), "λ={lambda}");
        }
    }

    #[test]
    fn backend_dual_and_spectral_require_positive_lambda() {
        let mut rng = Rng::new(24);
        let x = random_x(&mut rng, 10, 30);
        assert!(HatMatrix::build_with(&x, 0.0, GramBackend::Dual, None).is_err());
        assert!(SpectralGram::build(&x, None).hat(0.0).is_err());
        // Auto falls back to primal at λ=0, which errors on wide data with
        // the usual singular-gram message rather than panicking.
        assert!(HatMatrix::build_with(&x, 0.0, GramBackend::Auto, None).is_err());
        // …and on tall data λ=0 stays valid through Auto.
        let x_tall = random_x(&mut rng, 30, 5);
        let hat = HatMatrix::build_with(&x_tall, 0.0, GramBackend::Auto, None).unwrap();
        assert_eq!(hat.backend, GramBackend::Primal);
    }

    #[test]
    fn backend_pooled_kc_build_is_bitwise_deterministic() {
        // matmul_pool must not perturb K_c: dual hats built with and
        // without a pool are identical to the last bit.
        let mut rng = Rng::new(25);
        let x = random_x(&mut rng, 40, 150);
        let pool = crate::util::threadpool::ThreadPool::new(4);
        let serial = HatMatrix::build_with(&x, 0.8, GramBackend::Dual, None).unwrap();
        let pooled = HatMatrix::build_with(&x, 0.8, GramBackend::Dual, Some(&pool)).unwrap();
        assert_eq!(serial.h.as_slice(), pooled.h.as_slice());
    }

    #[test]
    fn backend_pool_primal_and_spectral_hats_bitwise_match_serial() {
        // The ctx plumbing's contract: a pool changes wall-clock only. The
        // pooled primal gram (syrk_t_pool), the pooled primal hat GEMM, and
        // the pooled spectral rescale GEMM must all reproduce the serial
        // floats exactly.
        let mut rng = Rng::new(27);
        let pool = crate::util::threadpool::ThreadPool::new(4);
        // tall: primal arm (syrk + hat GEMM)
        let x_tall = random_x(&mut rng, 60, 25);
        let serial = GramCache::build(&x_tall, GramBackend::Primal, None);
        let pooled = GramCache::build(&x_tall, GramBackend::Primal, Some(&pool));
        for lambda in [0.0, 0.4, 20.0] {
            let hs = serial.hat(lambda).unwrap();
            let hp = pooled.hat_pool(lambda, Some(&pool)).unwrap();
            assert_eq!(hs.h.as_slice(), hp.h.as_slice(), "primal λ={lambda}");
            // and the direct build_with entry point with a pool
            let direct = HatMatrix::build_with(&x_tall, lambda, GramBackend::Primal, Some(&pool))
                .unwrap();
            assert_eq!(hs.h.as_slice(), direct.h.as_slice(), "build_with λ={lambda}");
        }
        // wide: spectral arm (K_c GEMM + rescale GEMM)
        let x_wide = random_x(&mut rng, 30, 120);
        let sg_serial = SpectralGram::build(&x_wide, None);
        let sg_pooled = SpectralGram::build(&x_wide, Some(&pool));
        for lambda in [0.3, 5.0] {
            let hs = sg_serial.hat(lambda).unwrap();
            let hp = sg_pooled.hat_pool(lambda, Some(&pool)).unwrap();
            assert_eq!(hs.h.as_slice(), hp.h.as_slice(), "spectral λ={lambda}");
        }
    }

    #[test]
    fn backend_shared_nested_gram_matches_direct_spectral() {
        // The Eq. 9–12-style downdate: selecting + double-centering the full
        // XXᵀ must reproduce the per-fold centered Gram's hats to roundoff.
        let mut rng = Rng::new(28);
        let n = 30;
        let x = random_x(&mut rng, n, 90);
        let shared = SharedNestedGram::build(&x, None);
        assert_eq!(shared.n(), n);
        let te: Vec<usize> = (0..n).filter(|i| i % 4 == 1).collect();
        let tr = crate::fastcv::complement(&te, n);
        let x_tr = x.take_rows(&tr);
        let sg_down = shared.fold_spectral(&x_tr, &tr).unwrap();
        assert_eq!(sg_down.n(), tr.len());
        let direct = SpectralGram::build(&x_tr, None);
        for lambda in [0.2, 1.0, 30.0] {
            let h_down = sg_down.hat(lambda).unwrap().h;
            let h_direct = direct.hat(lambda).unwrap().h;
            let scale = h_direct.max_abs().max(1.0);
            assert!(
                h_down.max_abs_diff(&h_direct) < 1e-8 * scale,
                "λ={lambda}: |ΔH| = {}",
                h_down.max_abs_diff(&h_direct)
            );
            // the primal reference too
            let h_primal =
                HatMatrix::build_with(&x_tr, lambda, GramBackend::Primal, None).unwrap().h;
            assert!(
                h_down.max_abs_diff(&h_primal) < 1e-7 * scale,
                "λ={lambda} vs primal: |ΔH| = {}",
                h_down.max_abs_diff(&h_primal)
            );
        }
    }

    #[test]
    fn tiled_gram_cache_bitwise_matches_untiled_across_tile_sizes() {
        // Acceptance: the tiled K_c build reproduces the one-shot build to
        // the last bit across tile heights {1, 7, N, N+3} (remainder panel
        // included), serial and pooled — and the hats that follow are
        // bitwise equal too.
        let mut rng = Rng::new(61);
        let pool = crate::util::threadpool::ThreadPool::new(4);
        let n = 26;
        let x = random_x(&mut rng, n, 90);
        let reference = GramCache::build(&x, GramBackend::Dual, None);
        let GramCache::Dual { kc: kc_ref, .. } = &reference else { unreachable!() };
        for t in [1usize, 7, n, n + 3] {
            for pool_opt in [None, Some(&pool)] {
                let tiled =
                    GramCache::build_tiled(&x, GramBackend::Dual, pool_opt, TilePolicy::Rows(t))
                        .unwrap();
                let GramCache::Dual { kc, .. } = &tiled else { unreachable!() };
                assert_eq!(kc.as_slice(), kc_ref.as_slice(), "K_c moved (tile={t})");
                for lambda in [0.3, 5.0] {
                    let h_ref = reference.hat(lambda).unwrap();
                    let h_tiled =
                        tiled.hat_pool_tiled(lambda, pool_opt, TilePolicy::Rows(t)).unwrap();
                    assert_eq!(
                        h_ref.h.as_slice(),
                        h_tiled.h.as_slice(),
                        "hat moved (tile={t} λ={lambda})"
                    );
                }
            }
        }
        // Budget policy resolves to some tile and stays bitwise too.
        let budget = TilePolicy::Budget { bytes: 64 << 10 };
        assert!(budget.tile_rows(n, 90).is_some());
        let tiled = GramCache::build_tiled(&x, GramBackend::Dual, Some(&pool), budget).unwrap();
        let GramCache::Dual { kc, .. } = &tiled else { unreachable!() };
        assert_eq!(kc.as_slice(), kc_ref.as_slice(), "budget-tiled K_c moved");
    }

    #[test]
    fn tiled_policy_off_reproduces_todays_gram_cache_hats() {
        // Acceptance: TilePolicy::Off is the historical path, bitwise — for
        // every backend arm of the cache.
        let mut rng = Rng::new(62);
        let pool = crate::util::threadpool::ThreadPool::new(3);
        for &(n, p) in &[(30usize, 12usize), (14, 50)] {
            let x = random_x(&mut rng, n, p);
            for backend in [GramBackend::Primal, GramBackend::Dual, GramBackend::Spectral] {
                if backend != GramBackend::Primal && p < n {
                    continue;
                }
                let today = GramCache::build(&x, backend, None);
                let off = GramCache::build_tiled(&x, backend, None, TilePolicy::Off).unwrap();
                for lambda in [0.4, 8.0] {
                    let a = today.hat(lambda).unwrap();
                    let b = off.hat_pool_tiled(lambda, None, TilePolicy::Off).unwrap();
                    assert_eq!(a.h.as_slice(), b.h.as_slice(), "{backend:?} λ={lambda}");
                    // pooled Off too (the pooled in-place Cholesky is
                    // bit-identical to the serial factor)
                    let c = off.hat_pool_tiled(lambda, Some(&pool), TilePolicy::Off).unwrap();
                    assert_eq!(a.h.as_slice(), c.h.as_slice(), "{backend:?} pooled λ={lambda}");
                }
            }
        }
    }

    #[test]
    fn tiled_spectral_and_shared_nested_builds_bitwise_match() {
        let mut rng = Rng::new(63);
        let pool = crate::util::threadpool::ThreadPool::new(4);
        let n = 21;
        let x = random_x(&mut rng, n, 70);
        let sg_ref = SpectralGram::build(&x, None);
        for t in [1usize, 7, n, n + 3] {
            let sg = SpectralGram::build_tiled(&x, Some(&pool), TilePolicy::Rows(t));
            for lambda in [0.5, 12.0] {
                assert_eq!(
                    sg_ref.hat(lambda).unwrap().h.as_slice(),
                    sg.hat(lambda).unwrap().h.as_slice(),
                    "spectral tile={t} λ={lambda}"
                );
            }
        }
        let shared_ref = SharedNestedGram::build(&x, None);
        let shared_tiled =
            SharedNestedGram::build_tiled(&x, Some(&pool), TilePolicy::Rows(7)).unwrap();
        assert_eq!(
            shared_ref.to_dense().unwrap().as_slice(),
            shared_tiled.to_dense().unwrap().as_slice(),
            "XXᵀ moved"
        );
    }

    #[test]
    fn spill_gram_cache_dual_hats_bitwise_match_in_ram() {
        // Acceptance: the out-of-core dual cache — K_c panels + per-λ
        // spilled factor + streamed solve — reproduces the in-RAM dual
        // hats to the last bit across tile heights {1, 7, N, N+3}, RAM and
        // disk panels, serial and pooled.
        let mut rng = Rng::new(71);
        let pool = crate::util::threadpool::ThreadPool::new(3);
        let n = 22;
        let x = random_x(&mut rng, n, 70);
        let reference = GramCache::build(&x, GramBackend::Dual, None);
        let base = std::env::temp_dir()
            .join(format!("fastcv-hat-spill-{}", std::process::id()));
        for t in [1usize, 7, n, n + 3] {
            for dir in [None, Some(base.as_path())] {
                let tile = TilePolicy::Spill { dir: dir.map(|d| d.to_path_buf()), tile: t };
                let spilled =
                    GramCache::build_tiled(&x, GramBackend::Dual, Some(&pool), tile.clone())
                        .unwrap();
                assert!(matches!(spilled, GramCache::DualSpill { .. }));
                for lambda in [0.3, 5.0] {
                    let h_ref = reference.hat(lambda).unwrap();
                    let h_spill =
                        spilled.hat_pool_tiled(lambda, Some(&pool), tile.clone()).unwrap();
                    assert_eq!(
                        h_ref.h.as_slice(),
                        h_spill.h.as_slice(),
                        "hat moved (tile={t} disk={} λ={lambda})",
                        dir.is_some()
                    );
                    assert_eq!(h_spill.backend, GramBackend::Dual);
                }
                // λ = 0 stays a clean error, like the in-RAM dual arm
                assert!(spilled.hat(0.0).is_err());
            }
        }
        let _ = std::fs::remove_dir_all(&base);
    }

    #[test]
    fn spill_gram_cache_primal_hats_bitwise_match_in_ram() {
        // The spilled primal quadrant: G₀ panels via syrk_spill + per-λ
        // spilled factor must reproduce the in-RAM primal hats (their
        // Cholesky path) bitwise — tall shape, λ ≥ 0.
        let mut rng = Rng::new(72);
        let pool = crate::util::threadpool::ThreadPool::new(3);
        let x = random_x(&mut rng, 30, 12);
        let reference = GramCache::build(&x, GramBackend::Primal, None);
        for t in [1usize, 5, 13, 16] {
            let tile = TilePolicy::Spill { dir: None, tile: t };
            let spilled =
                GramCache::build_tiled(&x, GramBackend::Primal, Some(&pool), tile.clone())
                    .unwrap();
            assert!(matches!(spilled, GramCache::PrimalSpill { .. }));
            assert_eq!(spilled.n(), 30);
            for lambda in [0.0, 0.3, 10.0] {
                let h_ref = reference.hat(lambda).unwrap();
                let h_spill = spilled.hat_pool_tiled(lambda, Some(&pool), tile.clone()).unwrap();
                assert_eq!(
                    h_ref.h.as_slice(),
                    h_spill.h.as_slice(),
                    "primal hat moved (tile={t} λ={lambda})"
                );
                assert_eq!(h_spill.backend, GramBackend::Primal);
            }
        }
        // Wide + λ=0: the in-RAM arm falls back to LU; out of core this is
        // a clean error telling the caller to ridge, not a panic.
        let x_wide = random_x(&mut rng, 10, 30);
        let spilled = GramCache::build_tiled(
            &x_wide,
            GramBackend::Primal,
            None,
            TilePolicy::Spill { dir: None, tile: 8 },
        )
        .unwrap();
        let err = spilled.hat(0.0).err().expect("singular spilled gram must error");
        assert!(format!("{err:#}").contains("increase ridge"), "{err:#}");
    }

    #[test]
    fn spill_tiled_primal_gram_cache_uses_syrk_tiled_bitwise() {
        // The tiled-primal-syrk wiring: a Rows/Budget policy now routes the
        // primal G₀ through syrk_tiled — bitwise the same cache and hats as
        // the historical syrk_t_pool build.
        let mut rng = Rng::new(73);
        let pool = crate::util::threadpool::ThreadPool::new(3);
        let x = random_x(&mut rng, 26, 14);
        let reference = GramCache::build(&x, GramBackend::Primal, Some(&pool));
        let GramCache::Primal { g0: g0_ref, .. } = &reference else { unreachable!() };
        for tile in [TilePolicy::Rows(1), TilePolicy::Rows(7), TilePolicy::Budget { bytes: 4 << 10 }]
        {
            let tiled =
                GramCache::build_tiled(&x, GramBackend::Primal, Some(&pool), tile.clone())
                    .unwrap();
            let GramCache::Primal { g0, .. } = &tiled else { unreachable!() };
            assert_eq!(g0.as_slice(), g0_ref.as_slice(), "G₀ moved ({tile:?})");
            for lambda in [0.0, 2.0] {
                assert_eq!(
                    reference.hat(lambda).unwrap().h.as_slice(),
                    tiled.hat_pool_tiled(lambda, Some(&pool), tile.clone()).unwrap().h.as_slice(),
                    "primal hat moved ({tile:?} λ={lambda})"
                );
            }
        }
    }

    #[test]
    fn spill_shared_nested_gram_matches_dense() {
        // A spilled shared XXᵀ must gather to the dense build bitwise, and
        // its fold downdates must feed identical spectral/dual caches.
        let mut rng = Rng::new(74);
        let n = 24;
        let x = random_x(&mut rng, n, 60);
        let dense = SharedNestedGram::build(&x, None);
        let spilled = SharedNestedGram::build_tiled(
            &x,
            None,
            TilePolicy::Spill { dir: None, tile: 7 },
        )
        .unwrap();
        assert_eq!(spilled.n(), n);
        assert_eq!(
            dense.to_dense().unwrap().as_slice(),
            spilled.to_dense().unwrap().as_slice(),
            "spilled XXᵀ moved"
        );
        let te: Vec<usize> = (0..n).filter(|i| i % 4 == 2).collect();
        let tr = crate::fastcv::complement(&te, n);
        let x_tr = x.take_rows(&tr);
        let sg_dense = dense.fold_spectral(&x_tr, &tr).unwrap();
        let sg_spill = spilled.fold_spectral(&x_tr, &tr).unwrap();
        for lambda in [0.5, 8.0] {
            assert_eq!(
                sg_dense.hat(lambda).unwrap().h.as_slice(),
                sg_spill.hat(lambda).unwrap().h.as_slice(),
                "downdated spectral hat moved (λ={lambda})"
            );
        }
        let (dual_dense, dual_spill) =
            (dense.fold_dual(&x_tr, &tr).unwrap(), spilled.fold_dual(&x_tr, &tr).unwrap());
        assert_eq!(
            dual_dense.hat(1.3).unwrap().h.as_slice(),
            dual_spill.hat(1.3).unwrap().h.as_slice(),
            "downdated dual hat moved"
        );
    }

    #[test]
    fn spill_build_ctx_routes_the_policy_and_stays_bitwise() {
        // HatMatrix::build_ctx under a Spill policy (Auto → dual on this
        // wide shape) equals the plain dual build bitwise.
        let mut rng = Rng::new(75);
        let x = random_x(&mut rng, 18, 55);
        let reference = HatMatrix::build_with(&x, 0.7, GramBackend::Dual, None).unwrap();
        let ctx = super::super::context::ComputeContext::with_threads(2)
            .with_tile_policy(TilePolicy::Spill { dir: None, tile: 5 });
        let spilled = HatMatrix::build_ctx(&x, 0.7, &ctx).unwrap();
        assert_eq!(reference.h.as_slice(), spilled.h.as_slice());
        assert_eq!(spilled.backend, GramBackend::Dual);
    }

    #[test]
    fn tiled_build_ctx_honours_the_context_and_stays_bitwise() {
        // HatMatrix::build_ctx = build_with + tile knob, bitwise.
        let mut rng = Rng::new(64);
        let x = random_x(&mut rng, 18, 60);
        let reference = HatMatrix::build_with(&x, 0.7, GramBackend::Dual, None).unwrap();
        let ctx = super::super::context::ComputeContext::with_threads(3)
            .with_backend(GramBackend::Dual)
            .with_tile_policy(TilePolicy::Rows(5));
        let tiled = HatMatrix::build_ctx(&x, 0.7, &ctx).unwrap();
        assert_eq!(reference.h.as_slice(), tiled.h.as_slice());
        assert_eq!(tiled.backend, GramBackend::Dual);
    }

    #[test]
    fn backend_shared_nested_dual_downdate_matches_direct() {
        // fold_dual serves the same downdated K_c^{Tr} as fold_spectral —
        // its hats must agree with a direct per-fold dual build to roundoff
        // (same float-path caveat as the spectral downdate).
        let mut rng = Rng::new(65);
        let n = 24;
        let x = random_x(&mut rng, n, 80);
        let shared = SharedNestedGram::build(&x, None);
        let te: Vec<usize> = (0..n).filter(|i| i % 3 == 1).collect();
        let tr = crate::fastcv::complement(&te, n);
        let x_tr = x.take_rows(&tr);
        let down = shared.fold_dual(&x_tr, &tr).unwrap();
        let direct = GramCache::build(&x_tr, GramBackend::Dual, None);
        for lambda in [0.4, 2.0, 25.0] {
            let h_down = down.hat(lambda).unwrap().h;
            let h_direct = direct.hat(lambda).unwrap().h;
            let scale = h_direct.max_abs().max(1.0);
            assert!(
                h_down.max_abs_diff(&h_direct) < 1e-8 * scale,
                "λ={lambda}: |ΔH| = {}",
                h_down.max_abs_diff(&h_direct)
            );
        }
    }

    #[test]
    fn backend_on_demand_gram_ops_match_primal() {
        // inv_gram/solve_gram on a dual-built hat factor the primal Gram on
        // demand and must agree with the primal-built hat's stored factor.
        let mut rng = Rng::new(26);
        let x = random_x(&mut rng, 12, 30);
        let primal = HatMatrix::build_with(&x, 0.5, GramBackend::Primal, None).unwrap();
        let dual = HatMatrix::build_with(&x, 0.5, GramBackend::Dual, None).unwrap();
        let s_primal = primal.inv_gram();
        let s_dual = dual.inv_gram();
        assert!(s_primal.max_abs_diff(&s_dual) < 1e-9 * s_primal.max_abs().max(1.0));
        let b = Mat::from_fn(31, 3, |_, _| rng.gauss());
        let z_primal = primal.solve_gram(&b);
        let z_dual = dual.solve_gram(&b);
        assert!(z_primal.max_abs_diff(&z_dual) < 1e-9 * z_primal.max_abs().max(1.0));
    }

    #[test]
    fn hat_entries_are_whitened_kernel() {
        // §4.4: H_ij = x̃ᵢᵀ (X̃ᵀX̃+λI₀)⁻¹ x̃ⱼ.
        let mut rng = Rng::new(5);
        let x = random_x(&mut rng, 9, 3);
        let hat = HatMatrix::build(&x, 0.7).unwrap();
        for i in [0usize, 4, 8] {
            for j in [1usize, 4, 7] {
                let sxj = crate::linalg::matvec(&hat.inv_gram(), hat.xa.row(j));
                let hij = crate::linalg::dot(hat.xa.row(i), &sxj);
                assert!((hat.h[(i, j)] - hij).abs() < 1e-10);
            }
        }
    }
}
