//! The hat matrix `H = X̃ (X̃ᵀX̃ + λI₀)⁻¹ X̃ᵀ` (§2.4.2, §2.6.1).
//!
//! Built **once** per dataset; it depends on the features only, so it is
//! reused across every fold *and every label permutation* (§2.7) — that
//! reuse is the entire source of the paper's speed-up.

use crate::linalg::{gemm_acc, matmul, matvec_gemm_order, Cholesky, Lu, Mat};
use crate::model::linreg::gram_ridged;
use anyhow::{Context, Result};

/// Which factorisation of the gram matrix backs this hat matrix.
#[derive(Clone, Debug)]
enum GramFactor {
    Chol(Cholesky),
    Lu(Lu),
}

/// Precomputed full-data quantities shared by the analytic CV paths.
#[derive(Clone, Debug)]
pub struct HatMatrix {
    /// `H`, `N × N`.
    pub h: Mat,
    /// Augmented design `X̃ = [X, 1]`, `N × (P+1)`.
    pub xa: Mat,
    /// Factorisation of `G = X̃ᵀX̃ + λI₀` (the explicit inverse `S` is never
    /// needed on the hot path — see [`HatMatrix::inv_gram`]).
    factor: GramFactor,
    /// Ridge parameter used.
    pub lambda: f64,
}

impl HatMatrix {
    /// Build from raw data `x` (N×P) with ridge λ (λ=0 allowed when the
    /// gram matrix is non-singular, i.e. typically N > P).
    ///
    /// Perf note (EXPERIMENTS.md §Perf L3 #4): `H = X̃ G⁻¹ X̃ᵀ` is computed
    /// as `X̃ · solve(G, X̃ᵀ)` — a factorisation (`P³/3`) plus an `O(P²N)`
    /// multi-RHS solve — rather than materialising `G⁻¹` (`≈P³` extra).
    pub fn build(x: &Mat, lambda: f64) -> Result<HatMatrix> {
        assert!(lambda >= 0.0, "ridge λ must be ≥ 0");
        let xa = x.augment_ones();
        let g = gram_ridged(&xa, lambda);
        // Cholesky (G is SPD whenever invertible here); LU fallback gives a
        // clean error message for singular unridged fits.
        let (factor, w) = match Cholesky::factor(&g) {
            Ok(ch) => {
                let w = ch.solve_mat(&xa.t()); // W = G⁻¹X̃ᵀ, (P+1)×N
                (GramFactor::Chol(ch), w)
            }
            Err(_) => {
                let lu = Lu::factor(&g)
                    .context("gram matrix singular — increase ridge λ (P ≥ N with λ=0?)")?;
                let w = lu.solve_mat(&xa.t());
                (GramFactor::Lu(lu), w)
            }
        };
        // H = X̃ W.
        let mut h = Mat::zeros(xa.rows(), xa.rows());
        gemm_acc(&mut h, &xa, &w, 1.0, 0.0);
        h.symmetrize(); // exact-math symmetric; tidy roundoff
        Ok(HatMatrix { h, xa, factor, lambda })
    }

    /// Explicit inverse gram `S = (X̃ᵀX̃ + λI₀)⁻¹` — off the hot path; used
    /// by the Woodbury derivation utilities and tests.
    pub fn inv_gram(&self) -> Mat {
        match &self.factor {
            GramFactor::Chol(ch) => ch.inverse(),
            GramFactor::Lu(lu) => lu.inverse(),
        }
    }

    /// Solve `G z = b` against the stored factorisation.
    pub fn solve_gram(&self, b: &Mat) -> Mat {
        match &self.factor {
            GramFactor::Chol(ch) => ch.solve_mat(b),
            GramFactor::Lu(lu) => lu.solve_mat(b),
        }
    }

    /// Number of samples.
    pub fn n(&self) -> usize {
        self.h.rows()
    }

    /// Full-data fitted values `ŷ = H y` for a response/label vector.
    ///
    /// Computed in GEMM accumulation order ([`matvec_gemm_order`]) so the
    /// result is bit-identical to one column of [`Self::fit_response_mat`]
    /// — the serial and batched permutation engines rely on that equality.
    pub fn fit_response(&self, y: &[f64]) -> Vec<f64> {
        matvec_gemm_order(&self.h, y)
    }

    /// Full-data fits for a response *matrix* (multi-class `Ŷ = H Y`).
    pub fn fit_response_mat(&self, y: &Mat) -> Mat {
        matmul(&self.h, y)
    }

    /// The fold-local block `H_Te` (rows & cols at `te`).
    pub fn block(&self, te: &[usize]) -> Mat {
        self.h.take(te, te)
    }

    /// The cross block `H_{Tr,Te}` (rows `tr`, cols `te`) used by the bias
    /// adjustment (Eq. 15).
    pub fn cross_block(&self, tr: &[usize], te: &[usize]) -> Mat {
        self.h.take(tr, te)
    }

    /// `I − H_Te` for a fold.
    pub fn i_minus_block(&self, te: &[usize]) -> Mat {
        let mut m = self.block(te);
        m.scale(-1.0);
        for i in 0..te.len() {
            m[(i, i)] += 1.0;
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_all_close, Cases};
    use crate::util::rng::Rng;

    fn random_x(rng: &mut Rng, n: usize, p: usize) -> Mat {
        Mat::from_fn(n, p, |_, _| rng.gauss())
    }

    #[test]
    fn symmetric_and_idempotent_unridged() {
        let mut rng = Rng::new(1);
        let x = random_x(&mut rng, 20, 6);
        let hat = HatMatrix::build(&x, 0.0).unwrap();
        // symmetry
        assert!(hat.h.max_abs_diff(&hat.h.t()) < 1e-10);
        // idempotent: H² = H (projection) when λ=0
        let hh = matmul(&hat.h, &hat.h);
        assert!(hh.max_abs_diff(&hat.h) < 1e-8);
        // trace H = rank X̃ = P+1
        assert!((hat.h.trace() - 7.0).abs() < 1e-8);
    }

    #[test]
    fn ridge_contracts_hat() {
        let mut rng = Rng::new(2);
        let x = random_x(&mut rng, 15, 5);
        let h0 = HatMatrix::build(&x, 0.0).unwrap();
        let h1 = HatMatrix::build(&x, 10.0).unwrap();
        // Ridge shrinks the projection: trace decreases.
        assert!(h1.h.trace() < h0.h.trace());
        // Ones direction unpenalised (I₀): H·1 = 1 in both.
        let ones = vec![1.0; 15];
        assert_all_close(&h0.fit_response(&ones), &ones, 1e-8, "H·1 λ=0");
        assert_all_close(&h1.fit_response(&ones), &ones, 1e-8, "H·1 λ>0");
    }

    #[test]
    fn hy_matches_regression_fit() {
        // ŷ = Hy equals the prediction of the ridge regression fit.
        Cases::new(20).run("hat-vs-regression", |rng| {
            let n = 10 + rng.below(25);
            let p = 1 + rng.below(8);
            let x = random_x(rng, n, p);
            let y: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
            let lambda = crate::util::prop::ridge(rng, p < n);
            let hat = HatMatrix::build(&x, lambda).unwrap();
            let fit = crate::model::linreg::LinReg::fit(&x, &y, lambda).unwrap();
            assert_all_close(&hat.fit_response(&y), &fit.predict(&x), 1e-6, "Hy vs X̃β̂");
        });
    }

    #[test]
    fn wide_data_requires_ridge() {
        let mut rng = Rng::new(3);
        let x = random_x(&mut rng, 8, 20);
        assert!(HatMatrix::build(&x, 0.0).is_err());
        assert!(HatMatrix::build(&x, 0.5).is_ok());
    }

    #[test]
    fn blocks_agree_with_take() {
        let mut rng = Rng::new(4);
        let x = random_x(&mut rng, 12, 4);
        let hat = HatMatrix::build(&x, 0.1).unwrap();
        let te = [2usize, 5, 9];
        let tr = [0usize, 1, 3, 4, 6, 7, 8, 10, 11];
        let b = hat.block(&te);
        assert_eq!(b.shape(), (3, 3));
        assert_eq!(b[(0, 1)], hat.h[(2, 5)]);
        let cb = hat.cross_block(&tr, &te);
        assert_eq!(cb.shape(), (9, 3));
        assert_eq!(cb[(0, 2)], hat.h[(0, 9)]);
        let imb = hat.i_minus_block(&te);
        assert!((imb[(0, 0)] - (1.0 - hat.h[(2, 2)])).abs() < 1e-15);
        assert!((imb[(0, 1)] + hat.h[(2, 5)]).abs() < 1e-15);
    }

    #[test]
    fn hat_entries_are_whitened_kernel() {
        // §4.4: H_ij = x̃ᵢᵀ (X̃ᵀX̃+λI₀)⁻¹ x̃ⱼ.
        let mut rng = Rng::new(5);
        let x = random_x(&mut rng, 9, 3);
        let hat = HatMatrix::build(&x, 0.7).unwrap();
        for i in [0usize, 4, 8] {
            for j in [1usize, 4, 7] {
                let sxj = crate::linalg::matvec(&hat.inv_gram(), hat.xa.row(j));
                let hij = crate::linalg::dot(hat.xa.row(i), &sxj);
                assert!((hat.h[(i, j)] - hij).abs() < 1e-10);
            }
        }
    }
}
