//! The Woodbury/Sherman–Morrison intermediate identities (§2.4.3–2.4.5).
//!
//! These are the derivational stepping stones of the paper — Eq. 9 (RHS
//! downdate), Eq. 10/11 (inverse-scatter downdate) and Eq. 12 (fold-weight
//! update). The production path (Eq. 14) never materialises them, but they
//! are kept (a) as executable proofs backing the derivation, (b) to expose
//! per-fold model weights `β̇` cheaply when a caller wants the actual fold
//! models (e.g. for interpretation), and (c) as the ablation arm of
//! `benches/ablation_updates.rs`.

use super::hat::HatMatrix;
use crate::linalg::{matmul, matvec_t, Lu, Mat};
use anyhow::{Context, Result};

/// Eq. 9: `X̃_Trᵀ y_Tr = X̃ᵀy − X̃_Teᵀ y_Te` without touching training rows.
pub fn downdate_xty(hat: &HatMatrix, y: &[f64], te: &[usize]) -> Vec<f64> {
    let mut xty = matvec_t(&hat.xa, y);
    let xa_te = hat.xa.take_rows(te);
    let y_te: Vec<f64> = te.iter().map(|&i| y[i]).collect();
    let sub = matvec_t(&xa_te, &y_te);
    for (a, b) in xty.iter_mut().zip(&sub) {
        // lint:allow(float_accum, reason = "per-element downdate: each entry touched exactly once — order-free")
        *a -= b;
    }
    xty
}

/// Eq. 11: `(X̃_TrᵀX̃_Tr + λI₀)⁻¹ = S + S X̃_Teᵀ (I − H_Te)⁻¹ X̃_Te S`.
pub fn downdate_inverse(hat: &HatMatrix, te: &[usize]) -> Result<Mat> {
    let s = hat.inv_gram();
    let xa_te = hat.xa.take_rows(te);
    let s_xte = matmul(&s, &xa_te.t()); // S X̃_Teᵀ  ((P+1) × nte)
    let i_minus = hat.i_minus_block(te);
    let lu = Lu::factor(&i_minus).context("(I − H_Te) singular")?;
    // (I−H_Te)⁻¹ X̃_Te S = (I−H_Te)⁻¹ (S X̃_Teᵀ)ᵀ
    let solved = lu.solve_mat(&s_xte.t());
    let mut out = matmul(&s_xte, &solved);
    out.axpy(1.0, &s);
    Ok(out)
}

/// Eq. 12: fold weights `β̇ = β̂ − S X̃_Teᵀ (I−H_Te)⁻¹ ê_Te` — the actual
/// training-fold model, recovered without refitting.
pub fn fold_weights(hat: &HatMatrix, y: &[f64], te: &[usize]) -> Result<Vec<f64>> {
    let xty = matvec_t(&hat.xa, y);
    let beta_full = hat.solve_gram(&Mat::col_vec(&xty)).col(0);
    let y_hat = hat.fit_response(y);
    let e_hat_te: Vec<f64> = te.iter().map(|&i| y[i] - y_hat[i]).collect();
    let i_minus = hat.i_minus_block(te);
    let corr_te = Lu::factor(&i_minus).context("(I − H_Te) singular")?.solve_vec(&e_hat_te);
    let xa_te = hat.xa.take_rows(te);
    let corr = matvec_t(&xa_te, &corr_te); // X̃_Teᵀ (I−H_Te)⁻¹ ê_Te
    let s_corr = hat.solve_gram(&Mat::col_vec(&corr)).col(0);
    Ok(beta_full.iter().zip(&s_corr).map(|(b, c)| b - c).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fastcv::complement;
    use crate::model::linreg::gram_ridged;
    use crate::util::prop::{assert_all_close, Cases};

    #[test]
    fn eq9_matches_direct() {
        Cases::new(20).run("eq9", |rng| {
            let n = 10 + rng.below(20);
            let p = 1 + rng.below(6);
            let x = Mat::from_fn(n, p, |_, _| rng.gauss());
            let y: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
            let hat = HatMatrix::build(&x, 0.3).unwrap();
            let k = 3 + rng.below(3);
            let te: Vec<usize> = (0..n).filter(|i| i % k == 0).collect();
            let tr = complement(&te, n);
            let fast = downdate_xty(&hat, &y, &te);
            let xa_tr = hat.xa.take_rows(&tr);
            let y_tr: Vec<f64> = tr.iter().map(|&i| y[i]).collect();
            let direct = matvec_t(&xa_tr, &y_tr);
            assert_all_close(&fast, &direct, 1e-9, "X̃_Trᵀy_Tr");
        });
    }

    #[test]
    fn eq11_matches_direct_inverse() {
        Cases::new(20).run("eq11", |rng| {
            let n = 12 + rng.below(15);
            let p = 1 + rng.below(5);
            let x = Mat::from_fn(n, p, |_, _| rng.gauss());
            let lambda = 10f64.powf(rng.uniform_in(-2.0, 1.0));
            let hat = HatMatrix::build(&x, lambda).unwrap();
            let te: Vec<usize> = (0..n).filter(|i| i % 4 == 1).collect();
            let tr = complement(&te, n);
            let fast = downdate_inverse(&hat, &te).unwrap();
            let xa_tr = hat.xa.take_rows(&tr);
            let g_tr = gram_ridged(&xa_tr, lambda);
            let direct = Lu::factor(&g_tr).unwrap().inverse();
            assert!(
                fast.max_abs_diff(&direct) < 1e-6 * direct.max_abs().max(1.0),
                "Woodbury downdate mismatch: {}",
                fast.max_abs_diff(&direct)
            );
        });
    }

    #[test]
    fn eq12_recovers_fold_model() {
        Cases::new(20).run("eq12", |rng| {
            let n = 14 + rng.below(15);
            let p = 1 + rng.below(5);
            let x = Mat::from_fn(n, p, |_, _| rng.gauss());
            let y: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
            let lambda = 10f64.powf(rng.uniform_in(-2.0, 1.0));
            let hat = HatMatrix::build(&x, lambda).unwrap();
            let te: Vec<usize> = (0..n).filter(|i| i % 5 == 2).collect();
            let tr = complement(&te, n);
            let beta_dot = fold_weights(&hat, &y, &te).unwrap();
            // direct fold fit
            let x_tr = x.take_rows(&tr);
            let y_tr: Vec<f64> = tr.iter().map(|&i| y[i]).collect();
            let m = crate::model::linreg::LinReg::fit(&x_tr, &y_tr, lambda).unwrap();
            let mut direct = m.w.clone();
            direct.push(m.b);
            assert_all_close(&beta_dot, &direct, 1e-6, "β̇");
        });
    }
}
