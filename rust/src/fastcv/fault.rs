//! Deterministic fault injection: named fault sites + a counter-seeded
//! [`FaultPlan`] that decides, reproducibly, which arrivals at a site
//! fire.
//!
//! The serve/store/spill stack recovers from torn panel writes, corrupt
//! on-disk factors, panicking workers, and dropped connections — but none
//! of those recovery paths is testable by waiting for real crashes. This
//! module makes every fault a *scheduled event*: production code asks
//! [`hit`]`("site.name")` at each named site (a no-op returning `None`
//! when no plan is active), and a test — or a CI job via the
//! `FASTCV_FAULT_PLAN` environment variable — installs a plan that fires
//! deterministic faults at chosen arrivals.
//!
//! ## Plan grammar
//!
//! A plan is a comma-separated list of rules. Each rule names a site and
//! a trigger, with an optional `=arg` payload (meaning is site-specific —
//! e.g. a delay in milliseconds for `spill.read.delay`):
//!
//! | rule                | fires                                         |
//! |---------------------|-----------------------------------------------|
//! | `site@n`            | exactly on the `n`-th arrival (1-based)       |
//! | `site%k`            | on every `k`-th arrival                       |
//! | `site~seed:ppm`     | per-arrival coin from [`Rng::stream`]`(seed, arrival)`, firing with probability `ppm` per million |
//!
//! Example: `spill.write.torn@1,serve.worker.panic%3,spill.read.delay@2=50`.
//!
//! ## Determinism (the lint-L2 contract)
//!
//! A plan is a pure function of `(spec, per-site arrival count)`: the
//! probabilistic trigger draws from the counter-seeded
//! [`Rng::stream`](crate::util::rng::Rng::stream) — no entropy, no clock —
//! so the same plan against the same call sequence fires the same faults,
//! on every machine, every run. That is what lets the `chaos_*` property
//! suite pin recovery paths bitwise (a rebuilt-after-corruption factor
//! must equal the never-corrupted one).
//!
//! ## Activation
//!
//! Priority order for [`global`]: a plan installed by [`install`] (tests)
//! or [`set_plan`] (the [`ComputeContext::with_faults`] knob), else the
//! process-wide `FASTCV_FAULT_PLAN` environment plan, else none. Like the
//! ISA override, the active plan is process-global — fault sites live in
//! layers (panel files, daemon workers) that no per-call context reaches.
//!
//! [`ComputeContext::with_faults`]: crate::fastcv::context::ComputeContext::with_faults
//! [`Rng::stream`]: crate::util::rng::Rng::stream

use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, MutexGuard, OnceLock, PoisonError};

/// How a rule decides whether arrival number `a` (1-based) fires.
#[derive(Debug, Clone, PartialEq, Eq)]
enum Trigger {
    /// `site@n` — fire exactly on the n-th arrival.
    At(u64),
    /// `site%k` — fire on every k-th arrival (a = k, 2k, 3k, …).
    Every(u64),
    /// `site~seed:ppm` — fire iff the counter-seeded coin for this
    /// arrival lands below `ppm` (parts per million).
    Seeded { seed: u64, ppm: u64 },
}

impl Trigger {
    fn fires(&self, arrival: u64) -> bool {
        match *self {
            Trigger::At(n) => arrival == n,
            Trigger::Every(k) => arrival % k == 0,
            Trigger::Seeded { seed, ppm } => {
                // One u64 per (seed, arrival): a pure counter-seeded draw,
                // so the schedule is a function of the call sequence only.
                Rng::stream(seed, arrival).next_u64() % 1_000_000 < ppm
            }
        }
    }
}

/// One parsed rule: a site name, a trigger, and the `=arg` payload.
#[derive(Debug, Clone, PartialEq, Eq)]
struct Rule {
    site: String,
    trigger: Trigger,
    arg: u64,
}

/// A deterministic fault schedule: per-site arrival counters plus the
/// rules parsed from the plan spec (see the module docs for the grammar).
///
/// ```
/// use fastcv::fastcv::fault::FaultPlan;
///
/// let plan = FaultPlan::parse("spill.write.torn@2,spill.read.delay%3=50").unwrap();
/// assert_eq!(plan.hit("spill.write.torn"), None);     // arrival 1
/// assert_eq!(plan.hit("spill.write.torn"), Some(0));  // arrival 2 fires
/// assert_eq!(plan.hit("spill.read.delay"), None);
/// assert_eq!(plan.hit("spill.read.delay"), None);
/// assert_eq!(plan.hit("spill.read.delay"), Some(50)); // every 3rd, arg 50
/// assert_eq!(plan.hit("unlisted.site"), None);
/// ```
#[derive(Debug)]
pub struct FaultPlan {
    rules: Vec<Rule>,
    /// Arrivals seen per site — `BTreeMap`, not `HashMap`, per the repo's
    /// determinism lint (iteration order never matters here, but the rule
    /// is absolute).
    counters: Mutex<BTreeMap<String, u64>>,
}

impl FaultPlan {
    /// Parse a plan spec (the module-docs grammar). Errors name the
    /// offending rule — a misconfigured `FASTCV_FAULT_PLAN` must fail
    /// loudly, not silently inject nothing and fake chaos coverage.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut rules = Vec::new();
        for raw in spec.split(',') {
            let entry = raw.trim();
            if entry.is_empty() {
                continue;
            }
            rules.push(Self::parse_rule(entry).with_context(|| format!("fault rule {entry:?}"))?);
        }
        if rules.is_empty() {
            bail!("empty fault plan (spec {spec:?})");
        }
        Ok(FaultPlan { rules, counters: Mutex::new(BTreeMap::new()) })
    }

    fn parse_rule(entry: &str) -> Result<Rule> {
        let (body, arg) = match entry.split_once('=') {
            Some((body, arg)) => {
                (body, arg.parse::<u64>().with_context(|| format!("arg {arg:?}"))?)
            }
            None => (entry, 0),
        };
        let at = body.find(['@', '%', '~']);
        let Some(pos) = at else {
            bail!("no trigger — expected site@n, site%k, or site~seed:ppm");
        };
        let site = body[..pos].trim();
        if site.is_empty() {
            bail!("empty site name");
        }
        let num = |s: &str| s.parse::<u64>().with_context(|| format!("number {s:?}"));
        let rest = &body[pos + 1..];
        let trigger = match body.as_bytes()[pos] {
            b'@' => {
                let n = num(rest)?;
                if n == 0 {
                    bail!("@0 never fires (arrivals are 1-based)");
                }
                Trigger::At(n)
            }
            b'%' => {
                let k = num(rest)?;
                if k == 0 {
                    bail!("%0 would divide by zero");
                }
                Trigger::Every(k)
            }
            _ => {
                let Some((seed, ppm)) = rest.split_once(':') else {
                    bail!("~ trigger needs seed:ppm");
                };
                Trigger::Seeded { seed: num(seed)?, ppm: num(ppm)? }
            }
        };
        Ok(Rule { site: site.to_string(), trigger, arg })
    }

    /// Record one arrival at `site` and report whether it fires:
    /// `Some(arg)` (the rule's `=arg` payload, `0` when absent) when a
    /// rule triggers, `None` otherwise. Counting is per-site and
    /// per-plan, so plans installed by different tests never interfere.
    pub fn hit(&self, site: &str) -> Option<u64> {
        if !self.rules.iter().any(|r| r.site == site) {
            return None; // unlisted sites never pay the counter lock
        }
        let mut counters = self.counters.lock().unwrap_or_else(PoisonError::into_inner);
        let arrival = counters.entry(site.to_string()).or_insert(0);
        *arrival += 1;
        let a = *arrival;
        drop(counters);
        self.rules.iter().find(|r| r.site == site && r.trigger.fires(a)).map(|r| r.arg)
    }

    /// Arrivals recorded at `site` so far (test introspection).
    pub fn arrivals(&self, site: &str) -> u64 {
        let counters = self.counters.lock().unwrap_or_else(PoisonError::into_inner);
        counters.get(site).copied().unwrap_or(0)
    }
}

/// The programmatically installed plan (`None` = fall through to the
/// environment plan).
static ACTIVE: Mutex<Option<Arc<FaultPlan>>> = Mutex::new(None);
/// Serialises [`install`] scopes (tests) so nested guards can't
/// interleave their restore writes — same discipline as the ISA
/// `force_scope`.
static SCOPE_LOCK: Mutex<()> = Mutex::new(());

/// `FASTCV_FAULT_PLAN`, parsed once. A malformed plan is a configuration
/// error and must fail loudly — a chaos CI leg that silently injected
/// nothing would claim coverage it does not have.
fn env_plan() -> Option<Arc<FaultPlan>> {
    static ENV: OnceLock<Option<Arc<FaultPlan>>> = OnceLock::new();
    ENV.get_or_init(|| {
        let spec = std::env::var("FASTCV_FAULT_PLAN").ok()?;
        if spec.trim().is_empty() {
            return None;
        }
        match FaultPlan::parse(&spec) {
            Ok(p) => Some(Arc::new(p)),
            // lint:allow(panic, reason = "FASTCV_FAULT_PLAN misconfiguration must fail loudly, not silently inject nothing and fake chaos coverage")
            Err(e) => panic!("FASTCV_FAULT_PLAN={spec:?} did not parse: {e:#}"),
        }
    })
    .clone()
}

/// The active plan: the installed one, else the `FASTCV_FAULT_PLAN`
/// environment plan, else `None`. Cheap when no plan was ever configured
/// (one mutex lock + one `OnceLock` read).
pub fn global() -> Option<Arc<FaultPlan>> {
    let installed = ACTIVE.lock().unwrap_or_else(PoisonError::into_inner);
    installed.clone().or_else(env_plan)
}

/// Install (or with `None`, clear) the process-wide fault plan — the
/// [`ComputeContext::with_faults`] knob lands here. Like the ISA
/// override, this is process-global: fault sites live in layers no
/// per-call context threads through.
///
/// [`ComputeContext::with_faults`]: crate::fastcv::context::ComputeContext::with_faults
pub fn set_plan(plan: Option<Arc<FaultPlan>>) {
    *ACTIVE.lock().unwrap_or_else(PoisonError::into_inner) = plan;
}

/// A scoped plan for tests: installs `plan` until the guard drops, then
/// restores the previous one. Holds a global lock so concurrent test
/// scopes serialise instead of seeing each other's faults.
pub fn install(plan: FaultPlan) -> FaultScope {
    let lock = SCOPE_LOCK.lock().unwrap_or_else(PoisonError::into_inner);
    let mut active = ACTIVE.lock().unwrap_or_else(PoisonError::into_inner);
    let prev = active.replace(Arc::new(plan));
    drop(active);
    FaultScope { prev, _lock: lock }
}

/// Guard returned by [`install`]; restores the previously installed plan
/// on drop.
pub struct FaultScope {
    prev: Option<Arc<FaultPlan>>,
    _lock: MutexGuard<'static, ()>,
}

impl FaultScope {
    /// The plan this scope installed (for asserting on arrival counts).
    pub fn plan(&self) -> Arc<FaultPlan> {
        let active = ACTIVE.lock().unwrap_or_else(PoisonError::into_inner);
        // The scope holds SCOPE_LOCK, so the slot still holds our plan;
        // fall back to a fresh empty-rule plan only if someone bypassed
        // the scope discipline via set_plan.
        active.clone().unwrap_or_else(|| {
            Arc::new(FaultPlan { rules: Vec::new(), counters: Mutex::new(BTreeMap::new()) })
        })
    }
}

impl Drop for FaultScope {
    fn drop(&mut self) {
        *ACTIVE.lock().unwrap_or_else(PoisonError::into_inner) = self.prev.take();
    }
}

/// Record one arrival at `site` against the active plan: `Some(arg)` when
/// a fault fires, `None` when no plan is active or no rule triggers. This
/// is the one call production code makes at a fault site.
pub fn hit(site: &str) -> Option<u64> {
    global().and_then(|p| p.hit(site))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chaos_plan_triggers_are_deterministic_and_counted() {
        let plan = FaultPlan::parse("a.b@3, c.d%2=7").unwrap();
        assert_eq!(plan.hit("a.b"), None);
        assert_eq!(plan.hit("a.b"), None);
        assert_eq!(plan.hit("a.b"), Some(0), "@3 fires exactly on the third arrival");
        assert_eq!(plan.hit("a.b"), None, "@3 fires once");
        assert_eq!(plan.arrivals("a.b"), 4);
        for round in 0..3 {
            assert_eq!(plan.hit("c.d"), None, "round {round}");
            assert_eq!(plan.hit("c.d"), Some(7), "round {round}: %2 carries its =arg");
        }
        assert_eq!(plan.hit("never.listed"), None);
        assert_eq!(plan.arrivals("never.listed"), 0, "unlisted sites are not counted");
    }

    #[test]
    fn chaos_seeded_trigger_is_a_pure_function_of_the_arrival() {
        let a = FaultPlan::parse("s~9:250000").unwrap();
        let b = FaultPlan::parse("s~9:250000").unwrap();
        let seq_a: Vec<_> = (0..64).map(|_| a.hit("s").is_some()).collect();
        let seq_b: Vec<_> = (0..64).map(|_| b.hit("s").is_some()).collect();
        assert_eq!(seq_a, seq_b, "same spec + same arrivals = same schedule");
        let fired = seq_a.iter().filter(|&&f| f).count();
        assert!(fired > 0 && fired < 64, "ppm=250000 over 64 draws fired {fired}");
        // ppm=0 never fires; ppm=1e6 always fires
        let never = FaultPlan::parse("s~9:0").unwrap();
        let always = FaultPlan::parse("s~9:1000000").unwrap();
        assert!((0..32).all(|_| never.hit("s").is_none()));
        assert!((0..32).all(|_| always.hit("s").is_some()));
    }

    #[test]
    fn chaos_plan_parse_rejects_malformed_specs() {
        for bad in [
            "", "   ", "no-trigger", "@3", "site@0", "site%0", "site~5", "site~x:3",
            "site@two", "a.b@1=many",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad:?} must not parse");
        }
        // errors carry the offending rule
        let err = FaultPlan::parse("ok@1,bad%0").err().map(|e| format!("{e:#}"));
        assert!(err.as_deref().is_some_and(|m| m.contains("bad%0")), "{err:?}");
    }

    #[test]
    fn chaos_install_scope_restores_and_serialises() {
        assert_eq!(hit("scope.test"), None, "no plan installed");
        {
            let scope = install(FaultPlan::parse("scope.test@1").unwrap());
            assert_eq!(hit("scope.test"), Some(0));
            assert_eq!(hit("scope.test"), None);
            assert_eq!(scope.plan().arrivals("scope.test"), 2);
        }
        assert_eq!(hit("scope.test"), None, "dropped scope restored no-plan");
    }
}
