//! Analytical cross-validation for multi-class LDA (§2.10, Algorithm 2).
//!
//! Step 1 of optimal scoring (multivariate ridge regression on the class
//! indicator matrix) is updated analytically exactly like the binary case —
//! Eq. 14/15 applied columnwise to `Ê = Y − HY`. Step 2 (the `C×C`
//! eigenproblem giving the optimal scores `Θ̇` and scaling `Ḋ`) cannot be
//! updated, but is `O(C³)` per fold — negligible. Classification is by
//! nearest centroid in the cross-validated discriminant-score space.

use super::context::ComputeContext;
use super::hat::{GramBackend, HatMatrix};
use super::FoldCache;
use crate::linalg::{matmul, Mat};
use crate::model::lda_multiclass::nearest_centroid;
use crate::model::optimal_scoring::{indicator_matrix, score_basis};
use anyhow::{ensure, Result};

/// Analytic multi-class CV engine for one dataset + labelling.
#[derive(Debug)]
pub struct AnalyticMulticlassCv {
    /// Shared feature-side precomputation.
    pub hat: HatMatrix,
    /// Class labels (0..c).
    pub labels: Vec<usize>,
    /// Number of classes.
    pub n_classes: usize,
    /// Indicator matrix `Y`, `N × C`.
    pub y: Mat,
    /// Full-data fits `Ŷ = HY`.
    pub y_hat: Mat,
}

impl AnalyticMulticlassCv {
    /// Fit the single full-data multivariate regression (primal Gram; see
    /// [`Self::fit_with`] for the P ≫ N backends).
    pub fn fit(x: &Mat, labels: &[usize], c: usize, lambda: f64) -> Result<AnalyticMulticlassCv> {
        Self::fit_with(x, labels, c, lambda, GramBackend::Primal)
    }

    /// [`Self::fit`] through a chosen [`GramBackend`] (`Auto` picks by the
    /// P/N ratio). Predictions are backend-invariant: step 1's fits agree
    /// to ~1e-8 and step 2 is a `C×C` problem downstream of them.
    pub fn fit_with(
        x: &Mat,
        labels: &[usize],
        c: usize,
        lambda: f64,
        backend: GramBackend,
    ) -> Result<AnalyticMulticlassCv> {
        Self::fit_ctx(x, labels, c, lambda, &ComputeContext::serial().with_backend(backend))
    }

    /// [`Self::fit`] under a [`ComputeContext`]: the context's backend
    /// picks the Gram construction, its pool (if any) fans out the hat
    /// build's GEMMs, and its [`crate::linalg::TilePolicy`] bounds the dual
    /// `K_c` build's transients — all bit-identically to a serial build.
    pub fn fit_ctx(
        x: &Mat,
        labels: &[usize],
        c: usize,
        lambda: f64,
        ctx: &ComputeContext<'_>,
    ) -> Result<AnalyticMulticlassCv> {
        let hat = HatMatrix::build_ctx(x, lambda, ctx)?;
        Ok(Self::with_hat(hat, labels, c))
    }

    /// Re-use an existing hat matrix (permutation path: H is label-free).
    pub fn with_hat(hat: HatMatrix, labels: &[usize], c: usize) -> AnalyticMulticlassCv {
        assert_eq!(hat.n(), labels.len());
        let y = indicator_matrix(labels, c);
        let y_hat = hat.fit_response_mat(&y);
        AnalyticMulticlassCv { hat, labels: labels.to_vec(), n_classes: c, y, y_hat }
    }

    /// Swap in permuted labels without touching `H`.
    pub fn set_labels(&mut self, labels: &[usize]) {
        assert_eq!(self.hat.n(), labels.len());
        self.labels.copy_from_slice(labels);
        self.y = indicator_matrix(labels, self.n_classes);
        self.y_hat = self.hat.fit_response_mat(&self.y);
    }

    /// Algorithm 2: cross-validated predicted labels for every sample.
    /// The cache must be prepared `with_cross = true`. Samples not covered
    /// by any test fold keep the `usize::MAX` sentinel.
    pub fn predict_cached(&self, cache: &FoldCache) -> Result<Vec<usize>> {
        let cross = cache
            .cross
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("FoldCache must be prepared with with_cross=true"))?;
        let c = self.n_classes;
        let mut pred = vec![usize::MAX; self.hat.n()];
        for (k, te) in cache.folds.iter().enumerate() {
            let tr = &cache.trains[k];
            let n_tr = tr.len();
            // --- step 1: cross-validated fits (Eq. 14/15, columnwise) ---
            // Ê_Te (nte × C)
            let e_hat_te = Mat::from_fn(te.len(), c, |j, l| {
                self.y[(te[j], l)] - self.y_hat[(te[j], l)]
            });
            // Ė_Te = (I−H_Te)⁻¹ Ê_Te
            let e_dot_te = cache.lus[k].solve_mat(&e_hat_te);
            // Ẏ_Te = Y_Te − Ė_Te
            let y_dot_te = Mat::from_fn(te.len(), c, |j, l| self.y[(te[j], l)] - e_dot_te[(j, l)]);
            // Ė_Tr = Ê_Tr + H_{Tr,Te} Ė_Te ; Ẏ_Tr = Y_Tr − Ė_Tr
            let corr = matmul(&cross[k], &e_dot_te);
            let y_dot_tr = Mat::from_fn(n_tr, c, |j, l| {
                let i = tr[j];
                let e_tr = (self.y[(i, l)] - self.y_hat[(i, l)]) + corr[(j, l)];
                self.y[(i, l)] - e_tr
            });
            let y_tr = Mat::from_fn(n_tr, c, |j, l| self.y[(tr[j], l)]);
            let fold_pred =
                fold_step2_predict(k, c, tr, &self.labels, &y_tr, &y_dot_tr, &y_dot_te)?;
            for (j, &i) in te.iter().enumerate() {
                pred[i] = fold_pred[j];
            }
        }
        Ok(pred)
    }

    /// Matrix-response variant of [`Self::set_labels`] +
    /// [`Self::predict_cached`]: `y_stack` packs `B` class-indicator
    /// matrices side by side (`N × B·C`, permutation `b` owning columns
    /// `b·C..(b+1)·C`, with `labels_cols[b]` its labelling). Step 1 runs as
    /// **one** GEMM `Ŷ = H·Y_stack` plus one multi-RHS solve and one
    /// cross-block GEMM per fold for all `B` permutations; step 2 (the
    /// `C×C` optimal-scores eig) runs per permutation through the *same*
    /// per-fold code as the serial path, so predictions are bit-identical
    /// to `B` serial `set_labels` + `predict_cached` calls.
    ///
    /// Uses only the label-invariant state of `self` (hat matrix and class
    /// count) — the stored labelling is untouched.
    pub fn predict_cached_stacked(
        &self,
        cache: &FoldCache,
        y_stack: &Mat,
        labels_cols: &[Vec<usize>],
    ) -> Result<Vec<Vec<usize>>> {
        let cross = cache
            .cross
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("FoldCache must be prepared with with_cross=true"))?;
        let c = self.n_classes;
        let b = labels_cols.len();
        let n = self.hat.n();
        assert_eq!(y_stack.rows(), n, "stacked response rows must equal N");
        assert_eq!(y_stack.cols(), b * c, "stacked response must be N × B·C");
        let y_hat = self.hat.fit_response_mat(y_stack);
        let mut preds = vec![vec![usize::MAX; n]; b];
        for (k, te) in cache.folds.iter().enumerate() {
            let tr = &cache.trains[k];
            let n_tr = tr.len();
            let e_hat_te = Mat::from_fn(te.len(), b * c, |j, col| {
                y_stack[(te[j], col)] - y_hat[(te[j], col)]
            });
            let e_dot_te = cache.lus[k].solve_mat(&e_hat_te);
            let corr = matmul(&cross[k], &e_dot_te);
            for (p, labels) in labels_cols.iter().enumerate() {
                let off = p * c;
                let y_dot_te = Mat::from_fn(te.len(), c, |j, l| {
                    y_stack[(te[j], off + l)] - e_dot_te[(j, off + l)]
                });
                let y_dot_tr = Mat::from_fn(n_tr, c, |j, l| {
                    let i = tr[j];
                    let e_tr =
                        (y_stack[(i, off + l)] - y_hat[(i, off + l)]) + corr[(j, off + l)];
                    y_stack[(i, off + l)] - e_tr
                });
                let y_tr = Mat::from_fn(n_tr, c, |j, l| y_stack[(tr[j], off + l)]);
                let fold_pred =
                    fold_step2_predict(k, c, tr, labels, &y_tr, &y_dot_tr, &y_dot_te)?;
                for (j, &i) in te.iter().enumerate() {
                    preds[p][i] = fold_pred[j];
                }
            }
        }
        Ok(preds)
    }

    /// Convenience: prepare a cache and predict.
    pub fn predict(&self, folds: &[Vec<usize>]) -> Result<Vec<usize>> {
        let cache = FoldCache::prepare(&self.hat, folds, true)?;
        self.predict_cached(&cache)
    }
}

/// Step 2 of Algorithm 2 for one fold: from the cross-validated fits
/// `Ẏ_Tr`/`Ẏ_Te` and the training-fold indicator `Y_Tr`, solve the `C×C`
/// optimal-scores problem and classify the test fold by nearest centroid.
/// Shared verbatim by the serial and stacked engines so that equal inputs
/// yield bit-identical predictions.
fn fold_step2_predict(
    k: usize,
    c: usize,
    tr: &[usize],
    labels: &[usize],
    y_tr: &Mat,
    y_dot_tr: &Mat,
    y_dot_te: &Mat,
) -> Result<Vec<usize>> {
    let n_tr = tr.len();
    let counts: Vec<f64> = {
        let mut cnt = vec![0.0; c];
        for &i in tr {
            cnt[labels[i]] += 1.0;
        }
        cnt
    };
    ensure!(
        counts.iter().all(|&x| x > 0.0),
        "fold {k}: class absent from training set — use stratified folds"
    );
    // M = Ẏ_Trᵀ Y_Tr / N_Tr ; Dp = Y_TrᵀY_Tr / N_Tr
    let mut m = matmul(&y_dot_tr.t(), y_tr);
    m.scale(1.0 / n_tr as f64);
    let dp = Mat::diag(&counts.iter().map(|&x| x / n_tr as f64).collect::<Vec<_>>());
    let basis = score_basis(&m, &dp, n_tr)?;
    // Discriminant scores: Ž = Ẏ Θ̇ Ḋ for test and train.
    let theta_d = scale_cols(&basis.theta, &basis.d);
    let z_te = matmul(y_dot_te, &theta_d);
    let z_tr = matmul(y_dot_tr, &theta_d);
    // Class centroids in score space from the training fold.
    let ncomp = z_tr.cols();
    let mut centroids = Mat::zeros(c, ncomp);
    for (j, &i) in tr.iter().enumerate() {
        let l = labels[i];
        for q in 0..ncomp {
            // lint:allow(float_accum, reason = "serial centroid accumulation in canonical sample order; never pool-fanned")
            centroids[(l, q)] += z_tr[(j, q)];
        }
    }
    for l in 0..c {
        let inv = 1.0 / counts[l];
        for q in 0..ncomp {
            centroids[(l, q)] *= inv;
        }
    }
    Ok(nearest_centroid(&z_te, &centroids))
}

/// Scale each column `j` of `m` by `d[j]`.
fn scale_cols(m: &Mat, d: &[f64]) -> Mat {
    assert_eq!(m.cols(), d.len());
    Mat::from_fn(m.rows(), m.cols(), |i, j| m[(i, j)] * d[j])
}

/// The standard approach for multi-class LDA: retrain an optimal-scoring
/// LDA (equivalently, generalised-eig LDA) on every training fold. Baseline
/// for correctness tests and the Fig. 3c/d timings.
pub fn standard_cv_predict(
    x: &Mat,
    labels: &[usize],
    c: usize,
    folds: &[Vec<usize>],
    lambda: f64,
) -> Result<Vec<usize>> {
    super::validate_folds(folds, x.rows())?;
    let mut pred = vec![usize::MAX; x.rows()];
    for te in folds {
        let tr = super::complement(te, x.rows());
        let x_tr = x.take_rows(&tr);
        let l_tr: Vec<usize> = tr.iter().map(|&i| labels[i]).collect();
        let model = crate::model::lda_multiclass::MulticlassLda::train(
            &x_tr,
            &l_tr,
            c,
            crate::model::Reg::Ridge(lambda),
        )?;
        let fold_pred = model.predict(&x.take_rows(te));
        for (j, &i) in te.iter().enumerate() {
            pred[i] = fold_pred[j];
        }
    }
    Ok(pred)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cv::folds::stratified_kfold;
    use crate::model::lda_multiclass::tests::blobs;
    use crate::util::prop::Cases;
    use crate::util::rng::Rng;

    #[test]
    fn exactness_vs_standard_multiclass() {
        // The multi-class analogue of the paper's core claim: Alg. 2
        // predictions equal retrain-per-fold optimal-scoring/gen-eig LDA.
        Cases::new(25).run("analytic == standard (multiclass)", |rng| {
            let c = 3 + rng.below(3);
            let per = 8 + rng.below(10);
            let p = 2 + rng.below(12);
            let (x, labels) = blobs(rng, per, c, p, 2.0);
            let lambda = 10f64.powf(rng.uniform_in(-2.0, 1.0));
            let k = 3 + rng.below(3);
            let folds = stratified_kfold(&labels, k, rng);
            let std_pred = standard_cv_predict(&x, &labels, c, &folds, lambda).unwrap();
            let cv = AnalyticMulticlassCv::fit(&x, &labels, c, lambda).unwrap();
            let ana_pred = cv.predict(&folds).unwrap();
            let mismatches = std_pred.iter().zip(&ana_pred).filter(|(a, b)| a != b).count();
            assert_eq!(mismatches, 0, "predictions differ on {mismatches} samples");
        });
    }

    #[test]
    fn wide_data_multiclass() {
        // P ≫ N regime with ridge — the paper's main use case.
        let mut rng = Rng::new(3);
        let (x, labels) = blobs(&mut rng, 8, 4, 60, 3.0); // N=32, P=60
        let folds = stratified_kfold(&labels, 4, &mut rng);
        let std_pred = standard_cv_predict(&x, &labels, 4, &folds, 1.0).unwrap();
        let cv = AnalyticMulticlassCv::fit(&x, &labels, 4, 1.0).unwrap();
        let ana_pred = cv.predict(&folds).unwrap();
        assert_eq!(std_pred, ana_pred);
    }

    #[test]
    fn separable_blobs_accurate() {
        let mut rng = Rng::new(4);
        let (x, labels) = blobs(&mut rng, 20, 5, 10, 5.0);
        let folds = stratified_kfold(&labels, 5, &mut rng);
        let cv = AnalyticMulticlassCv::fit(&x, &labels, 5, 0.1).unwrap();
        let pred = cv.predict(&folds).unwrap();
        let acc = pred.iter().zip(&labels).filter(|(a, b)| a == b).count() as f64 / 100.0;
        assert!(acc > 0.9, "acc={acc}");
    }

    #[test]
    fn set_labels_permutation_roundtrip() {
        let mut rng = Rng::new(5);
        let (x, labels) = blobs(&mut rng, 10, 3, 6, 2.0);
        let folds = stratified_kfold(&labels, 3, &mut rng);
        let mut cv = AnalyticMulticlassCv::fit(&x, &labels, 3, 0.5).unwrap();
        let cache = FoldCache::prepare(&cv.hat, &folds, true).unwrap();
        let p0 = cv.predict_cached(&cache).unwrap();
        // permuted labels change predictions path but engine stays valid
        let perm = rng.permutation(30);
        let shuffled: Vec<usize> = perm.iter().map(|&i| labels[i]).collect();
        cv.set_labels(&shuffled);
        let p_ref = standard_cv_predict(&x, &shuffled, 3, &folds, 0.5).unwrap();
        let p_ana = cv.predict_cached(&cache).unwrap();
        assert_eq!(p_ana, p_ref, "permuted labels still exact");
        cv.set_labels(&labels);
        assert_eq!(cv.predict_cached(&cache).unwrap(), p0);
    }

    #[test]
    fn stacked_variant_bit_identical_to_serial() {
        let mut rng = Rng::new(7);
        let (x, labels) = blobs(&mut rng, 10, 3, 6, 2.0);
        let folds = stratified_kfold(&labels, 3, &mut rng);
        let mut cv = AnalyticMulticlassCv::fit(&x, &labels, 3, 0.4).unwrap();
        let cache = FoldCache::prepare(&cv.hat, &folds, true).unwrap();
        let b = 4;
        let mut labels_cols: Vec<Vec<usize>> = Vec::new();
        for _ in 0..b {
            let perm = rng.permutation(30);
            labels_cols.push(perm.iter().map(|&i| labels[i]).collect());
        }
        let mut y_stack = Mat::zeros(30, b * 3);
        for (p, lp) in labels_cols.iter().enumerate() {
            for (i, &l) in lp.iter().enumerate() {
                y_stack[(i, p * 3 + l)] = 1.0;
            }
        }
        let stacked = cv.predict_cached_stacked(&cache, &y_stack, &labels_cols).unwrap();
        for (p, lp) in labels_cols.iter().enumerate() {
            cv.set_labels(lp);
            let serial = cv.predict_cached(&cache).unwrap();
            assert_eq!(stacked[p], serial, "stacked perm {p} must equal serial exactly");
        }
    }

    #[test]
    fn backend_equivalence_multiclass_predictions() {
        // Acceptance: the multi-class front-end predicts identically through
        // every backend — wide and tall shapes, several class counts.
        use crate::fastcv::hat::GramBackend;
        let mut rng = Rng::new(31);
        for (per, c, p) in [(8usize, 4usize, 80usize), (15, 3, 6), (10, 5, 120)] {
            let (x, labels) = blobs(&mut rng, per, c, p, 2.5);
            let folds = stratified_kfold(&labels, 4, &mut rng);
            let lambda = 1.5;
            let primal =
                AnalyticMulticlassCv::fit_with(&x, &labels, c, lambda, GramBackend::Primal)
                    .unwrap();
            let pred_p = primal.predict(&folds).unwrap();
            for backend in [GramBackend::Dual, GramBackend::Spectral, GramBackend::Auto] {
                let cv =
                    AnalyticMulticlassCv::fit_with(&x, &labels, c, lambda, backend).unwrap();
                let pred = cv.predict(&folds).unwrap();
                assert_eq!(pred, pred_p, "backend {backend:?} predictions differ (c={c} p={p})");
            }
        }
    }

    #[test]
    fn backend_pool_fit_ctx_bitwise_matches_fit_with() {
        // The pooled multi-class fit must predict identically to the serial
        // one — the pool only fans out the hat build's GEMMs.
        use crate::fastcv::ComputeContext;
        let mut rng = Rng::new(33);
        let (x, labels) = blobs(&mut rng, 8, 4, 70, 2.5); // N=32, P=70
        let folds = stratified_kfold(&labels, 4, &mut rng);
        for backend in [GramBackend::Primal, GramBackend::Dual, GramBackend::Spectral] {
            let serial = AnalyticMulticlassCv::fit_with(&x, &labels, 4, 1.0, backend).unwrap();
            let ctx = ComputeContext::with_threads(4).with_backend(backend);
            let pooled = AnalyticMulticlassCv::fit_ctx(&x, &labels, 4, 1.0, &ctx).unwrap();
            assert_eq!(serial.hat.h.as_slice(), pooled.hat.h.as_slice(), "{backend:?} hat");
            assert_eq!(
                serial.predict(&folds).unwrap(),
                pooled.predict(&folds).unwrap(),
                "{backend:?} predictions"
            );
        }
    }

    #[test]
    fn binary_special_case_matches_binary_engine_predictions() {
        let mut rng = Rng::new(6);
        let (x, labels) = blobs(&mut rng, 15, 2, 5, 2.5);
        let folds = stratified_kfold(&labels, 5, &mut rng);
        let multi = AnalyticMulticlassCv::fit(&x, &labels, 2, 0.2).unwrap();
        let pred_multi = multi.predict(&folds).unwrap();
        let std_pred = standard_cv_predict(&x, &labels, 2, &folds, 0.2).unwrap();
        assert_eq!(pred_multi, std_pred);
    }
}
