//! # Incremental CV engine — sliding-window streaming on a rolling factor
//!
//! The paper's analytic CV machinery assumes a fixed design matrix: build
//! the (augmented, ridged) Gram `G̃ = X̃ᵀX̃ + λI₀` once, factor it, and
//! amortise the factor across folds and permutations. A *streaming* setting
//! breaks that amortisation: each arriving sample would force an `O(NP²)`
//! Gram rebuild plus an `O(P³)` refactor per step.
//!
//! This module restores the amortisation across **time**. The sliding
//! window's factor is maintained by the [`mod@crate::linalg::chol_update`]
//! rotation kernels:
//!
//! - **append** a sample `x` → rank-1 *update* of `L` with `x̃ = [x, 1]`
//!   (`O(P²)`),
//! - **evict** the oldest sample → hyperbolic *downdate* with its `x̃`
//!   (`O(P²)`),
//!
//! so a full window step costs `O(P²)` against the `O(NP² + P³)` rebuild.
//! Centering never recurs: the intercept column of `X̃` carries the mean
//! implicitly (the fitted intercept absorbs it — §2.2's augmented
//! formulation), so append/evict never touch the other rows.
//!
//! ## Drift and the exact-refresh knob
//!
//! Each rotation is backward-stable but not exact: after `t` steps the
//! maintained factor agrees with a from-scratch factorisation to roughly
//! `t · ε · κ(G̃)`. [`StreamConfig::exact_refresh_every`] = `K` bounds the
//! drift by rebuilding the factor exactly every `K` evaluated steps
//! through the *same* `syrk → ridge → factor` code path as
//! [`crate::fastcv::hat::GramCache`]'s primal arm — so the step after a
//! refresh is **bitwise** a from-scratch rebuild. `K = 0` never refreshes
//! (pure incremental); `K = 1` degenerates to the rebuild reference. A
//! failed downdate (the window's Gram drifting to the SPD boundary —
//! [`crate::linalg::chol_downdate`] refuses rather than corrupt the
//! factor) also forces an exact refresh, so the engine cannot silently
//! degrade.
//!
//! ## Determinism
//!
//! The same input sequence under the same [`StreamConfig`] produces the
//! same output bits: folds come from a fixed-seed [`Rng`], the rolling
//! permutation null uses the counter-addressed `Rng::stream` labels of
//! [`crate::fastcv::perm::permuted_labels`] under one anchor, and the
//! update kernels are ISA-invariant (the `kernel_conformance_*` and
//! `stream_*` suites pin this under forced scalar and SIMD dispatch).
//!
//! ## Store lineage
//!
//! With a [`FactorStore`] on the context, the rolling factor lives in the
//! store as an [`crate::store::ArtifactKind::Window`] artifact. A step
//! does not invalidate the previous entry — it **supersedes** it
//! ([`FactorStore::supersede`]): the child key (a running fingerprint of
//! the exact operation sequence) replaces the parent in place and a
//! lineage link keeps stale parent keys resolving to the updated factor.

use crate::cv::folds::kfold;
use crate::cv::metrics::accuracy_signed;
use crate::fastcv::binary::AnalyticBinaryCv;
use crate::fastcv::context::ComputeContext;
use crate::fastcv::hat::HatMatrix;
use crate::fastcv::perm::{p_value, permuted_labels};
use crate::fastcv::FoldCache;
use crate::linalg::{chol_downdate, chol_update, syrk_t_pool, Cholesky, Mat};
use crate::model::lda_binary::signed_codes;
use crate::store::key::Fnv;
use crate::store::{Artifact, ArtifactKey, FactorStore};
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};
use std::collections::VecDeque;
use std::sync::Arc;

/// The sliding-window factor as stored state: the current Cholesky factor
/// of the window's ridged augmented Gram, plus the lineage fingerprint of
/// the operation sequence that produced it (the window artifact's store
/// identity — see [`ArtifactKey::window`]).
#[derive(Clone)]
pub struct WindowFactor {
    /// Cholesky factor of `G̃ = X̃ᵀX̃ + λI₀` over the current window.
    pub chol: Cholesky,
    /// Running FNV digest of the exact build/append/evict sequence.
    pub lineage: u64,
}

impl WindowFactor {
    /// Resident RAM of the factor in bytes (the store's budget currency).
    pub fn resident_bytes(&self) -> usize {
        self.chol.n() * self.chol.n() * 8
    }
}

/// Streaming-engine configuration. Construct with struct-update syntax
/// over [`StreamConfig::default`].
#[derive(Clone, Debug)]
pub struct StreamConfig {
    /// Sliding-window capacity (samples kept live). Must be ≥ `folds`.
    pub window: usize,
    /// Ridge λ; must be > 0 — the unpenalised-intercept augmented Gram is
    /// SPD for any n ≥ 1 exactly when λ > 0, which is what makes the
    /// window factor maintainable from the first evaluated step.
    pub lambda: f64,
    /// CV fold count `k` (≥ 2).
    pub folds: usize,
    /// Rolling permutation-null size per step; 0 disables the null.
    pub n_perm: usize,
    /// Master seed: folds and the permutation anchor derive from it.
    pub seed: u64,
    /// Exact-refresh period `K`: every `K` evaluated steps the factor is
    /// rebuilt from scratch (bitwise the rebuild path). 0 = never.
    pub exact_refresh_every: usize,
    /// Reference mode: rebuild the factor from scratch on *every* step
    /// instead of maintaining it (what the incremental path is measured
    /// and tested against).
    pub rebuild: bool,
}

impl Default for StreamConfig {
    fn default() -> StreamConfig {
        StreamConfig {
            window: 64,
            lambda: 1.0,
            folds: 5,
            n_perm: 0,
            seed: 42,
            exact_refresh_every: 0,
            rebuild: false,
        }
    }
}

/// One evaluated stream step's outputs.
#[derive(Clone, Debug)]
pub struct StepResult {
    /// 1-based count of samples ingested so far.
    pub step: u64,
    /// Current window size (≤ `window`).
    pub n: usize,
    /// k-fold CV accuracy over the current window.
    pub accuracy: f64,
    /// Rolling permutation p-value (`None` when `n_perm = 0`).
    pub p_value: Option<f64>,
    /// Whether this step's factor came from an exact rebuild (first
    /// build, `--exact-refresh-every` firing, reference mode, or a
    /// downdate rescue).
    pub refreshed: bool,
    /// Whether a sample was evicted from the window this step.
    pub evicted: bool,
}

/// The streaming driver: feed samples with [`SlidingWindowCv::push`], get
/// a [`StepResult`] back once the window holds enough samples to evaluate
/// (`max(folds, 2)`).
pub struct SlidingWindowCv<'p> {
    cfg: StreamConfig,
    ctx: ComputeContext<'p>,
    window: VecDeque<(Vec<f64>, usize)>,
    /// Feature dimension, pinned by the first sample.
    dim: Option<usize>,
    factor: Option<Arc<WindowFactor>>,
    /// Store key of the currently published factor (lineage head).
    store_key: Option<ArtifactKey>,
    anchor: u64,
    fold_seed: u64,
    step: u64,
    since_refresh: usize,
    /// Evaluated steps whose factor was maintained incrementally (the
    /// complement of refreshes — surfaced for tests/benches).
    pub incremental_steps: u64,
    /// Exact refreshes forced by a refused downdate (SPD-boundary rescue).
    pub downdate_rescues: u64,
}

impl<'p> SlidingWindowCv<'p> {
    /// Validate `cfg` and bind the driver to a context (pool, store, ISA).
    pub fn new(cfg: StreamConfig, ctx: ComputeContext<'p>) -> Result<SlidingWindowCv<'p>> {
        if !(cfg.lambda > 0.0) {
            bail!("streaming CV requires ridge λ > 0 (got {})", cfg.lambda);
        }
        if cfg.folds < 2 {
            bail!("streaming CV needs k ≥ 2 folds (got {})", cfg.folds);
        }
        if cfg.window < cfg.folds {
            bail!("window ({}) must hold at least k = {} samples", cfg.window, cfg.folds);
        }
        // One anchor for the whole stream (the perm engines' discipline:
        // draw once, then address permutations by counter).
        let mut rng = Rng::new(cfg.seed);
        let anchor = rng.next_u64();
        let fold_seed = rng.next_u64();
        Ok(SlidingWindowCv {
            cfg,
            ctx,
            window: VecDeque::new(),
            dim: None,
            factor: None,
            store_key: None,
            anchor,
            fold_seed,
            step: 0,
            since_refresh: 0,
            incremental_steps: 0,
            downdate_rescues: 0,
        })
    }

    /// Ingest one sample. Returns `None` while the window is still
    /// filling; afterwards, the step's rolling CV result.
    pub fn push(&mut self, x: Vec<f64>, label: usize) -> Result<Option<StepResult>> {
        let dim = *self.dim.get_or_insert(x.len());
        if x.len() != dim {
            bail!("sample {} has {} features, stream started with {dim}", self.step + 1, x.len());
        }
        self.step += 1;
        // A scheduled exact refresh makes this step's rotations dead work
        // (the factor is rebuilt from scratch below), so decide first and
        // skip them — also keeps a downdate refused during dead work from
        // counting as a rescue.
        let refresh_due = self.cfg.exact_refresh_every > 0
            && self.since_refresh + 1 >= self.cfg.exact_refresh_every;
        let maintain = !self.cfg.rebuild && !refresh_due;
        let mut evicted = false;
        // Evict the oldest sample once the window is at capacity —
        // downdating the factor with its augmented row. A refused
        // downdate (SPD boundary) drops the factor; the rebuild branch
        // below restores it exactly.
        if self.window.len() == self.cfg.window {
            if let Some((old_x, _)) = self.window.pop_front() {
                evicted = true;
                if maintain {
                    if let Some(f) = self.factor.as_mut() {
                        let wf = Arc::make_mut(f);
                        let v = augmented(&old_x);
                        if chol_downdate(&mut wf.chol, &v).is_ok() {
                            wf.lineage = lineage_op(wf.lineage, b'e', &v);
                        } else {
                            self.factor = None;
                            self.downdate_rescues += 1;
                        }
                    }
                }
            }
        }
        // Append the new sample: rank-1 update with x̃ = [x, 1]. The mean
        // is never recentred — the intercept column carries it.
        if maintain {
            if let Some(f) = self.factor.as_mut() {
                let wf = Arc::make_mut(f);
                let v = augmented(&x);
                chol_update(&mut wf.chol, &v);
                wf.lineage = lineage_op(wf.lineage, b'a', &v);
            }
        }
        self.window.push_back((x, label));
        let n = self.window.len();
        if n < self.cfg.folds.max(2) {
            return Ok(None);
        }
        let refreshed = self.factor.is_none() || self.cfg.rebuild || refresh_due;
        if refreshed {
            self.refresh_exact()?;
            self.since_refresh = 0;
        } else {
            self.since_refresh += 1;
            self.incremental_steps += 1;
        }
        self.publish();
        match self.factor.clone() {
            Some(wf) => Ok(Some(self.evaluate(&wf, refreshed, evicted)?)),
            None => bail!("stream step {}: no factor after refresh", self.step),
        }
    }

    /// Borrow the current rolling factor (None while the window fills).
    pub fn factor(&self) -> Option<&WindowFactor> {
        self.factor.as_deref()
    }

    /// Rebuild the factor from scratch over the current window — the same
    /// `syrk_t_pool → ridge(I₀) → Cholesky::factor` sequence as the
    /// primal [`crate::fastcv::hat::GramCache`] arm, so the result is
    /// bitwise what a non-streaming build would produce. Consults the
    /// store first with the non-lineage-following [`FactorStore::get`]:
    /// only a factor still live under this *exact* content key (same
    /// window bytes, same λ) is a hit. Supersession links are never
    /// followed here — on a low-entropy stream the window bytes can
    /// repeat an earlier refresh step's, whose key has since been
    /// superseded by drifted incremental factors; serving the descendant
    /// would silently break the bitwise-rebuild contract (and neuter the
    /// refused-downdate rescue, which relies on this path being exact).
    fn refresh_exact(&mut self) -> Result<()> {
        let xa = self.window_x().augment_ones();
        let lineage = lineage_exact(&xa);
        if let Some(store) = self.ctx.store() {
            let key = ArtifactKey::window(lineage, self.cfg.lambda);
            if let Some(wf) = store.get_window(&key) {
                debug_assert_eq!(wf.lineage, lineage, "window entry keyed under foreign lineage");
                self.factor = Some(wf);
                return Ok(());
            }
        }
        let p1 = xa.cols();
        let mut g = syrk_t_pool(&xa, self.ctx.pool());
        for i in 0..p1 - 1 {
            // lint:allow(float_accum, reason = "ridge diagonal add: each entry touched exactly once — order-free")
            g[(i, i)] += self.cfg.lambda;
        }
        let ch = Cholesky::factor(&g)
            .context("window gram not SPD — degenerate window (duplicate rows with λ≈0?)")?;
        self.factor = Some(Arc::new(WindowFactor { chol: ch, lineage }));
        Ok(())
    }

    /// Route the current factor through the store's lineage API: the
    /// first publication is a [`FactorStore::put`]; every later one
    /// supersedes the previous step's key in place.
    fn publish(&mut self) {
        let (Some(store), Some(wf)) = (self.ctx.store(), self.factor.as_ref()) else {
            return;
        };
        let child = ArtifactKey::window(wf.lineage, self.cfg.lambda);
        if self.store_key.as_ref() == Some(&child) {
            return; // store hit on refresh — already live under this key
        }
        match self.store_key.take() {
            None => store.put(child.clone(), Artifact::Window(Arc::clone(wf))),
            Some(parent) => store.supersede(&parent, child.clone(), Artifact::Window(Arc::clone(wf))),
        }
        self.store_key = Some(child);
    }

    /// Current window as an N×P matrix (oldest sample first).
    fn window_x(&self) -> Mat {
        let n = self.window.len();
        let p = self.dim.unwrap_or(0);
        Mat::from_fn(n, p, |i, j| self.window[i].0[j])
    }

    /// Rolling k-fold CV (and optional permutation null) on the current
    /// factor: the factor is handed to [`HatMatrix::from_primal_factor`],
    /// so the solve → hat → fold-cache → decision-value chain is exactly
    /// the batch engine's.
    fn evaluate(&self, wf: &WindowFactor, refreshed: bool, evicted: bool) -> Result<StepResult> {
        let n = self.window.len();
        let xa = self.window_x().augment_ones();
        let labels: Vec<usize> = self.window.iter().map(|(_, l)| *l).collect();
        let y = signed_codes(&labels);
        let hat =
            HatMatrix::from_primal_factor(&xa, wf.chol.clone(), self.cfg.lambda, self.ctx.pool());
        let folds = kfold(n, self.cfg.folds, &mut Rng::new(self.fold_seed));
        let acv = AnalyticBinaryCv::with_hat(hat, &y);
        let cache = FoldCache::prepare_pool(&acv.hat, &folds, false, self.ctx.pool())
            .with_context(|| format!("stream step {}: fold cache", self.step))?;
        let dvals = acv.decision_values_cached(&cache);
        let accuracy = accuracy_signed(&dvals, &y);
        let p_val = if self.cfg.n_perm > 0 {
            let b = self.cfg.n_perm;
            let perms: Vec<Vec<f64>> = (0..b)
                .map(|t| signed_codes(&permuted_labels(&labels, self.anchor, t as u64)))
                .collect();
            let ys = Mat::from_fn(n, b, |i, t| perms[t][i]);
            let dmat = acv.decision_values_cached_mat(&cache, &ys);
            let null: Vec<f64> = (0..b)
                .map(|t| {
                    let col: Vec<f64> = (0..n).map(|i| dmat[(i, t)]).collect();
                    accuracy_signed(&col, &perms[t])
                })
                .collect();
            Some(p_value(accuracy, &null))
        } else {
            None
        };
        Ok(StepResult { step: self.step, n, accuracy, p_value: p_val, refreshed, evicted })
    }
}

/// `x̃ = [x, 1]` — one augmented design row (the update/downdate vector).
fn augmented(x: &[f64]) -> Vec<f64> {
    let mut v = Vec::with_capacity(x.len() + 1);
    v.extend_from_slice(x);
    v.push(1.0);
    v
}

/// Lineage fingerprint of an exact build over the augmented window.
fn lineage_exact(xa: &Mat) -> u64 {
    let mut h = Fnv::new().str("exact").word(xa.rows() as u64).word(xa.cols() as u64);
    for v in xa.as_slice() {
        h = h.word(v.to_bits());
    }
    h.finish()
}

/// Lineage transition for one append (`op = b'a'`) or evict (`op = b'e'`).
fn lineage_op(parent: u64, op: u8, v: &[f64]) -> u64 {
    let mut h = Fnv::new().word(parent).word(u64::from(op)).word(v.len() as u64);
    for x in v {
        h = h.word(x.to_bits());
    }
    h.finish()
}
