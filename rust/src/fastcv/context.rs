//! A shared compute context for the analytic front-ends.
//!
//! PR 2's Gram backends made the hat-matrix construction asymptotically
//! right for every N/P regime, but the analytic front-ends still built it
//! serially — `fit_with`, `search_lambda`, and the permutation engines all
//! passed `pool: None` to the `K_c`/`G₀` builds, so a single large-P job
//! left most cores idle unless it went through the coordinator's sweep
//! fan-out. [`ComputeContext`] closes that gap: one value that carries
//!
//! * a [`ThreadPool`] — **owned** ([`ComputeContext::with_threads`]) or
//!   **borrowed** ([`ComputeContext::borrowing`]) so a caller that already
//!   runs a pool (the coordinator, a bench harness) can lend it instead of
//!   spawning another;
//! * the [`GramBackend`] policy for every hat built under the context;
//! * cache-reuse knobs — currently
//!   [`ComputeContext::with_nested_sharing`], which lets
//!   [`crate::fastcv::lambda_search::nested_cv_ctx`] share one full-data
//!   Gram across all outer folds via the Eq. 9–12-style downdate;
//! * a [`TilePolicy`] for the `N×N` Gram builds and their Cholesky —
//!   `Off` (default) keeps the historical one-shot kernels bitwise, the
//!   tiled modes bound transient slabs for the §4.5 big-data regime (see
//!   [`crate::linalg::tiled`]).
//!
//! ## Determinism
//!
//! A pooled context never changes results, only wall-clock: every kernel
//! the pool reaches ([`crate::linalg::matmul_pool`],
//! [`crate::linalg::syrk_t_pool`]) is bit-identical to its serial
//! counterpart by construction, so `fit_ctx`/`search_lambda_ctx`/the perm
//! `_ctx` engines produce byte-equal outputs for any thread count
//! (property-tested as `backend_pool_*` tests). The reuse knobs are the
//! exception and are therefore opt-in: nested-fold Gram sharing changes the
//! float path (agreement is tested at tolerance, not bitwise).

use super::fault::{self, FaultPlan};
use super::hat::GramBackend;
use crate::linalg::{dispatch, Isa, TilePolicy};
use crate::store::FactorStore;
use crate::util::threadpool::ThreadPool;
use anyhow::Result;
use std::sync::Arc;

/// An owned-or-borrowed pool handle.
enum PoolRef<'p> {
    Owned(ThreadPool),
    Borrowed(&'p ThreadPool),
}

/// Shared compute policy for the analytic front-ends: an optional thread
/// pool, a [`GramBackend`], and cache-reuse knobs. See the module docs.
///
/// The default context ([`ComputeContext::serial`]) is serial,
/// [`GramBackend::Auto`], no reuse knobs — handing it to a `_ctx` entry
/// point reproduces the corresponding `_backend` entry point with `Auto`.
#[derive(Default)]
pub struct ComputeContext<'p> {
    pool: Option<PoolRef<'p>>,
    backend: GramBackend,
    nested_sharing: bool,
    tile_policy: TilePolicy,
    store: Option<&'p FactorStore>,
}

impl std::fmt::Debug for ComputeContext<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ComputeContext")
            .field("threads", &self.threads())
            .field("backend", &self.backend)
            .field("nested_sharing", &self.nested_sharing)
            .field("tile_policy", &self.tile_policy)
            .field("store", &self.store.is_some())
            .finish()
    }
}

impl<'p> ComputeContext<'p> {
    /// No pool, [`GramBackend::Auto`], no reuse knobs.
    pub fn serial() -> Self {
        Self::default()
    }

    /// Own a fresh pool of `threads` workers. `threads ≤ 1` spawns no pool
    /// at all (serial context), so a CLI `--threads 1` costs nothing.
    pub fn with_threads(threads: usize) -> Self {
        let pool = (threads > 1).then(|| PoolRef::Owned(ThreadPool::new(threads)));
        ComputeContext { pool, ..Self::default() }
    }

    /// Borrow an existing pool for the context's lifetime.
    pub fn borrowing(pool: &'p ThreadPool) -> Self {
        ComputeContext { pool: Some(PoolRef::Borrowed(pool)), ..Self::default() }
    }

    /// Set the [`GramBackend`] policy (builder style).
    pub fn with_backend(mut self, backend: GramBackend) -> Self {
        self.backend = backend;
        self
    }

    /// Enable/disable nested-CV Gram sharing across outer folds (builder
    /// style). Off by default: it trades bitwise reproduction of the
    /// per-fold rebuild for an `O(N²P)` → `O(N_tr²)` per-fold Gram cost
    /// (see [`crate::fastcv::lambda_search::nested_cv_ctx`]).
    pub fn with_nested_sharing(mut self, on: bool) -> Self {
        self.nested_sharing = on;
        self
    }

    /// Set the [`TilePolicy`] for the `N×N` Gram builds and their Cholesky
    /// (builder style). [`TilePolicy::Off`] — the default — keeps the
    /// historical one-shot kernels; the tiled modes are **bit-identical**
    /// to them (`tiled_*` property tests) but bound every transient slab
    /// beyond the factor itself to `O(tile)` rows — the §4.5 memory-bounded
    /// regime — and [`TilePolicy::Spill`] removes the resident factor too,
    /// persisting Gram/factor panels through the
    /// [`crate::linalg::spill`] layer (`spill_*` property tests). Surfaced
    /// on the CLI as `--tile-rows` / `--mem-budget` / `--spill-dir`.
    pub fn with_tile_policy(mut self, tile: TilePolicy) -> Self {
        self.tile_policy = tile;
        self
    }

    /// Lend a [`FactorStore`] to every factor build under this context
    /// (builder style). With a store, the `_ctx` entry points fetch their
    /// [`crate::fastcv::hat::GramCache`] / nested-Gram /
    /// [`crate::fastcv::bigdata::StreamingHat`] state through the keyed
    /// cache ([`crate::store::gram_for_ctx`] and siblings) instead of
    /// rebuilding per call; a hit serves the **same floats** a fresh build
    /// would (the store's bitwise contract), so this knob — like the pool
    /// and tile knobs — never moves a result. Without one (the default)
    /// every historical build path runs untouched.
    pub fn with_store(mut self, store: &'p FactorStore) -> Self {
        self.store = Some(store);
        self
    }

    /// Pin the `linalg` microkernel ISA (builder style) — the
    /// [`crate::linalg::dispatch`] knob. Unlike the other builder knobs
    /// this override is **process-wide** (kernel dispatch is a single
    /// global table, like `FASTCV_FORCE_ISA`), installed here so CLI/API
    /// callers configure everything through one context value; the last
    /// context to set it wins. Errors on an ISA the CPU cannot run. Like
    /// the pool/tile/store knobs it never moves a result: every ISA's
    /// kernels are bitwise-identical (the `kernel_conformance_*`
    /// contract), so this is a wall-clock/testing lever only. Surfaced on
    /// the CLI as `--isa scalar|avx2|neon`.
    pub fn with_isa(self, isa: Isa) -> Result<Self> {
        dispatch::force_isa(Some(isa))?;
        Ok(self)
    }

    /// The ISA the next kernel call under this (or any) context will run —
    /// reads the process-wide dispatch state.
    pub fn isa(&self) -> Isa {
        dispatch::active()
    }

    /// Install a deterministic [`FaultPlan`] (builder style) — the
    /// [`crate::fastcv::fault`] knob. Like [`ComputeContext::with_isa`]
    /// this override is **process-wide** (fault sites live in layers —
    /// panel files, daemon workers — that no per-call context reaches);
    /// the last context to set it wins, and `FASTCV_FAULT_PLAN` supplies
    /// a plan when no context installed one. Intended for chaos tests and
    /// drills only: with no plan active every fault site is a no-op.
    pub fn with_faults(self, plan: Arc<FaultPlan>) -> Self {
        fault::set_plan(Some(plan));
        self
    }

    /// The active fault plan, if any — reads the process-wide fault
    /// state, like [`ComputeContext::isa`].
    pub fn faults(&self) -> Option<Arc<FaultPlan>> {
        fault::global()
    }

    /// The lent [`FactorStore`], if any.
    pub fn store(&self) -> Option<&'p FactorStore> {
        self.store
    }

    /// The Gram backend policy.
    pub fn backend(&self) -> GramBackend {
        self.backend
    }

    /// Resolve this context's backend for a λ-grid (`positives` positive
    /// candidates on an `n×p` shape), **accounting for the tile policy**:
    /// under [`TilePolicy::Spill`], an `Auto` that would pick `Spectral`
    /// picks `Dual` instead — the spectral eigenvector matrix is an
    /// irreducible resident `N×N`, which is exactly what `--spill-dir`
    /// asks to avoid, while the dual per-λ Cholesky streams fully out of
    /// core (each candidate pays an `N³/3` spilled factor instead of
    /// sharing one eigendecomposition; winners agree across backends per
    /// the `backend_*` equivalence contract). An *explicit* backend —
    /// including `Spectral` — is always honoured.
    pub fn resolve_for_grid(&self, n: usize, p: usize, positives: usize) -> GramBackend {
        self.backend.resolve_for_grid_spill_aware(n, p, positives, &self.tile_policy)
    }

    /// The tiling policy for `N×N` Gram builds ([`TilePolicy::Off`] by
    /// default). Returned by clone — the `Spill` variant carries its
    /// spill-directory path.
    pub fn tile_policy(&self) -> TilePolicy {
        self.tile_policy.clone()
    }

    /// Whether nested CV may share one full-data Gram across outer folds.
    pub fn nested_sharing(&self) -> bool {
        self.nested_sharing
    }

    /// The pool to fan kernels over, if any.
    pub fn pool(&self) -> Option<&ThreadPool> {
        match &self.pool {
            None => None,
            Some(PoolRef::Owned(p)) => Some(p),
            Some(PoolRef::Borrowed(p)) => Some(p),
        }
    }

    /// Worker count (1 when serial).
    pub fn threads(&self) -> usize {
        self.pool().map_or(1, ThreadPool::size)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_context_has_no_pool_and_auto_backend() {
        let ctx = ComputeContext::serial();
        assert!(ctx.pool().is_none());
        assert_eq!(ctx.threads(), 1);
        assert_eq!(ctx.backend(), GramBackend::Auto);
        assert!(!ctx.nested_sharing());
    }

    #[test]
    fn with_threads_owns_a_pool_only_above_one() {
        assert!(ComputeContext::with_threads(0).pool().is_none());
        assert!(ComputeContext::with_threads(1).pool().is_none());
        let ctx = ComputeContext::with_threads(3);
        assert_eq!(ctx.threads(), 3);
        assert!(ctx.pool().is_some());
    }

    #[test]
    fn borrowing_lends_the_callers_pool() {
        let pool = ThreadPool::new(2);
        let ctx = ComputeContext::borrowing(&pool);
        assert_eq!(ctx.threads(), 2);
        assert!(std::ptr::eq(ctx.pool().unwrap(), &pool));
    }

    #[test]
    fn builder_knobs() {
        let ctx = ComputeContext::serial()
            .with_backend(GramBackend::Spectral)
            .with_nested_sharing(true)
            .with_tile_policy(TilePolicy::Rows(32));
        assert_eq!(ctx.backend(), GramBackend::Spectral);
        assert!(ctx.nested_sharing());
        assert_eq!(ctx.tile_policy(), TilePolicy::Rows(32));
        let dbg = format!("{ctx:?}");
        assert!(dbg.contains("Spectral"), "{dbg}");
        assert!(dbg.contains("Rows"), "{dbg}");
    }

    #[test]
    fn tiled_default_context_tiling_is_off() {
        assert!(ComputeContext::serial().tile_policy().is_off());
        assert!(ComputeContext::with_threads(2).tile_policy().is_off());
    }

    #[test]
    fn store_knob_is_off_by_default_and_borrowable() {
        assert!(ComputeContext::serial().store().is_none());
        let store = FactorStore::new();
        let ctx = ComputeContext::serial().with_store(&store);
        assert!(std::ptr::eq(ctx.store().unwrap(), &store));
        let dbg = format!("{ctx:?}");
        assert!(dbg.contains("store: true"), "{dbg}");
    }

    #[test]
    fn isa_knob_rejects_unsupported_and_reads_active() {
        // The reject path writes no global state, so this cannot race the
        // dispatch force_scope tests. (The install path is pinned by
        // dispatch::tests and the kernel-conformance suite.)
        for isa in [Isa::Avx2, Isa::Neon] {
            if !isa.is_supported() {
                assert!(ComputeContext::serial().with_isa(isa).is_err(), "{isa}");
            }
        }
        assert!(ComputeContext::serial().isa().is_supported());
    }

    #[test]
    fn faults_knob_installs_a_process_wide_plan() {
        // Hold a fault scope so this test serialises with every other
        // fault-state test, then layer the context knob on top; the scope
        // drop restores the pre-test state either way.
        let _scope = fault::install(FaultPlan::parse("ctx.other@1").unwrap());
        let ctx = ComputeContext::serial()
            .with_faults(Arc::new(FaultPlan::parse("ctx.site@1").unwrap()));
        assert!(ctx.faults().is_some());
        assert_eq!(fault::hit("ctx.site"), Some(0));
        assert_eq!(fault::hit("ctx.site"), None, "@1 fires once");
    }

    #[test]
    fn spill_auto_grid_resolution_prefers_dual_out_of_core() {
        // --spill-dir asks for no resident square; a spectral cache cannot
        // provide that (its eigenvector matrix is N×N), so Auto λ-grid
        // resolution under a Spill policy picks the fully-streamable Dual.
        let spill = TilePolicy::Spill { dir: None, tile: 8 };
        let ctx = ComputeContext::serial().with_tile_policy(spill.clone());
        assert_eq!(ctx.resolve_for_grid(20, 100, 4), GramBackend::Dual);
        // without spill, the usual spectral upgrade
        assert_eq!(
            ComputeContext::serial().resolve_for_grid(20, 100, 4),
            GramBackend::Spectral
        );
        // tall shapes keep primal either way
        assert_eq!(ctx.resolve_for_grid(100, 20, 4), GramBackend::Primal);
        // a single positive candidate was dual already
        assert_eq!(ctx.resolve_for_grid(20, 100, 1), GramBackend::Dual);
        // an explicit Spectral request is honoured (assembly-tiled only)
        let explicit = ComputeContext::serial()
            .with_backend(GramBackend::Spectral)
            .with_tile_policy(spill);
        assert_eq!(explicit.resolve_for_grid(20, 100, 4), GramBackend::Spectral);
    }
}
