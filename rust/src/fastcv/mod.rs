//! The paper's contribution: **analytical cross-validation** for
//! least-squares models and multi-class LDA.
//!
//! - [`hat`] — the hat matrix `H = X̃ (X̃ᵀX̃+λI₀)⁻¹ X̃ᵀ` and fold blocks
//! - [`binary`] — exact k-fold CV decision values for binary LDA /
//!   (ridge) regression from a single full-data fit (Eq. 14), with the
//!   `b_LDA` bias adjustment (Eq. 15)
//! - [`multiclass`] — the optimal-scoring extension (Alg. 2)
//! - [`perm`] — permutation testing with a shared hat matrix (Alg. 1)
//! - [`perm_batch`] — the batched, thread-parallel permutation engine
//! - [`woodbury`] — the intermediate Woodbury identities (Eq. 9–12), kept
//!   as a verifiable derivation and an ablation path
//! - [`bigdata`] — §4.5's scaling strategies: streaming hat blocks (no
//!   `N×N` materialisation), sparse random projections, LDA ensembles
//!
//! ## Batched permutation design
//!
//! Permutation testing is where the analytic approach pays off most
//! (Fig. 3b/3d, Fig. 4): `H` and the per-fold `(I − H_Te)` LU factors are
//! label-invariant (§2.7), so only `ŷ = H·y^σ` and the fold solves change
//! per permutation. [`perm_batch`] pushes the reuse one level further by
//! stacking `B` permuted responses into an `N×B` matrix: the per-permutation
//! matvec/solve stream becomes one GEMM plus one multi-RHS solve per fold
//! per batch, and batches fan out over the
//! [`ThreadPool`](crate::util::threadpool::ThreadPool). The matrix-response
//! entry points are [`binary::AnalyticBinaryCv::decision_values_cached_mat`],
//! [`binary::AnalyticBinaryCv::decision_values_bias_adjusted_mat`], and
//! [`multiclass::AnalyticMulticlassCv::predict_cached_stacked`].
//!
//! ### RNG-stream determinism contract
//!
//! Every permutation engine draws exactly **one** `u64` anchor from the
//! caller's RNG and derives permutation `t` as
//! [`perm::permuted_labels`]`(labels, anchor, t)`, an independent shuffle
//! from the counter-seeded [`Rng::stream`](crate::util::rng::Rng::stream).
//! Permutations are addressable by index: serial, batched, and
//! batched+threaded engines produce bit-identical null distributions for
//! any batch size and thread count, and two engines handed RNGs in the
//! same state see identical permutations. Changing the batching strategy
//! can therefore never change a scientific result — only wall-clock.
//!
//! ## Gram backends and the selection rule
//!
//! Every analytic front-end runs off the same hat matrix, but *how* that
//! matrix is built is a [`GramBackend`] choice with asymptotically
//! different costs (full derivations in [`hat`]'s module docs):
//!
//! | backend    | cost per hat        | best when                     |
//! |------------|---------------------|-------------------------------|
//! | `Primal`   | `O(NP² + P³)`       | N ≫ P, or λ = 0               |
//! | `Dual`     | `O(N²P + N³)`       | P ≫ N, single λ (λ > 0)       |
//! | `Spectral` | `O(N²P + N³)` once, then `O(N³)` per λ | P ≫ N, λ grids |
//!
//! `Auto` resolves by the P/N ratio: a single hat picks `Dual` when
//! `λ > 0 ∧ P > N` and `Primal` otherwise
//! ([`hat::GramBackend::resolve`]); a λ-grid caller
//! ([`lambda_search::search_lambda`]) upgrades the wide case to `Spectral`
//! as soon as ≥ 2 positive candidates amortise the eigendecomposition
//! ([`hat::GramBackend::resolve_for_grid`]). The backends agree to ~1e-8 on
//! decision values (property-tested as `backend_*` tests across this
//! module), so the choice is a pure wall-clock knob — exposed as
//! `--backend primal|dual|spectral|auto` on the CLI sweep alongside
//! `--engine`. The permutation engines' *default* backend is `Auto` (the
//! ROADMAP `Primal` → `Auto` flip): the hat is shared per run and null
//! accuracies are 1/N-quantised, so the ~1e-9 cross-backend hat roundoff
//! only moves a recorded null when a decision value sits within that
//! roundoff of the threshold — invariance is pinned on fixed-seed grids
//! by the golden contract in [`perm_batch`], and the `_backend` entry
//! points reproduce the historical `Primal` build exactly on demand.
//!
//! ## The compute context
//!
//! All Gram builds — the dual/spectral `K_c = X_cX_cᵀ` GEMM
//! ([`crate::linalg::matmul_pool`]), the primal `G₀ = X̃ᵀX̃` syrk
//! ([`crate::linalg::syrk_t_pool`]), and the per-candidate hat GEMMs — can
//! fan out over a [`ThreadPool`](crate::util::threadpool::ThreadPool).
//! Rather than threading a bare pool through every signature, the analytic
//! front-ends take a [`context::ComputeContext`] (owned or borrowed pool +
//! backend policy + cache-reuse knobs) through their `_ctx` entry points:
//! [`binary::AnalyticBinaryCv::fit_ctx`],
//! [`multiclass::AnalyticMulticlassCv::fit_ctx`],
//! [`lambda_search::search_lambda_ctx`],
//! [`lambda_search::search_lambda_multiclass`],
//! [`lambda_search::nested_cv_ctx`], and the four permutation engines
//! ([`perm::analytic_binary_permutation_ctx`],
//! [`perm::analytic_multiclass_permutation_ctx`],
//! [`perm_batch::analytic_binary_permutation_batched_ctx`],
//! [`perm_batch::analytic_multiclass_permutation_batched_ctx`]). Every
//! pooled kernel is bit-identical to its serial counterpart, so a context
//! never changes results — only wall-clock (property-tested as
//! `backend_pool_*` tests). The historical no-pool entry points (`fit`,
//! `fit_with`, `search_lambda`, the `_backend` engines) delegate to the
//! `_ctx` forms with a serial context and keep their bitwise outputs.

pub mod bigdata;
pub mod binary;
pub mod context;
pub mod fault;
pub mod hat;
pub mod incremental;
pub mod lambda_search;
pub mod multiclass;
pub mod perm;
pub mod perm_batch;
pub mod woodbury;

pub use context::ComputeContext;
pub use incremental::{SlidingWindowCv, StepResult, StreamConfig, WindowFactor};
pub use crate::linalg::TilePolicy;
pub use hat::{GramBackend, GramCache, SharedNestedGram, SpectralGram};

use crate::linalg::{Lu, Mat};
use crate::util::threadpool::ThreadPool;
use anyhow::{Context, Result};
use hat::HatMatrix;

/// Per-fold factorisations reusable across label permutations.
///
/// `(I − H_Te)` depends on features only (§2.7), so its LU factor is
/// computed once per fold and reused for every permutation — the single
/// biggest constant-factor win on the permutation path (see EXPERIMENTS.md
/// §Perf for the measured effect and `benches/ablation_updates.rs`).
pub struct FoldCache {
    /// Test-index set per fold.
    pub folds: Vec<Vec<usize>>,
    /// Train-index set per fold (complement).
    pub trains: Vec<Vec<usize>>,
    /// LU factor of `I − H_Te` per fold.
    pub lus: Vec<Lu>,
    /// `H_{Tr,Te}` per fold; present when bias adjustment or multi-class
    /// CV (which needs `Ẏ_Tr`) was requested.
    pub cross: Option<Vec<Mat>>,
}

impl FoldCache {
    /// Factor every fold of a partition. `with_cross` additionally gathers
    /// the `H_{Tr,Te}` blocks needed by Eq. 15 / Alg. 2.
    pub fn prepare(hat: &HatMatrix, folds: &[Vec<usize>], with_cross: bool) -> Result<FoldCache> {
        Self::prepare_pool(hat, folds, with_cross, None)
    }

    /// [`FoldCache::prepare`] with the per-fold `(I − H_Te)` LU factors
    /// fanned out **fold-wise** over `pool` — folds are independent, each
    /// factor's arithmetic is untouched, so the cache is bit-identical to
    /// the serial one for any pool size. This was the last serial section
    /// of a pooled λ search; the `_ctx` front-ends route here.
    pub fn prepare_pool(
        hat: &HatMatrix,
        folds: &[Vec<usize>],
        with_cross: bool,
        pool: Option<&ThreadPool>,
    ) -> Result<FoldCache> {
        let n = hat.n();
        validate_folds(folds, n)?;
        let trains: Vec<Vec<usize>> = folds.iter().map(|te| complement(te, n)).collect();
        let fold_err = |k: usize| {
            format!(
                "fold {k}: (I − H_Te) singular — the fold model itself is \
                 degenerate (λ=0 with P ≥ N_train?); increase ridge λ"
            )
        };
        let lus: Vec<Lu> = match pool {
            Some(pool) if pool.size() > 1 && folds.len() > 1 => pool
                .map(folds.len(), |k| Lu::factor(&hat.i_minus_block(&folds[k])))
                .into_iter()
                .enumerate()
                .map(|(k, r)| r.with_context(|| fold_err(k)))
                .collect::<Result<Vec<_>>>()?,
            _ => {
                let mut lus = Vec::with_capacity(folds.len());
                for (k, te) in folds.iter().enumerate() {
                    let m = hat.i_minus_block(te);
                    lus.push(Lu::factor(&m).with_context(|| fold_err(k))?);
                }
                lus
            }
        };
        let cross = if with_cross {
            Some(
                folds
                    .iter()
                    .zip(&trains)
                    .map(|(te, tr)| hat.cross_block(tr, te))
                    .collect(),
            )
        } else {
            None
        };
        Ok(FoldCache { folds: folds.to_vec(), trains, lus, cross })
    }

    /// Number of folds.
    pub fn k(&self) -> usize {
        self.folds.len()
    }
}

/// Sorted complement of `te` within `0..n`.
pub fn complement(te: &[usize], n: usize) -> Vec<usize> {
    let mut in_te = vec![false; n];
    for &i in te {
        in_te[i] = true;
    }
    (0..n).filter(|&i| !in_te[i]).collect()
}

/// Check a fold partition: non-empty disjoint test sets covering subsets of
/// `0..n`, each leaving a non-empty training set.
pub fn validate_folds(folds: &[Vec<usize>], n: usize) -> Result<()> {
    if folds.is_empty() {
        anyhow::bail!("no folds supplied");
    }
    let mut seen = vec![false; n];
    for (k, te) in folds.iter().enumerate() {
        if te.is_empty() {
            anyhow::bail!("fold {k} has an empty test set");
        }
        if te.len() >= n {
            anyhow::bail!("fold {k} leaves no training samples");
        }
        for &i in te {
            if i >= n {
                anyhow::bail!("fold {k}: index {i} out of range (n={n})");
            }
            if seen[i] {
                anyhow::bail!("sample {i} appears in more than one test set");
            }
            seen[i] = true;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn complement_basic() {
        assert_eq!(complement(&[1, 3], 5), vec![0, 2, 4]);
        assert_eq!(complement(&[], 3), vec![0, 1, 2]);
    }

    #[test]
    fn validate_folds_catches_errors() {
        assert!(validate_folds(&[], 4).is_err());
        assert!(validate_folds(&[vec![]], 4).is_err());
        assert!(validate_folds(&[vec![0, 1, 2, 3]], 4).is_err(), "no train left");
        assert!(validate_folds(&[vec![0], vec![0]], 4).is_err(), "overlap");
        assert!(validate_folds(&[vec![9]], 4).is_err(), "out of range");
        assert!(validate_folds(&[vec![0, 1], vec![2]], 4).is_ok());
    }

    #[test]
    fn cache_prepares_all_folds() {
        let mut rng = Rng::new(1);
        let x = Mat::from_fn(12, 3, |_, _| rng.gauss());
        let hat = HatMatrix::build(&x, 0.1).unwrap();
        let folds = vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7], vec![8, 9, 10, 11]];
        let cache = FoldCache::prepare(&hat, &folds, true).unwrap();
        assert_eq!(cache.k(), 3);
        assert_eq!(cache.trains[0], vec![4, 5, 6, 7, 8, 9, 10, 11]);
        let cross = cache.cross.as_ref().unwrap();
        assert_eq!(cross[1].shape(), (8, 4));
        let no_cross = FoldCache::prepare(&hat, &folds, false).unwrap();
        assert!(no_cross.cross.is_none());
    }

    #[test]
    fn backend_pool_fold_cache_prepare_bitwise_matches_serial() {
        // Fold-wise LU fan-out is a pure wall-clock knob: the factors a
        // pooled prepare produces must solve to the identical floats.
        let mut rng = Rng::new(2);
        let x = Mat::from_fn(24, 5, |_, _| rng.gauss());
        let hat = HatMatrix::build(&x, 0.3).unwrap();
        let folds: Vec<Vec<usize>> = (0..4).map(|k| (6 * k..6 * (k + 1)).collect()).collect();
        let serial = FoldCache::prepare(&hat, &folds, true).unwrap();
        let pool = crate::util::threadpool::ThreadPool::new(3);
        let pooled = FoldCache::prepare_pool(&hat, &folds, true, Some(&pool)).unwrap();
        assert_eq!(serial.k(), pooled.k());
        let rhs: Vec<f64> = (0..6).map(|i| i as f64 - 2.5).collect();
        for k in 0..serial.k() {
            let a = serial.lus[k].solve_vec(&rhs);
            let b = pooled.lus[k].solve_vec(&rhs);
            for (x1, x2) in a.iter().zip(&b) {
                assert_eq!(x1.to_bits(), x2.to_bits(), "fold {k} factor moved");
            }
        }
        // a singular fold still errors with the fold-indexed message
        let wide = Mat::from_fn(12, 8, |_, _| rng.gauss());
        let hat0 = HatMatrix::build(&wide, 0.0).unwrap();
        let halves = vec![(0..6).collect::<Vec<_>>(), (6..12).collect::<Vec<_>>()];
        let err = FoldCache::prepare_pool(&hat0, &halves, false, Some(&pool))
            .err()
            .expect("degenerate folds must error under a pool too");
        assert!(format!("{err:#}").contains("fold"), "{err:#}");
    }
}
