//! §4.5 "What about big data?" — the three coping strategies the paper
//! prescribes, implemented and tested:
//!
//! * **Too many samples** → [`StreamingHat`]: never materialise the `N×N`
//!   hat matrix; keep `T = X̃ S` (`N×(P+1)`) and compute the per-fold blocks
//!   `H_Te = T_Te X̃_Teᵀ` on the fly (`O(N_te² P)` per fold, `O(NP)` memory).
//! * **Too many features** → [`SparseProjection`]: an Achlioptas sparse
//!   random projection `A ∈ R^{P×Q}`, `Q ≪ P`, approximately preserving the
//!   covariance structure so `XA` can replace `X`.
//! * **Both** → [`LdaEnsemble`]: weak regularised-LDA learners on random
//!   feature/sample subsets, majority-vote aggregation, trainable in
//!   parallel.

use super::hat::GramBackend;
use super::FoldCache;
use crate::linalg::{matmul, matmul_pool, Cholesky, Lu, Mat};
use crate::model::linreg::gram_ridged;
use crate::model::Reg;
use crate::util::rng::Rng;
use crate::util::threadpool::ThreadPool;
use anyhow::{Context, Result};

/// Memory-light analytic CV state: `O(NP)` instead of `O(N²)`.
///
/// Two Gram backends, mirroring [`super::hat::HatMatrix`]:
///
/// * **Primal** — stores `T = X̃ S` (`N×(P+1)`); fold blocks are
///   `H_Te = T_Te X̃_Teᵀ`. Build cost `O(NP² + P³)`.
/// * **Dual** — stores `T_c = (K_c + λI)⁻¹ X_c` (`N×P`) and the column
///   means; fold blocks are `H_Te = (1/N)𝟙𝟙ᵀ + T_{c,Te} X_{c,Te}ᵀ` with
///   `X_c` rows re-centered on the fly from `xa`. Build cost
///   `O(N²P + N³)` — the P ≫ N path. The build materialises `K_c`
///   **transiently** (steady state stays `O(NP)`); out-of-core `K_c`
///   tiling is a ROADMAP open item.
#[derive(Debug)]
pub struct StreamingHat {
    /// Augmented design.
    pub xa: Mat,
    /// Primal: `T = X̃ S` (`N×(P+1)`); dual: `T_c = (K_c+λI)⁻¹X_c` (`N×P`).
    pub t: Mat,
    /// Ridge used.
    pub lambda: f64,
    /// Column means of `x` — present iff built through the dual backend.
    means: Option<Vec<f64>>,
}

impl StreamingHat {
    /// Build from raw data (same contract as [`super::hat::HatMatrix`]):
    /// the primal, bit-stable historical path.
    pub fn build(x: &Mat, lambda: f64) -> Result<StreamingHat> {
        Self::build_with(x, lambda, GramBackend::Primal, None)
    }

    /// Build through a chosen [`GramBackend`]. `Auto` resolves by the P/N
    /// ratio exactly like [`super::hat::GramBackend::resolve`]; `Spectral`
    /// is treated as `Dual` (a streaming hat serves a single λ, so an
    /// eigendecomposition buys nothing over one Cholesky).
    pub fn build_with(
        x: &Mat,
        lambda: f64,
        backend: GramBackend,
        pool: Option<&ThreadPool>,
    ) -> Result<StreamingHat> {
        assert!(lambda >= 0.0, "ridge λ must be ≥ 0");
        match backend.resolve(x.rows(), x.cols(), lambda) {
            GramBackend::Dual | GramBackend::Spectral => Self::build_dual(x, lambda, pool),
            _ => Self::build_primal(x, lambda),
        }
    }

    fn build_primal(x: &Mat, lambda: f64) -> Result<StreamingHat> {
        let xa = x.augment_ones();
        let g = gram_ridged(&xa, lambda);
        // T = X̃ G⁻¹ = solve(G, X̃ᵀ)ᵀ — no explicit inverse (see §Perf).
        let w = match Cholesky::factor(&g) {
            Ok(ch) => ch.solve_mat(&xa.t()),
            Err(_) => Lu::factor(&g).context("gram singular; increase λ")?.solve_mat(&xa.t()),
        };
        let t = w.t();
        Ok(StreamingHat { xa, t, lambda, means: None })
    }

    fn build_dual(x: &Mat, lambda: f64, pool: Option<&ThreadPool>) -> Result<StreamingHat> {
        anyhow::ensure!(
            lambda > 0.0,
            "dual streaming backend requires ridge λ > 0 (K_c is always singular: K_c𝟙 = 0)"
        );
        let n = x.rows();
        let xa = x.augment_ones();
        let means = x.col_means();
        let xc = Mat::from_fn(n, x.cols(), |i, j| x[(i, j)] - means[j]);
        // Transient N×N: K_c + λI, factored then discarded.
        let mut kl = matmul_pool(&xc, &xc.t(), pool);
        kl.symmetrize();
        for i in 0..n {
            kl[(i, i)] += lambda;
        }
        let ch = Cholesky::factor(&kl)
            .context("centered dual Gram K_c + λI not SPD — is λ > 0?")?;
        let t = ch.solve_mat(&xc); // T_c = (K_c+λI)⁻¹ X_c, N×P
        Ok(StreamingHat { xa, t, lambda, means: Some(means) })
    }

    /// Number of samples.
    pub fn n(&self) -> usize {
        self.xa.rows()
    }

    /// On-the-fly fold block: `H_Te = T_Te X̃_Teᵀ` (primal) or
    /// `(1/N)𝟙𝟙ᵀ + T_{c,Te} X_{c,Te}ᵀ` (dual).
    pub fn block(&self, te: &[usize]) -> Mat {
        let t_te = self.t.take_rows(te);
        match &self.means {
            None => {
                let xa_te = self.xa.take_rows(te);
                matmul(&t_te, &xa_te.t())
            }
            Some(means) => {
                let p = means.len();
                let xc_te =
                    Mat::from_fn(te.len(), p, |j, l| self.xa[(te[j], l)] - means[l]);
                let mut m = matmul(&t_te, &xc_te.t());
                let inv_n = 1.0 / self.n() as f64;
                for v in m.as_mut_slice() {
                    *v += inv_n;
                }
                m
            }
        }
    }

    /// Full-data fits `ŷ = H y` without materialising `H` — `O(NP)` both
    /// ways: `T (X̃ᵀ y)` (primal) or `T_c (X_cᵀ y) + ȳ𝟙` (dual).
    pub fn fit_response(&self, y: &[f64]) -> Vec<f64> {
        let xty = crate::linalg::matvec_t(&self.xa, y);
        match &self.means {
            None => crate::linalg::matvec(&self.t, &xty),
            Some(means) => {
                // X_cᵀy = Xᵀy − (Σy)·x̄ ; the last entry of X̃ᵀy *is* Σy.
                let sum_y = xty[means.len()];
                let z: Vec<f64> =
                    (0..means.len()).map(|j| xty[j] - sum_y * means[j]).collect();
                let mut out = crate::linalg::matvec(&self.t, &z);
                let ybar = sum_y / self.n() as f64;
                for v in out.iter_mut() {
                    *v += ybar;
                }
                out
            }
        }
    }

    /// Analytic CV decision values (Eq. 14) without materialising `H`.
    pub fn decision_values(&self, y: &[f64], folds: &[Vec<usize>]) -> Result<Vec<f64>> {
        super::validate_folds(folds, self.n())?;
        let y_hat = self.fit_response(y);
        let mut dvals = vec![f64::NAN; self.n()];
        for te in folds {
            let mut i_minus = self.block(te);
            i_minus.scale(-1.0);
            for i in 0..te.len() {
                i_minus[(i, i)] += 1.0;
            }
            let e_hat: Vec<f64> = te.iter().map(|&i| y[i] - y_hat[i]).collect();
            let e_dot = crate::linalg::solve(&i_minus, &e_hat)
                .context("(I − H_Te) singular; increase λ")?;
            for (j, &i) in te.iter().enumerate() {
                dvals[i] = y[i] - e_dot[j];
            }
        }
        Ok(dvals)
    }
}

/// Achlioptas sparse random projection: entries `±√(3/Q)` with probability
/// 1/6 each, 0 with probability 2/3 — `E[AAᵀ] = I`, so `XA` approximately
/// preserves pairwise geometry at `Q = O(log N / ε²)`.
///
/// Non-zeros are stored CSC-style (grouped per **output** column): each
/// output element is one contiguous gather-and-accumulate over its
/// column's entries, instead of the old full-triplet scan with scattered
/// writes across the whole output row per input row — `Q×` less write
/// traffic and sequential reads of the entry list (micro-benched in
/// `benches/linalg_kernels.rs`). Values are bit-identical to the scatter
/// formulation: within a column, entries keep ascending input-row order,
/// which is exactly the order the scatter accumulated them in.
#[derive(Debug, Clone)]
pub struct SparseProjection {
    /// `entries[col_ptr[j]..col_ptr[j+1]]` = the (input row, sign) pairs
    /// of output column `j`, ascending by input row.
    col_ptr: Vec<usize>,
    entries: Vec<(u32, f32)>,
    p: usize,
    q: usize,
    scale: f64,
}

impl SparseProjection {
    /// Sample a projection from `p` dims down to `q`.
    pub fn sample(p: usize, q: usize, rng: &mut Rng) -> SparseProjection {
        assert!(q >= 1);
        // Draw in (row, col) order — the RNG stream is part of the
        // reproducibility contract — then regroup by column.
        let mut triplets = Vec::with_capacity(p * q / 3 + 1);
        for i in 0..p {
            for j in 0..q {
                let r = rng.below(6);
                if r == 0 {
                    triplets.push((i as u32, j as u32, 1.0f32));
                } else if r == 1 {
                    triplets.push((i as u32, j as u32, -1.0f32));
                }
            }
        }
        // Counting sort by output column; row-major draw order means each
        // column's entries land in ascending input-row order.
        let mut col_ptr = vec![0usize; q + 1];
        for &(_, j, _) in &triplets {
            col_ptr[j as usize + 1] += 1;
        }
        for j in 0..q {
            col_ptr[j + 1] += col_ptr[j];
        }
        let mut next = col_ptr.clone();
        let mut entries = vec![(0u32, 0.0f32); triplets.len()];
        for &(i, j, s) in &triplets {
            entries[next[j as usize]] = (i, s);
            next[j as usize] += 1;
        }
        SparseProjection { col_ptr, entries, p, q, scale: (3.0 / q as f64).sqrt() }
    }

    /// Output dimensionality.
    pub fn q(&self) -> usize {
        self.q
    }

    /// Fraction of non-zero entries (≈1/3).
    pub fn density(&self) -> f64 {
        self.entries.len() as f64 / (self.p * self.q) as f64
    }

    /// Project a data matrix: `X A` (`N×P` → `N×Q`).
    pub fn project(&self, x: &Mat) -> Mat {
        assert_eq!(x.cols(), self.p, "projection dimension mismatch");
        let mut out = Mat::zeros(x.rows(), self.q);
        for i in 0..x.rows() {
            let row = x.row(i);
            let orow = out.row_mut(i);
            for (j, o) in orow.iter_mut().enumerate() {
                let mut acc = 0.0f64;
                for &(pi, sign) in &self.entries[self.col_ptr[j]..self.col_ptr[j + 1]] {
                    acc += sign as f64 * row[pi as usize];
                }
                *o = acc * self.scale;
            }
        }
        out
    }
}

/// Ensemble of weak regularised-LDA learners (§4.5): each trained on a
/// random subset of features and samples; majority vote at prediction.
pub struct LdaEnsemble {
    members: Vec<(Vec<usize>, crate::model::lda_binary::BinaryLda)>,
}

impl LdaEnsemble {
    /// Train `n_members` weak learners, each on `feat_frac` of the features
    /// and `sample_frac` of the samples, optionally in parallel on `pool`.
    pub fn train(
        x: &Mat,
        labels: &[usize],
        n_members: usize,
        feat_frac: f64,
        sample_frac: f64,
        reg: Reg,
        pool: Option<&crate::util::threadpool::ThreadPool>,
        rng: &mut Rng,
    ) -> Result<LdaEnsemble> {
        assert!(n_members >= 1);
        let p = x.cols();
        let n = x.rows();
        let n_feat = ((p as f64 * feat_frac).ceil() as usize).clamp(1, p);
        let n_samp = ((n as f64 * sample_frac).ceil() as usize).clamp(4, n);
        // A labelling missing a class can never produce a two-class
        // subsample — the old unbounded resample loop spun forever here.
        anyhow::ensure!(
            labels.iter().any(|&l| l == 0) && labels.iter().any(|&l| l == 1),
            "LdaEnsemble::train: both classes must be present in `labels` \
             (got a single-class labelling of {} samples)",
            labels.len()
        );
        // Bound the retries anyway: extreme imbalance + tiny sample_frac
        // can make a two-class draw arbitrarily rare.
        const MAX_RESAMPLE: usize = 1000;
        // Pre-draw subsets so training is deterministic regardless of pool.
        let draws: Vec<(Vec<usize>, Vec<usize>)> = (0..n_members)
            .map(|m| -> Result<(Vec<usize>, Vec<usize>)> {
                // resample until both classes present (bounded)
                for _ in 0..MAX_RESAMPLE {
                    let feats = rng.choose(p, n_feat);
                    let samps = rng.choose(n, n_samp);
                    let has0 = samps.iter().any(|&i| labels[i] == 0);
                    let has1 = samps.iter().any(|&i| labels[i] == 1);
                    if has0 && has1 {
                        return Ok((feats, samps));
                    }
                }
                anyhow::bail!(
                    "LdaEnsemble::train: member {m}: no subsample contained both classes \
                     after {MAX_RESAMPLE} draws — increase sample_frac or rebalance the data"
                )
            })
            .collect::<Result<Vec<_>>>()?;
        let train_one = |(feats, samps): &(Vec<usize>, Vec<usize>)| -> Result<(Vec<usize>, crate::model::lda_binary::BinaryLda)> {
            let xs = x.take(samps, feats);
            let ls: Vec<usize> = samps.iter().map(|&i| labels[i]).collect();
            let model = crate::model::lda_binary::BinaryLda::train(&xs, &ls, reg)?;
            Ok((feats.clone(), model))
        };
        let members: Vec<_> = match pool {
            Some(pool) => {
                let slots: Vec<std::sync::Mutex<Option<_>>> =
                    (0..n_members).map(|_| std::sync::Mutex::new(None)).collect();
                let slots_ref = &slots;
                let draws_ref = &draws;
                pool.for_each(n_members, move |i| {
                    *slots_ref[i].lock().unwrap() = Some(train_one(&draws_ref[i]));
                });
                slots
                    .into_iter()
                    .map(|s| s.into_inner().unwrap().unwrap())
                    .collect::<Result<Vec<_>>>()?
            }
            None => draws.iter().map(train_one).collect::<Result<Vec<_>>>()?,
        };
        Ok(LdaEnsemble { members })
    }

    /// Number of members.
    pub fn len(&self) -> usize {
        self.members.len()
    }

    /// Is the ensemble empty?
    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// Majority-vote prediction (ties → class 0, the "+1" class).
    pub fn predict(&self, x: &Mat) -> Vec<usize> {
        let n = x.rows();
        let mut votes1 = vec![0usize; n];
        for (feats, model) in &self.members {
            let xs = x.take_cols(feats);
            for (i, &l) in model.predict(&xs).iter().enumerate() {
                votes1[i] += l;
            }
        }
        let half = self.members.len();
        votes1.iter().map(|&v| usize::from(2 * v > half)).collect()
    }
}

/// Analytic CV on randomly projected data: the §4.5 "too many features"
/// pipeline in one call.
pub fn projected_analytic_cv(
    x: &Mat,
    y: &[f64],
    folds: &[Vec<usize>],
    q: usize,
    lambda: f64,
    rng: &mut Rng,
) -> Result<Vec<f64>> {
    let proj = SparseProjection::sample(x.cols(), q, rng);
    let xq = proj.project(x);
    let cv = super::binary::AnalyticBinaryCv::fit(&xq, y, lambda)?;
    let cache = FoldCache::prepare(&cv.hat, folds, false)?;
    Ok(cv.decision_values_cached(&cache))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cv::folds::kfold;
    use crate::data::synthetic::{generate, SyntheticSpec};
    use crate::util::prop::assert_all_close;

    #[test]
    fn streaming_hat_matches_dense_hat() {
        let mut rng = Rng::new(1);
        let ds = generate(&SyntheticSpec::binary(50, 20), &mut rng);
        let y = ds.y_signed();
        let folds = kfold(50, 5, &mut rng);
        let dense = super::super::binary::AnalyticBinaryCv::fit(&ds.x, &y, 0.7).unwrap();
        let dv_dense = dense.decision_values(&folds).unwrap();
        let stream = StreamingHat::build(&ds.x, 0.7).unwrap();
        let dv_stream = stream.decision_values(&y, &folds).unwrap();
        assert_all_close(&dv_stream, &dv_dense, 1e-9, "streaming == dense");
        // block equality
        let te = &folds[0];
        let b1 = dense.hat.block(te);
        let b2 = stream.block(te);
        assert!(b1.max_abs_diff(&b2) < 1e-10);
    }

    #[test]
    fn streaming_memory_is_np_not_n2() {
        // structural check: StreamingHat holds two N×(P+1)-ish matrices only
        let mut rng = Rng::new(2);
        let ds = generate(&SyntheticSpec::binary(60, 5), &mut rng);
        let s = StreamingHat::build(&ds.x, 0.1).unwrap();
        assert_eq!(s.t.shape(), (60, 6));
        assert_eq!(s.xa.shape(), (60, 6));
    }

    #[test]
    fn backend_equivalence_streaming_dual_matches_dense_and_primal() {
        // Wide shape: the dual streaming hat must reproduce the primal
        // streaming hat and the dense engine to 1e-8 — blocks, fits, and
        // decision values — while storing only N×P state.
        let mut rng = Rng::new(7);
        let ds = generate(&SyntheticSpec::binary(40, 120), &mut rng);
        let y = ds.y_signed();
        let folds = kfold(40, 5, &mut rng);
        let lambda = 0.9;
        let primal = StreamingHat::build_with(&ds.x, lambda, GramBackend::Primal, None).unwrap();
        let dual = StreamingHat::build_with(&ds.x, lambda, GramBackend::Dual, None).unwrap();
        assert_eq!(dual.t.shape(), (40, 120), "dual stores T_c (N×P)");
        let te = &folds[0];
        let b_p = primal.block(te);
        let b_d = dual.block(te);
        assert!(b_p.max_abs_diff(&b_d) < 1e-8, "|Δblock| = {}", b_p.max_abs_diff(&b_d));
        assert_all_close(&dual.fit_response(&y), &primal.fit_response(&y), 1e-8, "dual ŷ");
        let dv_p = primal.decision_values(&y, &folds).unwrap();
        let dv_d = dual.decision_values(&y, &folds).unwrap();
        assert_all_close(&dv_d, &dv_p, 1e-8, "streaming dual vs primal dvals");
        // Auto resolves to dual on this wide shape and to primal on tall.
        let auto = StreamingHat::build_with(&ds.x, lambda, GramBackend::Auto, None).unwrap();
        assert_eq!(auto.t.shape(), (40, 120));
        let tall = generate(&SyntheticSpec::binary(50, 10), &mut rng);
        let auto_tall =
            StreamingHat::build_with(&tall.x, lambda, GramBackend::Auto, None).unwrap();
        assert_eq!(auto_tall.t.shape(), (50, 11), "tall Auto keeps primal T = X̃S");
        // pooled K_c build is bit-identical
        let pool = crate::util::threadpool::ThreadPool::new(3);
        let dual_pooled =
            StreamingHat::build_with(&ds.x, lambda, GramBackend::Dual, Some(&pool)).unwrap();
        assert_eq!(dual.t.as_slice(), dual_pooled.t.as_slice());
    }

    #[test]
    fn ensemble_single_class_labels_errors_not_hangs() {
        // Regression: the resample loop could never see both classes and
        // span forever. Must bail with a clear error instead.
        let mut rng = Rng::new(8);
        let x = Mat::from_fn(20, 5, |_, _| rng.gauss());
        let labels = vec![0usize; 20];
        let res = LdaEnsemble::train(&x, &labels, 3, 0.5, 0.5, Reg::Ridge(1.0), None, &mut rng);
        let msg = format!("{:#}", res.err().expect("single-class labels must error"));
        assert!(msg.contains("both classes"), "unexpected error: {msg}");
        // ...and the all-class-1 flavour too.
        let labels = vec![1usize; 20];
        assert!(
            LdaEnsemble::train(&x, &labels, 3, 0.5, 0.5, Reg::Ridge(1.0), None, &mut rng)
                .is_err()
        );
    }

    #[test]
    fn projection_csc_matches_dense_reference() {
        // project(I_P) materialises the scaled dense A row by row; a random
        // X must then satisfy project(X) == X·A through the dense GEMM.
        let mut rng = Rng::new(9);
        let (p, q) = (60, 17);
        let proj = SparseProjection::sample(p, q, &mut rng);
        let dense_a = proj.project(&Mat::eye(p)); // P × Q, = scale·A
        let x = Mat::from_fn(8, p, |_, _| rng.gauss());
        let expect = crate::linalg::matmul(&x, &dense_a);
        let got = proj.project(&x);
        assert!(got.max_abs_diff(&expect) < 1e-10, "|Δ| = {}", got.max_abs_diff(&expect));
    }

    #[test]
    fn projection_preserves_geometry_approximately() {
        let mut rng = Rng::new(3);
        let p = 2000;
        let q = 300;
        let n = 20;
        let x = Mat::from_fn(n, p, |_, _| rng.gauss());
        let proj = SparseProjection::sample(p, q, &mut rng);
        assert!((proj.density() - 1.0 / 3.0).abs() < 0.03);
        let xq = proj.project(&x);
        assert_eq!(xq.shape(), (n, q));
        // pairwise squared distances preserved within ~35%
        for i in 0..5 {
            for j in (i + 1)..5 {
                let d_orig: f64 = (0..p).map(|k| (x[(i, k)] - x[(j, k)]).powi(2)).sum();
                let d_proj: f64 = (0..q).map(|k| (xq[(i, k)] - xq[(j, k)]).powi(2)).sum();
                let ratio = d_proj / d_orig;
                assert!((0.65..1.35).contains(&ratio), "ratio={ratio}");
            }
        }
    }

    #[test]
    fn projected_cv_still_decodes() {
        let mut rng = Rng::new(4);
        let mut spec = SyntheticSpec::binary(100, 800);
        spec.separation = 5.0;
        let ds = generate(&spec, &mut rng);
        let y = ds.y_signed();
        let folds = kfold(100, 5, &mut rng);
        // Unprojected baseline for context.
        let cv = super::super::binary::AnalyticBinaryCv::fit(&ds.x, &y, 1.0).unwrap();
        let acc_full = crate::cv::metrics::accuracy_signed(
            &cv.decision_values(&folds).unwrap(),
            &y,
        );
        let dv = projected_analytic_cv(&ds.x, &y, &folds, 200, 1.0, &mut rng).unwrap();
        let acc = crate::cv::metrics::accuracy_signed(&dv, &y);
        assert!(acc > 0.65, "projected CV acc={acc} (full-dim acc={acc_full})");
        assert!(acc_full > 0.75, "full-dim baseline acc={acc_full}");
    }

    #[test]
    fn ensemble_beats_weak_member_and_parallel_matches_serial() {
        let mut rng = Rng::new(5);
        let mut spec = SyntheticSpec::binary(120, 60);
        spec.separation = 1.6;
        let ds = generate(&spec, &mut rng);
        let mut rng_a = Rng::new(77);
        let mut rng_b = Rng::new(77);
        let serial = LdaEnsemble::train(
            &ds.x, &ds.labels, 15, 0.3, 0.6, Reg::Ridge(1.0), None, &mut rng_a,
        )
        .unwrap();
        let pool = crate::util::threadpool::ThreadPool::new(4);
        let parallel = LdaEnsemble::train(
            &ds.x, &ds.labels, 15, 0.3, 0.6, Reg::Ridge(1.0), Some(&pool), &mut rng_b,
        )
        .unwrap();
        let pred_s = serial.predict(&ds.x);
        let pred_p = parallel.predict(&ds.x);
        assert_eq!(pred_s, pred_p, "pool must not change results");
        let acc_ens = crate::cv::metrics::accuracy_labels(&pred_s, &ds.labels);
        // single weak member accuracy
        let (feats, model) = &serial.members[0];
        let acc_one = crate::cv::metrics::accuracy_labels(
            &model.predict(&ds.x.take_cols(feats)),
            &ds.labels,
        );
        assert!(
            acc_ens >= acc_one - 0.02,
            "ensemble {acc_ens} should not trail a weak member {acc_one}"
        );
        assert!(acc_ens > 0.7, "ensemble acc={acc_ens}");
    }
}
