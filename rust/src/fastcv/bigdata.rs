//! §4.5 "What about big data?" — the three coping strategies the paper
//! prescribes, implemented and tested:
//!
//! * **Too many samples** → [`StreamingHat`]: never materialise the `N×N`
//!   hat matrix; keep `T = X̃ S` (`N×(P+1)`) and compute the per-fold blocks
//!   `H_Te = T_Te X̃_Teᵀ` on the fly (`O(N_te² P)` per fold, `O(NP)` memory).
//! * **Too many features** → [`SparseProjection`]: an Achlioptas sparse
//!   random projection `A ∈ R^{P×Q}`, `Q ≪ P`, approximately preserving the
//!   covariance structure so `XA` can replace `X`.
//! * **Both** → [`LdaEnsemble`]: weak regularised-LDA learners on random
//!   feature/sample subsets, majority-vote aggregation, trainable in
//!   parallel.
//!
//! Every strategy has a `_ctx` entry point
//! ([`StreamingHat::build_ctx`], [`SparseProjection::project_ctx`],
//! [`LdaEnsemble::train_ctx`], [`projected_analytic_cv_ctx`]) taking a
//! [`ComputeContext`], so `--threads` (and, for the dual streaming build,
//! `--tile-rows`/`--mem-budget`) reaches every §4.5 mode; the historical
//! signatures delegate with a serial context, bitwise-unchanged.

use super::context::ComputeContext;
use super::hat::GramBackend;
use super::FoldCache;
use crate::linalg::{
    chol_spill, chol_spill_ridged, gram_spill, gram_tiled, matmul, matmul_pool, syrk_spill,
    Cholesky, Lu, Mat, PanelStore, TilePolicy,
};
use crate::model::linreg::gram_ridged;
use crate::model::Reg;
use crate::util::rng::Rng;
use crate::util::threadpool::ThreadPool;
use anyhow::{Context, Result};

/// Memory-light analytic CV state: `O(NP)` instead of `O(N²)`.
///
/// Two Gram backends, mirroring [`super::hat::HatMatrix`]:
///
/// * **Primal** — stores `T = X̃ S` (`N×(P+1)`); fold blocks are
///   `H_Te = T_Te X̃_Teᵀ`. Build cost `O(NP² + P³)`.
/// * **Dual** — stores `T_c = (K_c + λI)⁻¹ X_c` (`N×P`) and the column
///   means; fold blocks are `H_Te = (1/N)𝟙𝟙ᵀ + T_{c,Te} X_{c,Te}ᵀ` with
///   `X_c` rows re-centered on the fly from `xa`. Build cost
///   `O(N²P + N³)` — the P ≫ N path. The build needs the `N×N` Gram
///   transiently (steady state stays `O(NP)`); under a tiled
///   [`ComputeContext`] ([`StreamingHat::build_ctx`]) it is assembled from
///   `tile×P` centered slabs and factored **in place**, so beyond the one
///   irreducible `N×N` factor and the `O(NP)` outputs every transient is
///   tile-bounded — and under a `TilePolicy::Spill` context even that
///   factor goes: Gram and factor live as
///   [`PanelStore`](crate::linalg::spill::PanelStore) panels (RAM or
///   `--spill-dir` files) streamed through the left-looking
///   [`crate::linalg::spill::chol_spill`], so **nothing `N×N` is ever
///   resident** (see `docs/BACKENDS.md` "Out-of-core spill" and
///   `BENCH_spill.json`/`BENCH_tiling.json` for the resident-bytes
///   accounting).
#[derive(Debug, Clone)]
pub struct StreamingHat {
    /// Augmented design.
    pub xa: Mat,
    /// Primal: `T = X̃ S` (`N×(P+1)`); dual: `T_c = (K_c+λI)⁻¹X_c` (`N×P`).
    pub t: Mat,
    /// Ridge used.
    pub lambda: f64,
    /// The backend that actually built this hat — never `Auto`, and never
    /// `Spectral`: a streaming hat serves a single λ, so a `Spectral`
    /// request is **coerced to `Dual`** (recorded in
    /// [`StreamingHat::backend_label`] so CLI/report output is never
    /// mislabeled).
    pub backend: GramBackend,
    /// Column means of `x` — present iff built through the dual backend.
    means: Option<Vec<f64>>,
    /// Was a `Spectral` request coerced to `Dual`?
    spectral_coerced: bool,
}

impl StreamingHat {
    /// Build from raw data (same contract as [`super::hat::HatMatrix`]):
    /// the primal, bit-stable historical path.
    pub fn build(x: &Mat, lambda: f64) -> Result<StreamingHat> {
        Self::build_with(x, lambda, GramBackend::Primal, None)
    }

    /// Build through a chosen [`GramBackend`]. `Auto` resolves by the P/N
    /// ratio exactly like [`super::hat::GramBackend::resolve`]; `Spectral`
    /// is **coerced to `Dual`** (a streaming hat serves a single λ, so an
    /// eigendecomposition buys nothing over one Cholesky) — the coercion
    /// is recorded on the result: [`StreamingHat::backend`] reports `Dual`
    /// and [`StreamingHat::backend_label`] spells out the coercion so a
    /// `--backend spectral` streaming run is never silently mislabeled.
    pub fn build_with(
        x: &Mat,
        lambda: f64,
        backend: GramBackend,
        pool: Option<&ThreadPool>,
    ) -> Result<StreamingHat> {
        Self::build_impl(x, lambda, backend, pool, TilePolicy::Off)
    }

    /// Build under a full [`ComputeContext`] — backend policy, pool
    /// fan-out, and the context's [`TilePolicy`] for the dual arm's `K_c`
    /// assembly + in-place blocked Cholesky. Bit-identical to
    /// [`StreamingHat::build_with`] for any context.
    ///
    /// ```
    /// use fastcv::fastcv::bigdata::StreamingHat;
    /// use fastcv::fastcv::{ComputeContext, GramBackend};
    /// use fastcv::linalg::{Mat, TilePolicy};
    /// use fastcv::util::rng::Rng;
    ///
    /// let mut rng = Rng::new(3);
    /// let x = Mat::from_fn(20, 60, |_, _| rng.gauss());   // P ≫ N
    /// let ctx = ComputeContext::with_threads(2)
    ///     .with_backend(GramBackend::Auto)
    ///     .with_tile_policy(TilePolicy::Rows(8));         // tile-bounded K_c
    /// let hat = StreamingHat::build_ctx(&x, 0.5, &ctx).unwrap();
    /// assert_eq!(hat.t.shape(), (20, 60));                // T_c is N×P
    /// assert_eq!(hat.backend, GramBackend::Dual);         // Auto → dual (wide)
    /// ```
    pub fn build_ctx(x: &Mat, lambda: f64, ctx: &ComputeContext<'_>) -> Result<StreamingHat> {
        match ctx.store() {
            None => Self::build_impl(x, lambda, ctx.backend(), ctx.pool(), ctx.tile_policy()),
            // A store-carrying context serves the cached state (same floats
            // — the store's bitwise contract); the by-value signature is
            // kept, so the caller receives a copy of the shared artifact.
            // Zero-copy callers use `fetch_ctx`.
            Some(_) => Ok((*Self::fetch_ctx(x, lambda, ctx)?).clone()),
        }
    }

    /// Store-aware sibling of [`StreamingHat::build_ctx`] returning the
    /// shared artifact without copying: with a
    /// [`crate::store::FactorStore`] on the context, the λ-specific
    /// streaming state is fetched through the keyed cache
    /// (`ArtifactKind::Streaming`, keyed on data × λ bits × resolved
    /// backend × tile — a `--backend spectral` request keys separately so
    /// its coercion label survives); without one it builds fresh.
    pub fn fetch_ctx(
        x: &Mat,
        lambda: f64,
        ctx: &ComputeContext<'_>,
    ) -> Result<std::sync::Arc<StreamingHat>> {
        match ctx.store() {
            None => Ok(std::sync::Arc::new(Self::build_impl(
                x,
                lambda,
                ctx.backend(),
                ctx.pool(),
                ctx.tile_policy(),
            )?)),
            Some(store) => {
                // Key on the pre-coercion resolution: Spectral requests are
                // coerced to Dual *inside* the build but carry a distinct
                // `backend_label`, so they must not share a cache slot with
                // genuine Dual requests.
                let resolved = ctx.backend().resolve(x.rows(), x.cols(), lambda);
                let key =
                    crate::store::ArtifactKey::streaming(x, lambda, resolved, &ctx.tile_policy());
                store.get_or_build_streaming(&key, || {
                    Self::build_impl(x, lambda, ctx.backend(), ctx.pool(), ctx.tile_policy())
                })
            }
        }
    }

    fn build_impl(
        x: &Mat,
        lambda: f64,
        backend: GramBackend,
        pool: Option<&ThreadPool>,
        tile: TilePolicy,
    ) -> Result<StreamingHat> {
        assert!(lambda >= 0.0, "ridge λ must be ≥ 0");
        match backend.resolve(x.rows(), x.cols(), lambda) {
            GramBackend::Dual => Self::build_dual(x, lambda, pool, tile, false),
            GramBackend::Spectral => Self::build_dual(x, lambda, pool, tile, true),
            _ => Self::build_primal(x, lambda, pool, tile),
        }
    }

    fn build_primal(
        x: &Mat,
        lambda: f64,
        pool: Option<&ThreadPool>,
        tile: TilePolicy,
    ) -> Result<StreamingHat> {
        let xa = x.augment_ones();
        // Out-of-core (`TilePolicy::Spill`): the primal Gram `G₀ = X̃ᵀX̃` is
        // assembled as tile×(P+1) panels (bitwise = syrk_t, hence =
        // gram_ridged's basis) and factored with the ridge folded onto the
        // diagonal at panel load — the (P+1)×(P+1) square never exists in
        // RAM, matching the dual arm's guarantee on the other quadrant.
        // Bitwise-identical to the in-RAM Cholesky path below; the LU
        // fallback for singular unridged grams has no out-of-core form and
        // errors cleanly instead.
        if let Some((dir, tile_rows)) = tile.spill() {
            let p1 = xa.cols();
            let mut g0 = PanelStore::new(p1, tile_rows, dir)
                .context("creating the streaming-hat primal spill store")?;
            syrk_spill(&mut g0, &xa, pool)?;
            let ch = chol_spill_ridged(&g0, lambda, true, dir, pool).context(
                "spilled primal-gram factor failed: gram not SPD (increase ridge λ — \
                 no LU fallback out of core) or spill-store IO (see cause)",
            )?;
            drop(g0); // λ-free panels are no longer needed during the solve
            let mut w = xa.t();
            ch.solve_mat_in_place(&mut w)?;
            let t = w.t();
            return Ok(StreamingHat {
                xa,
                t,
                lambda,
                backend: GramBackend::Primal,
                means: None,
                spectral_coerced: false,
            });
        }
        // Tiled (`Rows`/`Budget`): banded Gram build + in-place blocked
        // factor — bitwise the one-shot Cholesky path below (syrk_tiled ==
        // syrk_t, factor_into == factor), with tile-bounded band
        // transients and no second (P+1)² for the factor. The rare
        // singular-gram rescue rebuilds densely for the pivoted LU,
        // exactly like the one-shot arm.
        let p1 = xa.cols();
        if let Some(t_rows) = tile.tile_rows(p1, p1) {
            let mut g = crate::linalg::syrk_tiled(&xa, t_rows, pool);
            for i in 0..p1 - 1 {
                // lint:allow(float_accum, reason = "ridge diagonal add: each entry touched exactly once — order-free")
                g[(i, i)] += lambda;
            }
            let w = match Cholesky::factor_into(g, t_rows, pool) {
                Ok(ch) => ch.solve_mat(&xa.t()),
                Err(_) => Lu::factor(&gram_ridged(&xa, lambda))
                    .context("gram singular; increase λ")?
                    .solve_mat(&xa.t()),
            };
            let t = w.t();
            return Ok(StreamingHat {
                xa,
                t,
                lambda,
                backend: GramBackend::Primal,
                means: None,
                spectral_coerced: false,
            });
        }
        let g = gram_ridged(&xa, lambda);
        // T = X̃ G⁻¹ = solve(G, X̃ᵀ)ᵀ — no explicit inverse (see §Perf).
        let w = match Cholesky::factor(&g) {
            Ok(ch) => ch.solve_mat(&xa.t()),
            Err(_) => Lu::factor(&g).context("gram singular; increase λ")?.solve_mat(&xa.t()),
        };
        let t = w.t();
        Ok(StreamingHat {
            xa,
            t,
            lambda,
            backend: GramBackend::Primal,
            means: None,
            spectral_coerced: false,
        })
    }

    fn build_dual(
        x: &Mat,
        lambda: f64,
        pool: Option<&ThreadPool>,
        tile: TilePolicy,
        spectral_coerced: bool,
    ) -> Result<StreamingHat> {
        anyhow::ensure!(
            lambda > 0.0,
            "dual streaming backend requires ridge λ > 0 (K_c is always singular: K_c𝟙 = 0)"
        );
        let n = x.rows();
        let p = x.cols();
        let xa = x.augment_ones();
        let means = x.col_means();
        // Out-of-core (`TilePolicy::Spill`): K_c + λI is assembled straight
        // into a PanelStore (centered tile×P slabs, ridge folded onto the
        // assembled diagonal — same float op as the dense `+= λ`), factored
        // by the left-looking spilled Cholesky, and solved by streaming
        // panels over the centered O(NP) buffer. The N×N **never exists in
        // RAM**: peak residency is T_c plus O(tile·(N+P)) slabs — this is
        // the "memory-bounded fast-CV at any N" build. Bitwise-identical
        // to the one-shot and tiled paths (spill_* property tests).
        if let Some((dir, tile_rows)) = tile.spill() {
            let mut store = PanelStore::new(n, tile_rows, dir)
                .context("creating the streaming-hat spill store")?;
            gram_spill(
                &mut store,
                lambda,
                |lo, hi| Mat::from_fn(hi - lo, p, |r, j| x[(lo + r, j)] - means[j]),
                pool,
            )?;
            let ch = chol_spill(store, pool).context(
                "spilled dual factor failed: K_c + λI not SPD (is λ > 0?) \
                 or spill-store IO (see cause)",
            )?;
            let mut t = Mat::from_fn(n, p, |i, j| x[(i, j)] - means[j]);
            ch.solve_mat_in_place(&mut t)?;
            return Ok(StreamingHat {
                xa,
                t,
                lambda,
                backend: GramBackend::Dual,
                means: Some(means),
                spectral_coerced,
            });
        }
        let t = match tile.tile_rows(n, p) {
            // Historical one-shot path, bitwise-unchanged (TilePolicy::Off).
            None => {
                let xc = Mat::from_fn(n, p, |i, j| x[(i, j)] - means[j]);
                // Transient N×N: K_c + λI, factored then discarded.
                let mut kl = matmul_pool(&xc, &xc.t(), pool);
                kl.symmetrize();
                for i in 0..n {
                    // lint:allow(float_accum, reason = "ridge diagonal add: each entry touched exactly once — order-free")
                    kl[(i, i)] += lambda;
                }
                let ch = Cholesky::factor(&kl)
                    .context("centered dual Gram K_c + λI not SPD — is λ > 0?")?;
                ch.solve_mat(&xc) // T_c = (K_c+λI)⁻¹ X_c, N×P
            }
            // Tiled path (bit-identical): K_c assembled from tile×P
            // centered slabs — no full X_c copy, no P×N transpose — then
            // factored in place (no second N×N) and solved directly into
            // the centered buffer. Beyond the one N×N factor and the O(NP)
            // steady state, every transient is tile-bounded.
            Some(tile_rows) => {
                // Same slab centering as `hat::centered_gram_tiled`, but
                // reusing the `means` already computed above — no second
                // O(NP) column-means sweep over X.
                let mut kl = gram_tiled(
                    n,
                    tile_rows,
                    |lo, hi| Mat::from_fn(hi - lo, p, |r, j| x[(lo + r, j)] - means[j]),
                    pool,
                );
                for i in 0..n {
                    // lint:allow(float_accum, reason = "ridge diagonal add: each entry touched exactly once — order-free")
                    kl[(i, i)] += lambda;
                }
                let ch = Cholesky::factor_into(kl, tile_rows, pool)
                    .context("centered dual Gram K_c + λI not SPD — is λ > 0?")?;
                let mut t = Mat::from_fn(n, p, |i, j| x[(i, j)] - means[j]);
                ch.solve_mat_in_place(&mut t); // X_c buffer becomes T_c
                t
            }
        };
        Ok(StreamingHat {
            xa,
            t,
            lambda,
            backend: GramBackend::Dual,
            means: Some(means),
            spectral_coerced,
        })
    }

    /// Human-readable backend label for reports/CLI: the resolved backend
    /// tag, with the `Spectral` → `Dual` coercion spelled out so streaming
    /// output built from a `--backend spectral` request is not mislabeled
    /// as a spectral build.
    pub fn backend_label(&self) -> String {
        if self.spectral_coerced {
            format!("{} (spectral coerced: streaming serves a single λ)", self.backend.tag())
        } else {
            self.backend.tag().to_string()
        }
    }

    /// Number of samples.
    pub fn n(&self) -> usize {
        self.xa.rows()
    }

    /// Resident heap footprint in bytes — the [`crate::store::FactorStore`]
    /// budget currency. Counts the augmented design `X̃`, the `N×P`
    /// projector `T`, and the dual column-means vector; both matrices are
    /// dense, so the streaming hat never has a spill-resident discount.
    pub fn resident_bytes(&self) -> usize {
        (self.xa.rows() * self.xa.cols()
            + self.t.rows() * self.t.cols()
            + self.means.as_ref().map_or(0, Vec::len))
            * 8
    }

    /// On-the-fly fold block: `H_Te = T_Te X̃_Teᵀ` (primal) or
    /// `(1/N)𝟙𝟙ᵀ + T_{c,Te} X_{c,Te}ᵀ` (dual).
    pub fn block(&self, te: &[usize]) -> Mat {
        let t_te = self.t.take_rows(te);
        match &self.means {
            None => {
                let xa_te = self.xa.take_rows(te);
                matmul(&t_te, &xa_te.t())
            }
            Some(means) => {
                let p = means.len();
                let xc_te =
                    Mat::from_fn(te.len(), p, |j, l| self.xa[(te[j], l)] - means[l]);
                let mut m = matmul(&t_te, &xc_te.t());
                let inv_n = 1.0 / self.n() as f64;
                for v in m.as_mut_slice() {
                    // lint:allow(float_accum, reason = "uniform centering offset: each entry touched exactly once — order-free")
                    *v += inv_n;
                }
                m
            }
        }
    }

    /// Full-data fits `ŷ = H y` without materialising `H` — `O(NP)` both
    /// ways: `T (X̃ᵀ y)` (primal) or `T_c (X_cᵀ y) + ȳ𝟙` (dual).
    pub fn fit_response(&self, y: &[f64]) -> Vec<f64> {
        let xty = crate::linalg::matvec_t(&self.xa, y);
        match &self.means {
            None => crate::linalg::matvec(&self.t, &xty),
            Some(means) => {
                // X_cᵀy = Xᵀy − (Σy)·x̄ ; the last entry of X̃ᵀy *is* Σy.
                let sum_y = xty[means.len()];
                let z: Vec<f64> =
                    (0..means.len()).map(|j| xty[j] - sum_y * means[j]).collect();
                let mut out = crate::linalg::matvec(&self.t, &z);
                let ybar = sum_y / self.n() as f64;
                for v in out.iter_mut() {
                    // lint:allow(float_accum, reason = "uniform centering offset: each entry touched exactly once — order-free")
                    *v += ybar;
                }
                out
            }
        }
    }

    /// Analytic CV decision values (Eq. 14) without materialising `H`.
    pub fn decision_values(&self, y: &[f64], folds: &[Vec<usize>]) -> Result<Vec<f64>> {
        super::validate_folds(folds, self.n())?;
        let y_hat = self.fit_response(y);
        let mut dvals = vec![f64::NAN; self.n()];
        for te in folds {
            let mut i_minus = self.block(te);
            i_minus.scale(-1.0);
            for i in 0..te.len() {
                i_minus[(i, i)] += 1.0;
            }
            let e_hat: Vec<f64> = te.iter().map(|&i| y[i] - y_hat[i]).collect();
            let e_dot = crate::linalg::solve(&i_minus, &e_hat)
                .context("(I − H_Te) singular; increase λ")?;
            for (j, &i) in te.iter().enumerate() {
                dvals[i] = y[i] - e_dot[j];
            }
        }
        Ok(dvals)
    }
}

/// Achlioptas sparse random projection: entries `±√(3/Q)` with probability
/// 1/6 each, 0 with probability 2/3 — `E[AAᵀ] = I`, so `XA` approximately
/// preserves pairwise geometry at `Q = O(log N / ε²)`.
///
/// Non-zeros are stored CSC-style (grouped per **output** column): each
/// output element is one contiguous gather-and-accumulate over its
/// column's entries, instead of the old full-triplet scan with scattered
/// writes across the whole output row per input row — `Q×` less write
/// traffic and sequential reads of the entry list (micro-benched in
/// `benches/linalg_kernels.rs`). Values are bit-identical to the scatter
/// formulation: within a column, entries keep ascending input-row order,
/// which is exactly the order the scatter accumulated them in.
#[derive(Debug, Clone)]
pub struct SparseProjection {
    /// `entries[col_ptr[j]..col_ptr[j+1]]` = the (input row, sign) pairs
    /// of output column `j`, ascending by input row.
    col_ptr: Vec<usize>,
    entries: Vec<(u32, f32)>,
    p: usize,
    q: usize,
    scale: f64,
}

impl SparseProjection {
    /// Sample a projection from `p` dims down to `q`.
    pub fn sample(p: usize, q: usize, rng: &mut Rng) -> SparseProjection {
        assert!(q >= 1);
        // Draw in (row, col) order — the RNG stream is part of the
        // reproducibility contract — then regroup by column.
        let mut triplets = Vec::with_capacity(p * q / 3 + 1);
        for i in 0..p {
            for j in 0..q {
                let r = rng.below(6);
                if r == 0 {
                    triplets.push((i as u32, j as u32, 1.0f32));
                } else if r == 1 {
                    triplets.push((i as u32, j as u32, -1.0f32));
                }
            }
        }
        // Counting sort by output column; row-major draw order means each
        // column's entries land in ascending input-row order.
        let mut col_ptr = vec![0usize; q + 1];
        for &(_, j, _) in &triplets {
            col_ptr[j as usize + 1] += 1;
        }
        for j in 0..q {
            // lint:allow(float_accum, reason = "integer CSC prefix sum — exact arithmetic")
            col_ptr[j + 1] += col_ptr[j];
        }
        let mut next = col_ptr.clone();
        let mut entries = vec![(0u32, 0.0f32); triplets.len()];
        for &(i, j, s) in &triplets {
            entries[next[j as usize]] = (i, s);
            next[j as usize] += 1;
        }
        SparseProjection { col_ptr, entries, p, q, scale: (3.0 / q as f64).sqrt() }
    }

    /// Output dimensionality.
    pub fn q(&self) -> usize {
        self.q
    }

    /// Fraction of non-zero entries (≈1/3).
    pub fn density(&self) -> f64 {
        self.entries.len() as f64 / (self.p * self.q) as f64
    }

    /// Project a data matrix: `X A` (`N×P` → `N×Q`).
    pub fn project(&self, x: &Mat) -> Mat {
        self.project_pool(x, None)
    }

    /// [`SparseProjection::project`] under a [`ComputeContext`]: output
    /// rows are independent, so they fan out over the context's pool —
    /// per-row arithmetic is untouched, making the pooled projection
    /// bit-identical to the serial one (`--threads` now reaches the §4.5
    /// "too many features" path).
    pub fn project_ctx(&self, x: &Mat, ctx: &ComputeContext<'_>) -> Mat {
        self.project_pool(x, ctx.pool())
    }

    /// [`SparseProjection::project`] with an explicit optional pool.
    pub fn project_pool(&self, x: &Mat, pool: Option<&ThreadPool>) -> Mat {
        assert_eq!(x.cols(), self.p, "projection dimension mismatch");
        let n = x.rows();
        let q = self.q;
        let mut out = Mat::zeros(n, q);
        let project_rows = |lo: usize, rows: &mut [f64]| {
            for (r, orow) in rows.chunks_mut(q).enumerate() {
                let row = x.row(lo + r);
                for (j, o) in orow.iter_mut().enumerate() {
                    let mut acc = 0.0f64;
                    for &(pi, sign) in &self.entries[self.col_ptr[j]..self.col_ptr[j + 1]] {
                        // lint:allow(float_accum, reason = "SparseProjection's own serial kernel; this loop is its canonical accumulation order")
                        acc += sign as f64 * row[pi as usize];
                    }
                    *o = acc * self.scale;
                }
            }
        };
        match pool {
            Some(pool) if pool.size() > 1 && n >= 2 && q > 0 => {
                let band_rows = n.div_ceil((pool.size() * 4).min(n));
                let project_rows = &project_rows;
                let jobs: Vec<_> = out
                    .as_mut_slice()
                    .chunks_mut(band_rows * q)
                    .enumerate()
                    .map(|(b, band)| move || project_rows(b * band_rows, band))
                    .collect();
                pool.scope(jobs);
            }
            _ => project_rows(0, out.as_mut_slice()),
        }
        out
    }
}

/// Ensemble of weak regularised-LDA learners (§4.5): each trained on a
/// random subset of features and samples; majority vote at prediction.
pub struct LdaEnsemble {
    members: Vec<(Vec<usize>, crate::model::lda_binary::BinaryLda)>,
}

impl LdaEnsemble {
    /// [`LdaEnsemble::train`] under a [`ComputeContext`] — members train in
    /// parallel on the context's pool; subset draws are consumed from `rng`
    /// *before* any training starts, so the ensemble is identical for any
    /// thread count (`--threads` now reaches the §4.5 "both too large"
    /// path).
    #[allow(clippy::too_many_arguments)]
    pub fn train_ctx(
        x: &Mat,
        labels: &[usize],
        n_members: usize,
        feat_frac: f64,
        sample_frac: f64,
        reg: Reg,
        ctx: &ComputeContext<'_>,
        rng: &mut Rng,
    ) -> Result<LdaEnsemble> {
        Self::train(x, labels, n_members, feat_frac, sample_frac, reg, ctx.pool(), rng)
    }

    /// Train `n_members` weak learners, each on `feat_frac` of the features
    /// and `sample_frac` of the samples, optionally in parallel on `pool`.
    #[allow(clippy::too_many_arguments)]
    pub fn train(
        x: &Mat,
        labels: &[usize],
        n_members: usize,
        feat_frac: f64,
        sample_frac: f64,
        reg: Reg,
        pool: Option<&crate::util::threadpool::ThreadPool>,
        rng: &mut Rng,
    ) -> Result<LdaEnsemble> {
        assert!(n_members >= 1);
        let p = x.cols();
        let n = x.rows();
        let n_feat = ((p as f64 * feat_frac).ceil() as usize).clamp(1, p);
        let n_samp = ((n as f64 * sample_frac).ceil() as usize).clamp(4, n);
        // A labelling missing a class can never produce a two-class
        // subsample — the old unbounded resample loop spun forever here.
        anyhow::ensure!(
            labels.iter().any(|&l| l == 0) && labels.iter().any(|&l| l == 1),
            "LdaEnsemble::train: both classes must be present in `labels` \
             (got a single-class labelling of {} samples)",
            labels.len()
        );
        // Bound the retries anyway: extreme imbalance + tiny sample_frac
        // can make a two-class draw arbitrarily rare.
        const MAX_RESAMPLE: usize = 1000;
        // Pre-draw subsets so training is deterministic regardless of pool.
        let draws: Vec<(Vec<usize>, Vec<usize>)> = (0..n_members)
            .map(|m| -> Result<(Vec<usize>, Vec<usize>)> {
                // resample until both classes present (bounded)
                for _ in 0..MAX_RESAMPLE {
                    let feats = rng.choose(p, n_feat);
                    let samps = rng.choose(n, n_samp);
                    let has0 = samps.iter().any(|&i| labels[i] == 0);
                    let has1 = samps.iter().any(|&i| labels[i] == 1);
                    if has0 && has1 {
                        return Ok((feats, samps));
                    }
                }
                anyhow::bail!(
                    "LdaEnsemble::train: member {m}: no subsample contained both classes \
                     after {MAX_RESAMPLE} draws — increase sample_frac or rebalance the data"
                )
            })
            .collect::<Result<Vec<_>>>()?;
        let train_one = |(feats, samps): &(Vec<usize>, Vec<usize>)| -> Result<(Vec<usize>, crate::model::lda_binary::BinaryLda)> {
            let xs = x.take(samps, feats);
            let ls: Vec<usize> = samps.iter().map(|&i| labels[i]).collect();
            let model = crate::model::lda_binary::BinaryLda::train(&xs, &ls, reg)?;
            Ok((feats.clone(), model))
        };
        let members: Vec<_> = match pool {
            Some(pool) => {
                let slots: Vec<std::sync::Mutex<Option<_>>> =
                    (0..n_members).map(|_| std::sync::Mutex::new(None)).collect();
                let slots_ref = &slots;
                let draws_ref = &draws;
                pool.for_each(n_members, move |i| {
                    // lint:allow(panic, reason = "pool job stores a computed value; a poisoned slot mutex is unreachable")
                    *slots_ref[i].lock().unwrap() = Some(train_one(&draws_ref[i]));
                });
                slots
                    .into_iter()
                    // lint:allow(panic, reason = "every slot is filled by for_each over 0..n_members, and no job panics while holding its lock")
                    .map(|s| s.into_inner().unwrap().unwrap())
                    .collect::<Result<Vec<_>>>()?
            }
            None => draws.iter().map(train_one).collect::<Result<Vec<_>>>()?,
        };
        Ok(LdaEnsemble { members })
    }

    /// Number of members.
    pub fn len(&self) -> usize {
        self.members.len()
    }

    /// Is the ensemble empty?
    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// Majority-vote prediction (ties → class 0, the "+1" class).
    pub fn predict(&self, x: &Mat) -> Vec<usize> {
        let n = x.rows();
        let mut votes1 = vec![0usize; n];
        for (feats, model) in &self.members {
            let xs = x.take_cols(feats);
            for (i, &l) in model.predict(&xs).iter().enumerate() {
                // lint:allow(float_accum, reason = "integer vote tally — exact arithmetic")
                votes1[i] += l;
            }
        }
        let half = self.members.len();
        votes1.iter().map(|&v| usize::from(2 * v > half)).collect()
    }
}

/// Analytic CV on randomly projected data: the §4.5 "too many features"
/// pipeline in one call. The historical entry point — primal hat, serial;
/// see [`projected_analytic_cv_ctx`] for the pooled/backended form.
pub fn projected_analytic_cv(
    x: &Mat,
    y: &[f64],
    folds: &[Vec<usize>],
    q: usize,
    lambda: f64,
    rng: &mut Rng,
) -> Result<Vec<f64>> {
    // Primal, serial: exactly the historical float path.
    projected_analytic_cv_ctx(
        x,
        y,
        folds,
        q,
        lambda,
        rng,
        &ComputeContext::serial().with_backend(GramBackend::Primal),
    )
}

/// [`projected_analytic_cv`] under a [`ComputeContext`]: the projection's
/// row loop, the hat build on the projected data, and the per-fold LU
/// factors all fan out over the context's pool (bit-identically —
/// `--threads` now reaches the whole §4.5 projection pipeline), and the
/// context's backend/tile knobs govern the hat on `XA`.
///
/// ```
/// use fastcv::cv::folds::kfold;
/// use fastcv::fastcv::bigdata::projected_analytic_cv_ctx;
/// use fastcv::fastcv::ComputeContext;
/// use fastcv::linalg::Mat;
/// use fastcv::util::rng::Rng;
///
/// let mut rng = Rng::new(11);
/// let x = Mat::from_fn(30, 400, |_, _| rng.gauss());   // P ≫ N
/// let y: Vec<f64> = (0..30).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
/// let folds = kfold(30, 3, &mut rng);
/// let ctx = ComputeContext::with_threads(2);
/// let dv = projected_analytic_cv_ctx(&x, &y, &folds, 50, 1.0, &mut rng, &ctx).unwrap();
/// assert_eq!(dv.len(), 30);
/// assert!(dv.iter().all(|v| v.is_finite()));
/// ```
pub fn projected_analytic_cv_ctx(
    x: &Mat,
    y: &[f64],
    folds: &[Vec<usize>],
    q: usize,
    lambda: f64,
    rng: &mut Rng,
    ctx: &ComputeContext<'_>,
) -> Result<Vec<f64>> {
    let proj = SparseProjection::sample(x.cols(), q, rng);
    let xq = proj.project_ctx(x, ctx);
    let cv = super::binary::AnalyticBinaryCv::fit_ctx(&xq, y, lambda, ctx)?;
    let cache = FoldCache::prepare_pool(&cv.hat, folds, false, ctx.pool())?;
    Ok(cv.decision_values_cached(&cache))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cv::folds::kfold;
    use crate::data::synthetic::{generate, SyntheticSpec};
    use crate::util::prop::assert_all_close;

    #[test]
    fn streaming_hat_matches_dense_hat() {
        let mut rng = Rng::new(1);
        let ds = generate(&SyntheticSpec::binary(50, 20), &mut rng);
        let y = ds.y_signed();
        let folds = kfold(50, 5, &mut rng);
        let dense = super::super::binary::AnalyticBinaryCv::fit(&ds.x, &y, 0.7).unwrap();
        let dv_dense = dense.decision_values(&folds).unwrap();
        let stream = StreamingHat::build(&ds.x, 0.7).unwrap();
        let dv_stream = stream.decision_values(&y, &folds).unwrap();
        assert_all_close(&dv_stream, &dv_dense, 1e-9, "streaming == dense");
        // block equality
        let te = &folds[0];
        let b1 = dense.hat.block(te);
        let b2 = stream.block(te);
        assert!(b1.max_abs_diff(&b2) < 1e-10);
    }

    #[test]
    fn streaming_memory_is_np_not_n2() {
        // structural check: StreamingHat holds two N×(P+1)-ish matrices only
        let mut rng = Rng::new(2);
        let ds = generate(&SyntheticSpec::binary(60, 5), &mut rng);
        let s = StreamingHat::build(&ds.x, 0.1).unwrap();
        assert_eq!(s.t.shape(), (60, 6));
        assert_eq!(s.xa.shape(), (60, 6));
    }

    #[test]
    fn backend_equivalence_streaming_dual_matches_dense_and_primal() {
        // Wide shape: the dual streaming hat must reproduce the primal
        // streaming hat and the dense engine to 1e-8 — blocks, fits, and
        // decision values — while storing only N×P state.
        let mut rng = Rng::new(7);
        let ds = generate(&SyntheticSpec::binary(40, 120), &mut rng);
        let y = ds.y_signed();
        let folds = kfold(40, 5, &mut rng);
        let lambda = 0.9;
        let primal = StreamingHat::build_with(&ds.x, lambda, GramBackend::Primal, None).unwrap();
        let dual = StreamingHat::build_with(&ds.x, lambda, GramBackend::Dual, None).unwrap();
        assert_eq!(dual.t.shape(), (40, 120), "dual stores T_c (N×P)");
        let te = &folds[0];
        let b_p = primal.block(te);
        let b_d = dual.block(te);
        assert!(b_p.max_abs_diff(&b_d) < 1e-8, "|Δblock| = {}", b_p.max_abs_diff(&b_d));
        assert_all_close(&dual.fit_response(&y), &primal.fit_response(&y), 1e-8, "dual ŷ");
        let dv_p = primal.decision_values(&y, &folds).unwrap();
        let dv_d = dual.decision_values(&y, &folds).unwrap();
        assert_all_close(&dv_d, &dv_p, 1e-8, "streaming dual vs primal dvals");
        // Auto resolves to dual on this wide shape and to primal on tall.
        let auto = StreamingHat::build_with(&ds.x, lambda, GramBackend::Auto, None).unwrap();
        assert_eq!(auto.t.shape(), (40, 120));
        let tall = generate(&SyntheticSpec::binary(50, 10), &mut rng);
        let auto_tall =
            StreamingHat::build_with(&tall.x, lambda, GramBackend::Auto, None).unwrap();
        assert_eq!(auto_tall.t.shape(), (50, 11), "tall Auto keeps primal T = X̃S");
        // pooled K_c build is bit-identical
        let pool = crate::util::threadpool::ThreadPool::new(3);
        let dual_pooled =
            StreamingHat::build_with(&ds.x, lambda, GramBackend::Dual, Some(&pool)).unwrap();
        assert_eq!(dual.t.as_slice(), dual_pooled.t.as_slice());
    }

    #[test]
    fn tiled_streaming_dual_bitwise_matches_untiled_across_tile_sizes() {
        // Acceptance: the tiled dual streaming build — slab-assembled K_c,
        // in-place blocked Cholesky, in-place solve — reproduces the
        // one-shot build to the last bit across tile heights {1, 7, N, N+3}
        // (remainder panel included), serial and pooled.
        use crate::fastcv::ComputeContext;
        let mut rng = Rng::new(19);
        let n = 26;
        let ds = generate(&SyntheticSpec::binary(n, 80), &mut rng);
        let y = ds.y_signed();
        let folds = kfold(n, 4, &mut rng);
        let lambda = 0.7;
        let reference = StreamingHat::build_with(&ds.x, lambda, GramBackend::Dual, None).unwrap();
        let dv_ref = reference.decision_values(&y, &folds).unwrap();
        for tile in [1usize, 7, n, n + 3] {
            for threads in [1usize, 4] {
                let ctx = ComputeContext::with_threads(threads)
                    .with_backend(GramBackend::Dual)
                    .with_tile_policy(TilePolicy::Rows(tile));
                let tiled = StreamingHat::build_ctx(&ds.x, lambda, &ctx).unwrap();
                assert_eq!(
                    reference.t.as_slice(),
                    tiled.t.as_slice(),
                    "T_c moved (tile={tile} threads={threads})"
                );
                assert_eq!(tiled.backend, GramBackend::Dual);
                let dv = tiled.decision_values(&y, &folds).unwrap();
                for (a, b) in dv_ref.iter().zip(&dv) {
                    assert_eq!(a.to_bits(), b.to_bits(), "dvals moved (tile={tile})");
                }
            }
        }
        // Budget policy engages and stays bitwise too.
        let ctx = ComputeContext::serial()
            .with_backend(GramBackend::Dual)
            .with_tile_policy(TilePolicy::Budget { bytes: 32 << 10 });
        let budget = StreamingHat::build_ctx(&ds.x, lambda, &ctx).unwrap();
        assert_eq!(reference.t.as_slice(), budget.t.as_slice());
        // …and an Off context reproduces build_with exactly (bitwise).
        let off = StreamingHat::build_ctx(
            &ds.x,
            lambda,
            &ComputeContext::serial().with_backend(GramBackend::Dual),
        )
        .unwrap();
        assert_eq!(reference.t.as_slice(), off.t.as_slice());
    }

    #[test]
    fn store_served_streaming_hat_bitwise_matches_fresh() {
        // A lent FactorStore must be a pure wall-clock knob: the fetched
        // Arc (hit) serves the exact floats a storeless build produces,
        // and the Spectral→Dual-coerced request keys separately from a
        // plain Dual one so its label survives caching.
        use crate::fastcv::ComputeContext;
        use crate::store::FactorStore;
        let mut rng = Rng::new(23);
        let ds = generate(&SyntheticSpec::binary(24, 70), &mut rng);
        let lambda = 0.4;
        let fresh = StreamingHat::build_ctx(
            &ds.x,
            lambda,
            &ComputeContext::serial().with_backend(GramBackend::Dual),
        )
        .unwrap();
        let store = FactorStore::new();
        let ctx = ComputeContext::serial()
            .with_backend(GramBackend::Dual)
            .with_store(&store);
        let first = StreamingHat::fetch_ctx(&ds.x, lambda, &ctx).unwrap();
        let second = StreamingHat::fetch_ctx(&ds.x, lambda, &ctx).unwrap();
        assert!(std::sync::Arc::ptr_eq(&first, &second), "second fetch must hit");
        assert_eq!(first.t.as_slice(), fresh.t.as_slice());
        assert_eq!(first.xa.as_slice(), fresh.xa.as_slice());
        // build_ctx with a store routes through the same cache entry.
        let cloned = StreamingHat::build_ctx(&ds.x, lambda, &ctx).unwrap();
        assert_eq!(cloned.t.as_slice(), fresh.t.as_slice());
        let stats = store.stats();
        assert_eq!((stats.hits, stats.misses, stats.entries), (2, 1, 1));
        // A Spectral request coerces to a dual build but keys on the
        // pre-coercion backend: it must NOT alias the Dual entry.
        let ctx_spec = ComputeContext::serial()
            .with_backend(GramBackend::Spectral)
            .with_store(&store);
        let coerced = StreamingHat::fetch_ctx(&ds.x, lambda, &ctx_spec).unwrap();
        assert!(!std::sync::Arc::ptr_eq(&first, &coerced));
        assert!(coerced.backend_label().contains("coerced"));
        assert!(!first.backend_label().contains("coerced"));
        assert_eq!(coerced.t.as_slice(), fresh.t.as_slice(), "same floats either key");
        assert_eq!(store.stats().entries, 2);
        assert!(first.resident_bytes() > 0);
    }

    #[test]
    fn spill_streaming_dual_bitwise_matches_one_shot() {
        // Acceptance: the out-of-core dual streaming build — K_c+λI panels
        // in a PanelStore, left-looking spilled factor, streamed solve —
        // reproduces the one-shot build to the last bit across tile
        // heights {1, 7, N, N+3}, RAM and disk panels, serial and pooled;
        // decision values follow bitwise.
        use crate::fastcv::ComputeContext;
        let mut rng = Rng::new(91);
        let n = 23;
        let ds = generate(&SyntheticSpec::binary(n, 70), &mut rng);
        let y = ds.y_signed();
        let folds = kfold(n, 4, &mut rng);
        let lambda = 0.8;
        let reference = StreamingHat::build_with(&ds.x, lambda, GramBackend::Dual, None).unwrap();
        let dv_ref = reference.decision_values(&y, &folds).unwrap();
        let base = std::env::temp_dir()
            .join(format!("fastcv-stream-spill-{}", std::process::id()));
        for tile in [1usize, 7, n, n + 3] {
            for disk in [false, true] {
                for threads in [1usize, 3] {
                    let dir = disk.then(|| base.clone());
                    let ctx = ComputeContext::with_threads(threads)
                        .with_backend(GramBackend::Dual)
                        .with_tile_policy(TilePolicy::Spill { dir, tile });
                    let spilled = StreamingHat::build_ctx(&ds.x, lambda, &ctx).unwrap();
                    assert_eq!(
                        reference.t.as_slice(),
                        spilled.t.as_slice(),
                        "T_c moved (tile={tile} disk={disk} threads={threads})"
                    );
                    assert_eq!(spilled.backend, GramBackend::Dual);
                    let dv = spilled.decision_values(&y, &folds).unwrap();
                    for (a, b) in dv_ref.iter().zip(&dv) {
                        assert_eq!(a.to_bits(), b.to_bits(), "dvals moved (tile={tile})");
                    }
                }
            }
        }
        // λ = 0 through the spilled path errors cleanly, like the dense dual
        let ctx = ComputeContext::serial()
            .with_backend(GramBackend::Dual)
            .with_tile_policy(TilePolicy::Spill { dir: None, tile: 8 });
        assert!(StreamingHat::build_ctx(&ds.x, 0.0, &ctx).is_err());
        let _ = std::fs::remove_dir_all(&base);
    }

    #[test]
    fn spill_streaming_primal_bitwise_matches_one_shot() {
        // The primal streaming arm honours TilePolicy::Spill too: G₀
        // panels + ridge-on-load factor + streamed solve must reproduce
        // the one-shot primal build (its Cholesky path) to the last bit —
        // no resident (P+1)×(P+1) on the tall quadrant either.
        use crate::fastcv::ComputeContext;
        let mut rng = Rng::new(92);
        let ds = generate(&SyntheticSpec::binary(40, 12), &mut rng);
        let y = ds.y_signed();
        let folds = kfold(40, 4, &mut rng);
        for lambda in [0.0, 0.5] {
            let reference = StreamingHat::build(&ds.x, lambda).unwrap();
            let dv_ref = reference.decision_values(&y, &folds).unwrap();
            for tile in [1usize, 7, 13, 16] {
                for policy in
                    [TilePolicy::Spill { dir: None, tile }, TilePolicy::Rows(tile)]
                {
                    let ctx = ComputeContext::with_threads(2)
                        .with_backend(GramBackend::Primal)
                        .with_tile_policy(policy.clone());
                    let spilled = StreamingHat::build_ctx(&ds.x, lambda, &ctx).unwrap();
                    assert_eq!(spilled.backend, GramBackend::Primal);
                    assert_eq!(spilled.t.shape(), (40, 13), "primal T = X̃S stays N×(P+1)");
                    assert_eq!(
                        reference.t.as_slice(),
                        spilled.t.as_slice(),
                        "primal T moved ({policy:?} λ={lambda})"
                    );
                    let dv = spilled.decision_values(&y, &folds).unwrap();
                    for (a, b) in dv_ref.iter().zip(&dv) {
                        assert_eq!(a.to_bits(), b.to_bits(), "dvals moved ({policy:?})");
                    }
                }
            }
        }
        // Wide + λ=0 through the spilled primal arm: the in-RAM LU
        // fallback has no out-of-core form — clean error, not a panic.
        let wide = generate(&SyntheticSpec::binary(12, 40), &mut rng);
        let ctx = ComputeContext::serial()
            .with_backend(GramBackend::Primal)
            .with_tile_policy(TilePolicy::Spill { dir: None, tile: 8 });
        let err = StreamingHat::build_ctx(&wide.x, 0.0, &ctx)
            .err()
            .expect("singular spilled primal gram must error");
        assert!(format!("{err:#}").contains("increase ridge"), "{err:#}");
    }

    #[test]
    fn streaming_spectral_request_is_coerced_to_dual_and_labelled() {
        // Small-fix satellite: a `--backend spectral` streaming build runs
        // the dual path — that was always the behaviour, but it was silent.
        // Pin it: the resolved backend reports Dual, the label path spells
        // out the coercion, and the numbers equal an explicit Dual build.
        let mut rng = Rng::new(20);
        let ds = generate(&SyntheticSpec::binary(18, 50), &mut rng);
        let spectral =
            StreamingHat::build_with(&ds.x, 0.9, GramBackend::Spectral, None).unwrap();
        assert_eq!(spectral.backend, GramBackend::Dual, "Spectral must coerce to Dual");
        assert!(
            spectral.backend_label().contains("spectral coerced"),
            "coercion missing from label: {}",
            spectral.backend_label()
        );
        assert!(spectral.backend_label().starts_with("dual"), "{}", spectral.backend_label());
        let dual = StreamingHat::build_with(&ds.x, 0.9, GramBackend::Dual, None).unwrap();
        assert_eq!(spectral.t.as_slice(), dual.t.as_slice(), "coerced build must equal dual");
        assert_eq!(dual.backend_label(), "dual", "no coercion note on a genuine dual build");
        // the primal/auto paths stay plainly labelled
        let primal = StreamingHat::build(&ds.x, 0.9).unwrap();
        assert_eq!(primal.backend, GramBackend::Primal);
        assert_eq!(primal.backend_label(), "primal");
    }

    #[test]
    fn backend_pool_project_ctx_bitwise_matches_serial() {
        // Row fan-out of the sparse projection is a pure wall-clock knob.
        use crate::fastcv::ComputeContext;
        let mut rng = Rng::new(21);
        let (p, q) = (300, 40);
        let proj = SparseProjection::sample(p, q, &mut rng);
        let x = Mat::from_fn(37, p, |_, _| rng.gauss());
        let serial = proj.project(&x);
        let ctx = ComputeContext::with_threads(4);
        let pooled = proj.project_ctx(&x, &ctx);
        assert_eq!(serial.as_slice(), pooled.as_slice());
        // serial ctx falls back to the serial kernel
        let none = proj.project_ctx(&x, &ComputeContext::serial());
        assert_eq!(serial.as_slice(), none.as_slice());
    }

    #[test]
    fn backend_pool_projected_cv_and_ensemble_ctx_match_historical() {
        // The ported §4.5 entry points: historical signatures delegate with
        // a serial context (bitwise), and a pooled context changes nothing.
        use crate::fastcv::ComputeContext;
        let mut rng_a = Rng::new(22);
        let mut rng_b = Rng::new(22);
        let mut rng_c = Rng::new(22);
        let ds = generate(&SyntheticSpec::binary(40, 200), &mut Rng::new(5));
        let y = ds.y_signed();
        let folds = kfold(40, 4, &mut Rng::new(6));
        let historical = projected_analytic_cv(&ds.x, &y, &folds, 60, 1.0, &mut rng_a).unwrap();
        let serial_ctx = projected_analytic_cv_ctx(
            &ds.x,
            &y,
            &folds,
            60,
            1.0,
            &mut rng_b,
            &ComputeContext::serial().with_backend(GramBackend::Primal),
        )
        .unwrap();
        let pooled_ctx = projected_analytic_cv_ctx(
            &ds.x,
            &y,
            &folds,
            60,
            1.0,
            &mut rng_c,
            &ComputeContext::with_threads(4).with_backend(GramBackend::Primal),
        )
        .unwrap();
        for ((a, b), c) in historical.iter().zip(&serial_ctx).zip(&pooled_ctx) {
            assert_eq!(a.to_bits(), b.to_bits(), "serial ctx moved the projected CV");
            assert_eq!(a.to_bits(), c.to_bits(), "pooled ctx moved the projected CV");
        }
        // ensemble: train_ctx(pooled) == train(serial) member-for-member
        let mut rng_d = Rng::new(23);
        let mut rng_e = Rng::new(23);
        let ds2 = generate(&SyntheticSpec::binary(60, 30), &mut Rng::new(7));
        let serial = LdaEnsemble::train(
            &ds2.x, &ds2.labels, 9, 0.4, 0.6, Reg::Ridge(1.0), None, &mut rng_d,
        )
        .unwrap();
        let ctx = ComputeContext::with_threads(3);
        let pooled = LdaEnsemble::train_ctx(
            &ds2.x, &ds2.labels, 9, 0.4, 0.6, Reg::Ridge(1.0), &ctx, &mut rng_e,
        )
        .unwrap();
        assert_eq!(serial.predict(&ds2.x), pooled.predict(&ds2.x));
    }

    #[test]
    fn ensemble_single_class_labels_errors_not_hangs() {
        // Regression: the resample loop could never see both classes and
        // span forever. Must bail with a clear error instead.
        let mut rng = Rng::new(8);
        let x = Mat::from_fn(20, 5, |_, _| rng.gauss());
        let labels = vec![0usize; 20];
        let res = LdaEnsemble::train(&x, &labels, 3, 0.5, 0.5, Reg::Ridge(1.0), None, &mut rng);
        let msg = format!("{:#}", res.err().expect("single-class labels must error"));
        assert!(msg.contains("both classes"), "unexpected error: {msg}");
        // ...and the all-class-1 flavour too.
        let labels = vec![1usize; 20];
        assert!(
            LdaEnsemble::train(&x, &labels, 3, 0.5, 0.5, Reg::Ridge(1.0), None, &mut rng)
                .is_err()
        );
    }

    #[test]
    fn projection_csc_matches_dense_reference() {
        // project(I_P) materialises the scaled dense A row by row; a random
        // X must then satisfy project(X) == X·A through the dense GEMM.
        let mut rng = Rng::new(9);
        let (p, q) = (60, 17);
        let proj = SparseProjection::sample(p, q, &mut rng);
        let dense_a = proj.project(&Mat::eye(p)); // P × Q, = scale·A
        let x = Mat::from_fn(8, p, |_, _| rng.gauss());
        let expect = crate::linalg::matmul(&x, &dense_a);
        let got = proj.project(&x);
        assert!(got.max_abs_diff(&expect) < 1e-10, "|Δ| = {}", got.max_abs_diff(&expect));
    }

    #[test]
    fn projection_preserves_geometry_approximately() {
        let mut rng = Rng::new(3);
        let p = 2000;
        let q = 300;
        let n = 20;
        let x = Mat::from_fn(n, p, |_, _| rng.gauss());
        let proj = SparseProjection::sample(p, q, &mut rng);
        assert!((proj.density() - 1.0 / 3.0).abs() < 0.03);
        let xq = proj.project(&x);
        assert_eq!(xq.shape(), (n, q));
        // pairwise squared distances preserved within ~35%
        for i in 0..5 {
            for j in (i + 1)..5 {
                let d_orig: f64 = (0..p).map(|k| (x[(i, k)] - x[(j, k)]).powi(2)).sum();
                let d_proj: f64 = (0..q).map(|k| (xq[(i, k)] - xq[(j, k)]).powi(2)).sum();
                let ratio = d_proj / d_orig;
                assert!((0.65..1.35).contains(&ratio), "ratio={ratio}");
            }
        }
    }

    #[test]
    fn projected_cv_still_decodes() {
        let mut rng = Rng::new(4);
        let mut spec = SyntheticSpec::binary(100, 800);
        spec.separation = 5.0;
        let ds = generate(&spec, &mut rng);
        let y = ds.y_signed();
        let folds = kfold(100, 5, &mut rng);
        // Unprojected baseline for context.
        let cv = super::super::binary::AnalyticBinaryCv::fit(&ds.x, &y, 1.0).unwrap();
        let acc_full = crate::cv::metrics::accuracy_signed(
            &cv.decision_values(&folds).unwrap(),
            &y,
        );
        let dv = projected_analytic_cv(&ds.x, &y, &folds, 200, 1.0, &mut rng).unwrap();
        let acc = crate::cv::metrics::accuracy_signed(&dv, &y);
        assert!(acc > 0.65, "projected CV acc={acc} (full-dim acc={acc_full})");
        assert!(acc_full > 0.75, "full-dim baseline acc={acc_full}");
    }

    #[test]
    fn ensemble_beats_weak_member_and_parallel_matches_serial() {
        let mut rng = Rng::new(5);
        let mut spec = SyntheticSpec::binary(120, 60);
        spec.separation = 1.6;
        let ds = generate(&spec, &mut rng);
        let mut rng_a = Rng::new(77);
        let mut rng_b = Rng::new(77);
        let serial = LdaEnsemble::train(
            &ds.x, &ds.labels, 15, 0.3, 0.6, Reg::Ridge(1.0), None, &mut rng_a,
        )
        .unwrap();
        let pool = crate::util::threadpool::ThreadPool::new(4);
        let parallel = LdaEnsemble::train(
            &ds.x, &ds.labels, 15, 0.3, 0.6, Reg::Ridge(1.0), Some(&pool), &mut rng_b,
        )
        .unwrap();
        let pred_s = serial.predict(&ds.x);
        let pred_p = parallel.predict(&ds.x);
        assert_eq!(pred_s, pred_p, "pool must not change results");
        let acc_ens = crate::cv::metrics::accuracy_labels(&pred_s, &ds.labels);
        // single weak member accuracy
        let (feats, model) = &serial.members[0];
        let acc_one = crate::cv::metrics::accuracy_labels(
            &model.predict(&ds.x.take_cols(feats)),
            &ds.labels,
        );
        assert!(
            acc_ens >= acc_one - 0.02,
            "ensemble {acc_ens} should not trail a weak member {acc_one}"
        );
        assert!(acc_ens > 0.7, "ensemble acc={acc_ens}");
    }
}
